"""Train the model family on the synthetic multi-task corpus.

Models (see DESIGN.md §1.3 — the laptop-scale substitution for the paper's
8B–235B targets):

    target  — 4-layer / d128 char LM, the model being accelerated
    sps     — 2-layer / d64 independent draft LM (standard SpS drafter)
    eagle   — 2-layer / d128 feature-conditioned drafter, KL-distilled
              from the target (EAGLE analog)
    medusa  — 4 residual heads over target features (Medusa analog)

Outputs raw f32 little-endian .bin files + a meta JSON per model under
--out, consumed both by aot.py (to bake example inputs) and by the rust
runtime (weight upload at engine start).

Usage: cd python && python -m compile.train --out ../artifacts/weights
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from . import model as M
from . import tokenizer

SEQ = 128
BATCH = 8


def batches(seed: int):
    stream = data.token_stream(seed, SEQ, tokenizer)
    while True:
        rows = [next(stream) for _ in range(BATCH)]
        yield jnp.asarray(np.array(rows, np.int32))


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros(())}


def adamw_update(params, grads, opt, lr, wd=0.01, b1=0.9, b2=0.95, eps=1e-8):
    t = opt["t"] + 1.0
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"],
                     grads)
    mh = jax.tree.map(lambda x: x / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda x: x / (1 - b2 ** t), v)
    params = jax.tree.map(
        lambda p, mm, vv: p - lr * (mm / (jnp.sqrt(vv) + eps) + wd * p),
        params, mh, vh,
    )
    return params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total, peak=3e-3, warmup=50):
    warm = peak * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def train_model(loss_fn, params, steps, seed, label, log_every=100):
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, batch, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    gen = batches(seed)
    t0 = time.time()
    losses = []
    for i in range(steps):
        lr = cosine_lr(i, steps)
        params, opt, loss = step_fn(params, opt, next(gen), lr)
        if i % log_every == 0 or i == steps - 1:
            losses.append(float(loss))
            print(
                f"[{label}] step {i:5d}/{steps} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)",
                flush=True,
            )
    return params, losses


def save_model(params, path_prefix: str, cfg=None):
    names = M.flat_names(params)
    vals = M.flat_values(params)
    offsets, tensors = [], []
    off = 0
    for n, a in zip(names, vals):
        a = np.asarray(a, np.float32)
        tensors.append(a)
        offsets.append(
            {"name": n, "shape": list(a.shape), "offset": off,
             "size": int(a.size)}
        )
        off += a.size
    flat = np.concatenate([t.reshape(-1) for t in tensors])
    flat.astype("<f4").tofile(path_prefix + ".bin")
    meta = {"tensors": offsets, "total": int(flat.size)}
    if cfg is not None:
        meta["config"] = cfg.as_dict()
    with open(path_prefix + ".json", "w") as f:
        json.dump(meta, f, indent=1)
    print(f"saved {path_prefix}.bin ({flat.size * 4 / 1e6:.1f} MB)")


def load_model(path_prefix: str, template) -> dict:
    flat = np.fromfile(path_prefix + ".bin", dtype="<f4")
    with open(path_prefix + ".json") as f:
        meta = json.load(f)
    vals = []
    for t in meta["tensors"]:
        a = flat[t["offset"]: t["offset"] + t["size"]].reshape(t["shape"])
        vals.append(jnp.asarray(a))
    return M.unflatten_like(template, vals)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--target-steps", type=int, default=1800)
    ap.add_argument("--sps-steps", type=int, default=700)
    ap.add_argument("--eagle-steps", type=int, default=800)
    ap.add_argument("--medusa-steps", type=int, default=450)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    key = jax.random.PRNGKey(args.seed)
    kt, ks, ke, km = jax.random.split(key, 4)
    history = {}

    # -- target LM ---------------------------------------------------------
    target = M.init_lm(M.TARGET_CFG, kt)
    target, hist = train_model(
        lambda p, b: M.lm_loss(M.TARGET_CFG, p, b),
        target, args.target_steps, seed=1, label="target",
    )
    history["target"] = hist
    save_model(target, os.path.join(args.out, "target"), M.TARGET_CFG)

    # -- independent SpS draft LM -----------------------------------------
    sps = M.init_lm(M.DRAFT_CFG, ks)
    sps, hist = train_model(
        lambda p, b: M.lm_loss(M.DRAFT_CFG, p, b),
        sps, args.sps_steps, seed=2, label="sps",
    )
    history["sps"] = hist
    save_model(sps, os.path.join(args.out, "sps"), M.DRAFT_CFG)

    # -- EAGLE drafter (KL distillation from the frozen target) -----------
    eagle = M.init_eagle(M.EAGLE_CFG, ke, M.TARGET_CFG)
    eagle, hist = train_model(
        lambda p, b: M.eagle_loss(M.EAGLE_CFG, p, M.TARGET_CFG, target, b),
        eagle, args.eagle_steps, seed=3, label="eagle",
    )
    history["eagle"] = hist
    save_model(eagle, os.path.join(args.out, "eagle"), M.EAGLE_CFG)

    # -- Medusa heads ------------------------------------------------------
    medusa = M.init_medusa(km, M.TARGET_CFG)
    medusa, hist = train_model(
        lambda p, b: M.medusa_loss(p, M.TARGET_CFG, target, b),
        medusa, args.medusa_steps, seed=4, label="medusa",
    )
    history["medusa"] = hist
    save_model(medusa, os.path.join(args.out, "medusa"))

    with open(os.path.join(args.out, "train_history.json"), "w") as f:
        json.dump(history, f, indent=1)
    print("training complete")


if __name__ == "__main__":
    main()
