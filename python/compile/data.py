"""Synthetic task corpus — laptop-scale analogs of the paper's benchmarks.

Five task families, chosen so that each carries the *metric family* of the
corresponding benchmark in the MARS evaluation (see DESIGN.md §1.3):

    arith  — GSM8K analog          exact-match accuracy on the final answer
    code   — HumanEval/MBPP analog avg@k exact output match
    chat   — MT-Bench/Alpaca analog judge score (target loglik + keywords)
    sum    — CNN/DailyMail analog  ROUGE-L (lead-1 summarization convention)
    mt     — WMT19 Zh-En analog    BLEU / chrF on a deterministic cipher

Every example is `prompt -> completion`; training documents are
`prompt + completion + EOS`. The same templates (not the same RNG) are
re-implemented in `rust/src/datasets/` for serving-side evaluation.
"""

import random

TASKS = ("arith", "code", "chat", "sum", "mt")

# ---------------------------------------------------------------- arith ----


def gen_arith(rng: random.Random) -> tuple[str, str]:
    kind = rng.randrange(3)
    if kind == 0:  # single op
        a, b = rng.randrange(2, 99), rng.randrange(2, 99)
        op = rng.choice(["+", "-", "*"])
        if op == "-" and b > a:
            a, b = b, a
        if op == "*":
            a, b = rng.randrange(2, 12), rng.randrange(2, 12)
        val = eval(f"{a}{op}{b}")
        return f"Q: {a}{op}{b}=?\nA: ", f"{val}\n"
    if kind == 1:  # two-step with shown work (reasoning-trace analog)
        a, b = rng.randrange(2, 9), rng.randrange(2, 9)
        c = rng.randrange(2, 9)
        inner = b + c
        val = a * inner
        return (
            f"Q: {a}*({b}+{c})=?\nA: ",
            f"{b}+{c}={inner}; {a}*{inner}={val}\n",
        )
    # chained additions
    xs = [rng.randrange(1, 50) for _ in range(3)]
    s1 = xs[0] + xs[1]
    s2 = s1 + xs[2]
    return (
        f"Q: {xs[0]}+{xs[1]}+{xs[2]}=?\nA: ",
        f"{xs[0]}+{xs[1]}={s1}; {s1}+{xs[2]}={s2}\n",
    )


def arith_answer(completion: str) -> str:
    """Final answer = last integer in the completion."""
    tail = completion.strip().replace(";", " ").split()
    for tok in reversed(tail):
        t = tok.split("=")[-1]
        if t.lstrip("-").isdigit():
            return t
    return ""


# ----------------------------------------------------------------- code ----

_WORDS = [
    "ab", "cat", "dog", "sun", "map", "key", "box", "red", "ice", "owl",
    "pin", "fox", "jam", "log", "net", "orb", "paw", "rug", "sky", "toe",
]


def _code_eval(fn: str, args: list) -> str:
    if fn == "rep":
        return args[0] * args[1]
    if fn == "rev":
        return args[0][::-1]
    if fn == "up":
        return args[0].upper()
    if fn == "cat":
        return args[0] + args[1]
    if fn == "zip2":
        return "".join(a + b for a, b in zip(args[0], args[1]))
    raise ValueError(fn)


def gen_code(rng: random.Random) -> tuple[str, str]:
    fn = rng.choice(["rep", "rev", "up", "cat", "zip2"])
    w = rng.choice(_WORDS)
    if fn == "rep":
        n = rng.randrange(2, 5)
        call, out = f"rep('{w}',{n})", _code_eval(fn, [w, n])
    elif fn in ("cat", "zip2"):
        w2 = rng.choice(_WORDS)
        if fn == "zip2":
            m = min(len(w), len(w2))
            w, w2 = w[:m], w2[:m]
        call, out = f"{fn}('{w}','{w2}')", _code_eval(fn, [w, w2])
    else:
        call, out = f"{fn}('{w}')", _code_eval(fn, [w])
    return f">>> {call}\n", f"'{out}'\n"


# ----------------------------------------------------------------- chat ----

_KB = [
    ("Zorland", "Mirefal"), ("Quovia", "Bruntal"), ("Aldora", "Seaphor"),
    ("Vintria", "Caldus"), ("Norvand", "Tessily"), ("Ostrevia", "Palmyre"),
    ("Kelluna", "Dorvane"), ("Merrowin", "Ashford"), ("Tallgard", "Rivermoor"),
    ("Ulmstead", "Graypost"), ("Firelund", "Coldbay"), ("Westmarch", "Highfen"),
]
_COLORS = [
    ("bryleaf", "green"), ("sunpetal", "yellow"), ("mooncap", "white"),
    ("ashroot", "gray"), ("embervine", "red"), ("frostfern", "blue"),
]
_OPINIONS = [
    ("the sea", "The sea is wide and calm at dawn."),
    ("the forest", "The forest is quiet and full of tall trees."),
    ("the city", "The city is busy and bright at night."),
    ("the desert", "The desert is dry and still under the sun."),
    ("the mountain", "The mountain is steep and cold at the top."),
]


def gen_chat(rng: random.Random) -> tuple[str, str]:
    kind = rng.randrange(3)
    if kind == 0:
        c, cap = rng.choice(_KB)
        return (
            f"User: What is the capital of {c}?\nBot: ",
            f"The capital of {c} is {cap}.\n",
        )
    if kind == 1:
        plant, col = rng.choice(_COLORS)
        return (
            f"User: What color is the {plant} plant?\nBot: ",
            f"The {plant} plant is {col}.\n",
        )
    topic, sent = rng.choice(_OPINIONS)
    return (f"User: Write one sentence about {topic}.\nBot: ", sent + "\n")


def chat_keywords(prompt: str, completion: str) -> list[str]:
    """Keywords the judge checks for (ground-truth content words)."""
    words = [w.strip(".?,'") for w in completion.split()]
    return [w for w in words if w and w[0].isupper() or len(w) >= 5][:3]


# ------------------------------------------------------------------ sum ----

_SUBJ = ["The mayor", "A farmer", "The team", "One pilot", "The crew",
         "A doctor", "The judge", "A singer", "The coach", "An actor"]
_VERB = ["opened", "visited", "repaired", "sold", "found", "built",
         "closed", "painted", "moved", "won"]
_OBJ = ["the old bridge", "a small market", "the north road", "a red barn",
        "the city hall", "a fishing boat", "the corn field", "a stone well",
        "the town clock", "a long fence"]
_WHEN = ["on Monday", "last week", "in the spring", "at noon",
         "after the storm", "before dawn", "in early May", "this year"]


def _sentence(rng: random.Random) -> str:
    return (
        f"{rng.choice(_SUBJ)} {rng.choice(_VERB)} {rng.choice(_OBJ)} "
        f"{rng.choice(_WHEN)}."
    )


def gen_sum(rng: random.Random) -> tuple[str, str]:
    n = rng.randrange(2, 4)
    sents = [_sentence(rng) for _ in range(n)]
    # lead-1 convention: the reference summary is the first sentence.
    return ("Text: " + " ".join(sents) + "\nSummary: ", sents[0] + "\n")


# ------------------------------------------------------------------- mt ----

# Deterministic substitution cipher over lowercase letters (the "source
# language"); translation = inverse mapping. Model learns char-level MT.
_CIPHER_SHIFT = 7


def cipher_encode(text: str) -> str:
    out = []
    for ch in text:
        if "a" <= ch <= "z":
            out.append(chr((ord(ch) - 97 + _CIPHER_SHIFT) % 26 + 97))
        else:
            out.append(ch)
    return "".join(out)


_MT_POOL = [
    "the river runs past the mill",
    "a cold wind moves the tall grass",
    "the old man sells bread at the market",
    "two boats wait near the stone pier",
    "rain fell on the quiet village at night",
    "the children walk to school along the canal",
    "a gray cat sleeps on the warm roof",
    "the train leaves the station before sunrise",
    "farmers bring apples and corn to the square",
    "lanterns light the narrow street in winter",
    "the baker opens his shop at dawn",
    "soldiers marched over the wooden bridge",
    "a letter arrived from the far coast",
    "the bell rings twice at the old tower",
    "ships carry salt and wool across the bay",
    "the girl paints small birds on paper",
]


def gen_mt(rng: random.Random) -> tuple[str, str]:
    src = rng.choice(_MT_POOL)
    # optionally recombine halves for variety
    if rng.random() < 0.5:
        other = rng.choice(_MT_POOL)
        a, b = src.split()[: 4], other.split()[4:]
        if b:
            src = " ".join(a + b)
    return (f"Translate: {cipher_encode(src)}\nOutput: ", src + "\n")


# ------------------------------------------------------------- corpus ------

_GENS = {
    "arith": gen_arith,
    "code": gen_code,
    "chat": gen_chat,
    "sum": gen_sum,
    "mt": gen_mt,
}


def gen_example(task: str, rng: random.Random) -> tuple[str, str]:
    return _GENS[task](rng)


def gen_document(rng: random.Random) -> str:
    task = rng.choice(TASKS)
    p, c = gen_example(task, rng)
    return p + c


def token_stream(seed: int, seq_len: int, tokenizer):
    """Infinite stream of packed training sequences (list[int] of seq_len+1).

    Documents are concatenated with EOS separators and chunked; the +1 makes
    (input, shifted-target) pairs trivial to slice.
    """
    rng = random.Random(seed)
    buf: list[int] = []
    while True:
        while len(buf) < seq_len + 1:
            buf.extend(tokenizer.encode(gen_document(rng)) + [tokenizer.EOS])
        yield buf[: seq_len + 1]
        buf = buf[seq_len:]
