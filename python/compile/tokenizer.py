"""Byte-level tokenizer shared (by specification) with the rust runtime.

Vocabulary layout — must stay in sync with `rust/src/tokenizer/mod.rs`
and is exported to `artifacts/vocab.json` by aot.py:

    0        PAD
    1        BOS
    2        EOS
    3        SEP   (unused by tasks, reserved)
    4..98    printable ASCII 0x20..0x7E  (id = byte - 0x20 + 4)
    99       NL    ('\n')
    100..127 unused padding up to VOCAB = 128
"""

PAD, BOS, EOS, SEP = 0, 1, 2, 3
NL_ID = 99
VOCAB = 128
_ASCII_LO, _ASCII_HI = 0x20, 0x7E
_OFFSET = 4

SPECIALS = {"<pad>": PAD, "<bos>": BOS, "<eos>": EOS, "<sep>": SEP}


def encode(text: str, bos: bool = False, eos: bool = False) -> list[int]:
    """Encode text to token ids. Unknown characters map to ' ' (space)."""
    ids = [BOS] if bos else []
    for ch in text:
        b = ord(ch)
        if ch == "\n":
            ids.append(NL_ID)
        elif _ASCII_LO <= b <= _ASCII_HI:
            ids.append(b - _ASCII_LO + _OFFSET)
        else:
            ids.append(_OFFSET)  # space fallback
    if eos:
        ids.append(EOS)
    return ids


def decode(ids) -> str:
    """Decode ids to text, skipping specials."""
    out = []
    for t in ids:
        t = int(t)
        if t == NL_ID:
            out.append("\n")
        elif _OFFSET <= t < _OFFSET + (_ASCII_HI - _ASCII_LO + 1):
            out.append(chr(t - _OFFSET + _ASCII_LO))
        # specials / padding ids are dropped
    return "".join(out)


def vocab_spec() -> dict:
    """Machine-readable vocab description for artifacts/vocab.json."""
    return {
        "vocab_size": VOCAB,
        "pad": PAD,
        "bos": BOS,
        "eos": EOS,
        "sep": SEP,
        "nl": NL_ID,
        "ascii_lo": _ASCII_LO,
        "ascii_hi": _ASCII_HI,
        "ascii_offset": _OFFSET,
    }
