"""Single-source registry of every lowered executable.

This module is deliberately jax-free: it is the machine-readable half of
the exec-name contract. `aot.py` derives its STATELESS / BATCH_STATE sets
and per-executable weight families from here (and asserts its lowering
table covers exactly this set), `contracts.py` exports it into
`artifacts/contracts.json`, and `mars check contracts` diffs the rust
sources against that export — so renaming or adding a round program in
`rounds.py` without updating every mirror fails a gate instead of failing
at artifact-load time (or worse, silently dispatching the wrong program).

Each entry: name -> (stateless, batched, weight_families)
  stateless        no leading flat-state argument (prefill builds one)
  batched          leading state is the BATCH_MAX-stacked vector (§9.5)
  weight_families  parameter pytrees appended after state+extras, in order
"""

# fmt: off
EXECS = {
    # prefill + solo rounds
    "prefill":           (True,  False, ("target", "eagle", "sps")),
    "prefill_ext":       (False, False, ("target", "eagle", "sps")),
    "ar_step":           (False, False, ("target",)),
    "sps_round":         (False, False, ("target", "sps")),
    "eagle_tree_round":  (False, False, ("target", "eagle")),
    "medusa_round":      (False, False, ("target", "medusa")),
    "verify_ext_round":  (False, False, ("target",)),
    # fused multi-round variants (DESIGN.md §9.6)
    "ar_multi":          (False, False, ("target",)),
    "sps_multi":         (False, False, ("target", "sps")),
    "eagle_tree_multi":  (False, False, ("target", "eagle")),
    "medusa_multi":      (False, False, ("target", "medusa")),
    # host-side result extraction
    "extract":           (False, False, ()),
    "extract_probe":     (False, False, ()),
    # cross-sequence batching (DESIGN.md §9.5)
    "ar_batch":          (False, True,  ("target",)),
    "sps_batch":         (False, True,  ("target", "sps")),
    "eagle_tree_batch":  (False, True,  ("target", "eagle")),
    "medusa_batch":      (False, True,  ("target", "medusa")),
    "verify_ext_batch":  (False, True,  ("target",)),
    # batched round packing (§9.5 x §9.6)
    "ar_batch_multi":         (False, True, ("target",)),
    "sps_batch_multi":        (False, True, ("target", "sps")),
    "eagle_tree_batch_multi": (False, True, ("target", "eagle")),
    "medusa_batch_multi":     (False, True, ("target", "medusa")),
    # admission splices + batched extraction
    "batch_join":        (False, True,  ()),
    "batch_slot":        (False, True,  ()),
    "extract_batch":     (False, True,  ()),
}
# fmt: on


def stateless() -> set:
    """Names lowered without a leading flat-state argument."""
    return {n for n, (s, _, _) in EXECS.items() if s}


def batched() -> set:
    """Names whose leading state is the stacked batch vector."""
    return {n for n, (_, b, _) in EXECS.items() if b}


def weight_families(name: str) -> tuple:
    return EXECS[name][2]
