"""L2 — JAX model definitions: target LM, independent draft LM, EAGLE-style
feature-conditioned draft head, and Medusa heads.

Everything is written as pure functions over parameter pytrees (dicts with
sorted keys) so that flattening order is deterministic for the rust loader.

Cache-based block processing is the core primitive: `block_apply` consumes a
block of T tokens at given cache *slots* with given absolute *positions* and
an explicit [T, S_MAX] attention mask, writes K/V into the cache, and returns
(logits, hiddens, new_cache). Chain decoding, tree verification and prefill
are all expressed through it (see rounds.py).
"""

import jax
import jax.numpy as jnp

from . import tokenizer

# ----------------------------------------------------------- configs -------


class ModelCfg:
    """Static architecture hyper-parameters (baked into the HLO)."""

    def __init__(self, vocab, d_model, n_layers, n_heads, d_head, d_ff, s_max):
        self.vocab = vocab
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.d_head = d_head
        self.d_ff = d_ff
        self.s_max = s_max

    def as_dict(self):
        return dict(
            vocab=self.vocab, d_model=self.d_model, n_layers=self.n_layers,
            n_heads=self.n_heads, d_head=self.d_head, d_ff=self.d_ff,
            s_max=self.s_max,
        )


S_MAX = 352          # KV-cache capacity (prompt + generation + draft block)
P_MAX = 160          # max prompt tokens
OUT_MAX = 224        # max generated tokens

TARGET_CFG = ModelCfg(tokenizer.VOCAB, 128, 4, 4, 32, 512, S_MAX)
DRAFT_CFG = ModelCfg(tokenizer.VOCAB, 64, 2, 2, 32, 256, S_MAX)   # SpS LM
EAGLE_CFG = ModelCfg(tokenizer.VOCAB, 128, 2, 4, 32, 512, S_MAX)  # draft head
MEDUSA_HEADS = 4

# ------------------------------------------------------------ init ---------


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return jax.random.normal(key, shape, jnp.float32) * scale


def init_lm(cfg: ModelCfg, key) -> dict:
    """Initialize a decoder-only LM. Tied embedding/unembedding."""
    keys = jax.random.split(key, 3 + cfg.n_layers)
    params = {
        "emb": _dense_init(keys[0], (cfg.vocab, cfg.d_model), 0.02),
        "pos": _dense_init(keys[1], (cfg.s_max, cfg.d_model), 0.02),
        "lnf_g": jnp.ones((cfg.d_model,)),
        "lnf_b": jnp.zeros((cfg.d_model,)),
    }
    for i in range(cfg.n_layers):
        params[f"layer{i}"] = _init_layer(cfg, keys[3 + i])
    return params


def _init_layer(cfg: ModelCfg, key) -> dict:
    k = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff
    hk = cfg.n_heads * cfg.d_head
    return {
        "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
        "wqkv": _dense_init(k[0], (d, 3 * hk)),
        "bqkv": jnp.zeros((3 * hk,)),
        "wo": _dense_init(k[1], (hk, d)),
        "bo": jnp.zeros((d,)),
        "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
        "w1": _dense_init(k[2], (d, f)),
        "b1": jnp.zeros((f,)),
        "w2": _dense_init(k[3], (f, d)),
        "b2": jnp.zeros((d,)),
    }


def init_eagle(cfg: ModelCfg, key, target_cfg: ModelCfg) -> dict:
    """EAGLE-style drafter: fc([emb; feature]) -> small transformer."""
    k = jax.random.split(key, 3 + cfg.n_layers)
    params = {
        "emb": _dense_init(k[0], (cfg.vocab, cfg.d_model), 0.02),
        "pos": _dense_init(k[1], (cfg.s_max, cfg.d_model), 0.02),
        "fc_w": _dense_init(k[2], (cfg.d_model + target_cfg.d_model, cfg.d_model)),
        "fc_b": jnp.zeros((cfg.d_model,)),
        "lnf_g": jnp.ones((cfg.d_model,)),
        "lnf_b": jnp.zeros((cfg.d_model,)),
        "unemb": _dense_init(k[0], (cfg.d_model, cfg.vocab), 0.02),
    }
    for i in range(cfg.n_layers):
        params[f"layer{i}"] = _init_layer(cfg, k[3 + i])
    return params


def init_medusa(key, target_cfg: ModelCfg, n_heads: int = MEDUSA_HEADS) -> dict:
    """Medusa: n residual heads over the target's final hidden state."""
    d, v = target_cfg.d_model, target_cfg.vocab
    ks = jax.random.split(key, 2 * n_heads)
    params = {}
    for h in range(n_heads):
        params[f"head{h}_w1"] = _dense_init(ks[2 * h], (d, d))
        params[f"head{h}_b1"] = jnp.zeros((d,))
        params[f"head{h}_w2"] = _dense_init(ks[2 * h + 1], (d, v), 0.02)
    return params


# ------------------------------------------------------- primitives --------


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def empty_cache(cfg: ModelCfg):
    """KV cache: [n_layers, 2(kv), n_heads, s_max, d_head]."""
    return jnp.zeros(
        (cfg.n_layers, 2, cfg.n_heads, cfg.s_max, cfg.d_head), jnp.float32
    )


def _attn_block(cfg, layer, x, cache_l, slots, mask):
    """One pre-LN attention + MLP layer over a block.

    x:      [T, D] block activations
    cache_l:[2, H, S, Dh] this layer's cache
    slots:  [T] int32 cache rows where this block's K/V are written
    mask:   [T, S] float {0,1} — which cache rows each block position may
            attend to AFTER the block's own K/V have been written.
    """
    T = x.shape[0]
    H, Dh = cfg.n_heads, cfg.d_head
    h = layer_norm(x, layer["ln1_g"], layer["ln1_b"])
    qkv = h @ layer["wqkv"] + layer["bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(T, H, Dh).transpose(1, 0, 2)  # [H,T,Dh]
    k = k.reshape(T, H, Dh).transpose(1, 0, 2)
    v = v.reshape(T, H, Dh).transpose(1, 0, 2)

    # scatter block K/V into cache rows `slots`
    ck = cache_l[0].at[:, slots, :].set(k.transpose(0, 1, 2))  # [H,S,Dh]
    cv = cache_l[1].at[:, slots, :].set(v)

    scores = jnp.einsum("htd,hsd->hts", q, ck) / (Dh ** 0.5)
    scores = jnp.where(mask[None, :, :] > 0, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hts,hsd->htd", probs, cv)
    ctx = ctx.transpose(1, 0, 2).reshape(T, H * Dh)
    x = x + ctx @ layer["wo"] + layer["bo"]

    h2 = layer_norm(x, layer["ln2_g"], layer["ln2_b"])
    x = x + jax.nn.gelu(h2 @ layer["w1"] + layer["b1"]) @ layer["w2"] + layer["b2"]
    return x, jnp.stack([ck, cv])


def block_apply(cfg: ModelCfg, params, cache, tokens, slots, positions, mask,
                inputs_override=None):
    """Run a T-token block through an LM with explicit cache slots/mask.

    tokens:    [T] int32
    slots:     [T] int32 cache rows (junk rows are fine — they are masked
               and later overwritten; see DESIGN.md §1.2 rollback)
    positions: [T] int32 absolute sequence positions (for pos-emb)
    mask:      [T, S_MAX] float attend-permission matrix
    inputs_override: optional [T, D] residual-stream inputs replacing the
               token embedding (used by the EAGLE drafter).

    Returns (logits [T, V], hidden [T, D], new_cache).
    """
    positions = jnp.clip(positions, 0, cfg.s_max - 1)
    if inputs_override is None:
        x = params["emb"][tokens] + params["pos"][positions]
    else:
        x = inputs_override + params["pos"][positions]
    new_layers = []
    for i in range(cfg.n_layers):
        x, cl = _attn_block(cfg, params[f"layer{i}"], x, cache[i], slots, mask)
        new_layers.append(cl)
    new_cache = jnp.stack(new_layers)
    h = layer_norm(x, params["lnf_g"], params["lnf_b"])
    if "unemb" in params:
        logits = h @ params["unemb"]
    else:
        logits = h @ params["emb"].T  # tied
    return logits, h, new_cache


def eagle_inputs(eagle_params, tokens, feats):
    """EAGLE drafter residual-stream inputs: fc([emb(tok); feature])."""
    e = eagle_params["emb"][tokens]
    x = jnp.concatenate([e, feats], axis=-1)
    return x @ eagle_params["fc_w"] + eagle_params["fc_b"]


def medusa_head_logits(medusa_params, feat, n_heads: int = MEDUSA_HEADS):
    """All Medusa head logits for one feature vector. Returns [n_heads, V]."""
    outs = []
    for h in range(n_heads):
        z = feat @ medusa_params[f"head{h}_w1"] + medusa_params[f"head{h}_b1"]
        z = jax.nn.silu(z) + feat
        outs.append(z @ medusa_params[f"head{h}_w2"])
    return jnp.stack(outs)


# -------------------------------------------------- training forward -------


def causal_lm_logits(cfg: ModelCfg, params, tokens):
    """Plain causal forward for training. tokens [B, T] -> (logits, hidden)."""
    B, T = tokens.shape

    def one(toks):
        cache = empty_cache(cfg)
        slots = jnp.arange(T, dtype=jnp.int32)
        mask = (
            (jnp.arange(cfg.s_max)[None, :] <= slots[:, None])
            & (jnp.arange(cfg.s_max)[None, :] < T)
        ).astype(jnp.float32)
        logits, h, _ = block_apply(cfg, params, cache, toks, slots, slots, mask)
        return logits, h

    return jax.vmap(one)(tokens)


def lm_loss(cfg: ModelCfg, params, batch):
    """batch [B, T+1] -> mean CE of next-token prediction."""
    inp, tgt = batch[:, :-1], batch[:, 1:]
    logits, _ = causal_lm_logits(cfg, params, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def eagle_loss(cfg: ModelCfg, eagle_params, target_cfg, target_params, batch):
    """Distill the EAGLE drafter.

    Two terms, as in the EAGLE recipe:
    * token KL — at position i the drafter sees (token_i, target feature_i)
      and must match the target's distribution for token i+1;
    * feature regression — the drafter's own hidden at position i must
      approximate the target's feature at position i+1, because at draft
      time (beyond the first speculated token) that hidden *is* the feature
      fed to the next drafter step. Without this term the drafter is
      out-of-distribution from the second tree level on (tau caps at ~2).
    """
    inp = batch[:, :-1]
    t_logits, t_feats = causal_lm_logits(target_cfg, target_params, inp)
    t_logits = jax.lax.stop_gradient(t_logits)
    t_feats = jax.lax.stop_gradient(t_feats)
    B, T = inp.shape

    def one(toks, feats):
        cache = empty_cache(cfg)
        slots = jnp.arange(T, dtype=jnp.int32)
        mask = (
            (jnp.arange(cfg.s_max)[None, :] <= slots[:, None])
            & (jnp.arange(cfg.s_max)[None, :] < T)
        ).astype(jnp.float32)
        x = eagle_inputs(eagle_params, toks, feats)
        logits, hid, _ = block_apply(
            cfg, eagle_params, cache, toks, slots, slots, mask,
            inputs_override=x,
        )
        return logits, hid

    d_logits, d_hid = jax.vmap(one)(inp, t_feats)
    t_lp = jax.nn.log_softmax(t_logits, axis=-1)
    d_lp = jax.nn.log_softmax(d_logits, axis=-1)
    # forward KL(target || draft)
    kl = jnp.mean(jnp.sum(jnp.exp(t_lp) * (t_lp - d_lp), axis=-1))
    # feature regression: hidden_i ~ target feature_{i+1}
    feat_mse = jnp.mean((d_hid[:, :-1] - t_feats[:, 1:]) ** 2)
    return kl + 0.7 * feat_mse


def medusa_loss(medusa_params, target_cfg, target_params, batch,
                n_heads: int = MEDUSA_HEADS):
    """Medusa head h at position i predicts token i+1+h (ground-truth CE)."""
    inp = batch[:, :-1]
    _, feats = causal_lm_logits(target_cfg, target_params, inp)
    feats = jax.lax.stop_gradient(feats)
    B, T = inp.shape
    total = 0.0
    for h in range(n_heads):
        z = feats @ medusa_params[f"head{h}_w1"] + medusa_params[f"head{h}_b1"]
        z = jax.nn.silu(z) + feats
        logits = z @ medusa_params[f"head{h}_w2"]  # [B, T, V]
        valid = T - 1 - h
        if valid <= 0:
            continue
        tgt = batch[:, 1 + h: 1 + h + valid]
        lp = jax.nn.log_softmax(logits[:, :valid], axis=-1)
        ll = jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        total = total - jnp.mean(ll) * (0.8 ** h)
    return total


# ---------------------------------------------------- flatten helpers ------


def flat_names(params: dict, prefix="") -> list:
    """Deterministic flattening order: sorted nested dict keys."""
    names = []
    for k in sorted(params.keys()):
        v = params[k]
        if isinstance(v, dict):
            names.extend(flat_names(v, prefix + k + "."))
        else:
            names.append(prefix + k)
    return names


def flat_values(params: dict) -> list:
    vals = []
    for k in sorted(params.keys()):
        v = params[k]
        if isinstance(v, dict):
            vals.extend(flat_values(v))
        else:
            vals.append(v)
    return vals


def unflatten_like(params: dict, vals: list) -> dict:
    it = iter(vals)

    def rec(p):
        out = {}
        for k in sorted(p.keys()):
            v = p[k]
            out[k] = rec(v) if isinstance(v, dict) else next(it)
        return out

    return rec(params)
