"""L2 round programs — one JAX function per decode method.

Every function has the signature

    state' = round(state, *weight_arrays)

over the flat f32 state of state_spec.py, and is lowered by aot.py into a
standalone HLO-text artifact that the rust coordinator drives in a loop.
Runtime knobs (temperature, K, beam, greedy, and the verification-policy
triple (policy_id, p0, p1) — see state_spec.py POLICY_*) are *state
scalars*, so a single artifact covers the paper's whole ablation grid and
every verification policy.

Methods:
    prefill           build the initial state from a prompt
    prefill_ext       extend a restored prefix-cache snapshot with the
                      uncached token suffix (DESIGN.md §8)
    ar_step           vanilla autoregressive decoding (the 1.00x baseline)
    sps_round         standard speculative sampling (Leviathan-style
                      rejection sampling, independent draft LM) + MARS
    eagle_tree_round  EAGLE-style feature-conditioned drafter with a
                      beam-built draft tree (chain == beam 1); tree verify
    medusa_round      Medusa heads with a static candidate tree
    verify_ext_round  verify host-provided draft tokens (PLD / Lookahead);
                      this is the pallas verify-kernel path
    ar_multi          up to `pack` fused ar_step rounds per device call
    sps_multi         up to `pack` fused sps_round rounds per device call
    eagle_tree_multi  up to `pack` fused eagle_tree_round rounds per call
    medusa_multi      up to `pack` fused medusa_round rounds per call
    extract           state -> scalars ++ out-ring (cheap per-round pull)
    extract_probe     state -> scalars ++ probe-ring (figures 1 & 4)
    *_batch           one round for each of BATCH_MAX stacked sequences
                      per dispatch (DESIGN.md §9.5); finished lanes are
                      whole-lane selected back, i.e. masked no-ops
    *_batch_multi     batched x packed: per-lane round budgets
    verify_ext_batch  batched host-draft verification (per-lane drafts)
    batch_join        splice a solo state into a batch lane (admission)
    batch_slot        extract one lane as a solo state (leave/snapshot)
    extract_batch     per-lane scalars ++ out-ring, one device call

Round packing (`*_multi`): the per-call dispatch tax (~0.5 ms `execute_b`
per round + one `extract` pull, DESIGN.md §1.1) is pure overhead the
paper's math never pays, so each device-coupled method also lowers a
fused variant that wraps its round body in a `lax.while_loop` running up
to `pack` rounds on-device. `pack` is a one-float extra input (the host's
adaptive controller shrinks it near the generation budget); the device
additionally caps it by the `rounds_per_call` cfg/state scalar and
`PACK_MAX`, and exits the loop the moment `finished` flips — every stop
condition (EOS, `max_new`, out-ring and context capacity) is folded into
that flag by `_commit`, so a packed call never runs overrun rounds.
Host-drafted methods (PLD / Lookahead) need fresh drafts each round and
keep the single-round `verify_ext_round` path.

KV rollback is positional (DESIGN.md §1.2): block rows are written at
slots >= pos; acceptance only advances pos, junk rows are overwritten by
the next round. Tree acceptance compacts the accepted path into contiguous
rows with a gather before committing.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import state_spec as S
from .kernels import verify_pallas, top2_pallas, ref

USE_PALLAS = os.environ.get("MARS_USE_PALLAS", "1") != "0"

_TOP2 = (lambda x: top2_pallas(x)) if USE_PALLAS else ref.top2_ref

NEG = -1e30


def topk_iter(x, k):
    """Iterative top-k via repeated argmax.

    jax.lax.top_k lowers to the `topk(..., largest=true)` HLO op, which the
    xla_extension 0.5.1 text parser (behind the rust `xla` crate) rejects.
    k is tiny here (<= C_MAX/B_MAX = 4), so k argmax passes are cheap and
    lower to plain reduce/iota ops that parse fine.

    x: [..., V] -> (vals [..., k], idx [..., k] int32)
    """
    vals, idxs = [], []
    cur = x
    for _ in range(k):
        i = jnp.argmax(cur, axis=-1)
        v = jnp.take_along_axis(cur, i[..., None], axis=-1)[..., 0]
        vals.append(v)
        idxs.append(i.astype(jnp.int32))
        cur = jnp.where(
            jax.nn.one_hot(i, x.shape[-1], dtype=bool), NEG, cur
        )
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


# ------------------------------------------------------------ helpers ------


def _key(v: S.View):
    """Derive a fresh PRNG key from (seed, counter) and bump the counter."""
    k = jax.random.fold_in(
        jax.random.PRNGKey(7), v.geti("seed") * 65536 + v.geti("rng")
    )
    v.add("rng", 1.0)
    return k


def _sample_rows(v: S.View, dists):
    """Sample one token per row of `dists` [R, V] at the state temperature.

    Greedy (flag) -> argmax. Returns int32 [R].
    """
    g = jax.random.gumbel(_key(v), dists.shape)
    temp = jnp.maximum(v.get("temp"), 1e-6)
    stoch = jnp.argmax(dists / temp + g, axis=-1)
    det = jnp.argmax(dists, axis=-1)
    pick = jnp.where(v.get("greedy") > 0.5, det, stoch)
    return pick.astype(jnp.int32)


def _relax_gate(v, z1, z2):
    """Policy relaxation gate given the top-2 logits at a position.

    Elementwise over arrays (or scalars). The gate decides whether the
    target's *top-2 token* may be accepted without an exact match; the
    token-identity check (draft == i2) is applied by the caller. Mirrors
    rust/src/verify/mod.rs and kernels/mars_verify.py:

        strict  (0): never
        mars    (1): z1>0 and z2>0 and z2/z1 > p0
        topk    (2): p0>=2 and z1>0 and z2>0 and z2/z1 > 1-p1
                     (the device pipeline materializes top-2 only, so k is
                     clamped to 2 on device; k>2 is host-reference-only)
        entropy (3): z1-z2 < p0 — the top-2 entropy H(sigma(z1-z2)) is
                     strictly decreasing in the logit gap, so an entropy
                     floor is a gap ceiling in nats
    """
    pid = v.get("policy_id")
    p0 = v.get("p0")
    p1 = v.get("p1")
    safe = (z1 > 0.0) & (z2 > 0.0)
    r = jnp.where(safe, z2 / jnp.maximum(z1, 1e-9), 0.0)
    mars = (pid == S.POLICY_MARS) & safe & (r > p0)
    topk = (pid == S.POLICY_TOPK) & (p0 >= 2.0) & safe & (r > 1.0 - p1)
    ent = (pid == S.POLICY_ENTROPY) & ((z1 - z2) < p0)
    return mars | topk | ent


def _causal_mask(slots, limit):
    """mask[i, j] = j <= slots[i] and j < limit-ish window. [T, S_MAX]."""
    cols = jnp.arange(M.S_MAX)[None, :]
    return (cols <= slots[:, None]).astype(jnp.float32)


def _target_block(v, t_params, tokens, slots, positions, mask):
    logits, hid, tkv = M.block_apply(
        M.TARGET_CFG, t_params, v.tkv, tokens, slots, positions, mask
    )
    v.tkv = tkv
    v.add("target_calls", 1.0)
    return logits, hid


def _eagle_block(v, e_params, tokens, feats, slots, positions, mask):
    x = M.eagle_inputs(e_params, tokens, feats)
    logits, hid, ekv = M.block_apply(
        M.EAGLE_CFG, e_params, v.ekv, tokens, slots, positions, mask,
        inputs_override=x,
    )
    v.ekv = ekv
    v.add("draft_steps", 1.0)
    return logits, hid


def _sps_block(v, s_params, tokens, slots, positions, mask):
    logits, hid, skv = M.block_apply(
        M.DRAFT_CFG, s_params, v.skv, tokens, slots, positions, mask
    )
    v.skv = skv
    v.add("draft_steps", 1.0)
    return logits, hid


def _catchup_eagle(v, e_params):
    """Process tokens [w .. pos-1] through the drafter (teacher-forced with
    true target features). Returns (drafter dist for position pos, drafter
    hidden at pos-1). Idempotent re-processing of the last row keeps the
    window logic uniform on the first round after prefill."""
    n = v.geti("pos")
    w = jnp.maximum(jnp.minimum(v.geti("eagle_pos"), n - 1), 0)
    ln = n - w  # 1 .. CATCHUP_MAX
    idx = w + jnp.arange(S.CATCHUP_MAX, dtype=jnp.int32)
    idx_c = jnp.minimum(idx, M.S_MAX - 1)
    toks = v.tokens[idx_c].astype(jnp.int32)
    feats = v.feat[idx_c]
    mask = _causal_mask(idx, n) * (
        jnp.arange(S.CATCHUP_MAX)[:, None] < ln
    ).astype(jnp.float32)
    logits, hid = _eagle_block(v, e_params, toks, feats, idx_c, idx_c, mask)
    last = jnp.minimum(ln - 1, S.CATCHUP_MAX - 1)
    v.set("eagle_pos", n.astype(jnp.float32))
    return logits[last], hid[last]


def _catchup_sps(v, s_params):
    """Same as _catchup_eagle for the independent SpS draft LM."""
    n = v.geti("pos")
    w = jnp.maximum(jnp.minimum(v.geti("sps_pos"), n - 1), 0)
    ln = n - w
    idx = w + jnp.arange(S.CATCHUP_MAX, dtype=jnp.int32)
    idx_c = jnp.minimum(idx, M.S_MAX - 1)
    toks = v.tokens[idx_c].astype(jnp.int32)
    mask = _causal_mask(idx, n) * (
        jnp.arange(S.CATCHUP_MAX)[:, None] < ln
    ).astype(jnp.float32)
    logits, _ = _sps_block(v, s_params, toks, idx_c, idx_c, mask)
    last = jnp.minimum(ln - 1, S.CATCHUP_MAX - 1)
    v.set("sps_pos", n.astype(jnp.float32))
    return logits[last]


def _probe_push(v, z1s, z2s, flags, count):
    """Append `count` (z1, z2, flag) rows to the probe ring (drop overflow)."""
    w = z1s.shape[0]
    on = v.get("probe_on") > 0.5
    base = v.geti("probe_len")
    j = jnp.arange(w)
    idx = jnp.where(
        on & (j < count), base + j, S.PROBE_MAX + 1  # dropped
    )
    rows = jnp.stack([z1s, z2s, flags], axis=1)
    v.probe = v.probe.at[idx, :].set(rows, mode="drop")
    v.set(
        "probe_len",
        jnp.minimum(
            v.get("probe_len") + jnp.where(on, count, 0).astype(jnp.float32),
            float(S.PROBE_MAX),
        ),
    )


def _commit(v, t_params, toks, m):
    """Commit `m` accepted tokens + 1 final (correction/bonus) token.

    toks: f32/int32 [CATCHUP_MAX] — toks[0..m-1] accepted (already in the
    target cache at rows n..n+m-1), toks[m] the final token (not yet
    processed). Handles EOS truncation, the final-token target step,
    out-ring append, stop flags and stats.
    """
    n = v.geti("pos")
    toks = toks.astype(jnp.int32)
    j = jnp.arange(S.CATCHUP_MAX)
    eos = v.geti("eos")
    total = m + 1

    # a finished state is inert: rounds become no-ops so the host may run
    # several rounds blindly between extract() pulls (perf lever)
    already_done = v.get("finished") > 0.5

    # EOS truncation: keep tokens up to and including the first EOS
    is_eos = (toks == eos) & (j < total)
    any_eos = jnp.any(is_eos)
    first_eos = jnp.argmax(is_eos)  # valid only if any_eos
    new_count = jnp.where(any_eos, first_eos + 1, total)
    new_count = jnp.where(already_done, 0, new_count)

    # final token step (token toks[m] at slot n+m); junk if truncated early
    fin_tok = toks[jnp.minimum(m, S.CATCHUP_MAX - 1)][None]
    fin_slot = jnp.minimum(n + m, M.S_MAX - 1)[None]
    mask = _causal_mask(fin_slot, n + m + 1)
    logits, hid = _target_block(
        v, t_params, fin_tok, fin_slot, fin_slot, mask
    )
    v.next_logits = jnp.where(already_done, v.next_logits, logits[0])
    # the final token's feature must land in the feat cache too — the
    # EAGLE drafter teacher-forces on it during the next catch-up
    v.feat = v.feat.at[fin_slot[0]].set(
        jnp.where(already_done, v.feat[fin_slot[0]], hid[0])
    )

    # sequence + out-ring bookkeeping
    tok_idx = jnp.where(j < new_count, n + j, M.S_MAX + 1)
    v.tokens = v.tokens.at[tok_idx].set(toks.astype(jnp.float32), mode="drop")
    out_base = v.geti("out_len")
    out_idx = jnp.where(j < new_count, out_base + j, M.OUT_MAX + 1)
    v.out = v.out.at[out_idx].set(toks.astype(jnp.float32), mode="drop")

    v.set("pos", (n + new_count).astype(jnp.float32))
    new_out = out_base + new_count
    v.set("out_len", jnp.minimum(new_out, M.OUT_MAX).astype(jnp.float32))
    done = already_done | (
        (any_eos & jnp.logical_not(already_done))
        | (new_out >= v.geti("max_new"))
        | (new_out >= M.OUT_MAX)
        | (n + new_count + S.CATCHUP_MAX + S.NODES_MAX >= M.S_MAX)
    )
    v.set("finished", jnp.where(done, 1.0, 0.0))
    v.add("rounds", jnp.where(already_done, 0.0, 1.0))
    v.add("committed", new_count.astype(jnp.float32))
    v.set("last_accept", m.astype(jnp.float32))
    return new_count


# ------------------------------------------------------------ prefill ------


def prefill(prompt, cfg, *t_e_s_weights):
    """Build the initial state. `prompt` f32 [P_MAX], `cfg` f32 [N_CFG]."""
    nt = len(_TARGET_NAMES)
    ne = len(_EAGLE_NAMES)
    t_params = M.unflatten_like(_TARGET_TREE, list(t_e_s_weights[:nt]))
    e_params = M.unflatten_like(_EAGLE_TREE, list(t_e_s_weights[nt:nt + ne]))
    s_params = M.unflatten_like(_SPS_TREE, list(t_e_s_weights[nt + ne:]))

    v = S.View(jnp.zeros((S.STATE_LEN,), jnp.float32))
    for name in ("temp", "p0", "p1", "policy_id", "kdraft", "max_new",
                 "eos", "beam", "branch", "probe_on", "greedy", "seed",
                 "rounds_per_call"):
        v.set(name, cfg[S.CFG[name]])
    plen = cfg[S.CFG["prompt_len"]].astype(jnp.int32)
    plen = jnp.clip(plen, 1, M.P_MAX)
    v.set("prompt_len", plen.astype(jnp.float32))
    v.set("pos", plen.astype(jnp.float32))
    v.set("eagle_pos", plen.astype(jnp.float32))
    v.set("sps_pos", plen.astype(jnp.float32))

    toks = prompt.astype(jnp.int32)
    v.tokens = v.tokens.at[: M.P_MAX].set(
        jnp.where(jnp.arange(M.P_MAX) < plen, prompt, 0.0)
    )
    slots = jnp.arange(M.P_MAX, dtype=jnp.int32)
    live = (jnp.arange(M.P_MAX)[:, None] < plen).astype(jnp.float32)
    mask = _causal_mask(slots, plen) * live

    t_logits, t_hid = _target_block(v, t_params, toks, slots, slots, mask)
    v.feat = v.feat.at[: M.P_MAX].set(t_hid)
    v.next_logits = t_logits[plen - 1]

    # drafter catch-up over the whole prompt
    e_logits, _ = _eagle_block(v, e_params, toks, t_hid, slots, slots, mask)
    s_logits, _ = _sps_block(v, s_params, toks, slots, slots, mask)
    return v.pack()


# -------------------------------------------------------- prefill_ext ------


def prefill_ext(state, ext, *t_e_s_weights):
    """Extend a prefilled state with a token suffix (prefix-cache reuse).

    `ext` f32 [P_MAX + 1] = [n, tok_0 .. tok_{P_MAX-1}]: the suffix of a
    prompt whose first `pos` tokens the state already encodes (a restored
    PrefixCache snapshot — DESIGN.md §8, restamped host-side by
    rust/src/runtime/state.rs before upload). Rows pos..pos+n-1 run
    through the target and both drafters exactly as `prefill` would have
    processed them, so `prefill(prefix ++ suffix)` and
    `prefill_ext(prefill(prefix), suffix)` agree on every live row; the
    rust side skips this call entirely on full-prompt hits (n == 0).
    """
    nt = len(_TARGET_NAMES)
    ne = len(_EAGLE_NAMES)
    t_params = M.unflatten_like(_TARGET_TREE, list(t_e_s_weights[:nt]))
    e_params = M.unflatten_like(_EAGLE_TREE, list(t_e_s_weights[nt:nt + ne]))
    s_params = M.unflatten_like(_SPS_TREE, list(t_e_s_weights[nt + ne:]))

    v = S.View(state)
    old = v.geti("pos")
    n = jnp.clip(ext[0].astype(jnp.int32), 0, M.P_MAX)
    n = jnp.minimum(n, M.P_MAX - old)  # whole prompt shares the budget
    new_len = old + n

    j = jnp.arange(M.P_MAX, dtype=jnp.int32)
    toks = ext[1:].astype(jnp.int32)
    live = j < n
    slots = jnp.minimum(old + j, M.S_MAX - 1)
    # suffix tokens land in the context ring at rows old..old+n-1
    tok_idx = jnp.where(live, old + j, M.S_MAX + 1)
    v.tokens = v.tokens.at[tok_idx].set(toks.astype(jnp.float32), mode="drop")

    # target over the suffix block: each row attends to the whole cached
    # prefix plus the suffix rows before it (dead lanes masked out, their
    # KV writes land at junk rows >= new_len, same as prefill's padding)
    mask = _causal_mask(slots, new_len) * live.astype(jnp.float32)[:, None]
    t_logits, t_hid = _target_block(v, t_params, toks, slots, slots, mask)
    feat_idx = jnp.where(live, old + j, M.S_MAX + 1)
    v.feat = v.feat.at[feat_idx].set(t_hid, mode="drop")
    last = jnp.clip(n - 1, 0, M.P_MAX - 1)
    v.next_logits = jnp.where(n > 0, t_logits[last], v.next_logits)

    # drafter catch-up over the suffix (teacher-forced, as in prefill)
    _eagle_block(v, e_params, toks, t_hid, slots, slots, mask)
    _sps_block(v, s_params, toks, slots, slots, mask)

    new_f = new_len.astype(jnp.float32)
    v.set("pos", new_f)
    v.set("eagle_pos", new_f)
    v.set("sps_pos", new_f)
    v.set("prompt_len", new_f)
    return v.pack()


# ------------------------------------------------------------ ar_step ------


def ar_step(state, *t_weights):
    """One vanilla AR step: sample from next_logits, process, append."""
    t_params = M.unflatten_like(_TARGET_TREE, list(t_weights))
    v = S.View(state)
    tok = _sample_rows(v, v.next_logits[None, :])[0]
    toks = jnp.zeros((S.CATCHUP_MAX,), jnp.int32).at[0].set(tok)
    _commit(v, t_params, toks, jnp.asarray(0, jnp.int32))
    # AR emits exactly one token per round; rounds/committed stats still
    # advance inside _commit, which is what tau excludes for the baseline.
    return v.pack()


# ------------------------------------------------------------- sps ---------


def sps_round(state, *weights):
    """Standard speculative sampling round (chain, independent draft LM).

    Exact Leviathan rejection sampling under the strict policy; relaxed
    policies apply their gate only on a rejection (accept the draft if it
    is the target's top-2 and the policy gate passes — e.g. MARS: r > θ on
    the positive domain).
    """
    nt = len(_TARGET_NAMES)
    t_params = M.unflatten_like(_TARGET_TREE, list(weights[:nt]))
    s_params = M.unflatten_like(_SPS_TREE, list(weights[nt:]))
    v = S.View(state)
    n = v.geti("pos")
    k_rt = jnp.clip(v.geti("kdraft"), 1, S.K_MAX)
    temp = jnp.maximum(v.get("temp"), 1e-6)
    greedy = v.get("greedy") > 0.5

    q0 = _catchup_sps(v, s_params)

    # ---- draft K tokens sequentially (dynamic bound while_loop) ----
    gum = jax.random.gumbel(_key(v), (S.K_MAX, M.TARGET_CFG.vocab))

    def draft_body(carry):
        i, cur_logits, toks, qs, skv = carry
        stoch = jnp.argmax(cur_logits / temp + gum[i], axis=-1)
        det = jnp.argmax(cur_logits)
        tok = jnp.where(greedy, det, stoch).astype(jnp.int32)
        toks = toks.at[i].set(tok)
        qs = qs.at[i].set(jax.nn.softmax(cur_logits / temp))
        # one drafter step for the next draft position
        slot = jnp.minimum(n + i, M.S_MAX - 1)[None]
        mask = _causal_mask(slot, n + i + 1)
        logits, _, skv2 = M.block_apply(
            M.DRAFT_CFG, s_params, skv, tok[None], slot, slot, mask
        )
        return i + 1, logits[0], toks, qs, skv2

    def draft_cond(carry):
        return carry[0] < k_rt

    toks0 = jnp.zeros((S.K_MAX,), jnp.int32)
    qs0 = jnp.zeros((S.K_MAX, M.TARGET_CFG.vocab), jnp.float32)
    _, _, d_toks, d_qs, skv = jax.lax.while_loop(
        draft_cond, draft_body, (jnp.asarray(0, jnp.int32), q0, toks0, qs0,
                                 v.skv)
    )
    v.skv = skv
    v.add("draft_steps", k_rt.astype(jnp.float32))

    # ---- target verify block over the K draft tokens ----
    slots = jnp.minimum(n + jnp.arange(S.K_MAX, dtype=jnp.int32), M.S_MAX - 1)
    live = (jnp.arange(S.K_MAX)[:, None] < k_rt).astype(jnp.float32)
    mask = _causal_mask(slots, n + S.K_MAX) * live
    t_logits, t_hid = _target_block(v, t_params, d_toks, slots, slots, mask)
    v.feat = v.feat.at[slots, :].set(t_hid)

    # dists[i] = target dist used to judge draft token i
    dists = jnp.concatenate([v.next_logits[None, :], t_logits[:-1]], axis=0)
    ps = jax.nn.softmax(dists / temp, axis=-1)
    z1, z2, i1, i2 = _TOP2(dists)

    u = jax.random.uniform(_key(v), (S.K_MAX,))
    p_d = jnp.take_along_axis(ps, d_toks[:, None], axis=1)[:, 0]
    q_d = jnp.take_along_axis(d_qs, d_toks[:, None], axis=1)[:, 0]
    ratio = p_d / jnp.maximum(q_d, 1e-20)
    strict_ok = jnp.where(
        greedy, (d_toks == i1), u < jnp.minimum(ratio, 1.0)
    )
    relaxed_ok = (
        _relax_gate(v, z1, z2)
        & (d_toks == i2)
        & jnp.logical_not(strict_ok)
    )
    ok = (strict_ok | relaxed_ok) & (jnp.arange(S.K_MAX) < k_rt)
    prefix = jnp.cumprod(ok.astype(jnp.int32))
    m = jnp.sum(prefix)
    flags = jnp.where(prefix > 0, jnp.where(relaxed_ok, 2.0, 1.0), 0.0)

    # ---- correction / bonus token ----
    stop_dist = dists[jnp.minimum(m, S.K_MAX - 1)]
    stop_p = ps[jnp.minimum(m, S.K_MAX - 1)]
    stop_q = d_qs[jnp.minimum(m, S.K_MAX - 1)]
    resid = jnp.maximum(stop_p - stop_q, 0.0)
    resid_ok = jnp.sum(resid) > 1e-9
    resid = jnp.where(resid_ok, resid, stop_p)
    g = jax.random.gumbel(_key(v), (M.TARGET_CFG.vocab,))
    resid_tok = jnp.argmax(jnp.log(jnp.maximum(resid, 1e-30)) + g)
    greedy_tok = jnp.argmax(stop_dist)
    bonus_dist = t_logits[jnp.minimum(k_rt - 1, S.K_MAX - 1)]
    gb = jax.random.gumbel(_key(v), (M.TARGET_CFG.vocab,))
    bonus_tok = jnp.where(
        greedy,
        jnp.argmax(bonus_dist),
        jnp.argmax(bonus_dist / temp + gb),
    )
    all_ok = m >= k_rt
    fin = jnp.where(
        all_ok, bonus_tok, jnp.where(greedy, greedy_tok, resid_tok)
    ).astype(jnp.int32)

    # stats + probe
    v.add("exact_accepts", jnp.sum(flags == 1.0))
    v.add("relaxed_accepts", jnp.sum(flags == 2.0))
    v.add("rejects", jnp.where(all_ok, 0.0, 1.0))
    v.add("bonus", jnp.where(all_ok, 1.0, 0.0))
    probe_n = jnp.minimum(m + 1, k_rt)
    _probe_push(v, z1, z2, flags, probe_n)

    toks = jnp.zeros((S.CATCHUP_MAX,), jnp.int32)
    toks = toks.at[: S.K_MAX].set(d_toks)
    toks = toks.at[jnp.minimum(m, S.CATCHUP_MAX - 1)].set(fin)
    _commit(v, t_params, toks, m)
    return v.pack()


# ------------------------------------------------- tree infrastructure -----


def _tree_dists_and_walk(v, dists, node_tok, node_parent, node_level,
                         node_alive, depth_rt):
    """Walk the verified tree from the root (node 0), applying the
    configured verification policy at every level. Node layout: B_MAX root-level slots
    (only 0 live), then levels at stride B_MAX.

    dists [NODES_TOT, V]: row i = target dist AT node i (its children are
    judged against it). Returns (m, path, t_fin, flags, probe arrays).
    """
    ntot = dists.shape[0]
    z1, z2, i1, i2 = _TOP2(dists)
    tstar = _sample_rows(v, dists)
    node_idx = jnp.arange(ntot)

    def body(l, carry):
        cur, m, stopped, path, flags, pz1, pz2 = carry
        is_child = (
            (node_parent == cur)
            & node_alive
            & (node_level == l)
        )
        t_s = tstar[cur]
        exact_hits = is_child & (node_tok == t_s)
        any_exact = jnp.any(exact_hits)
        exact_idx = jnp.argmax(exact_hits)

        relax_hits = is_child & (node_tok == i2[cur])
        any_relax = (
            _relax_gate(v, z1[cur], z2[cur]) & jnp.any(relax_hits)
            & jnp.logical_not(any_exact)
        )
        relax_idx = jnp.argmax(relax_hits)

        active = (l <= depth_rt) & jnp.logical_not(stopped)
        accept = active & (any_exact | any_relax)
        nxt = jnp.where(any_exact, exact_idx, relax_idx)
        flag = jnp.where(
            accept, jnp.where(any_exact, 1.0, 2.0), 0.0
        )
        path = path.at[l - 1].set(jnp.where(accept, nxt, -1))
        flags = flags.at[l - 1].set(jnp.where(active, flag, -1.0))
        pz1 = pz1.at[l - 1].set(z1[cur])
        pz2 = pz2.at[l - 1].set(z2[cur])
        cur = jnp.where(accept, nxt, cur)
        m = m + jnp.where(accept, 1, 0)
        stopped = stopped | (active & jnp.logical_not(accept))
        return cur, m, stopped, path, flags, pz1, pz2

    path0 = jnp.full((S.DEPTH_MAX,), -1, jnp.int32)
    flags0 = jnp.full((S.DEPTH_MAX,), -1.0, jnp.float32)
    pz0 = jnp.zeros((S.DEPTH_MAX,), jnp.float32)
    cur, m, stopped, path, flags, pz1, pz2 = jax.lax.fori_loop(
        1, S.DEPTH_MAX + 1, body,
        (jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
         jnp.asarray(False), path0, flags0, pz0, pz0),
    )
    t_fin = tstar[cur]
    return m, path, t_fin, flags, pz1, pz2, z1, z2


def _tree_commit(v, t_params, node_tok, m, path, t_fin, flags, pz1, pz2,
                 depth_rt):
    """Compact the accepted path into contiguous cache rows and commit."""
    n = v.geti("pos")
    j = jnp.arange(S.DEPTH_MAX)
    # block row of node index i is (i - B_MAX); path entries are node idx
    src = jnp.where(path >= 0, n + path - S.B_MAX, n + j)
    dst = n + j
    perm = jnp.arange(M.S_MAX, dtype=jnp.int32)
    perm = perm.at[jnp.minimum(dst, M.S_MAX - 1)].set(
        jnp.minimum(src, M.S_MAX - 1)
    )
    # restore identity beyond m
    perm = jnp.where(
        (jnp.arange(M.S_MAX) >= n + m) & (jnp.arange(M.S_MAX) < n + S.DEPTH_MAX + 1),
        jnp.arange(M.S_MAX), perm,
    )
    v.tkv = v.tkv[:, :, :, perm, :]
    v.feat = v.feat[perm, :]

    # stats + probe
    live = flags >= 0.0
    v.add("exact_accepts", jnp.sum(jnp.where(live & (flags == 1.0), 1.0, 0.0)))
    v.add("relaxed_accepts", jnp.sum(jnp.where(live & (flags == 2.0), 1.0, 0.0)))
    all_ok = m >= depth_rt
    v.add("rejects", jnp.where(all_ok, 0.0, 1.0))
    v.add("bonus", jnp.where(all_ok, 1.0, 0.0))
    probe_n = jnp.minimum(m + 1, depth_rt)
    pflags = jnp.where(flags < 0.0, 0.0, flags)
    _probe_push(v, pz1, pz2, pflags, probe_n)

    toks = jnp.zeros((S.CATCHUP_MAX,), jnp.int32)
    path_tok = jnp.where(
        path >= 0, node_tok[jnp.maximum(path, 0)], 0
    ).astype(jnp.int32)
    toks = toks.at[: S.DEPTH_MAX].set(path_tok)
    toks = toks.at[jnp.minimum(m, S.CATCHUP_MAX - 1)].set(t_fin)
    _commit(v, t_params, toks, m)


# ------------------------------------------------------- eagle tree --------


def eagle_tree_round(state, *weights):
    """EAGLE-style drafter + beam draft tree + policy tree verify.

    beam == 1, branch == 1 reproduces EAGLE-chain; larger beams are the
    static-shape analog of EAGLE-2/3 dynamic trees (DESIGN.md §4).
    """
    nt = len(_TARGET_NAMES)
    t_params = M.unflatten_like(_TARGET_TREE, list(weights[:nt]))
    e_params = M.unflatten_like(_EAGLE_TREE, list(weights[nt:]))
    v = S.View(state)
    n = v.geti("pos")
    depth_rt = jnp.clip(v.geti("kdraft"), 1, S.DEPTH_MAX)
    beam_rt = jnp.clip(v.geti("beam"), 1, S.B_MAX)
    branch_rt = jnp.clip(v.geti("branch"), 1, S.C_MAX)

    root_dlog, root_feat = _catchup_eagle(v, e_params)

    ntot = S.NODES_MAX + S.B_MAX  # level-0 root slots + drafted nodes
    node_tok = jnp.zeros((ntot,), jnp.int32)
    node_parent = jnp.full((ntot,), -1, jnp.int32)
    node_level = jnp.arange(ntot, dtype=jnp.int32) // S.B_MAX
    node_cum = jnp.full((ntot,), NEG, jnp.float32).at[0].set(0.0)
    node_alive = jnp.zeros((ntot,), bool).at[0].set(True)
    node_feat = jnp.zeros((ntot, M.EAGLE_CFG.d_model), jnp.float32)
    node_feat = node_feat.at[0].set(root_feat)
    node_dlog = jnp.zeros((ntot, M.TARGET_CFG.vocab), jnp.float32)
    node_dlog = node_dlog.at[0].set(root_dlog)

    def level_body(l, carry):
        (node_tok, node_parent, node_cum, node_alive, node_feat,
         node_dlog, ekv) = carry
        active = l <= depth_rt
        f_rows = (l - 1) * S.B_MAX + jnp.arange(S.B_MAX)
        f_dlog = node_dlog[f_rows]                     # [B, V]
        f_cum = node_cum[f_rows]
        f_alive = node_alive[f_rows]
        f_logp = jax.nn.log_softmax(f_dlog, axis=-1)
        vals, idxs = topk_iter(f_logp, S.C_MAX)        # [B, C]
        cand_cum = f_cum[:, None] + vals
        rank_ok = jnp.arange(S.C_MAX)[None, :] < branch_rt
        cand_cum = jnp.where(
            rank_ok & f_alive[:, None] & active, cand_cum, NEG
        )
        flat_cum = cand_cum.reshape(-1)
        flat_tok = idxs.reshape(-1).astype(jnp.int32)
        flat_par = jnp.repeat(f_rows, S.C_MAX)
        top_vals, top_pos = topk_iter(flat_cum, S.B_MAX)
        new_rows = l * S.B_MAX + jnp.arange(S.B_MAX)
        sel_tok = flat_tok[top_pos]
        sel_par = flat_par[top_pos].astype(jnp.int32)
        sel_alive = (
            (top_vals > NEG / 2)
            & (jnp.arange(S.B_MAX) < beam_rt)
            & active
        )
        node_tok = node_tok.at[new_rows].set(sel_tok)
        node_parent = node_parent.at[new_rows].set(sel_par)
        node_cum = node_cum.at[new_rows].set(
            jnp.where(sel_alive, top_vals, NEG)
        )
        node_alive = node_alive.at[new_rows].set(sel_alive)

        # drafter processes the new level (batch of B nodes, tree mask)
        par_feat = node_feat[sel_par]
        slots = jnp.minimum(n + new_rows - S.B_MAX, M.S_MAX - 1)
        positions = jnp.minimum(n + l - 1, M.S_MAX - 1) * jnp.ones(
            (S.B_MAX,), jnp.int32
        )
        # ancestors: walk parent chain (<= DEPTH_MAX hops)
        anc_cols = _ancestor_mask(node_parent, new_rows, n)
        committed = (jnp.arange(M.S_MAX)[None, :] < n).astype(jnp.float32)
        self_col = jax.nn.one_hot(slots, M.S_MAX, dtype=jnp.float32)
        mask = jnp.clip(committed + anc_cols + self_col, 0.0, 1.0)
        x = M.eagle_inputs(e_params, sel_tok, par_feat)
        logits, hid, ekv = M.block_apply(
            M.EAGLE_CFG, e_params, ekv, sel_tok, slots, positions, mask,
            inputs_override=x,
        )
        node_dlog = node_dlog.at[new_rows].set(logits)
        node_feat = node_feat.at[new_rows].set(hid)
        return (node_tok, node_parent, node_cum, node_alive, node_feat,
                node_dlog, ekv)

    # while_loop (not fori to DEPTH_MAX): levels beyond the runtime depth
    # are dead, and skipping them saves ~30% of drafter compute at K=7
    def level_cond(carry):
        l = carry[0]
        return l <= depth_rt

    def level_step(carry):
        l = carry[0]
        rest = level_body(l, carry[1])
        return (l + 1, rest)

    (_, (node_tok, node_parent, node_cum, node_alive, node_feat, node_dlog,
         ekv)) = jax.lax.while_loop(
        level_cond, level_step,
        (jnp.asarray(1, jnp.int32),
         (node_tok, node_parent, node_cum, node_alive, node_feat, node_dlog,
          v.ekv)),
    )
    v.ekv = ekv
    v.add("draft_steps", depth_rt.astype(jnp.float32))

    # ---- target verify over the drafted block ----
    blk = jnp.arange(S.NODES_MAX)
    rows = S.B_MAX + blk
    toks_blk = node_tok[rows]
    slots = jnp.minimum(n + blk, M.S_MAX - 1).astype(jnp.int32)
    positions = jnp.minimum(n + node_level[rows] - 1, M.S_MAX - 1)
    anc_cols = _ancestor_mask(node_parent, rows, n)
    committed = (jnp.arange(M.S_MAX)[None, :] < n).astype(jnp.float32)
    self_col = jax.nn.one_hot(slots, M.S_MAX, dtype=jnp.float32)
    mask = jnp.clip(committed + anc_cols + self_col, 0.0, 1.0)
    mask = mask * node_alive[rows][:, None].astype(jnp.float32)
    t_logits, t_hid = _target_block(
        v, t_params, toks_blk, slots, positions, mask
    )
    v.feat = v.feat.at[slots, :].set(t_hid)

    dists = jnp.concatenate(
        [jnp.broadcast_to(v.next_logits, (S.B_MAX, M.TARGET_CFG.vocab)),
         t_logits], axis=0,
    )
    m, path, t_fin, flags, pz1, pz2, _, _ = _tree_dists_and_walk(
        v, dists, node_tok, node_parent, node_level, node_alive, depth_rt
    )
    _tree_commit(v, t_params, node_tok, m, path, t_fin, flags, pz1, pz2,
                 depth_rt)
    return v.pack()


def _ancestor_mask(node_parent, rows, n):
    """[len(rows), S_MAX] — allowed in-block ancestor columns per node.

    Walks each node's parent chain; root-level parents (< B_MAX) map to the
    committed prefix and are excluded (already covered by col < n)."""
    def chain(i):
        def hop(_, carry):
            cur, cols = carry
            par = node_parent[jnp.maximum(cur, 0)]
            is_block = (par >= S.B_MAX) & (cur >= 0)
            slot = jnp.minimum(n + par - S.B_MAX, M.S_MAX - 1)
            cols = jnp.where(
                is_block,
                cols + jax.nn.one_hot(slot, M.S_MAX, dtype=jnp.float32),
                cols,
            )
            cur = jnp.where(cur >= 0, par, cur)
            return cur, cols

        cols0 = jnp.zeros((M.S_MAX,), jnp.float32)
        _, cols = jax.lax.fori_loop(0, S.DEPTH_MAX, hop, (i, cols0))
        return cols

    return jax.vmap(chain)(rows.astype(jnp.int32))


# ------------------------------------------------------------ medusa -------

# Static Medusa candidate tree: (parent_node or -1 root, head, rank).
# 14 nodes over 4 heads, mirroring the Medusa paper's pruned cartesian tree.
_MEDUSA_TOPO = [
    (-1, 0, 0), (-1, 0, 1), (-1, 0, 2), (-1, 0, 3),   # level 1: 0..3
    (0, 1, 0), (0, 1, 1), (1, 1, 0), (1, 1, 1),       # level 2: 4..7
    (4, 2, 0), (4, 2, 1), (5, 2, 0), (6, 2, 0),       # level 3: 8..11
    (8, 3, 0), (8, 3, 1),                             # level 4: 12..13
]
MEDUSA_NODES = len(_MEDUSA_TOPO)
_MEDUSA_DEPTH = 4


def medusa_round(state, *weights):
    """Medusa-style round: head candidates in a static tree + tree verify."""
    nt = len(_TARGET_NAMES)
    t_params = M.unflatten_like(_TARGET_TREE, list(weights[:nt]))
    m_params = M.unflatten_like(_MEDUSA_TREE, list(weights[nt:]))
    v = S.View(state)
    n = v.geti("pos")
    depth_rt = jnp.minimum(
        jnp.clip(v.geti("kdraft"), 1, S.DEPTH_MAX), _MEDUSA_DEPTH
    )

    feat = v.feat[jnp.maximum(n - 1, 0)]
    heads = M.medusa_head_logits(m_params, feat)      # [H, V]
    v.add("draft_steps", 1.0)
    max_rank = 4
    _, topk_idx = topk_iter(heads, max_rank)          # [H, max_rank]

    # map static topology into the shared walk/commit frame:
    # node arrays sized B_MAX + NODES_MAX like the eagle tree.
    ntot = S.NODES_MAX + S.B_MAX
    topo_par = np.array([p for p, _, _ in _MEDUSA_TOPO], np.int32)
    topo_head = np.array([h for _, h, _ in _MEDUSA_TOPO], np.int32)
    topo_rank = np.array([r for _, _, r in _MEDUSA_TOPO], np.int32)
    topo_level = topo_head + 1

    # place medusa node j at frame row B_MAX + j; parent -1 -> root row 0
    frame_rows = S.B_MAX + np.arange(MEDUSA_NODES)
    par_rows = np.where(topo_par < 0, 0, S.B_MAX + topo_par).astype(np.int32)

    node_tok = jnp.zeros((ntot,), jnp.int32)
    node_tok = node_tok.at[jnp.asarray(frame_rows)].set(
        topk_idx[jnp.asarray(topo_head), jnp.asarray(topo_rank)].astype(
            jnp.int32
        )
    )
    node_parent = jnp.full((ntot,), -1, jnp.int32)
    node_parent = node_parent.at[jnp.asarray(frame_rows)].set(
        jnp.asarray(par_rows)
    )
    node_level = jnp.zeros((ntot,), jnp.int32)
    node_level = node_level.at[jnp.asarray(frame_rows)].set(
        jnp.asarray(topo_level)
    )
    node_alive = jnp.zeros((ntot,), bool)
    node_alive = node_alive.at[jnp.asarray(frame_rows)].set(
        jnp.asarray(topo_level) <= depth_rt
    )
    node_alive = node_alive.at[0].set(True)

    # target verify: medusa nodes occupy block rows 0..MEDUSA_NODES-1
    blk = jnp.arange(S.NODES_MAX)
    rows = S.B_MAX + blk
    live_blk = blk < MEDUSA_NODES
    toks_blk = node_tok[rows]
    slots = jnp.minimum(n + blk, M.S_MAX - 1).astype(jnp.int32)
    positions = jnp.minimum(
        n + jnp.maximum(node_level[rows] - 1, 0), M.S_MAX - 1
    )
    anc_cols = _ancestor_mask(node_parent, rows, n)
    committed = (jnp.arange(M.S_MAX)[None, :] < n).astype(jnp.float32)
    self_col = jax.nn.one_hot(slots, M.S_MAX, dtype=jnp.float32)
    mask = jnp.clip(committed + anc_cols + self_col, 0.0, 1.0)
    mask = mask * (node_alive[rows] & live_blk)[:, None].astype(jnp.float32)
    t_logits, t_hid = _target_block(
        v, t_params, toks_blk, slots, positions, mask
    )
    v.feat = v.feat.at[slots, :].set(t_hid)

    dists = jnp.concatenate(
        [jnp.broadcast_to(v.next_logits, (S.B_MAX, M.TARGET_CFG.vocab)),
         t_logits], axis=0,
    )
    m, path, t_fin, flags, pz1, pz2, _, _ = _tree_dists_and_walk(
        v, dists, node_tok, node_parent, node_level, node_alive, depth_rt
    )
    _tree_commit(v, t_params, node_tok, m, path, t_fin, flags, pz1, pz2,
                 depth_rt)
    return v.pack()


# -------------------------------------------------------- verify_ext -------


def verify_ext_round(state, ext, *t_weights):
    """Verify a host-provided draft chain (PLD / Lookahead drafts).

    ext: f32 [K_MAX + 1] = [ext_len, tok_0 .. tok_{K_MAX-1}].
    ext_len == 0 degenerates to one AR step (m = 0, emit target sample).
    This path runs the pallas verify kernel end to end.
    """
    t_params = M.unflatten_like(_TARGET_TREE, list(t_weights))
    v = S.View(state)
    n = v.geti("pos")
    k_rt = jnp.clip(ext[0].astype(jnp.int32), 0, S.K_MAX)
    d_toks = ext[1:].astype(jnp.int32)

    slots = jnp.minimum(n + jnp.arange(S.K_MAX, dtype=jnp.int32), M.S_MAX - 1)
    live = (jnp.arange(S.K_MAX)[:, None] < k_rt).astype(jnp.float32)
    mask = _causal_mask(slots, n + S.K_MAX) * live
    t_logits, t_hid = _target_block(v, t_params, d_toks, slots, slots, mask)
    v.feat = v.feat.at[slots, :].set(t_hid)

    dists = jnp.concatenate([v.next_logits[None, :], t_logits[:-1]], axis=0)
    z1, z2, i1, i2 = _TOP2(dists)
    tstar = _sample_rows(v, dists)

    if USE_PALLAS:
        flags, r, mf = verify_pallas(
            z1, z2, i2, tstar, d_toks, v.get("policy_id"), v.get("p0"),
            v.get("p1"), k_rt,
        )
    else:
        flags, r, mf = ref.verify_ref(
            z1, z2, i2, tstar, d_toks, v.get("policy_id"), v.get("p0"),
            v.get("p1"), k_rt,
        )
    m = mf.astype(jnp.int32)

    # final token: bonus (all accepted) or the target's own pick
    bonus_dist = t_logits[jnp.maximum(jnp.minimum(k_rt - 1, S.K_MAX - 1), 0)]
    gb = jax.random.gumbel(_key(v), (M.TARGET_CFG.vocab,))
    temp = jnp.maximum(v.get("temp"), 1e-6)
    bonus_tok = jnp.where(
        v.get("greedy") > 0.5,
        jnp.argmax(bonus_dist),
        jnp.argmax(bonus_dist / temp + gb),
    ).astype(jnp.int32)
    all_ok = (m >= k_rt)
    stop_tok = tstar[jnp.minimum(m, S.K_MAX - 1)]
    fin = jnp.where(all_ok & (k_rt > 0), bonus_tok, stop_tok)

    v.add("exact_accepts", jnp.sum(flags == 1.0))
    v.add("relaxed_accepts", jnp.sum(flags == 2.0))
    v.add("rejects", jnp.where(all_ok, 0.0, 1.0))
    v.add("bonus", jnp.where(all_ok & (k_rt > 0), 1.0, 0.0))
    probe_n = jnp.minimum(m + 1, jnp.maximum(k_rt, 1))
    _probe_push(v, z1, z2, flags, probe_n)

    toks = jnp.zeros((S.CATCHUP_MAX,), jnp.int32)
    toks = toks.at[: S.K_MAX].set(d_toks)
    toks = toks.at[jnp.minimum(m, S.CATCHUP_MAX - 1)].set(fin)
    _commit(v, t_params, toks, m)
    return v.pack()


# ------------------------------------------------------ round packing ------


def _packed(round_fn, state, pack):
    """Run up to `pack` rounds of `round_fn` on-device.

    `pack` f32 [1]: the host's per-call round budget (its adaptive
    controller shrinks it as the sequence nears `max_new`). The device
    caps it by the `rounds_per_call` state scalar (the configured pack,
    0 = uncapped) and `PACK_MAX`, and exits as soon as `finished` flips —
    `_commit` folds every stop condition (EOS, `max_new`, out-ring and
    context capacity) into that flag, so no overrun round ever runs.
    Each fused round is bit-identical to one standalone round call: the
    loop body *is* the single-round program.
    """
    n_req = jnp.clip(pack[0].astype(jnp.int32), 1, S.PACK_MAX)
    cap = state[S.SCALARS["rounds_per_call"]].astype(jnp.int32)
    cap = jnp.where(cap >= 1, jnp.minimum(cap, S.PACK_MAX), n_req)
    n = jnp.minimum(n_req, cap)

    def cond(carry):
        i, st = carry
        return (i < n) & (st[S.SCALARS["finished"]] < 0.5)

    def body(carry):
        i, st = carry
        return i + 1, round_fn(st)

    _, st = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), state)
    )
    return st


def ar_multi(state, pack, *t_weights):
    """Up to `pack` fused `ar_step` rounds per device call."""
    return _packed(lambda st: ar_step(st, *t_weights), state, pack)


def sps_multi(state, pack, *weights):
    """Up to `pack` fused `sps_round` rounds per device call."""
    return _packed(lambda st: sps_round(st, *weights), state, pack)


def eagle_tree_multi(state, pack, *weights):
    """Up to `pack` fused `eagle_tree_round` rounds per device call
    (covers both the chain and tree descriptors, like the base program).
    """
    return _packed(lambda st: eagle_tree_round(st, *weights), state, pack)


def medusa_multi(state, pack, *weights):
    """Up to `pack` fused `medusa_round` rounds per device call."""
    return _packed(lambda st: medusa_round(st, *weights), state, pack)


# ------------------------------------------- cross-sequence batching -------
#
# DESIGN.md §9.5: the `*_batch` programs stack BATCH_MAX independent flat
# states into one vector [BATCH_MAX * STATE_LEN] and vmap the single-round
# body over the leading batch dimension, so B sequences draft-and-verify
# in ONE device dispatch. Every runtime knob is already a per-lane state
# scalar (temperature, seed/rng, the verification-policy triple, the
# method slots, rounds_per_call), so mixed per-slot configurations share
# a dispatch for free; only the method *identity* (the program) must
# match across lanes (batches group by method family).
#
# Masked no-op guarantee: a lane whose pre-round `finished` flag is set
# is BIT-FROZEN — the whole-lane select below discards everything the
# vmapped body computed for it (including rng/stat/probe writes), so a
# retired or empty lane can ride along indefinitely without perturbing
# itself or any live lane, and batched decode stays token-identical to
# solo decode per lane. Empty slots are seeded with zeros + finished = 1.


def _batch_lanes(state):
    """[BATCH_MAX * STATE_LEN] -> lanes [BATCH_MAX, STATE_LEN]."""
    return state.reshape(S.BATCH_MAX, S.STATE_LEN)


def _batch_select(old_lanes, new_lanes):
    """Freeze lanes whose pre-round `finished` flag was already set."""
    done = old_lanes[:, S.SCALARS["finished"]] > 0.5
    return jnp.where(done[:, None], old_lanes, new_lanes)


def _batched(round_fn, state):
    """One round of `round_fn` on every live lane, one dispatch."""
    lanes = _batch_lanes(state)
    new = jax.vmap(round_fn)(lanes)
    return _batch_select(lanes, new).reshape(-1)


def _packed_batch(round_fn, state, pack):
    """Up to `pack[b]` rounds of `round_fn` per lane, one dispatch.

    `pack` f32 [BATCH_MAX]: PER-LANE round budgets, so the host's
    adaptive controller (`engine::effective_pack`) keeps its semantics
    per slot — a lane on its TTFT-guarded first call runs one round
    while its neighbors pack, and a lane near its `max_new` budget
    shrinks independently. Each lane is additionally capped by its own
    `rounds_per_call` scalar and PACK_MAX (exactly `_packed`'s clamps),
    and freezes the moment its `finished` flips or its budget is spent;
    the loop exits when no lane is active. Per lane, the round sequence
    is token-identical to the solo `*_multi` program's (vmapped matmuls
    may reassociate float reductions, but every decode decision agrees).
    """
    lanes = _batch_lanes(state)
    n_req = jnp.clip(pack.astype(jnp.int32), 1, S.PACK_MAX)
    cap = lanes[:, S.SCALARS["rounds_per_call"]].astype(jnp.int32)
    cap = jnp.where(cap >= 1, jnp.minimum(cap, S.PACK_MAX), n_req)
    n = jnp.minimum(n_req, cap)

    def active(i, cur):
        return (i < n) & (cur[:, S.SCALARS["finished"]] < 0.5)

    def cond(carry):
        i, cur = carry
        return jnp.any(active(i, cur))

    def body(carry):
        i, cur = carry
        new = jax.vmap(round_fn)(cur)
        live = active(i, cur)
        cur = jnp.where(live[:, None], new, cur)
        return i + 1, cur

    _, lanes = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), lanes)
    )
    return lanes.reshape(-1)


def ar_batch(state, *t_weights):
    """One `ar_step` round per live lane, one dispatch."""
    return _batched(lambda st: ar_step(st, *t_weights), state)


def sps_batch(state, *weights):
    """One `sps_round` per live lane, one dispatch."""
    return _batched(lambda st: sps_round(st, *weights), state)


def eagle_tree_batch(state, *weights):
    """One `eagle_tree_round` per live lane, one dispatch."""
    return _batched(lambda st: eagle_tree_round(st, *weights), state)


def medusa_batch(state, *weights):
    """One `medusa_round` per live lane, one dispatch."""
    return _batched(lambda st: medusa_round(st, *weights), state)


def verify_ext_batch(state, ext, *t_weights):
    """One `verify_ext_round` per live lane with per-lane host drafts.

    ext: f32 [BATCH_MAX * (K_MAX + 1)] — lane b's draft vector at
    b*(K_MAX+1), same [len, tok...] encoding as `verify_ext_round`.
    Host-drafted families need fresh drafts every round, so there is no
    packed variant (exactly the solo fallback rule).
    """
    lanes = _batch_lanes(state)
    exts = ext.reshape(S.BATCH_MAX, S.K_MAX + 1)
    new = jax.vmap(lambda st, e: verify_ext_round(st, e, *t_weights))(
        lanes, exts
    )
    return _batch_select(lanes, new).reshape(-1)


def ar_batch_multi(state, pack, *t_weights):
    """Up to `pack[b]` fused `ar_step` rounds per lane per dispatch."""
    return _packed_batch(lambda st: ar_step(st, *t_weights), state, pack)


def sps_batch_multi(state, pack, *weights):
    """Up to `pack[b]` fused `sps_round` rounds per lane per dispatch."""
    return _packed_batch(lambda st: sps_round(st, *weights), state, pack)


def eagle_tree_batch_multi(state, pack, *weights):
    """Up to `pack[b]` fused `eagle_tree_round` rounds per lane per
    dispatch (covers chain and tree descriptors, like the base program).
    """
    return _packed_batch(
        lambda st: eagle_tree_round(st, *weights), state, pack
    )


def medusa_batch_multi(state, pack, *weights):
    """Up to `pack[b]` fused `medusa_round` rounds per lane per dispatch."""
    return _packed_batch(lambda st: medusa_round(st, *weights), state, pack)


def batch_join(state, lane, slot):
    """Install a solo state into lane `slot` of the batch state.

    `lane` f32 [STATE_LEN] is a freshly prefilled (or cache-restored)
    solo state already resident on device — continuous-batching admission
    is a device-to-device splice, no host traffic. `slot` f32 [1].
    """
    b = jnp.clip(slot[0].astype(jnp.int32), 0, S.BATCH_MAX - 1)
    lanes = _batch_lanes(state)
    lanes = jax.lax.dynamic_update_slice(lanes, lane[None, :], (b, 0))
    return lanes.reshape(-1)


def batch_slot(state, slot):
    """Extract lane `slot` of the batch state as a solo state.

    The leave-side of admission: the returned [STATE_LEN] buffer feeds
    `extract_probe`, snapshot export, or a `*_round` program directly.
    """
    b = jnp.clip(slot[0].astype(jnp.int32), 0, S.BATCH_MAX - 1)
    lanes = _batch_lanes(state)
    return jax.lax.dynamic_slice(lanes, (b, 0), (1, S.STATE_LEN))[0]


def extract_batch(state):
    """Per-lane cheap pull: BATCH_MAX x (scalars ++ out ring), one call."""
    return jax.vmap(extract)(_batch_lanes(state)).reshape(-1)


# ------------------------------------------------------------ extract ------


def extract(state):
    """Cheap per-round pull: scalars ++ out ring."""
    lay = S.layout()
    sc = state[: S.N_SCALARS]
    o = lay["out"]
    out = state[o["offset"]: o["offset"] + o["size"]]
    return jnp.concatenate([sc, out])


def extract_probe(state):
    """Probe pull for figures 1 & 4: scalars ++ probe ring."""
    lay = S.layout()
    sc = state[: S.N_SCALARS]
    p = lay["probe"]
    probe = state[p["offset"]: p["offset"] + p["size"]]
    return jnp.concatenate([sc, probe])


# ------------------------------------------------- weight trees (static) ---
# Template pytrees (shapes only) fixed at import time so flattening order is
# deterministic; aot.py and the tests build real params with the same trees.

_key0 = jax.random.PRNGKey(0)
_TARGET_TREE = jax.eval_shape(lambda: M.init_lm(M.TARGET_CFG, _key0))
_EAGLE_TREE = jax.eval_shape(
    lambda: M.init_eagle(M.EAGLE_CFG, _key0, M.TARGET_CFG)
)
_SPS_TREE = jax.eval_shape(lambda: M.init_lm(M.DRAFT_CFG, _key0))
_MEDUSA_TREE = jax.eval_shape(lambda: M.init_medusa(_key0, M.TARGET_CFG))

_TARGET_NAMES = M.flat_names(_TARGET_TREE)
_EAGLE_NAMES = M.flat_names(_EAGLE_TREE)
_SPS_NAMES = M.flat_names(_SPS_TREE)
_MEDUSA_NAMES = M.flat_names(_MEDUSA_TREE)


def weight_specs(which: str):
    """[(name, shape)] for a model family, in flattening order."""
    tree = {
        "target": _TARGET_TREE, "eagle": _EAGLE_TREE,
        "sps": _SPS_TREE, "medusa": _MEDUSA_TREE,
    }[which]
    names = M.flat_names(tree)
    vals = M.flat_values(tree)
    return [(n, tuple(int(d) for d in x.shape)) for n, x in zip(names, vals)]
