"""AOT pipeline: lower every round program to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.

Artifacts written to --out:
    <name>.hlo.txt         one per executable (prefill, ar_step, rounds...)
    state_layout.json      flat-state ABI (offsets, scalar ids, hash)
    vocab.json             tokenizer spec
    manifest.json          executable index: parameter lists, weight specs
    contracts.json         cross-layer contract manifest (mars check)

Usage: cd python && python -m compile.aot --weights ../artifacts/weights \
           --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import exec_registry as X
from . import model as M
from . import rounds as R
from . import state_spec as S
from . import tokenizer
from .train import load_model


def to_hlo_text(fn, arg_specs) -> str:
    # keep_unused: parameter lists must match the manifest exactly even if
    # XLA could prune an unused weight (the rust side passes all of them)
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def weight_spec_structs(which: str):
    return [f32(*shape) for _, shape in R.weight_specs(which)]


# Lowering inputs per executable: (fn, extra-inputs [(name, shape)]).
# Names, stateless/batched flags and weight families are single-sourced
# from exec_registry.EXECS (exported to artifacts/contracts.json and
# cross-checked against the rust mirrors by `mars check contracts`).
EXECUTABLES = {
    "prefill": (
        R.prefill, [("prompt", (M.P_MAX,)), ("cfg", (S.N_CFG,))]
    ),
    "prefill_ext": (R.prefill_ext, [("ext", (M.P_MAX + 1,))]),
    "ar_step": (R.ar_step, []),
    "sps_round": (R.sps_round, []),
    "eagle_tree_round": (R.eagle_tree_round, []),
    "medusa_round": (R.medusa_round, []),
    "verify_ext_round": (R.verify_ext_round, [("ext", (S.K_MAX + 1,))]),
    # round packing (DESIGN.md §9.6): fused multi-round variants; `pack`
    # is the host's per-call round budget, clamped on device
    "ar_multi": (R.ar_multi, [("pack", (1,))]),
    "sps_multi": (R.sps_multi, [("pack", (1,))]),
    "eagle_tree_multi": (R.eagle_tree_multi, [("pack", (1,))]),
    "medusa_multi": (R.medusa_multi, [("pack", (1,))]),
    "extract": (R.extract, []),
    "extract_probe": (R.extract_probe, []),
    # cross-sequence batching (DESIGN.md §9.5): BATCH_MAX stacked states
    # per dispatch; finished lanes are whole-lane selected back (masked
    # no-ops), per-lane cfg rides in each lane's own scalars
    "ar_batch": (R.ar_batch, []),
    "sps_batch": (R.sps_batch, []),
    "eagle_tree_batch": (R.eagle_tree_batch, []),
    "medusa_batch": (R.medusa_batch, []),
    "verify_ext_batch": (
        R.verify_ext_batch, [("ext", (S.BATCH_MAX * (S.K_MAX + 1),))]
    ),
    # batched round packing (§9.5 x §9.6): per-lane round budgets
    "ar_batch_multi": (R.ar_batch_multi, [("pack", (S.BATCH_MAX,))]),
    "sps_batch_multi": (R.sps_batch_multi, [("pack", (S.BATCH_MAX,))]),
    "eagle_tree_batch_multi": (
        R.eagle_tree_batch_multi, [("pack", (S.BATCH_MAX,))]
    ),
    "medusa_batch_multi": (R.medusa_batch_multi, [("pack", (S.BATCH_MAX,))]),
    # admission splices (device-to-device, no host traffic)
    "batch_join": (
        R.batch_join, [("lane", (S.STATE_LEN,)), ("slot", (1,))]
    ),
    "batch_slot": (R.batch_slot, [("slot", (1,))]),
    "extract_batch": (R.extract_batch, []),
}

assert set(EXECUTABLES) == set(X.EXECS), (
    "aot.EXECUTABLES and exec_registry.EXECS must cover the same names: "
    f"{set(EXECUTABLES) ^ set(X.EXECS)}"
)

STATELESS = X.stateless()  # no leading state argument
# leading state argument is the stacked batch state, not a solo state
BATCH_STATE = X.batched()


def lower_all(out_dir: str) -> dict:
    manifest = {"executables": {}, "weights": {}}
    for fam in ("target", "eagle", "sps", "medusa"):
        manifest["weights"][fam] = [
            {"name": n, "shape": list(s)} for n, s in R.weight_specs(fam)
        ]
    for name, (fn, extras) in EXECUTABLES.items():
        fams = list(X.weight_families(name))
        if name in STATELESS:
            specs = []
        elif name in BATCH_STATE:
            specs = [f32(S.BATCH_STATE_LEN)]
        else:
            specs = [f32(S.STATE_LEN)]
        specs += [f32(*shape) for _, shape in extras]
        for fam in fams:
            specs += weight_spec_structs(fam)
        print(f"lowering {name} ({len(specs)} params)...", flush=True)
        text = to_hlo_text(fn, specs)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["executables"][name] = {
            "file": f"{name}.hlo.txt",
            "state_input": name not in STATELESS,
            "batched": name in BATCH_STATE,
            "extras": [
                {"name": n, "shape": list(sh)} for n, sh in extras
            ],
            "weight_families": fams,
            "hlo_bytes": len(text),
        }
        print(f"  -> {len(text) / 1e6:.2f} MB hlo text", flush=True)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--weights", required=True)
    ap.add_argument("--out", required=True)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # validate weights exist (the manifest records their file layout)
    for fam, tmpl in (
        ("target", R._TARGET_TREE), ("eagle", R._EAGLE_TREE),
        ("sps", R._SPS_TREE), ("medusa", R._MEDUSA_TREE),
    ):
        load_model(os.path.join(args.weights, fam), tmpl)

    manifest = lower_all(args.out)
    manifest["model_cfgs"] = {
        "target": M.TARGET_CFG.as_dict(),
        "eagle": M.EAGLE_CFG.as_dict(),
        "sps": M.DRAFT_CFG.as_dict(),
        "medusa_heads": M.MEDUSA_HEADS,
    }
    manifest["use_pallas"] = R.USE_PALLAS
    layout_doc = json.loads(S.layout_json())
    manifest["state_hash"] = layout_doc["hash"]

    with open(os.path.join(args.out, "state_layout.json"), "w") as f:
        f.write(S.layout_json())
    with open(os.path.join(args.out, "contracts.json"), "w") as f:
        f.write(S.contracts_json())
    with open(os.path.join(args.out, "vocab.json"), "w") as f:
        json.dump(tokenizer.vocab_spec(), f, indent=1)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("aot complete:", args.out)


if __name__ == "__main__":
    main()
