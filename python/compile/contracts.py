"""Standalone contract-manifest exporter.

Writes artifacts/contracts.json without touching weights or lowering any
HLO — seconds, not minutes — so the CI `check` job (and anyone running
`mars check contracts` locally) can regenerate the manifest from the
python source of truth cheaply. `aot.py` writes the identical document
alongside the HLO artifacts.

Usage: cd python && python -m compile.contracts --out ../artifacts
"""

import argparse
import os

from . import state_spec as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "contracts.json")
    with open(path, "w") as f:
        f.write(S.contracts_json())
    print("wrote", path)


if __name__ == "__main__":
    main()
