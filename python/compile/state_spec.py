"""The flat f32 decoding-state ABI shared between the JAX programs and the
rust runtime.

Every lowered executable is single-input-state / single-output-state (plus
weight parameters): `state' = round(state, *weights)`. The state is one flat
f32 vector so that the PJRT output buffer of one call can be fed directly as
the input of the next with zero host traffic (see DESIGN.md §1.1 — Literal
arguments cost 42 ms/call on this box, device buffers 0.5 ms).

Token ids and counters are stored as f32 (exact for < 2^24).
The layout is exported to artifacts/state_layout.json and mirrored by
`rust/src/runtime/state.rs`; a layout hash guards against drift.
"""

import hashlib
import json

import jax.numpy as jnp

from . import model as M

# ----------------------------------------------------------- constants -----

N_SCALARS = 64
K_MAX = 16                 # max chain draft length
B_MAX = 4                  # max tree beam width
C_MAX = 4                  # max children per expansion
DEPTH_MAX = 10             # max tree depth
NODES_MAX = B_MAX * DEPTH_MAX
CATCHUP_MAX = K_MAX + 2    # max tokens committed per round (K or depth, +1)
PROBE_MAX = 1024
PROBE_W = 3                # (z1, z2, flag)
N_CFG = 16                 # prefill config vector length
PACK_MAX = 32              # max draft-verify rounds fused per device call
BATCH_MAX = 8              # max sequences per batched dispatch (§9.5)

# scalar slot indices ---------------------------------------------------

# Verification-policy slot triple (mirrored by rust/src/verify/mod.rs):
#   policy_id  0 = strict, 1 = mars, 2 = topk, 3 = entropy
#   p0, p1     per-policy parameters:
#                mars    p0 = theta (logit-ratio threshold)
#                topk    p0 = k (device clamps to 2), p1 = eps
#                entropy p0 = h_max (top-2 logit-gap ceiling, nats)
# One lowered artifact covers every policy — adding a policy is a new id,
# not a new HLO program.
POLICY_STRICT = 0.0
POLICY_MARS = 1.0
POLICY_TOPK = 2.0
POLICY_ENTROPY = 3.0

SCALARS = {
    "pos": 0,             # target-cache logical length (committed tokens)
    "eagle_pos": 1,       # EAGLE drafter processed length
    "sps_pos": 2,         # SpS draft-LM processed length
    "out_len": 3,         # generated tokens so far
    "finished": 4,        # 0/1
    "rng": 5,             # RNG counter (folded with seed)
    "temp": 6,            # sampling temperature (0 => greedy)
    "p0": 7,              # verification-policy parameter 0
    "policy_id": 8,       # verification policy id (see POLICY_*)
    "kdraft": 9,          # runtime chain draft length K <= K_MAX
    "max_new": 10,        # generation budget
    "eos": 11,            # EOS token id
    "beam": 12,           # runtime tree beam b <= B_MAX
    "branch": 13,         # runtime children per node c <= C_MAX
    "probe_on": 14,       # record (z1, z2, flag) probe entries
    "probe_len": 15,
    "rounds": 16,         # draft-verify cycles executed
    "committed": 17,      # tokens committed by rounds (for tau)
    "target_calls": 18,   # target forward blocks
    "draft_steps": 19,    # drafter forward blocks
    "exact_accepts": 20,
    "relaxed_accepts": 21,  # policy relaxations taken (flag == 2)
    "rejects": 22,
    "bonus": 23,          # all-accept bonus tokens
    "prompt_len": 24,
    "last_accept": 25,    # accepted length of the last round
    "greedy": 26,         # 0/1 (temp == 0)
    "seed": 27,
    "p1": 28,             # verification-policy parameter 1
    "rounds_per_call": 29,  # configured pack cap for *_multi programs
}

# prefill cfg vector indices -------------------------------------------

CFG = {
    "temp": 0, "p0": 1, "policy_id": 2, "kdraft": 3, "max_new": 4,
    "eos": 5, "beam": 6, "branch": 7, "probe_on": 8, "greedy": 9,
    "seed": 10, "prompt_len": 11, "p1": 12, "rounds_per_call": 13,
}

# ------------------------------------------------------------- layout ------


def _sections():
    t, e, s = M.TARGET_CFG, M.EAGLE_CFG, M.DRAFT_CFG
    tkv = t.n_layers * 2 * t.n_heads * t.s_max * t.d_head
    ekv = e.n_layers * 2 * e.n_heads * e.s_max * e.d_head
    skv = s.n_layers * 2 * s.n_heads * s.s_max * s.d_head
    feat = t.s_max * t.d_model
    return [
        ("scalars", (N_SCALARS,)),
        ("tokens", (M.S_MAX,)),
        ("out", (M.OUT_MAX,)),
        ("next_logits", (t.vocab,)),
        ("probe", (PROBE_MAX, PROBE_W)),
        ("tkv", (t.n_layers, 2, t.n_heads, t.s_max, t.d_head)),
        ("feat", (t.s_max, t.d_model)),
        ("ekv", (e.n_layers, 2, e.n_heads, e.s_max, e.d_head)),
        ("skv", (s.n_layers, 2, s.n_heads, s.s_max, s.d_head)),
    ]


def layout() -> dict:
    """name -> (offset, shape, size); plus total length."""
    out = {}
    off = 0
    for name, shape in _sections():
        size = 1
        for d in shape:
            size *= d
        out[name] = {"offset": off, "shape": list(shape), "size": size}
        off += size
    out["__total__"] = off
    return out


STATE_LEN = layout()["__total__"]

# extract vector: scalars ++ out ring
EXTRACT_LEN = N_SCALARS + M.OUT_MAX
# probe extract: scalars ++ probe ring
EXTRACT_PROBE_LEN = N_SCALARS + PROBE_MAX * PROBE_W

# cross-sequence batching (DESIGN.md §9.5): the `*_batch` programs run
# BATCH_MAX independent flat states stacked into one vector; per-lane
# knobs (policy triple, method slots, temp, seed, rounds_per_call) live
# in each lane's own scalars, so mixed configs share a dispatch.
BATCH_STATE_LEN = BATCH_MAX * STATE_LEN
EXTRACT_BATCH_LEN = BATCH_MAX * EXTRACT_LEN


def layout_json() -> str:
    lay = layout()
    doc = {
        "state_len": STATE_LEN,
        "extract_len": EXTRACT_LEN,
        "extract_probe_len": EXTRACT_PROBE_LEN,
        "n_scalars": N_SCALARS,
        "scalars": SCALARS,
        "cfg": CFG,
        "sections": {k: v for k, v in lay.items() if k != "__total__"},
        "consts": {
            "k_max": K_MAX, "b_max": B_MAX, "c_max": C_MAX,
            "depth_max": DEPTH_MAX, "nodes_max": NODES_MAX,
            "catchup_max": CATCHUP_MAX, "probe_max": PROBE_MAX,
            "probe_w": PROBE_W, "n_cfg": N_CFG, "pack_max": PACK_MAX,
            "batch_max": BATCH_MAX,
            "p_max": M.P_MAX, "out_max": M.OUT_MAX, "s_max": M.S_MAX,
            "vocab": M.TARGET_CFG.vocab,
        },
    }
    doc["hash"] = hashlib.sha256(
        json.dumps(doc, sort_keys=True).encode()
    ).hexdigest()[:16]
    return json.dumps(doc, indent=1, sort_keys=True)


def contracts_json() -> str:
    """Machine-readable contract manifest for `mars check contracts`.

    Everything the rust side hand-mirrors, in one document: the full
    state layout (slot names+indices, consts incl. PACK_MAX/BATCH_MAX/
    K_MAX/N_CFG), the verification-policy id table, and the exec-name
    registry with stateless/batched flags and weight families. Exported
    to artifacts/contracts.json by aot.py (and standalone, weights-free,
    by `python -m compile.contracts`); a committed copy lives at
    rust/tests/fixtures/contracts.json so the rust gates run without a
    python toolchain (tests/test_contracts.py pins its freshness).
    """
    from . import exec_registry as X

    doc = {
        "schema": 1,
        "layout": json.loads(layout_json()),
        "policies": {
            "strict": POLICY_STRICT,
            "mars": POLICY_MARS,
            "topk": POLICY_TOPK,
            "entropy": POLICY_ENTROPY,
        },
        "executables": {
            name: {
                "stateless": st,
                "batched": bt,
                "weight_families": list(fams),
            }
            for name, (st, bt, fams) in sorted(X.EXECS.items())
        },
    }
    doc["hash"] = hashlib.sha256(
        json.dumps(doc, sort_keys=True).encode()
    ).hexdigest()[:16]
    return json.dumps(doc, indent=1, sort_keys=True)


# ------------------------------------------------------ pack / unpack ------


class View:
    """Named views over the flat state inside a traced JAX program."""

    def __init__(self, state):
        self.flat = state
        lay = layout()
        self._lay = lay
        for name, spec in lay.items():
            if name == "__total__":
                continue
            off, size = spec["offset"], spec["size"]
            arr = state[off: off + size].reshape(spec["shape"])
            setattr(self, name, arr)

    # scalar helpers -----------------------------------------------------
    def get(self, name):
        return self.scalars[SCALARS[name]]

    def geti(self, name):
        return self.scalars[SCALARS[name]].astype(jnp.int32)

    def set(self, name, value):
        self.scalars = self.scalars.at[SCALARS[name]].set(
            jnp.asarray(value, jnp.float32)
        )

    def add(self, name, value):
        self.scalars = self.scalars.at[SCALARS[name]].add(
            jnp.asarray(value, jnp.float32)
        )

    def pack(self):
        parts = []
        for name, spec in self._lay.items():
            if name == "__total__":
                continue
            parts.append(getattr(self, name).reshape(-1))
        return jnp.concatenate(parts)
