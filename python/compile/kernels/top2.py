"""Pallas kernel: blocked top-2 reduction over the vocabulary axis.

Input  logits [T, V]
Output z1 [T], z2 [T], i1 [T], i2 [T]   (top-1/top-2 values and indices)

The grid tiles the vocab axis; a running (z1, z2, i1, i2) accumulator lives
in the output refs and is folded across tiles. On TPU the [T, VB] tile sits
in VMEM and the reduction runs on the VPU — one pass over the logits,
which is the roofline for this op (the jnp reference `top_k` does a sort
per row). See DESIGN.md §8 for the VMEM/MXU accounting.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _top2_kernel(x_ref, z1_ref, z2_ref, i1_ref, i2_ref, *, vb):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        z1_ref[...] = jnp.full_like(z1_ref, NEG)
        z2_ref[...] = jnp.full_like(z2_ref, NEG)
        i1_ref[...] = jnp.zeros_like(i1_ref)
        i2_ref[...] = jnp.zeros_like(i2_ref)

    x = x_ref[...]                                   # [T, VB] tile
    t = x.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (t, vb), 1) + j * vb

    # tile-local top-2
    tz1 = jnp.max(x, axis=1)
    ti1 = jnp.argmax(x, axis=1).astype(jnp.int32) + j * vb
    masked = jnp.where(col == ti1[:, None], NEG, x)
    tz2 = jnp.max(masked, axis=1)
    ti2 = jnp.argmax(masked, axis=1).astype(jnp.int32) + j * vb

    # fold with running accumulator: merge two sorted pairs
    az1, az2 = z1_ref[...], z2_ref[...]
    ai1, ai2 = i1_ref[...], i2_ref[...]

    best1 = jnp.where(tz1 > az1, tz1, az1)
    besti1 = jnp.where(tz1 > az1, ti1, ai1)
    # candidate seconds: the loser of the firsts, and both seconds
    lose1 = jnp.where(tz1 > az1, az1, tz1)
    losei1 = jnp.where(tz1 > az1, ai1, ti1)
    s = jnp.where(lose1 > az2, lose1, az2)
    si = jnp.where(lose1 > az2, losei1, ai2)
    best2 = jnp.where(tz2 > s, tz2, s)
    besti2 = jnp.where(tz2 > s, ti2, si)

    z1_ref[...] = best1
    z2_ref[...] = best2
    i1_ref[...] = besti1
    i2_ref[...] = besti2


def top2_pallas(logits, block_v: int = 128):
    """Top-2 values/indices per row of `logits` [T, V] via a Pallas kernel."""
    t, v = logits.shape
    assert v % block_v == 0, (v, block_v)
    grid = (v // block_v,)
    kernel = functools.partial(_top2_kernel, vb=block_v)
    z1, z2, i1, i2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((t, block_v), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((t,), lambda j: (0,)),
            pl.BlockSpec((t,), lambda j: (0,)),
            pl.BlockSpec((t,), lambda j: (0,)),
            pl.BlockSpec((t,), lambda j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.int32),
        ],
        interpret=True,  # CPU image: Mosaic custom-calls cannot run here
    )(logits)
    return z1, z2, i1, i2
