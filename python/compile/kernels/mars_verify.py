"""Pallas kernel: the MARS margin-aware accept scan (paper Algorithm 1).

Per verified position i (a chain position or a tree path step):

    exact match    draft_i == tstar_i                       -> accept (1)
    relaxation     draft_i == i2_i  and  r_i > theta
                   and z1_i > 0 and z2_i > 0 and mars_on    -> accept (2)
    otherwise      reject (0), scan stops at first reject

`tstar` is the target's chosen token at that position (argmax when greedy,
a temperature sample otherwise) — precomputed by the round program so the
kernel stays RNG-free. The kernel also emits r_i for the probe ring.

Outputs: flags [T] (0/1/2), r [T], m [1] (accepted prefix length).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _verify_kernel(z1_ref, z2_ref, i2_ref, tstar_ref, draft_ref, cfg_ref,
                   flags_ref, r_ref, m_ref, *, t_max):
    z1 = z1_ref[...]
    z2 = z2_ref[...]
    i2 = i2_ref[...]
    tstar = tstar_ref[...]
    draft = draft_ref[...]
    theta = cfg_ref[0]
    mars_on = cfg_ref[1]
    k = cfg_ref[2].astype(jnp.int32)          # number of live positions

    # margin ratio r = z2/z1, defined on the positive-dominant domain
    safe = (z1 > 0.0) & (z2 > 0.0)
    r = jnp.where(safe, z2 / jnp.maximum(z1, 1e-9), 0.0)

    exact = draft == tstar
    relaxed = (
        (mars_on > 0.5)
        & (draft == i2)
        & safe
        & (r > theta)
        & jnp.logical_not(exact)
    )
    ok = exact | relaxed
    live = jax.lax.broadcasted_iota(jnp.int32, (t_max,), 0) < k
    ok = ok & live

    # accepted prefix: positions before the first rejection
    prefix = jnp.cumprod(ok.astype(jnp.int32))
    flags = jnp.where(
        prefix > 0, jnp.where(relaxed, 2, 1), 0
    ).astype(jnp.float32)

    flags_ref[...] = flags
    r_ref[...] = r
    m_ref[0] = jnp.sum(prefix).astype(jnp.float32)


def mars_verify_pallas(z1, z2, i2, tstar, draft, theta, mars_on, k):
    """Run the MARS accept scan. All inputs are 1-D of length T (i2, tstar,
    draft int32; z1, z2 f32); theta/mars_on/k are scalars.

    Returns (flags f32 [T] in {0,1,2}, r f32 [T], m f32 scalar).
    """
    t = z1.shape[0]
    cfg = jnp.stack(
        [
            jnp.asarray(theta, jnp.float32),
            jnp.asarray(mars_on, jnp.float32),
            jnp.asarray(k, jnp.float32),
        ]
    )
    kernel = functools.partial(_verify_kernel, t_max=t)
    flags, r, m = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,  # CPU image: Mosaic custom-calls cannot run here
    )(z1, z2, i2.astype(jnp.int32), tstar.astype(jnp.int32),
      draft.astype(jnp.int32), cfg)
    return flags, r, m[0]
