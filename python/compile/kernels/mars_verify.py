"""Pallas kernel: the policy-driven accept scan (paper Algorithm 1,
generalized over the verification-policy slot triple).

Per verified position i (a chain position or a tree path step):

    exact match    draft_i == tstar_i                       -> accept (1)
    relaxation     draft_i == i2_i and the policy gate
                   passes at position i                     -> accept (2)
    otherwise      reject (0), scan stops at first reject

The policy gate is selected by `(policy_id, p0, p1)` — the same triple the
rust `verify::VerifyPolicy` encodes (state_spec.POLICY_*):

    strict  (0): never relax
    mars    (1): z1 > 0 and z2 > 0 and z2/z1 > p0           (p0 = theta)
    topk    (2): p0 >= 2 and z1 > 0 and z2 > 0 and
                 z2/z1 > 1 - p1                             (p0 = k, p1 = eps;
                 the pipeline materializes top-2 only, so k clamps to 2)
    entropy (3): z1 - z2 < p0                               (p0 = h_max, nats)

`tstar` is the target's chosen token at that position (argmax when greedy,
a temperature sample otherwise) — precomputed by the round program so the
kernel stays RNG-free. The kernel also emits r_i for the probe ring.

Outputs: flags [T] (0/1/2), r [T], m [1] (accepted prefix length).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

POLICY_STRICT = 0.0
POLICY_MARS = 1.0
POLICY_TOPK = 2.0
POLICY_ENTROPY = 3.0


def _verify_kernel(z1_ref, z2_ref, i2_ref, tstar_ref, draft_ref, cfg_ref,
                   flags_ref, r_ref, m_ref, *, t_max):
    z1 = z1_ref[...]
    z2 = z2_ref[...]
    i2 = i2_ref[...]
    tstar = tstar_ref[...]
    draft = draft_ref[...]
    policy_id = cfg_ref[0]
    p0 = cfg_ref[1]
    p1 = cfg_ref[2]
    k = cfg_ref[3].astype(jnp.int32)          # number of live positions

    # margin ratio r = z2/z1, defined on the positive-dominant domain
    safe = (z1 > 0.0) & (z2 > 0.0)
    r = jnp.where(safe, z2 / jnp.maximum(z1, 1e-9), 0.0)

    exact = draft == tstar
    gate_mars = (policy_id == POLICY_MARS) & safe & (r > p0)
    gate_topk = (
        (policy_id == POLICY_TOPK) & (p0 >= 2.0) & safe & (r > 1.0 - p1)
    )
    gate_ent = (policy_id == POLICY_ENTROPY) & ((z1 - z2) < p0)
    relaxed = (
        (gate_mars | gate_topk | gate_ent)
        & (draft == i2)
        & jnp.logical_not(exact)
    )
    ok = exact | relaxed
    live = jax.lax.broadcasted_iota(jnp.int32, (t_max,), 0) < k
    ok = ok & live

    # accepted prefix: positions before the first rejection
    prefix = jnp.cumprod(ok.astype(jnp.int32))
    flags = jnp.where(
        prefix > 0, jnp.where(relaxed, 2, 1), 0
    ).astype(jnp.float32)

    flags_ref[...] = flags
    r_ref[...] = r
    m_ref[0] = jnp.sum(prefix).astype(jnp.float32)


def verify_pallas(z1, z2, i2, tstar, draft, policy_id, p0, p1, k):
    """Run the policy accept scan. All inputs are 1-D of length T (i2,
    tstar, draft int32; z1, z2 f32); policy_id/p0/p1/k are scalars.

    Returns (flags f32 [T] in {0,1,2}, r f32 [T], m f32 scalar).
    """
    t = z1.shape[0]
    cfg = jnp.stack(
        [
            jnp.asarray(policy_id, jnp.float32),
            jnp.asarray(p0, jnp.float32),
            jnp.asarray(p1, jnp.float32),
            jnp.asarray(k, jnp.float32),
        ]
    )
    kernel = functools.partial(_verify_kernel, t_max=t)
    flags, r, m = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,  # CPU image: Mosaic custom-calls cannot run here
    )(z1, z2, i2.astype(jnp.int32), tstar.astype(jnp.int32),
      draft.astype(jnp.int32), cfg)
    return flags, r, m[0]


def mars_verify_pallas(z1, z2, i2, tstar, draft, theta, mars_on, k):
    """Legacy entrypoint: the pre-policy (theta, mars_on) signature,
    mapped onto the strict/mars policy ids."""
    on = jnp.asarray(mars_on, jnp.float32) > 0.5
    policy_id = jnp.where(on, POLICY_MARS, POLICY_STRICT)
    return verify_pallas(z1, z2, i2, tstar, draft, policy_id, theta, 0.0, k)
