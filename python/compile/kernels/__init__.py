"""L1 Pallas kernels (interpret=True on this CPU image) + pure-jnp oracle.

`top2` — blocked top-2 logit reduction over the vocab axis (the paper's
bandwidth-bound verification hot spot).
`mars_verify` — the margin-aware accept scan of Algorithm 1.
`ref` — pure-jnp reference implementations used by pytest and, when
`MARS_USE_PALLAS=0`, by the lowered rounds themselves (A/B artifact).
"""

from .top2 import top2_pallas  # noqa: F401
from .mars_verify import mars_verify_pallas  # noqa: F401
from . import ref  # noqa: F401
