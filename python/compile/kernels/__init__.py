"""L1 Pallas kernels (interpret=True on this CPU image) + pure-jnp oracle.

`top2` — blocked top-2 logit reduction over the vocab axis (the paper's
bandwidth-bound verification hot spot).
`mars_verify` — the policy-driven accept scan of Algorithm 1, generalized
over the `(policy_id, p0, p1)` verification-policy slot triple
(`verify_pallas`; `mars_verify_pallas` is the legacy theta/mars_on shim).
`ref` — pure-jnp reference implementations used by pytest and, when
`MARS_USE_PALLAS=0`, by the lowered rounds themselves (A/B artifact).
"""

from .top2 import top2_pallas  # noqa: F401
from .mars_verify import mars_verify_pallas, verify_pallas  # noqa: F401
from . import ref  # noqa: F401
