"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

pytest checks `top2_pallas` / `verify_pallas` against these across
shape/policy sweeps; the lowered rounds can also be built against the
oracle (MARS_USE_PALLAS=0) for an A/B artifact.
"""

import jax
import jax.numpy as jnp

from .mars_verify import (
    POLICY_ENTROPY,
    POLICY_MARS,
    POLICY_STRICT,
    POLICY_TOPK,
)


def top2_ref(logits):
    """Top-2 values/indices per row via lax.top_k."""
    vals, idx = jax.lax.top_k(logits, 2)
    return (
        vals[:, 0],
        vals[:, 1],
        idx[:, 0].astype(jnp.int32),
        idx[:, 1].astype(jnp.int32),
    )


def verify_ref(z1, z2, i2, tstar, draft, policy_id, p0, p1, k):
    """Reference accept scan — mirrors mars_verify.py exactly."""
    t = z1.shape[0]
    safe = (z1 > 0.0) & (z2 > 0.0)
    r = jnp.where(safe, z2 / jnp.maximum(z1, 1e-9), 0.0)
    i2 = i2.astype(jnp.int32)
    tstar = tstar.astype(jnp.int32)
    draft = draft.astype(jnp.int32)
    policy_id = jnp.asarray(policy_id, jnp.float32)
    p0 = jnp.asarray(p0, jnp.float32)
    p1 = jnp.asarray(p1, jnp.float32)

    exact = draft == tstar
    gate_mars = (policy_id == POLICY_MARS) & safe & (r > p0)
    gate_topk = (
        (policy_id == POLICY_TOPK) & (p0 >= 2.0) & safe & (r > 1.0 - p1)
    )
    gate_ent = (policy_id == POLICY_ENTROPY) & ((z1 - z2) < p0)
    relaxed = (
        (gate_mars | gate_topk | gate_ent)
        & (draft == i2)
        & jnp.logical_not(exact)
    )
    ok = (exact | relaxed) & (jnp.arange(t) < jnp.asarray(k, jnp.int32))
    prefix = jnp.cumprod(ok.astype(jnp.int32))
    flags = jnp.where(prefix > 0, jnp.where(relaxed, 2, 1), 0).astype(
        jnp.float32
    )
    m = jnp.sum(prefix).astype(jnp.float32)
    return flags, r, m


def mars_verify_ref(z1, z2, i2, tstar, draft, theta, mars_on, k):
    """Legacy entrypoint: (theta, mars_on) mapped onto policy ids."""
    on = jnp.asarray(mars_on, jnp.float32) > 0.5
    policy_id = jnp.where(on, POLICY_MARS, POLICY_STRICT)
    return verify_ref(z1, z2, i2, tstar, draft, policy_id, theta, 0.0, k)
