"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

pytest checks `top2_pallas` / `mars_verify_pallas` against these across
shape/θ sweeps; the lowered rounds can also be built against the oracle
(MARS_USE_PALLAS=0) for an A/B artifact.
"""

import jax
import jax.numpy as jnp


def top2_ref(logits):
    """Top-2 values/indices per row via lax.top_k."""
    vals, idx = jax.lax.top_k(logits, 2)
    return (
        vals[:, 0],
        vals[:, 1],
        idx[:, 0].astype(jnp.int32),
        idx[:, 1].astype(jnp.int32),
    )


def mars_verify_ref(z1, z2, i2, tstar, draft, theta, mars_on, k):
    """Reference accept scan — mirrors mars_verify.py exactly."""
    t = z1.shape[0]
    safe = (z1 > 0.0) & (z2 > 0.0)
    r = jnp.where(safe, z2 / jnp.maximum(z1, 1e-9), 0.0)
    i2 = i2.astype(jnp.int32)
    tstar = tstar.astype(jnp.int32)
    draft = draft.astype(jnp.int32)

    exact = draft == tstar
    relaxed = (
        (jnp.asarray(mars_on, jnp.float32) > 0.5)
        & (draft == i2)
        & safe
        & (r > jnp.asarray(theta, jnp.float32))
        & jnp.logical_not(exact)
    )
    ok = (exact | relaxed) & (jnp.arange(t) < jnp.asarray(k, jnp.int32))
    prefix = jnp.cumprod(ok.astype(jnp.int32))
    flags = jnp.where(prefix > 0, jnp.where(relaxed, 2, 1), 0).astype(
        jnp.float32
    )
    m = jnp.sum(prefix).astype(jnp.float32)
    return flags, r, m
