"""Contract-manifest tests (the `mars check contracts` input side).

The manifest is the machine-readable export of every hand-mirrored
surface: state layout, policy ids, exec registry. These tests pin its
shape, its internal consistency (the invariants the rust checker builds
on), and the freshness of the committed rust fixture so the rust gates
can run without a python toolchain.
"""

import json
import os

from compile import aot
from compile import exec_registry as X
from compile import state_spec as S

FIXTURE = os.path.join(
    os.path.dirname(__file__),
    "..", "..", "rust", "tests", "fixtures", "contracts.json",
)


def manifest():
    return json.loads(S.contracts_json())


def test_manifest_shape():
    doc = manifest()
    assert doc["schema"] == 1
    lay = doc["layout"]
    for key in ("scalars", "cfg", "consts", "sections", "hash"):
        assert key in lay, key
    assert doc["policies"] == {
        "strict": 0.0, "mars": 1.0, "topk": 2.0, "entropy": 3.0
    }
    assert set(doc["executables"]) == set(X.EXECS)
    for name, entry in doc["executables"].items():
        st, bt, fams = X.EXECS[name]
        assert entry["stateless"] is st, name
        assert entry["batched"] is bt, name
        assert entry["weight_families"] == list(fams), name


def test_manifest_consts_cover_rust_mirrors():
    # every const the rust runtime/engine reads by name must be exported
    consts = manifest()["layout"]["consts"]
    for name in (
        "pack_max", "batch_max", "k_max", "n_cfg", "probe_max", "probe_w"
    ):
        assert name in consts, name
    assert consts["pack_max"] == S.PACK_MAX
    assert consts["batch_max"] == S.BATCH_MAX
    assert consts["k_max"] == S.K_MAX
    assert consts["n_cfg"] == S.N_CFG


def test_cfg_names_are_scalar_names():
    # restamp_resumed (rust) copies cfg[i] onto the *scalar of the same
    # name*; a cfg slot without a scalar twin would panic at resume time
    assert set(S.CFG) <= set(S.SCALARS)


def test_registry_matches_aot_lowering_table():
    assert set(aot.EXECUTABLES) == set(X.EXECS)
    assert aot.STATELESS == X.stateless()
    assert aot.BATCH_STATE == X.batched()
    # exactly one stateless program (prefill builds the state)
    assert X.stateless() == {"prefill"}


def test_manifest_deterministic():
    a, b = manifest(), manifest()
    assert a == b
    assert a["hash"] == b["hash"]
    assert a["layout"]["hash"] == json.loads(S.layout_json())["hash"]


def test_committed_fixture_is_fresh():
    # rust/tests/fixtures/contracts.json is consumed by the rust property
    # tests and by `mars check contracts` when no artifacts dir exists;
    # regenerate with `python -m compile.contracts --out ../rust/tests/
    # fixtures` whenever this fails
    with open(FIXTURE) as f:
        committed = f.read()
    assert committed == S.contracts_json(), (
        "committed contracts fixture is stale — regenerate it: "
        "cd python && python -m compile.contracts "
        "--out ../rust/tests/fixtures"
    )
