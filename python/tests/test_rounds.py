"""Round-program semantics: greedy losslessness and MARS behavior.

The strongest single check in the repo: with MARS off and T=0, *every*
speculative round program must emit exactly the sequence that vanilla
greedy decoding of the target produces, token for token — speculative
decoding with strict verification is lossless. With MARS on, deviations
may only be margin-justified tie-breaks.

Uses small randomly-initialized weights (fast); artifact-level equivalence
against the trained weights is covered by the rust integration tests.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import rounds as R
from compile import state_spec as S
from compile import tokenizer as T


@pytest.fixture(scope="module")
def world():
    key = jax.random.PRNGKey(42)
    kt, ke, ks, km = jax.random.split(key, 4)
    target = M.init_lm(M.TARGET_CFG, kt)
    eagle = M.init_eagle(M.EAGLE_CFG, ke, M.TARGET_CFG)
    sps = M.init_lm(M.DRAFT_CFG, ks)
    medusa = M.init_medusa(km, M.TARGET_CFG)
    return {
        "target": target,
        "tw": M.flat_values(target),
        "ew": M.flat_values(eagle),
        "sw": M.flat_values(sps),
        "mw": M.flat_values(medusa),
        "prefill": jax.jit(R.prefill),
        "pext": jax.jit(R.prefill_ext),
        "ar": jax.jit(R.ar_step),
        "sps": jax.jit(R.sps_round),
        "tree": jax.jit(R.eagle_tree_round),
        "medusa": jax.jit(R.medusa_round),
        "ext": jax.jit(R.verify_ext_round),
        "ar_multi": jax.jit(R.ar_multi),
        "sps_multi": jax.jit(R.sps_multi),
        "tree_multi": jax.jit(R.eagle_tree_multi),
        "medusa_multi": jax.jit(R.medusa_multi),
        "extract": jax.jit(R.extract),
    }


PROMPT = "Q: 12+34=?\nA: "
MAXNEW = 20


def make_cfg(**kw):
    cfg = np.zeros(S.N_CFG, np.float32)
    base = dict(
        temp=0.0, greedy=1.0, policy_id=S.POLICY_STRICT, p0=0.9, p1=0.0,
        kdraft=5, max_new=MAXNEW, eos=T.EOS, beam=1, branch=1,
        probe_on=1.0, seed=3, prompt_len=0, rounds_per_call=0,
    )
    base.update(kw)
    for k, v in base.items():
        cfg[S.CFG[k]] = v
    return jnp.asarray(cfg)


def start(world, **cfg_kw):
    ids = T.encode(PROMPT)
    prompt = np.zeros(M.P_MAX, np.float32)
    prompt[: len(ids)] = ids
    cfg = make_cfg(prompt_len=len(ids), **cfg_kw)
    return world["prefill"](
        jnp.asarray(prompt), cfg, *world["tw"], *world["ew"], *world["sw"]
    )


def drive(world, st, step, max_rounds=48):
    for _ in range(max_rounds):
        sc = np.asarray(st[: S.N_SCALARS])
        if sc[S.SCALARS["finished"]] > 0:
            break
        st = step(st)
    sc = np.asarray(st[: S.N_SCALARS])
    lay = S.layout()["out"]
    out = np.asarray(
        st[lay["offset"]: lay["offset"] + lay["size"]]
    ).astype(int)
    n = int(sc[S.SCALARS["out_len"]])
    return out[:n][:MAXNEW], sc, st


@pytest.fixture(scope="module")
def greedy_ref(world):
    ids = T.encode(PROMPT)
    toks = list(ids)
    for _ in range(MAXNEW):
        x = jnp.asarray([toks], jnp.int32)
        logits, _ = M.causal_lm_logits(M.TARGET_CFG, world["target"], x)
        nxt = int(jnp.argmax(logits[0, len(toks) - 1]))
        toks.append(nxt)
        if nxt == T.EOS:
            break
    return np.array(toks[len(ids):])


def test_ar_greedy_matches_reference(world, greedy_ref):
    st = start(world)
    out, sc, _ = drive(world, st, lambda s: world["ar"](s, *world["tw"]))
    np.testing.assert_array_equal(out, greedy_ref)


def test_sps_greedy_lossless(world, greedy_ref):
    st = start(world)
    out, sc, _ = drive(
        world, st, lambda s: world["sps"](s, *world["tw"], *world["sw"])
    )
    np.testing.assert_array_equal(out, greedy_ref)


@pytest.mark.parametrize("beam,branch", [(1, 1), (2, 2), (4, 3)])
def test_eagle_tree_greedy_lossless(world, greedy_ref, beam, branch):
    st = start(world, beam=beam, branch=branch)
    out, sc, _ = drive(
        world, st, lambda s: world["tree"](s, *world["tw"], *world["ew"])
    )
    np.testing.assert_array_equal(out, greedy_ref)


def test_medusa_greedy_lossless(world, greedy_ref):
    st = start(world, kdraft=4)
    out, sc, _ = drive(
        world, st, lambda s: world["medusa"](s, *world["tw"], *world["mw"])
    )
    np.testing.assert_array_equal(out, greedy_ref)


def test_verify_ext_empty_draft_is_ar(world, greedy_ref):
    ext = jnp.zeros((S.K_MAX + 1,), jnp.float32)
    st = start(world)
    out, sc, _ = drive(
        world, st, lambda s: world["ext"](s, ext, *world["tw"])
    )
    np.testing.assert_array_equal(out, greedy_ref)


def test_verify_ext_oracle_accepts_everything(world, greedy_ref):
    st = start(world)
    for _ in range(24):
        sc = np.asarray(st[: S.N_SCALARS])
        if sc[S.SCALARS["finished"]] > 0:
            break
        n = int(sc[S.SCALARS["out_len"]])
        drafts = greedy_ref[n: n + 6]
        e = np.zeros(S.K_MAX + 1, np.float32)
        e[0] = len(drafts)
        e[1: 1 + len(drafts)] = drafts
        st = world["ext"](st, jnp.asarray(e), *world["tw"])
    sc = np.asarray(st[: S.N_SCALARS])
    lay = S.layout()["out"]
    out = np.asarray(
        st[lay["offset"]: lay["offset"] + lay["size"]]
    ).astype(int)[: int(sc[S.SCALARS["out_len"]])][:MAXNEW]
    np.testing.assert_array_equal(out, greedy_ref)
    tau = sc[S.SCALARS["committed"]] / max(sc[S.SCALARS["rounds"]], 1)
    assert tau > 4.0  # oracle drafts must be mostly accepted


def _prefill_ids(world, ids, **cfg_kw):
    prompt = np.zeros(M.P_MAX, np.float32)
    prompt[: len(ids)] = ids
    cfg = make_cfg(prompt_len=len(ids), **cfg_kw)
    return world["prefill"](
        jnp.asarray(prompt), cfg, *world["tw"], *world["ew"], *world["sw"]
    )


@pytest.mark.parametrize("split", [4, 9])
def test_prefill_ext_matches_cold_prefill(world, split):
    """prefill_ext(prefill(prefix), suffix) == prefill(prefix ++ suffix)
    on every live row: the scalar positions agree, next_logits agree, and
    greedy decode from the two states is token-identical (the prefix-cache
    reuse contract — DESIGN.md §8)."""
    ids = T.encode(PROMPT)
    assert 0 < split < len(ids)
    cold = _prefill_ids(world, ids)
    warm0 = _prefill_ids(world, ids[:split])
    e = np.zeros(M.P_MAX + 1, np.float32)
    suffix = ids[split:]
    e[0] = len(suffix)
    e[1: 1 + len(suffix)] = suffix
    warm = world["pext"](
        warm0, jnp.asarray(e), *world["tw"], *world["ew"], *world["sw"]
    )

    csc = np.asarray(cold[: S.N_SCALARS])
    wsc = np.asarray(warm[: S.N_SCALARS])
    for name in ("pos", "eagle_pos", "sps_pos", "prompt_len"):
        assert csc[S.SCALARS[name]] == wsc[S.SCALARS[name]], name
    lay = S.layout()
    for sec in ("tokens", "next_logits"):
        o = lay[sec]
        a = np.asarray(cold[o["offset"]: o["offset"] + o["size"]])
        b = np.asarray(warm[o["offset"]: o["offset"] + o["size"]])
        if sec == "tokens":
            np.testing.assert_array_equal(a[: len(ids)], b[: len(ids)])
        else:
            np.testing.assert_allclose(a, b, atol=1e-4)
    fo = lay["feat"]
    d = M.TARGET_CFG.d_model
    a = np.asarray(cold[fo["offset"]: fo["offset"] + fo["size"]])
    b = np.asarray(warm[fo["offset"]: fo["offset"] + fo["size"]])
    np.testing.assert_allclose(
        a[: len(ids) * d], b[: len(ids) * d], atol=1e-4
    )

    # the decisive check: greedy decode from either state is identical
    out_c, _, _ = drive(
        world, cold, lambda s: world["tree"](s, *world["tw"], *world["ew"])
    )
    out_w, _, _ = drive(
        world, warm, lambda s: world["tree"](s, *world["tw"], *world["ew"])
    )
    np.testing.assert_array_equal(out_c, out_w)


def test_prefill_ext_empty_suffix_keeps_position(world):
    ids = T.encode(PROMPT)
    st = _prefill_ids(world, ids)
    e = np.zeros(M.P_MAX + 1, np.float32)
    st2 = world["pext"](
        st, jnp.asarray(e), *world["tw"], *world["ew"], *world["sw"]
    )
    a = np.asarray(st[: S.N_SCALARS])
    b = np.asarray(st2[: S.N_SCALARS])
    for name in ("pos", "prompt_len"):
        assert a[S.SCALARS[name]] == b[S.SCALARS[name]], name
    lay = S.layout()["next_logits"]
    np.testing.assert_allclose(
        np.asarray(st[lay["offset"]: lay["offset"] + lay["size"]]),
        np.asarray(st2[lay["offset"]: lay["offset"] + lay["size"]]),
        atol=1e-5,
    )


def test_mars_greedy_only_differs_by_tiebreaks(world, greedy_ref):
    """With MARS on, any deviation must come with relaxed_accepts > 0."""
    # aggressive relaxation
    st = start(world, policy_id=S.POLICY_MARS, p0=0.5)
    out, sc, _ = drive(
        world, st, lambda s: world["tree"](s, *world["tw"], *world["ew"])
    )
    same = len(out) == len(greedy_ref) and np.array_equal(out, greedy_ref)
    if not same:
        assert sc[S.SCALARS["relaxed_accepts"]] > 0
    # and with theta ~ 1 mars must be inert
    st = start(world, policy_id=S.POLICY_MARS, p0=0.9999)
    out2, sc2, _ = drive(
        world, st, lambda s: world["tree"](s, *world["tw"], *world["ew"])
    )
    np.testing.assert_array_equal(out2, greedy_ref)
    assert sc2[S.SCALARS["relaxed_accepts"]] == 0


def test_policy_families_share_one_artifact(world, greedy_ref):
    """Every policy id runs through the same round program; inert settings
    must reproduce greedy, aggressive ones may only deviate with
    relaxed_accepts > 0."""
    inert = [
        (S.POLICY_STRICT, 0.0, 0.0),
        (S.POLICY_TOPK, 2.0, 0.0),      # eps = 0: ratio > 1 impossible
        (S.POLICY_TOPK, 1.0, 0.9),      # k < 2 disables relaxation
        (S.POLICY_ENTROPY, 0.0, 0.0),   # gap < 0 impossible
    ]
    for pid, p0, p1 in inert:
        st = start(world, policy_id=pid, p0=p0, p1=p1)
        out, sc, _ = drive(
            world, st, lambda s: world["tree"](s, *world["tw"], *world["ew"])
        )
        np.testing.assert_array_equal(
            out, greedy_ref, err_msg=f"policy {pid} p0={p0} p1={p1}"
        )
        assert sc[S.SCALARS["relaxed_accepts"]] == 0
    aggressive = [
        (S.POLICY_MARS, 0.3, 0.0),
        (S.POLICY_TOPK, 2.0, 0.7),
        (S.POLICY_ENTROPY, 3.0, 0.0),
    ]
    for pid, p0, p1 in aggressive:
        st = start(world, policy_id=pid, p0=p0, p1=p1)
        out, sc, _ = drive(
            world, st, lambda s: world["tree"](s, *world["tw"], *world["ew"])
        )
        same = len(out) == len(greedy_ref) and np.array_equal(
            out, greedy_ref
        )
        if not same:
            assert sc[S.SCALARS["relaxed_accepts"]] > 0, (pid, p0, p1)


def test_finished_state_is_inert(world):
    st = start(world)
    out, sc, st = drive(world, st, lambda s: world["ar"](s, *world["tw"]))
    assert sc[S.SCALARS["finished"]] > 0
    before = np.asarray(st)
    st2 = world["ar"](st, *world["tw"])
    after = np.asarray(st2)
    sc2 = after[: S.N_SCALARS]
    assert sc2[S.SCALARS["out_len"]] == sc[S.SCALARS["out_len"]]
    assert sc2[S.SCALARS["pos"]] == sc[S.SCALARS["pos"]]
    assert sc2[S.SCALARS["rounds"]] == sc[S.SCALARS["rounds"]]
    lay = S.layout()["out"]
    np.testing.assert_array_equal(
        before[lay["offset"]: lay["offset"] + lay["size"]],
        after[lay["offset"]: lay["offset"] + lay["size"]],
    )


def test_sampling_reproducible_by_seed(world):
    def run(seed):
        st = start(world, temp=1.0, greedy=0.0, seed=seed)
        out, _, _ = drive(
            world, st, lambda s: world["sps"](s, *world["tw"], *world["sw"])
        )
        return out

    a, b, c = run(5), run(5), run(6)
    np.testing.assert_array_equal(a, b)
    assert len(a) > 0


def test_probe_entries_recorded(world):
    st = start(world, probe_on=1.0, policy_id=S.POLICY_MARS, p0=0.5)
    _, sc, st = drive(
        world, st, lambda s: world["tree"](s, *world["tw"], *world["ew"])
    )
    assert sc[S.SCALARS["probe_len"]] > 0
    lay = S.layout()["probe"]
    probe = np.asarray(
        st[lay["offset"]: lay["offset"] + lay["size"]]
    ).reshape(S.PROBE_MAX, S.PROBE_W)
    n = int(sc[S.SCALARS["probe_len"]])
    flags = probe[:n, 2]
    assert np.all(np.isin(flags, [0.0, 1.0, 2.0]))


# ------------------------------------------------------ round packing ------

# (family, multi key, single key, weight-list keys, extra cfg)
_PACK_CASES = [
    ("ar", "ar_multi", "ar", ("tw",), {}),
    ("sps", "sps_multi", "sps", ("tw", "sw"), {}),
    ("tree", "tree_multi", "tree", ("tw", "ew"), dict(beam=2, branch=2)),
    ("medusa", "medusa_multi", "medusa", ("tw", "mw"), dict(kdraft=4)),
]


def _pack_arr(n):
    return jnp.asarray([float(n)], jnp.float32)


def _drive_packed(world, st, multi, wkeys, pack, max_calls=48):
    """Run packed calls until finished; returns (out, scalars)."""
    ws = [w for k in wkeys for w in world[k]]
    for _ in range(max_calls):
        sc = np.asarray(st[: S.N_SCALARS])
        if sc[S.SCALARS["finished"]] > 0:
            break
        st = world[multi](st, _pack_arr(pack), *ws)
    sc = np.asarray(st[: S.N_SCALARS])
    lay = S.layout()["out"]
    out = np.asarray(
        st[lay["offset"]: lay["offset"] + lay["size"]]
    ).astype(int)
    n = int(sc[S.SCALARS["out_len"]])
    return out[:n][:MAXNEW], sc, st


@pytest.mark.parametrize("fam,multi,single,wkeys,extra", _PACK_CASES)
@pytest.mark.parametrize("temp", [0.0, 1.0])
def test_packed_rounds_token_identical(world, fam, multi, single, wkeys,
                                       extra, temp):
    """pack > 1 must be token-identical to single rounds at T=0 and T=1:
    the fused loop body IS the single-round program, so output, RNG
    consumption and the round counter all agree exactly."""
    kw = dict(extra)
    if temp > 0:
        kw.update(temp=temp, greedy=0.0, seed=9)
    ws = [w for k in wkeys for w in world[k]]
    out_1, sc_1, _ = drive(
        world, start(world, **kw), lambda s: world[single](s, *ws)
    )
    out_p, sc_p, _ = _drive_packed(
        world, start(world, **kw), multi, wkeys, pack=4
    )
    np.testing.assert_array_equal(out_p, out_1, err_msg=f"{fam} T={temp}")
    assert sc_p[S.SCALARS["rounds"]] == sc_1[S.SCALARS["rounds"]], fam
    assert sc_p[S.SCALARS["committed"]] == sc_1[S.SCALARS["committed"]]


def test_packed_call_stops_at_finished(world):
    """One oversized packed call: the device loop must exit at the stop
    flag (EOS / max_new via _commit), never spinning overrun rounds —
    the adaptive-shrink boundary at the generation budget."""
    out_1, sc_1, _ = drive(
        world, start(world), lambda s: world["ar"](s, *world["tw"])
    )
    st = start(world)
    st = world["ar_multi"](st, _pack_arr(S.PACK_MAX), *world["tw"])
    sc = np.asarray(st[: S.N_SCALARS])
    assert sc[S.SCALARS["finished"]] > 0
    # exactly as many rounds as the budget needed, not PACK_MAX
    assert sc[S.SCALARS["rounds"]] == sc_1[S.SCALARS["rounds"]]
    lay = S.layout()["out"]
    out = np.asarray(
        st[lay["offset"]: lay["offset"] + lay["size"]]
    ).astype(int)[: int(sc[S.SCALARS["out_len"]])][:MAXNEW]
    np.testing.assert_array_equal(out, out_1)
    # a further packed call on the finished state is inert
    st2 = world["ar_multi"](st, _pack_arr(4), *world["tw"])
    sc2 = np.asarray(st2[: S.N_SCALARS])
    assert sc2[S.SCALARS["rounds"]] == sc[S.SCALARS["rounds"]]
    assert sc2[S.SCALARS["out_len"]] == sc[S.SCALARS["out_len"]]


def test_packed_call_respects_cfg_cap(world):
    """The rounds_per_call cfg slot caps the per-call pack input on
    device: a huge `pack` argument may not run more rounds per call than
    the configured cap."""
    st = start(world, rounds_per_call=2)
    st = world["ar_multi"](st, _pack_arr(S.PACK_MAX), *world["tw"])
    sc = np.asarray(st[: S.N_SCALARS])
    assert sc[S.SCALARS["rounds"]] == 2.0
    # and pack=1 under any cap degenerates to exactly one round
    st = world["ar_multi"](st, _pack_arr(1), *world["tw"])
    sc = np.asarray(st[: S.N_SCALARS])
    assert sc[S.SCALARS["rounds"]] == 3.0


def test_stats_tau_bounded_by_k_plus_one(world):
    st = start(world, kdraft=5)
    _, sc, _ = drive(
        world, st, lambda s: world["tree"](s, *world["tw"], *world["ew"])
    )
    tau = sc[S.SCALARS["committed"]] / max(sc[S.SCALARS["rounds"]], 1)
    assert 0.0 < tau <= 6.0 + 1e-6
