"""State ABI + model shape/grad tests (L2 correctness below the rounds)."""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import state_spec as S


def test_layout_sections_contiguous():
    lay = S.layout()
    off = 0
    for name, spec in lay.items():
        if name == "__total__":
            continue
        assert spec["offset"] == off, name
        size = int(np.prod(spec["shape"]))
        assert spec["size"] == size
        off += size
    assert lay["__total__"] == off == S.STATE_LEN


def test_layout_json_stable_hash():
    a = json.loads(S.layout_json())
    b = json.loads(S.layout_json())
    assert a["hash"] == b["hash"]
    assert a["state_len"] == S.STATE_LEN
    assert set(a["scalars"]) == set(S.SCALARS)


def test_view_pack_roundtrip():
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=S.STATE_LEN).astype(np.float32))
    v = S.View(flat)
    out = v.pack()
    np.testing.assert_array_equal(np.asarray(out), np.asarray(flat))


def test_view_scalar_set_get():
    v = S.View(jnp.zeros((S.STATE_LEN,), jnp.float32))
    v.set("pos", 42.0)
    v.add("pos", 3.0)
    assert float(v.get("pos")) == 45.0
    assert int(v.geti("pos")) == 45
    packed = v.pack()
    assert float(packed[S.SCALARS["pos"]]) == 45.0


def test_extract_lengths_consistent():
    assert S.EXTRACT_LEN == S.N_SCALARS + M.OUT_MAX
    assert S.EXTRACT_PROBE_LEN == S.N_SCALARS + S.PROBE_MAX * S.PROBE_W


@pytest.fixture(scope="module")
def tiny_params():
    key = jax.random.PRNGKey(0)
    return M.init_lm(M.TARGET_CFG, key)


def test_causal_forward_shapes(tiny_params):
    toks = jnp.zeros((2, 10), jnp.int32)
    logits, hidden = M.causal_lm_logits(M.TARGET_CFG, tiny_params, toks)
    assert logits.shape == (2, 10, M.TARGET_CFG.vocab)
    assert hidden.shape == (2, 10, M.TARGET_CFG.d_model)


def test_causality(tiny_params):
    """Changing a future token must not affect earlier logits."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(4, 99, (1, 12)), jnp.int32)
    b = a.at[0, -1].set((int(a[0, -1]) + 1) % 99 + 4)
    la, _ = M.causal_lm_logits(M.TARGET_CFG, tiny_params, a)
    lb, _ = M.causal_lm_logits(M.TARGET_CFG, tiny_params, b)
    np.testing.assert_allclose(
        np.asarray(la[0, :-1]), np.asarray(lb[0, :-1]), atol=1e-5
    )


def test_block_apply_incremental_equals_full(tiny_params):
    """Prefill + 1-token step == full forward (the cache correctness that
    the whole serving path rests on)."""
    cfg = M.TARGET_CFG
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(4, 99, 9), jnp.int32)

    # full forward
    full, _ = M.causal_lm_logits(cfg, tiny_params, toks[None])
    want = np.asarray(full[0, -1])

    # prefill 8, then step token 8
    cache = M.empty_cache(cfg)
    slots = jnp.arange(8, dtype=jnp.int32)
    mask = (
        (jnp.arange(cfg.s_max)[None, :] <= slots[:, None])
        & (jnp.arange(cfg.s_max)[None, :] < 8)
    ).astype(jnp.float32)
    _, _, cache = M.block_apply(
        cfg, tiny_params, cache, toks[:8], slots, slots, mask
    )
    slot = jnp.asarray([8], jnp.int32)
    mask1 = (jnp.arange(cfg.s_max)[None, :] <= 8).astype(jnp.float32)
    logits, _, _ = M.block_apply(
        cfg, tiny_params, cache, toks[8:9], slot, slot, mask1
    )
    np.testing.assert_allclose(np.asarray(logits[0]), want, atol=2e-4)


def test_lm_loss_decreases_one_step(tiny_params):
    """One gradient step on a fixed batch reduces the loss (fwd+bwd sanity)."""
    rng = np.random.default_rng(3)
    batch = jnp.asarray(rng.integers(4, 99, (4, 33)), jnp.int32)
    loss0, grads = jax.value_and_grad(
        lambda p: M.lm_loss(M.TARGET_CFG, p, batch)
    )(tiny_params)
    stepped = jax.tree.map(lambda p, g: p - 0.05 * g, tiny_params, grads)
    loss1 = M.lm_loss(M.TARGET_CFG, stepped, batch)
    assert float(loss1) < float(loss0)


def test_flatten_roundtrip(tiny_params):
    names = M.flat_names(tiny_params)
    vals = M.flat_values(tiny_params)
    assert len(names) == len(vals)
    rebuilt = M.unflatten_like(tiny_params, vals)
    for a, b in zip(M.flat_values(rebuilt), vals):
        assert a is b


def test_medusa_heads_shapes():
    key = jax.random.PRNGKey(4)
    mp = M.init_medusa(key, M.TARGET_CFG)
    feat = jnp.zeros((M.TARGET_CFG.d_model,), jnp.float32)
    logits = M.medusa_head_logits(mp, feat)
    assert logits.shape == (M.MEDUSA_HEADS, M.TARGET_CFG.vocab)


def test_eagle_inputs_shapes():
    key = jax.random.PRNGKey(5)
    ep = M.init_eagle(M.EAGLE_CFG, key, M.TARGET_CFG)
    toks = jnp.zeros((3,), jnp.int32)
    feats = jnp.zeros((3, M.TARGET_CFG.d_model), jnp.float32)
    x = M.eagle_inputs(ep, toks, feats)
    assert x.shape == (3, M.EAGLE_CFG.d_model)
