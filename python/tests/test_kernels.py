"""Pallas kernels vs the pure-jnp oracle — the L1 correctness signal.

Hypothesis sweeps shapes, magnitudes and thresholds; seeded grids cover
the edge cases the paper's Algorithm 1 depends on (ties, negative logits,
theta boundaries).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from hypothesis import given, settings, strategies as st

from compile.kernels import top2_pallas, mars_verify_pallas, ref

RNG = np.random.default_rng(1234)


def random_logits(t, v, scale, rng=RNG):
    return jnp.asarray(rng.normal(size=(t, v)).astype(np.float32) * scale)


# ----------------------------------------------------------------- top2 ----


@pytest.mark.parametrize("t", [1, 2, 7, 16, 41])
@pytest.mark.parametrize("v", [128, 256, 512])
def test_top2_matches_ref_shapes(t, v):
    x = random_logits(t, v, 3.0)
    got = top2_pallas(x)
    want = ref.top2_ref(x)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("block_v", [64, 128, 256])
def test_top2_block_sizes(block_v):
    x = random_logits(8, 256, 2.0)
    got = top2_pallas(x, block_v=block_v)
    want = ref.top2_ref(x)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w))


def test_top2_with_ties_prefers_lower_index():
    x = jnp.zeros((3, 128), jnp.float32)
    x = x.at[:, 5].set(2.0).at[:, 9].set(2.0)
    z1, z2, i1, i2 = top2_pallas(x)
    rz1, rz2, ri1, ri2 = ref.top2_ref(x)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(rz1))
    np.testing.assert_allclose(np.asarray(z2), np.asarray(rz2))
    assert np.all(np.asarray(i1) == np.asarray(ri1))


def test_top2_negative_dominated():
    x = random_logits(5, 128, 1.0) - 50.0
    got = top2_pallas(x)
    want = ref.top2_ref(x)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w))


@settings(max_examples=40, deadline=None)
@given(
    t=st.integers(1, 24),
    scale=st.floats(0.1, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_top2_hypothesis(t, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, 128)).astype(np.float32) * scale)
    z1, z2, i1, i2 = top2_pallas(x)
    rz1, rz2, ri1, ri2 = ref.top2_ref(x)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(rz1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z2), np.asarray(rz2), rtol=1e-6)
    # indices may differ only under exact value ties
    same = np.asarray(i1) == np.asarray(ri1)
    tied = np.isclose(np.asarray(z1), np.asarray(z2))
    assert np.all(same | tied)


# ---------------------------------------------------------------- verify ---


def verify_case(t, theta, mars_on, k, seed=0, force=None):
    rng = np.random.default_rng(seed)
    z1 = jnp.asarray(np.abs(rng.normal(size=t)).astype(np.float32) + 0.5)
    z2 = z1 * jnp.asarray(rng.uniform(0.3, 1.0, t).astype(np.float32))
    i2 = jnp.asarray(rng.integers(0, 128, t), jnp.int32)
    tstar = jnp.asarray(rng.integers(0, 128, t), jnp.int32)
    if force == "exact":
        draft = tstar
    elif force == "top2":
        draft = i2
    else:
        draft = jnp.where(
            jnp.asarray(rng.uniform(size=t)) < 0.4, tstar, i2
        ).astype(jnp.int32)
    got = mars_verify_pallas(z1, z2, i2, tstar, draft, theta, mars_on, k)
    want = ref.mars_verify_ref(z1, z2, i2, tstar, draft, theta, mars_on, k)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w))
    return got


@pytest.mark.parametrize("theta", [0.0, 0.5, 0.84, 0.9, 0.96, 1.0])
@pytest.mark.parametrize("mars_on", [0.0, 1.0])
def test_verify_matches_ref(theta, mars_on):
    verify_case(16, theta, mars_on, 12, seed=7)


def test_verify_exact_match_accepts_all():
    flags, r, m = verify_case(8, 0.9, 0.0, 8, force="exact")
    assert float(m) == 8.0
    assert np.all(np.asarray(flags) == 1.0)


def test_verify_theta_one_disables_relaxation():
    # theta=1: r can never exceed it, so MARS == strict
    flags_a, _, m_a = verify_case(16, 1.0, 1.0, 16, seed=3)
    flags_b, _, m_b = verify_case(16, 1.0, 0.0, 16, seed=3)
    np.testing.assert_allclose(np.asarray(flags_a), np.asarray(flags_b))
    assert float(m_a) == float(m_b)


def test_verify_theta_zero_mars_accepts_top2():
    flags, r, m = verify_case(8, 0.0, 1.0, 8, force="top2")
    # every draft is the top-2 token and all z are positive => all relaxed
    # (except positions where top-2 happens to equal tstar -> exact)
    assert float(m) == 8.0
    assert np.all(np.isin(np.asarray(flags), [1.0, 2.0]))


def test_verify_negative_logits_never_relax():
    t = 8
    z1 = jnp.full((t,), -1.0, jnp.float32)
    z2 = jnp.full((t,), -1.1, jnp.float32)
    i2 = jnp.arange(t, dtype=jnp.int32)
    tstar = jnp.full((t,), 99, jnp.int32)
    draft = i2  # matches top-2, but z1 < 0 => guard blocks relaxation
    flags, r, m = mars_verify_pallas(z1, z2, i2, tstar, draft, 0.0, 1.0, t)
    assert float(m) == 0.0
    assert np.all(np.asarray(flags) == 0.0)
    want = ref.mars_verify_ref(z1, z2, i2, tstar, draft, 0.0, 1.0, t)
    np.testing.assert_allclose(np.asarray(flags), np.asarray(want[0]))


def test_verify_stops_at_first_reject():
    t = 6
    z1 = jnp.ones((t,), jnp.float32) * 2.0
    z2 = jnp.ones((t,), jnp.float32) * 1.0  # r = 0.5 < theta
    i2 = jnp.full((t,), 7, jnp.int32)
    tstar = jnp.full((t,), 3, jnp.int32)
    draft = jnp.asarray([3, 3, 5, 3, 3, 3], jnp.int32)  # reject at pos 2
    flags, r, m = mars_verify_pallas(z1, z2, i2, tstar, draft, 0.9, 1.0, t)
    assert float(m) == 2.0
    np.testing.assert_allclose(np.asarray(flags), [1, 1, 0, 0, 0, 0])


def test_verify_k_limits_live_positions():
    flags, r, m = verify_case(16, 0.9, 1.0, 4, force="exact")
    assert float(m) == 4.0


@settings(max_examples=40, deadline=None)
@given(
    t=st.integers(1, 17),
    theta=st.floats(0.0, 1.0),
    mars_on=st.sampled_from([0.0, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_verify_hypothesis(t, theta, mars_on, seed):
    k = max(1, t - 2)
    verify_case(t, theta, mars_on, k, seed=seed)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_verify_monotone_in_theta(seed):
    """Raising theta can only reduce the accepted prefix."""
    prev = None
    for theta in [0.0, 0.5, 0.9, 0.99, 1.0]:
        _, _, m = verify_case(12, theta, 1.0, 12, seed=seed)
        if prev is not None:
            assert float(m) <= prev + 1e-9
        prev = float(m)
