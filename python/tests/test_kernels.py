"""Pallas kernels vs the pure-jnp oracle — the L1 correctness signal.

Hypothesis sweeps shapes, magnitudes and thresholds; seeded grids cover
the edge cases the paper's Algorithm 1 depends on (ties, negative logits,
policy boundaries). Hypothesis is optional: when the container lacks it,
the property sweeps self-skip and the seeded grids still run.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - offline container without dep
    def _skip_deco(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    given = settings = _skip_deco

    class st:  # noqa: N801 - stand-in namespace, args unused when skipped
        integers = staticmethod(lambda *a, **k: None)
        floats = staticmethod(lambda *a, **k: None)
        sampled_from = staticmethod(lambda *a, **k: None)

from compile.kernels import (
    mars_verify_pallas,
    ref,
    top2_pallas,
    verify_pallas,
)
from compile.kernels.mars_verify import (
    POLICY_ENTROPY,
    POLICY_MARS,
    POLICY_STRICT,
    POLICY_TOPK,
)

RNG = np.random.default_rng(1234)


def random_logits(t, v, scale, rng=RNG):
    return jnp.asarray(rng.normal(size=(t, v)).astype(np.float32) * scale)


# ----------------------------------------------------------------- top2 ----


@pytest.mark.parametrize("t", [1, 2, 7, 16, 41])
@pytest.mark.parametrize("v", [128, 256, 512])
def test_top2_matches_ref_shapes(t, v):
    x = random_logits(t, v, 3.0)
    got = top2_pallas(x)
    want = ref.top2_ref(x)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("block_v", [64, 128, 256])
def test_top2_block_sizes(block_v):
    x = random_logits(8, 256, 2.0)
    got = top2_pallas(x, block_v=block_v)
    want = ref.top2_ref(x)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w))


def test_top2_with_ties_prefers_lower_index():
    x = jnp.zeros((3, 128), jnp.float32)
    x = x.at[:, 5].set(2.0).at[:, 9].set(2.0)
    z1, z2, i1, i2 = top2_pallas(x)
    rz1, rz2, ri1, ri2 = ref.top2_ref(x)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(rz1))
    np.testing.assert_allclose(np.asarray(z2), np.asarray(rz2))
    assert np.all(np.asarray(i1) == np.asarray(ri1))


def test_top2_negative_dominated():
    x = random_logits(5, 128, 1.0) - 50.0
    got = top2_pallas(x)
    want = ref.top2_ref(x)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w))


@settings(max_examples=40, deadline=None)
@given(
    t=st.integers(1, 24),
    scale=st.floats(0.1, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_top2_hypothesis(t, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, 128)).astype(np.float32) * scale)
    z1, z2, i1, i2 = top2_pallas(x)
    rz1, rz2, ri1, ri2 = ref.top2_ref(x)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(rz1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z2), np.asarray(rz2), rtol=1e-6)
    # indices may differ only under exact value ties
    same = np.asarray(i1) == np.asarray(ri1)
    tied = np.isclose(np.asarray(z1), np.asarray(z2))
    assert np.all(same | tied)


# ---------------------------------------------------------------- verify ---


def policy_case(t, policy_id, p0, p1, k, seed=0, force=None):
    """Run kernel + oracle over a random case for one policy triple."""
    rng = np.random.default_rng(seed)
    z1 = jnp.asarray(np.abs(rng.normal(size=t)).astype(np.float32) + 0.5)
    z2 = z1 * jnp.asarray(rng.uniform(0.3, 1.0, t).astype(np.float32))
    i2 = jnp.asarray(rng.integers(0, 128, t), jnp.int32)
    tstar = jnp.asarray(rng.integers(0, 128, t), jnp.int32)
    if force == "exact":
        draft = tstar
    elif force == "top2":
        draft = i2
    else:
        draft = jnp.where(
            jnp.asarray(rng.uniform(size=t)) < 0.4, tstar, i2
        ).astype(jnp.int32)
    got = verify_pallas(z1, z2, i2, tstar, draft, policy_id, p0, p1, k)
    want = ref.verify_ref(z1, z2, i2, tstar, draft, policy_id, p0, p1, k)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w))
    return got


def verify_case(t, theta, mars_on, k, seed=0, force=None):
    """Legacy-shaped case: (theta, mars_on) mapped onto policy ids."""
    pid = POLICY_MARS if mars_on > 0.5 else POLICY_STRICT
    return policy_case(t, pid, theta, 0.0, k, seed=seed, force=force)


@pytest.mark.parametrize("theta", [0.0, 0.5, 0.84, 0.9, 0.96, 1.0])
@pytest.mark.parametrize("mars_on", [0.0, 1.0])
def test_verify_matches_ref(theta, mars_on):
    verify_case(16, theta, mars_on, 12, seed=7)


def test_verify_exact_match_accepts_all():
    flags, r, m = verify_case(8, 0.9, 0.0, 8, force="exact")
    assert float(m) == 8.0
    assert np.all(np.asarray(flags) == 1.0)


def test_verify_theta_one_disables_relaxation():
    # theta=1: r can never exceed it, so MARS == strict
    flags_a, _, m_a = verify_case(16, 1.0, 1.0, 16, seed=3)
    flags_b, _, m_b = verify_case(16, 1.0, 0.0, 16, seed=3)
    np.testing.assert_allclose(np.asarray(flags_a), np.asarray(flags_b))
    assert float(m_a) == float(m_b)


def test_verify_theta_zero_mars_accepts_top2():
    flags, r, m = verify_case(8, 0.0, 1.0, 8, force="top2")
    # every draft is the top-2 token and all z are positive => all relaxed
    # (except positions where top-2 happens to equal tstar -> exact)
    assert float(m) == 8.0
    assert np.all(np.isin(np.asarray(flags), [1.0, 2.0]))


def test_verify_negative_logits_never_relax():
    t = 8
    z1 = jnp.full((t,), -1.0, jnp.float32)
    z2 = jnp.full((t,), -1.1, jnp.float32)
    i2 = jnp.arange(t, dtype=jnp.int32)
    tstar = jnp.full((t,), 99, jnp.int32)
    draft = i2  # matches top-2, but z1 < 0 => guard blocks relaxation
    flags, r, m = mars_verify_pallas(z1, z2, i2, tstar, draft, 0.0, 1.0, t)
    assert float(m) == 0.0
    assert np.all(np.asarray(flags) == 0.0)
    want = ref.mars_verify_ref(z1, z2, i2, tstar, draft, 0.0, 1.0, t)
    np.testing.assert_allclose(np.asarray(flags), np.asarray(want[0]))


def test_verify_stops_at_first_reject():
    t = 6
    z1 = jnp.ones((t,), jnp.float32) * 2.0
    z2 = jnp.ones((t,), jnp.float32) * 1.0  # r = 0.5 < theta
    i2 = jnp.full((t,), 7, jnp.int32)
    tstar = jnp.full((t,), 3, jnp.int32)
    draft = jnp.asarray([3, 3, 5, 3, 3, 3], jnp.int32)  # reject at pos 2
    flags, r, m = mars_verify_pallas(z1, z2, i2, tstar, draft, 0.9, 1.0, t)
    assert float(m) == 2.0
    np.testing.assert_allclose(np.asarray(flags), [1, 1, 0, 0, 0, 0])


def test_verify_k_limits_live_positions():
    flags, r, m = verify_case(16, 0.9, 1.0, 4, force="exact")
    assert float(m) == 4.0


@settings(max_examples=40, deadline=None)
@given(
    t=st.integers(1, 17),
    theta=st.floats(0.0, 1.0),
    mars_on=st.sampled_from([0.0, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_verify_hypothesis(t, theta, mars_on, seed):
    k = max(1, t - 2)
    verify_case(t, theta, mars_on, k, seed=seed)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_verify_monotone_in_theta(seed):
    """Raising theta can only reduce the accepted prefix."""
    prev = None
    for theta in [0.0, 0.5, 0.9, 0.99, 1.0]:
        _, _, m = verify_case(12, theta, 1.0, 12, seed=seed)
        if prev is not None:
            assert float(m) <= prev + 1e-9
        prev = float(m)


# ------------------------------------------------------ policy families ---


@pytest.mark.parametrize("policy_id,p0,p1", [
    (POLICY_STRICT, 0.0, 0.0),
    (POLICY_MARS, 0.9, 0.0),
    (POLICY_TOPK, 2.0, 0.1),
    (POLICY_TOPK, 1.0, 0.5),   # k < 2: relaxation disabled on device
    (POLICY_ENTROPY, 1.5, 0.0),
    (POLICY_ENTROPY, 0.0, 0.0),
])
def test_policy_kernel_matches_ref(policy_id, p0, p1):
    for seed in [1, 7, 23]:
        policy_case(16, policy_id, p0, p1, 12, seed=seed)


def test_legacy_shim_equals_policy_form():
    """mars_verify_pallas(theta, mars_on) == verify_pallas(policy triple)."""
    rng = np.random.default_rng(5)
    t = 16
    z1 = jnp.asarray(np.abs(rng.normal(size=t)).astype(np.float32) + 0.5)
    z2 = z1 * jnp.asarray(rng.uniform(0.3, 1.0, t).astype(np.float32))
    i2 = jnp.asarray(rng.integers(0, 128, t), jnp.int32)
    tstar = jnp.asarray(rng.integers(0, 128, t), jnp.int32)
    draft = i2
    for theta, mars_on, pid in [
        (0.9, 1.0, POLICY_MARS),
        (0.9, 0.0, POLICY_STRICT),
    ]:
        legacy = mars_verify_pallas(
            z1, z2, i2, tstar, draft, theta, mars_on, t
        )
        new = verify_pallas(z1, z2, i2, tstar, draft, pid, theta, 0.0, t)
        for a, b in zip(legacy, new):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_topk2_equals_mars_complement():
    """topk(k=2, eps) must decide exactly like mars(theta = 1 - eps)."""
    for seed in range(5):
        for eps in [0.05, 0.1, 0.3]:
            a = policy_case(
                14, POLICY_TOPK, 2.0, eps, 14, seed=seed, force="top2"
            )
            b = policy_case(
                14, POLICY_MARS, 1.0 - eps, 0.0, 14, seed=seed,
                force="top2",
            )
            for x, y in zip(a, b):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y))


def test_entropy_gate_is_gap_ceiling():
    t = 6
    z1 = jnp.asarray([3.0, 3.0, 3.0, 3.0, 3.0, 3.0], jnp.float32)
    z2 = jnp.asarray([2.9, 2.6, 1.0, 2.9, 2.9, 2.9], jnp.float32)
    i2 = jnp.full((t,), 7, jnp.int32)
    tstar = jnp.full((t,), 3, jnp.int32)
    draft = i2  # every draft is the top-2 token
    flags, r, m = verify_pallas(
        z1, z2, i2, tstar, draft, POLICY_ENTROPY, 0.5, 0.0, t
    )
    # gaps: .1 .4 2.0 .1 .1 .1 -> first two relax, third rejects
    assert float(m) == 2.0
    np.testing.assert_allclose(np.asarray(flags), [2, 2, 0, 0, 0, 0])
    # entropy relaxes regardless of sign (gap-based, no positivity guard)
    flags2, _, m2 = verify_pallas(
        z1 - 10.0, z2 - 10.0, i2, tstar, draft, POLICY_ENTROPY, 0.5, 0.0, t
    )
    assert float(m2) == 2.0


def test_strict_policy_never_relaxes():
    flags, _, m = policy_case(
        12, POLICY_STRICT, 0.0, 0.0, 12, seed=11, force="top2"
    )
    # top-2 drafts under strict: only coincidental exact matches accept
    assert np.all(np.isin(np.asarray(flags), [0.0, 1.0]))
