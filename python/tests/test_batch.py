"""Cross-sequence batching semantics (DESIGN.md §9.5).

The contract the rust `BatchRunner` builds on: stacking B independent
flat states and stepping them with a `*_batch` program is per lane
*token-identical* to driving each state alone with the matching solo
round program — same committed tokens, same round/accept/RNG counters —
mixed per-lane configs (policy, temperature, seed, pack budget)
included. (Bit-identity of the float tails is not promised: vmapped
matmuls may reassociate reductions at the ~1e-6 level; every decode
*decision* must still agree.) A finished or empty lane is a masked
no-op returned bit-for-bit, never perturbing itself or its neighbors.

Uses small randomly-initialized weights (fast); artifact-level batched
equivalence is covered by the rust integration tests.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import exec_registry as X
from compile import model as M
from compile import rounds as R
from compile import state_spec as S
from compile import tokenizer as T


@pytest.fixture(scope="module")
def world():
    key = jax.random.PRNGKey(42)
    kt, ke, ks, km = jax.random.split(key, 4)
    target = M.init_lm(M.TARGET_CFG, kt)
    eagle = M.init_eagle(M.EAGLE_CFG, ke, M.TARGET_CFG)
    sps = M.init_lm(M.DRAFT_CFG, ks)
    medusa = M.init_medusa(km, M.TARGET_CFG)
    return {
        "target": target,
        "tw": M.flat_values(target),
        "ew": M.flat_values(eagle),
        "sw": M.flat_values(sps),
        "mw": M.flat_values(medusa),
        "prefill": jax.jit(R.prefill),
        "ar": jax.jit(R.ar_step),
        "sps": jax.jit(R.sps_round),
        "tree": jax.jit(R.eagle_tree_round),
        "medusa": jax.jit(R.medusa_round),
        "ext": jax.jit(R.verify_ext_round),
        "ar_multi": jax.jit(R.ar_multi),
        "ar_batch": jax.jit(R.ar_batch),
        "sps_batch": jax.jit(R.sps_batch),
        "tree_batch": jax.jit(R.eagle_tree_batch),
        "medusa_batch": jax.jit(R.medusa_batch),
        "ext_batch": jax.jit(R.verify_ext_batch),
        "ar_batch_multi": jax.jit(R.ar_batch_multi),
        "sps_batch_multi": jax.jit(R.sps_batch_multi),
        "batch_join": jax.jit(R.batch_join),
        "batch_slot": jax.jit(R.batch_slot),
        "extract": jax.jit(R.extract),
        "extract_batch": jax.jit(R.extract_batch),
    }


PROMPT = "Q: 12+34=?\nA: "
MAXNEW = 20


def make_cfg(**kw):
    cfg = np.zeros(S.N_CFG, np.float32)
    base = dict(
        temp=0.0, greedy=1.0, policy_id=S.POLICY_STRICT, p0=0.9, p1=0.0,
        kdraft=5, max_new=MAXNEW, eos=T.EOS, beam=1, branch=1,
        probe_on=1.0, seed=3, prompt_len=0, rounds_per_call=0,
    )
    base.update(kw)
    for k, v in base.items():
        cfg[S.CFG[k]] = v
    return jnp.asarray(cfg)


def start(world, prompt=PROMPT, **cfg_kw):
    ids = T.encode(prompt)
    buf = np.zeros(M.P_MAX, np.float32)
    buf[: len(ids)] = ids
    cfg = make_cfg(prompt_len=len(ids), **cfg_kw)
    return world["prefill"](
        jnp.asarray(buf), cfg, *world["tw"], *world["ew"], *world["sw"]
    )


def out_of(state):
    sc = np.asarray(state[: S.N_SCALARS])
    lay = S.layout()["out"]
    out = np.asarray(
        state[lay["offset"]: lay["offset"] + lay["size"]]
    ).astype(int)
    return out[: int(sc[S.SCALARS["out_len"]])][:MAXNEW], sc


def drive(world, st, step, max_rounds=48):
    for _ in range(max_rounds):
        sc = np.asarray(st[: S.N_SCALARS])
        if sc[S.SCALARS["finished"]] > 0:
            break
        st = step(st)
    out, sc = out_of(st)
    return out, sc, st


def stack(states):
    """Stack solo states into a batch state; empty slots inert (finished)."""
    lanes = np.zeros((S.BATCH_MAX, S.STATE_LEN), np.float32)
    lanes[:, S.SCALARS["finished"]] = 1.0
    for i, st in enumerate(states):
        lanes[i] = np.asarray(st)
    return jnp.asarray(lanes.reshape(-1))


def lanes_of(bst):
    return np.asarray(bst).reshape(S.BATCH_MAX, S.STATE_LEN)


def drive_batched(world, bst, step, max_rounds=48):
    for _ in range(max_rounds):
        fin = lanes_of(bst)[:, S.SCALARS["finished"]]
        if (fin > 0).all():
            break
        bst = step(bst)
    return bst


# every decision-bearing scalar: committed tokens, counters, stats, RNG
_DECISION_SCALARS = [
    "pos", "out_len", "finished", "rng", "rounds", "committed",
    "target_calls", "draft_steps", "exact_accepts", "relaxed_accepts",
    "rejects", "bonus", "last_accept", "probe_len",
]


def assert_lane_matches_solo(lane, ref_state, msg):
    out_b, sc_b = out_of(lane)
    out_s, sc_s = out_of(np.asarray(ref_state))
    np.testing.assert_array_equal(out_b, out_s, err_msg=msg)
    for name in _DECISION_SCALARS:
        assert sc_b[S.SCALARS[name]] == sc_s[S.SCALARS[name]], (msg, name)


# (family, batch key, single key, weight-list keys, extra cfg)
_BATCH_CASES = [
    ("ar", "ar_batch", "ar", ("tw",), {}),
    ("sps", "sps_batch", "sps", ("tw", "sw"), {}),
    ("tree", "tree_batch", "tree", ("tw", "ew"), dict(beam=2, branch=2)),
    ("medusa", "medusa_batch", "medusa", ("tw", "mw"), dict(kdraft=4)),
]


@pytest.mark.parametrize("fam,batch,single,wkeys,extra", _BATCH_CASES)
@pytest.mark.parametrize("temp", [0.0, 1.0])
def test_batched_token_identical_to_solo(world, fam, batch, single, wkeys,
                                         extra, temp):
    """Per-lane token identity to solo decode, with per-lane mixed
    configs: each lane carries its own policy / seed / temperature in its
    scalars, so one batched dispatch serves all of them at once."""
    lane_cfgs = [
        dict(extra),
        dict(extra, policy_id=S.POLICY_MARS, p0=0.5, seed=7),
        dict(extra, policy_id=S.POLICY_TOPK, p0=2.0, p1=0.4, seed=11),
    ]
    if temp > 0:
        for i, kw in enumerate(lane_cfgs):
            kw.update(temp=temp, greedy=0.0, seed=20 + i)
    ws = [w for k in wkeys for w in world[k]]

    solo = []
    for kw in lane_cfgs:
        _, _, st = drive(
            world, start(world, **kw), lambda s: world[single](s, *ws)
        )
        solo.append(np.asarray(st))

    bst = stack([start(world, **kw) for kw in lane_cfgs])
    bst = drive_batched(world, bst, lambda s: world[batch](s, *ws))
    lanes = lanes_of(bst)
    for i, ref in enumerate(solo):
        assert_lane_matches_solo(
            lanes[i], ref, f"{fam} lane {i} T={temp}"
        )


def test_empty_and_finished_lanes_are_bit_frozen(world):
    """Masked no-op pin: a lane whose `finished` flag is set before the
    round — whether a retired sequence or a never-occupied zero slot — is
    returned bit-for-bit, and live lanes decode as if alone."""
    _, _, done = drive(
        world, start(world), lambda s: world["ar"](s, *world["tw"])
    )
    assert np.asarray(done)[S.SCALARS["finished"]] > 0
    out_solo, sc_solo, _ = drive(
        world, start(world, seed=5), lambda s: world["ar"](s, *world["tw"])
    )

    bst = stack([done, start(world, seed=5)])
    before = lanes_of(bst).copy()
    bst = drive_batched(world, bst, lambda s: world["ar_batch"](s, *world["tw"]))
    lanes = lanes_of(bst)
    # lane 0 (finished) and lanes 2.. (empty) are untouched
    np.testing.assert_array_equal(lanes[0], before[0])
    for b in range(2, S.BATCH_MAX):
        np.testing.assert_array_equal(lanes[b], before[b], err_msg=f"lane {b}")
    # lane 1 decoded exactly as it would alone
    out, sc = out_of(lanes[1])
    np.testing.assert_array_equal(out, out_solo)
    assert sc[S.SCALARS["rounds"]] == sc_solo[S.SCALARS["rounds"]]


def test_batch_join_at_round_boundary_restores_state(world):
    """Continuous-batching admission pin: splicing a freshly prefilled
    solo state into a lane between rounds, then continuing batched, gives
    exactly the solo decode — and `batch_slot` reads the lane back
    bit-for-bit (the leave side)."""
    ws = world["tw"]
    bst = stack([start(world)])
    for _ in range(2):
        bst = world["ar_batch"](bst, *ws)

    joiner = start(world, seed=13)
    bst = world["batch_join"](
        bst, joiner, jnp.asarray([1.0], jnp.float32)
    )
    np.testing.assert_array_equal(
        lanes_of(bst)[1], np.asarray(joiner)
    )

    bst = drive_batched(world, bst, lambda s: world["ar_batch"](s, *ws))
    _, _, ref0 = drive(world, start(world), lambda s: world["ar"](s, *ws))
    _, _, ref1 = drive(world, joiner, lambda s: world["ar"](s, *ws))
    assert_lane_matches_solo(lanes_of(bst)[0], ref0, "incumbent lane")
    assert_lane_matches_solo(lanes_of(bst)[1], ref1, "joined lane")

    # leave side: batch_slot pulls the lane unchanged
    lane = world["batch_slot"](bst, jnp.asarray([1.0], jnp.float32))
    np.testing.assert_array_equal(np.asarray(lane), lanes_of(bst)[1])


def test_batch_multi_per_lane_pack_budgets(world):
    """Batched round packing: each lane takes its own pack budget and
    rounds_per_call cap, and per lane the result is token-identical to
    the solo `*_multi` drive with that budget."""
    cfgs = [dict(), dict(seed=4), dict(rounds_per_call=2, seed=8)]
    packs = [1.0, 4.0, float(S.PACK_MAX)]

    solo = []
    for kw, p in zip(cfgs, packs):
        st = start(world, **kw)
        for _ in range(48):
            if np.asarray(st[: S.N_SCALARS])[S.SCALARS["finished"]] > 0:
                break
            st = world["ar_multi"](
                st, jnp.asarray([p], jnp.float32), *world["tw"]
            )
        solo.append(np.asarray(st))

    bst = stack([start(world, **kw) for kw in cfgs])
    pack = np.ones(S.BATCH_MAX, np.float32)
    pack[: len(packs)] = packs
    bst = drive_batched(
        world, bst,
        lambda s: world["ar_batch_multi"](s, jnp.asarray(pack), *world["tw"]),
    )
    lanes = lanes_of(bst)
    for i, ref in enumerate(solo):
        assert_lane_matches_solo(lanes[i], ref, f"lane {i}")


def test_verify_ext_batch_per_lane_drafts(world):
    """Host-drafted batching: lane 0 gets empty drafts (degenerates to
    AR), lane 1 gets oracle drafts from its own greedy tail — both must
    land on the same greedy output, and lane 1 must accept at depth."""
    out_ref, _, _ = drive(
        world, start(world), lambda s: world["ar"](s, *world["tw"])
    )
    bst = stack([start(world), start(world)])
    kw = S.K_MAX + 1
    for _ in range(48):
        lanes = lanes_of(bst)
        fin = lanes[:, S.SCALARS["finished"]]
        if (fin > 0).all():
            break
        ext = np.zeros(S.BATCH_MAX * kw, np.float32)
        n1 = int(lanes[1, S.SCALARS["out_len"]])
        drafts = out_ref[n1: n1 + 6]
        ext[kw] = len(drafts)
        ext[kw + 1: kw + 1 + len(drafts)] = drafts
        bst = world["ext_batch"](bst, jnp.asarray(ext), *world["tw"])
    lanes = lanes_of(bst)
    for b in (0, 1):
        out, sc = out_of(lanes[b])
        np.testing.assert_array_equal(out, out_ref, err_msg=f"lane {b}")
    sc1 = lanes[1, : S.N_SCALARS]
    tau = sc1[S.SCALARS["committed"]] / max(sc1[S.SCALARS["rounds"]], 1)
    assert tau > 4.0  # oracle drafts mostly accepted
    # and lane 1 finished in fewer rounds than the AR lane
    assert sc1[S.SCALARS["rounds"]] < lanes[0, S.SCALARS["rounds"]]


def test_extract_batch_matches_per_lane_extract(world):
    sts = [start(world), start(world, seed=5)]
    bst = stack(sts)
    got = np.asarray(world["extract_batch"](bst)).reshape(
        S.BATCH_MAX, S.EXTRACT_LEN
    )
    lanes = lanes_of(bst)
    for b in range(S.BATCH_MAX):
        ref = np.asarray(world["extract"](jnp.asarray(lanes[b])))
        np.testing.assert_array_equal(got[b], ref, err_msg=f"lane {b}")


def test_all_batch_programs_aot_lower(world):
    """Every `*_batch` executable lowers through the real AOT path
    (stablehlo -> HLO text via the xla_extension parser) with the exact
    manifest specs — the shape contract the rust runtime loads."""
    for name in sorted(aot.BATCH_STATE):
        fn, extras = aot.EXECUTABLES[name]
        specs = [aot.f32(S.BATCH_STATE_LEN)]
        specs += [aot.f32(*shape) for _, shape in extras]
        for fam in X.weight_families(name):
            specs += aot.weight_spec_structs(fam)
        text = aot.to_hlo_text(fn, specs)
        assert "ENTRY" in text, name
