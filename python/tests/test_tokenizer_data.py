"""Tokenizer + synthetic corpus tests (the python halves of the mirrored
implementations; the rust halves have twin tests in rust/src)."""

import random

import pytest

from compile import data, tokenizer


def test_roundtrip_ascii():
    s = "Q: 12+34=?\nA: 46\n"
    assert tokenizer.decode(tokenizer.encode(s)) == s


def test_vocab_bounds():
    for ch in map(chr, range(0x20, 0x7F)):
        ids = tokenizer.encode(ch)
        assert len(ids) == 1 and 4 <= ids[0] < tokenizer.VOCAB


def test_specials():
    ids = tokenizer.encode("x", bos=True, eos=True)
    assert ids[0] == tokenizer.BOS and ids[-1] == tokenizer.EOS
    assert tokenizer.decode(ids) == "x"


def test_newline_id():
    assert tokenizer.encode("\n") == [tokenizer.NL_ID]


def test_unknown_maps_to_space():
    assert tokenizer.decode(tokenizer.encode("héllo")) == "h llo"


def test_vocab_spec_pins_layout():
    spec = tokenizer.vocab_spec()
    assert spec["vocab_size"] == 128
    assert spec["ascii_offset"] == 4
    assert spec["nl"] == 99


@pytest.mark.parametrize("task", data.TASKS)
def test_generators_produce_prompt_completion(task):
    rng = random.Random(5)
    for _ in range(40):
        p, c = data.gen_example(task, rng)
        assert p and c.endswith("\n")
        # everything must tokenize within the char vocab
        ids = tokenizer.encode(p + c)
        assert all(0 <= t < tokenizer.VOCAB for t in ids)


def test_arith_answer_extraction():
    assert data.arith_answer("4+5=9; 3*9=27\n") == "27"
    assert data.arith_answer("95\n") == "95"
    assert data.arith_answer("nothing") == ""


def test_arith_answers_match_reference():
    rng = random.Random(11)
    for _ in range(60):
        p, c = data.gen_arith(rng)
        ans = data.arith_answer(c)
        assert ans and c.strip().endswith(ans)


def test_cipher_deterministic_and_shifted():
    assert data.cipher_encode("abc") == "hij"
    assert data.cipher_encode("xyz") == "efg"
    assert data.cipher_encode("a b.") == "h i."


def test_token_stream_packs_fixed_length():
    stream = data.token_stream(0, 64, tokenizer)
    for _ in range(5):
        seq = next(stream)
        assert len(seq) == 65
        assert all(0 <= t < tokenizer.VOCAB for t in seq)


def test_token_stream_deterministic():
    a = [next(data.token_stream(3, 32, tokenizer)) for _ in range(1)]
    b = [next(data.token_stream(3, 32, tokenizer)) for _ in range(1)]
    assert a == b
