//! Integration tests over the real artifacts (auto-skip when
//! `make artifacts` has not run, so `cargo test` stays green on a fresh
//! checkout).
//!
//! PJRT handles are not `Send`, so each test builds its own thread-local
//! engine; the checks are grouped into three coarse tests to amortize the
//! ~30 s executable-compilation cost.

use std::path::PathBuf;

use mars::engine::{DecodeEngine, GenParams, SpecMethod};
use mars::runtime::{Artifacts, Runtime};
use mars::spec::METHODS;
use mars::verify::{AcceptFlag, VerifyPolicy};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("MARS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if Artifacts::available(&dir) {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts missing — run `make artifacts`");
        None
    }
}

fn params(method: SpecMethod, policy: VerifyPolicy, temp: f32) -> GenParams {
    GenParams {
        method,
        policy,
        temperature: temp,
        max_new: 24,
        seed: 11,
        ..GenParams::default()
    }
}

#[test]
fn artifacts_metadata_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let a = Artifacts::load(&dir).expect("artifacts load");
    assert!(a.layout.state_len > 0);
    for name in [
        "prefill",
        "prefill_ext",
        "ar_step",
        "sps_round",
        "eagle_tree_round",
        "medusa_round",
        "verify_ext_round",
        "ar_multi",
        "sps_multi",
        "eagle_tree_multi",
        "medusa_multi",
        "extract",
        "extract_probe",
        // cross-sequence batched decoding (DESIGN.md §9.5)
        "ar_batch",
        "sps_batch",
        "eagle_tree_batch",
        "medusa_batch",
        "verify_ext_batch",
        "ar_batch_multi",
        "sps_batch_multi",
        "eagle_tree_batch_multi",
        "medusa_batch_multi",
        "batch_join",
        "batch_slot",
        "extract_batch",
    ] {
        assert!(
            a.executable_names().iter().any(|n| n == name),
            "missing {name}"
        );
    }
}

/// All engine-level semantics in one test (single runtime build).
#[test]
fn engine_semantics_suite() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = DecodeEngine::new(Runtime::new(&dir).expect("runtime"));

    // --- greedy losslessness: every method == AR at T=0 ----------------
    let prompt = "Q: 21+17=?\nA: ";
    let ar = engine
        .generate(prompt, &params(SpecMethod::Ar, VerifyPolicy::Strict, 0.0))
        .expect("ar");
    assert!(!ar.tokens.is_empty());
    // every speculative descriptor in the registry, at its defaults
    for method in SpecMethod::speculative_defaults() {
        let r = engine
            .generate(prompt, &params(method, VerifyPolicy::Strict, 0.0))
            .unwrap_or_else(|e| panic!("{method:?}: {e:#}"));
        assert_eq!(
            r.tokens, ar.tokens,
            "{method:?} diverged from greedy AR: {:?} vs {:?}",
            r.text, ar.text
        );
    }
    assert_eq!(SpecMethod::speculative_defaults().len(), METHODS.len() - 1);

    // --- Strict policy == MARS at theta -> 1, and never relaxes --------
    let strict = engine
        .generate(
            prompt,
            &params(SpecMethod::default(), VerifyPolicy::Strict, 0.0),
        )
        .expect("strict");
    assert_eq!(strict.snapshot.relaxed_accepts, 0.0);
    let p = params(
        SpecMethod::default(),
        VerifyPolicy::Mars { theta: 0.9999 },
        0.0,
    );
    let mars = engine.generate(prompt, &p).expect("mars");
    assert_eq!(strict.tokens, mars.tokens);
    assert_eq!(mars.snapshot.relaxed_accepts, 0.0);

    // --- Strict is token-identical across policy encodings on a fixed
    //     seed set: legacy-equivalent strict vs near-inert relaxed rules --
    for (i, ex) in mars::datasets::dataset(mars::datasets::Task::Arith, 3, 9)
        .iter()
        .enumerate()
    {
        let mut ps = params(SpecMethod::default(), VerifyPolicy::Strict, 1.0);
        ps.seed = 100 + i as u64;
        let a = engine.generate(&ex.prompt, &ps).expect("strict fixed");
        ps.policy = VerifyPolicy::Mars { theta: 0.9999 };
        let b = engine.generate(&ex.prompt, &ps).expect("inert mars");
        assert_eq!(a.tokens, b.tokens, "strict != inert-mars on example {i}");
        assert_eq!(a.snapshot.relaxed_accepts, 0.0);
    }

    // --- MARS never reduces tau ----------------------------------------
    let mut tau_strict = 0.0;
    let mut tau_mars = 0.0;
    for (i, ex) in mars::datasets::dataset(mars::datasets::Task::Mt, 4, 3)
        .iter()
        .enumerate()
    {
        let mut p = params(SpecMethod::default(), VerifyPolicy::Strict, 1.0);
        p.max_new = 48;
        p.seed = i as u64;
        tau_strict += engine.generate(&ex.prompt, &p).expect("s").tau();
        p.policy = VerifyPolicy::Mars { theta: 0.9 };
        tau_mars += engine.generate(&ex.prompt, &p).expect("m").tau();
    }
    assert!(
        tau_mars >= tau_strict * 0.98,
        "tau(MARS)={tau_mars} < tau(strict)={tau_strict}"
    );

    // --- sampling reproducibility --------------------------------------
    let p = params(SpecMethod::Sps { k: 7 }, VerifyPolicy::default(), 1.0);
    let a = engine.generate("Q: 3+4=?\nA: ", &p).expect("a");
    let b = engine.generate("Q: 3+4=?\nA: ", &p).expect("b");
    assert_eq!(a.tokens, b.tokens);

    // --- extract_every must not change tokens --------------------------
    let mut p = params(SpecMethod::default(), VerifyPolicy::default(), 1.0);
    p.max_new = 32;
    let a = engine.generate("Q: 12+7=?\nA: ", &p).expect("a");
    p.extract_every = 4;
    let b = engine.generate("Q: 12+7=?\nA: ", &p).expect("b");
    assert_eq!(a.tokens, b.tokens, "blind rounds changed the output");

    // --- round packing: packed decode is token-identical to unpacked
    //     across every method family x every verify policy, T=0 and T=1
    //     (the fused loop body IS the single-round program) -------------
    for method in SpecMethod::all_defaults() {
        for policy in [
            VerifyPolicy::Strict,
            VerifyPolicy::Mars { theta: 0.9 },
            VerifyPolicy::TopK { k: 2, eps: 0.1 },
            VerifyPolicy::Entropy { h_max: 1.0 },
        ] {
            for temp in [0.0f32, 1.0] {
                let mut p = params(method, policy, temp);
                p.max_new = 32;
                let unpacked =
                    engine.generate(prompt, &p).unwrap_or_else(|e| {
                        panic!("{method:?}/{policy:?} unpacked: {e:#}")
                    });
                p.rounds_per_call = 8;
                let packed =
                    engine.generate(prompt, &p).unwrap_or_else(|e| {
                        panic!("{method:?}/{policy:?} packed: {e:#}")
                    });
                assert_eq!(
                    packed.tokens, unpacked.tokens,
                    "{method:?}/{policy:?}/T={temp}: packed decode \
                     diverged: {:?} vs {:?}",
                    packed.text, unpacked.text
                );
                assert_eq!(
                    packed.snapshot.rounds, unpacked.snapshot.rounds,
                    "{method:?}/{policy:?}/T={temp}: round counts differ"
                );
                // device-coupled methods must actually amortize calls;
                // host drafters have no fused program and fall back
                if method.multi_exec_name().is_some()
                    && unpacked.snapshot.rounds >= 4.0
                {
                    assert!(
                        packed.device_calls < unpacked.device_calls,
                        "{method:?}/{policy:?}/T={temp}: packing saved \
                         no device calls ({} vs {})",
                        packed.device_calls,
                        unpacked.device_calls
                    );
                }
            }
        }
    }

    // --- adaptive shrink at the max_new boundary: a packed run may not
    //     commit past the budget any differently than an unpacked run --
    {
        let mut p = params(SpecMethod::default(), VerifyPolicy::default(), 0.0);
        p.max_new = 5; // smaller than one default pack
        let unpacked = engine.generate(prompt, &p).expect("boundary unpacked");
        p.rounds_per_call = 16;
        let packed = engine.generate(prompt, &p).expect("boundary packed");
        assert_eq!(packed.tokens, unpacked.tokens);
        assert!(packed.tokens.len() <= 5);
    }

    // --- probe entries flow to host ------------------------------------
    let mut p = params(SpecMethod::default(), VerifyPolicy::default(), 1.0);
    p.probe = true;
    p.max_new = 40;
    let r = engine
        .generate("Translate: aol ypcly\nOutput: ", &p)
        .expect("probe run");
    let probe = r.probe.expect("probe dump");
    assert!(!probe.entries.is_empty());
    for e in &probe.entries {
        assert!(matches!(
            e.flag,
            AcceptFlag::Reject | AcceptFlag::Exact | AcceptFlag::Relaxed
        ));
        assert!(e.z1 >= e.z2, "top-1 logit below top-2: {e:?}");
    }

    // --- limits + errors ------------------------------------------------
    let mut p = params(SpecMethod::default(), VerifyPolicy::default(), 1.0);
    p.max_new = 64;
    let r = engine
        .generate("Text: The crew painted a red barn at noon.\nSummary: ", &p)
        .expect("limit");
    assert!(r.tokens.len() <= 64);
    assert!(engine
        .generate("", &params(SpecMethod::Ar, VerifyPolicy::Strict, 0.0))
        .is_err());

    // --- prefix-cache reuse: warm decode token-identical to cold (T=0),
    //     every policy family x a chain and a tree drafter --------------
    {
        use mars::cache::PrefixCache;
        use mars::engine::SeqRunner;
        use std::cell::RefCell;
        use std::rc::Rc;
        let drive = |runner: &mut SeqRunner<'_>| loop {
            if let Some(r) = runner.step().expect("step") {
                return r;
            }
        };
        let turn1 = "Sys: short.\nU: 21+17?\nB:";
        for policy in [
            VerifyPolicy::Strict,
            VerifyPolicy::Mars { theta: 0.9 },
            VerifyPolicy::TopK { k: 2, eps: 0.1 },
            VerifyPolicy::Entropy { h_max: 1.0 },
        ] {
            for method in [
                SpecMethod::EagleChain { depth: 7 },
                SpecMethod::default(), // the default eagle tree
            ] {
                let p = params(method, policy, 0.0);
                let cache = Rc::new(RefCell::new(PrefixCache::new(64 << 20)));
                // turn 1 warms the cache (prefill + final-commit snapshots)
                let t1 = mars::tokenizer::encode(turn1);
                let mut r = SeqRunner::new_with_cache(
                    &engine.rt,
                    &t1,
                    &p,
                    false,
                    Some(cache.clone()),
                )
                .expect("turn 1");
                let first = drive(&mut r);
                assert_eq!(first.prefill_cached_tokens, 0, "cold turn 1");
                // turn 2 extends turn 1 + its answer byte-for-byte
                let turn2 = format!("{turn1}{}\nU: 3+4?\nB:", first.text);
                let t2 = mars::tokenizer::encode(&turn2);
                let mut cold =
                    SeqRunner::new(&engine.rt, &t2, &p, false).expect("cold");
                let cold = drive(&mut cold);
                let mut warm = SeqRunner::new_with_cache(
                    &engine.rt,
                    &t2,
                    &p,
                    false,
                    Some(cache.clone()),
                )
                .expect("warm");
                let warm = drive(&mut warm);
                assert!(
                    warm.prefill_cached_tokens > 0,
                    "{method:?}/{policy:?}: turn 2 missed the cache"
                );
                assert_eq!(
                    warm.tokens, cold.tokens,
                    "{method:?}/{policy:?}: cached-prefix decode diverged \
                     from cold at T=0: {:?} vs {:?}",
                    warm.text, cold.text
                );
                assert!(cache.borrow().stats().tokens_saved > 0);
            }
        }
    }

    // --- hostloop runtime must be output-identical ----------------------
    let p = params(SpecMethod::default(), VerifyPolicy::default(), 1.0);
    let resident = engine.generate("Q: 8+13=?\nA: ", &p).expect("res");
    drop(engine);
    let rt = Runtime::new(&dir).expect("rt");
    let mut hl = DecodeEngine::new(rt);
    hl.hostloop = true;
    let host = hl.generate("Q: 8+13=?\nA: ", &p).expect("host");
    assert_eq!(resident.tokens, host.tokens);
}

#[test]
fn router_end_to_end_over_tcp() {
    use mars::coordinator::router::{Router, RouterConfig, RouterPolicy};
    use mars::coordinator::server;
    use std::sync::Arc;
    let Some(dir) = artifacts_dir() else { return };
    // pack=4 server default: wire requests without "rounds_per_call"
    // run packed (exercising cache x packing composition throughout),
    // an explicit 1 opts out, streaming stays per-round — all pinned
    // below
    let mut rcfg = RouterConfig::new(&dir);
    rcfg.slots = 2;
    rcfg.policy = RouterPolicy::RoundRobin;
    rcfg.pack = 4;
    let router = Arc::new(Router::start(rcfg).expect("router"));
    let handle = server::serve(router.clone(), "127.0.0.1:0").expect("serve");
    let addr = handle.addr.to_string();
    let pong =
        server::client_roundtrip(&addr, r#"{"cmd": "ping"}"#).expect("ping");
    assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));
    // legacy flat keys over the wire must still map onto Mars{theta}
    let resp = server::client_roundtrip(
        &addr,
        "{\"prompt\": \"Q: 2+2=?\\nA: \", \"method\": \"eagle_tree\", \
         \"mars\": true, \"max_new\": 12, \"seed\": 4}",
    )
    .expect("gen");
    assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true));
    assert!(resp.get("tokens").and_then(|t| t.as_usize()).unwrap() > 0);
    assert_eq!(
        resp.get("policy").and_then(|p| p.as_str()),
        Some("mars:0.9")
    );
    // the reply echoes the full descriptor label that actually ran
    assert_eq!(
        resp.get("method").and_then(|m| m.as_str()),
        Some("eagle_tree:k=7,beam=2,branch=2")
    );
    // and the structured form works end to end
    let resp2 = server::client_roundtrip(
        &addr,
        "{\"prompt\": \"Q: 2+2=?\\nA: \", \"method\": \"eagle_tree\", \
         \"policy\": {\"topk\": {\"k\": 2, \"eps\": 0.1}}, \
         \"max_new\": 12, \"seed\": 4}",
    )
    .expect("gen2");
    assert_eq!(resp2.get("ok").and_then(|b| b.as_bool()), Some(true));
    assert_eq!(
        resp2.get("policy").and_then(|p| p.as_str()),
        Some("topk:2:0.1")
    );
    // identical prompt again: the replica's prefix cache serves the whole
    // prompt and the reply says so
    let resp3 = server::client_roundtrip(
        &addr,
        "{\"prompt\": \"Q: 2+2=?\\nA: \", \"method\": \"eagle_tree\", \
         \"mars\": true, \"max_new\": 12, \"seed\": 4}",
    )
    .expect("gen3");
    assert_eq!(resp3.get("ok").and_then(|b| b.as_bool()), Some(true));
    assert!(
        resp3
            .get("cached_tokens")
            .and_then(|t| t.as_usize())
            .unwrap_or(0)
            > 0,
        "repeat prompt missed the prefix cache: {}",
        resp3.to_string_json()
    );
    assert_eq!(resp3.get("tokens"), resp.get("tokens"));
    // opting out must force a cold prefill
    let resp4 = server::client_roundtrip(
        &addr,
        "{\"prompt\": \"Q: 2+2=?\\nA: \", \"method\": \"eagle_tree\", \
         \"mars\": true, \"max_new\": 12, \"seed\": 4, \"cache\": false}",
    )
    .expect("gen4");
    assert!(resp4.get("cached_tokens").is_none());
    let metrics =
        server::client_roundtrip(&addr, r#"{"cmd": "metrics"}"#).expect("m");
    assert_eq!(
        metrics.get("requests_ok").and_then(|v| v.as_usize()),
        Some(4)
    );
    // serving percentiles are exported
    assert!(metrics.get("ttft_ms_p99").is_some());
    assert!(metrics.get("tpot_ms_p50").is_some());
    // per-policy breakout: three mars requests, one topk request
    assert_eq!(
        metrics.path(&["policy", "mars", "requests"]).and_then(|v| v.as_usize()),
        Some(3)
    );
    assert_eq!(
        metrics.path(&["policy", "topk", "requests"]).and_then(|v| v.as_usize()),
        Some(1)
    );
    // per-method breakout: every request ran the eagle_tree family
    assert_eq!(
        metrics
            .path(&["method", "eagle_tree", "requests"])
            .and_then(|v| v.as_usize()),
        Some(4)
    );
    assert!(metrics.path(&["method", "eagle_tree", "ttft_ms_p50"]).is_some());
    // prefix-cache counters are exported (DESIGN.md §8): the repeat
    // prompt above hit, the opt-out and first runs missed
    assert!(
        metrics.path(&["cache", "hits"]).and_then(|v| v.as_usize())
            >= Some(1),
        "cache hits missing: {}",
        metrics.to_string_json()
    );
    assert!(
        metrics
            .path(&["cache", "tokens_saved"])
            .and_then(|v| v.as_usize())
            >= Some(1)
    );
    assert!(metrics.path(&["cache", "hit_rate"]).is_some());
    assert!(metrics.path(&["cache", "bytes_resident"]).is_some());

    // ---- pipelining: two requests on one connection, out-of-order ids --
    {
        use std::io::{BufRead, BufReader, Write};
        let mut sock =
            std::net::TcpStream::connect(&addr).expect("connect");
        // the long request first: with 2 slots both interleave and the
        // 2-token request must complete (and reply) before the long one
        let batch = "{\"id\": 101, \"prompt\": \"Text: The crew painted a \
                     red barn at noon.\\nSummary: \", \
                     \"max_new\": 64, \"seed\": 1}\n\
                     {\"id\": 102, \"prompt\": \"Q: 2+2=?\\nA: \", \
                     \"max_new\": 2, \"seed\": 1}\n";
        sock.write_all(batch.as_bytes()).expect("write batch");
        let mut reader = BufReader::new(sock);
        let mut got = Vec::new();
        for _ in 0..2 {
            let mut line = String::new();
            reader.read_line(&mut line).expect("read reply");
            let v = mars::util::json::Value::parse(&line).expect("json");
            assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
            got.push(v.get("id").and_then(|x| x.as_usize()).unwrap());
        }
        assert_eq!(
            got,
            vec![102, 101],
            "pipelined replies must complete out of submission order"
        );
    }

    // ---- streaming: deltas arrive before the final reply and
    //      concatenate to exactly the final text ------------------------
    {
        let (deltas, fin) = server::client_stream(
            &addr,
            "{\"id\": 7, \"prompt\": \"Q: 13+8=?\\nA: \", \"stream\": true, \
             \"policy\": \"mars:0.9\", \"max_new\": 24, \"seed\": 2}",
        )
        .expect("stream");
        assert!(!deltas.is_empty(), "no streamed deltas before the reply");
        assert_eq!(fin.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(fin.get("done").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(fin.get("id").and_then(|x| x.as_usize()), Some(7));
        let joined: String = deltas
            .iter()
            .map(|d| {
                assert_eq!(d.get("id").and_then(|x| x.as_usize()), Some(7));
                assert_eq!(
                    d.get("done").and_then(|b| b.as_bool()),
                    Some(false)
                );
                d.get("delta").and_then(|s| s.as_str()).unwrap().to_string()
            })
            .collect();
        assert_eq!(
            Some(joined.as_str()),
            fin.get("text").and_then(|t| t.as_str()),
            "deltas must concatenate to the final text"
        );
    }

    // ---- cancel mid-generation: the terminal reply carries the
    //      committed prefix and canceled = true -------------------------
    {
        use std::io::{BufRead, BufReader, Write};
        let mut sock =
            std::net::TcpStream::connect(&addr).expect("connect");
        // request + cancel in one batch: the cancel is processed while
        // the (very long) request is still in its first rounds
        let batch = "{\"id\": 301, \"prompt\": \"Tell me a story. \", \
                     \"max_new\": 2048, \"seed\": 3}\n\
                     {\"cmd\": \"cancel\", \"id\": 301}\n";
        sock.write_all(batch.as_bytes()).expect("write batch");
        let mut reader = BufReader::new(sock);
        let mut ack_ok = None;
        let mut fin = None;
        while fin.is_none() {
            let mut line = String::new();
            reader.read_line(&mut line).expect("read reply");
            let v = mars::util::json::Value::parse(&line).expect("json");
            if v.get("cmd").and_then(|c| c.as_str()) == Some("cancel") {
                ack_ok = v.get("ok").and_then(|b| b.as_bool());
            } else {
                fin = Some(v);
            }
        }
        assert_eq!(ack_ok, Some(true), "cancel ack must find the request");
        let fin = fin.unwrap();
        assert_eq!(fin.get("id").and_then(|x| x.as_usize()), Some(301));
        assert_eq!(fin.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(
            fin.get("canceled").and_then(|b| b.as_bool()),
            Some(true),
            "reply must be flagged canceled: {}",
            fin.to_string_json()
        );
        // far fewer tokens than max_new committed before the cancel hit
        let tokens = fin.get("tokens").and_then(|t| t.as_usize()).unwrap();
        assert!(tokens < 2048, "cancel did not stop generation: {tokens}");
    }

    // ---- round packing over the wire: a packed request is
    //      token-identical to unpacked and echoes the effective pack ----
    {
        let base = "{\"prompt\": \"Q: 9+5=?\\nA: \", \"method\": \
                    \"eagle_tree\", \"policy\": \"mars:0.9\", \
                    \"max_new\": 16, \"seed\": 6, \"cache\": false";
        // explicit "rounds_per_call": 1 must opt out of the server's
        // --pack 4 default — truly unpacked, nothing echoed
        let unpacked = server::client_roundtrip(
            &addr,
            &format!("{base}, \"rounds_per_call\": 1}}"),
        )
        .expect("unpacked");
        assert_eq!(unpacked.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert!(
            unpacked.get("rounds_per_call").is_none(),
            "explicit 1 must opt out of the server pack default: {}",
            unpacked.to_string_json()
        );
        // omitting the field inherits the server default — echoed as 4
        let defaulted =
            server::client_roundtrip(&addr, &format!("{base}}}"))
                .expect("defaulted");
        assert_eq!(
            defaulted.get("rounds_per_call").and_then(|v| v.as_usize()),
            Some(4),
            "server --pack default must apply and echo: {}",
            defaulted.to_string_json()
        );
        assert_eq!(
            defaulted.get("text").and_then(|t| t.as_str()),
            unpacked.get("text").and_then(|t| t.as_str()),
            "server-default packing diverged from opt-out"
        );
        let packed = server::client_roundtrip(
            &addr,
            &format!("{base}, \"rounds_per_call\": 8}}"),
        )
        .expect("packed");
        assert_eq!(packed.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(
            packed.get("rounds_per_call").and_then(|v| v.as_usize()),
            Some(8),
            "reply must echo the effective pack: {}",
            packed.to_string_json()
        );
        assert_eq!(
            packed.get("text").and_then(|t| t.as_str()),
            unpacked.get("text").and_then(|t| t.as_str()),
            "packed decode diverged over the wire"
        );
        // an absurd pack is clamped to the artifact's PACK_MAX on the
        // host (the device clamps its loop the same way), the echo
        // reports the clamped value, and generation is still complete
        // and token-identical — not truncated by round-cap overcounting
        let huge = server::client_roundtrip(
            &addr,
            &format!("{base}, \"rounds_per_call\": 1000}}"),
        )
        .expect("huge pack");
        assert_eq!(
            huge.get("rounds_per_call").and_then(|v| v.as_usize()),
            Some(32),
            "host must clamp the pack to PACK_MAX: {}",
            huge.to_string_json()
        );
        assert_eq!(
            huge.get("text").and_then(|t| t.as_str()),
            unpacked.get("text").and_then(|t| t.as_str()),
            "clamped huge pack diverged"
        );
        // streaming under a pack request: the replica caps the slot at 1
        // (no echo — packing did not run) and per-round delta reassembly
        // still reproduces the final text exactly
        let (deltas, fin) = server::client_stream(
            &addr,
            "{\"id\": 9, \"prompt\": \"Q: 9+5=?\\nA: \", \"method\": \
             \"eagle_tree\", \"policy\": \"mars:0.9\", \"stream\": true, \
             \"rounds_per_call\": 8, \"max_new\": 16, \"seed\": 6, \
             \"cache\": false}",
        )
        .expect("packed stream");
        assert!(!deltas.is_empty());
        assert!(
            fin.get("rounds_per_call").is_none(),
            "streaming slots must not pack: {}",
            fin.to_string_json()
        );
        let joined: String = deltas
            .iter()
            .map(|d| {
                d.get("delta").and_then(|s| s.as_str()).unwrap().to_string()
            })
            .collect();
        assert_eq!(
            Some(joined.as_str()),
            fin.get("text").and_then(|t| t.as_str()),
            "streamed deltas must concatenate to the final text under \
             pack caps"
        );
        assert_eq!(
            fin.get("text").and_then(|t| t.as_str()),
            unpacked.get("text").and_then(|t| t.as_str()),
            "streamed packed request diverged from unpacked"
        );
    }
}

/// Cross-sequence batched decoding (DESIGN.md §9.5): lanes stepped
/// together through the `*_batch` programs must be token-identical to
/// the same requests run solo at T=0, per-lane knobs must stay
/// lane-local, mid-flight joins must splice cleanly, and shared
/// dispatches must actually amortize.
#[test]
fn batched_decode_semantics_suite() {
    use mars::engine::{BatchRunner, GenResult};
    let Some(dir) = artifacts_dir() else { return };
    let engine = DecodeEngine::new(Runtime::new(&dir).expect("runtime"));
    if !engine.rt.supports_batching() {
        eprintln!(
            "[skip] artifacts predate batched decoding — rerun `make \
             artifacts`"
        );
        return;
    }

    let prompts =
        ["Q: 21+17=?\nA: ", "Q: 3+4=?\nA: ", "Q: 12+7=?\nA: ", "Q: 9+5=?\nA: "];
    let policies = [
        VerifyPolicy::Strict,
        VerifyPolicy::Mars { theta: 0.9 },
        VerifyPolicy::TopK { k: 2, eps: 0.1 },
        VerifyPolicy::Entropy { h_max: 1.0 },
    ];
    let solo = |p: &GenParams, i: usize| {
        engine
            .generate(prompts[i], p)
            .unwrap_or_else(|e| panic!("solo {:?}: {e:#}", p.method))
    };
    // drive a runner until every live lane retires, collecting per-slot
    // results
    fn drain(runner: &mut BatchRunner<'_>) -> Vec<Option<GenResult>> {
        let mut done: Vec<Option<GenResult>> =
            (0..runner.batch_max()).map(|_| None).collect();
        while !runner.is_empty() {
            for (slot, r) in runner.step().expect("batched step") {
                assert!(done[slot].is_none(), "slot {slot} retired twice");
                done[slot] = Some(r);
            }
        }
        done
    }

    // --- every method family x every verify policy: a two-lane batch at
    //     T=0 is token- and decision-identical to solo decodes ----------
    for method in SpecMethod::all_defaults() {
        for policy in policies {
            let mut runner =
                BatchRunner::new(&engine.rt).expect("batch runner");
            assert!(runner.batch_max() >= 2, "BATCH_MAX < 2");
            let mut admitted = Vec::new();
            for i in 0..2 {
                let mut p = params(method, policy, 0.0);
                p.max_new = 16;
                p.seed = 20 + i as u64;
                let toks = mars::tokenizer::encode(prompts[i]);
                let slot = runner
                    .admit(&toks, &p, None)
                    .unwrap_or_else(|e| {
                        panic!("{method:?}/{policy:?} admit: {e:#}")
                    });
                admitted.push((slot, i, p));
            }
            let mut done = drain(&mut runner);
            for (slot, i, p) in admitted {
                let b = done[slot].take().expect("lane retired");
                let s = solo(&p, i);
                assert_eq!(
                    b.tokens, s.tokens,
                    "{method:?}/{policy:?} lane {i}: batched decode \
                     diverged from solo: {:?} vs {:?}",
                    b.text, s.text
                );
                // decision scalars, not just tokens: the verify rule ran
                // identically inside the batched program
                assert_eq!(b.snapshot.rounds, s.snapshot.rounds);
                assert_eq!(
                    b.snapshot.exact_accepts,
                    s.snapshot.exact_accepts
                );
                assert_eq!(
                    b.snapshot.relaxed_accepts,
                    s.snapshot.relaxed_accepts
                );
            }
        }
    }

    // --- per-lane knobs are lane-local: one batch, four different verify
    //     policies and seeds sharing the dispatch stream ----------------
    {
        let method = SpecMethod::Sps { k: 7 };
        let mut runner = BatchRunner::new(&engine.rt).expect("batch runner");
        let b = runner.batch_max().min(4);
        let mut admitted = Vec::new();
        for i in 0..b {
            let mut p = params(method, policies[i % policies.len()], 0.0);
            p.max_new = 16;
            p.seed = 40 + i as u64;
            let toks = mars::tokenizer::encode(prompts[i]);
            let slot = runner.admit(&toks, &p, None).expect("mixed admit");
            admitted.push((slot, i, p));
        }
        let mut done = drain(&mut runner);
        for (slot, i, p) in admitted {
            let r = done[slot].take().expect("lane retired");
            let s = solo(&p, i);
            assert_eq!(
                r.tokens, s.tokens,
                "mixed-policy lane {i} ({:?}) diverged",
                p.policy
            );
            if b >= 2 {
                // amortization: a lane in a shared batch pays strictly
                // less than one dispatch per dispatch it rode in
                assert!(
                    r.dispatch_share < r.device_calls as f64,
                    "lane {i}: dispatch_share {} not amortized over {} \
                     calls",
                    r.dispatch_share,
                    r.device_calls
                );
            }
        }
    }

    // --- continuous admission: a lane joining mid-flight at a round
    //     boundary decodes exactly as it would solo ---------------------
    {
        let mut runner = BatchRunner::new(&engine.rt).expect("batch runner");
        let mut admitted = Vec::new();
        for i in 0..2 {
            let mut p =
                params(SpecMethod::default(), VerifyPolicy::Mars { theta: 0.9 }, 0.0);
            p.max_new = 24;
            p.seed = 60 + i as u64;
            let toks = mars::tokenizer::encode(prompts[i]);
            let slot = runner.admit(&toks, &p, None).expect("early admit");
            admitted.push((slot, i, p));
        }
        let mut done: Vec<Option<GenResult>> =
            (0..runner.batch_max()).map(|_| None).collect();
        for _ in 0..3 {
            for (slot, r) in runner.step().expect("warmup step") {
                done[slot] = Some(r);
            }
        }
        // the late joiner splices into a batch whose other lanes have
        // already advanced several rounds
        let mut p =
            params(SpecMethod::default(), VerifyPolicy::Mars { theta: 0.9 }, 0.0);
        p.max_new = 12;
        p.seed = 62;
        let toks = mars::tokenizer::encode(prompts[2]);
        let slot = runner.admit(&toks, &p, None).expect("late join");
        admitted.push((slot, 2, p));
        while !runner.is_empty() {
            for (slot, r) in runner.step().expect("drain step") {
                done[slot] = Some(r);
            }
        }
        for (slot, i, p) in admitted {
            let r = done[slot].take().expect("lane retired");
            let s = solo(&p, i);
            assert_eq!(
                r.tokens, s.tokens,
                "lane {i} diverged after a mid-flight join: {:?} vs {:?}",
                r.text, s.text
            );
        }
    }

    // --- dispatch amortization at full occupancy: a packed 4-lane sps
    //     batch spends far fewer amortized dispatches per token than the
    //     same packed requests run solo --------------------------------
    if engine.rt.layout().batch_max() >= 4 {
        let mk = |i: usize| {
            let mut p =
                params(SpecMethod::Sps { k: 7 }, VerifyPolicy::Strict, 0.0);
            p.max_new = 24;
            p.seed = 80 + i as u64;
            p.rounds_per_call = 4;
            p
        };
        let (mut solo_calls, mut solo_toks) = (0.0f64, 0usize);
        for i in 0..4 {
            let s = solo(&mk(i), i);
            solo_calls += s.dispatch_share;
            solo_toks += s.tokens.len();
        }
        let mut runner = BatchRunner::new(&engine.rt).expect("batch runner");
        for i in 0..4 {
            let toks = mars::tokenizer::encode(prompts[i]);
            runner.admit(&toks, &mk(i), None).expect("full admit");
        }
        let (mut batch_share, mut batch_toks) = (0.0f64, 0usize);
        for r in drain(&mut runner).into_iter().flatten() {
            batch_share += r.dispatch_share;
            batch_toks += r.tokens.len();
        }
        assert_eq!(batch_toks, solo_toks, "token counts diverged");
        let ratio = (batch_share / batch_toks as f64)
            / (solo_calls / solo_toks as f64);
        assert!(
            ratio < 0.6,
            "B=4 amortized dispatches/token not < 0.6x solo: {ratio:.3} \
             ({batch_share:.1} vs {solo_calls:.1} over {batch_toks} \
             tokens)"
        );
    } else {
        eprintln!("[skip] BATCH_MAX < 4 — amortization pin skipped");
    }
}

/// The batched serving path end to end: `--batch 4` replica loop,
/// concurrent requests sharing lanes, streaming delta reassembly from a
/// batched slot, mixed-family queueing, cancel, and the exported
/// occupancy histogram (DESIGN.md §9.5).
#[test]
fn batched_router_end_to_end_over_tcp() {
    use mars::coordinator::router::{Router, RouterConfig, RouterPolicy};
    use mars::coordinator::server;
    use std::io::{BufRead, BufReader, Write};
    use std::sync::Arc;
    let Some(dir) = artifacts_dir() else { return };
    {
        let a = Artifacts::load(&dir).expect("artifacts load");
        if !a.executable_names().iter().any(|n| n == "batch_join") {
            eprintln!("[skip] artifacts predate batched decoding");
            return;
        }
    }
    let mut rcfg = RouterConfig::new(&dir);
    rcfg.slots = 4;
    rcfg.policy = RouterPolicy::RoundRobin;
    rcfg.pack = 4;
    rcfg.batch = 4;
    let router = Arc::new(Router::start(rcfg).expect("router"));
    let handle = server::serve(router.clone(), "127.0.0.1:0").expect("serve");
    let addr = handle.addr.to_string();

    // ---- four concurrent identical requests share the batch and must
    //      reply identically (join splice + masked lanes are inert) -----
    let gen_req = |id: usize| {
        format!(
            "{{\"id\": {id}, \"prompt\": \"Q: 21+17=?\\nA: \", \"method\": \
             \"eagle_tree\", \"policy\": \"mars:0.9\", \"max_new\": 16, \
             \"seed\": 5, \"cache\": false}}\n"
        )
    };
    let mut sock = std::net::TcpStream::connect(&addr).expect("connect");
    let batch: String = (401..405).map(gen_req).collect();
    sock.write_all(batch.as_bytes()).expect("write batch");
    let mut reader = BufReader::new(sock);
    let mut texts = std::collections::BTreeMap::new();
    for _ in 0..4 {
        let mut line = String::new();
        reader.read_line(&mut line).expect("read reply");
        let v = mars::util::json::Value::parse(&line).expect("json");
        assert_eq!(
            v.get("ok").and_then(|b| b.as_bool()),
            Some(true),
            "{line}"
        );
        texts.insert(
            v.get("id").and_then(|x| x.as_usize()).unwrap(),
            v.get("text").and_then(|t| t.as_str()).unwrap().to_string(),
        );
    }
    assert_eq!(texts.len(), 4, "a reply went missing: {texts:?}");
    let reference = texts.values().next().unwrap().clone();
    assert!(
        texts.values().all(|t| *t == reference),
        "concurrent batched lanes of one request diverged: {texts:?}"
    );

    // ---- the same request at occupancy 1 (queue now empty) matches ----
    let lone = server::client_roundtrip(&addr, gen_req(409).trim())
        .expect("lone");
    assert_eq!(
        lone.get("text").and_then(|t| t.as_str()),
        Some(reference.as_str()),
        "occupancy-1 batched decode diverged from occupancy-4"
    );

    // ---- streaming from a batched slot: per-round deltas reassemble to
    //      exactly the final text --------------------------------------
    let (deltas, fin) = server::client_stream(
        &addr,
        "{\"id\": 410, \"prompt\": \"Q: 21+17=?\\nA: \", \"method\": \
         \"eagle_tree\", \"policy\": \"mars:0.9\", \"stream\": true, \
         \"max_new\": 16, \"seed\": 5, \"cache\": false}",
    )
    .expect("batched stream");
    assert!(!deltas.is_empty(), "no deltas from the batched slot");
    let joined: String = deltas
        .iter()
        .map(|d| d.get("delta").and_then(|s| s.as_str()).unwrap().to_string())
        .collect();
    assert_eq!(
        Some(joined.as_str()),
        fin.get("text").and_then(|t| t.as_str()),
        "batched-slot deltas must concatenate to the final text"
    );
    assert_eq!(
        fin.get("text").and_then(|t| t.as_str()),
        Some(reference.as_str()),
        "streamed batched decode diverged"
    );

    // ---- a mixed-family arrival queues behind the running family and
    //      still completes (admission skip-ahead never drops it) --------
    {
        let mut sock = std::net::TcpStream::connect(&addr).expect("connect");
        let batch = format!(
            "{}{{\"id\": 430, \"prompt\": \"Q: 3+4=?\\nA: \", \"method\": \
             \"sps\", \"max_new\": 8, \"seed\": 6}}\n{}",
            gen_req(428),
            gen_req(429)
        );
        sock.write_all(batch.as_bytes()).expect("write mixed");
        let mut reader = BufReader::new(sock);
        let mut ids = Vec::new();
        for _ in 0..3 {
            let mut line = String::new();
            reader.read_line(&mut line).expect("read reply");
            let v = mars::util::json::Value::parse(&line).expect("json");
            assert_eq!(
                v.get("ok").and_then(|b| b.as_bool()),
                Some(true),
                "{line}"
            );
            ids.push(v.get("id").and_then(|x| x.as_usize()).unwrap());
        }
        ids.sort_unstable();
        assert_eq!(ids, vec![428, 429, 430]);
    }

    // ---- cancel retires one lane without disturbing its batchmates ----
    {
        let mut sock = std::net::TcpStream::connect(&addr).expect("connect");
        let batch = format!(
            "{{\"id\": 440, \"prompt\": \"Tell me a story. \", \
             \"max_new\": 2048, \"seed\": 3}}\n{}{{\"cmd\": \"cancel\", \
             \"id\": 440}}\n",
            gen_req(441)
        );
        sock.write_all(batch.as_bytes()).expect("write cancel");
        let mut reader = BufReader::new(sock);
        let mut canceled = None;
        let mut mate = None;
        while canceled.is_none() || mate.is_none() {
            let mut line = String::new();
            reader.read_line(&mut line).expect("read reply");
            let v = mars::util::json::Value::parse(&line).expect("json");
            match v.get("id").and_then(|x| x.as_usize()) {
                Some(440) if v.get("cmd").is_none() => canceled = Some(v),
                Some(441) => mate = Some(v),
                _ => {}
            }
        }
        let canceled = canceled.unwrap();
        assert_eq!(
            canceled.get("canceled").and_then(|b| b.as_bool()),
            Some(true),
            "cancel lost on the batched path: {}",
            canceled.to_string_json()
        );
        let tokens =
            canceled.get("tokens").and_then(|t| t.as_usize()).unwrap();
        assert!(tokens < 2048, "cancel did not stop the lane: {tokens}");
        let mate = mate.unwrap();
        assert_eq!(mate.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(
            mate.get("text").and_then(|t| t.as_str()),
            Some(reference.as_str()),
            "a batchmate's output changed when its neighbor was canceled"
        );
    }

    // ---- the occupancy histogram is exported and saw shared work ------
    let metrics =
        server::client_roundtrip(&addr, r#"{"cmd": "metrics"}"#).expect("m");
    let dispatches = metrics
        .path(&["batch", "dispatches"])
        .and_then(|v| v.as_usize())
        .unwrap_or(0);
    assert!(
        dispatches > 0,
        "no batched dispatches recorded: {}",
        metrics.to_string_json()
    );
    assert!(metrics.path(&["batch", "occupancy_mean"]).is_some());
    let hist = metrics
        .path(&["batch", "occupancy_hist"])
        .and_then(|h| h.as_obj())
        .expect("occupancy_hist");
    assert!(
        hist.keys().any(|k| k.parse::<usize>().unwrap_or(0) >= 2),
        "no dispatch ever ran more than one lane: {hist:?}"
    );
}

// ------------------------------------------- bench record/diff harness -----

/// Every bench target emits schema-valid records on real artifacts, and
/// `bench diff` behaves as the regression gate promises: exit-clean on
/// self-compare, loud (naming the key) on a perturbation past threshold.
/// One engine build covers all targets (PJRT handles are not `Send`).
#[test]
fn bench_harness_suite() {
    use mars::bench::diff::{diff_docs, DiffCfg};
    use mars::bench::record::{Provenance, RecordDoc};
    use mars::bench::{self, BenchCtx};

    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir().join(format!(
        "mars-bench-test-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&tmp);
    let engine = DecodeEngine::new(Runtime::new(&dir).expect("runtime"));
    let mut ctx = BenchCtx::new(&engine, 2, 7);
    ctx.max_new = 16;
    // out_dir intentionally missing: the emitter must create it
    ctx.out_dir = tmp.join("results");
    ctx.bench_dir = tmp.clone();
    assert!(!ctx.out_dir.exists());

    let methods = [SpecMethod::Sps { k: 7 }];
    let policies = [VerifyPolicy::Mars { theta: 0.9 }];
    bench::packing(&ctx, &methods, &policies, &[1, 2]).expect("packing");
    if engine.rt.supports_batching() {
        bench::batch(&ctx, &methods, &policies, &[1, 2]).expect("batch");
    }
    bench::policy_sweep(&ctx, &methods, &policies).expect("policies");
    assert!(ctx.out_dir.join("packing.md").exists(), "emit-into-missing-dir");

    // every emitted doc passes the shared validator, provenance measured
    let mut docs = Vec::new();
    for target in ["packing", "batch", "policies"] {
        let path = tmp.join(format!("BENCH_{target}.json"));
        if target == "batch" && !engine.rt.supports_batching() {
            continue;
        }
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{target}: {e}"));
        let doc = RecordDoc::parse(&text)
            .unwrap_or_else(|e| panic!("{target}: {e}"));
        assert_eq!(doc.target, target);
        assert_eq!(doc.env.provenance, Provenance::Measured, "{target}");
        assert_eq!(
            doc.env.artifact_hash,
            engine.rt.layout().hash,
            "{target}"
        );
        assert!(!doc.records.is_empty(), "{target}");
        docs.push(doc);
    }

    // self-compare: clean pass, every ratio exactly 1.0
    for doc in &docs {
        let r = diff_docs(doc, doc, &DiffCfg::default());
        assert!(!r.regressed(), "{}: diff(x, x) regressed", doc.target);
        assert!(r.added.is_empty() && r.removed.is_empty(), "{}", doc.target);
        for row in &r.rows {
            assert_eq!(row.ratio, 1.0, "{}: {}", doc.target, row.key);
        }
    }

    // perturb past threshold: tok_per_s halved (n=2 widens 15% -> 30%,
    // a 50% drop still fails), ttft tripled — both named in the output
    let packing = &docs[0];
    let mut bad = packing.clone();
    let mut hit_tok = false;
    let mut hit_ttft = false;
    for r in &mut bad.records {
        if r.metric == "tok_per_s" && !hit_tok {
            r.value *= 0.5;
            hit_tok = true;
        } else if r.metric == "ttft_ms_p50" && !hit_ttft {
            r.value *= 3.0;
            hit_ttft = true;
        }
    }
    assert!(hit_tok && hit_ttft, "fixture rows missing");
    let r = diff_docs(packing, &bad, &DiffCfg::default());
    assert!(r.regressed(), "perturbed copy must fail the gate");
    let rendered = r.render("old", "new");
    for f in r.failures() {
        assert!(rendered.contains(&f.key), "key {} not named", f.key);
    }
    assert!(
        r.failures().iter().any(|f| f.key.contains("tok_per_s")),
        "tok_per_s drop not flagged"
    );
    assert!(
        r.failures().iter().any(|f| f.key.contains("ttft_ms_p50")),
        "ttft rise not flagged"
    );

    // key-pairing totality: a removed record is reported, never dropped
    let mut shrunk = packing.clone();
    let gone = shrunk.records.pop().expect("has records").key_id();
    let r = diff_docs(packing, &shrunk, &DiffCfg::default());
    assert_eq!(r.removed, vec![gone]);

    let _ = std::fs::remove_dir_all(&tmp);
}

/// Simclock determinism pin: the same seed and config produce identical
/// simulated_units across two independent runs — including the
/// DISPATCH_OVERHEAD / dispatch_share terms that packing (DESIGN.md
/// §9.6) and batching (§9.5) feed through the cost model.
#[test]
fn simclock_determinism_pin() {
    use mars::bench::simclock;

    let Some(dir) = artifacts_dir() else { return };
    let engine = DecodeEngine::new(Runtime::new(&dir).expect("runtime"));
    let prompt = "Sum the list: 3 1 4 1 5 9 2 6.\nAnswer: ";
    let mut p = params(
        SpecMethod::Sps { k: 7 },
        VerifyPolicy::Mars { theta: 0.9 },
        1.0,
    );
    p.seed = 7;
    p.cache = false; // a warm prefix must not skew run b's accounting
    p.rounds_per_call = 2; // exercise the packed-dispatch accounting
    let a = engine.generate(prompt, &p).expect("run a");
    let b = engine.generate(prompt, &p).expect("run b");
    assert_eq!(a.tokens, b.tokens, "token stream must be seed-determined");
    assert_eq!(a.device_calls, b.device_calls);
    assert_eq!(a.dispatch_share, b.dispatch_share);
    assert_eq!(a.snapshot.rounds, b.snapshot.rounds);
    assert_eq!(a.snapshot.draft_steps, b.snapshot.draft_steps);
    let ua = simclock::simulated_units(p.method, &a);
    let ub = simclock::simulated_units(p.method, &b);
    assert_eq!(ua, ub, "simulated_units must be bit-identical");
    // the dispatch term is live: zeroing dispatch_share changes the cost
    let mut free = a.clone();
    free.dispatch_share = 0.0;
    assert!(
        simclock::simulated_units(p.method, &free) < ua,
        "DISPATCH_OVERHEAD term missing from simulated_units"
    );
}

/// Telemetry surfaces end to end (DESIGN.md §12): probe-driven margin
/// histograms, the `prom` and `metrics`+`reset` RPCs, and the `--trace`
/// JSONL span log, all against a live traced server.
#[test]
fn telemetry_surfaces_over_tcp() {
    use mars::coordinator::router::{Router, RouterConfig, RouterPolicy};
    use mars::coordinator::server;
    use mars::obs::trace::{summarize, TraceWriter};
    use std::sync::Arc;
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir()
        .join(format!("mars-telemetry-test-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).expect("tmp dir");
    let trace_path = tmp.join("trace.jsonl");
    let trace =
        Some(Arc::new(TraceWriter::create(&trace_path).expect("trace")));
    let mut rcfg = RouterConfig::new(&dir);
    rcfg.slots = 2;
    rcfg.policy = RouterPolicy::RoundRobin;
    rcfg.trace = trace;
    let router = Arc::new(Router::start(rcfg).expect("router"));
    let handle = server::serve(router.clone(), "127.0.0.1:0").expect("serve");
    let addr = handle.addr.to_string();

    // two probe-enabled requests under MARS: every verify decision flows
    // into the margin-by-outcome histograms
    for seed in [4, 5] {
        let resp = server::client_roundtrip(
            &addr,
            &format!(
                "{{\"prompt\": \"Q: 2+2=?\\nA: \", \"method\": \
                 \"eagle_tree\", \"policy\": \"mars:0.9\", \"probe\": true, \
                 \"max_new\": 12, \"seed\": {seed}}}"
            ),
        )
        .expect("gen");
        assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true));
    }

    // margin histograms in the JSON snapshot, split by outcome, counts
    // covering every decision (exact + relaxed + reject >= accepted)
    let snap =
        server::client_roundtrip(&addr, r#"{"cmd": "metrics"}"#).expect("m");
    let count = |outcome: &str| {
        snap.path(&["margin", "mars", "eagle_tree", outcome, "count"])
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| {
                panic!("missing margin.{outcome}: {}", snap.to_string_json())
            })
    };
    let total = count("exact") + count("relaxed") + count("reject");
    assert!(total > 0.0, "no margin samples: {}", snap.to_string_json());
    // per-round telemetry flowed through the sink into the snapshot
    let turns = snap
        .path(&["rounds", "turns"])
        .and_then(|v| v.as_usize())
        .unwrap_or(0);
    assert!(turns > 0, "no round events: {}", snap.to_string_json());

    // the prom RPC serves the text exposition with the same truth
    let prom = server::client_roundtrip(&addr, r#"{"cmd": "prom"}"#)
        .expect("prom");
    let text = prom.get("prom").and_then(|p| p.as_str()).expect("prom text");
    for needle in [
        "# TYPE mars_requests_ok counter",
        "mars_requests_ok 2",
        "# TYPE mars_margin histogram",
        "outcome=\"exact\"",
        "mars_round_turns",
        "mars_ttft_ms_bucket",
        "le=\"+Inf\"",
    ] {
        assert!(text.contains(needle), "prom missing {needle:?}:\n{text}");
    }

    // metrics + reset: the reply carries the pre-reset truth, the next
    // scrape starts from zero
    let pre = server::client_roundtrip(
        &addr,
        r#"{"cmd": "metrics", "reset": true}"#,
    )
    .expect("reset");
    assert_eq!(pre.get("requests_ok").and_then(|v| v.as_usize()), Some(2));
    let post =
        server::client_roundtrip(&addr, r#"{"cmd": "metrics"}"#).expect("m2");
    assert_eq!(post.get("requests_ok").and_then(|v| v.as_usize()), Some(0));
    assert!(
        post.get("margin").is_none(),
        "reset left margin histograms: {}",
        post.to_string_json()
    );

    // the trace file carries the full span lifecycle for both requests
    let s = summarize(&trace_path).expect("summarize");
    assert_eq!(s.bad_lines, 0, "trace log has unparseable lines");
    assert_eq!(s.ok, 2, "expected 2 ok commits");
    assert!(s.round_events > 0, "no round spans traced");
    assert!(s.queue_ms.count() >= 2, "queue spans missing");
    assert!(s.prefill_ms.count() >= 2, "prefill spans missing");
    assert!(s.tokens > 0, "commit spans carried no tokens");
    std::fs::remove_dir_all(&tmp).ok();
}

/// Fault-tolerance chaos suite (DESIGN.md §13) on real artifacts:
/// injected dispatch faults under load, a replica killed outright with
/// router failover, per-request deadlines, and overload shedding. The
/// invariants throughout: every request reaches a terminal reply
/// (success / busy / deadline / typed error — the suite finishing at
/// all proves no connection hung), the router stops selecting a downed
/// replica, load gauges reconcile to zero at drain, and the failure
/// taxonomy shows up on the metrics and trace surfaces.
#[test]
fn chaos_fault_tolerance_suite() {
    use mars::coordinator::replica::ReplicaHealth;
    use mars::coordinator::router::{Router, RouterConfig, RouterPolicy};
    use mars::coordinator::server;
    use mars::fault::FaultSpec;
    use mars::obs::trace::{summarize, TraceWriter};
    use std::sync::Arc;
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir()
        .join(format!("mars-chaos-test-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).expect("tmp dir");

    let gen_req = |id: usize| {
        format!(
            "{{\"id\": {id}, \"prompt\": \"Q: 21+17=?\\nA: \", \"method\": \
             \"eagle_tree\", \"policy\": \"mars:0.9\", \"max_new\": 12, \
             \"seed\": 5, \"cache\": false}}"
        )
    };

    // ---- wave 1: dispatch faults at rate 0.2 on every replica --------
    // Every request must still reach a terminal reply (ok or a typed
    // error naming the injected fault), the failure counters must land
    // on the snapshot and the trace, and the gauges must reconcile.
    {
        let trace_path = tmp.join("chaos-trace.jsonl");
        let mut rcfg = RouterConfig::new(&dir);
        rcfg.replicas = 2;
        rcfg.slots = 2;
        rcfg.fault =
            Some(FaultSpec::parse("dispatch=0.2,seed=11").expect("spec"));
        rcfg.trace = Some(Arc::new(
            TraceWriter::create(&trace_path).expect("trace"),
        ));
        let router = Arc::new(Router::start(rcfg).expect("router"));
        let handle =
            server::serve(router.clone(), "127.0.0.1:0").expect("serve");
        let addr = handle.addr.to_string();
        let (mut ok, mut failed) = (0usize, 0usize);
        for id in 0..16 {
            let resp = server::client_roundtrip(&addr, &gen_req(500 + id))
                .expect("terminal reply");
            if resp.get("ok").and_then(|b| b.as_bool()) == Some(true) {
                ok += 1;
            } else {
                failed += 1;
                assert!(
                    resp.get("error").is_some(),
                    "failed reply lacks an error: {}",
                    resp.to_string_json()
                );
            }
        }
        assert_eq!(ok + failed, 16, "a request went missing");
        assert!(ok > 0, "rate-0.2 faults killed every request");
        let snap = server::client_roundtrip(&addr, r#"{"cmd": "metrics"}"#)
            .expect("metrics");
        if failed > 0 {
            let dispatch_failed = snap
                .path(&["failures", "dispatch_failed"])
                .and_then(|v| v.as_usize())
                .unwrap_or(0);
            assert!(
                dispatch_failed > 0,
                "failures absent from the snapshot: {}",
                snap.to_string_json()
            );
        }
        // health gauge: both replicas reported a state
        assert!(
            snap.path(&["health", "0"]).is_some()
                && snap.path(&["health", "1"]).is_some(),
            "replica health missing from the snapshot: {}",
            snap.to_string_json()
        );
        // gauges reconcile at drain: nothing active, nothing queued
        assert_eq!(router.active_total(), 0, "load gauge leaked");
        assert_eq!(router.queued_total(), 0, "queued gauge leaked");
        drop(handle);
        if failed > 0 {
            let s = summarize(&trace_path).expect("summarize");
            assert!(
                s.fault_events > 0,
                "injected faults left no failure-semantics trace lines"
            );
        }
    }

    // ---- wave 2: kill replica 0 outright, router fails over ----------
    // dispatch=1.0 scoped to replica 0: its admission-failure streak
    // trips the supervisor into Down, the router's pick mask drops it,
    // and later requests succeed on replica 1. Requests that died on
    // replica 0 got typed (mostly retriable) errors, never silence.
    {
        let mut rcfg = RouterConfig::new(&dir);
        rcfg.replicas = 2;
        rcfg.slots = 2;
        rcfg.fault = Some(
            FaultSpec::parse("dispatch=1.0,rebuild=1.0,seed=3,only=0")
                .expect("spec"),
        );
        let router = Arc::new(Router::start(rcfg).expect("router"));
        let handle =
            server::serve(router.clone(), "127.0.0.1:0").expect("serve");
        let addr = handle.addr.to_string();
        let mut reference: Option<String> = None;
        for id in 0..24 {
            let resp = server::client_roundtrip(&addr, &gen_req(600 + id))
                .expect("terminal reply");
            if resp.get("ok").and_then(|b| b.as_bool()) == Some(true) {
                // survivors all ran the same T=0 request on replica 1
                let text = resp
                    .get("text")
                    .and_then(|t| t.as_str())
                    .expect("ok reply has text")
                    .to_string();
                if let Some(r) = &reference {
                    assert_eq!(
                        &text, r,
                        "failover changed a deterministic output"
                    );
                } else {
                    reference = Some(text);
                }
            }
        }
        assert!(
            reference.is_some(),
            "no request ever succeeded after failover"
        );
        let healths = router.healths();
        assert_eq!(
            healths[0],
            ReplicaHealth::Down,
            "replica 0 should be Down after its failure streak: {healths:?}"
        );
        assert_eq!(healths[1], ReplicaHealth::Up, "{healths:?}");
        // once Down, the router must stop selecting replica 0: a fresh
        // burst must be all-ok
        for id in 0..4 {
            let resp = server::client_roundtrip(&addr, &gen_req(650 + id))
                .expect("terminal reply");
            assert_eq!(
                resp.get("ok").and_then(|b| b.as_bool()),
                Some(true),
                "router still routes to the downed replica: {}",
                resp.to_string_json()
            );
        }
        let snap = server::client_roundtrip(&addr, r#"{"cmd": "metrics"}"#)
            .expect("metrics");
        assert!(
            snap.path(&["failures", "replica_down"])
                .and_then(|v| v.as_usize())
                .unwrap_or(0)
                > 0,
            "replica_down not counted: {}",
            snap.to_string_json()
        );
        assert_eq!(
            snap.path(&["health", "0"]).and_then(|v| v.as_str()),
            Some("down"),
            "health gauge disagrees: {}",
            snap.to_string_json()
        );
        assert_eq!(router.active_total(), 0, "load gauge leaked");
    }

    // ---- wave 3: per-request deadline — partial text, not an error ---
    {
        let mut rcfg = RouterConfig::new(&dir);
        rcfg.slots = 2;
        let router = Arc::new(Router::start(rcfg).expect("router"));
        let handle =
            server::serve(router.clone(), "127.0.0.1:0").expect("serve");
        let addr = handle.addr.to_string();
        let resp = server::client_roundtrip(
            &addr,
            "{\"id\": 700, \"prompt\": \"Tell me a story. \", \
             \"max_new\": 2048, \"seed\": 3, \"deadline_ms\": 1}",
        )
        .expect("deadline reply");
        assert_eq!(
            resp.get("ok").and_then(|b| b.as_bool()),
            Some(true),
            "a deadline reply is partial success, not an error: {}",
            resp.to_string_json()
        );
        assert_eq!(
            resp.get("deadline_exceeded").and_then(|b| b.as_bool()),
            Some(true),
            "deadline_exceeded missing: {}",
            resp.to_string_json()
        );
        let tokens = resp.get("tokens").and_then(|t| t.as_usize()).unwrap();
        assert!(tokens < 2048, "deadline did not stop generation: {tokens}");
        // without the field, the same request runs to its budget
        let resp = server::client_roundtrip(
            &addr,
            "{\"id\": 701, \"prompt\": \"Q: 2+2=?\\nA: \", \"max_new\": 8, \
             \"seed\": 3}",
        )
        .expect("no-deadline reply");
        assert!(resp.get("deadline_exceeded").is_none());
        assert_eq!(router.active_total(), 0, "load gauge leaked");
    }

    // ---- wave 4: overload shedding — typed busy, nothing executed ----
    {
        let mut rcfg = RouterConfig::new(&dir);
        rcfg.shed_above = Some(0); // shed everything: backlog >= 0
        let router = Arc::new(Router::start(rcfg).expect("router"));
        let handle =
            server::serve(router.clone(), "127.0.0.1:0").expect("serve");
        let addr = handle.addr.to_string();
        let resp = server::client_roundtrip(&addr, &gen_req(800))
            .expect("busy reply");
        assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(false));
        assert_eq!(
            resp.get("busy").and_then(|b| b.as_bool()),
            Some(true),
            "shed reply not flagged busy: {}",
            resp.to_string_json()
        );
        assert_eq!(
            resp.get("retriable").and_then(|b| b.as_bool()),
            Some(true)
        );
        assert!(
            resp.get("retry_after_ms")
                .and_then(|v| v.as_usize())
                .unwrap_or(0)
                >= 50,
            "retry_after_ms hint missing: {}",
            resp.to_string_json()
        );
        let snap = server::client_roundtrip(&addr, r#"{"cmd": "metrics"}"#)
            .expect("metrics");
        assert!(
            snap.path(&["failures", "shed"])
                .and_then(|v| v.as_usize())
                .unwrap_or(0)
                > 0,
            "shed not counted: {}",
            snap.to_string_json()
        );
        let _ = handle;
    }
    std::fs::remove_dir_all(&tmp).ok();
}

/// Chaos on the batched path (DESIGN.md §13): a mid-decode step fault
/// drains the whole batch; the supervisor requeues the innocent lanes
/// and, after the session rebuild, they decode to exactly the tokens a
/// fault-free run produces (T=0) — requeue preserves determinism. Lanes
/// that exhaust the requeue budget get a typed retriable error instead.
#[test]
fn chaos_batched_requeue_token_identity() {
    use mars::coordinator::router::{Router, RouterConfig};
    use mars::coordinator::server;
    use mars::fault::FaultSpec;
    use std::io::{BufRead, BufReader, Write};
    use std::sync::Arc;
    let Some(dir) = artifacts_dir() else { return };
    {
        let a = Artifacts::load(&dir).expect("artifacts load");
        if !a.executable_names().iter().any(|n| n == "batch_join") {
            eprintln!("[skip] artifacts predate batched decoding");
            return;
        }
    }
    let gen_req = |id: usize| {
        format!(
            "{{\"id\": {id}, \"prompt\": \"Q: 21+17=?\\nA: \", \"method\": \
             \"eagle_tree\", \"policy\": \"mars:0.9\", \"max_new\": 16, \
             \"seed\": 5, \"cache\": false}}\n"
        )
    };

    // fault-free reference output for the T=0 request
    let reference = {
        let mut rcfg = RouterConfig::new(&dir);
        rcfg.slots = 4;
        rcfg.batch = 4;
        let router = Arc::new(Router::start(rcfg).expect("router"));
        let handle =
            server::serve(router.clone(), "127.0.0.1:0").expect("serve");
        let resp = server::client_roundtrip(
            &handle.addr.to_string(),
            gen_req(900).trim(),
        )
        .expect("reference");
        assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true));
        resp.get("text").and_then(|t| t.as_str()).unwrap().to_string()
    };

    // same requests under injected step faults: every lane terminates,
    // and every lane that terminates ok is token-identical to reference
    let mut rcfg = RouterConfig::new(&dir);
    rcfg.slots = 4;
    rcfg.batch = 4;
    rcfg.fault =
        Some(FaultSpec::parse("dispatch=0.15,seed=23").expect("spec"));
    let router = Arc::new(Router::start(rcfg).expect("router"));
    let handle = server::serve(router.clone(), "127.0.0.1:0").expect("serve");
    let addr = handle.addr.to_string();
    let mut sock = std::net::TcpStream::connect(&addr).expect("connect");
    let batch: String = (901..909).map(gen_req).collect();
    sock.write_all(batch.as_bytes()).expect("write batch");
    let mut reader = BufReader::new(sock);
    let (mut ok, mut retriable, mut hard) = (0usize, 0usize, 0usize);
    for _ in 0..8 {
        let mut line = String::new();
        reader.read_line(&mut line).expect("read reply");
        let v = mars::util::json::Value::parse(&line).expect("json");
        if v.get("ok").and_then(|b| b.as_bool()) == Some(true) {
            ok += 1;
            assert_eq!(
                v.get("text").and_then(|t| t.as_str()),
                Some(reference.as_str()),
                "a requeued lane diverged from the fault-free output"
            );
        } else if v.get("retriable").and_then(|b| b.as_bool()) == Some(true)
        {
            retriable += 1;
        } else {
            hard += 1;
        }
    }
    assert_eq!(ok + retriable + hard, 8, "a lane never terminated");
    assert!(ok > 0, "every lane died under rate-0.15 faults");
    // the supervisor left the gauges consistent after the drain/requeue
    assert_eq!(router.active_total(), 0, "load gauge leaked");
    assert_eq!(router.queued_total(), 0, "queued gauge leaked");
    let snap = server::client_roundtrip(&addr, r#"{"cmd": "metrics"}"#)
        .expect("metrics");
    if ok < 8 || retriable > 0 {
        assert!(
            snap.get("failures").is_some(),
            "faulted wave exported no failure counters: {}",
            snap.to_string_json()
        );
    }
}
