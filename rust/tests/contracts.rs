//! Contract-checker integration tests: run `mars check contracts`
//! in-process against the *committed* tree — the fixture manifest
//! (`tests/fixtures/contracts.json`, freshness-pinned by the python
//! suite) against the real rust sources and BENCHMARKS.md — plus
//! manifest-driven property tests of the cfg-slot codec. No artifacts
//! and no python toolchain needed, so plain `cargo test` gates all of
//! it.

use std::path::{Path, PathBuf};

use mars::check::{run_all, ContractManifest, Sources};
use mars::runtime::state::Layout;
use mars::spec::METHODS;
use mars::util::json::Value;
use mars::verify::VerifyPolicy;

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_manifest() -> ContractManifest {
    ContractManifest::load(
        &crate_root().join("tests/fixtures/contracts.json"),
    )
    .expect("fixture manifest parses")
}

fn real_sources() -> Sources {
    Sources::load(&crate_root().join("src")).expect("sources load")
}

/// The committed tree must be drift-free: this is the same check the CI
/// `check` job runs via the CLI (there against a freshly exported
/// manifest; the python suite pins the fixture to that export).
#[test]
fn committed_tree_has_no_contract_drift() {
    let m = fixture_manifest();
    let s = real_sources();
    let bench = std::fs::read_to_string(crate_root().join("../BENCHMARKS.md"))
        .expect("BENCHMARKS.md readable");
    let report = run_all(&m, &s, Some(&bench));
    assert!(report.ok(), "contract drift:\n{}", report.render());
}

/// The manifest embeds the full layout document — the same shape the
/// runtime loads from `state_layout.json` — so `Layout::from_json`
/// must accept it verbatim.
#[test]
fn manifest_layout_doc_builds_a_runtime_layout() {
    let m = fixture_manifest();
    let lay = Layout::from_json(&m.layout_doc).expect("layout builds");
    assert_eq!(lay.konst("n_cfg"), m.consts["n_cfg"]);
    assert_eq!(lay.hash.len(), 16);
    for (name, &idx) in &m.scalars {
        assert_eq!(lay.scalars[name], idx, "scalar {name}");
    }
}

/// Property: for every registered method family × verification policy,
/// the host cfg encoding round-trips through the manifest's slot
/// indices — the policy triple decodes back to the same policy, the
/// method knobs land in the method slots, and bounds hold.
#[test]
fn cfg_encoding_round_trips_every_method_x_policy() {
    let m = fixture_manifest();
    let lay = Layout::from_json(&m.layout_doc).expect("layout builds");
    let policies = VerifyPolicy::parse_list(
        "strict,mars:0.9,mars:0.5,topk:2:0.1,entropy:1.5",
    )
    .expect("policy list parses");
    let prompt_len = 11usize;
    for info in METHODS {
        for &policy in &policies {
            let policy = policy.normalize_for_device();
            let params = mars::engine::GenParams {
                method: info.default,
                policy,
                seed: 42,
                rounds_per_call: 3,
                ..Default::default()
            };
            let cfg =
                mars::runtime::encode_cfg(&lay, prompt_len, &params);
            assert_eq!(cfg.len(), m.consts["n_cfg"], "{}", info.name);
            let at = |slot: &str| cfg[m.cfg[slot]];
            // policy triple decodes back to the same policy
            let decoded = VerifyPolicy::decode_slots([
                at("policy_id"),
                at("p0"),
                at("p1"),
            ])
            .unwrap_or_else(|e| {
                panic!("{}: policy decode failed: {e}", info.name)
            });
            assert_eq!(decoded, policy, "{}", info.name);
            // the device policy id is one of the manifest's ids
            assert!(
                m.policies.values().any(|&v| v == at("policy_id") as f64),
                "{}: policy_id {} not in manifest",
                info.name,
                at("policy_id")
            );
            // method knobs land in the method slots
            let [kdraft, beam, branch] = info.default.encode_slots();
            assert_eq!(at("kdraft"), kdraft, "{}", info.name);
            assert_eq!(at("beam"), beam, "{}", info.name);
            assert_eq!(at("branch"), branch, "{}", info.name);
            // request plumbing
            assert_eq!(at("prompt_len"), prompt_len as f32);
            assert_eq!(at("rounds_per_call"), 3.0);
            assert_eq!(at("seed"), 42.0);
        }
    }
}

/// Drift injected into a *copy* of the committed manifest must be
/// caught, with the offending key named — one perturbation per
/// hand-mirrored surface (the in-crate unit tests cover the same on
/// synthetic fixtures; this exercises the real sources end to end).
#[test]
fn injected_manifest_drift_fails_the_checker_naming_the_key() {
    let text = std::fs::read_to_string(
        crate_root().join("tests/fixtures/contracts.json"),
    )
    .expect("fixture readable");
    let s = real_sources();
    let bench = std::fs::read_to_string(crate_root().join("../BENCHMARKS.md"))
        .expect("BENCHMARKS.md readable");
    let perturbed = |from: &str, to: &str| -> ContractManifest {
        assert!(text.contains(from), "fixture lacks {from}");
        ContractManifest::parse(&text.replace(from, to))
            .expect("perturbed manifest still parses")
    };
    struct Case {
        label: &'static str,
        from: &'static str,
        to: &'static str,
        surface: &'static str,
        key: &'static str,
    }
    let cases = [
        // scalar slot renamed out from under REQUIRED_SCALARS
        Case {
            label: "scalar slot",
            from: "\"pos\":",
            to: "\"pos_renamed\":",
            surface: "state-scalars",
            key: "pos",
        },
        // policy id renumbered on the python side only
        Case {
            label: "policy id",
            from: "\"mars\": 1.0",
            to: "\"mars\": 5.0",
            surface: "policy-ids",
            key: "mars",
        },
        // executable renamed in the registry
        Case {
            label: "exec name",
            from: "\"sps_round\":",
            to: "\"sps_round_v2\":",
            surface: "exec-names",
            key: "sps_round",
        },
        // layout const dropped (the engine's pack clamp reads it)
        Case {
            label: "layout const",
            from: "\"pack_max\":",
            to: "\"pack_max_gone\":",
            surface: "layout-consts",
            key: "pack_max",
        },
    ];
    for case in cases {
        let m = perturbed(case.from, case.to);
        let report = run_all(&m, &s, Some(&bench));
        assert!(
            !report.ok(),
            "{}: checker passed on perturbed manifest",
            case.label
        );
        assert!(
            report
                .drifts
                .iter()
                .any(|d| d.surface == case.surface && d.key == case.key),
            "{}: no [{}] drift naming '{}' — got:\n{}",
            case.label,
            case.surface,
            case.key,
            report.render()
        );
    }
}

/// Wire-field drift: a field added to the codec but not the protocol
/// doc must be caught. Perturbs the *source* side (a fixture request
/// codec with one extra field) against the real server doc.
#[test]
fn undocumented_wire_field_fails_the_checker_naming_the_field() {
    let m = fixture_manifest();
    let mut s = real_sources();
    s.request
        .push_str("\nfn probe(v: &Value) { let _ = v.get(\"turbo_mode\"); }\n");
    let bench = std::fs::read_to_string(crate_root().join("../BENCHMARKS.md"))
        .expect("BENCHMARKS.md readable");
    let report = run_all(&m, &s, Some(&bench));
    assert!(
        report
            .drifts
            .iter()
            .any(|d| d.surface == "wire-fields" && d.key == "turbo_mode"),
        "no wire-field drift naming 'turbo_mode':\n{}",
        report.render()
    );
}

/// Threshold-table drift: BENCHMARKS.md without the canonical table
/// must fail the bench-thresholds surface.
#[test]
fn stale_threshold_table_fails_the_checker() {
    let m = fixture_manifest();
    let s = real_sources();
    let report = run_all(&m, &s, Some("# BENCHMARKS\n\nno table\n"));
    assert!(report
        .drifts
        .iter()
        .any(|d| d.surface == "bench-thresholds"));
}

/// The fixture manifest's embedded layout hash must match the committed
/// artifact layout when one is present (same python export lineage).
#[test]
fn fixture_layout_hash_matches_committed_artifacts() {
    let m = fixture_manifest();
    let lay_path = Path::new("artifacts/state_layout.json");
    let committed = crate_root().join("..").join(lay_path);
    let path = if committed.is_file() {
        committed
    } else {
        eprintln!("[skip] no committed artifacts/state_layout.json");
        return;
    };
    let doc = Value::parse(
        &std::fs::read_to_string(path).expect("layout readable"),
    )
    .expect("layout parses");
    let hash = doc.get("hash").and_then(|h| h.as_str()).unwrap_or("");
    let embedded = m
        .layout_doc
        .get("hash")
        .and_then(|h| h.as_str())
        .unwrap_or("");
    assert_eq!(hash, embedded, "manifest layout lineage != artifacts");
}
