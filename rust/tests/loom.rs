#![cfg(feature = "loom")]
//! Loom model of the router ↔ batched-replica admission hand-off
//! (`cargo test --features loom --test loom --release`; the nightly CI
//! job runs it, see .github/workflows/nightly.yml).
//!
//! What is modeled — the exact atomics protocol of
//! `coordinator/router.rs` / `coordinator/replica.rs`, with the device
//! work abstracted away:
//!
//! * the router increments `queued_hint` *before* publishing the item
//!   (submit), and decrements it on a failed send;
//! * the replica moves items into lanes per [`plan_admissions`] and
//!   decrements `queued_hint` only at the admission ack — after the
//!   item landed in a lane or errored out;
//! * a dispatch failure fails every live lane and, when the batch
//!   session cannot be rebuilt, drains the still-queued items with one
//!   decrement each (the queue-gauge repair path) before the replica
//!   dies.
//!
//! Checked invariants, across every interleaving loom explores:
//!
//! * **no lost or double decrement** — `queued_hint` is exactly zero
//!   once all submitted items are acked or drained (an underflowing
//!   `fetch_sub` on the `usize` gauge would wrap and make the replica
//!   look infinitely loaded to least-loaded routing, starving it);
//! * **no lost item** — every submitted item is either admitted once or
//!   error-replied once, never both, never neither (a lost wakeup);
//! * **gauge never wraps mid-flight** — the hint stays below the wrap
//!   region at every decrement.
//!
//! A kill/restart model layers the supervision protocol of
//! `coordinator/replica.rs` on top: a dispatch fault kills the batch,
//! the victim lane is error-replied, innocent lanes are *requeued*
//! (gauge up **before** re-publish, exactly like `submit`) and the
//! restarted replica re-admits them — the same conservation and
//! no-double-decrement invariants must hold across the kill/restart
//! boundary.
//!
//! A fourth model covers the sharded metrics registry
//! (`coordinator/metrics.rs`, DESIGN.md §12): racing per-replica
//! recorders vs merge-on-snapshot vs the `reset` RPC, with the real
//! lock order (global stamp first, then shards in index order) — every
//! record must land in exactly one of {wiped-by-reset, final merge}.

use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use loom::sync::{Arc, Mutex};
use loom::thread;

use mars::coordinator::replica::plan_admissions;

/// One modeled request: its batched-program family, and its outcome
/// cell (None = unanswered, Some(true) = admitted, Some(false) =
/// error-replied). The cell stands in for the reply channel.
struct Item {
    family: &'static str,
    outcome: Mutex<Option<bool>>,
}

/// Shared router/replica state: the work queue models the mpsc channel
/// (loom has no channels; a mutexed deque has the same happens-before
/// edges via its lock), the gauges are the real protocol's atomics.
struct Shared {
    queue: Mutex<Vec<usize>>,
    queued_hint: AtomicUsize,
    active: AtomicUsize,
    items: Vec<Item>,
}

fn submit(s: &Shared, idx: usize) {
    // router: hint up *before* publish — the replica may ack (and
    // decrement) the instant the item is visible
    s.queued_hint.fetch_add(1, Ordering::Relaxed);
    s.queue.lock().unwrap().push(idx);
}

/// One admission pass of the batched loop: drain the queue, plan, ack.
/// `fail_dispatch` models a step error on a non-empty batch: every lane
/// fails, the session rebuild fails, and the drain path repairs the
/// queue gauge before the replica exits.
fn replica_pass(s: &Shared, slots: usize, fail_dispatch: bool) {
    let mut pending: Vec<usize> = s.queue.lock().unwrap().drain(..).collect();
    let mut occupancy = 0usize;
    let mut admitted: Vec<usize> = Vec::new();
    while !pending.is_empty() {
        let families: Vec<&str> =
            pending.iter().map(|&i| s.items[i].family).collect();
        let running = admitted.first().map(|&i| s.items[i].family);
        let plan = plan_admissions(occupancy, slots, running, &families);
        if plan.is_empty() {
            break;
        }
        let mut taken = 0usize;
        for &idx in &plan {
            let item_idx = pending.remove(idx - taken);
            taken += 1;
            // admission ack: outcome lands, then the hint drops —
            // exactly one decrement per submitted item
            *s.items[item_idx].outcome.lock().unwrap() = Some(true);
            admitted.push(item_idx);
            occupancy += 1;
            let before = s.queued_hint.fetch_sub(1, Ordering::Relaxed);
            assert!(before > 0, "queued_hint underflow at admission ack");
            s.active.store(occupancy, Ordering::Relaxed);
        }
    }
    if fail_dispatch && !admitted.is_empty() {
        // step error: every live lane is failed (their hints already
        // dropped at admission), and the queue-gauge repair drains the
        // family-mismatched leftovers with one decrement each
        for &i in &admitted {
            *s.items[i].outcome.lock().unwrap() = Some(false);
        }
        for item_idx in pending.drain(..) {
            *s.items[item_idx].outcome.lock().unwrap() = Some(false);
            let before = s.queued_hint.fetch_sub(1, Ordering::Relaxed);
            assert!(before > 0, "queued_hint underflow in gauge repair");
        }
        s.active.store(0, Ordering::Relaxed);
    }
}

fn check_final(s: &Shared, submitted: usize) {
    // drain whatever a pass has not consumed yet (a real replica loops)
    let leftover = s.queue.lock().unwrap().len();
    let hint = s.queued_hint.load(Ordering::Relaxed);
    assert!(
        hint < usize::MAX / 2,
        "queued_hint wrapped: {hint} (double decrement)"
    );
    // conservation: unacked items are exactly the queued leftovers
    let answered = s
        .items
        .iter()
        .take(submitted)
        .filter(|it| it.outcome.lock().unwrap().is_some())
        .count();
    assert_eq!(
        hint, leftover,
        "gauge out of sync: hint {hint} vs {leftover} still queued"
    );
    assert_eq!(
        answered + leftover,
        submitted,
        "lost or duplicated item: {answered} answered, {leftover} queued"
    );
}

fn items(families: &[&'static str]) -> Vec<Item> {
    families
        .iter()
        .map(|f| Item { family: f, outcome: Mutex::new(None) })
        .collect()
}

/// Two racing submitters, one replica pass: the hint-before-publish
/// ordering must hold for every interleaving (submit racing ack).
#[test]
fn loom_admission_ack_never_double_decrements() {
    loom::model(|| {
        let s = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            queued_hint: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            items: items(&["sps_batch", "sps_batch"]),
        });
        let s1 = s.clone();
        let t1 = thread::spawn(move || submit(&s1, 0));
        let s2 = s.clone();
        let t2 = thread::spawn(move || submit(&s2, 1));
        let s3 = s.clone();
        let t3 = thread::spawn(move || replica_pass(&s3, 2, false));
        t1.join().unwrap();
        t2.join().unwrap();
        t3.join().unwrap();
        // a real replica loops; one final pass consumes what the racing
        // pass may have missed, then the books must balance
        replica_pass(&s, 2, false);
        check_final(&s, 2);
    });
}

/// Submission racing a failing dispatch: the batch-wide restart path
/// (fail lanes + drain queue with gauge repair) must neither lose an
/// ack nor decrement twice, whatever the interleaving.
#[test]
fn loom_step_error_restart_repairs_the_queue_gauge() {
    loom::model(|| {
        let s = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            queued_hint: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            // mixed families: the second item is skipped by the planner
            // (family mismatch) and must be caught by the repair drain
            items: items(&["sps_batch", "eagle_tree_batch"]),
        });
        let s1 = s.clone();
        let t1 = thread::spawn(move || submit(&s1, 0));
        let s2 = s.clone();
        let t2 = thread::spawn(move || submit(&s2, 1));
        let s3 = s.clone();
        let t3 = thread::spawn(move || replica_pass(&s3, 2, true));
        t1.join().unwrap();
        t2.join().unwrap();
        t3.join().unwrap();
        replica_pass(&s, 2, true);
        check_final(&s, 2);
    });
}

/// The dead-replica path: when the send fails (receiver gone), the
/// router undoes its own hint — racing that undo against a normal
/// submit+ack on the same gauge must stay balanced.
#[test]
fn loom_failed_send_undo_balances_the_gauge() {
    loom::model(|| {
        let s = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            queued_hint: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            items: items(&["sps_batch"]),
        });
        // normal submit+ack on one thread
        let s1 = s.clone();
        let t1 = thread::spawn(move || {
            submit(&s1, 0);
        });
        // failed-send undo on another: hint up, send fails, hint down
        let s2 = s.clone();
        let t2 = thread::spawn(move || {
            s2.queued_hint.fetch_add(1, Ordering::Relaxed);
            let before = s2.queued_hint.fetch_sub(1, Ordering::Relaxed);
            assert!(before > 0, "undo underflow");
        });
        t1.join().unwrap();
        t2.join().unwrap();
        replica_pass(&s, 1, false);
        check_final(&s, 1);
    });
}

/// One supervised kill: admit what the planner allows, then fail the
/// dispatch. The first admitted lane is the victim (its requeue budget
/// is exhausted → error reply); every other lane is innocent and goes
/// back to the queue — outcome cell cleared and `queued_hint` bumped
/// *before* the re-publish, the exact `ctl.queued.fetch_add(1)` /
/// `pending.push_front` pairing of the real supervisor. Items the
/// planner skipped keep their original hint and simply stay queued for
/// the restarted replica.
fn replica_kill_requeue(s: &Shared, slots: usize) {
    let mut pending: Vec<usize> = s.queue.lock().unwrap().drain(..).collect();
    let mut occupancy = 0usize;
    let mut admitted: Vec<usize> = Vec::new();
    while !pending.is_empty() {
        let families: Vec<&str> =
            pending.iter().map(|&i| s.items[i].family).collect();
        let running = admitted.first().map(|&i| s.items[i].family);
        let plan = plan_admissions(occupancy, slots, running, &families);
        if plan.is_empty() {
            break;
        }
        let mut taken = 0usize;
        for &idx in &plan {
            let item_idx = pending.remove(idx - taken);
            taken += 1;
            *s.items[item_idx].outcome.lock().unwrap() = Some(true);
            admitted.push(item_idx);
            occupancy += 1;
            let before = s.queued_hint.fetch_sub(1, Ordering::Relaxed);
            assert!(before > 0, "queued_hint underflow at admission ack");
            s.active.store(occupancy, Ordering::Relaxed);
        }
    }
    if let Some((&victim, innocent)) = admitted.split_first() {
        // the victim's budget is spent: terminal error reply
        *s.items[victim].outcome.lock().unwrap() = Some(false);
        // innocent lanes: revoke the ack and requeue, gauge-first
        for &i in innocent {
            *s.items[i].outcome.lock().unwrap() = None;
            s.queued_hint.fetch_add(1, Ordering::Relaxed);
            s.queue.lock().unwrap().push(i);
        }
    }
    // planner-skipped leftovers never lost their hint: back in queue
    let mut q = s.queue.lock().unwrap();
    for item_idx in pending.drain(..) {
        q.push(item_idx);
    }
    drop(q);
    s.active.store(0, Ordering::Relaxed);
}

/// Kill/restart interleaving (supervision protocol): submits race a
/// replica kill that requeues its innocent lanes; the restarted
/// replica then re-admits everything still queued. Whatever loom
/// interleaves, every item must end admitted or error-replied exactly
/// once and the queue gauge must balance — the requeue `fetch_add`
/// must pair with exactly one later admission `fetch_sub`.
#[test]
fn loom_kill_restart_requeues_innocent_lanes_and_balances() {
    loom::model(|| {
        let s = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            queued_hint: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            // same family: both racing items are admissible into one
            // batch, so the kill has an innocent batchmate to requeue
            items: items(&["sps_batch", "sps_batch", "sps_batch"]),
        });
        let s1 = s.clone();
        let t1 = thread::spawn(move || submit(&s1, 0));
        let s2 = s.clone();
        let t2 = thread::spawn(move || submit(&s2, 1));
        let s3 = s.clone();
        let t3 = thread::spawn(move || replica_kill_requeue(&s3, 2));
        t1.join().unwrap();
        t2.join().unwrap();
        t3.join().unwrap();
        // restart era: a fresh request arrives and the rebuilt replica
        // drains the queue (requeued innocents + whatever the kill
        // pass never saw) without further faults
        submit(&s, 2);
        // the rebuilt replica is given enough slots to drain the whole
        // backlog in one pass (the model's pass, unlike the real loop,
        // does not iterate once occupancy hits the slot cap)
        replica_pass(&s, 3, false);
        check_final(&s, 3);
    });
}

// ------------------------------------------- sharded metrics registry -----

/// Minimal model of `MetricsRegistry`: per-replica shards behind their
/// own mutexes, a global mutex holding the elapsed stamp, and the
/// `started_stamped` fast-path atomic. Lock order mirrors the real
/// code: `record` touches global (stamp) then its shard; `reset` locks
/// global, drops it, then sweeps the shards in index order; `merged`
/// locks shards in index order only.
struct ShardedReg {
    shards: [Mutex<u64>; 2],
    /// `Some(_)` models the armed `started` stamp.
    global: Mutex<Option<u64>>,
    stamped: AtomicBool,
}

impl ShardedReg {
    fn new() -> Self {
        ShardedReg {
            shards: [Mutex::new(0), Mutex::new(0)],
            global: Mutex::new(None),
            stamped: AtomicBool::new(false),
        }
    }

    fn record(&self, replica: usize) {
        // stamp fast path: only the first recorder after a reset takes
        // the global lock (same shape as `stamp_started`)
        if !self.stamped.swap(true, Ordering::Relaxed) {
            *self.global.lock().unwrap() = Some(1);
        }
        *self.shards[replica % 2].lock().unwrap() += 1;
    }

    fn merged(&self) -> u64 {
        self.shards.iter().map(|s| *s.lock().unwrap()).sum()
    }

    /// Zero everything; returns the counts wiped (the real reset drops
    /// them — the model keeps them to assert conservation).
    fn reset(&self) -> u64 {
        let mut g = self.global.lock().unwrap();
        *g = None;
        self.stamped.store(false, Ordering::Relaxed);
        drop(g);
        let mut wiped = 0u64;
        for s in &self.shards {
            let mut c = s.lock().unwrap();
            wiped += *c;
            *c = 0;
        }
        wiped
    }
}

/// Racing recorders on distinct shards vs a snapshot-merge vs a reset:
/// no deadlock under the real lock order, snapshots never over-count,
/// and every record lands in exactly one of {wiped, final merge}.
#[test]
fn loom_sharded_metrics_merge_conserves_counts() {
    loom::model(|| {
        let r = Arc::new(ShardedReg::new());
        let r1 = r.clone();
        let t1 = thread::spawn(move || r1.record(0));
        let r2 = r.clone();
        let t2 = thread::spawn(move || r2.record(1));
        let r3 = r.clone();
        let t3 = thread::spawn(move || {
            // a mid-flight scrape must see a prefix of the truth
            let seen = r3.merged();
            assert!(seen <= 2, "snapshot over-counted: {seen}");
            r3.reset()
        });
        t1.join().unwrap();
        t2.join().unwrap();
        let wiped = t3.join().unwrap();
        let rest = r.merged();
        assert_eq!(
            wiped + rest,
            2,
            "records lost or double-counted: wiped {wiped}, merged {rest}"
        );
        // a record whose stamp fast-path raced the reset may land its
        // count after the sweep with the stamp momentarily disarmed —
        // benign (the next record re-arms it). The invariant that must
        // hold: an armed stamp always has a populated global cell,
        // because every false→true swap is followed by a locked store
        // and any later reset would have disarmed the stamp again.
        if r.stamped.load(Ordering::Relaxed) {
            assert!(
                r.global.lock().unwrap().is_some(),
                "stamp armed but the global started cell is empty"
            );
        }
    });
}
