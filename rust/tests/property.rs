//! Hand-rolled property tests (proptest is not in the offline registry):
//! seeded randomized checks of the verification rule, the host drafters,
//! the eval metrics and the coordinator invariants. 200+ random cases per
//! property, deterministic by seed.

use mars::datasets::{dataset, Task};
use mars::eval;
use mars::spec::{
    HostDrafter, LookaheadDrafter, PldDrafter, SpecMethod, METHODS,
};
use mars::util::json::Value;
use mars::util::prng::Rng;
use mars::verify::{AcceptFlag, VerifyPolicy};

/// The pre-refactor inline MARS accept rule (paper Algorithm 1 + the
/// positive-domain guard), kept verbatim as the oracle that pins the
/// `VerifyPolicy` reference verifier to the old `mars: bool` semantics.
fn legacy_mars_accept(
    z1: f32,
    z2: f32,
    v1: u32,
    v2: u32,
    draft: u32,
    theta: f32,
    mars_on: bool,
) -> u8 {
    if draft == v1 {
        return 1; // exact
    }
    if mars_on && draft == v2 && z1 > 0.0 && z2 > 0.0 && z2 / z1 > theta {
        return 2; // relaxed
    }
    0
}

fn mars_accept(
    z1: f32,
    z2: f32,
    v1: u32,
    v2: u32,
    draft: u32,
    theta: f32,
    mars_on: bool,
) -> u8 {
    let policy = if mars_on {
        VerifyPolicy::Mars { theta }
    } else {
        VerifyPolicy::Strict
    };
    // tstar == v1 here: the greedy case, where the target's pick is top-1
    policy.accept(draft, v1, &[(v1, z1), (v2, z2)]) as u8
}

fn random_policy(rng: &mut Rng) -> VerifyPolicy {
    match rng.below(4) {
        0 => VerifyPolicy::Strict,
        1 => VerifyPolicy::Mars {
            theta: ((rng.f64() * 1000.0).round() / 1000.0) as f32,
        },
        2 => VerifyPolicy::TopK {
            k: 1 + rng.usize_below(6),
            eps: ((rng.f64() * 100.0).round() / 100.0) as f32,
        },
        _ => VerifyPolicy::Entropy {
            h_max: ((rng.f64() * 4000.0).round() / 1000.0) as f32,
        },
    }
}

fn random_method(rng: &mut Rng) -> SpecMethod {
    match rng.below(7) {
        0 => SpecMethod::Ar,
        1 => SpecMethod::Sps { k: 1 + rng.usize_below(16) },
        2 => SpecMethod::EagleChain { depth: 1 + rng.usize_below(10) },
        3 => SpecMethod::EagleTree {
            depth: 1 + rng.usize_below(10),
            beam: 1 + rng.usize_below(4),
            branch: 1 + rng.usize_below(4),
        },
        4 => SpecMethod::Medusa { depth: 1 + rng.usize_below(4) },
        5 => {
            let min_ngram = 1 + rng.usize_below(4);
            SpecMethod::Pld {
                min_ngram,
                max_ngram: min_ngram + rng.usize_below(4),
                k: 1 + rng.usize_below(16),
            }
        }
        _ => SpecMethod::Lookahead {
            n: 1 + rng.usize_below(5),
            g: 1 + rng.usize_below(10),
            cap: 1 + rng.usize_below(8192),
            k: 1 + rng.usize_below(16),
        },
    }
}

#[test]
fn prop_method_cli_label_round_trips() {
    let mut rng = Rng::new(300);
    for _ in 0..500 {
        let m = random_method(&mut rng);
        let label = m.label();
        assert_eq!(
            SpecMethod::parse(&label),
            Some(m),
            "label {label:?} did not round-trip"
        );
    }
}

#[test]
fn prop_method_json_round_trips() {
    let mut rng = Rng::new(301);
    for _ in 0..500 {
        let m = random_method(&mut rng);
        let text = m.to_json().to_string_json();
        let back = Value::parse(&text).expect("method json parses");
        assert_eq!(
            SpecMethod::from_json(&back),
            Ok(m),
            "json {text} did not round-trip"
        );
    }
}

#[test]
fn prop_method_cli_json_name_agree() {
    // CLI string ↔ JSON object ↔ canonical name: the three surfaces of
    // one descriptor always agree
    let mut rng = Rng::new(302);
    for _ in 0..300 {
        let m = random_method(&mut rng);
        let via_cli = SpecMethod::parse(&m.label()).unwrap();
        let json = Value::parse(&m.to_json().to_string_json()).unwrap();
        let via_json = SpecMethod::from_json(&json).unwrap();
        assert_eq!(via_cli, via_json);
        assert_eq!(via_cli.name(), m.name());
        assert_eq!(m.info().name, m.name());
    }
}

#[test]
fn prop_legacy_method_strings_and_flat_knobs_pin() {
    // every legacy bare "method" string and --k/--beam/--branch flag
    // combination still parses, and the flat wire form equals the
    // structured descriptor form built from the same knobs
    let mut rng = Rng::new(303);
    let legacy_names = [
        "ar", "baseline", "vanilla", "sps", "spd", "eagle", "eagle_chain",
        "eagle_tree", "eagle3", "tree", "medusa", "pld", "lookahead", "la",
    ];
    for _ in 0..400 {
        let name = *rng.pick(&legacy_names);
        let with_k = rng.bool(0.5);
        let with_beam = rng.bool(0.5);
        let with_branch = rng.bool(0.5);
        let k = 1 + rng.usize_below(16);
        let beam = 1 + rng.usize_below(4);
        let branch = 1 + rng.usize_below(4);
        let mut o = Value::obj();
        o.set("method", Value::Str(name.into()));
        if with_k {
            o.set("k", Value::Num(k as f64));
        }
        if with_beam {
            o.set("beam", Value::Num(beam as f64));
        }
        if with_branch {
            o.set("branch", Value::Num(branch as f64));
        }
        let got = SpecMethod::from_request(&o)
            .unwrap_or_else(|e| panic!("{}: {e}", o.to_string_json()));
        // oracle: family default + the same overrides applied directly
        let base = SpecMethod::parse(name).expect(name);
        let want = base.with_overrides(
            with_k.then_some(k),
            with_beam.then_some(beam),
            with_branch.then_some(branch),
        );
        assert_eq!(got, want, "{}", o.to_string_json());
        // and the parsed descriptor's own JSON form round-trips to itself
        let structured =
            Value::parse(&got.to_json().to_string_json()).unwrap();
        assert_eq!(SpecMethod::from_json(&structured), Ok(got));
    }
}

#[test]
fn prop_registry_defaults_parse_from_every_alias() {
    for info in METHODS {
        for spelling in
            std::iter::once(&info.name).chain(info.aliases.iter())
        {
            assert_eq!(
                SpecMethod::parse(spelling),
                Some(info.default),
                "{spelling}"
            );
        }
    }
}

#[test]
fn prop_policy_cli_label_round_trips() {
    let mut rng = Rng::new(200);
    for _ in 0..500 {
        let p = random_policy(&mut rng);
        let label = p.label();
        assert_eq!(
            VerifyPolicy::parse(&label),
            Some(p),
            "label {label:?} did not round-trip"
        );
    }
}

#[test]
fn prop_policy_json_round_trips() {
    let mut rng = Rng::new(201);
    for _ in 0..500 {
        let p = random_policy(&mut rng);
        let text = p.to_json().to_string_json();
        let back = Value::parse(&text).expect("policy json parses");
        assert_eq!(
            VerifyPolicy::from_json(&back),
            Ok(p),
            "json {text} did not round-trip"
        );
    }
}

#[test]
fn prop_policy_slots_round_trip() {
    let mut rng = Rng::new(202);
    for _ in 0..500 {
        let p = random_policy(&mut rng);
        assert_eq!(VerifyPolicy::decode_slots(p.encode_slots()), Ok(p));
    }
}

#[test]
fn prop_request_json_round_trips_wire_fields() {
    // the full request wire surface (id, stream, policy, method, sampling
    // knobs) survives a JSON encode → parse_request_json round trip; the
    // method is carried either as its CLI label or its structured object
    use mars::coordinator::request::parse_request_json;
    let mut rng = Rng::new(207);
    for _ in 0..400 {
        let id = rng.below(1_000_000);
        let stream = rng.bool(0.5);
        let policy = random_policy(&mut rng);
        let method = random_method(&mut rng);
        let max_new = 1 + rng.usize_below(256);
        let seed = rng.below(1u64 << 40);
        let mut o = Value::obj();
        o.set("id", Value::Num(id as f64));
        o.set("prompt", Value::Str("Q: 1+1=?\nA: ".into()));
        if stream {
            o.set("stream", Value::Bool(true));
        }
        o.set("policy", Value::Str(policy.label()));
        if rng.bool(0.5) {
            o.set("method", Value::Str(method.label()));
        } else {
            o.set("method", method.to_json());
        }
        o.set("max_new", Value::Num(max_new as f64));
        o.set("seed", Value::Num(seed as f64));
        let text = o.to_string_json();
        let back = Value::parse(&text).expect("request json parses");
        let req = parse_request_json(0, &back)
            .unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(req.id, id, "{text}");
        assert_eq!(req.stream, stream, "{text}");
        assert_eq!(
            req.params.policy,
            policy.normalize_for_device(),
            "{text}"
        );
        assert_eq!(req.params.method, method, "{text}");
        assert_eq!(req.params.max_new, max_new, "{text}");
        assert_eq!(req.params.seed, seed, "{text}");
    }
}

#[test]
fn prop_legacy_request_keys_equal_policy_forms() {
    // every legacy {mars, theta} pair parses to the policy whose own JSON
    // round-trips to itself
    let mut rng = Rng::new(203);
    for _ in 0..300 {
        let mars_on = rng.bool(0.5);
        let theta = ((rng.f64() * 1000.0).round() / 1000.0) as f32;
        let legacy = Value::parse(&format!(
            "{{\"mars\": {mars_on}, \"theta\": {theta}}}"
        ))
        .expect("legacy json");
        let p = VerifyPolicy::from_request(&legacy).expect("legacy parse");
        let want = if mars_on {
            VerifyPolicy::Mars { theta }
        } else {
            VerifyPolicy::Strict
        };
        assert_eq!(p, want);
        let structured = p.to_json().to_string_json();
        let back = Value::parse(&structured).unwrap();
        assert_eq!(VerifyPolicy::from_json(&back), Ok(p));
    }
}

#[test]
fn prop_strict_policy_matches_legacy_mars_off() {
    // bit-identity of the rule: the Strict policy decides exactly like the
    // pre-refactor path with mars == false, over random inputs
    let mut rng = Rng::new(204);
    for _ in 0..2000 {
        let z1 = (rng.f64() * 20.0 - 4.0) as f32;
        let z2 = z1 - (rng.f64() * 3.0) as f32;
        let v1 = rng.below(128) as u32;
        let v2 = rng.below(128) as u32;
        let other = rng.below(128) as u32;
        let draft = *rng.pick(&[v1, v2, other]);
        let theta = rng.f64() as f32;
        let legacy = legacy_mars_accept(z1, z2, v1, v2, draft, theta, false);
        let got = VerifyPolicy::Strict.accept(draft, v1, &[(v1, z1), (v2, z2)]);
        assert_eq!(got as u8, legacy);
        // and Mars{theta} decides exactly like mars == true
        let legacy_on = legacy_mars_accept(z1, z2, v1, v2, draft, theta, true);
        let got_on = VerifyPolicy::Mars { theta }
            .accept(draft, v1, &[(v1, z1), (v2, z2)]);
        assert_eq!(got_on as u8, legacy_on, "z1={z1} z2={z2} theta={theta}");
    }
}

#[test]
fn prop_topk2_equals_mars_complement() {
    // TopK{2, eps} is definitionally Mars{1 - eps}
    let mut rng = Rng::new(205);
    for _ in 0..2000 {
        let z1 = (rng.f64() * 20.0 - 4.0) as f32;
        let z2 = z1 - (rng.f64() * 3.0) as f32;
        let v1 = rng.below(64) as u32;
        let v2 = 64 + rng.below(64) as u32;
        let draft = *rng.pick(&[v1, v2, 200]);
        let eps = (rng.f64() * 0.5) as f32;
        let a = VerifyPolicy::TopK { k: 2, eps }
            .accept(draft, v1, &[(v1, z1), (v2, z2)]);
        let b = VerifyPolicy::Mars { theta: 1.0 - eps }
            .accept(draft, v1, &[(v1, z1), (v2, z2)]);
        assert_eq!(a, b, "z1={z1} z2={z2} eps={eps} draft={draft}");
    }
}

#[test]
fn prop_every_policy_accepts_exact_and_scan_is_prefix() {
    let mut rng = Rng::new(206);
    for _ in 0..300 {
        let p = random_policy(&mut rng);
        let n = 1 + rng.usize_below(12);
        let rows: Vec<(u32, Vec<(u32, f32)>)> = (0..n)
            .map(|_| {
                let z1 = (rng.f64() * 10.0 - 2.0) as f32;
                let v1 = rng.below(128) as u32;
                let v2 = 128 + rng.below(128) as u32;
                (v1, vec![(v1, z1), (v2, z1 - rng.f64() as f32)])
            })
            .collect();
        // exact drafts: every policy must accept the full chain
        let exact: Vec<u32> = rows.iter().map(|(t, _)| *t).collect();
        let (flags, m) = p.scan(&exact, &rows);
        assert_eq!(m, n, "{p:?} rejected an exact chain");
        assert!(flags.iter().all(|f| *f == AcceptFlag::Exact));
        // random drafts: accepted flags must form a prefix
        let drafts: Vec<u32> = rows
            .iter()
            .map(|(t, top)| *rng.pick(&[*t, top[1].0, 999]))
            .collect();
        let (flags, m) = p.scan(&drafts, &rows);
        assert!(m <= n);
        for (i, f) in flags.iter().enumerate() {
            assert_eq!(f.accepted(), i < m, "non-prefix accept in {p:?}");
        }
    }
}

#[test]
fn prop_mars_superset_of_strict() {
    // anything strict accepts, MARS accepts too (flag may upgrade only
    // from 0 to 2, never 1 to 0)
    let mut rng = Rng::new(101);
    for _ in 0..2000 {
        let z1 = (rng.f64() * 20.0 - 4.0) as f32;
        let z2 = z1 - (rng.f64() * 3.0) as f32;
        let v1 = rng.below(128) as u32;
        let v2 = rng.below(128) as u32;
        let other = rng.below(128) as u32;
        let draft = *rng.pick(&[v1, v2, other]);
        let theta = rng.f64() as f32;
        let strict = mars_accept(z1, z2, v1, v2, draft, theta, false);
        let relaxed = mars_accept(z1, z2, v1, v2, draft, theta, true);
        assert!(relaxed >= strict || (strict == 1 && relaxed == 1));
        if strict == 1 {
            assert_eq!(relaxed, 1);
        }
    }
}

#[test]
fn prop_mars_monotone_in_theta() {
    let mut rng = Rng::new(102);
    for _ in 0..2000 {
        let z1 = (rng.f64() * 10.0) as f32 + 0.1;
        let z2 = z1 * (rng.f64() as f32);
        let v2 = rng.below(128) as u32;
        let v1 = 127 - v2;
        let draft = v2;
        let lo = (rng.f64() * 0.5) as f32;
        let hi = lo + (rng.f64() * 0.5) as f32;
        let a_lo = mars_accept(z1, z2, v1, v2, draft, lo, true);
        let a_hi = mars_accept(z1, z2, v1, v2, draft, hi, true);
        // accepting at the higher threshold implies accepting at the lower
        if a_hi == 2 {
            assert_eq!(a_lo, 2, "z1={z1} z2={z2} lo={lo} hi={hi}");
        }
    }
}

#[test]
fn prop_mars_never_relaxes_nonpositive_logits() {
    let mut rng = Rng::new(103);
    for _ in 0..2000 {
        let z1 = -(rng.f64() as f32) * 5.0;
        let z2 = z1 - 0.01;
        let v2 = 2 + rng.below(126) as u32; // distinct from v1 = 1
        assert_eq!(
            mars_accept(z1, z2, 1, v2, v2, 0.0, true),
            0,
            "relaxed on negative logits"
        );
    }
}

#[test]
fn prop_pld_drafts_are_substrings_of_history() {
    let mut rng = Rng::new(104);
    for _ in 0..300 {
        let len = 10 + rng.usize_below(200);
        let vocab = 2 + rng.below(12) as u32; // small vocab => repeats
        let history: Vec<u32> =
            (0..len).map(|_| rng.below(vocab as u64) as u32).collect();
        let mut d = PldDrafter::new(2, 4);
        let k = 1 + rng.usize_below(8);
        let draft = d.draft(&history, k);
        assert!(draft.len() <= k);
        if !draft.is_empty() {
            // the draft must appear verbatim somewhere in the history
            let found = history
                .windows(draft.len())
                .any(|w| w == draft.as_slice());
            assert!(found, "draft {draft:?} not in history");
        }
    }
}

#[test]
fn prop_lookahead_drafts_come_from_pool_continuations() {
    let mut rng = Rng::new(105);
    for _ in 0..200 {
        let len = 20 + rng.usize_below(100);
        let history: Vec<u32> =
            (0..len).map(|_| rng.below(8) as u32).collect();
        let mut d = LookaheadDrafter::new(3, 6, 1024);
        d.observe(&history);
        let draft = d.draft(&history, 6);
        if !draft.is_empty() {
            let mut joined = history[history.len() - 3..].to_vec();
            joined.extend(&draft);
            let found = history
                .windows(joined.len().min(history.len()))
                .any(|w| w == &joined[..w.len()]);
            assert!(found, "pool continuation not grounded in history");
        }
    }
}

#[test]
fn prop_rouge_bounds_and_identity() {
    let mut rng = Rng::new(106);
    let words = ["aa", "bb", "cc", "dd", "ee"];
    for _ in 0..500 {
        let n = 1 + rng.usize_below(12);
        let a: Vec<&str> = (0..n).map(|_| *rng.pick(&words)).collect();
        let m = 1 + rng.usize_below(12);
        let b: Vec<&str> = (0..m).map(|_| *rng.pick(&words)).collect();
        let sa = a.join(" ");
        let sb = b.join(" ");
        let f = eval::rouge_l(&sa, &sb);
        assert!((0.0..=1.0).contains(&f));
        assert!((eval::rouge_l(&sa, &sa) - 1.0).abs() < 1e-12);
        // symmetry of F1
        assert!((f - eval::rouge_l(&sb, &sa)).abs() < 1e-12);
    }
}

#[test]
fn prop_chrf_bounds() {
    let mut rng = Rng::new(107);
    for _ in 0..300 {
        let n = 1 + rng.usize_below(30);
        let a: String = (0..n)
            .map(|_| (b'a' + rng.below(6) as u8) as char)
            .collect();
        let b: String = (0..n)
            .map(|_| (b'a' + rng.below(6) as u8) as char)
            .collect();
        let c = eval::chrf(&a, &b);
        assert!((0.0..=100.0 + 1e-9).contains(&c), "{c}");
        assert!((eval::chrf(&a, &a) - 100.0).abs() < 1e-9);
    }
}

#[test]
fn prop_bleu_perfect_geq_noisy() {
    let mut rng = Rng::new(108);
    let words = ["the", "cat", "sat", "on", "mat", "dog", "ran"];
    for _ in 0..200 {
        let n = 5 + rng.usize_below(10);
        let r: Vec<&str> = (0..n).map(|_| *rng.pick(&words)).collect();
        let reference = r.join(" ");
        // corrupt one word
        let mut c = r.clone();
        let i = rng.usize_below(c.len());
        c[i] = if c[i] == "the" { "dog" } else { "the" };
        let candidate = c.join(" ");
        let perfect =
            eval::corpus_bleu(&[(reference.clone(), reference.clone())]);
        let noisy = eval::corpus_bleu(&[(candidate, reference)]);
        assert!(perfect >= noisy - 1e-9);
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(109);
    fn gen(rng: &mut Rng, depth: usize) -> Value {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bool(0.5)),
            2 => Value::Num((rng.f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let n = rng.usize_below(12);
                Value::Str(
                    (0..n)
                        .map(|_| (0x20 + rng.below(95) as u8) as char)
                        .collect(),
                )
            }
            4 => Value::Arr(
                (0..rng.usize_below(4)).map(|_| gen(rng, depth + 1)).collect(),
            ),
            _ => {
                let mut o = Value::obj();
                for i in 0..rng.usize_below(4) {
                    o.set(&format!("k{i}"), gen(rng, depth + 1));
                }
                o
            }
        }
    }
    for _ in 0..500 {
        let v = gen(&mut rng, 0);
        let text = v.to_string_json();
        let back = Value::parse(&text).expect("roundtrip parse");
        assert_eq!(v, back, "{text}");
    }
}

#[test]
fn prop_datasets_stable_across_calls() {
    for task in Task::all() {
        for seed in [0u64, 1, 99] {
            let a = dataset(*task, 8, seed);
            let b = dataset(*task, 8, seed);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.prompt, y.prompt);
                assert_eq!(x.reference, y.reference);
            }
        }
    }
}

#[test]
fn prop_judge_reference_dominates_corruption() {
    let mut rng = Rng::new(110);
    for ex in dataset(Task::Chat, 30, 5) {
        let good = eval::judge_score(&ex, &ex.reference);
        // corrupt: drop keywords
        let corrupted: String = ex
            .reference
            .split_whitespace()
            .filter(|w| !ex.keywords.iter().any(|k| w.contains(k.as_str())))
            .collect::<Vec<_>>()
            .join(" ");
        let bad = eval::judge_score(&ex, &corrupted);
        assert!(good >= bad, "{good} < {bad} for {:?}", ex.reference);
        let _ = rng.next_u64();
    }
}

// ------------------------------------------------------- round packing -----

#[test]
fn prop_effective_pack_invariants() {
    // the adaptive pack controller (engine::effective_pack): always in
    // [1, configured∧cap], 1 before the first commit (TTFT guard), and
    // never larger than the remaining budget (every round commits >= 1
    // token, so a bigger pack is guaranteed overrun work)
    use mars::engine::effective_pack;
    let mut rng = Rng::new(645);
    for _ in 0..2000 {
        let configured = rng.usize_below(40);
        let cap = if rng.bool(0.3) { 1 } else { usize::MAX };
        let max_new = 1 + rng.usize_below(300);
        let committed = rng.usize_below(max_new + 50);
        let pack = effective_pack(configured, cap, committed, max_new);
        assert!(pack >= 1);
        assert!(pack <= configured.max(1));
        assert!(pack <= cap);
        if committed == 0 {
            assert_eq!(pack, 1, "first call must run a single round");
        } else if committed < max_new {
            assert!(
                pack <= max_new - committed,
                "pack {pack} overruns remaining {} (configured \
                 {configured})",
                max_new - committed
            );
        } else {
            assert_eq!(pack, 1, "past the budget only the minimum runs");
        }
        // monotone in progress: approaching the budget never grows the pack
        if committed >= 1 && committed + 1 <= max_new + 49 {
            let next = effective_pack(configured, cap, committed + 1, max_new);
            assert!(next <= pack.max(1));
        }
    }
}

// ------------------------------------------------- batched admission -------

#[test]
fn prop_plan_admissions_invariants() {
    // the continuous-batching admission planner (replica::plan_admissions,
    // DESIGN.md §9.5): over random occupancy / slot budgets / running
    // families / queues, the plan (a) never over-admits past the free
    // lanes, (b) admits exactly one method family per plan (matching the
    // running family when the batch is non-empty), (c) is FIFO within the
    // admitted family — it skips an index only for family mismatch — and
    // (d) never starves the queue head: an empty batch with a free slot
    // always admits index 0.
    use mars::coordinator::replica::plan_admissions;
    let families = ["sps_batch", "ar_batch", "medusa_batch", "eagle_tree_batch"];
    let mut rng = Rng::new(646);
    for case in 0..2000 {
        let slots = rng.usize_below(9); // 0..=8, includes degenerate 0
        let occupancy = rng.usize_below(slots + 2); // may exceed slots
        let running_family = if occupancy > 0 && rng.bool(0.8) {
            Some(*rng.pick(&families))
        } else {
            None
        };
        let queued: Vec<&str> = (0..rng.usize_below(12))
            .map(|_| *rng.pick(&families))
            .collect();
        let plan =
            plan_admissions(occupancy, slots, running_family, &queued);
        let free = slots.saturating_sub(occupancy);
        let ctx = format!(
            "case {case}: occ={occupancy} slots={slots} \
             running={running_family:?} queued={queued:?} plan={plan:?}"
        );

        // (a) lane budget: never admit more than the free slots
        assert!(plan.len() <= free, "over-admitted: {ctx}");
        // indices are valid, strictly ascending (FIFO order preserved)
        for w in plan.windows(2) {
            assert!(w[0] < w[1], "plan not ascending: {ctx}");
        }
        assert!(plan.iter().all(|&i| i < queued.len()), "{ctx}");

        // (b) one family per plan, pinned to the running family when the
        // batch already holds lanes of it
        let admitted_family = plan.first().map(|&i| queued[i]);
        if let Some(fam) = admitted_family {
            assert!(
                plan.iter().all(|&i| queued[i] == fam),
                "mixed families admitted: {ctx}"
            );
            if let Some(run) = running_family {
                assert_eq!(fam, run, "family switched mid-batch: {ctx}");
            }
        }

        // (c) FIFO within the family: every skipped earlier index must be
        // a family mismatch (greedy => no same-family arrival waits while
        // a later one boards)
        let target = admitted_family.or(running_family);
        if let Some(fam) = target {
            let matching: Vec<usize> = queued
                .iter()
                .enumerate()
                .filter(|(_, f)| **f == fam)
                .map(|(i, _)| i)
                .collect();
            let want: Vec<usize> =
                matching.into_iter().take(free).collect();
            assert_eq!(plan, want, "not FIFO within family: {ctx}");
        }

        // (d) head never starves: empty batch + free slot => index 0 boards
        if occupancy == 0 && free > 0 && !queued.is_empty() {
            assert_eq!(plan.first(), Some(&0), "head starved: {ctx}");
        }

        // planning is idempotent on the post-admission state: after the
        // plan boards, a re-plan over the remaining queue admits nothing
        // new unless lanes are still free
        if free > 0 && plan.len() == free {
            let remaining: Vec<&str> = queued
                .iter()
                .enumerate()
                .filter(|(i, _)| !plan.contains(i))
                .map(|(_, f)| *f)
                .collect();
            let replan = plan_admissions(
                occupancy + plan.len(),
                slots,
                admitted_family.or(running_family),
                &remaining,
            );
            assert!(replan.is_empty(), "re-plan over full batch: {ctx}");
        }
    }
}

// ------------------------------------------------------- prefix cache ------

#[test]
fn prop_chain_hash_incremental_matches_batch() {
    let mut rng = Rng::new(640);
    for _ in 0..200 {
        let n = rng.usize_below(40);
        let toks: Vec<u32> =
            (0..n).map(|_| rng.below(300) as u32).collect();
        let mut hasher = mars::cache::key::PrefixHasher::new();
        for l in 0..=n {
            assert_eq!(
                hasher.hash(),
                mars::cache::key::prefix_hash(&toks[..l]),
                "prefix {l} of {toks:?}"
            );
            if l < n {
                hasher.push(toks[l]);
            }
        }
    }
}

#[test]
fn prop_cache_lookup_returns_longest_true_prefix() {
    // tiny token alphabet on purpose: token-level prefix collisions are
    // the common case, so the longest-match logic actually gets exercised
    let mut rng = Rng::new(641);
    for case in 0..60 {
        let mut cache = mars::cache::PrefixCache::new(1 << 20);
        let mut stored: Vec<Vec<u32>> = Vec::new();
        for _ in 0..rng.usize_below(12) {
            let n = 1 + rng.usize_below(8);
            let toks: Vec<u32> =
                (0..n).map(|_| rng.below(3) as u32).collect();
            cache.insert(&toks, vec![toks.len() as f32; 4]);
            stored.push(toks);
        }
        for _ in 0..20 {
            let n = rng.usize_below(10);
            let query: Vec<u32> =
                (0..n).map(|_| rng.below(3) as u32).collect();
            let oracle = stored
                .iter()
                .filter(|s| query.starts_with(s))
                .map(|s| s.len())
                .max();
            let got = cache.lookup(&query, false);
            assert_eq!(
                got.as_ref().map(|(l, _)| *l),
                oracle,
                "case {case}: query {query:?} stored {stored:?}"
            );
            if let Some((l, state)) = got {
                // the snapshot handed back is the matched entry's own
                // (a shared Arc handle — zero-copy on the hot path)
                assert_eq!(&state[..], &vec![l as f32; 4][..]);
            }
        }
    }
}

#[test]
fn prop_cache_lru_never_exceeds_budget() {
    let mut rng = Rng::new(642);
    for _ in 0..40 {
        let budget = 256 + rng.usize_below(2048);
        let mut cache = mars::cache::PrefixCache::new(budget);
        for _ in 0..60 {
            match rng.below(3) {
                0 | 1 => {
                    let n = 1 + rng.usize_below(6);
                    let toks: Vec<u32> =
                        (0..n).map(|_| rng.below(50) as u32).collect();
                    let state = vec![0.5f32; rng.usize_below(120)];
                    cache.insert(&toks, state);
                }
                _ => {
                    let n = rng.usize_below(8);
                    let q: Vec<u32> =
                        (0..n).map(|_| rng.below(50) as u32).collect();
                    let _ = cache.lookup(&q, false);
                }
            }
            assert!(
                cache.bytes_resident() <= budget,
                "resident {} > budget {budget}",
                cache.bytes_resident()
            );
            let s = cache.stats();
            assert_eq!(s.bytes_resident, cache.bytes_resident() as u64);
            assert_eq!(s.entries, cache.entries() as u64);
        }
    }
}

#[test]
fn prop_restamp_resumed_roundtrips_layout_and_pos() {
    use mars::runtime::state::{
        restamp_resumed, Layout, RESUME_RESET_SCALARS,
    };
    let json = r#"{
      "state_len": 300, "extract_len": 72, "extract_probe_len": 112,
      "n_scalars": 64,
      "scalars": {"pos":0,"eagle_pos":1,"sps_pos":2,"out_len":3,
        "finished":4,"rng":5,"temp":6,"p0":7,"policy_id":8,"kdraft":9,
        "max_new":10,"eos":11,"beam":12,"branch":13,"probe_on":14,
        "probe_len":15,"rounds":16,"committed":17,"target_calls":18,
        "draft_steps":19,"exact_accepts":20,"relaxed_accepts":21,
        "rejects":22,"bonus":23,"prompt_len":24,"last_accept":25,
        "greedy":26,"seed":27,"p1":28},
      "cfg": {"temp":0,"p0":1,"policy_id":2,"kdraft":3,"max_new":4,
        "eos":5,"beam":6,"branch":7,"probe_on":8,"greedy":9,"seed":10,
        "prompt_len":11,"p1":12},
      "sections": {"out": {"offset":64, "size":8, "shape":[8]},
        "tkv": {"offset":72, "size":228, "shape":[228]}},
      "consts": {"probe_max":16, "probe_w":3, "n_cfg":16},
      "hash": "prop"
    }"#;
    let lay = Layout::from_json(&Value::parse(json).unwrap()).unwrap();
    let mut rng = Rng::new(643);
    for _ in 0..100 {
        let snapshot: Vec<f32> =
            (0..300).map(|_| rng.f64() as f32).collect();
        let cfg: Vec<f32> = (0..16).map(|_| rng.f64() as f32).collect();
        let mut state = snapshot.clone();
        restamp_resumed(&lay, &mut state, &cfg);
        // pos family survives bit-exactly
        for name in ["pos", "eagle_pos", "sps_pos"] {
            assert_eq!(state[lay.scalar(name)], snapshot[lay.scalar(name)]);
        }
        // every section survives bit-exactly (only scalars change)
        for sec in lay.sections.values() {
            assert_eq!(
                &state[sec.offset..sec.offset + sec.size],
                &snapshot[sec.offset..sec.offset + sec.size]
            );
        }
        // cfg values land on their named scalars
        for (name, &ci) in &lay.cfg {
            assert_eq!(state[lay.scalar(name)], cfg[ci], "{name}");
        }
        // per-request counters are zeroed
        for name in RESUME_RESET_SCALARS {
            assert_eq!(state[lay.scalar(name)], 0.0, "{name}");
        }
    }
}

/// Host-reference decode harness for the reuse-correctness pin: a
/// deterministic synthetic target (top-2 logits are a pure function of
/// the token history via the cache's own chain hash), drafted either as
/// a chain or as a 2-branch tree, verified by the *host reference
/// verifier* (`VerifyPolicy::scan`). Commits mirror Algorithm 1: the
/// accepted prefix plus the target's pick at the first reject (bonus =
/// the target pick after a fully accepted chain).
mod host_reference_decode {
    use super::*;

    const VOCAB: u32 = 24;

    /// Synthetic target: (tstar, top-2 rows) at the position after
    /// `history` — deterministic, so decode is a pure function of the
    /// token history and cached-prefix reuse must be output-invariant.
    pub fn target_row(history: &[u32]) -> (u32, Vec<(u32, f32)>) {
        let h = mars::cache::key::prefix_hash(history);
        let v1 = (h % VOCAB as u64) as u32;
        let mut v2 = ((h >> 17) % VOCAB as u64) as u32;
        if v2 == v1 {
            v2 = (v2 + 1) % VOCAB;
        }
        let z1 = 0.5 + ((h >> 32) % 64) as f32 / 16.0; // 0.5 .. 4.4
        let ratio = ((h >> 40) % 100) as f32 / 100.0; // 0 .. 0.99
        (v1, vec![(v1, z1), (v2, z1 * ratio)])
    }

    /// Chain drafter: k tokens, teacher-forced on its own continuations,
    /// drawn from the target family but salted — near-miss drafts that
    /// exercise exact, relaxed and reject paths.
    pub fn draft_chain(history: &[u32], k: usize, salt: u64) -> Vec<u32> {
        let mut ctx = history.to_vec();
        let mut out = Vec::new();
        for _ in 0..k {
            let h = mars::cache::key::prefix_hash(&ctx) ^ salt;
            let (v1, _) = target_row(&ctx);
            // mostly the target's own pick, sometimes a salted miss
            let tok = if h % 4 == 0 {
                (h % VOCAB as u64) as u32
            } else {
                v1
            };
            out.push(tok);
            ctx.push(tok);
        }
        out
    }

    /// One verify round over a drafted chain: scan, commit the accepted
    /// prefix + the target's pick at the cut (paper Algorithm 1 shape).
    fn round(history: &mut Vec<u32>, drafts: &[u32], policy: VerifyPolicy) {
        let mut rows = Vec::new();
        let mut ctx = history.clone();
        for &d in drafts {
            rows.push(target_row(&ctx));
            ctx.push(d);
        }
        let (_, m) = policy.scan(drafts, &rows);
        history.extend(&drafts[..m]);
        // bonus/correction token: the target's pick after the accepted
        // prefix (recompute when the scan cut the chain short)
        let fin = if m == drafts.len() {
            target_row(history).0
        } else {
            rows[m].0
        };
        history.push(fin);
    }

    /// Decode `max_new` tokens from `prompt`; `tree` drafts two salted
    /// branches per round and verifies the better one.
    pub fn decode(
        prompt: &[u32],
        policy: VerifyPolicy,
        tree: bool,
        max_new: usize,
    ) -> Vec<u32> {
        let mut history = prompt.to_vec();
        while history.len() < prompt.len() + max_new {
            let drafts = if tree {
                // two branches; verify the one the scan accepts deeper
                let a = draft_chain(&history, 4, 0x5A17);
                let b = draft_chain(&history, 4, 0xB0B5);
                let score = |d: &[u32]| {
                    let mut ctx = history.clone();
                    let mut rows = Vec::new();
                    for &t in d {
                        rows.push(target_row(&ctx));
                        ctx.push(t);
                    }
                    policy.scan(d, &rows).1
                };
                if score(&b) > score(&a) {
                    b
                } else {
                    a
                }
            } else {
                draft_chain(&history, 5, 0x5A17)
            };
            round(&mut history, &drafts, policy);
        }
        history[prompt.len()..].to_vec()
    }
}

#[test]
fn prop_cached_prefix_decode_token_identical_on_host_reference() {
    // every policy family x a chain and a tree drafter: decoding with a
    // restored cached prefix must be token-identical to a cold decode at
    // T=0 (the host reference analog of the integration-test pin)
    let policies = [
        VerifyPolicy::Strict,
        VerifyPolicy::Mars { theta: 0.6 },
        VerifyPolicy::TopK { k: 2, eps: 0.4 },
        VerifyPolicy::Entropy { h_max: 1.0 },
    ];
    let mut rng = Rng::new(644);
    for case in 0..30 {
        let plen = 6 + rng.usize_below(10);
        let prompt: Vec<u32> =
            (0..plen).map(|_| rng.below(24) as u32).collect();
        let cut = 1 + rng.usize_below(plen - 1);
        for policy in policies {
            for tree in [false, true] {
                let cold =
                    host_reference_decode::decode(&prompt, policy, tree, 12);

                // warm path: the cache stores the prefix "state" (for
                // the host reference the state IS the token history);
                // restore it, confirm the matched length, and resume
                let mut cache = mars::cache::PrefixCache::new(1 << 20);
                cache.insert(
                    &prompt[..cut],
                    prompt[..cut].iter().map(|&t| t as f32).collect(),
                );
                let (l, state) =
                    cache.lookup(&prompt, false).expect("prefix hit");
                assert!(l >= cut, "lookup lost the stored prefix");
                let mut history: Vec<u32> =
                    state.iter().map(|&f| f as u32).collect();
                assert_eq!(&history[..], &prompt[..l]);
                history.extend(&prompt[l..]); // "suffix prefill"
                let warm = host_reference_decode::decode(
                    &history, policy, tree, 12,
                );
                assert_eq!(
                    cold, warm,
                    "case {case}: policy {policy:?} tree={tree} cut={cut}"
                );
            }
        }
    }
}

// ------------------------------------------------ bench record schema ------

use mars::bench::diff::{diff_docs, metric_rule, DiffCfg, Direction, Verdict};
use mars::bench::record::{Env, Provenance, RecordDoc};

const METRIC_POOL: [&str; 9] = [
    "tok_per_s",
    "ttft_ms_p50",
    "ttft_ms_p99",
    "tpot_ms_p50",
    "tau",
    "device_calls_per_token",
    "accuracy",
    "speedup_sim",
    "weird_custom_metric",
];

fn random_word(rng: &mut Rng) -> String {
    let len = 1 + rng.usize_below(8);
    (0..len)
        .map(|_| (b'a' + rng.below(26) as u8) as char)
        .collect()
}

fn random_value(rng: &mut Rng) -> f64 {
    match rng.below(3) {
        // integral values exercise the int rendering path
        0 => rng.below(100_000) as f64,
        1 => (rng.f64() - 0.5) * 2e6,
        _ => rng.f64() * 1e-3,
    }
}

/// Random schema-valid document: unique key ids by construction.
fn random_doc(rng: &mut Rng) -> RecordDoc {
    let target = ["packing", "batch", "policies", "serve"]
        [rng.usize_below(4)]
    .to_string();
    let mut doc = RecordDoc::new(
        &target,
        Env {
            provenance: if rng.below(2) == 0 {
                Provenance::Measured
            } else {
                Provenance::Estimated
            },
            host: random_word(rng),
            artifact_hash: random_word(rng),
            created_by: format!("mars bench {target}"),
            note: if rng.below(2) == 0 {
                Some(random_word(rng))
            } else {
                None
            },
        },
    );
    for _ in 0..rng.usize_below(3) {
        doc.config_num(&random_word(rng), random_value(rng));
    }
    let mut seen = std::collections::BTreeSet::new();
    for _ in 0..1 + rng.usize_below(12) {
        let metric = METRIC_POOL[rng.usize_below(METRIC_POOL.len())];
        let keys = [
            ("method", random_word(rng)),
            ("policy", random_word(rng)),
        ];
        doc.push(
            metric,
            random_value(rng),
            "u",
            rng.usize_below(32),
            rng.below(1000),
            &keys,
        );
        let id = doc.records.last().unwrap().key_id();
        if !seen.insert(id) {
            doc.records.pop();
        }
    }
    if doc.records.is_empty() {
        doc.push("tok_per_s", 1.0, "tok/s", 4, 7, &[("method", "m".into())]);
    }
    doc
}

#[test]
fn prop_record_doc_round_trips_byte_identical() {
    let mut rng = Rng::new(700);
    for case in 0..300 {
        let doc = random_doc(&mut rng);
        let text = doc.render();
        let back = RecordDoc::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(back, doc, "case {case}: typed round-trip");
        assert_eq!(back.render(), text, "case {case}: byte round-trip");
    }
}

#[test]
fn prop_diff_reflexivity() {
    let mut rng = Rng::new(701);
    for case in 0..300 {
        let doc = random_doc(&mut rng);
        let r = diff_docs(&doc, &doc, &DiffCfg::default());
        assert!(!r.regressed(), "case {case}: diff(x, x) regressed");
        assert!(r.warnings().is_empty(), "case {case}: diff(x, x) warned");
        assert!(
            r.added.is_empty() && r.removed.is_empty(),
            "case {case}: diff(x, x) reported unmatched keys"
        );
        assert_eq!(r.rows.len(), doc.records.len(), "case {case}");
        for row in &r.rows {
            assert_eq!(row.ratio, 1.0, "case {case}: {}", row.key);
        }
    }
}

#[test]
fn prop_diff_threshold_monotonic() {
    // for a fixed baseline, a strictly worse new value is never judged
    // less severely than a better one (severity: Pass < Warn < Fail)
    let sev = |v: Verdict| match v {
        Verdict::Pass | Verdict::Info => 0,
        Verdict::Warn => 1,
        Verdict::Fail => 2,
    };
    let mut rng = Rng::new(702);
    for case in 0..400 {
        let metric = METRIC_POOL[rng.usize_below(METRIC_POOL.len())];
        let (dir, _) = metric_rule(metric);
        if dir == Direction::Info {
            continue;
        }
        let old_v = 1.0 + rng.f64() * 1000.0;
        let a = old_v * (0.1 + rng.f64() * 1.8);
        let b = old_v * (0.1 + rng.f64() * 1.8);
        // `worse` is the value farther in the metric's bad direction
        let (worse, better) = match dir {
            Direction::Higher => (a.min(b), a.max(b)),
            _ => (a.max(b), a.min(b)),
        };
        let n = 1 + rng.usize_below(32);
        let estimated = rng.below(2) == 0;
        let mk = |value: f64| {
            let mut d = RecordDoc::new(
                "packing",
                Env {
                    provenance: if estimated {
                        Provenance::Estimated
                    } else {
                        Provenance::Measured
                    },
                    host: "h".into(),
                    artifact_hash: "x".into(),
                    created_by: "t".into(),
                    note: None,
                },
            );
            d.push(metric, value, "u", n, 7, &[("method", "m".into())]);
            d
        };
        let old = mk(old_v);
        let vw = diff_docs(&old, &mk(worse), &DiffCfg::default()).rows[0]
            .verdict;
        let vb = diff_docs(&old, &mk(better), &DiffCfg::default()).rows[0]
            .verdict;
        assert!(
            sev(vw) >= sev(vb),
            "case {case}: {metric} old={old_v} worse={worse} ({vw:?}) \
             better={better} ({vb:?})"
        );
    }
}

#[test]
fn prop_diff_key_pairing_total() {
    // every key on either side lands in exactly one of rows/added/removed
    let mut rng = Rng::new(703);
    for case in 0..200 {
        let mut old = random_doc(&mut rng);
        let mut new = random_doc(&mut rng);
        // force the same target so keys can actually collide
        new.target = old.target.clone();
        for r in &mut new.records {
            r.target = old.target.clone();
        }
        // splice some shared records in so all three buckets are hit
        for r in old.records.iter().take(rng.usize_below(4)) {
            let mut shared = r.clone();
            shared.value += 1.0;
            if !new.records.iter().any(|x| x.key_id() == shared.key_id()) {
                new.records.push(shared);
            }
        }
        let report = diff_docs(&old, &new, &DiffCfg::default());
        let paired: std::collections::BTreeSet<String> =
            report.rows.iter().map(|r| r.key.clone()).collect();
        let added: std::collections::BTreeSet<String> =
            report.added.iter().cloned().collect();
        let removed: std::collections::BTreeSet<String> =
            report.removed.iter().cloned().collect();
        for r in &old.records {
            let id = r.key_id();
            assert!(
                paired.contains(&id) ^ removed.contains(&id),
                "case {case}: old key {id} dropped or double-counted"
            );
            assert!(!added.contains(&id), "case {case}: old key {id} added");
        }
        for r in &new.records {
            let id = r.key_id();
            assert!(
                paired.contains(&id) ^ added.contains(&id),
                "case {case}: new key {id} dropped or double-counted"
            );
        }
        assert_eq!(
            paired.len() + added.len() + removed.len(),
            old.by_key().len() + new.by_key().len() - paired.len(),
            "case {case}: bucket sizes disagree"
        );
    }
}

// ------------------------------------------------ observability (§12) ------

use mars::coordinator::metrics::{MetricsRegistry, RequestMetrics};
use mars::obs::hist::StreamHistogram;
use mars::obs::round::RoundEvent;
use mars::obs::trace::{Phase, TraceEvent};

/// A random histogram over a wide dynamic range (sub-bucket-min tail,
/// mid-range, and saturating top included).
fn random_hist(rng: &mut Rng, n: usize) -> (StreamHistogram, Vec<f64>) {
    let mut h = StreamHistogram::new();
    let mut vals = Vec::with_capacity(n);
    for _ in 0..n {
        // log-uniform across ~12 decades plus occasional edge values
        let v = match rng.below(20) {
            0 => 0.0,
            1 => -rng.f64(),
            2 => 1e9 * (1.0 + rng.f64()),
            _ => 10f64.powf(rng.f64() * 12.0 - 6.0),
        };
        h.record(v);
        vals.push(v);
    }
    (h, vals)
}

/// Two histograms agree observably: same count/sum/min/max, same
/// quantiles, same cumulative counts.
fn assert_hist_eq(a: &StreamHistogram, b: &StreamHistogram, ctx: &str) {
    assert_eq!(a.count(), b.count(), "{ctx}: count");
    assert!((a.sum() - b.sum()).abs() <= 1e-9 * a.sum().abs().max(1.0), "{ctx}: sum");
    assert_eq!(a.min(), b.min(), "{ctx}: min");
    assert_eq!(a.max(), b.max(), "{ctx}: max");
    for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
        assert_eq!(a.quantile(q), b.quantile(q), "{ctx}: q{q}");
    }
    for x in [1e-7, 1e-3, 1.0, 42.0, 1e4, 1e9] {
        assert_eq!(a.count_le(x), b.count_le(x), "{ctx}: count_le({x})");
    }
}

#[test]
fn prop_histogram_merge_commutative() {
    let mut rng = Rng::new(800);
    for case in 0..200 {
        let (a, _) = random_hist(&mut rng, 1 + rng.usize_below(200));
        let (b, _) = random_hist(&mut rng, rng.usize_below(200));
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_hist_eq(&ab, &ba, &format!("case {case}: a+b vs b+a"));
    }
}

#[test]
fn prop_histogram_merge_associative() {
    let mut rng = Rng::new(801);
    for case in 0..200 {
        let (a, _) = random_hist(&mut rng, rng.usize_below(150));
        let (b, _) = random_hist(&mut rng, rng.usize_below(150));
        let (c, _) = random_hist(&mut rng, 1 + rng.usize_below(150));
        let mut left = a.clone(); // (a + b) + c
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone(); // a + (b + c)
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_hist_eq(&left, &right, &format!("case {case}: assoc"));
    }
}

#[test]
fn prop_histogram_merge_matches_single_stream() {
    // splitting a stream across shards and merging loses nothing — the
    // per-replica sharding of the metrics registry relies on this
    let mut rng = Rng::new(802);
    for case in 0..100 {
        let n = 1 + rng.usize_below(400);
        let mut shards: Vec<StreamHistogram> =
            (0..4).map(|_| StreamHistogram::new()).collect();
        let mut all = StreamHistogram::new();
        for i in 0..n {
            let v = 10f64.powf(rng.f64() * 8.0 - 4.0);
            shards[i % 4].record(v);
            all.record(v);
        }
        let mut merged = shards[0].clone();
        for s in &shards[1..] {
            merged.merge(s);
        }
        assert_hist_eq(&merged, &all, &format!("case {case}: shard split"));
    }
}

#[test]
fn prop_histogram_quantile_error_bounded() {
    // vs the exact nearest-rank sample: relative error <= 2^(1/32)-1
    // (~2.2%), asserted with a little float headroom at 2.5%
    let mut rng = Rng::new(803);
    for case in 0..60 {
        let n = 10 + rng.usize_below(2000);
        let mut h = StreamHistogram::new();
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            let v = 10f64.powf(rng.f64() * 7.0 - 3.0);
            h.record(v);
            vals.push(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.01, 0.1, 0.5, 0.9, 0.99] {
            let rank = ((n as f64 * q).ceil() as usize).clamp(1, n);
            let exact = vals[rank - 1];
            let got = h.quantile(q);
            let rel = (got / exact - 1.0).abs();
            assert!(
                rel < 0.025,
                "case {case}: n={n} q={q}: {got} vs exact {exact} \
                 (rel {rel:.4})"
            );
        }
    }
}

fn random_trace_event(rng: &mut Rng) -> TraceEvent {
    let phase = match rng.below(10) {
        0 => Phase::Queue,
        1 => Phase::Prefill,
        2 => Phase::Round,
        3 => Phase::Commit,
        4 => Phase::Fault,
        5 => Phase::Requeue,
        6 => Phase::Health,
        7 => Phase::Deadline,
        8 => Phase::Shed,
        _ => Phase::Error,
    };
    let mut ev = TraceEvent::new(
        rng.f64() * 1e6,
        rng.below(1 << 30),
        rng.usize_below(16),
        phase,
    );
    let mut opt_f64 = |rng: &mut Rng| {
        if rng.below(2) == 0 { Some(rng.f64() * 1e4) } else { None }
    };
    ev.wall_ms = opt_f64(rng);
    ev.tau = opt_f64(rng);
    if rng.below(2) == 0 {
        ev.tokens = Some(rng.below(100_000));
    }
    if rng.below(2) == 0 {
        ev.cached_tokens = Some(rng.below(100_000));
    }
    if rng.below(2) == 0 {
        ev.ok = Some(rng.below(2) == 0);
    }
    if rng.below(2) == 0 {
        ev.policy = Some(random_policy(rng).name().to_string());
    }
    if rng.below(2) == 0 {
        ev.method = Some(random_method(rng).name().to_string());
    }
    if rng.below(2) == 0 {
        ev.detail = Some(random_word(rng));
    }
    if phase == Phase::Round {
        ev.round = Some(RoundEvent {
            turn: rng.below(1000),
            rounds: rng.below(16),
            drafted: rng.below(64),
            accepted: rng.below(64),
            exact: rng.below(64),
            relaxed: rng.below(8),
            rejects: rng.below(2),
            committed: rng.below(64),
            last_accept: rng.below(64),
            margin: if rng.below(2) == 0 { Some(rng.f64()) } else { None },
            wall_ms: rng.f64() * 100.0,
            sim_units: if rng.below(2) == 0 {
                Some(rng.f64() * 10.0)
            } else {
                None
            },
            pack: 1 + rng.below(16),
            occupancy: 1 + rng.below(8),
            finished: rng.below(2) == 0,
        });
    }
    ev
}

#[test]
fn prop_trace_render_parse_round_trips() {
    let mut rng = Rng::new(804);
    for case in 0..500 {
        let ev = random_trace_event(&mut rng);
        let line = ev.render();
        let back = TraceEvent::parse_line(&line)
            .unwrap_or_else(|e| panic!("case {case}: {line} -> {e}"));
        assert_eq!(back, ev, "case {case}: {line}");
    }
}

/// Reference verifier: run random decisive-position probes through
/// `VerifyPolicy::accept` exactly like the device-side verify does, and
/// feed the (margin, flag) pairs into the registry.
#[test]
fn prop_margin_histograms_split_exhaustively_by_outcome() {
    // strict + relaxed + reject histogram counts must equal the verify
    // decisions fed in — no decision may vanish or double-count
    let mut rng = Rng::new(805);
    for case in 0..50 {
        let reg = MetricsRegistry::new();
        let n = 1 + rng.usize_below(300);
        let mut want = [0u64; 3]; // exact, relaxed, reject
        let mut samples: Vec<(f64, AcceptFlag)> = Vec::new();
        for _ in 0..n {
            let z1 = (rng.f64() * 8.0) as f32;
            let z2 = z1 * rng.f64() as f32; // z2 <= z1, the sorted truth
            let v1 = rng.below(100) as u32;
            let v2 = v1 + 1 + rng.below(100) as u32;
            let draft = if rng.below(2) == 0 {
                v1
            } else if rng.below(2) == 0 {
                v2
            } else {
                v2 + 1 + rng.below(100) as u32
            };
            let theta = rng.f64() as f32;
            let flag = VerifyPolicy::Mars { theta }
                .accept(draft, v1, &[(v1, z1), (v2, z2)]);
            match flag {
                AcceptFlag::Exact => want[0] += 1,
                AcceptFlag::Relaxed => want[1] += 1,
                AcceptFlag::Reject => want[2] += 1,
            }
            let margin = if z1 > 0.0 && z2 > 0.0 {
                (z2 / z1) as f64
            } else {
                0.0
            };
            samples.push((margin, flag));
        }
        // spread across replicas: the shard merge must conserve counts
        for (i, chunk) in samples.chunks(64).enumerate() {
            reg.record_margins(i, "mars", "eagle_tree", chunk);
        }
        let snap = reg.snapshot_json();
        let count = |outcome: &str| {
            snap.path(&["margin", "mars", "eagle_tree", outcome, "count"])
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0) as u64
        };
        let got = [count("exact"), count("relaxed"), count("reject")];
        assert_eq!(got, want, "case {case}: outcome split leaked decisions");
        assert_eq!(
            got.iter().sum::<u64>(),
            n as u64,
            "case {case}: decisions lost"
        );
    }
}

#[test]
fn prop_registry_memory_stays_bounded_under_load() {
    // the sharded registry's byte footprint depends on label cardinality
    // (policies x methods x outcomes), never on request volume
    let reg = MetricsRegistry::new();
    let mut rng = Rng::new(806);
    let mut m = RequestMetrics {
        ok: true,
        replica: 0,
        tokens: 32,
        decode_seconds: 0.1,
        prefill_seconds: 0.01,
        queue_seconds: 0.001,
        ttft_seconds: 0.02,
        tau: 3.0,
        relaxed_accepts: 1.0,
        policy: "mars",
        method: "eagle_tree",
    };
    // settle the label space first: one record per shard creates the
    // per-policy/per-method entries, which is the only growth allowed
    for r in 0..8 {
        m.replica = r;
        reg.record(m);
    }
    let settled = reg.approx_bytes();
    for i in 0..50_000usize {
        m.replica = i % 8;
        m.decode_seconds = rng.f64();
        m.queue_seconds = rng.f64() * 0.01;
        reg.record(m);
    }
    let after = reg.approx_bytes();
    assert_eq!(
        after, settled,
        "registry grew {settled} -> {after} bytes under pure request load"
    );
}

// ------------------------------------------ failure semantics (§13) -----

use mars::coordinator::replica::{requeue_next_retries, MAX_REQUEUES};
use mars::fault::{backoff_bound_ms, backoff_ms, FaultSpec};

#[test]
fn prop_backoff_bound_monotone_capped_over_random_configs() {
    let mut rng = Rng::new(900);
    for case in 0..200 {
        let base = 1 + rng.below(200);
        let cap = base + rng.below(20_000);
        let mut prev = 0u64;
        for attempt in 0..70u32 {
            let b = backoff_bound_ms(attempt, base, cap);
            assert!(
                b >= prev,
                "case {case}: bound shrank {prev} -> {b} at attempt \
                 {attempt} (base={base}, cap={cap})"
            );
            assert!(b <= cap, "case {case}: bound {b} above cap {cap}");
            assert!(b >= base.min(cap), "case {case}: bound {b} below base");
            prev = b;
        }
        // the cap is reached, not just approached: exponential growth
        // saturates well before attempt 70
        assert_eq!(
            backoff_bound_ms(69, base, cap),
            cap,
            "case {case}: bound never reached the cap"
        );
    }
}

#[test]
fn prop_backoff_jitter_stays_in_equal_jitter_band() {
    let mut rng = Rng::new(901);
    for case in 0..500 {
        let base = 1 + rng.below(100);
        let cap = base + rng.below(10_000);
        let attempt = rng.below(20) as u32;
        let bound = backoff_bound_ms(attempt, base, cap);
        let ms = backoff_ms(attempt, base, cap, &mut rng);
        assert!(
            ms >= bound / 2 && ms <= bound,
            "case {case}: jittered {ms} outside [{}, {bound}]",
            bound / 2
        );
    }
}

#[test]
fn prop_fault_spec_label_parse_round_trips() {
    let mut rng = Rng::new(902);
    for case in 0..300 {
        // rates as exact nonzero thousandths: `{}` on these f64s prints
        // the same digits back, and a 0-rate part would be (correctly)
        // dropped from the canonical label, breaking spec equality
        fn rate(rng: &mut Rng) -> f64 {
            (1 + rng.below(999)) as f64 / 1000.0
        }
        let mut parts = Vec::new();
        if rng.below(2) == 0 {
            parts.push(format!("dispatch={}", rate(&mut rng)));
        }
        if rng.below(2) == 0 {
            parts.push(format!(
                "latency={}:{}",
                rate(&mut rng),
                1 + rng.below(500)
            ));
        }
        if rng.below(2) == 0 {
            parts.push(format!("rebuild={}", rate(&mut rng)));
        }
        parts.push(format!("seed={}", rng.below(1 << 30)));
        if rng.below(2) == 0 {
            parts.push(format!("only={}", rng.below(8)));
        }
        let raw = parts.join(",");
        let spec = FaultSpec::parse(&raw)
            .unwrap_or_else(|e| panic!("case {case}: {raw:?}: {e}"));
        let label = spec.label();
        let back = FaultSpec::parse(&label)
            .unwrap_or_else(|e| panic!("case {case}: label {label:?}: {e}"));
        assert_eq!(spec, back, "case {case}: label round-trip changed the spec");
        assert_eq!(back.label(), label, "case {case}: label not canonical");
    }
}

#[test]
fn prop_fault_plan_streams_deterministic_and_forked_per_replica() {
    let mut rng = Rng::new(903);
    for case in 0..50 {
        let spec = FaultSpec {
            dispatch_rate: 0.3 + rng.f64() * 0.4,
            seed: rng.below(1 << 30),
            ..FaultSpec::default()
        };
        let draws = |replica: usize| -> Vec<bool> {
            let plan = spec
                .build(replica)
                .unwrap_or_else(|| panic!("case {case}: plan filtered"));
            (0..96).map(|_| plan.dispatch_fault()).collect()
        };
        // same (seed, replica) twice -> identical stream (reproducible
        // chaos runs); sibling replicas -> distinct forked streams
        assert_eq!(draws(0), draws(0), "case {case}: stream not stable");
        assert_eq!(draws(3), draws(3), "case {case}: stream not stable");
        assert_ne!(
            draws(0),
            draws(1),
            "case {case}: replicas share one fault stream"
        );
    }
}

#[test]
fn prop_requeue_budget_exhausts_in_bounded_steps() {
    // a lane that gets victimized by every single batch fault must reach
    // a terminal outcome after exactly MAX_REQUEUES requeues — never an
    // unbounded retry loop, never a silent drop — and the retry counter
    // must climb one per requeue, monotone
    let mut retries = 0u32;
    let mut requeues = 0usize;
    loop {
        match requeue_next_retries(retries) {
            Some(next) => {
                assert_eq!(next, retries + 1, "retry counter must be monotone");
                retries = next;
                requeues += 1;
                assert!(
                    requeues <= MAX_REQUEUES as usize,
                    "budget exceeded: {requeues} requeues"
                );
            }
            None => break,
        }
    }
    assert_eq!(requeues, MAX_REQUEUES as usize);
    // exhaustion is absorbing: once over budget, always terminal
    for r in MAX_REQUEUES..MAX_REQUEUES + 10 {
        assert_eq!(requeue_next_retries(r), None, "budget not absorbing at {r}");
    }
}
