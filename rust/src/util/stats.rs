//! Streaming statistics: mean/min/max accumulators, percentile summaries
//! and a log-scaled latency histogram. Shared by the serving metrics
//! registry and the bench harness (criterion is not in the offline
//! registry; `benches/` use these primitives with `harness = false`).

/// Simple accumulator with exact percentiles (stores samples).
#[derive(Debug, Default, Clone)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    /// q in [0,1]; nearest-rank on the sorted samples.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() as f64 - 1.0) * q).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

/// Fixed-bucket log2 histogram for lock-cheap hot-path recording
/// (microseconds -> bucket = floor(log2(us))).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { buckets: vec![0; 40], count: 0, sum: 0.0 }
    }
}

impl LogHistogram {
    pub fn record(&mut self, value: f64) {
        let b = if value <= 1.0 {
            0
        } else {
            (value.log2() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += value;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (self.count as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return (1u64 << (i + 1)) as f64;
            }
        }
        (1u64 << self.buckets.len()) as f64
    }
}

/// Format a mean±std cell the way the bench tables print it.
pub fn fmt_ms(mean_ms: f64) -> String {
    if mean_ms >= 100.0 {
        format!("{:.0}ms", mean_ms)
    } else if mean_ms >= 1.0 {
        format!("{:.2}ms", mean_ms)
    } else {
        format!("{:.0}us", mean_ms * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_edges() {
        let mut s = Summary::new();
        s.push(10.0);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(1.0), 10.0);
        assert_eq!(Summary::new().percentile(0.5), 0.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LogHistogram::default();
        for i in 1..1000u64 {
            h.record(i as f64);
        }
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(0.99));
        assert_eq!(h.count, 999);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_ms(0.5), "500us");
        assert_eq!(fmt_ms(2.345), "2.35ms");
        assert_eq!(fmt_ms(150.0), "150ms");
    }
}
