//! std-only substrate utilities.
//!
//! The offline registry only carries the `xla` crate's dependency tree
//! (no serde / rand / clap / criterion), so the small infrastructure those
//! crates would normally provide is implemented here: a JSON value type
//! with parser and writer ([`json`]), a splitmix/PCG PRNG ([`prng`]), a
//! tiny CLI flag parser ([`cli`]), and streaming statistics used by both
//! the metrics registry and the bench harness ([`stats`]).

pub mod cli;
pub mod json;
pub mod prng;
pub mod stats;
