//! Deterministic PRNG (splitmix64 core) — `rand` is not in the offline
//! registry. Used for workload generation, sampling-side host logic and
//! the hand-rolled property tests.

#[derive(Debug, Clone)]
pub struct Rng {
    s: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { s: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.s = self.s.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.s;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        // rejection sampling for exact uniformity
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fork a stream deterministically (for per-request seeds).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut acc = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        let mean = acc / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }

    #[test]
    fn forks_diverge() {
        let mut r = Rng::new(4);
        let mut f1 = r.fork();
        let mut f2 = r.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
