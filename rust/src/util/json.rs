//! Minimal JSON: a `Value` enum, a recursive-descent parser and a writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null). Used for artifact manifests, the line-JSON
//! serving protocol and benchmark result files.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(s: &str) -> Result<Value, ParseError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `v.path(&["sections", "tkv", "offset"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: Value) -> &mut Value {
        if let Value::Obj(m) = self {
            m.insert(key.to_string(), v);
        }
        self
    }

    pub fn from_f64(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn from_str_(s: &str) -> Value {
        Value::Str(s.to_string())
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected token")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let mut end = self.i + 1;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.path(&["c", "d"]).unwrap().as_f64().unwrap(), -2500.0);
        let re = Value::parse(&v.to_string_json()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_nested_arrays() {
        let v = Value::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(v.idx(1).unwrap().idx(1).unwrap().idx(0).unwrap().as_f64(),
                   Some(4.0));
    }

    #[test]
    fn escapes_roundtrip() {
        let mut o = Value::obj();
        o.set("k", Value::Str("a\"b\\c\nd\te".into()));
        let re = Value::parse(&o.to_string_json()).unwrap();
        assert_eq!(re.get("k").unwrap().as_str().unwrap(), "a\"b\\c\nd\te");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Value::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Value::Num(3.0).to_string_json(), "3");
        assert_eq!(Value::Num(3.5).to_string_json(), "3.5");
    }
}
