//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Grammar: `mars <subcommand> [--flag value] [--switch] [positional...]`.
//! Flag values are opaque strings here; structured values (e.g.
//! `--policy mars:0.9` → `verify::VerifyPolicy`) are parsed by the
//! consumer so this layer stays dependency-free.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name). Flags take a value unless
    /// listed in `switches`.
    pub fn parse(argv: &[String], switches: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if switches.contains(&name) {
                    out.switches.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{name} needs a value"))?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        let a = Args::parse(
            &sv(&["bench", "--table", "1", "--quiet", "extra"]),
            &["quiet"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("table"), Some("1"));
        assert!(a.has("quiet"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&sv(&["run", "--theta=0.9"]), &[]).unwrap();
        assert_eq!(a.get_f64("theta", 0.0), 0.9);
    }

    #[test]
    fn policy_flag_passes_through_both_forms() {
        let a = Args::parse(&sv(&["generate", "--policy", "mars:0.9"]), &[])
            .unwrap();
        assert_eq!(a.get("policy"), Some("mars:0.9"));
        let b = Args::parse(&sv(&["generate", "--policy=topk:2:0.1"]), &[])
            .unwrap();
        assert_eq!(b.get("policy"), Some("topk:2:0.1"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["x", "--k"]), &[]).is_err());
    }
}
