//! Model weight loading: raw f32 little-endian `.bin` + `.json` metadata
//! written by `python/compile/train.py`, validated against the shapes the
//! AOT manifest recorded at lowering time.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// One model family's flat weights + per-tensor metadata.
#[derive(Debug, Clone)]
pub struct WeightFile {
    pub family: String,
    pub tensors: Vec<TensorMeta>,
    pub data: Vec<f32>,
}

impl WeightFile {
    pub fn load(dir: &Path, family: &str) -> Result<WeightFile> {
        let bin = dir.join(format!("{family}.bin"));
        let meta_path = dir.join(format!("{family}.json"));
        let bytes = fs::read(&bin)
            .with_context(|| format!("reading {}", bin.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("{}: size not a multiple of 4", bin.display());
        }
        let mut data = Vec::with_capacity(bytes.len() / 4);
        for c in bytes.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        let meta_text = fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let meta = Value::parse(&meta_text)
            .with_context(|| format!("parsing {}", meta_path.display()))?;
        let mut tensors = Vec::new();
        for t in meta.get("tensors").and_then(|v| v.as_arr()).context("tensors")? {
            tensors.push(TensorMeta {
                name: t.get("name").and_then(|v| v.as_str()).context("name")?.into(),
                shape: t
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .context("shape")?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                offset: t.get("offset").and_then(|v| v.as_usize()).context("offset")?,
                size: t.get("size").and_then(|v| v.as_usize()).context("size")?,
            });
        }
        let total = meta.get("total").and_then(|v| v.as_usize()).context("total")?;
        if total != data.len() {
            bail!(
                "{family}: meta total {total} != bin elements {}",
                data.len()
            );
        }
        Ok(WeightFile { family: family.to_string(), tensors, data })
    }

    pub fn tensor_data(&self, t: &TensorMeta) -> &[f32] {
        &self.data[t.offset..t.offset + t.size]
    }

    /// Validate tensor names/shapes against the AOT manifest's record of
    /// what the executables were lowered with.
    pub fn check_against_manifest(&self, manifest_family: &Value) -> Result<()> {
        let expect = manifest_family.as_arr().context("weights family")?;
        if expect.len() != self.tensors.len() {
            bail!(
                "{}: manifest lists {} tensors, weight file has {}",
                self.family,
                expect.len(),
                self.tensors.len()
            );
        }
        for (e, t) in expect.iter().zip(&self.tensors) {
            let name = e.get("name").and_then(|v| v.as_str()).unwrap_or("");
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().map(|x| x.as_usize().unwrap_or(0)).collect())
                .unwrap_or_default();
            if name != t.name || shape != t.shape {
                bail!(
                    "{}: tensor mismatch: manifest {name:?}{shape:?} vs \
                     weights {:?}{:?}",
                    self.family,
                    t.name,
                    t.shape
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_family(dir: &Path, fam: &str, vals: &[f32]) {
        let mut f = fs::File::create(dir.join(format!("{fam}.bin"))).unwrap();
        for v in vals {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        let meta = format!(
            r#"{{"tensors": [{{"name": "w", "shape": [{}], "offset": 0,
                 "size": {}}}], "total": {}}}"#,
            vals.len(),
            vals.len(),
            vals.len()
        );
        fs::write(dir.join(format!("{fam}.json")), meta).unwrap();
    }

    #[test]
    fn loads_roundtrip() {
        let dir = std::env::temp_dir().join("mars_wtest");
        fs::create_dir_all(&dir).unwrap();
        write_family(&dir, "t1", &[1.5, -2.0, 3.25]);
        let w = WeightFile::load(&dir, "t1").unwrap();
        assert_eq!(w.tensors.len(), 1);
        assert_eq!(w.tensor_data(&w.tensors[0]), &[1.5, -2.0, 3.25]);
    }

    #[test]
    fn total_mismatch_fails() {
        let dir = std::env::temp_dir().join("mars_wtest2");
        fs::create_dir_all(&dir).unwrap();
        write_family(&dir, "t2", &[1.0]);
        fs::write(
            dir.join("t2.json"),
            r#"{"tensors": [], "total": 99}"#,
        )
        .unwrap();
        assert!(WeightFile::load(&dir, "t2").is_err());
    }

    #[test]
    fn manifest_check() {
        let dir = std::env::temp_dir().join("mars_wtest3");
        fs::create_dir_all(&dir).unwrap();
        write_family(&dir, "t3", &[0.0; 4]);
        let w = WeightFile::load(&dir, "t3").unwrap();
        let ok = Value::parse(r#"[{"name": "w", "shape": [4]}]"#).unwrap();
        assert!(w.check_against_manifest(&ok).is_ok());
        let bad = Value::parse(r#"[{"name": "x", "shape": [4]}]"#).unwrap();
        assert!(w.check_against_manifest(&bad).is_err());
    }
}
