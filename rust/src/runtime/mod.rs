//! Runtime — the PJRT bridge (layer boundary between rust and the AOT'd
//! JAX programs).
//!
//! Design constraints measured on this box (DESIGN.md §1.1):
//! * `execute` with `Literal` args costs ~42 ms/call for MB-sized inputs;
//!   `execute_b` with device-resident `PjRtBuffer`s costs ~0.5 ms. All hot
//!   state therefore stays in device buffers, chained call-to-call.
//! * Multi-output executables return a single tuple buffer that cannot be
//!   split on device, so every round program is single-output (the packed
//!   state vector) by construction.
//!
//! [`Runtime::session`] starts a device-resident decode; the deliberately
//! naive [`Session::set_hostloop`] mode round-trips the full state through
//! host memory every call and is kept as the §Perf "before" baseline.
//! [`Runtime::session_from_state`] instead resumes a prefix-cache
//! snapshot ([`Session::export_state`]) and prefills only the uncached
//! token suffix via the `prefill_ext` artifact (DESIGN.md §8).

pub mod state;
pub mod weights;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;
use state::{Layout, ProbeDump, Snapshot};
use weights::WeightFile;

/// Parsed artifact directory: manifest + layout + vocab (no device objects).
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub manifest: Value,
    pub layout: Layout,
    pub vocab: Value,
}

impl Artifacts {
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let read = |name: &str| -> Result<Value> {
            let p = dir.join(name);
            let text = fs::read_to_string(&p)
                .with_context(|| format!("reading {}", p.display()))?;
            Value::parse(&text)
                .map_err(|e| anyhow!("parsing {}: {e}", p.display()))
        };
        let manifest = read("manifest.json")?;
        let layout_doc = read("state_layout.json")?;
        let layout = Layout::from_json(&layout_doc)?;
        let vocab = read("vocab.json")?;
        crate::tokenizer::check_vocab_spec(&vocab)
            .map_err(|e| anyhow!("{e}"))?;
        let manifest_hash = manifest
            .get("state_hash")
            .and_then(|h| h.as_str())
            .unwrap_or("");
        if manifest_hash != layout.hash {
            bail!(
                "state layout hash mismatch: manifest {manifest_hash} vs \
                 layout {}",
                layout.hash
            );
        }
        Ok(Artifacts { dir: dir.to_path_buf(), manifest, layout, vocab })
    }

    /// Default artifact location: `$MARS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("MARS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// True if the artifact directory looks complete (used by tests to
    /// self-skip when `make artifacts` has not run).
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
            && dir.join("state_layout.json").exists()
            && dir.join("weights/target.bin").exists()
    }

    pub fn executable_names(&self) -> Vec<String> {
        self.manifest
            .get("executables")
            .and_then(|e| e.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }
}

struct Exec {
    exe: xla::PjRtLoadedExecutable,
    state_input: bool,
    /// number of extra (non-state, non-weight) inputs
    extras: Vec<(String, usize)>,
    /// uploaded weight buffers, already in parameter order
    weight_bufs: Vec<xla::PjRtBuffer>,
}

/// Encode one request's generation parameters into the prefill `cfg`
/// vector for `lay` — the host side of the cfg-slot contract
/// (`state_spec.CFG`; checked against the manifest by `mars check
/// contracts`, and round-tripped against [`VerifyPolicy::decode_slots`]
/// / [`SpecMethod::encode_slots`] by the property tests in
/// `tests/contracts.rs`). Free of any device handle so tests can drive
/// it from a manifest-built [`Layout`] alone.
///
/// [`VerifyPolicy::decode_slots`]: crate::verify::VerifyPolicy::decode_slots
/// [`SpecMethod::encode_slots`]: crate::spec::SpecMethod::encode_slots
pub fn encode_cfg(
    lay: &Layout,
    prompt_len: usize,
    params: &crate::engine::GenParams,
) -> Vec<f32> {
    let n_cfg = lay.konst("n_cfg");
    let mut cfg = vec![0f32; n_cfg];
    let c = |name: &str| lay.cfg[name];
    cfg[c("temp")] = params.temperature;
    let [policy_id, p0, p1] = params.policy.encode_slots();
    cfg[c("policy_id")] = policy_id;
    cfg[c("p0")] = p0;
    cfg[c("p1")] = p1;
    // method lowering: the descriptor's knobs become config slots
    // (the method identity lowers to the executable name; see
    // `SpecMethod::encode_slots` / `SpecMethod::exec_name`)
    let [kdraft, beam, branch] = params.method.encode_slots();
    cfg[c("kdraft")] = kdraft;
    cfg[c("max_new")] = params.max_new as f32;
    cfg[c("eos")] = crate::tokenizer::EOS as f32;
    cfg[c("beam")] = beam;
    cfg[c("branch")] = branch;
    cfg[c("probe_on")] = if params.probe { 1.0 } else { 0.0 };
    cfg[c("greedy")] = if params.temperature <= 0.0 { 1.0 } else { 0.0 };
    cfg[c("seed")] = (params.seed % (1 << 24)) as f32;
    cfg[c("prompt_len")] = prompt_len as f32;
    // round packing (DESIGN.md §9.6): the configured pack cap; old
    // artifact layouts predate the slot, so write it only when known
    // (those artifacts lack the *_multi programs anyway)
    if let Some(&ci) = lay.cfg.get("rounds_per_call") {
        cfg[ci] = params.rounds_per_call as f32;
    }
    cfg
}

/// A live PJRT CPU client with every executable compiled and all weight
/// families resident on device. Owns all device objects — PJRT handles are
/// not `Send`, so a `Runtime` must be created and used on one thread (the
/// coordinator spawns one engine thread per replica; see `coordinator`).
pub struct Runtime {
    pub artifacts: Artifacts,
    client: xla::PjRtClient,
    execs: BTreeMap<String, Exec>,
    /// wall time spent compiling HLO at startup
    pub compile_seconds: f64,
    /// Installed fault-injection plan (DESIGN.md §13). `None` in
    /// production; the chaos harness installs one via
    /// [`Runtime::install_fault_plan`] to inject dispatch errors,
    /// hung-dispatch latency, and batch-session rebuild failures.
    fault: Option<std::sync::Arc<crate::fault::FaultPlan>>,
}

impl Runtime {
    pub fn new(dir: &Path) -> Result<Runtime> {
        let artifacts = Artifacts::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;

        // load + verify weight families once, upload per executable below
        let wdir = dir.join("weights");
        let mut families: BTreeMap<String, WeightFile> = BTreeMap::new();
        let manifest_weights = artifacts
            .manifest
            .get("weights")
            .and_then(|w| w.as_obj())
            .context("manifest weights")?;
        for fam in manifest_weights.keys() {
            let wf = WeightFile::load(&wdir, fam)?;
            wf.check_against_manifest(&manifest_weights[fam])?;
            families.insert(fam.clone(), wf);
        }

        let t0 = std::time::Instant::now();
        let mut execs = BTreeMap::new();
        let exec_manifest = artifacts
            .manifest
            .get("executables")
            .and_then(|e| e.as_obj())
            .context("manifest executables")?;
        for (name, spec) in exec_manifest {
            let file = spec.get("file").and_then(|f| f.as_str()).context("file")?;
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("path")?,
            )
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;

            let mut weight_bufs = Vec::new();
            for fam in spec
                .get("weight_families")
                .and_then(|f| f.as_arr())
                .context("weight_families")?
            {
                let fam = fam.as_str().context("family name")?;
                let wf = &families[fam];
                for t in &wf.tensors {
                    let dims: Vec<usize> = t.shape.clone();
                    let buf = client
                        .buffer_from_host_buffer(
                            wf.tensor_data(t),
                            &dims,
                            None,
                        )
                        .map_err(|e| anyhow!("upload {}: {e:?}", t.name))?;
                    weight_bufs.push(buf);
                }
            }
            let extras = spec
                .get("extras")
                .and_then(|f| f.as_arr())
                .context("extras")?
                .iter()
                .map(|e| {
                    let n = e.get("name").and_then(|v| v.as_str()).unwrap_or("");
                    let sz: usize = e
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .map(|a| {
                            a.iter().map(|x| x.as_usize().unwrap_or(0)).product()
                        })
                        .unwrap_or(0);
                    (n.to_string(), sz)
                })
                .collect();
            execs.insert(
                name.clone(),
                Exec {
                    exe,
                    state_input: spec
                        .get("state_input")
                        .and_then(|b| b.as_bool())
                        .unwrap_or(true),
                    extras,
                    weight_bufs,
                },
            );
        }
        Ok(Runtime {
            artifacts,
            client,
            execs,
            compile_seconds: t0.elapsed().as_secs_f64(),
            fault: None,
        })
    }

    /// Install a fault-injection plan: every subsequent [`Runtime::run`]
    /// dispatch and [`Runtime::batch_session`] rebuild consults it.
    pub fn install_fault_plan(
        &mut self,
        plan: std::sync::Arc<crate::fault::FaultPlan>,
    ) {
        self.fault = Some(plan);
    }

    /// The installed fault plan, if any (the replica supervisor reads
    /// its injection counters into the metrics surface).
    pub fn fault_plan(&self) -> Option<&std::sync::Arc<crate::fault::FaultPlan>> {
        self.fault.as_ref()
    }

    pub fn layout(&self) -> &Layout {
        &self.artifacts.layout
    }

    pub fn has_exec(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    fn exec(&self, name: &str) -> Result<&Exec> {
        self.execs
            .get(name)
            .ok_or_else(|| anyhow!("no executable '{name}' in artifacts"))
    }

    fn upload(&self, data: &[f32]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, &[data.len()], None)
            .map_err(|e| anyhow!("buffer upload: {e:?}"))
    }

    fn pull(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync: {e:?}"))?;
        lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
    }

    /// Run a named executable: args = [state?] ++ extras ++ weights.
    /// Returns the single output buffer.
    fn run(
        &self,
        name: &str,
        state: Option<&xla::PjRtBuffer>,
        extras: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        // fault injection (DESIGN.md §13): latency models a hung
        // dispatch (what deadlines bound), the error models a transient
        // device fault (what the supervisor requeues around)
        if let Some(plan) = &self.fault {
            if let Some(ms) = plan.latency() {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
            if plan.dispatch_fault() {
                bail!(
                    "{} dispatch fault on {name}",
                    crate::fault::INJECTED_PREFIX
                );
            }
        }
        let ex = self.exec(name)?;
        if ex.state_input != state.is_some() {
            bail!("{name}: state argument mismatch");
        }
        if ex.extras.len() != extras.len() {
            bail!(
                "{name}: expected {} extras, got {}",
                ex.extras.len(),
                extras.len()
            );
        }
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(1 + extras.len() + ex.weight_bufs.len());
        if let Some(s) = state {
            args.push(s);
        }
        args.extend_from_slice(extras);
        args.extend(ex.weight_bufs.iter());
        let mut outs = ex
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let mut replica = outs
            .pop()
            .filter(|r| !r.is_empty())
            .ok_or_else(|| anyhow!("{name}: no output"))?;
        Ok(replica.remove(0))
    }

    /// Build the prefill `cfg` vector for one request (shared by cold
    /// [`Runtime::session`] and the prefix-cache resume path, whose host
    /// restamp mirrors the cfg→scalar copy the device `prefill` performs).
    fn cfg_vector(
        &self,
        prompt_len: usize,
        params: &crate::engine::GenParams,
    ) -> Vec<f32> {
        encode_cfg(self.layout(), prompt_len, params)
    }

    /// Start a decode session for one request.
    pub fn session(
        &self,
        prompt_tokens: &[u32],
        params: &crate::engine::GenParams,
    ) -> Result<Session<'_>> {
        let lay = self.layout();
        let p_max = lay.konst("p_max");
        if prompt_tokens.is_empty() {
            bail!("empty prompt");
        }
        if prompt_tokens.len() > p_max {
            bail!("prompt too long: {} > {p_max}", prompt_tokens.len());
        }
        let mut prompt = vec![0f32; p_max];
        for (i, &t) in prompt_tokens.iter().enumerate() {
            prompt[i] = t as f32;
        }
        let cfg = self.cfg_vector(prompt_tokens.len(), params);

        let prompt_buf = self.upload(&prompt)?;
        let cfg_buf = self.upload(&cfg)?;
        let state = self.run("prefill", None, &[&prompt_buf, &cfg_buf])?;
        Ok(Session::wrap(self, DeviceState::Buffer(state), 1))
    }

    /// Does this artifact set carry the fused multi-round program
    /// `exec_name` (round packing)? Older builds lack the `*_multi`
    /// variants; callers fall back to the single-round path.
    pub fn supports_round_packing(&self, exec_name: &str) -> bool {
        self.has_exec(exec_name)
    }

    /// Can this artifact set extend a restored snapshot with a token
    /// suffix? Older artifact builds lack `prefill_ext`; on those the
    /// prefix cache still serves exact full-prompt hits (restore is a
    /// restamp + upload, no device program needed).
    pub fn supports_suffix_prefill(&self) -> bool {
        self.has_exec("prefill_ext")
    }

    /// Does this artifact set carry the cross-sequence batched programs
    /// (`*_batch`, DESIGN.md §9.5)? Requires the layout's `batch_max`
    /// constant plus the admission/extract splice programs; older
    /// artifact sets fall back to interleaved solo sessions.
    pub fn supports_batching(&self) -> bool {
        self.layout().batch_max() > 0
            && self.has_exec("batch_join")
            && self.has_exec("batch_slot")
            && self.has_exec("extract_batch")
    }

    /// Start an empty batched decode over `batch_max` lanes (DESIGN.md
    /// §9.5): every lane is zeroed with `finished = 1`, so the `*_batch`
    /// programs treat it as a masked no-op until [`BatchSession::join`]
    /// splices a prefilled sequence in.
    pub fn batch_session(&self) -> Result<BatchSession<'_>> {
        if !self.supports_batching() {
            bail!("artifacts lack the *_batch programs (DESIGN.md §9.5)");
        }
        if let Some(plan) = &self.fault {
            if plan.rebuild_fault() {
                bail!(
                    "{} batch session rebuild fault",
                    crate::fault::INJECTED_PREFIX
                );
            }
        }
        let lay = self.layout();
        let b = lay.batch_max();
        let fin = lay.scalar("finished");
        let mut host = vec![0f32; b * lay.state_len];
        for lane in 0..b {
            host[lane * lay.state_len + fin] = 1.0;
        }
        let state = self.upload(&host)?;
        Ok(BatchSession {
            rt: self,
            state,
            batch_max: b,
            pack_buf: None,
            ext_staging: Vec::new(),
            ext_buf: None,
            rounds_run: 0,
            device_calls: 1,
        })
    }

    /// Resume a prefix-cache snapshot as a fresh session (DESIGN.md §8):
    /// restamp the request's cfg scalars onto the cached state host-side
    /// ([`state::restamp_resumed`]), upload it, and run `prefill_ext`
    /// over the uncached suffix `prompt_tokens[cached_len..]` (skipped
    /// entirely when the whole prompt was cached).
    pub fn session_from_state(
        &self,
        cached: &[f32],
        cached_len: usize,
        prompt_tokens: &[u32],
        params: &crate::engine::GenParams,
    ) -> Result<Session<'_>> {
        let lay = self.layout();
        let p_max = lay.konst("p_max");
        if cached.len() != lay.state_len {
            bail!(
                "cached state length {} != layout state_len {}",
                cached.len(),
                lay.state_len
            );
        }
        if cached_len == 0 || cached_len > prompt_tokens.len() {
            bail!(
                "cached prefix {} outside prompt of {} tokens",
                cached_len,
                prompt_tokens.len()
            );
        }
        if prompt_tokens.len() > p_max {
            bail!("prompt too long: {} > {p_max}", prompt_tokens.len());
        }
        let suffix = &prompt_tokens[cached_len..];
        if !suffix.is_empty() && !self.supports_suffix_prefill() {
            bail!("artifacts lack 'prefill_ext' (partial prefix reuse)");
        }
        let mut state = cached.to_vec();
        let cfg = self.cfg_vector(prompt_tokens.len(), params);
        state::restamp_resumed(lay, &mut state, &cfg);

        let state_buf = self.upload(&state)?;
        let mut device_calls = 1; // the MB-sized state upload is traffic
        let state_buf = if suffix.is_empty() {
            state_buf
        } else {
            let mut ext = vec![0f32; p_max + 1];
            ext[0] = suffix.len() as f32;
            for (i, &t) in suffix.iter().enumerate() {
                ext[1 + i] = t as f32;
            }
            let ext_buf = self.upload(&ext)?;
            device_calls += 1;
            self.run("prefill_ext", Some(&state_buf), &[&ext_buf])?
        };
        Ok(Session::wrap(self, DeviceState::Buffer(state_buf), device_calls))
    }
}

enum DeviceState {
    Buffer(xla::PjRtBuffer),
    /// hostloop mode: the state lives on the host between calls
    Host(Vec<f32>),
}

/// One in-flight decode: wraps the device-resident state and drives round
/// executables. Borrows the runtime (single-threaded by construction).
pub struct Session<'a> {
    rt: &'a Runtime,
    state: DeviceState,
    hostloop: bool,
    /// Cached `pack` argument of the last [`Session::round_packed`] call:
    /// the one-float budget buffer is reuploaded only when the adaptive
    /// controller changes the value, not every call.
    pack_buf: Option<(usize, xla::PjRtBuffer)>,
    /// Preallocated staging vector for `round_ext` draft uploads (reused
    /// across rounds instead of a fresh `Vec<f32>` per call).
    ext_staging: Vec<f32>,
    /// Device buffer holding `ext_staging`'s last uploaded contents; kept
    /// so an unchanged draft vector (above all the empty draft) skips the
    /// re-upload entirely.
    ext_buf: Option<xla::PjRtBuffer>,
    /// Rounds driven so far. Packed calls count their *requested* budget
    /// (the device may exit the fused loop early at a stop flag), so this
    /// is an upper bound used for loop caps, not an exact round count —
    /// the state's own `rounds` scalar is exact.
    pub rounds_run: u64,
    /// Device executions + buffer uploads this session issued.
    pub device_calls: u64,
}

impl<'a> Session<'a> {
    fn wrap(rt: &'a Runtime, state: DeviceState, device_calls: u64) -> Self {
        Session {
            rt,
            state,
            hostloop: false,
            pack_buf: None,
            ext_staging: Vec::new(),
            ext_buf: None,
            rounds_run: 0,
            device_calls,
        }
    }

    /// Switch to the naive host-roundtrip runtime (§Perf baseline): the
    /// state is pulled to host after every call and re-uploaded before the
    /// next one.
    pub fn set_hostloop(&mut self, on: bool) -> Result<()> {
        if on == self.hostloop {
            return Ok(());
        }
        self.hostloop = on;
        self.state = match std::mem::replace(
            &mut self.state,
            DeviceState::Host(vec![]),
        ) {
            DeviceState::Buffer(b) if on => DeviceState::Host(self.rt.pull(&b)?),
            DeviceState::Host(h) if !on => {
                DeviceState::Buffer(self.rt.upload(&h)?)
            }
            other => other,
        };
        Ok(())
    }

    fn state_buf(&mut self) -> Result<xla::PjRtBuffer> {
        match &self.state {
            DeviceState::Buffer(_) => {
                match std::mem::replace(
                    &mut self.state,
                    DeviceState::Host(vec![]),
                ) {
                    DeviceState::Buffer(b) => Ok(b),
                    _ => unreachable!(),
                }
            }
            DeviceState::Host(h) => {
                self.device_calls += 1; // upload counts as traffic
                self.rt.upload(h)
            }
        }
    }

    fn store_state(&mut self, buf: xla::PjRtBuffer) -> Result<()> {
        if self.hostloop {
            self.state = DeviceState::Host(self.rt.pull(&buf)?);
        } else {
            self.state = DeviceState::Buffer(buf);
        }
        Ok(())
    }

    /// Run one round of the named executable (no extra inputs).
    pub fn round(&mut self, exec_name: &str) -> Result<()> {
        let sb = self.state_buf()?;
        let out = self.rt.run(exec_name, Some(&sb), &[])?;
        self.device_calls += 1;
        self.rounds_run += 1;
        self.store_state(out)
    }

    /// Run one fused multi-round call of a `*_multi` executable: up to
    /// `rounds` draft-verify rounds per dispatch (round packing,
    /// DESIGN.md §9.6). The device exits the fused loop early once the
    /// sequence finishes, so over-asking costs nothing; the one-float
    /// budget buffer is cached and reuploaded only when `rounds` changes.
    pub fn round_packed(&mut self, exec_name: &str, rounds: usize) -> Result<()> {
        let rounds = rounds.max(1);
        let reuse = matches!(&self.pack_buf, Some((v, _)) if *v == rounds);
        if !reuse {
            let buf = self.rt.upload(&[rounds as f32])?;
            self.device_calls += 1;
            self.pack_buf = Some((rounds, buf));
        }
        let sb = self.state_buf()?;
        let out = {
            let (_, pack_buf) =
                self.pack_buf.as_ref().expect("pack buffer present");
            self.rt.run(exec_name, Some(&sb), &[pack_buf])?
        };
        self.device_calls += 1;
        self.rounds_run += rounds as u64;
        self.store_state(out)
    }

    /// Run one `verify_ext_round` with host-provided draft tokens. The
    /// staging vector is preallocated once and the device buffer is
    /// reuploaded only when the draft contents actually changed (empty
    /// and repeated drafts ride the previous upload for free).
    pub fn round_ext(&mut self, drafts: &[u32]) -> Result<()> {
        let k_max = self.rt.layout().konst("k_max");
        if self.ext_staging.len() != k_max + 1 {
            self.ext_staging = vec![0f32; k_max + 1];
            self.ext_buf = None;
        }
        let n = drafts.len().min(k_max);
        let mut changed = self.ext_buf.is_none();
        let (len_slot, body) =
            self.ext_staging.split_first_mut().expect("staging nonempty");
        if *len_slot != n as f32 {
            *len_slot = n as f32;
            changed = true;
        }
        for (i, slot) in body.iter_mut().enumerate() {
            let v = if i < n { drafts[i] as f32 } else { 0.0 };
            if *slot != v {
                *slot = v;
                changed = true;
            }
        }
        if changed {
            self.ext_buf = Some(self.rt.upload(&self.ext_staging)?);
            self.device_calls += 1;
        }
        let sb = self.state_buf()?;
        let out = {
            let ext_buf = self.ext_buf.as_ref().expect("ext buffer present");
            self.rt.run("verify_ext_round", Some(&sb), &[ext_buf])?
        };
        self.device_calls += 1;
        self.rounds_run += 1;
        self.store_state(out)
    }

    /// Pull the cheap per-round snapshot (scalars + out ring).
    pub fn extract(&mut self) -> Result<Snapshot> {
        let sb = self.state_buf()?;
        let out = self.rt.run("extract", Some(&sb), &[])?;
        self.device_calls += 1;
        let raw = self.rt.pull(&out)?;
        // state buffer was consumed as an arg; put it back
        self.state = DeviceState::Buffer(sb);
        if self.hostloop {
            let b = match std::mem::replace(
                &mut self.state,
                DeviceState::Host(vec![]),
            ) {
                DeviceState::Buffer(b) => b,
                _ => unreachable!(),
            };
            self.state = DeviceState::Host(self.rt.pull(&b)?);
        }
        Snapshot::decode(self.rt.layout(), &raw)
    }

    /// Pull the full flat state vector to host — the prefix-cache
    /// snapshot (DESIGN.md §8). One literal transfer, no device program;
    /// the session keeps decoding from the same buffer afterwards.
    pub fn export_state(&mut self) -> Result<Vec<f32>> {
        match &self.state {
            DeviceState::Buffer(b) => self.rt.pull(b),
            DeviceState::Host(h) => Ok(h.clone()),
        }
    }

    /// Pull the probe ring (figures 1 & 4).
    pub fn extract_probe(&mut self) -> Result<ProbeDump> {
        let sb = self.state_buf()?;
        let out = self.rt.run("extract_probe", Some(&sb), &[])?;
        self.device_calls += 1;
        let raw = self.rt.pull(&out)?;
        self.state = DeviceState::Buffer(sb);
        ProbeDump::decode(self.rt.layout(), &raw)
    }
}

/// A cross-sequence batched decode (DESIGN.md §9.5): `batch_max` stacked
/// flat states stepped by one `*_batch` dispatch per round, so B
/// independent sequences draft-and-verify for the price of one device
/// call. Sequences join at round boundaries via the `batch_join` device
/// splice (device-to-device; the only host traffic is a one-float slot
/// index) and leave by finishing — the programs whole-lane mask a
/// finished lane, which then idles bit-frozen until a new sequence
/// reuses its slot. Per-lane knobs (policy triple, method slots, temp,
/// seed, `rounds_per_call`) ride in each lane's own scalars, stamped by
/// that lane's prefill, so mixed configs share a dispatch; only the
/// method *family* (the program identity) must match across lanes.
pub struct BatchSession<'a> {
    rt: &'a Runtime,
    /// Stacked `[batch_max * state_len]` device state.
    state: xla::PjRtBuffer,
    /// Lane count (the layout's `batch_max` constant).
    pub batch_max: usize,
    /// Cached per-lane `pack` argument of the last
    /// [`BatchSession::round_packed`] call (reuploaded only on change).
    pack_buf: Option<(Vec<f32>, xla::PjRtBuffer)>,
    /// Staging for the per-lane `verify_ext_batch` draft blocks.
    ext_staging: Vec<f32>,
    ext_buf: Option<xla::PjRtBuffer>,
    /// Batched round dispatches issued (each steps every live lane; a
    /// fused `*_batch_multi` call still counts once).
    pub rounds_run: u64,
    /// Device executions + buffer uploads this session issued.
    pub device_calls: u64,
}

impl<'a> BatchSession<'a> {
    /// Splice one prefilled solo session into `slot` (device-to-device
    /// `batch_join`). The lane's own cfg scalars ride in with its state,
    /// so per-lane policy/method/temperature/seed/`rounds_per_call` all
    /// come from the joined request. The caller should read the lane
    /// session's `device_calls` (its prefill traffic) before dropping it.
    pub fn join(&mut self, lane: &mut Session<'a>, slot: usize) -> Result<()> {
        if slot >= self.batch_max {
            bail!("slot {slot} out of range (batch_max {})", self.batch_max);
        }
        let lane_buf = lane.state_buf()?;
        let slot_buf = self.rt.upload(&[slot as f32])?;
        let out = self
            .rt
            .run("batch_join", Some(&self.state), &[&lane_buf, &slot_buf])?;
        self.state = out;
        self.device_calls += 2;
        Ok(())
    }

    /// Splice a host-provided flat lane state into `slot`. Used to
    /// retire a lane whose device `finished` flag never set (cancel,
    /// round-cap overrun): splicing a zeroed `finished = 1` lane over it
    /// re-masks the slot. Costs one state-sized upload, so it is the
    /// exception path; normal leaves are free (the lane finishes and the
    /// programs mask it).
    pub fn join_host(&mut self, lane: &[f32], slot: usize) -> Result<()> {
        if slot >= self.batch_max {
            bail!("slot {slot} out of range (batch_max {})", self.batch_max);
        }
        if lane.len() != self.rt.layout().state_len {
            bail!(
                "lane state length {} != layout state_len {}",
                lane.len(),
                self.rt.layout().state_len
            );
        }
        let lane_buf = self.rt.upload(lane)?;
        let slot_buf = self.rt.upload(&[slot as f32])?;
        let out = self
            .rt
            .run("batch_join", Some(&self.state), &[&lane_buf, &slot_buf])?;
        self.state = out;
        self.device_calls += 3;
        Ok(())
    }

    /// One batched round of the named `*_batch` executable: every
    /// unfinished lane drafts-and-verifies, finished and empty lanes are
    /// masked no-ops.
    pub fn round(&mut self, exec_name: &str) -> Result<()> {
        let out = self.rt.run(exec_name, Some(&self.state), &[])?;
        self.state = out;
        self.device_calls += 1;
        self.rounds_run += 1;
        Ok(())
    }

    /// One batched fused multi-round call (`*_batch_multi`, §9.5 × §9.6)
    /// with a per-lane round budget: the device loops while any lane has
    /// budget left and is unfinished, masking lanes whose budget ran out.
    /// The per-lane budget buffer is cached and reuploaded only when the
    /// budgets change (steady-state packing costs no upload).
    pub fn round_packed(
        &mut self,
        exec_name: &str,
        packs: &[usize],
    ) -> Result<()> {
        if packs.len() != self.batch_max {
            bail!(
                "pack vector length {} != batch_max {}",
                packs.len(),
                self.batch_max
            );
        }
        let vals: Vec<f32> = packs.iter().map(|&p| p.max(1) as f32).collect();
        let reuse = matches!(&self.pack_buf, Some((v, _)) if *v == vals);
        if !reuse {
            let buf = self.rt.upload(&vals)?;
            self.device_calls += 1;
            self.pack_buf = Some((vals, buf));
        }
        let out = {
            let (_, pack_buf) =
                self.pack_buf.as_ref().expect("pack buffer present");
            self.rt.run(exec_name, Some(&self.state), &[pack_buf])?
        };
        self.state = out;
        self.device_calls += 1;
        self.rounds_run += 1;
        Ok(())
    }

    /// One batched `verify_ext_batch` round with per-lane host draft
    /// blocks (`[len, tok...]`, `k_max + 1` floats per lane). Lanes
    /// without a live host-drafted request pass an empty draft (their
    /// finished mask makes the AR fallback a no-op anyway). As in the
    /// solo path, the staging buffer is reuploaded only when some lane's
    /// drafts actually changed.
    pub fn round_ext(&mut self, drafts: &[Vec<u32>]) -> Result<()> {
        if drafts.len() != self.batch_max {
            bail!(
                "draft vector count {} != batch_max {}",
                drafts.len(),
                self.batch_max
            );
        }
        let k_max = self.rt.layout().konst("k_max");
        let w = k_max + 1;
        if self.ext_staging.len() != self.batch_max * w {
            self.ext_staging = vec![0f32; self.batch_max * w];
            self.ext_buf = None;
        }
        let mut changed = self.ext_buf.is_none();
        for (lane, d) in drafts.iter().enumerate() {
            let n = d.len().min(k_max);
            let block = &mut self.ext_staging[lane * w..(lane + 1) * w];
            if block[0] != n as f32 {
                block[0] = n as f32;
                changed = true;
            }
            for (i, slot) in block[1..].iter_mut().enumerate() {
                let v = if i < n { d[i] as f32 } else { 0.0 };
                if *slot != v {
                    *slot = v;
                    changed = true;
                }
            }
        }
        if changed {
            self.ext_buf = Some(self.rt.upload(&self.ext_staging)?);
            self.device_calls += 1;
        }
        let out = {
            let ext_buf = self.ext_buf.as_ref().expect("ext buffer present");
            self.rt.run("verify_ext_batch", Some(&self.state), &[ext_buf])?
        };
        self.state = out;
        self.device_calls += 1;
        self.rounds_run += 1;
        Ok(())
    }

    /// Pull every lane's cheap snapshot in one `extract_batch` dispatch
    /// (scalars + out ring per lane, decoded per lane).
    pub fn extract_all(&mut self) -> Result<Vec<Snapshot>> {
        let out = self.rt.run("extract_batch", Some(&self.state), &[])?;
        self.device_calls += 1;
        let raw = self.rt.pull(&out)?;
        let lay = self.rt.layout();
        let w = lay.extract_len;
        if raw.len() != self.batch_max * w {
            bail!(
                "extract_batch length mismatch: got {}, want {}",
                raw.len(),
                self.batch_max * w
            );
        }
        (0..self.batch_max)
            .map(|lane| Snapshot::decode(lay, &raw[lane * w..(lane + 1) * w]))
            .collect()
    }

    /// Pull one lane's full flat state to host (`batch_slot` + literal
    /// transfer) — the prefix-cache snapshot of a batched lane.
    pub fn export_slot(&mut self, slot: usize) -> Result<Vec<f32>> {
        if slot >= self.batch_max {
            bail!("slot {slot} out of range (batch_max {})", self.batch_max);
        }
        let slot_buf = self.rt.upload(&[slot as f32])?;
        let out =
            self.rt.run("batch_slot", Some(&self.state), &[&slot_buf])?;
        self.device_calls += 2;
        self.rt.pull(&out)
    }
}
