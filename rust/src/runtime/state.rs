//! Rust mirror of the flat f32 state ABI (`python/compile/state_spec.py`).
//!
//! The layout is *loaded* from `artifacts/state_layout.json` rather than
//! hard-coded, and the scalar names this module relies on are validated at
//! load time, so python-side layout changes fail fast instead of silently
//! misreading offsets.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;
use crate::verify::AcceptFlag;

/// Scalar slots the rust side reads/writes (names must exist in the JSON).
/// `policy_id`/`p0`/`p1` carry the [`crate::verify::VerifyPolicy`] slot
/// triple (one HLO artifact covers every verification policy).
pub const REQUIRED_SCALARS: &[&str] = &[
    "pos", "out_len", "finished", "temp", "policy_id", "p0", "p1", "kdraft",
    "max_new", "eos", "beam", "branch", "probe_on", "probe_len", "rounds",
    "committed", "target_calls", "draft_steps", "exact_accepts",
    "relaxed_accepts", "rejects", "bonus", "prompt_len", "last_accept",
    "greedy", "seed", "rng",
];

#[derive(Debug, Clone)]
pub struct Section {
    pub offset: usize,
    pub size: usize,
    pub shape: Vec<usize>,
}

/// Parsed state layout + ABI constants.
#[derive(Debug, Clone)]
pub struct Layout {
    pub state_len: usize,
    pub extract_len: usize,
    pub extract_probe_len: usize,
    pub n_scalars: usize,
    pub scalars: BTreeMap<String, usize>,
    pub cfg: BTreeMap<String, usize>,
    pub sections: BTreeMap<String, Section>,
    pub consts: BTreeMap<String, usize>,
    pub hash: String,
}

impl Layout {
    pub fn from_json(doc: &Value) -> Result<Layout> {
        let num = |v: &Value, k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("state_layout.json: missing {k}"))
        };
        let mut scalars = BTreeMap::new();
        for (k, v) in doc
            .get("scalars")
            .and_then(|v| v.as_obj())
            .context("scalars")?
        {
            scalars.insert(k.clone(), v.as_usize().context("scalar idx")?);
        }
        let mut cfg = BTreeMap::new();
        for (k, v) in doc.get("cfg").and_then(|v| v.as_obj()).context("cfg")? {
            cfg.insert(k.clone(), v.as_usize().context("cfg idx")?);
        }
        let mut sections = BTreeMap::new();
        for (k, v) in doc
            .get("sections")
            .and_then(|v| v.as_obj())
            .context("sections")?
        {
            let shape = v
                .get("shape")
                .and_then(|s| s.as_arr())
                .context("shape")?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            sections.insert(
                k.clone(),
                Section {
                    offset: num(v, "offset")?,
                    size: num(v, "size")?,
                    shape,
                },
            );
        }
        let mut consts = BTreeMap::new();
        for (k, v) in doc
            .get("consts")
            .and_then(|v| v.as_obj())
            .context("consts")?
        {
            consts.insert(k.clone(), v.as_usize().context("const")?);
        }
        let lay = Layout {
            state_len: num(doc, "state_len")?,
            extract_len: num(doc, "extract_len")?,
            extract_probe_len: num(doc, "extract_probe_len")?,
            n_scalars: num(doc, "n_scalars")?,
            scalars,
            cfg,
            sections,
            consts,
            hash: doc
                .get("hash")
                .and_then(|h| h.as_str())
                .unwrap_or("")
                .to_string(),
        };
        for name in REQUIRED_SCALARS {
            if !lay.scalars.contains_key(*name) {
                bail!("state_layout.json lacks scalar '{name}'");
            }
        }
        Ok(lay)
    }

    pub fn scalar(&self, name: &str) -> usize {
        self.scalars[name]
    }

    pub fn konst(&self, name: &str) -> usize {
        self.consts[name]
    }

    /// Optional ABI constant — `None` when the artifact set predates the
    /// constant (layouts are loaded, not hard-coded, so new consts must
    /// degrade gracefully against old artifact dirs).
    pub fn konst_opt(&self, name: &str) -> Option<usize> {
        self.consts.get(name).copied()
    }

    /// Max sequences per batched dispatch (the `*_batch` programs,
    /// DESIGN.md §9.5), or 0 when the artifact set predates batching.
    pub fn batch_max(&self) -> usize {
        self.konst_opt("batch_max").unwrap_or(0)
    }
}

/// Per-request scalars zeroed when a prefix-cache snapshot is resumed as
/// a new request (DESIGN.md §8): output bookkeeping, the RNG counter and
/// every accounting counter restart from zero, exactly as a cold
/// `prefill` leaves them. The device-progress scalars (`pos`,
/// `eagle_pos`, `sps_pos`) and every KV/feature section are what the
/// cache exists to keep, so they are *not* listed here.
pub const RESUME_RESET_SCALARS: &[&str] = &[
    "out_len",
    "finished",
    "rng",
    "probe_len",
    "rounds",
    "committed",
    "target_calls",
    "draft_steps",
    "exact_accepts",
    "relaxed_accepts",
    "rejects",
    "bonus",
    "last_accept",
];

/// Restamp a cached state snapshot for reuse as a fresh request: copy
/// every cfg-slot value onto its state scalar (the host mirror of the
/// cfg→scalar copy the device `prefill` performs, so the snapshot runs
/// under the *new* request's temperature/policy/method/seed), then zero
/// the [`RESUME_RESET_SCALARS`]. Everything else — `pos`/`eagle_pos`/
/// `sps_pos` and all KV/feature/token sections — is left untouched.
pub fn restamp_resumed(lay: &Layout, state: &mut [f32], cfg: &[f32]) {
    for (name, &ci) in &lay.cfg {
        state[lay.scalar(name)] = cfg[ci];
    }
    for name in RESUME_RESET_SCALARS {
        state[lay.scalar(name)] = 0.0;
    }
}

/// Decoded `extract()` output: the per-round snapshot the engine polls.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub pos: usize,
    pub out_len: usize,
    pub finished: bool,
    pub rounds: f64,
    pub committed: f64,
    pub target_calls: f64,
    pub draft_steps: f64,
    pub exact_accepts: f64,
    pub relaxed_accepts: f64,
    pub rejects: f64,
    pub bonus: f64,
    pub last_accept: f64,
    pub tokens: Vec<u32>,
}

impl Snapshot {
    pub fn decode(lay: &Layout, raw: &[f32]) -> Result<Snapshot> {
        if raw.len() != lay.extract_len {
            bail!(
                "extract length mismatch: got {}, want {}",
                raw.len(),
                lay.extract_len
            );
        }
        let s = |name: &str| raw[lay.scalar(name)] as f64;
        let out_len = s("out_len") as usize;
        let out = &raw[lay.n_scalars..];
        let tokens = out
            .iter()
            .take(out_len)
            .map(|&x| x as u32)
            .collect::<Vec<_>>();
        Ok(Snapshot {
            pos: s("pos") as usize,
            out_len,
            finished: s("finished") > 0.5,
            rounds: s("rounds"),
            committed: s("committed"),
            target_calls: s("target_calls"),
            draft_steps: s("draft_steps"),
            exact_accepts: s("exact_accepts"),
            relaxed_accepts: s("relaxed_accepts"),
            rejects: s("rejects"),
            bonus: s("bonus"),
            last_accept: s("last_accept"),
            tokens,
        })
    }

    /// Average committed tokens per draft-verify cycle (the paper's tau).
    pub fn tau(&self) -> f64 {
        if self.rounds > 0.0 {
            self.committed / self.rounds
        } else {
            0.0
        }
    }
}

/// Decoded `extract_probe()` output — (z1, z2, flag) rows for figures 1/4.
#[derive(Debug, Clone, Default)]
pub struct ProbeDump {
    pub entries: Vec<ProbeEntry>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProbeEntry {
    pub z1: f32,
    pub z2: f32,
    /// accept-flag taxonomy: rejected / exact / policy-relaxed accept
    pub flag: AcceptFlag,
}

impl ProbeDump {
    pub fn decode(lay: &Layout, raw: &[f32]) -> Result<ProbeDump> {
        if raw.len() != lay.extract_probe_len {
            bail!("extract_probe length mismatch: {}", raw.len());
        }
        let n = (raw[lay.scalar("probe_len")] as usize)
            .min(lay.konst("probe_max"));
        let w = lay.konst("probe_w");
        let body = &raw[lay.n_scalars..];
        let mut entries = Vec::with_capacity(n);
        for i in 0..n {
            entries.push(ProbeEntry {
                z1: body[i * w],
                z2: body[i * w + 1],
                flag: AcceptFlag::from_f32(body[i * w + 2]),
            });
        }
        Ok(ProbeDump { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_layout() -> Layout {
        let json = r#"{
          "state_len": 200, "extract_len": 72, "extract_probe_len": 112,
          "n_scalars": 64,
          "scalars": {"pos":0,"eagle_pos":1,"sps_pos":2,"out_len":3,
            "finished":4,"rng":5,"temp":6,"p0":7,"policy_id":8,"kdraft":9,
            "max_new":10,"eos":11,"beam":12,"branch":13,"probe_on":14,
            "probe_len":15,"rounds":16,"committed":17,"target_calls":18,
            "draft_steps":19,"exact_accepts":20,"relaxed_accepts":21,
            "rejects":22,"bonus":23,"prompt_len":24,"last_accept":25,
            "greedy":26,"seed":27,"p1":28},
          "cfg": {"temp":0},
          "sections": {"out": {"offset":64, "size":8, "shape":[8]}},
          "consts": {"probe_max":16, "probe_w":3},
          "hash": "abc"
        }"#;
        Layout::from_json(&Value::parse(json).unwrap()).unwrap()
    }

    #[test]
    fn snapshot_decodes() {
        let lay = demo_layout();
        let mut raw = vec![0f32; lay.extract_len];
        raw[lay.scalar("pos")] = 12.0;
        raw[lay.scalar("out_len")] = 3.0;
        raw[lay.scalar("finished")] = 1.0;
        raw[lay.scalar("rounds")] = 4.0;
        raw[lay.scalar("committed")] = 10.0;
        raw[64] = 30.0;
        raw[65] = 31.0;
        raw[66] = 2.0;
        let snap = Snapshot::decode(&lay, &raw).unwrap();
        assert_eq!(snap.tokens, vec![30, 31, 2]);
        assert!(snap.finished);
        assert!((snap.tau() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn snapshot_rejects_bad_len() {
        let lay = demo_layout();
        assert!(Snapshot::decode(&lay, &[0.0; 3]).is_err());
    }

    #[test]
    fn probe_decodes() {
        let lay = demo_layout();
        let mut raw = vec![0f32; lay.extract_probe_len];
        raw[lay.scalar("probe_len")] = 2.0;
        raw[64] = 5.0;
        raw[65] = 4.5;
        raw[66] = 2.0;
        raw[67] = 3.0;
        raw[68] = 1.0;
        raw[69] = 0.0;
        let p = ProbeDump::decode(&lay, &raw).unwrap();
        assert_eq!(p.entries.len(), 2);
        assert_eq!(p.entries[0].flag, AcceptFlag::Relaxed);
        assert_eq!(
            p.entries[1],
            ProbeEntry { z1: 3.0, z2: 1.0, flag: AcceptFlag::Reject }
        );
    }

    #[test]
    fn restamp_resumed_keeps_progress_and_sections() {
        // a layout whose cfg maps several names (the demo layout above
        // only carries temp) — mirrors the real CFG table shape
        let json = r#"{
          "state_len": 200, "extract_len": 72, "extract_probe_len": 112,
          "n_scalars": 64,
          "scalars": {"pos":0,"eagle_pos":1,"sps_pos":2,"out_len":3,
            "finished":4,"rng":5,"temp":6,"p0":7,"policy_id":8,"kdraft":9,
            "max_new":10,"eos":11,"beam":12,"branch":13,"probe_on":14,
            "probe_len":15,"rounds":16,"committed":17,"target_calls":18,
            "draft_steps":19,"exact_accepts":20,"relaxed_accepts":21,
            "rejects":22,"bonus":23,"prompt_len":24,"last_accept":25,
            "greedy":26,"seed":27,"p1":28},
          "cfg": {"temp":0,"p0":1,"policy_id":2,"kdraft":3,"max_new":4,
            "seed":5,"prompt_len":6,"p1":7},
          "sections": {"out": {"offset":64, "size":8, "shape":[8]}},
          "consts": {"probe_max":16, "probe_w":3},
          "hash": "abc"
        }"#;
        let lay = Layout::from_json(&Value::parse(json).unwrap()).unwrap();
        let mut state = vec![0.5f32; 200];
        state[lay.scalar("pos")] = 17.0;
        state[lay.scalar("eagle_pos")] = 17.0;
        state[lay.scalar("sps_pos")] = 16.0;
        state[lay.scalar("rounds")] = 9.0;
        state[lay.scalar("out_len")] = 5.0;
        state[lay.scalar("finished")] = 1.0;
        let cfg = [0.7f32, 0.9, 1.0, 7.0, 32.0, 11.0, 21.0, 0.25];
        restamp_resumed(&lay, &mut state, &cfg);
        // progress scalars and sections survive exactly
        assert_eq!(state[lay.scalar("pos")], 17.0);
        assert_eq!(state[lay.scalar("eagle_pos")], 17.0);
        assert_eq!(state[lay.scalar("sps_pos")], 16.0);
        assert_eq!(state[64], 0.5, "section content must be untouched");
        // cfg values land on their scalar slots
        assert_eq!(state[lay.scalar("temp")], 0.7);
        assert_eq!(state[lay.scalar("policy_id")], 1.0);
        assert_eq!(state[lay.scalar("p1")], 0.25);
        assert_eq!(state[lay.scalar("prompt_len")], 21.0);
        assert_eq!(state[lay.scalar("seed")], 11.0);
        // per-request counters restart from zero
        for name in RESUME_RESET_SCALARS {
            assert_eq!(state[lay.scalar(name)], 0.0, "{name}");
        }
    }

    #[test]
    fn batch_max_defaults_to_zero_on_old_layouts() {
        // the demo layout's consts predate batching
        let lay = demo_layout();
        assert_eq!(lay.konst_opt("batch_max"), None);
        assert_eq!(lay.batch_max(), 0);
        assert_eq!(lay.konst_opt("probe_w"), Some(3));
    }

    #[test]
    fn missing_scalar_fails() {
        let json = r#"{"state_len":1,"extract_len":1,"extract_probe_len":1,
          "n_scalars":1,"scalars":{"pos":0},"cfg":{},"sections":{},
          "consts":{},"hash":""}"#;
        assert!(Layout::from_json(&Value::parse(json).unwrap()).is_err());
    }
}
