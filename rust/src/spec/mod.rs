//! Speculative-drafting subsystem — descriptors, registry, drafters.
//!
//! The mirror image of [`crate::verify`] (DESIGN.md §7): where PR 1 made
//! the *accept rule* a pluggable [`crate::verify::VerifyPolicy`], this
//! module makes the *drafting side* a pluggable [`SpecMethod`] descriptor
//! carrying every per-method knob, with one canonical representation
//! across
//!
//! * the CLI (`--method eagle_tree:k=7,beam=2,branch=2`, see
//!   [`SpecMethod::parse`]),
//! * the line-JSON protocol (`"method": {"eagle_tree": {"k": 7}}` plus
//!   the legacy bare string `"method": "eagle_tree"` and the flat
//!   `"k"`/`"beam"`/`"branch"` wire knobs, see
//!   [`SpecMethod::from_request`]),
//! * the device config-slot lowering `(kdraft, beam, branch)` consumed by
//!   the round programs (see [`SpecMethod::encode_slots`] and
//!   `python/compile/state_spec.py` — the method *identity* lowers to the
//!   executable name, [`SpecMethod::exec_name`], since each method is a
//!   separate AOT'd program), and
//! * a [`DraftSource`] built from the descriptor
//!   ([`SpecMethod::draft_source`]) that unifies device-coupled drafting
//!   (SpS LM, EAGLE head, Medusa heads run inside the lowered programs)
//!   with host-side retrieval drafting (PLD, Lookahead feed
//!   `verify_ext_round`).
//!
//! Every method is registered once in the [`METHODS`] table; the engine,
//! the request layer, the CLI and the bench sweeps iterate that table
//! instead of re-listing variants. Adding a method = one enum variant +
//! one table row (+ its round program).

#![warn(missing_docs)]

pub mod lookahead;
pub mod pld;

pub use lookahead::LookaheadDrafter;
pub use pld::PldDrafter;

use crate::util::json::Value;

/// A host drafter proposes up to `k` continuation tokens given the full
/// token history (prompt ++ generated).
pub trait HostDrafter {
    /// Propose up to `k` draft tokens continuing `history`.
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32>;

    /// Observe newly committed tokens (for pool-building drafters).
    fn observe(&mut self, _history: &[u32]) {}
}

/// One request's drafting engine, built from a [`SpecMethod`] descriptor
/// (see [`SpecMethod::draft_source`]). Unifies the two drafting shapes of
/// the paper's Table 1: model-based drafters that run *inside* the AOT'd
/// device program, and host-side retrieval drafters that propose tokens
/// for `verify_ext_round`.
pub trait DraftSource: Send {
    /// Name of the device executable driven each round.
    fn exec_name(&self) -> &'static str;

    /// Host-proposed draft tokens for the next round, or `None` when
    /// drafting happens inside the device program itself. An empty vec
    /// degenerates to one AR step on device.
    fn next_drafts(&mut self, history: &[u32]) -> Option<Vec<u32>>;
}

/// Device-coupled drafting: the round program drafts internally.
struct DeviceDraft {
    exec: &'static str,
}

impl DraftSource for DeviceDraft {
    fn exec_name(&self) -> &'static str {
        self.exec
    }

    fn next_drafts(&mut self, _history: &[u32]) -> Option<Vec<u32>> {
        None
    }
}

/// Host drafting: a [`HostDrafter`] proposes up to `k` tokens per round,
/// verified by `verify_ext_round`.
struct HostDraft {
    exec: &'static str,
    k: usize,
    drafter: Box<dyn HostDrafter + Send>,
}

impl DraftSource for HostDraft {
    fn exec_name(&self) -> &'static str {
        self.exec
    }

    fn next_drafts(&mut self, history: &[u32]) -> Option<Vec<u32>> {
        self.drafter.observe(history);
        Some(self.drafter.draft(history, self.k))
    }
}

/// A speculative-decoding method descriptor: the method family plus every
/// per-method drafting knob (the paper's Table 1 lineup). The old flat
/// `Method` enum + loose `GenParams { k, beam, branch }` knobs collapsed
/// into this one value.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpecMethod {
    /// Vanilla autoregressive decoding — the 1.00× baseline (no τ).
    Ar,
    /// Standard speculative sampling: independent draft LM, chain of `k`
    /// tokens per round (Leviathan et al.).
    Sps {
        /// Chain draft length per round (device clamps to `K_MAX`).
        k: usize,
    },
    /// EAGLE-style feature-conditioned head, chain decoding — the beam-1
    /// degenerate tree.
    EagleChain {
        /// Chain depth per round (device clamps to `DEPTH_MAX`).
        depth: usize,
    },
    /// EAGLE-style feature-conditioned head over a static beam tree.
    EagleTree {
        /// Tree depth per round (device clamps to `DEPTH_MAX`).
        depth: usize,
        /// Beam width (device clamps to `B_MAX`).
        beam: usize,
        /// Children per expanded node (device clamps to `C_MAX`).
        branch: usize,
    },
    /// Medusa-style multi-head static tree.
    Medusa {
        /// Tree depth (device clamps to the head count).
        depth: usize,
    },
    /// Prompt Lookup Decoding: host n-gram match over the history.
    Pld {
        /// Shortest n-gram worth matching.
        min_ngram: usize,
        /// Longest n-gram to try (longest-first).
        max_ngram: usize,
        /// Max draft tokens proposed per round.
        k: usize,
    },
    /// Simplified Lookahead: host n-gram pool filled from the observed
    /// history (DESIGN.md §9.4).
    Lookahead {
        /// N-gram order of the pool keys.
        n: usize,
        /// Continuation length stored per key.
        g: usize,
        /// Pool capacity (inserts stop when full).
        cap: usize,
        /// Max draft tokens proposed per round.
        k: usize,
    },
}

impl Default for SpecMethod {
    /// The paper's headline configuration: EAGLE tree, K=7, beam 2.
    fn default() -> Self {
        SpecMethod::EagleTree { depth: 7, beam: 2, branch: 2 }
    }
}

/// One registry row: everything the stack needs to know about a method
/// family without matching on the enum.
pub struct MethodInfo {
    /// Canonical short name — the metrics label and bench table key.
    pub name: &'static str,
    /// Row label used by the paper-table benches.
    pub paper_label: &'static str,
    /// Accepted CLI/JSON spelling aliases (lowercase).
    pub aliases: &'static [&'static str],
    /// The family's default descriptor (all knobs at paper defaults).
    pub default: SpecMethod,
    /// One-line description for usage text.
    pub summary: &'static str,
}

/// The single method registry: `engine`, `coordinator/request`, `main`
/// and `bench` iterate this table instead of re-listing enum variants.
pub const METHODS: &[MethodInfo] = &[
    MethodInfo {
        name: "ar",
        paper_label: "Baseline (AR)",
        aliases: &["baseline", "vanilla"],
        default: SpecMethod::Ar,
        summary: "vanilla autoregressive decoding (1.00x baseline)",
    },
    MethodInfo {
        name: "sps",
        paper_label: "SpS",
        aliases: &["spd"],
        default: SpecMethod::Sps { k: 7 },
        summary: "independent draft LM, chain speculative sampling",
    },
    MethodInfo {
        name: "eagle_chain",
        paper_label: "EAGLE (chain)",
        aliases: &["eagle", "eagle-chain"],
        default: SpecMethod::EagleChain { depth: 7 },
        summary: "feature-conditioned EAGLE head, chain decoding",
    },
    MethodInfo {
        name: "eagle_tree",
        paper_label: "EAGLE-3 (tree)",
        aliases: &["eagle-tree", "eagle3", "tree"],
        default: SpecMethod::EagleTree { depth: 7, beam: 2, branch: 2 },
        summary: "feature-conditioned EAGLE head over a static beam tree",
    },
    MethodInfo {
        name: "medusa",
        paper_label: "Medusa",
        aliases: &[],
        default: SpecMethod::Medusa { depth: 4 },
        summary: "multi-head static candidate tree",
    },
    MethodInfo {
        name: "pld",
        paper_label: "PLD",
        aliases: &[],
        default: SpecMethod::Pld { min_ngram: 2, max_ngram: 4, k: 7 },
        summary: "host prompt-lookup n-gram drafting",
    },
    MethodInfo {
        name: "lookahead",
        paper_label: "Lookahead",
        aliases: &["la"],
        default: SpecMethod::Lookahead { n: 3, g: 8, cap: 4096, k: 7 },
        summary: "host n-gram pool drafting (simplified lookahead)",
    },
];

/// Resolve a lowercase family name or alias to its registry row.
fn lookup(name: &str) -> Option<&'static MethodInfo> {
    METHODS
        .iter()
        .find(|m| m.name == name || m.aliases.contains(&name))
}

impl SpecMethod {
    /// Parse the CLI string form: `family[:knob=v,knob=v,...]`, e.g.
    /// `eagle_tree:k=7,beam=2,branch=2`, `pld:min=3,max=5`, `sps:k=6`,
    /// or a bare family name / alias (`eagle3`, `la`) for the defaults.
    ///
    /// Knobs per family: `sps: k`; `eagle_chain: k|depth`;
    /// `eagle_tree: k|depth, beam, branch`; `medusa: k|depth`;
    /// `pld: min|min_ngram, max|max_ngram, k`;
    /// `lookahead: n, g, cap, k`; `ar` takes none.
    pub fn parse(s: &str) -> Option<SpecMethod> {
        let s = s.trim().to_ascii_lowercase();
        let (head, args) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s.as_str(), None),
        };
        let mut m = lookup(head)?.default;
        if let Some(args) = args {
            for pair in args.split(',') {
                let (key, val) = pair.trim().split_once('=')?;
                let val: usize = val.trim().parse().ok()?;
                m = m.set_knob(key.trim(), val)?;
            }
        }
        if m.validate().is_err() {
            return None;
        }
        Some(m)
    }

    /// Parse a comma-separated sweep list, e.g.
    /// `sps:k=6,eagle_tree:k=7,beam=4,pld`. A segment containing `=` but
    /// no `:` is a knob continuation of the previous method (commas do
    /// double duty as list and knob separators).
    pub fn parse_list(s: &str) -> Option<Vec<SpecMethod>> {
        let mut items: Vec<String> = Vec::new();
        for seg in s.split(',') {
            let seg = seg.trim();
            if seg.is_empty() {
                continue;
            }
            if seg.contains('=') && !seg.contains(':') {
                let prev = items.last_mut()?;
                // first knob after a bare family name opens with ':'
                prev.push(if prev.contains(':') { ',' } else { ':' });
                prev.push_str(seg);
            } else {
                items.push(seg.to_string());
            }
        }
        items
            .iter()
            .map(|i| SpecMethod::parse(i))
            .collect::<Option<Vec<_>>>()
            .filter(|v| !v.is_empty())
    }

    /// Apply one parsed `key=value` knob; `None` when the family has no
    /// such knob.
    fn set_knob(self, key: &str, val: usize) -> Option<SpecMethod> {
        use SpecMethod::*;
        Some(match (self, key) {
            (Sps { .. }, "k") => Sps { k: val },
            (EagleChain { .. }, "k" | "depth") => EagleChain { depth: val },
            (EagleTree { beam, branch, .. }, "k" | "depth") => {
                EagleTree { depth: val, beam, branch }
            }
            (EagleTree { depth, branch, .. }, "beam") => {
                EagleTree { depth, beam: val, branch }
            }
            (EagleTree { depth, beam, .. }, "branch") => {
                EagleTree { depth, beam, branch: val }
            }
            (Medusa { .. }, "k" | "depth") => Medusa { depth: val },
            (Pld { max_ngram, k, .. }, "min" | "min_ngram") => {
                Pld { min_ngram: val, max_ngram, k }
            }
            (Pld { min_ngram, k, .. }, "max" | "max_ngram") => {
                Pld { min_ngram, max_ngram: val, k }
            }
            (Pld { min_ngram, max_ngram, .. }, "k") => {
                Pld { min_ngram, max_ngram, k: val }
            }
            (Lookahead { g, cap, k, .. }, "n") => Lookahead { n: val, g, cap, k },
            (Lookahead { n, cap, k, .. }, "g") => Lookahead { n, g: val, cap, k },
            (Lookahead { n, g, k, .. }, "cap") => {
                Lookahead { n, g, cap: val, k }
            }
            (Lookahead { n, g, cap, .. }, "k") => {
                Lookahead { n, g, cap, k: val }
            }
            _ => return None,
        })
    }

    /// Check descriptor invariants (what the drafter constructors assert).
    pub fn validate(&self) -> Result<(), String> {
        use SpecMethod::*;
        let ok = match *self {
            Ar => true,
            Sps { k } => k >= 1,
            EagleChain { depth } => depth >= 1,
            EagleTree { depth, beam, branch } => {
                depth >= 1 && beam >= 1 && branch >= 1
            }
            Medusa { depth } => depth >= 1,
            Pld { min_ngram, max_ngram, k } => {
                min_ngram >= 1 && max_ngram >= min_ngram && k >= 1
            }
            Lookahead { n, g, k, .. } => n >= 1 && g >= 1 && k >= 1,
        };
        if ok {
            Ok(())
        } else {
            Err(format!("invalid {} parameters", self.name()))
        }
    }

    /// Canonical family name (metrics label and bench table key; stable
    /// across knob values).
    pub fn name(&self) -> &'static str {
        match self {
            SpecMethod::Ar => "ar",
            SpecMethod::Sps { .. } => "sps",
            SpecMethod::EagleChain { .. } => "eagle_chain",
            SpecMethod::EagleTree { .. } => "eagle_tree",
            SpecMethod::Medusa { .. } => "medusa",
            SpecMethod::Pld { .. } => "pld",
            SpecMethod::Lookahead { .. } => "lookahead",
        }
    }

    /// This method's registry row.
    pub fn info(&self) -> &'static MethodInfo {
        // every variant has a row; the registry test pins this
        METHODS.iter().find(|m| m.name == self.name()).unwrap()
    }

    /// Full CLI label; `parse(label())` round-trips the descriptor.
    pub fn label(&self) -> String {
        match *self {
            SpecMethod::Ar => "ar".to_string(),
            SpecMethod::Sps { k } => format!("sps:k={k}"),
            SpecMethod::EagleChain { depth } => format!("eagle_chain:k={depth}"),
            SpecMethod::EagleTree { depth, beam, branch } => {
                format!("eagle_tree:k={depth},beam={beam},branch={branch}")
            }
            SpecMethod::Medusa { depth } => format!("medusa:k={depth}"),
            SpecMethod::Pld { min_ngram, max_ngram, k } => {
                format!("pld:min={min_ngram},max={max_ngram},k={k}")
            }
            SpecMethod::Lookahead { n, g, cap, k } => {
                format!("lookahead:n={n},g={g},cap={cap},k={k}")
            }
        }
    }

    /// Does this method use draft-verify rounds (i.e. has a meaningful τ)?
    pub fn is_speculative(&self) -> bool {
        !matches!(self, SpecMethod::Ar)
    }

    /// Default descriptors of every registered family, registry order.
    pub fn all_defaults() -> Vec<SpecMethod> {
        METHODS.iter().map(|m| m.default).collect()
    }

    /// Default descriptors of every speculative family (no `ar`).
    pub fn speculative_defaults() -> Vec<SpecMethod> {
        METHODS
            .iter()
            .map(|m| m.default)
            .filter(|m| m.is_speculative())
            .collect()
    }

    // ----------------------------------------------------- JSON codec ----

    /// Wire form: `"ar"` for the knobless baseline, else a one-key object
    /// like `{"eagle_tree": {"k": 7, "beam": 2, "branch": 2}}`.
    pub fn to_json(&self) -> Value {
        let one = |family: &str, fields: &[(&str, usize)]| -> Value {
            let mut inner = Value::obj();
            for (name, val) in fields {
                inner.set(name, Value::Num(*val as f64));
            }
            let mut o = Value::obj();
            o.set(family, inner);
            o
        };
        match *self {
            SpecMethod::Ar => Value::Str("ar".into()),
            SpecMethod::Sps { k } => one("sps", &[("k", k)]),
            SpecMethod::EagleChain { depth } => {
                one("eagle_chain", &[("k", depth)])
            }
            SpecMethod::EagleTree { depth, beam, branch } => one(
                "eagle_tree",
                &[("k", depth), ("beam", beam), ("branch", branch)],
            ),
            SpecMethod::Medusa { depth } => one("medusa", &[("k", depth)]),
            SpecMethod::Pld { min_ngram, max_ngram, k } => one(
                "pld",
                &[("min_ngram", min_ngram), ("max_ngram", max_ngram), ("k", k)],
            ),
            SpecMethod::Lookahead { n, g, cap, k } => one(
                "lookahead",
                &[("n", n), ("g", g), ("cap", cap), ("k", k)],
            ),
        }
    }

    /// Parse the wire form produced by [`SpecMethod::to_json`]; a JSON
    /// string is treated as the CLI form (so `"eagle_tree:k=7"` and the
    /// legacy bare `"sps"` both work). Object bodies may omit knobs —
    /// missing knobs take the family defaults.
    pub fn from_json(v: &Value) -> Result<SpecMethod, String> {
        if let Some(s) = v.as_str() {
            return SpecMethod::parse(s)
                .ok_or_else(|| format!("unknown method '{s}'"));
        }
        let obj = v
            .as_obj()
            .ok_or("method must be a string or a one-key object")?;
        if obj.len() != 1 {
            return Err("method object must have exactly one key".into());
        }
        let (key, body) = obj.iter().next().unwrap();
        let info = lookup(&key.to_ascii_lowercase())
            .ok_or_else(|| format!("unknown method '{key}'"))?;
        let mut m = info.default;
        let body = body
            .as_obj()
            .ok_or_else(|| format!("method.{key} parameters must be an object"))?;
        for (pk, pv) in body {
            let val = pv
                .as_f64()
                .filter(|f| f.is_finite() && *f >= 0.0 && f.fract() == 0.0)
                .map(|f| f as usize)
                .ok_or_else(|| {
                    format!("method.{key}.{pk} must be a non-negative integer")
                })?;
            m = m.set_knob(pk, val).ok_or_else(|| {
                format!("unknown {} parameter '{pk}'", info.name)
            })?;
        }
        m.validate()?;
        Ok(m)
    }

    /// Resolve the method of one request object: the `"method"` key (a
    /// structured object, a CLI string, or a legacy bare family name);
    /// absent means the default. The legacy flat `"k"` / `"beam"` /
    /// `"branch"` wire knobs then override the descriptor's matching
    /// knobs, so `{"method": "eagle_tree", "k": 7}` and
    /// `{"method": {"eagle_tree": {"k": 7}}}` produce identical params.
    pub fn from_request(v: &Value) -> Result<SpecMethod, String> {
        let base = match v.get("method") {
            Some(m) => SpecMethod::from_json(m)?,
            None => SpecMethod::default(),
        };
        let knob = |name: &str| -> Result<Option<usize>, String> {
            match v.get(name) {
                None => Ok(None),
                Some(x) => x
                    .as_f64()
                    .filter(|f| f.is_finite() && *f >= 0.0)
                    .map(|f| Some(f as usize))
                    .ok_or_else(|| {
                        format!("'{name}' must be a non-negative number")
                    }),
            }
        };
        Ok(base.with_overrides(knob("k")?, knob("beam")?, knob("branch")?))
    }

    /// Apply the legacy flat `--k` / `--beam` / `--branch` knobs onto this
    /// descriptor: `k` maps to the family's primary length knob (chain
    /// length, tree depth, or host draft length), `beam`/`branch` apply to
    /// the tree method only. Knobs a family does not have are ignored, and
    /// values are passed through unvalidated — exactly the leniency of the
    /// pre-descriptor flat `GenParams` fields (unused knobs never reached
    /// the round programs; device-read slots are clamped on device). The
    /// structured forms ([`SpecMethod::parse`] / [`SpecMethod::from_json`])
    /// are strict instead.
    pub fn with_overrides(
        self,
        k: Option<usize>,
        beam: Option<usize>,
        branch: Option<usize>,
    ) -> SpecMethod {
        let mut m = self;
        for (knob, val) in [("k", k), ("beam", beam), ("branch", branch)] {
            if let Some(val) = val {
                m = m.set_knob(knob, val).unwrap_or(m);
            }
        }
        m
    }

    // ------------------------------------------------ device lowering ----

    /// Name of the AOT'd round program this method drives. The method
    /// identity lowers to the executable (each method is a separate HLO
    /// artifact); the knobs lower to config slots
    /// ([`SpecMethod::encode_slots`]).
    pub fn exec_name(&self) -> &'static str {
        match self {
            SpecMethod::Ar => "ar_step",
            SpecMethod::Sps { .. } => "sps_round",
            SpecMethod::EagleChain { .. } | SpecMethod::EagleTree { .. } => {
                "eagle_tree_round"
            }
            SpecMethod::Medusa { .. } => "medusa_round",
            SpecMethod::Pld { .. } | SpecMethod::Lookahead { .. } => {
                "verify_ext_round"
            }
        }
    }

    /// Name of the fused multi-round program (round packing, DESIGN.md
    /// §9.6) that runs up to `rounds_per_call` rounds of this method per
    /// dispatch, or `None` for host-drafted families (PLD / Lookahead
    /// need fresh host drafts every round, so they cannot pack). Callers
    /// must still gate on `Runtime::has_exec` — older artifact sets
    /// predate the `*_multi` variants and fall back to single rounds.
    pub fn multi_exec_name(&self) -> Option<&'static str> {
        match self {
            SpecMethod::Ar => Some("ar_multi"),
            SpecMethod::Sps { .. } => Some("sps_multi"),
            SpecMethod::EagleChain { .. } | SpecMethod::EagleTree { .. } => {
                Some("eagle_tree_multi")
            }
            SpecMethod::Medusa { .. } => Some("medusa_multi"),
            SpecMethod::Pld { .. } | SpecMethod::Lookahead { .. } => None,
        }
    }

    /// Name of the cross-sequence batched round program (batched
    /// decoding, DESIGN.md §9.5) that steps `BATCH_MAX` stacked lanes of
    /// this method in one dispatch. Every family has one: host-drafted
    /// families batch through `verify_ext_batch` with per-lane draft
    /// vectors. Callers must gate on `Runtime::supports_batching` —
    /// artifact sets lowered before §9.5 lack the `*_batch` programs.
    pub fn batch_exec_name(&self) -> &'static str {
        match self {
            SpecMethod::Ar => "ar_batch",
            SpecMethod::Sps { .. } => "sps_batch",
            SpecMethod::EagleChain { .. } | SpecMethod::EagleTree { .. } => {
                "eagle_tree_batch"
            }
            SpecMethod::Medusa { .. } => "medusa_batch",
            SpecMethod::Pld { .. } | SpecMethod::Lookahead { .. } => {
                "verify_ext_batch"
            }
        }
    }

    /// Name of the batched fused multi-round program (§9.5 × §9.6): up to
    /// a per-lane round budget per dispatch across the whole batch, or
    /// `None` for host-drafted families (fresh host drafts are needed
    /// every round, exactly as for [`SpecMethod::multi_exec_name`]).
    pub fn batch_multi_exec_name(&self) -> Option<&'static str> {
        match self {
            SpecMethod::Ar => Some("ar_batch_multi"),
            SpecMethod::Sps { .. } => Some("sps_batch_multi"),
            SpecMethod::EagleChain { .. } | SpecMethod::EagleTree { .. } => {
                Some("eagle_tree_batch_multi")
            }
            SpecMethod::Medusa { .. } => Some("medusa_batch_multi"),
            SpecMethod::Pld { .. } | SpecMethod::Lookahead { .. } => None,
        }
    }

    /// Encode into the `(kdraft, beam, branch)` config-slot triple the
    /// round programs read (see `python/compile/state_spec.py`). Chain
    /// methods lower to the degenerate `beam = branch = 1` tree; host
    /// drafters keep their knobs host-side (the device reads the per-round
    /// `ext` draft count instead of `kdraft`), so they lower the draft
    /// budget only. The device clamps every slot to its static bound.
    pub fn encode_slots(&self) -> [f32; 3] {
        match *self {
            SpecMethod::Ar => [0.0, 1.0, 1.0],
            SpecMethod::Sps { k } => [k as f32, 1.0, 1.0],
            SpecMethod::EagleChain { depth } => [depth as f32, 1.0, 1.0],
            SpecMethod::EagleTree { depth, beam, branch } => {
                [depth as f32, beam as f32, branch as f32]
            }
            SpecMethod::Medusa { depth } => [depth as f32, 1.0, 1.0],
            SpecMethod::Pld { k, .. } => [k as f32, 1.0, 1.0],
            SpecMethod::Lookahead { k, .. } => [k as f32, 1.0, 1.0],
        }
    }

    // -------------------------------------------------------- drafting ---

    /// Build this request's [`DraftSource`] from the descriptor — the one
    /// construction point for host drafters, so per-request knobs like
    /// `pld:min=3,max=5` actually reach the drafter (`SeqRunner` used to
    /// hard-code `::default()` here).
    pub fn draft_source(&self) -> Box<dyn DraftSource> {
        match *self {
            SpecMethod::Pld { min_ngram, max_ngram, k } => Box::new(HostDraft {
                exec: self.exec_name(),
                k,
                drafter: Box::new(PldDrafter::new(min_ngram, max_ngram)),
            }),
            SpecMethod::Lookahead { n, g, cap, k } => Box::new(HostDraft {
                exec: self.exec_name(),
                k,
                drafter: Box::new(LookaheadDrafter::new(n, g, cap)),
            }),
            m => Box::new(DeviceDraft { exec: m.exec_name() }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_consistent() {
        for info in METHODS {
            assert_eq!(info.default.name(), info.name, "{}", info.name);
            assert!(info.default.validate().is_ok(), "{}", info.name);
            // every alias resolves back to the same row
            for alias in info.aliases {
                assert_eq!(
                    SpecMethod::parse(alias),
                    Some(info.default),
                    "{alias}"
                );
            }
            assert_eq!(SpecMethod::parse(info.name), Some(info.default));
        }
        assert_eq!(SpecMethod::all_defaults().len(), METHODS.len());
        assert_eq!(
            SpecMethod::speculative_defaults().len(),
            METHODS.len() - 1
        );
    }

    #[test]
    fn parse_covers_every_family_and_knob() {
        assert_eq!(SpecMethod::parse("ar"), Some(SpecMethod::Ar));
        assert_eq!(
            SpecMethod::parse("sps:k=6"),
            Some(SpecMethod::Sps { k: 6 })
        );
        assert_eq!(
            SpecMethod::parse("eagle_chain:depth=5"),
            Some(SpecMethod::EagleChain { depth: 5 })
        );
        assert_eq!(
            SpecMethod::parse("eagle_tree:k=9,beam=3,branch=4"),
            Some(SpecMethod::EagleTree { depth: 9, beam: 3, branch: 4 })
        );
        assert_eq!(
            SpecMethod::parse("eagle_tree:beam=1"),
            Some(SpecMethod::EagleTree { depth: 7, beam: 1, branch: 2 })
        );
        assert_eq!(
            SpecMethod::parse("medusa:k=2"),
            Some(SpecMethod::Medusa { depth: 2 })
        );
        assert_eq!(
            SpecMethod::parse("pld:min=3,max=5"),
            Some(SpecMethod::Pld { min_ngram: 3, max_ngram: 5, k: 7 })
        );
        assert_eq!(
            SpecMethod::parse("lookahead:n=2,g=4,cap=64,k=5"),
            Some(SpecMethod::Lookahead { n: 2, g: 4, cap: 64, k: 5 })
        );
        // rejects: unknown family, unknown knob, malformed pair, invalid
        assert_eq!(SpecMethod::parse("warp"), None);
        assert_eq!(SpecMethod::parse("ar:k=7"), None);
        assert_eq!(SpecMethod::parse("sps:beam=2"), None);
        assert_eq!(SpecMethod::parse("sps:k"), None);
        assert_eq!(SpecMethod::parse("sps:k=0"), None);
        assert_eq!(SpecMethod::parse("pld:min=5,max=2"), None);
    }

    #[test]
    fn label_round_trips() {
        for info in METHODS {
            let d = info.default;
            assert_eq!(SpecMethod::parse(&d.label()), Some(d), "{:?}", d);
        }
        let custom = SpecMethod::Lookahead { n: 2, g: 3, cap: 17, k: 4 };
        assert_eq!(SpecMethod::parse(&custom.label()), Some(custom));
    }

    #[test]
    fn json_round_trips() {
        for info in METHODS {
            let d = info.default;
            let text = d.to_json().to_string_json();
            let back = Value::parse(&text).unwrap();
            assert_eq!(SpecMethod::from_json(&back), Ok(d), "{text}");
        }
        // partial object bodies take family defaults for missing knobs
        let v = Value::parse(r#"{"eagle_tree": {"k": 9}}"#).unwrap();
        assert_eq!(
            SpecMethod::from_json(&v),
            Ok(SpecMethod::EagleTree { depth: 9, beam: 2, branch: 2 })
        );
        let v = Value::parse(r#"{"pld": {}}"#).unwrap();
        assert_eq!(
            SpecMethod::from_json(&v),
            Ok(SpecMethod::Pld { min_ngram: 2, max_ngram: 4, k: 7 })
        );
        // rejects
        for bad in [
            r#"{"warp": {}}"#,
            r#"{"sps": {"beam": 2}}"#,
            r#"{"sps": {"k": 1.5}}"#,
            r#"{"sps": 7}"#,
            r#"{"sps": {}, "pld": {}}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(SpecMethod::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn request_legacy_flat_knobs_override() {
        let legacy =
            Value::parse(r#"{"method": "eagle_tree", "k": 9, "beam": 3}"#)
                .unwrap();
        let structured = Value::parse(
            r#"{"method": {"eagle_tree": {"k": 9, "beam": 3}}}"#,
        )
        .unwrap();
        assert_eq!(
            SpecMethod::from_request(&legacy),
            SpecMethod::from_request(&structured)
        );
        // knobs a family does not have are ignored, like the old flat
        // GenParams fields the round programs never read
        let v = Value::parse(r#"{"method": "sps", "k": 6, "beam": 5}"#)
            .unwrap();
        assert_eq!(
            SpecMethod::from_request(&v),
            Ok(SpecMethod::Sps { k: 6 })
        );
        // absent method -> default descriptor, still overridable
        let v = Value::parse(r#"{"k": 11}"#).unwrap();
        assert_eq!(
            SpecMethod::from_request(&v),
            Ok(SpecMethod::EagleTree { depth: 11, beam: 2, branch: 2 })
        );
    }

    #[test]
    fn parse_list_handles_knob_commas() {
        let list =
            SpecMethod::parse_list("sps:k=6,eagle_tree:k=7,beam=4,pld")
                .unwrap();
        assert_eq!(
            list,
            vec![
                SpecMethod::Sps { k: 6 },
                SpecMethod::EagleTree { depth: 7, beam: 4, branch: 2 },
                SpecMethod::Pld { min_ngram: 2, max_ngram: 4, k: 7 },
            ]
        );
        // a knob continuation directly after a bare family name
        assert_eq!(
            SpecMethod::parse_list("eagle_tree,beam=4,pld"),
            Some(vec![
                SpecMethod::EagleTree { depth: 7, beam: 4, branch: 2 },
                SpecMethod::Pld { min_ngram: 2, max_ngram: 4, k: 7 },
            ])
        );
        assert_eq!(SpecMethod::parse_list("beam=4"), None);
        assert_eq!(SpecMethod::parse_list(""), None);
    }

    #[test]
    fn slots_lower_chain_as_degenerate_tree() {
        assert_eq!(
            SpecMethod::EagleChain { depth: 5 }.encode_slots(),
            [5.0, 1.0, 1.0]
        );
        assert_eq!(
            SpecMethod::EagleTree { depth: 7, beam: 2, branch: 3 }
                .encode_slots(),
            [7.0, 2.0, 3.0]
        );
        assert_eq!(SpecMethod::Sps { k: 6 }.encode_slots(), [6.0, 1.0, 1.0]);
        assert_eq!(SpecMethod::Ar.encode_slots(), [0.0, 1.0, 1.0]);
    }

    #[test]
    fn exec_names_cover_every_family() {
        for info in METHODS {
            let exec = info.default.exec_name();
            assert!(!exec.is_empty(), "{}", info.name);
        }
        assert_eq!(SpecMethod::default().exec_name(), "eagle_tree_round");
        assert_eq!(
            SpecMethod::parse("pld").unwrap().exec_name(),
            "verify_ext_round"
        );
    }

    #[test]
    fn multi_exec_names_cover_device_coupled_families() {
        // every device-coupled method has a fused variant named after its
        // round program; host-drafted families pack nothing
        for info in METHODS {
            let base = info.default.exec_name();
            match info.default.multi_exec_name() {
                Some(multi) => assert_eq!(
                    multi,
                    format!(
                        "{}_multi",
                        base.trim_end_matches("_round").trim_end_matches("_step")
                    ),
                    "{}",
                    info.name
                ),
                None => assert_eq!(base, "verify_ext_round", "{}", info.name),
            }
        }
        assert_eq!(
            SpecMethod::EagleChain { depth: 5 }.multi_exec_name(),
            Some("eagle_tree_multi")
        );
        assert_eq!(SpecMethod::Ar.multi_exec_name(), Some("ar_multi"));
    }

    #[test]
    fn batch_exec_names_cover_every_family() {
        // every family batches: device-coupled methods get their own
        // `*_batch` program, host-drafted ones share verify_ext_batch
        for info in METHODS {
            let base = info.default.exec_name();
            let batch = info.default.batch_exec_name();
            if base == "verify_ext_round" {
                assert_eq!(batch, "verify_ext_batch", "{}", info.name);
                assert_eq!(
                    info.default.batch_multi_exec_name(),
                    None,
                    "{}: host drafts cannot pack rounds",
                    info.name
                );
            } else {
                assert_eq!(
                    batch,
                    format!(
                        "{}_batch",
                        base.trim_end_matches("_round").trim_end_matches("_step")
                    ),
                    "{}",
                    info.name
                );
                assert_eq!(
                    info.default.batch_multi_exec_name(),
                    Some(
                        match batch {
                            "ar_batch" => "ar_batch_multi",
                            "sps_batch" => "sps_batch_multi",
                            "eagle_tree_batch" => "eagle_tree_batch_multi",
                            "medusa_batch" => "medusa_batch_multi",
                            other => panic!("unexpected {other}"),
                        }
                    ),
                    "{}",
                    info.name
                );
            }
        }
        assert_eq!(
            SpecMethod::EagleChain { depth: 5 }.batch_exec_name(),
            "eagle_tree_batch"
        );
        assert_eq!(SpecMethod::Ar.batch_exec_name(), "ar_batch");
    }

    #[test]
    fn descriptor_knobs_reach_the_pld_drafter() {
        // regression for the hard-coded PldDrafter::default() in
        // SeqRunner::new: the tail 2-gram [1, 2] repeats (match at 0,
        // continuation [3, 4, ...]) but no 3-gram repeats, so narrowing
        // min_ngram from 2 to 3 must change what gets drafted.
        let h = [1u32, 2, 3, 4, 9, 9, 1, 2];
        let mut default = SpecMethod::parse("pld").unwrap().draft_source();
        let drafted = default.next_drafts(&h).expect("pld drafts on host");
        assert_eq!(drafted, vec![3, 4, 9, 9, 1, 2]);
        let mut narrow =
            SpecMethod::parse("pld:min=3,max=5").unwrap().draft_source();
        let drafted = narrow.next_drafts(&h).expect("pld drafts on host");
        assert!(drafted.is_empty(), "min=3 must kill the 2-gram match");
        // and the k knob bounds the proposal length
        let mut short =
            SpecMethod::parse("pld:k=2").unwrap().draft_source();
        assert_eq!(short.next_drafts(&h), Some(vec![3, 4]));
    }

    #[test]
    fn descriptor_knobs_reach_the_lookahead_drafter() {
        let h = [5u32, 6, 7, 8, 9, 5, 6];
        let mut src = SpecMethod::parse("lookahead:n=2,g=4,cap=100,k=3")
            .unwrap()
            .draft_source();
        // next_drafts observes the history, then keys the pool on the tail
        assert_eq!(src.next_drafts(&h), Some(vec![7, 8, 9]));
        // device-coupled methods draft inside the round program
        let mut dev = SpecMethod::default().draft_source();
        assert_eq!(dev.next_drafts(&h), None);
        assert_eq!(dev.exec_name(), "eagle_tree_round");
    }
}
