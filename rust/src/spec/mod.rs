//! Host-side speculative drafting components.
//!
//! The model-based drafters (SpS LM, EAGLE head, Medusa heads) run inside
//! the AOT'd device programs; the retrieval-based baselines of the paper's
//! Table 1 — Prompt Lookup Decoding and (simplified) Lookahead — draft on
//! the host from the token history and feed `verify_ext_round`.

pub mod lookahead;
pub mod pld;

pub use lookahead::LookaheadDrafter;
pub use pld::PldDrafter;

/// A host drafter proposes up to `k` continuation tokens given the full
/// token history (prompt ++ generated).
pub trait HostDrafter {
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32>;

    /// Observe newly committed tokens (for pool-building drafters).
    fn observe(&mut self, _history: &[u32]) {}
}
