//! Simplified Lookahead decoding (Fu et al., 2024).
//!
//! The original maintains an n-gram pool filled by Jacobi fixed-point
//! iterations running alongside decoding. We keep the n-gram pool and its
//! verification path but fill it from the observed generation history
//! instead of Jacobi branches (documented deviation — DESIGN.md §9.4):
//! on this substrate the Jacobi branch would share the single CPU device
//! with the main decode and cannot run "for free" as it does on under-
//! utilized GPUs.

use std::collections::HashMap;

use super::HostDrafter;

/// Simplified-lookahead drafter: an n-gram → continuation pool filled
/// from the observed history. Built from a
/// [`super::SpecMethod::Lookahead`] descriptor via
/// [`super::SpecMethod::draft_source`].
pub struct LookaheadDrafter {
    /// n-gram order of the pool keys
    pub n: usize,
    /// continuation length stored per key
    pub g: usize,
    pool: HashMap<Vec<u32>, Vec<u32>>,
    seen: usize,
    /// pool capacity (oldest entries are not evicted; inserts stop)
    pub cap: usize,
}

impl Default for LookaheadDrafter {
    fn default() -> Self {
        LookaheadDrafter::new(3, 8, 4096)
    }
}

impl LookaheadDrafter {
    /// Build a pool of `n`-gram keys with `g`-token continuations, capped
    /// at `cap` entries.
    pub fn new(n: usize, g: usize, cap: usize) -> Self {
        assert!(n >= 1 && g >= 1);
        LookaheadDrafter { n, g, pool: HashMap::new(), seen: 0, cap }
    }

    /// Number of n-gram entries currently in the pool.
    pub fn pool_len(&self) -> usize {
        self.pool.len()
    }
}

impl HostDrafter for LookaheadDrafter {
    fn observe(&mut self, history: &[u32]) {
        // incrementally index new n-gram -> continuation pairs
        let len = history.len();
        if len < self.n + 1 {
            return;
        }
        let start = self.seen.saturating_sub(self.n + self.g);
        for i in start..len.saturating_sub(self.n) {
            if self.pool.len() >= self.cap {
                break;
            }
            let key = history[i..i + self.n].to_vec();
            let cont_end = (i + self.n + self.g).min(len);
            let cont = history[i + self.n..cont_end].to_vec();
            if !cont.is_empty() {
                // newest continuation wins (matches lookahead's refresh)
                self.pool.insert(key, cont);
            }
        }
        self.seen = len;
    }

    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32> {
        if history.len() < self.n {
            return Vec::new();
        }
        let key = &history[history.len() - self.n..];
        match self.pool.get(key) {
            Some(cont) => cont.iter().take(k).copied().collect(),
            None => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_from_history() {
        let mut d = LookaheadDrafter::new(2, 4, 100);
        let h = vec![5, 6, 7, 8, 9, 5, 6];
        d.observe(&h);
        // key [5,6] -> continuation [7,8,9,...]
        assert_eq!(d.draft(&h, 3), vec![7, 8, 9]);
    }

    #[test]
    fn empty_without_observation() {
        let mut d = LookaheadDrafter::new(2, 4, 100);
        assert!(d.draft(&[1, 2, 3], 4).is_empty());
    }

    #[test]
    fn incremental_observe() {
        let mut d = LookaheadDrafter::new(2, 2, 100);
        let mut h = vec![1, 2, 3];
        d.observe(&h);
        h.extend([4, 1, 2]);
        d.observe(&h);
        assert_eq!(d.draft(&h, 2), vec![3, 4]);
        assert!(d.pool_len() >= 2);
    }

    #[test]
    fn capacity_bounds_pool() {
        let mut d = LookaheadDrafter::new(1, 1, 3);
        let h: Vec<u32> = (0..100).collect();
        d.observe(&h);
        assert!(d.pool_len() <= 3);
    }
}
