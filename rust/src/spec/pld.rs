//! Prompt Lookup Decoding (Somasundaram et al., 2024): draft the
//! continuation of the longest recent n-gram match found in the existing
//! token history (prompt + generation). No model, no training — pure
//! string matching, which is why it shines on summarization-style tasks
//! (CNN/DM column of Table 1) and does little for open-ended chat.

use super::HostDrafter;

/// Prompt-lookup drafter: proposes the continuation of the most recent
/// earlier occurrence of the history's tail n-gram. Built from a
/// [`super::SpecMethod::Pld`] descriptor via
/// [`super::SpecMethod::draft_source`].
pub struct PldDrafter {
    /// longest n-gram to try to match (tried longest-first)
    pub max_ngram: usize,
    /// shortest n-gram worth matching
    pub min_ngram: usize,
}

impl Default for PldDrafter {
    fn default() -> Self {
        PldDrafter { max_ngram: 4, min_ngram: 2 }
    }
}

impl PldDrafter {
    /// Build a drafter matching n-grams of length `min_ngram..=max_ngram`.
    pub fn new(min_ngram: usize, max_ngram: usize) -> Self {
        assert!(min_ngram >= 1 && max_ngram >= min_ngram);
        PldDrafter { max_ngram, min_ngram }
    }

    /// Find the continuation of the most recent earlier occurrence of the
    /// history's tail n-gram; longest n wins, most recent match wins.
    fn lookup(&self, history: &[u32], k: usize) -> Vec<u32> {
        let len = history.len();
        for n in (self.min_ngram..=self.max_ngram).rev() {
            if len < n + 1 {
                continue;
            }
            let tail = &history[len - n..];
            // scan right-to-left over earlier positions
            for start in (0..len - n).rev() {
                if &history[start..start + n] == tail {
                    let cont_from = start + n;
                    let take = k.min(len - cont_from);
                    if take == 0 {
                        continue;
                    }
                    return history[cont_from..cont_from + take].to_vec();
                }
            }
        }
        Vec::new()
    }
}

impl HostDrafter for PldDrafter {
    fn draft(&mut self, history: &[u32], k: usize) -> Vec<u32> {
        self.lookup(history, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_repeat() {
        // history: "a b c d ... a b" -> draft "c d"
        let h = vec![1, 2, 3, 4, 9, 9, 1, 2];
        let mut d = PldDrafter::new(2, 4);
        assert_eq!(d.draft(&h, 2), vec![3, 4]);
    }

    #[test]
    fn longest_ngram_wins() {
        // tail [2,3,4] matches at 0 (cont 5); tail [3,4] also matches.
        let h = vec![2, 3, 4, 5, 0, 3, 4, 7, 2, 3, 4];
        let mut d = PldDrafter::new(2, 4);
        assert_eq!(d.draft(&h, 1), vec![5]);
    }

    #[test]
    fn no_match_empty() {
        let h = vec![1, 2, 3, 4, 5];
        let mut d = PldDrafter::new(2, 4);
        assert!(d.draft(&h, 4).is_empty());
    }

    #[test]
    fn respects_k() {
        let h = vec![1, 2, 3, 4, 5, 6, 1, 2];
        let mut d = PldDrafter::new(2, 2);
        // continuation may run into the repeated tail itself
        assert_eq!(d.draft(&h, 10), vec![3, 4, 5, 6, 1, 2]);
        assert_eq!(d.draft(&h, 1), vec![3]);
    }

    #[test]
    fn short_history_safe() {
        let mut d = PldDrafter::default();
        assert!(d.draft(&[], 4).is_empty());
        assert!(d.draft(&[1], 4).is_empty());
        assert!(d.draft(&[1, 1], 4).is_empty());
    }

    #[test]
    fn most_recent_match_preferred() {
        // [1,2] occurs at 0 (cont 3) and at 4 (cont 7); recent wins.
        let h = vec![1, 2, 3, 0, 1, 2, 7, 8, 1, 2];
        let mut d = PldDrafter::new(2, 2);
        assert_eq!(d.draft(&h, 1), vec![7]);
    }
}
