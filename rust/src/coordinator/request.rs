//! Request/response types + the line-JSON wire encoding.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::engine::{GenParams, GenResult, Method};
use crate::util::json::Value;
use crate::verify::VerifyPolicy;

pub type RequestId = u64;

/// A generation request as admitted by the scheduler.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: String,
    pub params: GenParams,
}

/// Terminal response for a request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub ok: bool,
    pub error: Option<String>,
    pub text: String,
    pub tokens: usize,
    pub tau: f64,
    pub decode_seconds: f64,
    pub prefill_seconds: f64,
    pub relaxed_accepts: f64,
    /// verification-policy label (`VerifyPolicy::label`), e.g. `mars:0.9`
    pub policy: String,
}

impl Response {
    pub fn from_result(
        id: RequestId,
        r: &GenResult,
        policy: VerifyPolicy,
    ) -> Response {
        Response {
            id,
            ok: true,
            error: None,
            text: r.text.clone(),
            tokens: r.tokens.len(),
            tau: r.tau(),
            decode_seconds: r.decode_seconds,
            prefill_seconds: r.prefill_seconds,
            relaxed_accepts: r.snapshot.relaxed_accepts,
            policy: policy.label(),
        }
    }

    pub fn from_error(id: RequestId, msg: &str) -> Response {
        Response {
            id,
            ok: false,
            error: Some(msg.to_string()),
            text: String::new(),
            tokens: 0,
            tau: 0.0,
            decode_seconds: 0.0,
            prefill_seconds: 0.0,
            relaxed_accepts: 0.0,
            policy: String::new(),
        }
    }

    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("id", Value::Num(self.id as f64));
        o.set("ok", Value::Bool(self.ok));
        if let Some(e) = &self.error {
            o.set("error", Value::Str(e.clone()));
        }
        o.set("text", Value::Str(self.text.clone()));
        o.set("tokens", Value::Num(self.tokens as f64));
        o.set("tau", Value::Num(self.tau));
        o.set("decode_seconds", Value::Num(self.decode_seconds));
        o.set("prefill_seconds", Value::Num(self.prefill_seconds));
        o.set("relaxed_accepts", Value::Num(self.relaxed_accepts));
        if !self.policy.is_empty() {
            o.set("policy", Value::Str(self.policy.clone()));
        }
        o
    }
}

/// Wire format: one JSON object per line.
/// `{"prompt": "...", "method": "eagle_tree",
///   "policy": {"mars": {"theta": 0.9}},
///   "temperature": 1.0, "k": 7, "max_new": 128, "seed": 1}`
///
/// The `"policy"` value may also be a CLI string (`"mars:0.9"`); the
/// legacy flat `"mars"` / `"theta"` keys still parse (to `Strict` /
/// `Mars { theta }`) for old clients.
pub fn parse_request_json(id: RequestId, v: &Value) -> Result<Request, String> {
    let prompt = v
        .get("prompt")
        .and_then(|p| p.as_str())
        .ok_or("missing 'prompt'")?
        .to_string();
    let mut params = GenParams::default();
    if let Some(m) = v.get("method").and_then(|m| m.as_str()) {
        params.method =
            Method::parse(m).ok_or_else(|| format!("unknown method '{m}'"))?;
    }
    // clamp to device-executable form so the echoed policy label and the
    // per-policy metrics describe the rule that actually ran
    params.policy = VerifyPolicy::from_request(v)?.normalize_for_device();
    let fget = |k: &str| v.get(k).and_then(|x| x.as_f64());
    if let Some(x) = fget("temperature") {
        params.temperature = x as f32;
    }
    if let Some(x) = fget("k") {
        params.k = x as usize;
    }
    if let Some(x) = fget("beam") {
        params.beam = x as usize;
    }
    if let Some(x) = fget("branch") {
        params.branch = x as usize;
    }
    if let Some(x) = fget("max_new") {
        params.max_new = x as usize;
    }
    if let Some(x) = fget("seed") {
        params.seed = x as u64;
    }
    Ok(Request { id, prompt, params })
}

/// Work item flowing to a replica: the request, its reply channel, and the
/// submission timestamp (stamped by the router so queue-wait metrics
/// measure time spent waiting, not prefill).
pub struct WorkItem {
    pub request: Request,
    pub reply: Sender<Response>,
    pub submitted_at: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal() {
        let v = Value::parse(r#"{"prompt": "hi"}"#).unwrap();
        let r = parse_request_json(1, &v).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.params.method, Method::EagleTree);
        assert_eq!(r.params.policy, VerifyPolicy::default());
    }

    #[test]
    fn parses_structured_policy() {
        let v = Value::parse(
            r#"{"prompt": "x", "method": "sps",
                "policy": {"mars": {"theta": 0.92}}, "temperature": 0.5,
                "k": 9, "max_new": 32, "seed": 7}"#,
        )
        .unwrap();
        let r = parse_request_json(2, &v).unwrap();
        assert_eq!(r.params.method, Method::Sps);
        assert_eq!(r.params.policy, VerifyPolicy::Mars { theta: 0.92 });
        assert_eq!(r.params.k, 9);
        assert_eq!(r.params.seed, 7);
    }

    #[test]
    fn parses_policy_string_and_new_families() {
        for (text, want) in [
            (r#"{"prompt":"x","policy":"strict"}"#, VerifyPolicy::Strict),
            (
                // k is clamped to the device's top-2 width at admission
                r#"{"prompt":"x","policy":"topk:3:0.2"}"#,
                VerifyPolicy::TopK { k: 2, eps: 0.2 },
            ),
            (
                r#"{"prompt":"x","policy":{"entropy":{"h_max":1.5}}}"#,
                VerifyPolicy::Entropy { h_max: 1.5 },
            ),
        ] {
            let v = Value::parse(text).unwrap();
            assert_eq!(
                parse_request_json(1, &v).unwrap().params.policy,
                want,
                "{text}"
            );
        }
    }

    #[test]
    fn topk_above_device_width_is_clamped_at_admission() {
        // the device pipeline materializes top-2 only; the request layer
        // clamps so the echoed label matches the rule that actually runs
        let v = Value::parse(
            r#"{"prompt":"x","policy":{"topk":{"k":5,"eps":0.3}}}"#,
        )
        .unwrap();
        let r = parse_request_json(1, &v).unwrap();
        assert_eq!(r.params.policy, VerifyPolicy::TopK { k: 2, eps: 0.3 });
    }

    #[test]
    fn legacy_mars_theta_keys_round_trip() {
        let v = Value::parse(
            r#"{"prompt": "x", "method": "sps", "mars": false,
                "theta": 0.92, "temperature": 0.5, "k": 9, "max_new": 32,
                "seed": 7}"#,
        )
        .unwrap();
        let r = parse_request_json(2, &v).unwrap();
        assert_eq!(r.params.policy, VerifyPolicy::Strict);

        let v = Value::parse(r#"{"prompt": "x", "mars": true, "theta": 0.92}"#)
            .unwrap();
        let r = parse_request_json(3, &v).unwrap();
        assert_eq!(r.params.policy, VerifyPolicy::Mars { theta: 0.92 });
        // and the parsed policy's own JSON form round-trips back to itself
        let again =
            VerifyPolicy::from_json(&r.params.policy.to_json()).unwrap();
        assert_eq!(again, r.params.policy);
    }

    #[test]
    fn rejects_bad_method_and_policy() {
        let v = Value::parse(r#"{"prompt": "x", "method": "warp"}"#).unwrap();
        assert!(parse_request_json(3, &v).is_err());
        let v =
            Value::parse(r#"{"prompt": "x", "policy": "warp"}"#).unwrap();
        assert!(parse_request_json(4, &v).is_err());
    }

    #[test]
    fn response_json_roundtrips() {
        let resp = Response {
            id: 9,
            ok: true,
            error: None,
            text: "out".into(),
            tokens: 3,
            tau: 5.5,
            decode_seconds: 0.25,
            prefill_seconds: 0.05,
            relaxed_accepts: 4.0,
            policy: "mars:0.9".into(),
        };
        let v = resp.to_json();
        assert_eq!(v.get("id").unwrap().as_usize(), Some(9));
        assert_eq!(v.get("tau").unwrap().as_f64(), Some(5.5));
        assert_eq!(v.get("policy").unwrap().as_str(), Some("mars:0.9"));
    }
}
