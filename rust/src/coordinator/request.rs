//! Request/response types + the line-JSON wire encoding, including the
//! streaming surface: [`StreamDelta`] events, the [`StreamSink`] callback
//! threaded from the engine's round-commit hook to the connection writer,
//! and the cooperative cancel flag carried by every [`WorkItem`].

use std::sync::atomic::AtomicBool;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use crate::engine::{GenParams, GenResult};
use crate::spec::SpecMethod;
use crate::util::json::Value;
use crate::verify::VerifyPolicy;

/// Identifier echoed on every reply and delta line. Client-assigned when
/// the request carries an `"id"` field; router-assigned otherwise.
pub type RequestId = u64;

/// Highest client-assignable wire id (exclusive). Ids at or above this
/// are reserved for server-assigned connection-local fallback ids, and
/// everything below stays exactly representable in the f64 the JSON
/// wire encoding carries.
pub const CLIENT_ID_MAX: u64 = 1 << 52;

/// Extract a well-formed client `"id"` from a wire object: present,
/// finite, a non-negative integer, and below [`CLIENT_ID_MAX`].
pub fn wire_id(v: &Value) -> Option<RequestId> {
    v.get("id")
        .and_then(|x| x.as_f64())
        .filter(|f| {
            f.is_finite()
                && *f >= 0.0
                && f.fract() == 0.0
                && *f < CLIENT_ID_MAX as f64
        })
        .map(|f| f as RequestId)
}

/// A generation request as admitted by the scheduler.
#[derive(Debug, Clone)]
pub struct Request {
    /// Reply/delta correlation id (see [`RequestId`]).
    pub id: RequestId,
    /// Raw prompt text (tokenized at replica admission).
    pub prompt: String,
    /// Generation parameters, including the verification policy.
    pub params: GenParams,
    /// Stream incremental `{"delta": ...}` lines as verify rounds commit
    /// tokens (wire field `"stream": true`).
    pub stream: bool,
    /// The request carried its own `"rounds_per_call"` / `"pack"` wire
    /// field (even an explicit 1, which opts *out* of packing on a
    /// `--pack` server). When `false` the replica applies its server
    /// default. Programmatic submissions set `true`: their
    /// [`GenParams`] are authoritative as given.
    pub pack_specified: bool,
    /// Per-request wall deadline in milliseconds, measured from router
    /// submission (wire field `"deadline_ms"`; absent means the server's
    /// `--deadline-ms` default, or none). Enforced at round boundaries:
    /// an expired request finalizes with its partial committed prefix
    /// and `"deadline_exceeded": true` on the reply (DESIGN.md §13).
    pub deadline_ms: Option<u64>,
}

/// Terminal response for a request.
#[derive(Debug, Clone)]
pub struct Response {
    /// Correlation id copied from the request.
    pub id: RequestId,
    /// `false` when the request failed; see [`Response::error`].
    pub ok: bool,
    /// Error message when `ok == false`.
    pub error: Option<String>,
    /// Full decoded completion (partial when canceled).
    pub text: String,
    /// Number of committed tokens.
    pub tokens: usize,
    /// Mean accepted tokens per draft-verify cycle.
    pub tau: f64,
    /// Wall-clock decode time (prefill excluded), seconds.
    pub decode_seconds: f64,
    /// Wall-clock prefill time, seconds.
    pub prefill_seconds: f64,
    /// Policy-relaxed acceptances across the whole generation.
    pub relaxed_accepts: f64,
    /// verification-policy label (`VerifyPolicy::label`), e.g. `mars:0.9`
    pub policy: String,
    /// method descriptor label (`SpecMethod::label`) that actually ran,
    /// e.g. `eagle_tree:k=7,beam=2,branch=2`
    pub method: String,
    /// The request was canceled mid-generation (`{"cmd": "cancel"}`);
    /// `text` holds whatever had committed by then.
    pub canceled: bool,
    /// Prompt tokens restored from the replica's prefix cache instead of
    /// prefilled (wire field `"cached_tokens"`, emitted when > 0).
    pub cached_tokens: usize,
    /// Effective round-packing budget the request ran under — after the
    /// `--pack` server default, streaming cap, capability fallback and
    /// `PACK_MAX` clamp (wire field `"rounds_per_call"`, emitted when
    /// > 1; the first call of any sequence still runs unpacked, the
    /// TTFT guard of DESIGN.md §9.6).
    pub rounds_per_call: usize,
    /// The request's deadline fired before it finished naturally: `text`
    /// holds the partial committed prefix and the wire reply carries
    /// `"deadline_exceeded": true` (DESIGN.md §13).
    pub deadline_exceeded: bool,
    /// The server shed this request at admission (queue depth above
    /// `--shed-above`): wire reply `{"busy": true, "retry_after_ms": N}`
    /// with `ok == false`.
    pub busy: bool,
    /// Client back-off hint accompanying a shed reply, milliseconds
    /// (wire field `"retry_after_ms"`, emitted alongside `"busy"`).
    pub retry_after_ms: Option<u64>,
    /// The failure is transient — shed, replica lost mid-flight, or all
    /// replicas down — and the client may safely resubmit (wire field
    /// `"retriable": true`; never set on request-shaped errors).
    pub retriable: bool,
}

/// One incremental streaming event: the text committed since the previous
/// delta of the same request. Concatenating every delta of a request
/// reproduces the final [`Response::text`] exactly.
#[derive(Debug, Clone)]
pub struct StreamDelta {
    /// Correlation id copied from the request.
    pub id: RequestId,
    /// Newly committed text (possibly empty rounds are not emitted).
    pub delta: String,
    /// Total tokens committed so far, including this delta.
    pub tokens: usize,
}

impl StreamDelta {
    /// Wire form: `{"id": N, "delta": "...", "tokens": T, "done": false}`.
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("id", Value::Num(self.id as f64));
        o.set("delta", Value::Str(self.delta.clone()));
        o.set("tokens", Value::Num(self.tokens as f64));
        o.set("done", Value::Bool(false));
        o
    }
}

/// Per-round delta callback threaded from the replica's decode loop to
/// whatever transport owns the request (the TCP connection writer in
/// `server`, a collector in tests/benches).
pub type StreamSink = Box<dyn FnMut(StreamDelta) + Send>;

impl Response {
    /// Build the success response for a finished generation, echoing the
    /// method and policy labels that actually ran.
    pub fn from_result(
        id: RequestId,
        r: &GenResult,
        params: &GenParams,
    ) -> Response {
        Response {
            id,
            ok: true,
            error: None,
            text: r.text.clone(),
            tokens: r.tokens.len(),
            tau: r.tau(),
            decode_seconds: r.decode_seconds,
            prefill_seconds: r.prefill_seconds,
            relaxed_accepts: r.snapshot.relaxed_accepts,
            policy: params.policy.label(),
            method: params.method.label(),
            canceled: false,
            cached_tokens: r.prefill_cached_tokens,
            rounds_per_call: params.rounds_per_call,
            deadline_exceeded: r.deadline_exceeded,
            busy: false,
            retry_after_ms: None,
            retriable: false,
        }
    }

    /// Build an error response (`ok == false`).
    pub fn from_error(id: RequestId, msg: &str) -> Response {
        Response {
            id,
            ok: false,
            error: Some(msg.to_string()),
            text: String::new(),
            tokens: 0,
            tau: 0.0,
            decode_seconds: 0.0,
            prefill_seconds: 0.0,
            relaxed_accepts: 0.0,
            policy: String::new(),
            method: String::new(),
            canceled: false,
            cached_tokens: 0,
            rounds_per_call: 1,
            deadline_exceeded: false,
            busy: false,
            retry_after_ms: None,
            retriable: false,
        }
    }

    /// Build a *retriable* error response (`ok == false`,
    /// `"retriable": true`): the failure is transient — the replica was
    /// lost mid-flight, the requeue budget ran out, or every replica is
    /// down — and the client may safely resubmit (DESIGN.md §13).
    pub fn retriable_error(id: RequestId, msg: &str) -> Response {
        let mut r = Response::from_error(id, msg);
        r.retriable = true;
        r
    }

    /// Build the overload-shed reply (`ok == false`, `"busy": true`,
    /// `"retriable": true`, `"retry_after_ms"` back-off hint): the queue
    /// depth crossed `--shed-above` and the request was rejected at
    /// admission instead of blocking the accept path (DESIGN.md §13).
    pub fn busy(id: RequestId, retry_after_ms: u64) -> Response {
        let mut r = Response::from_error(id, "server overloaded");
        r.busy = true;
        r.retriable = true;
        r.retry_after_ms = Some(retry_after_ms);
        r
    }

    /// Wire form of the terminal reply line (one JSON object).
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("id", Value::Num(self.id as f64));
        o.set("ok", Value::Bool(self.ok));
        if let Some(e) = &self.error {
            o.set("error", Value::Str(e.clone()));
        }
        o.set("text", Value::Str(self.text.clone()));
        o.set("tokens", Value::Num(self.tokens as f64));
        o.set("tau", Value::Num(self.tau));
        o.set("decode_seconds", Value::Num(self.decode_seconds));
        o.set("prefill_seconds", Value::Num(self.prefill_seconds));
        o.set("relaxed_accepts", Value::Num(self.relaxed_accepts));
        if !self.policy.is_empty() {
            o.set("policy", Value::Str(self.policy.clone()));
        }
        if !self.method.is_empty() {
            o.set("method", Value::Str(self.method.clone()));
        }
        if self.canceled {
            o.set("canceled", Value::Bool(true));
        }
        if self.cached_tokens > 0 {
            o.set("cached_tokens", Value::Num(self.cached_tokens as f64));
        }
        if self.rounds_per_call > 1 {
            o.set(
                "rounds_per_call",
                Value::Num(self.rounds_per_call as f64),
            );
        }
        if self.deadline_exceeded {
            o.set("deadline_exceeded", Value::Bool(true));
        }
        if self.busy {
            o.set("busy", Value::Bool(true));
        }
        if let Some(ms) = self.retry_after_ms {
            o.set("retry_after_ms", Value::Num(ms as f64));
        }
        if self.retriable {
            o.set("retriable", Value::Bool(true));
        }
        o
    }
}

/// Wire format: one JSON object per line.
/// `{"id": 3, "prompt": "...", "method": {"eagle_tree": {"k": 7}},
///   "policy": {"mars": {"theta": 0.9}}, "stream": true,
///   "temperature": 1.0, "max_new": 128, "seed": 1}`
///
/// `"id"` (optional) overrides the fallback `id` argument and is echoed
/// on every delta and the terminal reply — it is what lets a client
/// pipeline many requests on one connection and match out-of-order
/// completions. `"stream": true` requests incremental delta lines.
///
/// The `"method"` value may be a structured one-key object, a CLI string
/// (`"eagle_tree:k=7,beam=2"`), or a legacy bare family name
/// (`"eagle_tree"`); the legacy flat `"k"` / `"beam"` / `"branch"` keys
/// still override the descriptor's matching knobs for old clients (see
/// `SpecMethod::from_request`). Likewise the `"policy"` value may be a
/// CLI string (`"mars:0.9"`) and the legacy flat `"mars"` / `"theta"`
/// keys still parse (to `Strict` / `Mars { theta }`).
///
/// `"rounds_per_call"` (alias `"pack"`) opts the request into round
/// packing: up to N draft-verify rounds fused per device dispatch
/// (DESIGN.md §9.6). Absent means the server's `--pack` default;
/// streaming requests are capped to 1 by the replica so every round
/// still emits its delta, and the reply echoes the effective value.
///
/// `"deadline_ms"` sets the request's wall deadline, measured from
/// router submission; absent means the server's `--deadline-ms` default
/// (or none). An expired request finalizes at the next round boundary
/// with its partial text and `"deadline_exceeded": true`.
pub fn parse_request_json(id: RequestId, v: &Value) -> Result<Request, String> {
    let prompt = v
        .get("prompt")
        .and_then(|p| p.as_str())
        .ok_or("missing 'prompt'")?
        .to_string();
    let id = match v.get("id") {
        None => id,
        Some(_) => wire_id(v).ok_or(
            "'id' must be a non-negative integer below 2^52",
        )?,
    };
    let stream = match v.get("stream") {
        None => false,
        Some(x) => x.as_bool().ok_or("'stream' must be a boolean")?,
    };
    let cache = match v.get("cache") {
        None => true,
        Some(x) => x.as_bool().ok_or("'cache' must be a boolean")?,
    };
    // margin telemetry opt-in (DESIGN.md §12): dump the device probe
    // ring at finalize so the registry's margin-by-outcome histograms
    // see this request's decisive z2/z1 ratios (solo/interleaved lanes
    // only; batched lanes don't dump probes)
    let probe = match v.get("probe") {
        None => false,
        Some(x) => x.as_bool().ok_or("'probe' must be a boolean")?,
    };
    // the policy is clamped to device-executable form so the echoed
    // label and the per-policy metrics describe the rule that actually ran
    let mut params = GenParams {
        method: SpecMethod::from_request(v)?,
        policy: VerifyPolicy::from_request(v)?.normalize_for_device(),
        ..GenParams::default()
    };
    let fget = |k: &str| v.get(k).and_then(|x| x.as_f64());
    if let Some(x) = fget("temperature") {
        params.temperature = x as f32;
    }
    if let Some(x) = fget("max_new") {
        params.max_new = x as usize;
    }
    if let Some(x) = fget("seed") {
        params.seed = x as u64;
    }
    // round packing: `"rounds_per_call"` (alias `"pack"`) fuses up to N
    // draft-verify rounds per device dispatch (DESIGN.md §9.6); an
    // explicit 1 opts out of the server's `--pack` default
    let pack_field = v.get("rounds_per_call").or_else(|| v.get("pack"));
    let pack_specified = pack_field.is_some();
    if let Some(x) = pack_field {
        params.rounds_per_call = x
            .as_f64()
            .filter(|f| f.is_finite() && *f >= 1.0 && f.fract() == 0.0)
            .map(|f| f as usize)
            .ok_or("'rounds_per_call' must be a positive integer")?;
    }
    // per-request wall deadline (DESIGN.md §13); 0 is rejected — a
    // request that can spend no time at all is a client bug, not a
    // degenerate shed
    let deadline_ms = match v.get("deadline_ms") {
        None => None,
        Some(x) => Some(
            x.as_f64()
                .filter(|f| f.is_finite() && *f >= 1.0 && f.fract() == 0.0)
                .map(|f| f as u64)
                .ok_or("'deadline_ms' must be a positive integer")?,
        ),
    };
    params.cache = cache;
    params.probe = probe;
    Ok(Request { id, prompt, params, stream, pack_specified, deadline_ms })
}

/// Work item flowing to a replica: the request, its reply channel, and the
/// submission timestamp (stamped by the router so queue-wait metrics
/// measure time spent waiting, not prefill).
pub struct WorkItem {
    /// The admitted request.
    pub request: Request,
    /// Channel carrying the single terminal [`Response`].
    pub reply: Sender<Response>,
    /// Router-submit timestamp; queue wait and TTFT measure from here.
    pub submitted_at: Instant,
    /// Per-round delta sink for `"stream": true` requests (taken by the
    /// replica and handed to the engine's round-commit callback).
    pub stream: Option<StreamSink>,
    /// Cooperative cancel flag: the replica checks it between rounds and
    /// finalizes early with the committed prefix when set.
    pub cancel: Arc<AtomicBool>,
    /// Requeue attempts consumed so far (DESIGN.md §13): incremented
    /// each time a batch dispatch failure re-admits this innocent lane;
    /// past the supervisor's budget the request fails retriably instead
    /// of looping forever.
    pub retries: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal() {
        let v = Value::parse(r#"{"prompt": "hi"}"#).unwrap();
        let r = parse_request_json(1, &v).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.params.method, SpecMethod::default());
        assert_eq!(r.params.policy, VerifyPolicy::default());
    }

    #[test]
    fn parses_structured_policy() {
        let v = Value::parse(
            r#"{"prompt": "x", "method": "sps",
                "policy": {"mars": {"theta": 0.92}}, "temperature": 0.5,
                "k": 9, "max_new": 32, "seed": 7}"#,
        )
        .unwrap();
        let r = parse_request_json(2, &v).unwrap();
        assert_eq!(r.params.method, SpecMethod::Sps { k: 9 });
        assert_eq!(r.params.policy, VerifyPolicy::Mars { theta: 0.92 });
        assert_eq!(r.params.seed, 7);
    }

    #[test]
    fn legacy_and_structured_method_forms_are_identical() {
        // the acceptance pin: the legacy flat form and the structured
        // descriptor form must produce byte-identical GenParams
        let legacy = Value::parse(
            r#"{"prompt": "x", "method": "eagle_tree", "k": 7}"#,
        )
        .unwrap();
        let structured = Value::parse(
            r#"{"prompt": "x", "method": {"eagle_tree": {"k": 7}}}"#,
        )
        .unwrap();
        let a = parse_request_json(1, &legacy).unwrap();
        let b = parse_request_json(1, &structured).unwrap();
        assert_eq!(a.params, b.params);
        assert_eq!(format!("{:?}", a.params), format!("{:?}", b.params));
        // the CLI-string wire form lands on the same descriptor too
        let cli = Value::parse(
            r#"{"prompt": "x", "method": "eagle_tree:k=7"}"#,
        )
        .unwrap();
        assert_eq!(parse_request_json(1, &cli).unwrap().params, a.params);
    }

    #[test]
    fn parses_policy_string_and_new_families() {
        for (text, want) in [
            (r#"{"prompt":"x","policy":"strict"}"#, VerifyPolicy::Strict),
            (
                // k is clamped to the device's top-2 width at admission
                r#"{"prompt":"x","policy":"topk:3:0.2"}"#,
                VerifyPolicy::TopK { k: 2, eps: 0.2 },
            ),
            (
                r#"{"prompt":"x","policy":{"entropy":{"h_max":1.5}}}"#,
                VerifyPolicy::Entropy { h_max: 1.5 },
            ),
        ] {
            let v = Value::parse(text).unwrap();
            assert_eq!(
                parse_request_json(1, &v).unwrap().params.policy,
                want,
                "{text}"
            );
        }
    }

    #[test]
    fn topk_above_device_width_is_clamped_at_admission() {
        // the device pipeline materializes top-2 only; the request layer
        // clamps so the echoed label matches the rule that actually runs
        let v = Value::parse(
            r#"{"prompt":"x","policy":{"topk":{"k":5,"eps":0.3}}}"#,
        )
        .unwrap();
        let r = parse_request_json(1, &v).unwrap();
        assert_eq!(r.params.policy, VerifyPolicy::TopK { k: 2, eps: 0.3 });
    }

    #[test]
    fn legacy_mars_theta_keys_round_trip() {
        let v = Value::parse(
            r#"{"prompt": "x", "method": "sps", "mars": false,
                "theta": 0.92, "temperature": 0.5, "k": 9, "max_new": 32,
                "seed": 7}"#,
        )
        .unwrap();
        let r = parse_request_json(2, &v).unwrap();
        assert_eq!(r.params.policy, VerifyPolicy::Strict);
        assert_eq!(r.params.method, SpecMethod::Sps { k: 9 });

        let v = Value::parse(r#"{"prompt": "x", "mars": true, "theta": 0.92}"#)
            .unwrap();
        let r = parse_request_json(3, &v).unwrap();
        assert_eq!(r.params.policy, VerifyPolicy::Mars { theta: 0.92 });
        // and the parsed policy's own JSON form round-trips back to itself
        let again =
            VerifyPolicy::from_json(&r.params.policy.to_json()).unwrap();
        assert_eq!(again, r.params.policy);
    }

    #[test]
    fn rejects_bad_method_and_policy() {
        let v = Value::parse(r#"{"prompt": "x", "method": "warp"}"#).unwrap();
        assert!(parse_request_json(3, &v).is_err());
        let v =
            Value::parse(r#"{"prompt": "x", "policy": "warp"}"#).unwrap();
        assert!(parse_request_json(4, &v).is_err());
    }

    #[test]
    fn response_json_roundtrips() {
        let resp = Response {
            id: 9,
            ok: true,
            error: None,
            text: "out".into(),
            tokens: 3,
            tau: 5.5,
            decode_seconds: 0.25,
            prefill_seconds: 0.05,
            relaxed_accepts: 4.0,
            policy: "mars:0.9".into(),
            method: "eagle_tree:k=7,beam=2,branch=2".into(),
            canceled: false,
            cached_tokens: 0,
            rounds_per_call: 1,
            deadline_exceeded: false,
            busy: false,
            retry_after_ms: None,
            retriable: false,
        };
        let v = resp.to_json();
        assert_eq!(v.get("id").unwrap().as_usize(), Some(9));
        assert_eq!(v.get("tau").unwrap().as_f64(), Some(5.5));
        assert_eq!(v.get("policy").unwrap().as_str(), Some("mars:0.9"));
        assert_eq!(
            v.get("method").unwrap().as_str(),
            Some("eagle_tree:k=7,beam=2,branch=2")
        );
        // "canceled" only appears on canceled responses
        assert!(v.get("canceled").is_none());
        let mut c = resp.clone();
        c.canceled = true;
        assert_eq!(
            c.to_json().get("canceled").and_then(|b| b.as_bool()),
            Some(true)
        );
        // "cached_tokens" only appears when the prefix cache served rows
        assert!(v.get("cached_tokens").is_none());
        let mut w = resp.clone();
        w.cached_tokens = 12;
        assert_eq!(
            w.to_json().get("cached_tokens").and_then(|t| t.as_usize()),
            Some(12)
        );
        // "rounds_per_call" only appears when the request actually packed
        assert!(v.get("rounds_per_call").is_none());
        let mut p = resp.clone();
        p.rounds_per_call = 8;
        assert_eq!(
            p.to_json()
                .get("rounds_per_call")
                .and_then(|t| t.as_usize()),
            Some(8)
        );
        // the failure-semantics fields only appear when set
        for field in ["deadline_exceeded", "busy", "retry_after_ms", "retriable"]
        {
            assert!(v.get(field).is_none(), "{field} emitted unset");
        }
        let mut d = resp.clone();
        d.deadline_exceeded = true;
        assert_eq!(
            d.to_json().get("deadline_exceeded").and_then(|b| b.as_bool()),
            Some(true)
        );
    }

    #[test]
    fn busy_reply_carries_the_shed_fields() {
        let v = Response::busy(4, 150).to_json();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false));
        assert_eq!(v.get("busy").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(
            v.get("retry_after_ms").and_then(|t| t.as_usize()),
            Some(150)
        );
        assert_eq!(v.get("retriable").and_then(|b| b.as_bool()), Some(true));
    }

    #[test]
    fn retriable_error_sets_only_the_retriable_flag() {
        let v = Response::retriable_error(7, "replica lost").to_json();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false));
        assert_eq!(v.get("retriable").and_then(|b| b.as_bool()), Some(true));
        assert!(v.get("busy").is_none());
        assert!(v.get("retry_after_ms").is_none());
    }

    #[test]
    fn parses_deadline_ms() {
        let v = Value::parse(r#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(parse_request_json(1, &v).unwrap().deadline_ms, None);
        let v = Value::parse(r#"{"prompt": "hi", "deadline_ms": 2500}"#)
            .unwrap();
        assert_eq!(
            parse_request_json(1, &v).unwrap().deadline_ms,
            Some(2500)
        );
        for bad in [
            r#"{"prompt": "hi", "deadline_ms": 0}"#,
            r#"{"prompt": "hi", "deadline_ms": -5}"#,
            r#"{"prompt": "hi", "deadline_ms": 1.5}"#,
            r#"{"prompt": "hi", "deadline_ms": "soon"}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(parse_request_json(1, &v).is_err(), "{bad}");
        }
    }

    #[test]
    fn parses_rounds_per_call_and_pack_alias() {
        // absent: defaults apply AND the replica may overlay its --pack
        let v = Value::parse(r#"{"prompt": "hi"}"#).unwrap();
        let r = parse_request_json(1, &v).unwrap();
        assert_eq!(r.params.rounds_per_call, 1);
        assert!(!r.pack_specified);
        let v = Value::parse(r#"{"prompt": "hi", "rounds_per_call": 8}"#)
            .unwrap();
        let r = parse_request_json(1, &v).unwrap();
        assert_eq!(r.params.rounds_per_call, 8);
        assert!(r.pack_specified);
        let v = Value::parse(r#"{"prompt": "hi", "pack": 4}"#).unwrap();
        assert_eq!(parse_request_json(1, &v).unwrap().params.rounds_per_call, 4);
        // an explicit 1 is still "specified": it opts the request out of
        // packing on a --pack server rather than inheriting the default
        let v = Value::parse(r#"{"prompt": "hi", "rounds_per_call": 1}"#)
            .unwrap();
        let r = parse_request_json(1, &v).unwrap();
        assert_eq!(r.params.rounds_per_call, 1);
        assert!(r.pack_specified);
        for bad in [
            r#"{"prompt": "hi", "rounds_per_call": 0}"#,
            r#"{"prompt": "hi", "rounds_per_call": 2.5}"#,
            r#"{"prompt": "hi", "rounds_per_call": "x"}"#,
            r#"{"prompt": "hi", "pack": -1}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(parse_request_json(1, &v).is_err(), "{bad}");
        }
    }

    #[test]
    fn parses_probe_opt_in() {
        let v = Value::parse(r#"{"prompt": "hi"}"#).unwrap();
        assert!(!parse_request_json(1, &v).unwrap().params.probe);
        let v = Value::parse(r#"{"prompt": "hi", "probe": true}"#).unwrap();
        assert!(parse_request_json(1, &v).unwrap().params.probe);
        let v = Value::parse(r#"{"prompt": "hi", "probe": 1}"#).unwrap();
        assert!(parse_request_json(1, &v).is_err());
    }

    #[test]
    fn parses_cache_opt_out() {
        let v = Value::parse(r#"{"prompt": "hi"}"#).unwrap();
        assert!(parse_request_json(1, &v).unwrap().params.cache);
        let v = Value::parse(r#"{"prompt": "hi", "cache": false}"#).unwrap();
        assert!(!parse_request_json(1, &v).unwrap().params.cache);
        let v = Value::parse(r#"{"prompt": "hi", "cache": 1}"#).unwrap();
        assert!(parse_request_json(1, &v).is_err());
    }

    #[test]
    fn parses_client_id_and_stream() {
        let v = Value::parse(r#"{"prompt": "hi"}"#).unwrap();
        let r = parse_request_json(77, &v).unwrap();
        assert_eq!(r.id, 77, "fallback id used when 'id' absent");
        assert!(!r.stream);
        let v = Value::parse(r#"{"id": 42, "prompt": "hi", "stream": true}"#)
            .unwrap();
        let r = parse_request_json(77, &v).unwrap();
        assert_eq!(r.id, 42, "client id overrides the fallback");
        assert!(r.stream);
        for bad in [
            r#"{"id": -3, "prompt": "hi"}"#,
            r#"{"id": "x", "prompt": "hi"}"#,
            r#"{"id": 1.5, "prompt": "hi"}"#,
            // 2^52: the base of the reserved server-assigned id range
            r#"{"id": 4503599627370496, "prompt": "hi"}"#,
            r#"{"prompt": "hi", "stream": 1}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(parse_request_json(0, &v).is_err(), "{bad}");
        }
        // wire_id mirrors exactly those rules
        assert_eq!(
            wire_id(&Value::parse(r#"{"id": 9}"#).unwrap()),
            Some(9)
        );
        assert_eq!(wire_id(&Value::parse(r#"{"id": 1.5}"#).unwrap()), None);
        assert_eq!(
            wire_id(
                &Value::parse(r#"{"id": 4503599627370496}"#).unwrap()
            ),
            None
        );
    }

    #[test]
    fn stream_delta_wire_form() {
        let d = StreamDelta { id: 5, delta: "ab".into(), tokens: 2 };
        let v = d.to_json();
        assert_eq!(v.get("id").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("delta").unwrap().as_str(), Some("ab"));
        assert_eq!(v.get("done").unwrap().as_bool(), Some(false));
    }
}
