//! Request/response types + the line-JSON wire encoding.

use std::sync::mpsc::Sender;

use crate::engine::{GenParams, GenResult, Method};
use crate::util::json::Value;

pub type RequestId = u64;

/// A generation request as admitted by the scheduler.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: String,
    pub params: GenParams,
}

/// Terminal response for a request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub ok: bool,
    pub error: Option<String>,
    pub text: String,
    pub tokens: usize,
    pub tau: f64,
    pub decode_seconds: f64,
    pub prefill_seconds: f64,
    pub relaxed_accepts: f64,
}

impl Response {
    pub fn from_result(id: RequestId, r: &GenResult) -> Response {
        Response {
            id,
            ok: true,
            error: None,
            text: r.text.clone(),
            tokens: r.tokens.len(),
            tau: r.tau(),
            decode_seconds: r.decode_seconds,
            prefill_seconds: r.prefill_seconds,
            relaxed_accepts: r.snapshot.relaxed_accepts,
        }
    }

    pub fn from_error(id: RequestId, msg: &str) -> Response {
        Response {
            id,
            ok: false,
            error: Some(msg.to_string()),
            text: String::new(),
            tokens: 0,
            tau: 0.0,
            decode_seconds: 0.0,
            prefill_seconds: 0.0,
            relaxed_accepts: 0.0,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("id", Value::Num(self.id as f64));
        o.set("ok", Value::Bool(self.ok));
        if let Some(e) = &self.error {
            o.set("error", Value::Str(e.clone()));
        }
        o.set("text", Value::Str(self.text.clone()));
        o.set("tokens", Value::Num(self.tokens as f64));
        o.set("tau", Value::Num(self.tau));
        o.set("decode_seconds", Value::Num(self.decode_seconds));
        o.set("prefill_seconds", Value::Num(self.prefill_seconds));
        o.set("relaxed_accepts", Value::Num(self.relaxed_accepts));
        o
    }
}

/// Wire format: one JSON object per line.
/// `{"prompt": "...", "method": "eagle_tree", "mars": true, "theta": 0.9,
///   "temperature": 1.0, "k": 7, "max_new": 128, "seed": 1}`
pub fn parse_request_json(id: RequestId, v: &Value) -> Result<Request, String> {
    let prompt = v
        .get("prompt")
        .and_then(|p| p.as_str())
        .ok_or("missing 'prompt'")?
        .to_string();
    let mut params = GenParams::default();
    if let Some(m) = v.get("method").and_then(|m| m.as_str()) {
        params.method =
            Method::parse(m).ok_or_else(|| format!("unknown method '{m}'"))?;
    }
    if let Some(b) = v.get("mars").and_then(|b| b.as_bool()) {
        params.mars = b;
    }
    let fget = |k: &str| v.get(k).and_then(|x| x.as_f64());
    if let Some(x) = fget("theta") {
        params.theta = x as f32;
    }
    if let Some(x) = fget("temperature") {
        params.temperature = x as f32;
    }
    if let Some(x) = fget("k") {
        params.k = x as usize;
    }
    if let Some(x) = fget("beam") {
        params.beam = x as usize;
    }
    if let Some(x) = fget("branch") {
        params.branch = x as usize;
    }
    if let Some(x) = fget("max_new") {
        params.max_new = x as usize;
    }
    if let Some(x) = fget("seed") {
        params.seed = x as u64;
    }
    Ok(Request { id, prompt, params })
}

/// Work item flowing to a replica: the request plus its reply channel.
pub struct WorkItem {
    pub request: Request,
    pub reply: Sender<Response>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal() {
        let v = Value::parse(r#"{"prompt": "hi"}"#).unwrap();
        let r = parse_request_json(1, &v).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.params.method, Method::EagleTree);
    }

    #[test]
    fn parses_full() {
        let v = Value::parse(
            r#"{"prompt": "x", "method": "sps", "mars": false,
                "theta": 0.92, "temperature": 0.5, "k": 9, "max_new": 32,
                "seed": 7}"#,
        )
        .unwrap();
        let r = parse_request_json(2, &v).unwrap();
        assert_eq!(r.params.method, Method::Sps);
        assert!(!r.params.mars);
        assert!((r.params.theta - 0.92).abs() < 1e-6);
        assert_eq!(r.params.k, 9);
        assert_eq!(r.params.seed, 7);
    }

    #[test]
    fn rejects_bad_method() {
        let v = Value::parse(r#"{"prompt": "x", "method": "warp"}"#).unwrap();
        assert!(parse_request_json(3, &v).is_err());
    }

    #[test]
    fn response_json_roundtrips() {
        let resp = Response {
            id: 9,
            ok: true,
            error: None,
            text: "out".into(),
            tokens: 3,
            tau: 5.5,
            decode_seconds: 0.25,
            prefill_seconds: 0.05,
            relaxed_accepts: 4.0,
        };
        let v = resp.to_json();
        assert_eq!(v.get("id").unwrap().as_usize(), Some(9));
        assert_eq!(v.get("tau").unwrap().as_f64(), Some(5.5));
    }
}
