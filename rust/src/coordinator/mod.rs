//! Coordinator — the serving layer (vLLM-router-shaped, DESIGN.md §5).
//!
//! Topology: [`Router`] → N engine replicas. Each replica is a dedicated
//! OS thread that owns its own PJRT client, compiled executables and
//! uploaded weights (PJRT handles are not `Send`; thread-ownership is the
//! std-only equivalent of vLLM's per-GPU engine processes). A scheduler
//! admits queued requests into per-replica slots (continuous batching
//! across sequences), replica decode loops run rounds until
//! EOS/length/cancel, and results stream back over channels or the
//! line-JSON TCP protocol in [`server`].
//!
//! The wire protocol is **pipelined and streaming**: requests carry
//! client ids and complete out of order on one connection;
//! `"stream": true` requests emit per-round
//! [`StreamDelta`](request::StreamDelta) lines as verify rounds commit
//! tokens; `{"cmd": "cancel", "id": N}` stops a request between rounds
//! and returns the committed prefix. See the [`server`] module doc for
//! the full protocol grammar and [`metrics`] for the TTFT/TPOT serving
//! percentiles the `mars bench serve` load generator reports.

#![warn(missing_docs)]

pub mod metrics;
pub mod replica;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use metrics::MetricsRegistry;
pub use replica::EngineReplica;
pub use request::{Request, RequestId, Response, StreamDelta, StreamSink};
pub use router::{
    Router, RouterConfig, RouterPolicy, SubmitHandle, SubmitOptions,
};
pub use scheduler::{Scheduler, SubmitTarget};
