//! Coordinator — the serving layer (vLLM-router-shaped, DESIGN.md §5).
//!
//! Topology: [`Router`] → N engine replicas. Each replica is a dedicated
//! OS thread that owns its own PJRT client, compiled executables and
//! uploaded weights (PJRT handles are not `Send`; thread-ownership is the
//! std-only equivalent of vLLM's per-GPU engine processes). A scheduler
//! thread admits queued requests into per-replica slots (continuous
//! batching across sequences), decode workers run rounds until
//! EOS/length/cancel, and results stream back over channels or the
//! line-JSON TCP protocol in [`server`].

pub mod metrics;
pub mod replica;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use metrics::MetricsRegistry;
pub use replica::EngineReplica;
pub use request::{Request, RequestId, Response};
pub use router::{RouterPolicy, Router};
pub use scheduler::{Scheduler, SubmitTarget};
