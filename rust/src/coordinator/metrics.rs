//! Serving metrics registry (DESIGN.md §12): sharded per-replica
//! recording into fixed-bucket streaming histograms, merged at snapshot.
//!
//! The hot path is [`record`]/[`record_occupancy`]/[`record_round`]: a
//! replica locks only its own shard (`replica % N_SHARDS`), and every
//! distribution lands in an O(buckets) [`StreamHistogram`] — memory is
//! bounded by the bucket count times the live (policy × method) key
//! set, *not* by request volume (regression-pinned by
//! `memory_is_bounded_by_buckets_not_requests`). Snapshots merge the
//! shards element-wise; [`reset`] zeroes counters and the
//! `started`-at-first-record elapsed stamp between bench waves.
//!
//! What is tracked, per merged snapshot:
//!
//! * latency histograms — TTFT (submit → first committed token), TPOT,
//!   decode/prefill/queue, per-token µs;
//! * acceptance statistics — τ, relaxed-accept counts, broken out per
//!   verification-policy family and per speculative-method family;
//! * **margin-by-outcome histograms** — the decisive z2/z1 target
//!   margin split strict-accept / relaxed-accept / reject per
//!   policy × method ([`record_margins`]), the paper's low-margin-regime
//!   evidence as a live distribution;
//! * per-round aggregates — device-turn wall time and accepted-per-turn
//!   from the engine's [`RoundEvent`] stream ([`record_round`]);
//! * batch-occupancy histogram (DESIGN.md §9.5) and per-replica
//!   prefix-cache gauges (DESIGN.md §8) summed into one `"cache"`
//!   object.
//!
//! Failure semantics (DESIGN.md §13): every failure outcome in the
//! serving stack lands in the [`FailureKind`] counters
//! ([`record_failure`]) and every replica health transition in the
//! per-replica health gauge ([`record_health`]) — both exported on the
//! same snapshot/Prometheus surfaces as the latency metrics, so a chaos
//! run can assert its injected faults were counted, not swallowed.
//!
//! Export surfaces: [`snapshot_json`] (the `{"cmd":"metrics"}` RPC and
//! the `mars serve` shutdown print) and [`render_prometheus`] (the
//! `{"cmd":"prom"}` RPC and the `--prom-addr` scrape endpoint).
//! `mars bench serve` reports the same quantities measured client-side
//! (see BENCHMARKS.md).
//!
//! [`record`]: MetricsRegistry::record
//! [`record_occupancy`]: MetricsRegistry::record_occupancy
//! [`record_round`]: MetricsRegistry::record_round
//! [`record_margins`]: MetricsRegistry::record_margins
//! [`record_failure`]: MetricsRegistry::record_failure
//! [`record_health`]: MetricsRegistry::record_health
//! [`snapshot_json`]: MetricsRegistry::snapshot_json
//! [`render_prometheus`]: MetricsRegistry::render_prometheus
//! [`reset`]: MetricsRegistry::reset

// Serving-layer lint wall (DESIGN.md §11): a panic while holding a
// registry lock poisons it for every replica, so unwrap/expect are
// denied in non-test code — locks recover from poisoning instead
// (metrics are monotone counters/histograms; a shard interrupted
// mid-update is still safe to keep recording into).
#![deny(clippy::unwrap_used, clippy::expect_used)]
#![cfg_attr(test, allow(clippy::unwrap_used, clippy::expect_used))]

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use crate::cache::CacheStats;
use crate::obs::hist::StreamHistogram;
use crate::obs::prom::PromText;
use crate::obs::round::RoundEvent;
use crate::util::json::Value;
use crate::verify::AcceptFlag;

/// Registry shard count. Replica `r` records into shard
/// `r % N_SHARDS`, so up to 8 replicas never contend on a record.
const N_SHARDS: usize = 8;

/// Poison-recovering lock (the `lock_inflight` idiom, DESIGN.md §11):
/// a replica that panicked while recording must not take the whole
/// metrics surface down with it — counters and histograms stay valid
/// under interruption, so recovering the guard is safe.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Failure taxonomy for the serving stack (DESIGN.md §13). Every
/// terminal or recovered failure in router/replica/server increments
/// exactly one of these counters via
/// [`MetricsRegistry::record_failure`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FailureKind {
    /// A device dispatch returned an error (injected or real),
    /// poisoning the replica's stacked batch state.
    DispatchFailed,
    /// An innocent batchmate of a failed dispatch was requeued for
    /// re-execution.
    LaneRequeued,
    /// A lane exhausted its requeue budget and was failed retriable.
    RequeueBudgetExhausted,
    /// A batch-session rebuild attempt failed (the supervisor backs
    /// off and retries).
    SessionRebuildFailed,
    /// A replica transitioned to `Down` (rebuild budget exhausted);
    /// also counts each request it refuses while down.
    ReplicaDown,
    /// The router lost a replica mid-submit (work channel closed).
    ReplicaLost,
    /// A submit found no routable replica at all.
    AllReplicasDown,
    /// A request ran out of its deadline budget (partial commit).
    DeadlineExceeded,
    /// A request was refused at admission (queue-depth shedding).
    Shed,
}

impl FailureKind {
    /// Stable wire/label name of the failure kind.
    pub fn as_str(self) -> &'static str {
        match self {
            FailureKind::DispatchFailed => "dispatch_failed",
            FailureKind::LaneRequeued => "lane_requeued",
            FailureKind::RequeueBudgetExhausted => {
                "requeue_budget_exhausted"
            }
            FailureKind::SessionRebuildFailed => "session_rebuild_failed",
            FailureKind::ReplicaDown => "replica_down",
            FailureKind::ReplicaLost => "replica_lost",
            FailureKind::AllReplicasDown => "all_replicas_down",
            FailureKind::DeadlineExceeded => "deadline_exceeded",
            FailureKind::Shed => "shed",
        }
    }
}

/// Upper bounds for the Prometheus latency histograms, milliseconds.
const LAT_BOUNDS_MS: [f64; 10] =
    [1.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 1000.0, 5000.0, 20000.0];

/// Upper bounds for the Prometheus margin histograms (z2/z1 ratio).
const MARGIN_BOUNDS: [f64; 7] = [0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 1.0];

/// Per-policy-family aggregates (keyed by `VerifyPolicy::name`).
#[derive(Debug, Default)]
struct PolicyAgg {
    requests: u64,
    tokens: u64,
    tau: StreamHistogram,
    relaxed: StreamHistogram,
}

/// Per-method-family aggregates (keyed by `SpecMethod::name`).
#[derive(Debug, Default)]
struct MethodAgg {
    requests: u64,
    tokens: u64,
    tau: StreamHistogram,
    ttft_ms: StreamHistogram,
}

/// Margin-by-outcome histograms for one policy × method pair.
#[derive(Debug, Default)]
struct MarginAgg {
    exact: StreamHistogram,
    relaxed: StreamHistogram,
    reject: StreamHistogram,
}

/// Aggregates over the engine's per-turn [`RoundEvent`] stream.
#[derive(Debug, Default)]
struct RoundAgg {
    turns: u64,
    rounds: u64,
    drafted: u64,
    accepted: u64,
    relaxed_turns: u64,
    wall_ms: StreamHistogram,
    accepted_per_turn: StreamHistogram,
}

/// One replica-sharded slice of the registry.
#[derive(Debug, Default)]
struct Shard {
    requests_ok: u64,
    requests_err: u64,
    tokens_out: u64,
    decode_ms: StreamHistogram,
    prefill_ms: StreamHistogram,
    queue_ms: StreamHistogram,
    ttft_ms: StreamHistogram,
    tpot_ms: StreamHistogram,
    per_token_us: StreamHistogram,
    tau: StreamHistogram,
    relaxed: StreamHistogram,
    by_policy: BTreeMap<&'static str, PolicyAgg>,
    by_method: BTreeMap<&'static str, MethodAgg>,
    /// Batch-occupancy histogram (DESIGN.md §9.5): how many batched
    /// dispatches ran with N occupied lanes. Solo/interleaved replicas
    /// record nothing here; under `--batch` every round dispatch counts
    /// once, so the distribution shows how full the batch actually ran
    /// (the amortization factor the occupancy sweep measures).
    occupancy: BTreeMap<usize, u64>,
    /// Margin-by-outcome histograms per (policy, method).
    margins: BTreeMap<(&'static str, &'static str), MarginAgg>,
    rounds: RoundAgg,
}

impl Shard {
    /// Element-wise merge (snapshot-time shard reduction).
    fn merge(&mut self, o: &Shard) {
        self.requests_ok += o.requests_ok;
        self.requests_err += o.requests_err;
        self.tokens_out += o.tokens_out;
        self.decode_ms.merge(&o.decode_ms);
        self.prefill_ms.merge(&o.prefill_ms);
        self.queue_ms.merge(&o.queue_ms);
        self.ttft_ms.merge(&o.ttft_ms);
        self.tpot_ms.merge(&o.tpot_ms);
        self.per_token_us.merge(&o.per_token_us);
        self.tau.merge(&o.tau);
        self.relaxed.merge(&o.relaxed);
        for (name, agg) in &o.by_policy {
            let p = self.by_policy.entry(name).or_default();
            p.requests += agg.requests;
            p.tokens += agg.tokens;
            p.tau.merge(&agg.tau);
            p.relaxed.merge(&agg.relaxed);
        }
        for (name, agg) in &o.by_method {
            let m = self.by_method.entry(name).or_default();
            m.requests += agg.requests;
            m.tokens += agg.tokens;
            m.tau.merge(&agg.tau);
            m.ttft_ms.merge(&agg.ttft_ms);
        }
        for (occ, n) in &o.occupancy {
            *self.occupancy.entry(*occ).or_insert(0) += n;
        }
        for (key, agg) in &o.margins {
            let m = self.margins.entry(*key).or_default();
            m.exact.merge(&agg.exact);
            m.relaxed.merge(&agg.relaxed);
            m.reject.merge(&agg.reject);
        }
        self.rounds.turns += o.rounds.turns;
        self.rounds.rounds += o.rounds.rounds;
        self.rounds.drafted += o.rounds.drafted;
        self.rounds.accepted += o.rounds.accepted;
        self.rounds.relaxed_turns += o.rounds.relaxed_turns;
        self.rounds.wall_ms.merge(&o.rounds.wall_ms);
        self.rounds.accepted_per_turn.merge(&o.rounds.accepted_per_turn);
    }

    /// Resident bytes of this shard's histogram storage (the
    /// memory-bound regression test sums this across shards).
    fn approx_bytes(&self) -> usize {
        let h = StreamHistogram::approx_bytes();
        let fixed = 10 * h + std::mem::size_of::<Shard>();
        fixed
            + self.by_policy.len() * 2 * h
            + self.by_method.len() * 2 * h
            + self.margins.len() * 3 * h
            + self.occupancy.len()
                * std::mem::size_of::<(usize, u64)>()
    }
}

/// Cross-shard state: the elapsed stamp and the per-replica cache
/// gauges (latest-value semantics, not mergeable counters).
#[derive(Debug, Default)]
struct Global {
    started: Option<Instant>,
    /// Latest prefix-cache stats per replica (each replica owns its own
    /// store — DESIGN.md §8 — and republishes after every admission).
    cache_by_replica: BTreeMap<usize, CacheStats>,
    /// Failure counters by [`FailureKind`] label (DESIGN.md §13).
    /// Low-frequency — failures take the global lock, not a shard.
    failures: BTreeMap<&'static str, u64>,
    /// Latest health state per replica (`"up"`/`"draining"`/`"down"`,
    /// latest-value semantics like the cache gauges).
    health_by_replica: BTreeMap<usize, &'static str>,
}

/// Shared serving-metrics registry (one per router, shared by replicas).
#[derive(Debug)]
pub struct MetricsRegistry {
    shards: Vec<Mutex<Shard>>,
    global: Mutex<Global>,
    /// Fast-path guard so records skip the global lock once the
    /// elapsed stamp exists.
    started_stamped: AtomicBool,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry {
            shards: (0..N_SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            global: Mutex::new(Global::default()),
            started_stamped: AtomicBool::new(false),
        }
    }
}

/// One request's measurements.
#[derive(Debug, Clone, Copy)]
pub struct RequestMetrics {
    /// Whether the request completed successfully.
    pub ok: bool,
    /// Replica that served the request (shard selector).
    pub replica: usize,
    /// Committed output tokens.
    pub tokens: usize,
    /// Wall-clock decode time (prefill excluded), seconds.
    pub decode_seconds: f64,
    /// Wall-clock prefill time, seconds.
    pub prefill_seconds: f64,
    /// Router-submit → replica-admission wait, seconds.
    pub queue_seconds: f64,
    /// Router-submit → first committed token, seconds (the serving TTFT:
    /// queue + prefill + first verify round).
    pub ttft_seconds: f64,
    /// Mean accepted tokens per draft-verify cycle.
    pub tau: f64,
    /// Policy-relaxed acceptances across the generation.
    pub relaxed_accepts: f64,
    /// verification-policy family (`VerifyPolicy::name`)
    pub policy: &'static str,
    /// speculative-method family (`SpecMethod::name`)
    pub method: &'static str,
}

impl MetricsRegistry {
    /// Fresh, empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn stamp_started(&self) {
        if self.started_stamped.load(Ordering::Relaxed) {
            return;
        }
        let mut g = relock(&self.global);
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
        self.started_stamped.store(true, Ordering::Relaxed);
    }

    fn shard(&self, replica: usize) -> &Mutex<Shard> {
        &self.shards[replica % N_SHARDS]
    }

    /// Record one finished request (errors count separately).
    pub fn record(&self, m: RequestMetrics) {
        self.stamp_started();
        let mut g = relock(self.shard(m.replica));
        if !m.ok {
            g.requests_err += 1;
            return;
        }
        g.requests_ok += 1;
        g.tokens_out += m.tokens as u64;
        g.decode_ms.record(m.decode_seconds * 1e3);
        g.prefill_ms.record(m.prefill_seconds * 1e3);
        g.queue_ms.record(m.queue_seconds * 1e3);
        g.ttft_ms.record(m.ttft_seconds * 1e3);
        if m.tokens > 0 {
            // TPOT: decode time amortized over committed tokens
            g.tpot_ms.record(m.decode_seconds * 1e3 / m.tokens as f64);
            g.per_token_us
                .record(m.decode_seconds * 1e6 / m.tokens as f64);
        }
        if m.tau > 0.0 {
            g.tau.record(m.tau);
        }
        g.relaxed.record(m.relaxed_accepts);
        if !m.policy.is_empty() {
            let p = g.by_policy.entry(m.policy).or_default();
            p.requests += 1;
            p.tokens += m.tokens as u64;
            if m.tau > 0.0 {
                p.tau.record(m.tau);
            }
            p.relaxed.record(m.relaxed_accepts);
        }
        if !m.method.is_empty() {
            let a = g.by_method.entry(m.method).or_default();
            a.requests += 1;
            a.tokens += m.tokens as u64;
            if m.tau > 0.0 {
                a.tau.record(m.tau);
            }
            a.ttft_ms.record(m.ttft_seconds * 1e3);
        }
    }

    /// Record one batched device dispatch that ran with `occupied` live
    /// lanes (DESIGN.md §9.5). Called by the replica's batched loop once
    /// per round dispatch; the resulting histogram is the occupancy
    /// distribution the `"batch"` snapshot object reports.
    pub fn record_occupancy(&self, replica: usize, occupied: usize) {
        self.stamp_started();
        let mut g = relock(self.shard(replica));
        *g.occupancy.entry(occupied).or_insert(0) += 1;
    }

    /// Record one sequence's probe-surfaced decision margins, split by
    /// outcome: `samples` pairs the decisive position's z2/z1 target
    /// margin with its [`AcceptFlag`]. Strict accepts, policy-relaxed
    /// accepts and rejects land in separate histograms per
    /// policy × method — the low-margin-regime picture.
    pub fn record_margins(
        &self,
        replica: usize,
        policy: &'static str,
        method: &'static str,
        samples: &[(f64, AcceptFlag)],
    ) {
        if samples.is_empty() {
            return;
        }
        self.stamp_started();
        let mut g = relock(self.shard(replica));
        let agg = g.margins.entry((policy, method)).or_default();
        for &(margin, flag) in samples {
            match flag {
                AcceptFlag::Exact => agg.exact.record(margin),
                AcceptFlag::Relaxed => agg.relaxed.record(margin),
                AcceptFlag::Reject => agg.reject.record(margin),
            }
        }
    }

    /// Record one engine device turn (the [`RoundEvent`] stream the
    /// replicas install on their runners).
    pub fn record_round(&self, replica: usize, ev: &RoundEvent) {
        self.stamp_started();
        let mut g = relock(self.shard(replica));
        let r = &mut g.rounds;
        r.turns += 1;
        r.rounds += ev.rounds;
        r.drafted += ev.drafted;
        r.accepted += ev.accepted;
        if ev.relaxed > 0 {
            r.relaxed_turns += 1;
        }
        r.wall_ms.record(ev.wall_ms);
        r.accepted_per_turn.record(ev.accepted as f64);
    }

    /// Count one failure outcome (DESIGN.md §13). Failures are
    /// low-frequency relative to requests, so they take the global
    /// lock instead of a shard — one counter per [`FailureKind`],
    /// exported as the `"failures"` snapshot object and the
    /// `mars_failures_total{kind=...}` Prometheus series.
    pub fn record_failure(&self, kind: FailureKind) {
        let mut g = relock(&self.global);
        *g.failures.entry(kind.as_str()).or_insert(0) += 1;
    }

    /// Current count for one failure kind (drain/chaos assertions).
    pub fn failure_count(&self, kind: FailureKind) -> u64 {
        relock(&self.global)
            .failures
            .get(kind.as_str())
            .copied()
            .unwrap_or(0)
    }

    /// Publish one replica's health state (`"up"` / `"draining"` /
    /// `"down"`) — latest-value gauge semantics, exported as the
    /// `"health"` snapshot object and `mars_replica_health` series.
    pub fn record_health(&self, replica: usize, state: &'static str) {
        let mut g = relock(&self.global);
        g.health_by_replica.insert(replica, state);
    }

    /// Publish one replica's prefix-cache stats (the replica re-sends its
    /// whole [`CacheStats`] gauge set; the registry keeps the latest per
    /// replica and sums across replicas in [`snapshot_json`]).
    ///
    /// [`snapshot_json`]: MetricsRegistry::snapshot_json
    pub fn record_cache(&self, replica: usize, stats: CacheStats) {
        let mut g = relock(&self.global);
        g.cache_by_replica.insert(replica, stats);
    }

    /// Zero every counter, histogram and the `started` elapsed stamp
    /// (the `{"cmd":"metrics","reset":true}` RPC and the bench serve
    /// `--reset` scraper use this between waves so scenarios do not
    /// smear). Cache gauges clear too; replicas republish them on their
    /// next admission.
    pub fn reset(&self) {
        // global first: a racing stamp_started after this point re-arms
        // the elapsed clock for the new wave, which is what reset means
        let mut g = relock(&self.global);
        g.started = None;
        g.cache_by_replica.clear();
        // failure counters zero between waves; health is a live gauge
        // of current replica state, so it survives the reset
        g.failures.clear();
        self.started_stamped.store(false, Ordering::Relaxed);
        drop(g);
        for s in &self.shards {
            *relock(s) = Shard::default();
        }
    }

    /// Merge every shard into one (snapshot-time reduction).
    fn merged(&self) -> Shard {
        let mut all = Shard::default();
        for s in &self.shards {
            all.merge(&relock(s));
        }
        all
    }

    /// Resident bytes of the registry's metric storage — O(buckets ×
    /// live key set), independent of request volume.
    pub fn approx_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| relock(s).approx_bytes())
            .sum()
    }

    /// Aggregate snapshot as JSON (served by the `metrics` RPC and printed
    /// by `mars serve` on shutdown).
    pub fn snapshot_json(&self) -> Value {
        let g = self.merged();
        let (elapsed, cache_agg, failures, health) = {
            let gl = relock(&self.global);
            let elapsed = gl
                .started
                .map(|t| t.elapsed().as_secs_f64())
                .unwrap_or(0.0)
                .max(1e-9);
            let mut agg = CacheStats::default();
            for s in gl.cache_by_replica.values() {
                agg.hits += s.hits;
                agg.misses += s.misses;
                agg.insertions += s.insertions;
                agg.evictions += s.evictions;
                agg.tokens_saved += s.tokens_saved;
                agg.bytes_resident += s.bytes_resident;
                agg.entries += s.entries;
            }
            (
                elapsed,
                agg,
                gl.failures.clone(),
                gl.health_by_replica.clone(),
            )
        };
        let mut o = Value::obj();
        o.set("requests_ok", Value::Num(g.requests_ok as f64));
        o.set("requests_err", Value::Num(g.requests_err as f64));
        o.set("tokens_out", Value::Num(g.tokens_out as f64));
        o.set(
            "throughput_tok_s",
            Value::Num(g.tokens_out as f64 / elapsed),
        );
        o.set(
            "throughput_req_s",
            Value::Num(g.requests_ok as f64 / elapsed),
        );
        o.set("decode_ms_p50", Value::Num(g.decode_ms.p50()));
        o.set("decode_ms_p99", Value::Num(g.decode_ms.p99()));
        o.set("decode_ms_mean", Value::Num(g.decode_ms.mean()));
        o.set("prefill_ms_mean", Value::Num(g.prefill_ms.mean()));
        o.set("queue_ms_p50", Value::Num(g.queue_ms.p50()));
        o.set("queue_ms_p99", Value::Num(g.queue_ms.p99()));
        o.set("ttft_ms_p50", Value::Num(g.ttft_ms.p50()));
        o.set("ttft_ms_p99", Value::Num(g.ttft_ms.p99()));
        o.set("tpot_ms_p50", Value::Num(g.tpot_ms.p50()));
        o.set("tpot_ms_p99", Value::Num(g.tpot_ms.p99()));
        o.set(
            "per_token_us_p50",
            Value::Num(g.per_token_us.quantile(0.5)),
        );
        o.set("tau_mean", Value::Num(g.tau.mean()));
        o.set("relaxed_accepts_mean", Value::Num(g.relaxed.mean()));
        let mut pol = Value::obj();
        for (name, agg) in &g.by_policy {
            let mut p = Value::obj();
            p.set("requests", Value::Num(agg.requests as f64));
            p.set("tokens", Value::Num(agg.tokens as f64));
            p.set("tau_mean", Value::Num(agg.tau.mean()));
            p.set("relaxed_mean", Value::Num(agg.relaxed.mean()));
            pol.set(name, p);
        }
        o.set("policy", pol);
        let mut met = Value::obj();
        for (name, agg) in &g.by_method {
            let mut m = Value::obj();
            m.set("requests", Value::Num(agg.requests as f64));
            m.set("tokens", Value::Num(agg.tokens as f64));
            m.set("tau_mean", Value::Num(agg.tau.mean()));
            m.set("ttft_ms_p50", Value::Num(agg.ttft_ms.p50()));
            m.set("ttft_ms_p99", Value::Num(agg.ttft_ms.p99()));
            met.set(name, m);
        }
        o.set("method", met);
        let mut cache = Value::obj();
        cache.set("hits", Value::Num(cache_agg.hits as f64));
        cache.set("misses", Value::Num(cache_agg.misses as f64));
        cache.set("hit_rate", Value::Num(cache_agg.hit_rate()));
        cache.set("tokens_saved", Value::Num(cache_agg.tokens_saved as f64));
        cache.set("insertions", Value::Num(cache_agg.insertions as f64));
        cache.set("evictions", Value::Num(cache_agg.evictions as f64));
        cache.set(
            "bytes_resident",
            Value::Num(cache_agg.bytes_resident as f64),
        );
        cache.set("entries", Value::Num(cache_agg.entries as f64));
        o.set("cache", cache);
        // failure counters + health gauges (DESIGN.md §13): emitted
        // only once something failed / a replica published health, so
        // pre-existing snapshot consumers see no new keys on the happy
        // path
        if !failures.is_empty() {
            let mut f = Value::obj();
            for (kind, n) in &failures {
                f.set(kind, Value::Num(*n as f64));
            }
            o.set("failures", f);
        }
        if !health.is_empty() {
            let mut h = Value::obj();
            for (replica, state) in &health {
                h.set(
                    &replica.to_string(),
                    Value::Str((*state).to_string()),
                );
            }
            o.set("health", h);
        }
        let dispatches: u64 = g.occupancy.values().sum();
        if dispatches > 0 {
            let lane_rounds: u64 = g
                .occupancy
                .iter()
                .map(|(occ, n)| *occ as u64 * n)
                .sum();
            let mut hist = Value::obj();
            for (occ, n) in &g.occupancy {
                hist.set(&occ.to_string(), Value::Num(*n as f64));
            }
            let mut batch = Value::obj();
            batch.set("dispatches", Value::Num(dispatches as f64));
            // mean occupied lanes per dispatch — the §9.5 amortization
            // factor (device_calls/token shrinks by roughly this)
            batch.set(
                "occupancy_mean",
                Value::Num(lane_rounds as f64 / dispatches as f64),
            );
            batch.set("occupancy_hist", hist);
            o.set("batch", batch);
        }
        if !g.margins.is_empty() {
            let mut margin = Value::obj();
            for ((policy, method), agg) in &g.margins {
                let mut per_outcome = Value::obj();
                for (outcome, h) in [
                    ("exact", &agg.exact),
                    ("relaxed", &agg.relaxed),
                    ("reject", &agg.reject),
                ] {
                    let mut v = Value::obj();
                    v.set("count", Value::Num(h.count() as f64));
                    v.set("mean", Value::Num(h.mean()));
                    v.set("p50", Value::Num(h.p50()));
                    v.set("p90", Value::Num(h.p90()));
                    per_outcome.set(outcome, v);
                }
                // nested policy -> method -> outcome objects
                let entry = match margin.get(*policy) {
                    Some(v) => v.clone(),
                    None => Value::obj(),
                };
                let mut entry = entry;
                entry.set(method, per_outcome);
                margin.set(policy, entry);
            }
            o.set("margin", margin);
        }
        if g.rounds.turns > 0 {
            let r = &g.rounds;
            let mut rounds = Value::obj();
            rounds.set("turns", Value::Num(r.turns as f64));
            rounds.set("rounds", Value::Num(r.rounds as f64));
            rounds.set("drafted", Value::Num(r.drafted as f64));
            rounds.set("accepted", Value::Num(r.accepted as f64));
            rounds.set("relaxed_turns", Value::Num(r.relaxed_turns as f64));
            rounds.set("wall_ms_p50", Value::Num(r.wall_ms.p50()));
            rounds.set("wall_ms_p99", Value::Num(r.wall_ms.p99()));
            rounds.set(
                "accepted_per_turn_mean",
                Value::Num(r.accepted_per_turn.mean()),
            );
            o.set("rounds", rounds);
        }
        o
    }

    /// Prometheus text exposition 0.0.4 of the merged snapshot (served
    /// by the `{"cmd":"prom"}` RPC and the `--prom-addr` endpoint).
    pub fn render_prometheus(&self) -> String {
        let g = self.merged();
        let gl = relock(&self.global);
        let elapsed = gl
            .started
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        let mut agg = CacheStats::default();
        for s in gl.cache_by_replica.values() {
            agg.hits += s.hits;
            agg.misses += s.misses;
            agg.tokens_saved += s.tokens_saved;
            agg.bytes_resident += s.bytes_resident;
            agg.entries += s.entries;
        }
        let failures = gl.failures.clone();
        let health = gl.health_by_replica.clone();
        drop(gl);
        let mut p = PromText::new();
        p.counter("mars_requests_ok", &[], g.requests_ok as f64);
        p.counter("mars_requests_err", &[], g.requests_err as f64);
        p.counter("mars_tokens_out", &[], g.tokens_out as f64);
        p.gauge("mars_uptime_seconds", &[], elapsed);
        p.gauge("mars_tau_mean", &[], g.tau.mean());
        p.counter("mars_relaxed_accepts_total", &[], g.relaxed.sum());
        for (name, h) in [
            ("mars_ttft_ms", &g.ttft_ms),
            ("mars_tpot_ms", &g.tpot_ms),
            ("mars_queue_ms", &g.queue_ms),
            ("mars_decode_ms", &g.decode_ms),
        ] {
            p.histogram(name, &[], h, &LAT_BOUNDS_MS);
        }
        for (name, agg) in &g.by_policy {
            p.counter(
                "mars_policy_requests",
                &[("policy", name)],
                agg.requests as f64,
            );
            p.gauge(
                "mars_policy_tau_mean",
                &[("policy", name)],
                agg.tau.mean(),
            );
        }
        for (name, agg) in &g.by_method {
            p.counter(
                "mars_method_requests",
                &[("method", name)],
                agg.requests as f64,
            );
        }
        for ((policy, method), agg) in &g.margins {
            for (outcome, h) in [
                ("exact", &agg.exact),
                ("relaxed", &agg.relaxed),
                ("reject", &agg.reject),
            ] {
                p.histogram(
                    "mars_margin",
                    &[
                        ("policy", policy),
                        ("method", method),
                        ("outcome", outcome),
                    ],
                    h,
                    &MARGIN_BOUNDS,
                );
            }
        }
        if g.rounds.turns > 0 {
            p.counter("mars_round_turns", &[], g.rounds.turns as f64);
            p.counter(
                "mars_round_relaxed_turns",
                &[],
                g.rounds.relaxed_turns as f64,
            );
            p.histogram(
                "mars_round_wall_ms",
                &[],
                &g.rounds.wall_ms,
                &LAT_BOUNDS_MS,
            );
        }
        let dispatches: u64 = g.occupancy.values().sum();
        if dispatches > 0 {
            p.counter("mars_batch_dispatches", &[], dispatches as f64);
        }
        for (kind, n) in &failures {
            p.counter("mars_failures_total", &[("kind", kind)], *n as f64);
        }
        for (replica, state) in &health {
            // numeric severity gauge: 0 up, 1 draining, 2 down — easy
            // to alert on (`max(mars_replica_health) >= 2`)
            let code = match *state {
                "up" => 0.0,
                "draining" => 1.0,
                _ => 2.0,
            };
            p.gauge(
                "mars_replica_health",
                &[("replica", &replica.to_string()), ("state", state)],
                code,
            );
        }
        p.gauge("mars_cache_hits", &[], agg.hits as f64);
        p.gauge("mars_cache_misses", &[], agg.misses as f64);
        p.gauge("mars_cache_tokens_saved", &[], agg.tokens_saved as f64);
        p.gauge(
            "mars_cache_bytes_resident",
            &[],
            agg.bytes_resident as f64,
        );
        p.finish()
    }

    /// Total requests recorded (ok + errors) — used by drain loops.
    pub fn requests_done(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                let g = relock(s);
                g.requests_ok + g.requests_err
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(tokens: usize, decode: f64) -> RequestMetrics {
        RequestMetrics {
            ok: true,
            replica: 0,
            tokens,
            decode_seconds: decode,
            prefill_seconds: 0.01,
            queue_seconds: 0.002,
            ttft_seconds: 0.02,
            tau: 5.0,
            relaxed_accepts: 2.0,
            policy: "mars",
            method: "eagle_tree",
        }
    }

    #[test]
    fn records_and_aggregates() {
        let r = MetricsRegistry::new();
        r.record(m(10, 0.1));
        r.record(m(30, 0.3));
        let v = r.snapshot_json();
        assert_eq!(v.get("requests_ok").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("tokens_out").unwrap().as_usize(), Some(40));
        assert_eq!(v.get("tau_mean").unwrap().as_f64(), Some(5.0));
        assert!(v.get("decode_ms_p99").unwrap().as_f64().unwrap() >= 100.0);
        // ttft is the measured submit→first-token time, 20 ms here (a
        // constant stream is quantile-exact: min/max clamping)
        let ttft = v.get("ttft_ms_p50").unwrap().as_f64().unwrap();
        assert!((ttft - 20.0).abs() < 1e-9, "{ttft}");
        // tpot = decode / tokens = 10 ms/tok for both samples
        for q in ["tpot_ms_p50", "tpot_ms_p99"] {
            let tpot = v.get(q).unwrap().as_f64().unwrap();
            assert!((tpot - 10.0).abs() < 1e-9, "{q} = {tpot}");
        }
    }

    #[test]
    fn shards_merge_across_replicas() {
        let r = MetricsRegistry::new();
        for replica in 0..20 {
            r.record(RequestMetrics { replica, ..m(10, 0.1) });
        }
        let v = r.snapshot_json();
        assert_eq!(v.get("requests_ok").unwrap().as_usize(), Some(20));
        assert_eq!(v.get("tokens_out").unwrap().as_usize(), Some(200));
        assert_eq!(
            v.path(&["policy", "mars", "requests"]).unwrap().as_usize(),
            Some(20)
        );
        assert_eq!(r.requests_done(), 20);
    }

    #[test]
    fn per_method_breakout() {
        let r = MetricsRegistry::new();
        r.record(m(10, 0.1));
        r.record(RequestMetrics { method: "pld", tau: 2.0, ..m(20, 0.2) });
        let v = r.snapshot_json();
        let met = v.get("method").unwrap();
        assert_eq!(
            met.path(&["eagle_tree", "requests"]).unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            met.path(&["pld", "tokens"]).unwrap().as_usize(),
            Some(20)
        );
        assert_eq!(
            met.path(&["pld", "tau_mean"]).unwrap().as_f64(),
            Some(2.0)
        );
        // ttft breakout: both samples stamped 20 ms in m()
        let ttft = met
            .path(&["eagle_tree", "ttft_ms_p50"])
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((ttft - 20.0).abs() < 1e-9, "{ttft}");
    }

    #[test]
    fn per_policy_breakout() {
        let r = MetricsRegistry::new();
        r.record(m(10, 0.1));
        r.record(RequestMetrics {
            policy: "strict",
            relaxed_accepts: 0.0,
            ..m(20, 0.2)
        });
        let v = r.snapshot_json();
        let pol = v.get("policy").unwrap();
        assert_eq!(
            pol.path(&["mars", "requests"]).unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            pol.path(&["strict", "tokens"]).unwrap().as_usize(),
            Some(20)
        );
        assert_eq!(
            pol.path(&["strict", "relaxed_mean"]).unwrap().as_f64(),
            Some(0.0)
        );
    }

    #[test]
    fn margin_histograms_split_by_outcome() {
        let r = MetricsRegistry::new();
        // no margins recorded -> no "margin" object at all
        assert!(r.snapshot_json().get("margin").is_none());
        r.record_margins(
            0,
            "mars",
            "eagle_tree",
            &[
                (0.95, AcceptFlag::Relaxed),
                (0.92, AcceptFlag::Relaxed),
                (0.99, AcceptFlag::Exact),
                (0.30, AcceptFlag::Reject),
            ],
        );
        // a second replica's samples for the same pair must merge in
        r.record_margins(1, "mars", "eagle_tree", &[(0.91, AcceptFlag::Relaxed)]);
        let v = r.snapshot_json();
        let mk = |outcome: &str, field: &str| {
            v.path(&["margin", "mars", "eagle_tree", outcome, field])
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert_eq!(mk("relaxed", "count"), 3.0);
        assert_eq!(mk("exact", "count"), 1.0);
        assert_eq!(mk("reject", "count"), 1.0);
        // exact means survive bucketing
        let mean = mk("relaxed", "mean");
        assert!((mean - (0.95 + 0.92 + 0.91) / 3.0).abs() < 1e-12, "{mean}");
        // relaxed accepts concentrate high, rejects low — the paper's
        // low-margin-regime split must be visible in the snapshot
        assert!(mk("relaxed", "p50") > mk("reject", "p50"));
    }

    #[test]
    fn round_events_aggregate() {
        let r = MetricsRegistry::new();
        assert!(r.snapshot_json().get("rounds").is_none());
        for turn in 0..4u64 {
            r.record_round(
                0,
                &RoundEvent {
                    turn,
                    rounds: 1,
                    drafted: 7,
                    accepted: 5,
                    relaxed: u64::from(turn % 2 == 0),
                    wall_ms: 2.0,
                    ..Default::default()
                },
            );
        }
        let v = r.snapshot_json();
        assert_eq!(
            v.path(&["rounds", "turns"]).unwrap().as_usize(),
            Some(4)
        );
        assert_eq!(
            v.path(&["rounds", "drafted"]).unwrap().as_usize(),
            Some(28)
        );
        assert_eq!(
            v.path(&["rounds", "relaxed_turns"]).unwrap().as_usize(),
            Some(2)
        );
        let wall = v.path(&["rounds", "wall_ms_p50"]).unwrap().as_f64().unwrap();
        assert!((wall - 2.0).abs() < 1e-9, "{wall}");
    }

    #[test]
    fn cache_gauges_sum_across_replicas() {
        let r = MetricsRegistry::new();
        let one = CacheStats {
            hits: 3,
            misses: 1,
            insertions: 4,
            evictions: 0,
            tokens_saved: 120,
            bytes_resident: 1000,
            entries: 4,
        };
        r.record_cache(0, one);
        r.record_cache(1, CacheStats { hits: 1, misses: 3, ..one });
        // a replica republishing replaces its previous gauge set
        r.record_cache(0, one);
        let v = r.snapshot_json();
        let c = v.get("cache").unwrap();
        assert_eq!(c.get("hits").unwrap().as_usize(), Some(4));
        assert_eq!(c.get("misses").unwrap().as_usize(), Some(4));
        assert_eq!(c.get("tokens_saved").unwrap().as_usize(), Some(240));
        assert_eq!(c.get("bytes_resident").unwrap().as_usize(), Some(2000));
        let rate = c.get("hit_rate").unwrap().as_f64().unwrap();
        assert!((rate - 0.5).abs() < 1e-9, "{rate}");
    }

    #[test]
    fn occupancy_histogram_tracks_batched_dispatches() {
        let r = MetricsRegistry::new();
        // no batched dispatches recorded -> no "batch" object at all
        assert!(r.snapshot_json().get("batch").is_none());
        for occ in [1, 4, 4, 4, 3] {
            r.record_occupancy(0, occ);
        }
        let v = r.snapshot_json();
        let b = v.get("batch").unwrap();
        assert_eq!(b.get("dispatches").unwrap().as_usize(), Some(5));
        let mean = b.get("occupancy_mean").unwrap().as_f64().unwrap();
        assert!((mean - 16.0 / 5.0).abs() < 1e-9, "{mean}");
        let hist = b.get("occupancy_hist").unwrap();
        assert_eq!(hist.get("4").unwrap().as_usize(), Some(3));
        assert_eq!(hist.get("1").unwrap().as_usize(), Some(1));
        assert!(hist.get("2").is_none());
    }

    #[test]
    fn errors_counted_separately() {
        let r = MetricsRegistry::new();
        r.record(RequestMetrics { ok: false, ..m(0, 0.0) });
        let v = r.snapshot_json();
        assert_eq!(v.get("requests_err").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("requests_ok").unwrap().as_usize(), Some(0));
        assert_eq!(r.requests_done(), 1);
    }

    #[test]
    fn reset_zeroes_counters_and_elapsed_stamp() {
        let r = MetricsRegistry::new();
        r.record(m(10, 0.1));
        r.record_occupancy(0, 4);
        r.record_margins(0, "mars", "eagle_tree", &[(0.9, AcceptFlag::Relaxed)]);
        r.record_cache(0, CacheStats { hits: 1, ..CacheStats::default() });
        assert_eq!(r.requests_done(), 1);
        r.reset();
        let v = r.snapshot_json();
        assert_eq!(v.get("requests_ok").unwrap().as_usize(), Some(0));
        assert!(v.get("batch").is_none());
        assert!(v.get("margin").is_none());
        assert_eq!(v.path(&["cache", "hits"]).unwrap().as_usize(), Some(0));
        assert_eq!(r.requests_done(), 0);
        // the elapsed stamp re-arms: the next record restarts the clock
        r.record(m(10, 0.1));
        assert_eq!(r.requests_done(), 1);
    }

    #[test]
    fn prometheus_exposition_carries_margin_histograms() {
        let r = MetricsRegistry::new();
        r.record(m(10, 0.1));
        r.record_margins(
            0,
            "mars",
            "eagle_tree",
            &[(0.95, AcceptFlag::Relaxed), (0.2, AcceptFlag::Reject)],
        );
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE mars_requests_ok counter"), "{text}");
        assert!(text.contains("mars_requests_ok 1"), "{text}");
        assert!(text.contains("# TYPE mars_margin histogram"), "{text}");
        assert!(
            text.contains(
                "mars_margin_bucket{policy=\"mars\",method=\"eagle_tree\",\
                 outcome=\"relaxed\",le=\"+Inf\"} 1"
            ),
            "{text}"
        );
        assert!(text.contains("mars_ttft_ms_count 1"), "{text}");
    }

    #[test]
    fn failure_counters_and_health_gauges_export() {
        let r = MetricsRegistry::new();
        // nothing failed -> neither object exists in the snapshot
        assert!(r.snapshot_json().get("failures").is_none());
        assert!(r.snapshot_json().get("health").is_none());
        r.record_failure(FailureKind::DispatchFailed);
        r.record_failure(FailureKind::DispatchFailed);
        r.record_failure(FailureKind::LaneRequeued);
        r.record_health(0, "up");
        r.record_health(1, "down");
        r.record_health(1, "draining"); // latest value wins
        assert_eq!(r.failure_count(FailureKind::DispatchFailed), 2);
        assert_eq!(r.failure_count(FailureKind::Shed), 0);
        let v = r.snapshot_json();
        assert_eq!(
            v.path(&["failures", "dispatch_failed"]).unwrap().as_usize(),
            Some(2)
        );
        assert_eq!(
            v.path(&["failures", "lane_requeued"]).unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            v.path(&["health", "1"]).unwrap().as_str(),
            Some("draining")
        );
        let text = r.render_prometheus();
        assert!(
            text.contains(
                "mars_failures_total{kind=\"dispatch_failed\"} 2"
            ),
            "{text}"
        );
        assert!(
            text.contains(
                "mars_replica_health{replica=\"1\",state=\"draining\"} 1"
            ),
            "{text}"
        );
        // reset zeroes failure counters; health is a live gauge of
        // current replica state, so it survives
        r.reset();
        let v = r.snapshot_json();
        assert!(v.get("failures").is_none());
        assert_eq!(v.path(&["health", "0"]).unwrap().as_str(), Some("up"));
    }

    #[test]
    fn memory_is_bounded_by_buckets_not_requests() {
        let r = MetricsRegistry::new();
        for i in 0..1_000usize {
            r.record(RequestMetrics { replica: i % 4, ..m(10, 0.1) });
        }
        let before = r.approx_bytes();
        // a further million requests over the same key set must not
        // grow the registry at all — O(buckets), not O(requests)
        for i in 0..1_000_000usize {
            r.record(RequestMetrics { replica: i % 4, ..m(10, 0.1) });
        }
        let after = r.approx_bytes();
        assert_eq!(
            before, after,
            "registry grew with request volume: {before} -> {after}"
        );
        // fixed ceiling: 8 shards of fixed histograms + one live
        // policy/method/margin key set stays well under 8 MB
        assert!(after < 8 << 20, "registry resident bytes {after}");
    }
}
