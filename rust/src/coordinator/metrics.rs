//! Serving metrics registry: latency histograms (including the serving
//! percentiles TTFT — submit → first committed token — and TPOT — decode
//! time per output token), throughput counters and speculative-decoding
//! acceptance statistics, shared across replicas via a mutex (recording
//! is a handful of float ops; not hot enough to need sharding on this
//! substrate). Acceptance stats are additionally broken out per
//! verification-policy family so a mixed-policy workload exposes the
//! per-rule τ / relaxation picture, and per speculative-method family
//! (`SpecMethod::name`) so a mixed-method workload exposes the per-
//! drafter τ / TTFT picture, and per-replica prefix-cache gauges
//! (hits/misses/tokens-saved/bytes-resident — DESIGN.md §8) summed into
//! one `"cache"` object. `mars bench serve` reports the same
//! quantities measured client-side (see BENCHMARKS.md).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::cache::CacheStats;
use crate::util::json::Value;
use crate::util::stats::{LogHistogram, Summary};

/// Per-policy-family aggregates (keyed by `VerifyPolicy::name`).
#[derive(Debug, Default)]
struct PolicyAgg {
    requests: u64,
    tokens: u64,
    tau: Summary,
    relaxed: Summary,
}

/// Per-method-family aggregates (keyed by `SpecMethod::name`).
#[derive(Debug, Default)]
struct MethodAgg {
    requests: u64,
    tokens: u64,
    tau: Summary,
    ttft_ms: Summary,
}

#[derive(Debug, Default)]
struct Inner {
    started: Option<Instant>,
    requests_ok: u64,
    requests_err: u64,
    tokens_out: u64,
    decode_ms: Summary,
    prefill_ms: Summary,
    queue_ms: Summary,
    ttft_ms: Summary,
    tpot_ms: Summary,
    per_token_us: LogHistogram,
    tau: Summary,
    relaxed: Summary,
    by_policy: BTreeMap<&'static str, PolicyAgg>,
    by_method: BTreeMap<&'static str, MethodAgg>,
    /// Batch-occupancy histogram (DESIGN.md §9.5): how many batched
    /// dispatches ran with N occupied lanes. Solo/interleaved replicas
    /// record nothing here; under `--batch` every round dispatch counts
    /// once, so the distribution shows how full the batch actually ran
    /// (the amortization factor the occupancy sweep measures).
    occupancy: BTreeMap<usize, u64>,
    /// Latest prefix-cache stats per replica (each replica owns its own
    /// store — DESIGN.md §8 — and republishes after every admission).
    cache_by_replica: BTreeMap<usize, CacheStats>,
}

/// Shared serving-metrics registry (one per router, shared by replicas).
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

/// One request's measurements.
#[derive(Debug, Clone, Copy)]
pub struct RequestMetrics {
    /// Whether the request completed successfully.
    pub ok: bool,
    /// Committed output tokens.
    pub tokens: usize,
    /// Wall-clock decode time (prefill excluded), seconds.
    pub decode_seconds: f64,
    /// Wall-clock prefill time, seconds.
    pub prefill_seconds: f64,
    /// Router-submit → replica-admission wait, seconds.
    pub queue_seconds: f64,
    /// Router-submit → first committed token, seconds (the serving TTFT:
    /// queue + prefill + first verify round).
    pub ttft_seconds: f64,
    /// Mean accepted tokens per draft-verify cycle.
    pub tau: f64,
    /// Policy-relaxed acceptances across the generation.
    pub relaxed_accepts: f64,
    /// verification-policy family (`VerifyPolicy::name`)
    pub policy: &'static str,
    /// speculative-method family (`SpecMethod::name`)
    pub method: &'static str,
}

impl MetricsRegistry {
    /// Fresh, empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one finished request (errors count separately).
    pub fn record(&self, m: RequestMetrics) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
        if !m.ok {
            g.requests_err += 1;
            return;
        }
        g.requests_ok += 1;
        g.tokens_out += m.tokens as u64;
        g.decode_ms.push(m.decode_seconds * 1e3);
        g.prefill_ms.push(m.prefill_seconds * 1e3);
        g.queue_ms.push(m.queue_seconds * 1e3);
        g.ttft_ms.push(m.ttft_seconds * 1e3);
        if m.tokens > 0 {
            // TPOT: decode time amortized over committed tokens
            g.tpot_ms.push(m.decode_seconds * 1e3 / m.tokens as f64);
            g.per_token_us
                .record(m.decode_seconds * 1e6 / m.tokens as f64);
        }
        if m.tau > 0.0 {
            g.tau.push(m.tau);
        }
        g.relaxed.push(m.relaxed_accepts);
        if !m.policy.is_empty() {
            let p = g.by_policy.entry(m.policy).or_default();
            p.requests += 1;
            p.tokens += m.tokens as u64;
            if m.tau > 0.0 {
                p.tau.push(m.tau);
            }
            p.relaxed.push(m.relaxed_accepts);
        }
        if !m.method.is_empty() {
            let a = g.by_method.entry(m.method).or_default();
            a.requests += 1;
            a.tokens += m.tokens as u64;
            if m.tau > 0.0 {
                a.tau.push(m.tau);
            }
            a.ttft_ms.push(m.ttft_seconds * 1e3);
        }
    }

    /// Record one batched device dispatch that ran with `occupied` live
    /// lanes (DESIGN.md §9.5). Called by the replica's batched loop once
    /// per round dispatch; the resulting histogram is the occupancy
    /// distribution the `"batch"` snapshot object reports.
    pub fn record_occupancy(&self, occupied: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
        *g.occupancy.entry(occupied).or_insert(0) += 1;
    }

    /// Publish one replica's prefix-cache stats (the replica re-sends its
    /// whole [`CacheStats`] gauge set; the registry keeps the latest per
    /// replica and sums across replicas in [`snapshot_json`]).
    ///
    /// [`snapshot_json`]: MetricsRegistry::snapshot_json
    pub fn record_cache(&self, replica: usize, stats: CacheStats) {
        let mut g = self.inner.lock().unwrap();
        g.cache_by_replica.insert(replica, stats);
    }

    /// Aggregate snapshot as JSON (served by the `metrics` RPC and printed
    /// by `mars serve` on shutdown).
    pub fn snapshot_json(&self) -> Value {
        let g = self.inner.lock().unwrap();
        let elapsed = g
            .started
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        let mut o = Value::obj();
        o.set("requests_ok", Value::Num(g.requests_ok as f64));
        o.set("requests_err", Value::Num(g.requests_err as f64));
        o.set("tokens_out", Value::Num(g.tokens_out as f64));
        o.set(
            "throughput_tok_s",
            Value::Num(g.tokens_out as f64 / elapsed),
        );
        o.set(
            "throughput_req_s",
            Value::Num(g.requests_ok as f64 / elapsed),
        );
        o.set("decode_ms_p50", Value::Num(g.decode_ms.p50()));
        o.set("decode_ms_p99", Value::Num(g.decode_ms.p99()));
        o.set("decode_ms_mean", Value::Num(g.decode_ms.mean()));
        o.set("prefill_ms_mean", Value::Num(g.prefill_ms.mean()));
        o.set("queue_ms_p50", Value::Num(g.queue_ms.p50()));
        o.set("queue_ms_p99", Value::Num(g.queue_ms.p99()));
        o.set("ttft_ms_p50", Value::Num(g.ttft_ms.p50()));
        o.set("ttft_ms_p99", Value::Num(g.ttft_ms.p99()));
        o.set("tpot_ms_p50", Value::Num(g.tpot_ms.p50()));
        o.set("tpot_ms_p99", Value::Num(g.tpot_ms.p99()));
        o.set(
            "per_token_us_p50",
            Value::Num(g.per_token_us.quantile(0.5)),
        );
        o.set("tau_mean", Value::Num(g.tau.mean()));
        o.set("relaxed_accepts_mean", Value::Num(g.relaxed.mean()));
        let mut pol = Value::obj();
        for (name, agg) in &g.by_policy {
            let mut p = Value::obj();
            p.set("requests", Value::Num(agg.requests as f64));
            p.set("tokens", Value::Num(agg.tokens as f64));
            p.set("tau_mean", Value::Num(agg.tau.mean()));
            p.set("relaxed_mean", Value::Num(agg.relaxed.mean()));
            pol.set(name, p);
        }
        o.set("policy", pol);
        let mut met = Value::obj();
        for (name, agg) in &g.by_method {
            let mut m = Value::obj();
            m.set("requests", Value::Num(agg.requests as f64));
            m.set("tokens", Value::Num(agg.tokens as f64));
            m.set("tau_mean", Value::Num(agg.tau.mean()));
            m.set("ttft_ms_p50", Value::Num(agg.ttft_ms.p50()));
            m.set("ttft_ms_p99", Value::Num(agg.ttft_ms.p99()));
            met.set(name, m);
        }
        o.set("method", met);
        let mut agg = CacheStats::default();
        for s in g.cache_by_replica.values() {
            agg.hits += s.hits;
            agg.misses += s.misses;
            agg.insertions += s.insertions;
            agg.evictions += s.evictions;
            agg.tokens_saved += s.tokens_saved;
            agg.bytes_resident += s.bytes_resident;
            agg.entries += s.entries;
        }
        let mut cache = Value::obj();
        cache.set("hits", Value::Num(agg.hits as f64));
        cache.set("misses", Value::Num(agg.misses as f64));
        cache.set("hit_rate", Value::Num(agg.hit_rate()));
        cache.set("tokens_saved", Value::Num(agg.tokens_saved as f64));
        cache.set("insertions", Value::Num(agg.insertions as f64));
        cache.set("evictions", Value::Num(agg.evictions as f64));
        cache.set("bytes_resident", Value::Num(agg.bytes_resident as f64));
        cache.set("entries", Value::Num(agg.entries as f64));
        o.set("cache", cache);
        let dispatches: u64 = g.occupancy.values().sum();
        if dispatches > 0 {
            let lane_rounds: u64 = g
                .occupancy
                .iter()
                .map(|(occ, n)| *occ as u64 * n)
                .sum();
            let mut hist = Value::obj();
            for (occ, n) in &g.occupancy {
                hist.set(&occ.to_string(), Value::Num(*n as f64));
            }
            let mut batch = Value::obj();
            batch.set("dispatches", Value::Num(dispatches as f64));
            // mean occupied lanes per dispatch — the §9.5 amortization
            // factor (device_calls/token shrinks by roughly this)
            batch.set(
                "occupancy_mean",
                Value::Num(lane_rounds as f64 / dispatches as f64),
            );
            batch.set("occupancy_hist", hist);
            o.set("batch", batch);
        }
        o
    }

    /// Total requests recorded (ok + errors) — used by drain loops.
    pub fn requests_done(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.requests_ok + g.requests_err
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(tokens: usize, decode: f64) -> RequestMetrics {
        RequestMetrics {
            ok: true,
            tokens,
            decode_seconds: decode,
            prefill_seconds: 0.01,
            queue_seconds: 0.002,
            ttft_seconds: 0.02,
            tau: 5.0,
            relaxed_accepts: 2.0,
            policy: "mars",
            method: "eagle_tree",
        }
    }

    #[test]
    fn records_and_aggregates() {
        let r = MetricsRegistry::new();
        r.record(m(10, 0.1));
        r.record(m(30, 0.3));
        let v = r.snapshot_json();
        assert_eq!(v.get("requests_ok").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("tokens_out").unwrap().as_usize(), Some(40));
        assert_eq!(v.get("tau_mean").unwrap().as_f64(), Some(5.0));
        assert!(v.get("decode_ms_p99").unwrap().as_f64().unwrap() >= 100.0);
        // ttft is the measured submit→first-token time, 20 ms here
        let ttft = v.get("ttft_ms_p50").unwrap().as_f64().unwrap();
        assert!((ttft - 20.0).abs() < 1e-9, "{ttft}");
        // tpot = decode / tokens = 10 ms/tok for both samples
        for q in ["tpot_ms_p50", "tpot_ms_p99"] {
            let tpot = v.get(q).unwrap().as_f64().unwrap();
            assert!((tpot - 10.0).abs() < 1e-9, "{q} = {tpot}");
        }
    }

    #[test]
    fn per_method_breakout() {
        let r = MetricsRegistry::new();
        r.record(m(10, 0.1));
        r.record(RequestMetrics { method: "pld", tau: 2.0, ..m(20, 0.2) });
        let v = r.snapshot_json();
        let met = v.get("method").unwrap();
        assert_eq!(
            met.path(&["eagle_tree", "requests"]).unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            met.path(&["pld", "tokens"]).unwrap().as_usize(),
            Some(20)
        );
        assert_eq!(
            met.path(&["pld", "tau_mean"]).unwrap().as_f64(),
            Some(2.0)
        );
        // ttft breakout: both samples stamped 20 ms in m()
        let ttft = met
            .path(&["eagle_tree", "ttft_ms_p50"])
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((ttft - 20.0).abs() < 1e-9, "{ttft}");
    }

    #[test]
    fn per_policy_breakout() {
        let r = MetricsRegistry::new();
        r.record(m(10, 0.1));
        r.record(RequestMetrics {
            policy: "strict",
            relaxed_accepts: 0.0,
            ..m(20, 0.2)
        });
        let v = r.snapshot_json();
        let pol = v.get("policy").unwrap();
        assert_eq!(
            pol.path(&["mars", "requests"]).unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            pol.path(&["strict", "tokens"]).unwrap().as_usize(),
            Some(20)
        );
        assert_eq!(
            pol.path(&["strict", "relaxed_mean"]).unwrap().as_f64(),
            Some(0.0)
        );
    }

    #[test]
    fn cache_gauges_sum_across_replicas() {
        let r = MetricsRegistry::new();
        let one = CacheStats {
            hits: 3,
            misses: 1,
            insertions: 4,
            evictions: 0,
            tokens_saved: 120,
            bytes_resident: 1000,
            entries: 4,
        };
        r.record_cache(0, one);
        r.record_cache(1, CacheStats { hits: 1, misses: 3, ..one });
        // a replica republishing replaces its previous gauge set
        r.record_cache(0, one);
        let v = r.snapshot_json();
        let c = v.get("cache").unwrap();
        assert_eq!(c.get("hits").unwrap().as_usize(), Some(4));
        assert_eq!(c.get("misses").unwrap().as_usize(), Some(4));
        assert_eq!(c.get("tokens_saved").unwrap().as_usize(), Some(240));
        assert_eq!(c.get("bytes_resident").unwrap().as_usize(), Some(2000));
        let rate = c.get("hit_rate").unwrap().as_f64().unwrap();
        assert!((rate - 0.5).abs() < 1e-9, "{rate}");
    }

    #[test]
    fn occupancy_histogram_tracks_batched_dispatches() {
        let r = MetricsRegistry::new();
        // no batched dispatches recorded -> no "batch" object at all
        assert!(r.snapshot_json().get("batch").is_none());
        for occ in [1, 4, 4, 4, 3] {
            r.record_occupancy(occ);
        }
        let v = r.snapshot_json();
        let b = v.get("batch").unwrap();
        assert_eq!(b.get("dispatches").unwrap().as_usize(), Some(5));
        let mean = b.get("occupancy_mean").unwrap().as_f64().unwrap();
        assert!((mean - 16.0 / 5.0).abs() < 1e-9, "{mean}");
        let hist = b.get("occupancy_hist").unwrap();
        assert_eq!(hist.get("4").unwrap().as_usize(), Some(3));
        assert_eq!(hist.get("1").unwrap().as_usize(), Some(1));
        assert!(hist.get("2").is_none());
    }

    #[test]
    fn errors_counted_separately() {
        let r = MetricsRegistry::new();
        r.record(RequestMetrics { ok: false, ..m(0, 0.0) });
        let v = r.snapshot_json();
        assert_eq!(v.get("requests_err").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("requests_ok").unwrap().as_usize(), Some(0));
        assert_eq!(r.requests_done(), 1);
    }
}
