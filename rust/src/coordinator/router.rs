//! Router: fronts N engine replicas and assigns requests by policy.
//! The vLLM-router analog (DESIGN.md §5): round-robin, least-loaded, or
//! prefix-affinity (hash the prompt head to the replica whose prefix
//! cache holds that conversation's snapshots — caches are per-replica
//! because PJRT handles are not `Send`; DESIGN.md §8). Submission is
//! non-blocking ([`Router::submit_opts`]) and returns a [`SubmitHandle`]
//! carrying the reply channel and the cooperative cancel flag; streaming
//! requests additionally thread a per-round delta sink down to the
//! replica's decode loop. Load accounting is exact: `queued_hint` is
//! incremented at submit and decremented by the replica's admission ack,
//! so `LeastLoaded` sees queued backlog, not just active slots.
//!
//! Failover (DESIGN.md §13): [`pick_replica`] skips `Down` replicas
//! (`PrefixAffinity` degrades to least-loaded-among-healthy when its
//! pinned replica is unhealthy), an all-replicas-down submit returns a
//! typed retriable error instead of hanging, and `--shed-above N`
//! rejects new work with `{"busy": true, "retry_after_ms": ...}` once
//! the queued backlog crosses the threshold.

// Serving-layer lint wall (DESIGN.md §11): a panic here takes the whole
// connection or replica down, so unwrap/expect are denied outright in
// non-test code — recover or propagate instead.
#![deny(clippy::unwrap_used, clippy::expect_used)]
#![cfg_attr(test, allow(clippy::unwrap_used, clippy::expect_used))]

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::metrics::{FailureKind, MetricsRegistry};
use crate::coordinator::replica::{
    EngineReplica, ReplicaConfig, ReplicaHealth,
};
use crate::coordinator::request::{
    Request, RequestId, Response, StreamSink, WorkItem,
};
use crate::engine::GenParams;
use crate::fault::FaultSpec;
use crate::obs::trace::TraceWriter;

/// Replica-assignment policy (`--route rr|ll|prefix`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Strict rotation across replicas.
    RoundRobin,
    /// Pick the replica with the fewest active + queued sequences.
    LeastLoaded,
    /// Hash the prompt head ([`crate::cache::key::affinity_hash`]) so
    /// every turn of one conversation lands on the replica whose prefix
    /// cache already holds its snapshots.
    PrefixAffinity,
}

impl RouterPolicy {
    /// Parse the CLI form (`rr`/`round_robin`, `ll`/`least_loaded`,
    /// `prefix`/`prefix_affinity`/`pa`).
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "rr" | "round_robin" | "round-robin" => Some(RouterPolicy::RoundRobin),
            "ll" | "least_loaded" | "least-loaded" => Some(RouterPolicy::LeastLoaded),
            "prefix" | "prefix_affinity" | "prefix-affinity" | "pa" => {
                Some(RouterPolicy::PrefixAffinity)
            }
            _ => None,
        }
    }
}

/// Pure replica-choice rule — unit-testable without live replicas.
/// `loads` are active + queued counts per replica, `rr` the round-robin
/// ticket, `prompt` the request text (only `PrefixAffinity` reads it),
/// `up[i]` whether replica `i` is routable (health != `Down`,
/// DESIGN.md §13). Returns `None` when every replica is down — the
/// caller replies with a typed retriable error instead of queueing onto
/// a corpse.
///
/// Failover semantics per policy:
/// * `RoundRobin` rotates across the routable replicas only;
/// * `LeastLoaded` takes the minimum over routable replicas;
/// * `PrefixAffinity` pins `affinity_hash(prompt) % n` while that
///   replica is routable and *degrades to least-loaded among the
///   routable* when it is not (the pinned replica's prefix cache is
///   gone with it — any healthy replica serves the turn cold).
pub fn pick_replica(
    policy: RouterPolicy,
    loads: &[usize],
    rr: usize,
    prompt: &str,
    up: &[bool],
) -> Option<usize> {
    let n = loads.len();
    let routable = |i: usize| up.get(i).copied().unwrap_or(true);
    let least_loaded = || {
        loads
            .iter()
            .enumerate()
            .filter(|&(i, _)| routable(i))
            .min_by_key(|&(_, &l)| l)
            .map(|(i, _)| i)
    };
    match policy {
        RouterPolicy::RoundRobin => {
            let alive: Vec<usize> = (0..n).filter(|&i| routable(i)).collect();
            (!alive.is_empty()).then(|| alive[rr % alive.len()])
        }
        RouterPolicy::LeastLoaded => least_loaded(),
        RouterPolicy::PrefixAffinity => {
            if n == 0 {
                return None;
            }
            let pinned =
                (crate::cache::key::affinity_hash(prompt) % n as u64) as usize;
            if routable(pinned) {
                Some(pinned)
            } else {
                least_loaded()
            }
        }
    }
}

/// Per-submission options (see [`Router::submit_opts`]).
#[derive(Default)]
pub struct SubmitOptions {
    /// Client-assigned correlation id echoed on replies and deltas;
    /// `None` lets the router assign a unique internal id.
    pub id: Option<RequestId>,
    /// Per-round delta sink for streaming requests.
    pub stream: Option<StreamSink>,
    /// The caller pinned `GenParams::rounds_per_call` itself (a wire
    /// request carried `"rounds_per_call"`/`"pack"`, even an explicit
    /// 1): the replica must not apply its `--pack` server default.
    pub pack_specified: bool,
    /// Per-request wall deadline in milliseconds from submission
    /// (`"deadline_ms"` on the wire; `None` lets the replica apply the
    /// server's `--deadline-ms` default).
    pub deadline_ms: Option<u64>,
}

/// Live handle to one submitted request.
pub struct SubmitHandle {
    /// Receives the single terminal [`Response`].
    pub rx: Receiver<Response>,
    /// Cooperative cancel flag: set it (any ordering) and the replica
    /// finalizes the request early with the committed prefix.
    pub cancel: Arc<AtomicBool>,
    /// The id replies and deltas will carry.
    pub id: RequestId,
}

/// Everything [`Router::start`] needs to spin up the serving topology —
/// one struct instead of the 9-positional-argument `start_traced` this
/// replaced, so the failure-semantics knobs (`fault`, `deadline_ms`,
/// `shed_above`, DESIGN.md §13) ride along without another signature
/// bump.
#[derive(Clone)]
pub struct RouterConfig {
    /// Compiled-artifact directory every replica loads.
    pub artifact_dir: std::path::PathBuf,
    /// Engine replica count (threads; min 1).
    pub replicas: usize,
    /// Interleaved sequence slots per replica.
    pub slots: usize,
    /// Force the host-roundtrip runtime (§Perf baseline).
    pub hostloop: bool,
    /// Replica-assignment policy (`--route`).
    pub policy: RouterPolicy,
    /// Per-replica prefix-cache budget (DESIGN.md §8).
    pub cache: crate::cache::CacheConfig,
    /// Server-side round-packing default (`--pack`, DESIGN.md §9.6).
    pub pack: usize,
    /// Cross-sequence batch width (`--batch`, DESIGN.md §9.5); 1 keeps
    /// the interleaved loop.
    pub batch: usize,
    /// Shared span-trace writer (`--trace FILE`, DESIGN.md §12).
    pub trace: Option<Arc<TraceWriter>>,
    /// Fault-injection spec (`--fault-plan`, DESIGN.md §13) installed on
    /// every replica runtime the spec applies to.
    pub fault: Option<FaultSpec>,
    /// Server-default per-request deadline (`--deadline-ms`): applied to
    /// requests that carry no `"deadline_ms"` of their own.
    pub deadline_ms: Option<u64>,
    /// Overload-shedding threshold (`--shed-above N`): once the queued
    /// backlog across replicas reaches N, new submissions are rejected
    /// with `{"busy": true, "retry_after_ms": ...}`.
    pub shed_above: Option<usize>,
}

impl RouterConfig {
    /// Config with every knob at its serving default (one replica, two
    /// slots, least-loaded routing, default cache, no packing, no
    /// batching, no trace, no faults, no deadline, no shedding).
    pub fn new(artifact_dir: &Path) -> RouterConfig {
        RouterConfig {
            artifact_dir: artifact_dir.to_path_buf(),
            replicas: 1,
            slots: 2,
            hostloop: false,
            policy: RouterPolicy::LeastLoaded,
            cache: crate::cache::CacheConfig::default(),
            pack: 1,
            batch: 1,
            trace: None,
            fault: None,
            deadline_ms: None,
            shed_above: None,
        }
    }
}

/// Front of the serving topology: owns the replicas and their queues.
pub struct Router {
    replicas: Vec<EngineReplica>,
    senders: Vec<Sender<WorkItem>>,
    policy: RouterPolicy,
    rr_next: AtomicUsize,
    next_id: AtomicU64,
    /// Overload-shedding threshold (see [`RouterConfig::shed_above`]).
    shed_above: Option<usize>,
    /// Shared serving-metrics registry (also served by `{"cmd":"metrics"}`).
    pub metrics: Arc<MetricsRegistry>,
}

impl Router {
    /// Spin up `cfg.replicas` engine threads and wait until every
    /// runtime has compiled its executables (a replica that cannot even
    /// start is a config error, not a fault to supervise — bail).
    pub fn start(cfg: RouterConfig) -> Result<Router> {
        let metrics = Arc::new(MetricsRegistry::new());
        let mut replicas = Vec::new();
        let mut senders = Vec::new();
        let mut readys: Vec<Receiver<Result<(), String>>> = Vec::new();
        for id in 0..cfg.replicas.max(1) {
            let (tx, rx) = channel::<WorkItem>();
            let (ready_tx, ready_rx) = channel();
            let rep = EngineReplica::spawn(
                id,
                ReplicaConfig {
                    artifact_dir: cfg.artifact_dir.clone(),
                    slots: cfg.slots,
                    hostloop: cfg.hostloop,
                    cache: cfg.cache,
                    pack: cfg.pack,
                    batch: cfg.batch,
                    trace: cfg.trace.clone(),
                    fault: cfg.fault.clone(),
                    deadline_ms: cfg.deadline_ms,
                },
                rx,
                metrics.clone(),
                ready_tx,
            );
            replicas.push(rep);
            senders.push(tx);
            readys.push(ready_rx);
        }
        for (i, r) in readys.iter().enumerate() {
            match r.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => bail!("replica {i} failed to start: {e}"),
                Err(_) => bail!("replica {i} died during startup"),
            }
        }
        Ok(Router {
            replicas,
            senders,
            policy: cfg.policy,
            rr_next: AtomicUsize::new(0),
            next_id: AtomicU64::new(1),
            shed_above: cfg.shed_above,
            metrics,
        })
    }

    /// Number of replicas behind this router.
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Total active + queued sequences across every replica — what a
    /// graceful shutdown polls down to zero before exiting.
    pub fn active_total(&self) -> usize {
        self.replicas.iter().map(|r| r.load()).sum()
    }

    /// Per-replica active + queued load (exact: queued items stay
    /// counted until the replica's admission ack).
    pub fn loads(&self) -> Vec<usize> {
        self.replicas.iter().map(|r| r.load()).collect()
    }

    /// Per-replica supervision health (DESIGN.md §13).
    pub fn healths(&self) -> Vec<ReplicaHealth> {
        self.replicas.iter().map(|r| r.health()).collect()
    }

    /// Queued-but-unadmitted backlog across every replica — the depth
    /// `--shed-above` compares against (active slots are working, not
    /// waiting; shedding is about the line, not the tills).
    pub fn queued_total(&self) -> usize {
        self.replicas.iter().map(|r| r.queued()).sum()
    }

    /// Should a new submission be shed right now (DESIGN.md §13)?
    /// Returns the `retry_after_ms` hint to reply with when yes: a
    /// deterministic back-off proportional to how far past the
    /// threshold the backlog is, so deeper overload pushes clients
    /// further away.
    pub fn should_shed(&self) -> Option<u64> {
        let threshold = self.shed_above?;
        let queued = self.queued_total();
        if queued >= threshold {
            let over = queued.saturating_sub(threshold) as u64;
            Some((50 * (over + 1)).min(5_000))
        } else {
            None
        }
    }

    fn pick(&self, prompt: &str) -> Option<usize> {
        let up: Vec<bool> = self
            .replicas
            .iter()
            .map(|r| r.health() != ReplicaHealth::Down)
            .collect();
        pick_replica(
            self.policy,
            &self.loads(),
            self.rr_next.fetch_add(1, Ordering::Relaxed),
            prompt,
            &up,
        )
    }

    /// Submit a request without blocking the caller: the reply channel,
    /// cancel flag and effective id come back in a [`SubmitHandle`]. This
    /// is what lets one connection pipeline many in-flight requests.
    pub fn submit_opts(
        &self,
        prompt: &str,
        params: GenParams,
        opts: SubmitOptions,
    ) -> SubmitHandle {
        let id = opts
            .id
            .unwrap_or_else(|| self.next_id.fetch_add(1, Ordering::Relaxed));
        let cancel = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel();
        let Some(idx) = self.pick(prompt) else {
            // every replica is Down: reply with a typed retriable error
            // immediately instead of queueing onto a corpse (the handle
            // contract is unchanged — the terminal reply just arrives
            // before the caller's first recv)
            self.metrics.record_failure(FailureKind::AllReplicasDown);
            let _ = tx.send(Response::retriable_error(
                id,
                "all replicas down; retry later",
            ));
            return SubmitHandle { rx, cancel, id };
        };
        self.replicas[idx]
            .queued_hint
            .fetch_add(1, Ordering::Relaxed);
        let item = WorkItem {
            request: Request {
                id,
                prompt: prompt.to_string(),
                params,
                stream: opts.stream.is_some(),
                pack_specified: opts.pack_specified,
                deadline_ms: opts.deadline_ms,
            },
            reply: tx,
            submitted_at: std::time::Instant::now(),
            stream: opts.stream,
            cancel: cancel.clone(),
            retries: 0,
        };
        // the hint stays up until the replica's admission ack (it
        // decrements after moving the item into an active slot, or after
        // replying with a prefill error), so least-loaded routing sees
        // queued backlog exactly — a burst spreads instead of piling onto
        // the first replica whose gauges had not caught up yet
        if let Err(failed) = self.senders[idx].send(item) {
            // replica gone: the receiver hung up and will never ack —
            // undo the hint so the dead replica doesn't look loaded, and
            // reply retriably instead of letting the request hang
            self.replicas[idx]
                .queued_hint
                .fetch_sub(1, Ordering::Relaxed);
            self.metrics.record_failure(FailureKind::ReplicaLost);
            let _ = failed
                .0
                .reply
                .send(Response::retriable_error(id, "replica queue closed"));
        }
        SubmitHandle { rx, cancel, id }
    }

    /// Submit a request; the response arrives on the returned channel.
    /// Programmatic [`GenParams`] are authoritative as given — the
    /// replica's `--pack` server default is a *wire* convenience and is
    /// not overlaid here.
    pub fn submit(
        &self,
        prompt: &str,
        params: GenParams,
    ) -> Receiver<Response> {
        let opts = SubmitOptions { pack_specified: true, ..Default::default() };
        self.submit_opts(prompt, params, opts).rx
    }

    /// Submit and wait.
    pub fn generate(&self, prompt: &str, params: GenParams) -> Response {
        match self.submit(prompt, params).recv() {
            Ok(r) => r,
            Err(_) => Response::from_error(0, "replica dropped request"),
        }
    }

    /// Submit-and-wait with a per-round delta sink: `stream` receives a
    /// [`crate::coordinator::request::StreamDelta`] every time a verify
    /// round commits new tokens, before the terminal response returns.
    pub fn generate_streaming(
        &self,
        prompt: &str,
        params: GenParams,
        stream: StreamSink,
    ) -> Response {
        let h = self.submit_opts(
            prompt,
            params,
            SubmitOptions {
                stream: Some(stream),
                pack_specified: true,
                ..Default::default()
            },
        );
        match h.rx.recv() {
            Ok(r) => r,
            Err(_) => Response::from_error(h.id, "replica dropped request"),
        }
    }

    /// Disconnect the queues and join every replica (drains active work).
    pub fn shutdown(mut self) {
        self.senders.clear(); // disconnect queues
        for r in &mut self.replicas {
            r.stop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const UP4: [bool; 4] = [true; 4];

    #[test]
    fn least_loaded_picks_the_min_under_skew() {
        // the queued_hint regression shape: replica 0 has a backlog that
        // only exact accounting exposes — the pick must not tie-break to 0
        assert_eq!(
            pick_replica(RouterPolicy::LeastLoaded, &[5, 0], 0, "", &[true; 2]),
            Some(1)
        );
        assert_eq!(
            pick_replica(RouterPolicy::LeastLoaded, &[3, 2, 7, 1], 0, "", &UP4),
            Some(3)
        );
        // ties go to the first minimum (stable)
        assert_eq!(
            pick_replica(RouterPolicy::LeastLoaded, &[2, 2, 2], 9, "", &[true; 3]),
            Some(0)
        );
    }

    #[test]
    fn round_robin_cycles() {
        for rr in 0..6 {
            assert_eq!(
                pick_replica(
                    RouterPolicy::RoundRobin,
                    &[0, 0, 0],
                    rr,
                    "",
                    &[true; 3]
                ),
                Some(rr % 3)
            );
        }
    }

    #[test]
    fn prefix_affinity_pins_conversations() {
        let loads = [0usize; 4];
        let turn1 = "Sys: be brief.\nU: capital of Zorland?\nB:";
        let turn2 = "Sys: be brief.\nU: capital of Zorland?\nB: Mirefal\n\
                     U: and of Quovia?\nB:";
        let a = pick_replica(RouterPolicy::PrefixAffinity, &loads, 0, turn1, &UP4)
            .unwrap();
        let b = pick_replica(RouterPolicy::PrefixAffinity, &loads, 7, turn2, &UP4)
            .unwrap();
        assert_eq!(a, b, "later turns must follow their conversation");
        assert!(a < 4);
        // load skew must not move an affinity pick
        let c = pick_replica(
            RouterPolicy::PrefixAffinity,
            &[9, 9, 9, 9],
            0,
            turn1,
            &UP4,
        )
        .unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn every_policy_skips_down_replicas() {
        // replica 0 is Down: no policy may route to it
        let up = [false, true, true];
        for rr in 0..6 {
            let got =
                pick_replica(RouterPolicy::RoundRobin, &[0, 0, 0], rr, "", &up)
                    .unwrap();
            assert_ne!(got, 0, "round-robin routed to a Down replica");
        }
        assert_eq!(
            pick_replica(RouterPolicy::LeastLoaded, &[0, 5, 3], 0, "", &up),
            Some(2),
            "least-loaded must take the min over routable replicas only"
        );
    }

    #[test]
    fn prefix_affinity_degrades_to_least_loaded_when_pinned_is_down() {
        let prompt = "Sys: be brief.\nU: capital of Zorland?\nB:";
        let pinned = pick_replica(
            RouterPolicy::PrefixAffinity,
            &[0; 4],
            0,
            prompt,
            &UP4,
        )
        .unwrap();
        // kill the pinned replica; load the others unevenly
        let mut up = UP4;
        up[pinned] = false;
        let mut loads = [7usize; 4];
        let fallback = (pinned + 1) % 4;
        loads[fallback] = 0;
        let got =
            pick_replica(RouterPolicy::PrefixAffinity, &loads, 0, prompt, &up)
                .unwrap();
        assert_eq!(got, fallback, "degraded pick must be least-loaded healthy");
    }

    #[test]
    fn all_replicas_down_yields_none() {
        let down = [false; 3];
        for policy in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PrefixAffinity,
        ] {
            assert_eq!(
                pick_replica(policy, &[0, 0, 0], 0, "hi", &down),
                None,
                "{policy:?} must not pick among corpses"
            );
        }
    }

    #[test]
    fn route_grammar_parses() {
        assert_eq!(RouterPolicy::parse("rr"), Some(RouterPolicy::RoundRobin));
        assert_eq!(
            RouterPolicy::parse("ll"),
            Some(RouterPolicy::LeastLoaded)
        );
        for s in ["prefix", "prefix_affinity", "prefix-affinity", "pa"] {
            assert_eq!(
                RouterPolicy::parse(s),
                Some(RouterPolicy::PrefixAffinity),
                "{s}"
            );
        }
        assert_eq!(RouterPolicy::parse("warp"), None);
    }
}
