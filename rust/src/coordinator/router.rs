//! Router: fronts N engine replicas and assigns requests by policy.
//! The vLLM-router analog (DESIGN.md §5): round-robin, least-loaded, or
//! prefix-affinity (hash the prompt head to the replica whose prefix
//! cache holds that conversation's snapshots — caches are per-replica
//! because PJRT handles are not `Send`; DESIGN.md §8). Submission is
//! non-blocking ([`Router::submit_opts`]) and returns a [`SubmitHandle`]
//! carrying the reply channel and the cooperative cancel flag; streaming
//! requests additionally thread a per-round delta sink down to the
//! replica's decode loop. Load accounting is exact: `queued_hint` is
//! incremented at submit and decremented by the replica's admission ack,
//! so `LeastLoaded` sees queued backlog, not just active slots.

// Serving-layer lint wall (DESIGN.md §11): a panic here takes the whole
// connection or replica down, so unwrap/expect are denied outright in
// non-test code — recover or propagate instead.
#![deny(clippy::unwrap_used, clippy::expect_used)]
#![cfg_attr(test, allow(clippy::unwrap_used, clippy::expect_used))]

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::metrics::MetricsRegistry;
use crate::coordinator::replica::{EngineReplica, ReplicaConfig};
use crate::coordinator::request::{
    Request, RequestId, Response, StreamSink, WorkItem,
};
use crate::engine::GenParams;
use crate::obs::trace::TraceWriter;

/// Replica-assignment policy (`--route rr|ll|prefix`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Strict rotation across replicas.
    RoundRobin,
    /// Pick the replica with the fewest active + queued sequences.
    LeastLoaded,
    /// Hash the prompt head ([`crate::cache::key::affinity_hash`]) so
    /// every turn of one conversation lands on the replica whose prefix
    /// cache already holds its snapshots.
    PrefixAffinity,
}

impl RouterPolicy {
    /// Parse the CLI form (`rr`/`round_robin`, `ll`/`least_loaded`,
    /// `prefix`/`prefix_affinity`/`pa`).
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "rr" | "round_robin" | "round-robin" => Some(RouterPolicy::RoundRobin),
            "ll" | "least_loaded" | "least-loaded" => Some(RouterPolicy::LeastLoaded),
            "prefix" | "prefix_affinity" | "prefix-affinity" | "pa" => {
                Some(RouterPolicy::PrefixAffinity)
            }
            _ => None,
        }
    }
}

/// Pure replica-choice rule — unit-testable without live replicas.
/// `loads` are active + queued counts per replica, `rr` the round-robin
/// ticket, `prompt` the request text (only `PrefixAffinity` reads it).
pub fn pick_replica(
    policy: RouterPolicy,
    loads: &[usize],
    rr: usize,
    prompt: &str,
) -> usize {
    let n = loads.len().max(1);
    match policy {
        RouterPolicy::RoundRobin => rr % n,
        RouterPolicy::LeastLoaded => loads
            .iter()
            .enumerate()
            .min_by_key(|(_, &l)| l)
            .map(|(i, _)| i)
            .unwrap_or(0),
        RouterPolicy::PrefixAffinity => {
            (crate::cache::key::affinity_hash(prompt) % n as u64) as usize
        }
    }
}

/// Per-submission options (see [`Router::submit_opts`]).
#[derive(Default)]
pub struct SubmitOptions {
    /// Client-assigned correlation id echoed on replies and deltas;
    /// `None` lets the router assign a unique internal id.
    pub id: Option<RequestId>,
    /// Per-round delta sink for streaming requests.
    pub stream: Option<StreamSink>,
    /// The caller pinned `GenParams::rounds_per_call` itself (a wire
    /// request carried `"rounds_per_call"`/`"pack"`, even an explicit
    /// 1): the replica must not apply its `--pack` server default.
    pub pack_specified: bool,
}

/// Live handle to one submitted request.
pub struct SubmitHandle {
    /// Receives the single terminal [`Response`].
    pub rx: Receiver<Response>,
    /// Cooperative cancel flag: set it (any ordering) and the replica
    /// finalizes the request early with the committed prefix.
    pub cancel: Arc<AtomicBool>,
    /// The id replies and deltas will carry.
    pub id: RequestId,
}

/// Front of the serving topology: owns the replicas and their queues.
pub struct Router {
    replicas: Vec<EngineReplica>,
    senders: Vec<Sender<WorkItem>>,
    policy: RouterPolicy,
    rr_next: AtomicUsize,
    next_id: AtomicU64,
    /// Shared serving-metrics registry (also served by `{"cmd":"metrics"}`).
    pub metrics: Arc<MetricsRegistry>,
}

impl Router {
    /// Spin up `n_replicas` engine threads and wait until every runtime
    /// has compiled its executables. `pack` is the server-side round
    /// packing default (`--pack`, DESIGN.md §9.6) replicas apply to
    /// requests that don't carry their own `"rounds_per_call"`; `batch`
    /// is the cross-sequence batch width (`--batch`, DESIGN.md §9.5) —
    /// replicas with batching-capable artifacts decode up to that many
    /// lanes per device dispatch, 1 keeps the interleaved loop.
    pub fn start(
        artifact_dir: &Path,
        n_replicas: usize,
        slots: usize,
        hostloop: bool,
        policy: RouterPolicy,
        cache: crate::cache::CacheConfig,
        pack: usize,
        batch: usize,
    ) -> Result<Router> {
        Router::start_traced(
            artifact_dir,
            n_replicas,
            slots,
            hostloop,
            policy,
            cache,
            pack,
            batch,
            None,
        )
    }

    /// [`Router::start`] with a shared span-trace writer (`mars serve
    /// --trace FILE`, DESIGN.md §12): every replica logs queue →
    /// prefill → round → commit lines for each request it serves.
    #[allow(clippy::too_many_arguments)]
    pub fn start_traced(
        artifact_dir: &Path,
        n_replicas: usize,
        slots: usize,
        hostloop: bool,
        policy: RouterPolicy,
        cache: crate::cache::CacheConfig,
        pack: usize,
        batch: usize,
        trace: Option<Arc<TraceWriter>>,
    ) -> Result<Router> {
        let metrics = Arc::new(MetricsRegistry::new());
        let mut replicas = Vec::new();
        let mut senders = Vec::new();
        let mut readys: Vec<Receiver<Result<(), String>>> = Vec::new();
        for id in 0..n_replicas.max(1) {
            let (tx, rx) = channel::<WorkItem>();
            let (ready_tx, ready_rx) = channel();
            let rep = EngineReplica::spawn(
                id,
                ReplicaConfig {
                    artifact_dir: artifact_dir.to_path_buf(),
                    slots,
                    hostloop,
                    cache,
                    pack,
                    batch,
                    trace: trace.clone(),
                },
                rx,
                metrics.clone(),
                ready_tx,
            );
            replicas.push(rep);
            senders.push(tx);
            readys.push(ready_rx);
        }
        for (i, r) in readys.iter().enumerate() {
            match r.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => bail!("replica {i} failed to start: {e}"),
                Err(_) => bail!("replica {i} died during startup"),
            }
        }
        Ok(Router {
            replicas,
            senders,
            policy,
            rr_next: AtomicUsize::new(0),
            next_id: AtomicU64::new(1),
            metrics,
        })
    }

    /// Number of replicas behind this router.
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Total active + queued sequences across every replica — what a
    /// graceful shutdown polls down to zero before exiting.
    pub fn active_total(&self) -> usize {
        self.replicas.iter().map(|r| r.load()).sum()
    }

    /// Per-replica active + queued load (exact: queued items stay
    /// counted until the replica's admission ack).
    pub fn loads(&self) -> Vec<usize> {
        self.replicas.iter().map(|r| r.load()).collect()
    }

    fn pick(&self, prompt: &str) -> usize {
        pick_replica(
            self.policy,
            &self.loads(),
            self.rr_next.fetch_add(1, Ordering::Relaxed),
            prompt,
        )
    }

    /// Submit a request without blocking the caller: the reply channel,
    /// cancel flag and effective id come back in a [`SubmitHandle`]. This
    /// is what lets one connection pipeline many in-flight requests.
    pub fn submit_opts(
        &self,
        prompt: &str,
        params: GenParams,
        opts: SubmitOptions,
    ) -> SubmitHandle {
        let id = opts
            .id
            .unwrap_or_else(|| self.next_id.fetch_add(1, Ordering::Relaxed));
        let cancel = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel();
        let idx = self.pick(prompt);
        self.replicas[idx]
            .queued_hint
            .fetch_add(1, Ordering::Relaxed);
        let item = WorkItem {
            request: Request {
                id,
                prompt: prompt.to_string(),
                params,
                stream: opts.stream.is_some(),
                pack_specified: opts.pack_specified,
            },
            reply: tx,
            submitted_at: std::time::Instant::now(),
            stream: opts.stream,
            cancel: cancel.clone(),
        };
        // the hint stays up until the replica's admission ack (it
        // decrements after moving the item into an active slot, or after
        // replying with a prefill error), so least-loaded routing sees
        // queued backlog exactly — a burst spreads instead of piling onto
        // the first replica whose gauges had not caught up yet
        if self.senders[idx].send(item).is_err() {
            // replica gone: the receiver hung up and will never ack —
            // undo the hint so the dead replica doesn't look loaded
            self.replicas[idx]
                .queued_hint
                .fetch_sub(1, Ordering::Relaxed);
        }
        SubmitHandle { rx, cancel, id }
    }

    /// Submit a request; the response arrives on the returned channel.
    /// Programmatic [`GenParams`] are authoritative as given — the
    /// replica's `--pack` server default is a *wire* convenience and is
    /// not overlaid here.
    pub fn submit(
        &self,
        prompt: &str,
        params: GenParams,
    ) -> Receiver<Response> {
        let opts = SubmitOptions { pack_specified: true, ..Default::default() };
        self.submit_opts(prompt, params, opts).rx
    }

    /// Submit and wait.
    pub fn generate(&self, prompt: &str, params: GenParams) -> Response {
        match self.submit(prompt, params).recv() {
            Ok(r) => r,
            Err(_) => Response::from_error(0, "replica dropped request"),
        }
    }

    /// Submit-and-wait with a per-round delta sink: `stream` receives a
    /// [`crate::coordinator::request::StreamDelta`] every time a verify
    /// round commits new tokens, before the terminal response returns.
    pub fn generate_streaming(
        &self,
        prompt: &str,
        params: GenParams,
        stream: StreamSink,
    ) -> Response {
        let h = self.submit_opts(
            prompt,
            params,
            SubmitOptions {
                stream: Some(stream),
                pack_specified: true,
                ..Default::default()
            },
        );
        match h.rx.recv() {
            Ok(r) => r,
            Err(_) => Response::from_error(h.id, "replica dropped request"),
        }
    }

    /// Disconnect the queues and join every replica (drains active work).
    pub fn shutdown(mut self) {
        self.senders.clear(); // disconnect queues
        for r in &mut self.replicas {
            r.stop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_picks_the_min_under_skew() {
        // the queued_hint regression shape: replica 0 has a backlog that
        // only exact accounting exposes — the pick must not tie-break to 0
        assert_eq!(
            pick_replica(RouterPolicy::LeastLoaded, &[5, 0], 0, ""),
            1
        );
        assert_eq!(
            pick_replica(RouterPolicy::LeastLoaded, &[3, 2, 7, 1], 0, ""),
            3
        );
        // ties go to the first minimum (stable)
        assert_eq!(
            pick_replica(RouterPolicy::LeastLoaded, &[2, 2, 2], 9, ""),
            0
        );
    }

    #[test]
    fn round_robin_cycles() {
        for rr in 0..6 {
            assert_eq!(
                pick_replica(RouterPolicy::RoundRobin, &[0, 0, 0], rr, ""),
                rr % 3
            );
        }
    }

    #[test]
    fn prefix_affinity_pins_conversations() {
        let loads = [0usize; 4];
        let turn1 = "Sys: be brief.\nU: capital of Zorland?\nB:";
        let turn2 = "Sys: be brief.\nU: capital of Zorland?\nB: Mirefal\n\
                     U: and of Quovia?\nB:";
        let a = pick_replica(RouterPolicy::PrefixAffinity, &loads, 0, turn1);
        let b = pick_replica(RouterPolicy::PrefixAffinity, &loads, 7, turn2);
        assert_eq!(a, b, "later turns must follow their conversation");
        assert!(a < 4);
        // load skew must not move an affinity pick
        let c = pick_replica(
            RouterPolicy::PrefixAffinity,
            &[9, 9, 9, 9],
            0,
            turn1,
        );
        assert_eq!(a, c);
    }

    #[test]
    fn route_grammar_parses() {
        assert_eq!(RouterPolicy::parse("rr"), Some(RouterPolicy::RoundRobin));
        assert_eq!(
            RouterPolicy::parse("ll"),
            Some(RouterPolicy::LeastLoaded)
        );
        for s in ["prefix", "prefix_affinity", "prefix-affinity", "pa"] {
            assert_eq!(
                RouterPolicy::parse(s),
                Some(RouterPolicy::PrefixAffinity),
                "{s}"
            );
        }
        assert_eq!(RouterPolicy::parse("warp"), None);
    }
}
