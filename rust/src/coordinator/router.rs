//! Router: fronts N engine replicas and assigns requests by policy.
//! The vLLM-router analog (DESIGN.md §5): round-robin or least-loaded.

use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::metrics::MetricsRegistry;
use crate::coordinator::replica::{EngineReplica, ReplicaConfig};
use crate::coordinator::request::{Request, Response, WorkItem};
use crate::engine::GenParams;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastLoaded,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "rr" | "round_robin" | "round-robin" => Some(RouterPolicy::RoundRobin),
            "ll" | "least_loaded" | "least-loaded" => Some(RouterPolicy::LeastLoaded),
            _ => None,
        }
    }
}

pub struct Router {
    replicas: Vec<EngineReplica>,
    senders: Vec<Sender<WorkItem>>,
    policy: RouterPolicy,
    rr_next: AtomicUsize,
    next_id: AtomicU64,
    pub metrics: Arc<MetricsRegistry>,
}

impl Router {
    /// Spin up `n_replicas` engine threads and wait until every runtime
    /// has compiled its executables.
    pub fn start(
        artifact_dir: &Path,
        n_replicas: usize,
        slots: usize,
        hostloop: bool,
        policy: RouterPolicy,
    ) -> Result<Router> {
        let metrics = Arc::new(MetricsRegistry::new());
        let mut replicas = Vec::new();
        let mut senders = Vec::new();
        let mut readys: Vec<Receiver<Result<(), String>>> = Vec::new();
        for id in 0..n_replicas.max(1) {
            let (tx, rx) = channel::<WorkItem>();
            let (ready_tx, ready_rx) = channel();
            let rep = EngineReplica::spawn(
                id,
                ReplicaConfig {
                    artifact_dir: artifact_dir.to_path_buf(),
                    slots,
                    hostloop,
                },
                rx,
                metrics.clone(),
                ready_tx,
            );
            replicas.push(rep);
            senders.push(tx);
            readys.push(ready_rx);
        }
        for (i, r) in readys.iter().enumerate() {
            match r.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => bail!("replica {i} failed to start: {e}"),
                Err(_) => bail!("replica {i} died during startup"),
            }
        }
        Ok(Router {
            replicas,
            senders,
            policy,
            rr_next: AtomicUsize::new(0),
            next_id: AtomicU64::new(1),
            metrics,
        })
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    fn pick(&self) -> usize {
        match self.policy {
            RouterPolicy::RoundRobin => {
                self.rr_next.fetch_add(1, Ordering::Relaxed)
                    % self.replicas.len()
            }
            RouterPolicy::LeastLoaded => self
                .replicas
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.load())
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(
        &self,
        prompt: &str,
        params: GenParams,
    ) -> Receiver<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let idx = self.pick();
        self.replicas[idx]
            .queued_hint
            .fetch_add(1, Ordering::Relaxed);
        let item = WorkItem {
            request: Request { id, prompt: prompt.to_string(), params },
            reply: tx,
            submitted_at: std::time::Instant::now(),
        };
        // hint is decremented on admission approximation: the replica only
        // tracks active slots, so decrement when the send succeeds — the
        // queue-depth signal is best-effort by design.
        if self.senders[idx].send(item).is_err() {
            // replica gone: nothing else to do; receiver will hang up
        }
        self.replicas[idx]
            .queued_hint
            .fetch_sub(1, Ordering::Relaxed);
        rx
    }

    /// Submit and wait.
    pub fn generate(&self, prompt: &str, params: GenParams) -> Response {
        match self.submit(prompt, params).recv() {
            Ok(r) => r,
            Err(_) => Response::from_error(0, "replica dropped request"),
        }
    }

    pub fn shutdown(mut self) {
        self.senders.clear(); // disconnect queues
        for r in &mut self.replicas {
            r.stop();
        }
    }
}
