//! Engine replica: a dedicated OS thread owning one PJRT client.
//!
//! PJRT handles are not `Send`, so the `Runtime` is constructed *inside*
//! the thread and never crosses it. The replica runs one of two
//! continuous-batching loops:
//!
//! * **Interleaved** (default, `--batch 1` or artifacts without the
//!   `*_batch` programs): up to `slots` sequences are active at once and
//!   their rounds are interleaved round-robin over the single device —
//!   iteration-level scheduling, one sequence per dispatch.
//! * **Batched** (`--batch N` on batching-capable artifacts, DESIGN.md
//!   §9.5): one [`BatchRunner`] steps every live lane in a *single*
//!   device dispatch over the stacked state. Requests join at round
//!   boundaries (solo cache-aware prefill, then a `batch_join` splice)
//!   and leave at round boundaries (vLLM-style), so the dispatch
//!   overhead and the round's GEMMs amortize across the occupancy,
//!   which [`MetricsRegistry::record_occupancy`] histograms per
//!   dispatch. One dispatch runs one program, so lanes must share a
//!   method *family* ([`SpecMethod::batch_exec_name`]); admission is
//!   FIFO with family-mismatch skip-ahead ([`plan_admissions`]) —
//!   knobs, policies and temperatures are per-lane state and always
//!   mix.
//!
//! Both loops are packing-aware (DESIGN.md §9.6): one turn is one
//! *device call*, which under round packing fuses up to
//! `rounds_per_call` draft-verify rounds — so a packed slot holds the
//! device pack× longer per turn. Admission therefore caps streaming
//! slots at 1 (per-round delta granularity) and the engine's adaptive
//! controller runs every sequence's first turn unpacked (TTFT p99) and
//! shrinks the pack near the generation budget; in the batched loop the
//! pack budget is *per-lane* (`*_batch_multi`).

// Serving-layer lint wall (DESIGN.md §11): a panic here takes the whole
// connection or replica down, so unwrap/expect are denied outright in
// non-test code — recover or propagate instead.
#![deny(clippy::unwrap_used, clippy::expect_used)]
#![cfg_attr(test, allow(clippy::unwrap_used, clippy::expect_used))]

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{CacheConfig, SharedPrefixCache};
use crate::coordinator::metrics::{
    FailureKind, MetricsRegistry, RequestMetrics,
};
use crate::coordinator::request::{Response, StreamDelta, WorkItem};
use crate::engine::{BatchRunner, GenParams, GenResult, SeqRunner};
use crate::fault::{backoff_ms, FaultSpec};
use crate::obs::round::RoundEvent;
use crate::obs::trace::{Phase, TraceEvent, TraceWriter};
use crate::runtime::Runtime;
use crate::util::prng::Rng;
use crate::verify::AcceptFlag;

/// Requeue budget (DESIGN.md §13): how often an innocent batchmate of a
/// failed dispatch may be re-admitted before it fails retriable.
pub const MAX_REQUEUES: u32 = 3;

/// Pure requeue decision the batched supervisor applies per victim lane
/// (property-tested): `Some(n)` re-admits the lane with retry count `n`;
/// `None` means the budget is exhausted and the lane must get a
/// terminal *retriable* error instead — never a silent drop, never an
/// unbounded retry loop.
pub fn requeue_next_retries(retries: u32) -> Option<u32> {
    if retries >= MAX_REQUEUES {
        None
    } else {
        Some(retries + 1)
    }
}

/// Batch-session rebuild attempts before the replica goes `Down`.
const REBUILD_ATTEMPTS: u32 = 5;

/// First rebuild backoff bound, milliseconds (doubles per attempt).
const BACKOFF_BASE_MS: u64 = 10;

/// Rebuild backoff cap, milliseconds.
const BACKOFF_CAP_MS: u64 = 500;

/// Consecutive session-build failures at admission before the replica
/// declares itself dead rather than error-replying forever (a gray
/// failure the router would keep routing into).
const ADMISSION_FAILURE_LIMIT: usize = 5;

/// Seed for the supervisor's deterministic jitter PRNG (mixed with the
/// replica id; no wall clock involved).
const SUPERVISOR_SEED: u64 = 0x6d61_7273_7375_7065;

/// Replica health state (DESIGN.md §13), published by the serving loop
/// through an atomic so the router reads it lock-free on every pick.
///
/// * `Up` — serving normally.
/// * `Draining` — a fault poisoned the device state; the supervisor is
///   rebuilding the session (capped, jittered backoff) after requeueing
///   the innocent lanes. Still routable: queued work serves after the
///   rebuild.
/// * `Down` — the rebuild budget is exhausted. The thread stays alive
///   to drain its channel with typed *retriable* errors (no client
///   ever hangs on a corpse), but the router stops selecting it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// Serving normally.
    Up,
    /// Supervisor is rebuilding the device session.
    Draining,
    /// Dead for good; drains its queue with retriable errors.
    Down,
}

impl ReplicaHealth {
    /// Stable label (metrics gauge + trace `detail`).
    pub fn as_str(self) -> &'static str {
        match self {
            ReplicaHealth::Up => "up",
            ReplicaHealth::Draining => "draining",
            ReplicaHealth::Down => "down",
        }
    }

    fn from_u8(v: u8) -> ReplicaHealth {
        match v {
            0 => ReplicaHealth::Up,
            1 => ReplicaHealth::Draining,
            _ => ReplicaHealth::Down,
        }
    }
}

/// Handle to one engine-replica thread (see the module doc).
pub struct EngineReplica {
    /// Replica index (stable over the router's lifetime).
    pub id: usize,
    handle: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    /// Gauge of currently active (admitted, undone) sequences.
    pub active: Arc<AtomicUsize>,
    /// Submitted-but-not-admitted items: incremented by the router at
    /// submit, decremented by this replica's admission ack (after the
    /// item lands in an active slot or errors out), so `load()` counts
    /// queued backlog exactly instead of "best effort".
    pub queued_hint: Arc<AtomicUsize>,
    /// Current [`ReplicaHealth`] discriminant (DESIGN.md §13), written
    /// by the serving loop, read lock-free by the router on every pick.
    health: Arc<AtomicU8>,
}

/// Startup configuration for one replica.
pub struct ReplicaConfig {
    /// Directory holding the compiled HLO artifacts.
    pub artifact_dir: PathBuf,
    /// concurrent sequences interleaved on this replica
    pub slots: usize,
    /// Force the naive host-roundtrip runtime (§Perf baseline).
    pub hostloop: bool,
    /// Prefix-cache configuration: the store is built *inside* the
    /// replica thread and never leaves it, like the runtime it snapshots
    /// (DESIGN.md §8).
    pub cache: CacheConfig,
    /// Server-side round-packing default (`--pack`, DESIGN.md §9.6):
    /// requests whose wire object omitted `"rounds_per_call"` fuse up
    /// to this many rounds per device dispatch (an explicit
    /// `"rounds_per_call": 1` opts out instead of inheriting this). A
    /// packed step holds the device pack× longer per
    /// interleave turn, so the loop caps streaming slots at 1 (delta
    /// granularity) and the engine's controller caps the first turn of
    /// every sequence at 1 (TTFT p99).
    pub pack: usize,
    /// Cross-sequence batch width (`--batch`, DESIGN.md §9.5): when > 1
    /// and the artifacts carry the `*_batch` programs, the replica runs
    /// the batched loop with up to this many lanes live per dispatch
    /// (clamped to the layout's `batch_max`). 1 (or 0) keeps the
    /// interleaved loop; so do pre-batching artifact sets, silently —
    /// capability is detected, not configured.
    pub batch: usize,
    /// Shared JSONL span-trace writer (`mars serve --trace FILE`,
    /// DESIGN.md §12): when set, every request logs queue → prefill →
    /// round → commit lines through it. `None` = tracing off (the
    /// default); the replica pays nothing beyond the `Option` check.
    pub trace: Option<Arc<TraceWriter>>,
    /// Deterministic fault-injection spec (`--fault-plan`, DESIGN.md
    /// §13): built into a per-replica `FaultPlan` inside the thread and
    /// installed on the runtime's dispatch choke point. `None` = no
    /// injection (the default; the hot path pays one `Option` check).
    pub fault: Option<FaultSpec>,
    /// Server-side default deadline (`--deadline-ms`): requests whose
    /// wire object omitted `"deadline_ms"` inherit this budget,
    /// measured from router submit. `None` = no default.
    pub deadline_ms: Option<u64>,
}

impl EngineReplica {
    /// Spawn the replica thread. `ready` is signalled (with any startup
    /// error) once the runtime has compiled its executables.
    pub fn spawn(
        id: usize,
        cfg: ReplicaConfig,
        work: Receiver<WorkItem>,
        metrics: Arc<MetricsRegistry>,
        ready: std::sync::mpsc::Sender<Result<(), String>>,
    ) -> EngineReplica {
        let shutdown = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let queued_hint = Arc::new(AtomicUsize::new(0));
        let health = Arc::new(AtomicU8::new(ReplicaHealth::Up as u8));
        let sd = shutdown.clone();
        let act = active.clone();
        let queued = queued_hint.clone();
        let hlt = health.clone();
        let ready_err = ready.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("mars-replica-{id}"))
            .spawn(move || {
                let mut rt = match Runtime::new(&cfg.artifact_dir) {
                    Ok(rt) => {
                        let _ = ready.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                // deterministic fault injection (DESIGN.md §13): the
                // spec forks its seed per replica, so the same plan
                // replays the same fault schedule run over run
                if let Some(spec) = &cfg.fault {
                    if let Some(plan) = spec.build(id) {
                        rt.install_fault_plan(Arc::new(plan));
                    }
                }
                metrics.record_health(id, ReplicaHealth::Up.as_str());
                let ctl = ReplicaCtl {
                    shutdown: &sd,
                    active: &act,
                    queued: &queued,
                    health: &hlt,
                };
                replica_loop(id, &rt, &cfg, &work, &metrics, &ctl);
            });
        let handle = match spawned {
            Ok(h) => Some(h),
            Err(e) => {
                // no thread, no runtime: report through the ready channel
                // (Router::start bails) instead of panicking the caller
                let _ = ready_err
                    .send(Err(format!("spawn replica thread: {e}")));
                None
            }
        };
        EngineReplica {
            id,
            handle,
            shutdown,
            active,
            queued_hint,
            health,
        }
    }

    /// Current load (active sequences) — used by least-loaded routing.
    pub fn load(&self) -> usize {
        self.active.load(Ordering::Relaxed)
            + self.queued_hint.load(Ordering::Relaxed)
    }

    /// Current health state (lock-free; the router reads this on every
    /// pick and routes around `Down` replicas — DESIGN.md §13).
    pub fn health(&self) -> ReplicaHealth {
        ReplicaHealth::from_u8(self.health.load(Ordering::Relaxed))
    }

    /// Submitted-but-not-admitted backlog (overload shedding reads the
    /// sum of these across replicas).
    pub fn queued(&self) -> usize {
        self.queued_hint.load(Ordering::Relaxed)
    }

    /// Signal shutdown and join the replica thread (drains active work).
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EngineReplica {
    fn drop(&mut self) {
        self.stop();
    }
}

struct Active<'rt> {
    runner: SeqRunner<'rt>,
    item: WorkItem,
    /// submit → admission wait (stamped from `WorkItem::submitted_at`, so
    /// the metric measures actual queue time, not prefill)
    queue_seconds: f64,
    /// submit → first committed token (stamped after the first round that
    /// commits); the honest serving TTFT, including queue + prefill
    ttft_seconds: Option<f64>,
}

/// Shutdown flag + load/health gauges shared with the
/// [`EngineReplica`] handle — everything the serving loop publishes
/// back to the router side.
struct ReplicaCtl<'a> {
    shutdown: &'a AtomicBool,
    active: &'a AtomicUsize,
    /// submitted-but-not-admitted items (see [`EngineReplica::queued_hint`])
    queued: &'a AtomicUsize,
    /// current [`ReplicaHealth`] as its `u8` discriminant
    health: &'a AtomicU8,
}

impl ReplicaCtl<'_> {
    /// Publish a health transition on every surface at once: the
    /// router-visible atomic, the metrics gauge and the span trace.
    fn set_health(
        &self,
        id: usize,
        h: ReplicaHealth,
        metrics: &MetricsRegistry,
        trace: &Option<Arc<TraceWriter>>,
    ) {
        self.health.store(h as u8, Ordering::Relaxed);
        metrics.record_health(id, h.as_str());
        trace_span(trace, 0, id, Phase::Health, |ev| {
            ev.detail = Some(h.as_str().to_string());
        });
    }
}

/// Absolute deadline for one item (DESIGN.md §13): the request's own
/// `"deadline_ms"`, else the server default — measured from router
/// submit, so queue time counts against the budget.
fn item_deadline(item: &WorkItem, cfg: &ReplicaConfig) -> Option<Instant> {
    item.request
        .deadline_ms
        .or(cfg.deadline_ms)
        .map(|ms| item.submitted_at + Duration::from_millis(ms))
}

fn replica_loop(
    id: usize,
    rt: &Runtime,
    cfg: &ReplicaConfig,
    work: &Receiver<WorkItem>,
    metrics: &Arc<MetricsRegistry>,
    ctl: &ReplicaCtl<'_>,
) {
    // capability-gated dispatch (module doc): `--batch N` only engages
    // the batched loop on artifact sets that carry the `*_batch`
    // programs; everything else serves exactly as before
    if cfg.batch > 1 && rt.supports_batching() {
        batched_loop(id, rt, cfg, work, metrics, ctl)
    } else {
        interleaved_loop(id, rt, cfg, work, metrics, ctl)
    }
}

/// Error-path metrics for a request that never produced tokens.
fn failed_metrics(
    replica: usize,
    item: &WorkItem,
    queue_seconds: f64,
) -> RequestMetrics {
    RequestMetrics {
        ok: false,
        replica,
        tokens: 0,
        decode_seconds: 0.0,
        prefill_seconds: 0.0,
        queue_seconds,
        ttft_seconds: 0.0,
        tau: 0.0,
        relaxed_accepts: 0.0,
        policy: item.request.params.policy.name(),
        method: item.request.params.method.name(),
    }
}

/// Log one span line through the optional trace writer (DESIGN.md §12).
fn trace_span(
    trace: &Option<Arc<TraceWriter>>,
    id: u64,
    replica: usize,
    phase: Phase,
    fill: impl FnOnce(&mut TraceEvent),
) {
    if let Some(t) = trace {
        let mut ev = TraceEvent::new(t.now_ms(), id, replica, phase);
        fill(&mut ev);
        t.log(&ev);
    }
}

/// Terminal accounting of one successful request (shared by both
/// loops): request id + the queue/TTFT stamps the loop kept.
struct DoneStamps {
    rid: u64,
    queue_seconds: f64,
    ttft_seconds: f64,
}

/// Success-path bookkeeping shared by both loops: the counter record,
/// the probe-surfaced decision margins split by outcome (when the
/// request carried `"probe": true`), and the terminal trace line.
fn record_success(
    replica: usize,
    metrics: &MetricsRegistry,
    trace: &Option<Arc<TraceWriter>>,
    done: DoneStamps,
    params: &GenParams,
    result: &GenResult,
) {
    metrics.record(RequestMetrics {
        ok: true,
        replica,
        tokens: result.tokens.len(),
        decode_seconds: result.decode_seconds,
        prefill_seconds: result.prefill_seconds,
        queue_seconds: done.queue_seconds,
        ttft_seconds: done.ttft_seconds,
        tau: result.tau(),
        relaxed_accepts: result.snapshot.relaxed_accepts,
        policy: params.policy.name(),
        method: params.method.name(),
    });
    if let Some(p) = &result.probe {
        // decisive-position target margin z2/z1 — the same ratio the
        // offline analyze figures plot, now split by accept outcome
        let samples: Vec<(f64, AcceptFlag)> = p
            .entries
            .iter()
            .map(|e| {
                let m = if e.z1 > 0.0 && e.z2 > 0.0 {
                    (e.z2 / e.z1) as f64
                } else {
                    0.0
                };
                (m, e.flag)
            })
            .collect();
        metrics.record_margins(
            replica,
            params.policy.name(),
            params.method.name(),
            &samples,
        );
    }
    if result.deadline_exceeded {
        // the commit above is partial: the deadline fired at a round
        // boundary (DESIGN.md §13) — count it and log its own line
        metrics.record_failure(FailureKind::DeadlineExceeded);
        trace_span(trace, done.rid, replica, Phase::Deadline, |ev| {
            ev.tokens = Some(result.tokens.len() as u64);
        });
    }
    trace_span(trace, done.rid, replica, Phase::Commit, |ev| {
        ev.wall_ms = Some(result.decode_seconds * 1e3);
        ev.tokens = Some(result.tokens.len() as u64);
        ev.tau = Some(result.tau());
        ev.ok = Some(true);
        ev.policy = Some(params.policy.name().to_string());
        ev.method = Some(params.method.name().to_string());
    });
}

fn interleaved_loop(
    id: usize,
    rt: &Runtime,
    cfg: &ReplicaConfig,
    work: &Receiver<WorkItem>,
    metrics: &Arc<MetricsRegistry>,
    ctl: &ReplicaCtl<'_>,
) {
    let mut active: Vec<Active<'_>> = Vec::new();
    let slots = cfg.slots.max(1);
    // consecutive session-build failures (DESIGN.md §13): a streak
    // means the device is gone, not that one request was unlucky
    let mut admission_failures = 0usize;
    // the prefix cache lives and dies on this thread, like the runtime
    let cache: Option<SharedPrefixCache> = cfg.cache.build();
    let publish_cache = |cache: &Option<SharedPrefixCache>| {
        if let Some(c) = cache {
            metrics.record_cache(id, c.borrow().stats());
        }
    };
    loop {
        if ctl.shutdown.load(Ordering::Relaxed) && active.is_empty() {
            return;
        }
        // a replica whose session builds fail back-to-back is dead,
        // not degraded: go Down and drain with retriable errors
        // instead of error-replying forever (DESIGN.md §13)
        if admission_failures >= ADMISSION_FAILURE_LIMIT
            && active.is_empty()
        {
            ctl.set_health(id, ReplicaHealth::Down, metrics, &cfg.trace);
            metrics.record_failure(FailureKind::ReplicaDown);
            eprintln!(
                "replica {id}: {admission_failures} consecutive \
                 session failures; draining"
            );
            return drain_down(
                id,
                work,
                VecDeque::new(),
                metrics,
                ctl,
                &cfg.trace,
            );
        }
        // ---- admission: fill free slots -------------------------------
        while active.len() < slots {
            let mut item = if active.is_empty() {
                match work.recv_timeout(Duration::from_millis(50)) {
                    Ok(i) => i,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        if active.is_empty() {
                            return;
                        }
                        break;
                    }
                }
            } else {
                match work.try_recv() {
                    Ok(i) => i,
                    Err(_) => break,
                }
            };
            let queue_seconds =
                Instant::now().duration_since(item.submitted_at).as_secs_f64();
            let toks = crate::tokenizer::encode(&item.request.prompt);
            let req_cache = if item.request.params.cache {
                cache.clone()
            } else {
                None
            };
            // packing-aware admission (DESIGN.md §9.6): the server
            // `--pack` default applies only to requests that did not
            // pin "rounds_per_call" themselves (an explicit 1 opts out
            // of packing on a packed server)
            if !item.request.pack_specified
                && item.request.params.rounds_per_call <= 1
            {
                item.request.params.rounds_per_call = cfg.pack.max(1);
            }
            let admitted = SeqRunner::new_with_cache(
                rt,
                &toks,
                &item.request.params,
                cfg.hostloop,
                req_cache,
            );
            match admitted {
                Ok(mut runner) => {
                    admission_failures = 0;
                    // per-request deadline (DESIGN.md §13): measured
                    // from router submit, enforced at round boundaries
                    runner.set_deadline(item_deadline(&item, cfg));
                    // streaming slots never pack: a fused call would
                    // collapse per-round deltas into one chunk and hold
                    // the device pack× longer before the next delta
                    if item.request.stream {
                        runner.set_pack_cap(1);
                    }
                    // the reply echoes the packing that actually runs —
                    // 1 (suppressed) for streaming-capped slots, host
                    // drafters and artifacts without *_multi programs
                    item.request.params.rounds_per_call =
                        runner.effective_rounds_per_call();
                    // thread the per-round commit callback: decode only
                    // the newly committed tail (the byte-level tokenizer
                    // decodes tokens independently, so tail decodes
                    // concatenate to the full text) and push the delta
                    // into the request's sink
                    if let Some(mut sink) = item.stream.take() {
                        let id = item.request.id;
                        let mut seen_tokens = 0usize;
                        runner.set_on_commit(Box::new(move |committed: &[u32]| {
                            if committed.len() <= seen_tokens {
                                return;
                            }
                            let delta = crate::tokenizer::decode(
                                &committed[seen_tokens..],
                            );
                            seen_tokens = committed.len();
                            // special ids decode to "" — nothing to send
                            if !delta.is_empty() {
                                sink(StreamDelta {
                                    id,
                                    delta,
                                    tokens: committed.len(),
                                });
                            }
                        }));
                    }
                    // per-turn telemetry: fan each RoundEvent into the
                    // sharded registry and (when tracing) the span log
                    {
                        let mreg = metrics.clone();
                        let tr = cfg.trace.clone();
                        let rid = item.request.id;
                        runner.set_round_sink(Box::new(
                            move |ev: &RoundEvent| {
                                mreg.record_round(id, ev);
                                trace_span(
                                    &tr,
                                    rid,
                                    id,
                                    Phase::Round,
                                    |te| te.round = Some(*ev),
                                );
                            },
                        ));
                    }
                    trace_span(
                        &cfg.trace,
                        item.request.id,
                        id,
                        Phase::Queue,
                        |te| te.wall_ms = Some(queue_seconds * 1e3),
                    );
                    trace_span(
                        &cfg.trace,
                        item.request.id,
                        id,
                        Phase::Prefill,
                        |te| {
                            te.wall_ms =
                                Some(runner.prefill_seconds * 1e3);
                            te.cached_tokens =
                                Some(runner.prefill_cached_tokens as u64);
                        },
                    );
                    active.push(Active {
                        runner,
                        item,
                        queue_seconds,
                        ttft_seconds: None,
                    });
                    ctl.active.store(active.len(), Ordering::Relaxed);
                }
                Err(e) => {
                    admission_failures += 1;
                    metrics.record_failure(FailureKind::DispatchFailed);
                    let resp = Response::from_error(
                        item.request.id,
                        &format!("prefill failed: {e:#}"),
                    );
                    metrics.record(failed_metrics(id, &item, queue_seconds));
                    trace_span(
                        &cfg.trace,
                        item.request.id,
                        id,
                        Phase::Error,
                        |te| te.ok = Some(false),
                    );
                    let _ = item.reply.send(resp);
                }
            }
            // admission ack: only now does the item stop counting as
            // queued — the active gauge (or the error reply) already
            // reflects it, so `load()` never dips mid-admission
            ctl.queued.fetch_sub(1, Ordering::Relaxed);
            publish_cache(&cache);
            if admission_failures >= ADMISSION_FAILURE_LIMIT {
                break; // fall through to the dead-streak check above
            }
        }
        if active.is_empty() {
            continue;
        }
        // ---- one interleaved round per active sequence ----------------
        let mut i = 0;
        while i < active.len() {
            let a = &mut active[i];
            // cooperative cancel: finalize with the committed prefix
            // instead of stepping further
            let canceled =
                a.item.cancel.load(Ordering::Relaxed);
            let step_res = if canceled {
                a.runner.finish_early().map(Some)
            } else {
                a.runner.step()
            };
            if step_res.is_ok()
                && a.ttft_seconds.is_none()
                && a.runner.committed() > 0
            {
                a.ttft_seconds =
                    Some(a.item.submitted_at.elapsed().as_secs_f64());
            }
            let done = match step_res {
                Ok(Some(result)) => {
                    let params = &a.item.request.params;
                    let mut resp = Response::from_result(
                        a.item.request.id,
                        &result,
                        params,
                    );
                    resp.canceled = canceled;
                    record_success(
                        id,
                        metrics,
                        &cfg.trace,
                        DoneStamps {
                            rid: a.item.request.id,
                            queue_seconds: a.queue_seconds,
                            ttft_seconds: a.ttft_seconds.unwrap_or(
                                a.queue_seconds + result.prefill_seconds,
                            ),
                        },
                        params,
                        &result,
                    );
                    let _ = a.item.reply.send(resp);
                    true
                }
                Ok(None) => false,
                Err(e) => {
                    metrics.record_failure(FailureKind::DispatchFailed);
                    let _ = a.item.reply.send(Response::from_error(
                        a.item.request.id,
                        &format!("decode failed: {e:#}"),
                    ));
                    metrics
                        .record(failed_metrics(id, &a.item, a.queue_seconds));
                    trace_span(
                        &cfg.trace,
                        a.item.request.id,
                        id,
                        Phase::Error,
                        |te| te.ok = Some(false),
                    );
                    true
                }
            };
            if done {
                active.swap_remove(i);
                ctl.active.store(active.len(), Ordering::Relaxed);
                // finalize exported a fresh context snapshot — publish
                // the new residency/hit gauges
                publish_cache(&cache);
            } else {
                i += 1;
            }
        }
    }
}

/// Pure admission planner for the batched loop: given the occupancy,
/// the lane budget, the running family (`None` = empty batch) and the
/// queued requests' batched-program families in arrival order, return
/// the queue indices to admit at this round boundary, ascending.
///
/// Invariants (property-tested in `tests/property.rs`):
/// * never over-admits — at most `slots - occupancy` indices;
/// * every admitted index shares one family (the running one when the
///   batch is non-empty — one dispatch runs one program);
/// * FIFO within a family — an index is skipped only for family
///   mismatch, never while an earlier same-family arrival waits;
/// * no starvation of the queue head: when the batch is empty and a
///   slot is free, index 0 is always admitted, so once the batch drains
///   the oldest waiter defines the next family.
pub fn plan_admissions<'q>(
    occupancy: usize,
    slots: usize,
    running_family: Option<&'q str>,
    queued: &[&'q str],
) -> Vec<usize> {
    let mut free = slots.saturating_sub(occupancy);
    let mut family = running_family;
    let mut admit = Vec::new();
    for (i, fam) in queued.iter().enumerate() {
        if free == 0 {
            break;
        }
        if let Some(f) = family {
            if f != *fam {
                continue;
            }
        }
        family = Some(fam);
        admit.push(i);
        free -= 1;
    }
    admit
}

/// Per-slot request bookkeeping for the batched loop (the device-side
/// lane state lives inside the [`BatchRunner`]).
struct BatchLane {
    item: WorkItem,
    /// submit → admission wait
    queue_seconds: f64,
    /// submit → first committed token (stamped after the dispatch that
    /// first commits)
    ttft_seconds: Option<f64>,
}

/// Send the final response + metrics for one finished batched lane.
fn deliver_batched(
    id: usize,
    lane: BatchLane,
    result: anyhow::Result<GenResult>,
    canceled: bool,
    metrics: &MetricsRegistry,
    trace: &Option<Arc<TraceWriter>>,
) {
    match result {
        Ok(result) => {
            let params = &lane.item.request.params;
            let mut resp =
                Response::from_result(lane.item.request.id, &result, params);
            resp.canceled = canceled;
            // TTFT is stamped by the loop after the dispatch that first
            // commits; a lane that finished in its first dispatch gets
            // stamped here instead, and a lane that never committed
            // falls back to queue + prefill (same as the solo loop)
            let ttft = lane.ttft_seconds.unwrap_or_else(|| {
                if result.tokens.is_empty() {
                    lane.queue_seconds + result.prefill_seconds
                } else {
                    lane.item.submitted_at.elapsed().as_secs_f64()
                }
            });
            record_success(
                id,
                metrics,
                trace,
                DoneStamps {
                    rid: lane.item.request.id,
                    queue_seconds: lane.queue_seconds,
                    ttft_seconds: ttft,
                },
                params,
                &result,
            );
            let _ = lane.item.reply.send(resp);
        }
        Err(e) => {
            metrics.record(failed_metrics(id, &lane.item, lane.queue_seconds));
            trace_span(trace, lane.item.request.id, id, Phase::Error, |te| {
                te.ok = Some(false)
            });
            let _ = lane.item.reply.send(Response::from_error(
                lane.item.request.id,
                &format!("decode failed: {e:#}"),
            ));
        }
    }
}

/// The §9.5 batched loop: one [`BatchRunner`] steps every live lane per
/// device dispatch; requests join and leave at round boundaries (see
/// the module doc for the admission contract).
fn batched_loop(
    id: usize,
    rt: &Runtime,
    cfg: &ReplicaConfig,
    work: &Receiver<WorkItem>,
    metrics: &Arc<MetricsRegistry>,
    ctl: &ReplicaCtl<'_>,
) {
    let mut runner = match BatchRunner::new(rt) {
        Ok(r) => r,
        Err(e) => {
            // supports_batching() said yes but the session bring-up
            // failed — serve interleaved rather than killing the replica
            metrics.record_failure(FailureKind::SessionRebuildFailed);
            eprintln!(
                "replica {id}: batch session failed ({e:#}); \
                 serving interleaved"
            );
            return interleaved_loop(id, rt, cfg, work, metrics, ctl);
        }
    };
    let slots = cfg.batch.min(runner.batch_max()).max(1);
    let cache: Option<SharedPrefixCache> = cfg.cache.build();
    let publish_cache = |cache: &Option<SharedPrefixCache>| {
        if let Some(c) = cache {
            metrics.record_cache(id, c.borrow().stats());
        }
    };
    // request bookkeeping parallel to the runner's device lanes
    let mut lanes: Vec<Option<BatchLane>> =
        (0..runner.batch_max()).map(|_| None).collect();
    // family-mismatched arrivals wait here; they still count as queued
    // (`queued_hint` drops only at admission ack) so `load()` is exact
    let mut pending: VecDeque<WorkItem> = VecDeque::new();
    // consecutive solo-prefill failures at admission (DESIGN.md §13)
    let mut admission_failures = 0usize;
    loop {
        if ctl.shutdown.load(Ordering::Relaxed)
            && runner.is_empty()
            && pending.is_empty()
        {
            return;
        }
        // back-to-back session failures mean the device is gone: go
        // Down and drain with retriable errors (DESIGN.md §13)
        if admission_failures >= ADMISSION_FAILURE_LIMIT
            && runner.is_empty()
        {
            ctl.set_health(id, ReplicaHealth::Down, metrics, &cfg.trace);
            metrics.record_failure(FailureKind::ReplicaDown);
            eprintln!(
                "replica {id}: {admission_failures} consecutive \
                 session failures; draining"
            );
            return drain_down(id, work, pending, metrics, ctl, &cfg.trace);
        }
        // ---- intake: drain the channel into the arrival queue ---------
        if runner.is_empty() && pending.is_empty() {
            match work.recv_timeout(Duration::from_millis(50)) {
                Ok(i) => pending.push_back(i),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
        while let Ok(i) = work.try_recv() {
            pending.push_back(i);
        }
        // ---- admission at the round boundary --------------------------
        let families: Vec<&str> = pending
            .iter()
            .map(|it| it.request.params.method.batch_exec_name())
            .collect();
        let plan = plan_admissions(
            runner.occupancy(),
            slots,
            runner.family(),
            &families,
        );
        let mut admitted = 0usize;
        for &idx in &plan {
            // `plan` is ascending, so each removal shifts the rest left;
            // a planner index past the queue would be a planner bug —
            // skip it rather than panic the replica thread mid-batch
            let Some(mut item) = pending.remove(idx - admitted) else {
                debug_assert!(false, "planned index {idx} out of range");
                continue;
            };
            admitted += 1;
            let queue_seconds = Instant::now()
                .duration_since(item.submitted_at)
                .as_secs_f64();
            let toks = crate::tokenizer::encode(&item.request.prompt);
            let req_cache = if item.request.params.cache {
                cache.clone()
            } else {
                None
            };
            // same packing-aware admission as the interleaved loop: the
            // server default applies only when the request didn't pin
            // "rounds_per_call" itself
            if !item.request.pack_specified
                && item.request.params.rounds_per_call <= 1
            {
                item.request.params.rounds_per_call = cfg.pack.max(1);
            }
            match runner.admit(&toks, &item.request.params, req_cache) {
                Ok(slot) => {
                    admission_failures = 0;
                    // per-request deadline (DESIGN.md §13): measured
                    // from router submit, enforced at round boundaries
                    runner.set_deadline(slot, item_deadline(&item, cfg));
                    // streaming lanes never pack (per-round deltas); the
                    // *other* lanes keep their own pack budgets — packing
                    // is per-lane under `*_batch_multi`
                    if item.request.stream {
                        runner.set_pack_cap(slot, 1);
                    }
                    item.request.params.rounds_per_call =
                        runner.effective_rounds_per_call(slot);
                    if let Some(mut sink) = item.stream.take() {
                        let rid = item.request.id;
                        let mut seen_tokens = 0usize;
                        runner.set_on_commit(
                            slot,
                            Box::new(move |committed: &[u32]| {
                                if committed.len() <= seen_tokens {
                                    return;
                                }
                                let delta = crate::tokenizer::decode(
                                    &committed[seen_tokens..],
                                );
                                seen_tokens = committed.len();
                                if !delta.is_empty() {
                                    sink(StreamDelta {
                                        id: rid,
                                        delta,
                                        tokens: committed.len(),
                                    });
                                }
                            }),
                        );
                    }
                    // per-turn telemetry (same fan-out as the
                    // interleaved loop; events carry the occupancy)
                    {
                        let mreg = metrics.clone();
                        let tr = cfg.trace.clone();
                        let rid = item.request.id;
                        runner.set_round_sink(
                            slot,
                            Box::new(move |ev: &RoundEvent| {
                                mreg.record_round(id, ev);
                                trace_span(
                                    &tr,
                                    rid,
                                    id,
                                    Phase::Round,
                                    |te| te.round = Some(*ev),
                                );
                            }),
                        );
                    }
                    trace_span(
                        &cfg.trace,
                        item.request.id,
                        id,
                        Phase::Queue,
                        |te| te.wall_ms = Some(queue_seconds * 1e3),
                    );
                    if let Some((pf, cached)) = runner.prefill_stats(slot) {
                        trace_span(
                            &cfg.trace,
                            item.request.id,
                            id,
                            Phase::Prefill,
                            |te| {
                                te.wall_ms = Some(pf * 1e3);
                                te.cached_tokens = Some(cached as u64);
                            },
                        );
                    }
                    lanes[slot] = Some(BatchLane {
                        item,
                        queue_seconds,
                        ttft_seconds: None,
                    });
                    ctl.active.store(runner.occupancy(), Ordering::Relaxed);
                }
                Err(e) => {
                    admission_failures += 1;
                    metrics.record_failure(FailureKind::DispatchFailed);
                    let resp = Response::from_error(
                        item.request.id,
                        &format!("prefill failed: {e:#}"),
                    );
                    metrics.record(failed_metrics(id, &item, queue_seconds));
                    trace_span(
                        &cfg.trace,
                        item.request.id,
                        id,
                        Phase::Error,
                        |te| te.ok = Some(false),
                    );
                    let _ = item.reply.send(resp);
                }
            }
            ctl.queued.fetch_sub(1, Ordering::Relaxed);
            publish_cache(&cache);
        }
        if runner.is_empty() {
            continue;
        }
        // ---- cooperative cancel: finalize at this round boundary ------
        for slot in 0..lanes.len() {
            let canceled = lanes[slot]
                .as_ref()
                .map_or(false, |l| l.item.cancel.load(Ordering::Relaxed));
            if !canceled {
                continue;
            }
            let done = runner.finish_early(slot);
            // the cancel scan above only selects occupied slots, so the
            // lane is live; a None here would be a bookkeeping bug
            let Some(lane) = lanes[slot].take() else { continue };
            deliver_batched(id, lane, done, true, metrics, &cfg.trace);
            ctl.active.store(runner.occupancy(), Ordering::Relaxed);
            publish_cache(&cache);
        }
        if runner.is_empty() {
            continue;
        }
        // ---- one shared dispatch for every live lane ------------------
        metrics.record_occupancy(id, runner.occupancy());
        match runner.step() {
            Ok(finished) => {
                for (slot, result) in finished {
                    // the runner only reports slots it stepped, which are
                    // exactly the occupied lanes
                    let Some(lane) = lanes[slot].take() else { continue };
                    deliver_batched(
                        id,
                        lane,
                        Ok(result),
                        false,
                        metrics,
                        &cfg.trace,
                    );
                    publish_cache(&cache);
                }
                // stamp TTFT on lanes whose first token landed this turn
                for slot in 0..lanes.len() {
                    if let Some(lane) = lanes[slot].as_mut() {
                        if lane.ttft_seconds.is_none()
                            && runner.committed(slot) > 0
                        {
                            lane.ttft_seconds = Some(
                                lane.item
                                    .submitted_at
                                    .elapsed()
                                    .as_secs_f64(),
                            );
                        }
                    }
                }
            }
            Err(e) => {
                // ---- supervisor (DESIGN.md §13) -----------------------
                // a dispatch failure poisons the whole stacked state,
                // but the *requests* riding it are innocent: requeue
                // them front-of-queue with a bounded retry budget and
                // rebuild the device session under capped, jittered
                // backoff. Health is published at every transition so
                // the router routes around us while we recover.
                let msg = format!("{e:#}");
                metrics.record_failure(FailureKind::DispatchFailed);
                trace_span(&cfg.trace, 0, id, Phase::Fault, |ev| {
                    ev.detail = Some(msg.clone());
                });
                ctl.set_health(
                    id,
                    ReplicaHealth::Draining,
                    metrics,
                    &cfg.trace,
                );
                // requeue victims in arrival order at the queue front
                // (FIFO survives the fault); greedy decode re-executes
                // deterministically, so a requeued lane's final text is
                // token-identical to an unfaulted run
                let mut victims: Vec<BatchLane> =
                    lanes.iter_mut().filter_map(|l| l.take()).collect();
                victims.sort_by_key(|l| l.item.submitted_at);
                for lane in victims.into_iter().rev() {
                    let queue_seconds = lane.queue_seconds;
                    let mut item = lane.item;
                    let Some(next_retries) =
                        requeue_next_retries(item.retries)
                    else {
                        metrics.record_failure(
                            FailureKind::RequeueBudgetExhausted,
                        );
                        metrics.record(failed_metrics(
                            id,
                            &item,
                            queue_seconds,
                        ));
                        trace_span(
                            &cfg.trace,
                            item.request.id,
                            id,
                            Phase::Error,
                            |te| te.ok = Some(false),
                        );
                        let _ = item.reply.send(Response::retriable_error(
                            item.request.id,
                            &format!(
                                "decode failed after {MAX_REQUEUES} \
                                 retries: {msg}"
                            ),
                        ));
                        continue;
                    };
                    item.retries = next_retries;
                    metrics.record_failure(FailureKind::LaneRequeued);
                    trace_span(
                        &cfg.trace,
                        item.request.id,
                        id,
                        Phase::Requeue,
                        |te| {
                            te.detail =
                                Some(format!("retry {}", item.retries));
                        },
                    );
                    // the lane re-enters the queue: its hint comes
                    // back up here and drops again at re-admission,
                    // so `load()` stays exact through the fault
                    ctl.queued.fetch_add(1, Ordering::Relaxed);
                    pending.push_front(item);
                }
                ctl.active.store(0, Ordering::Relaxed);
                // ---- rebuild under capped, jittered backoff -----------
                let mut rng = Rng::new(SUPERVISOR_SEED ^ id as u64);
                let mut rebuilt = None;
                for attempt in 0..REBUILD_ATTEMPTS {
                    if ctl.shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    match BatchRunner::new(rt) {
                        Ok(r) => {
                            rebuilt = Some(r);
                            break;
                        }
                        Err(e2) => {
                            metrics.record_failure(
                                FailureKind::SessionRebuildFailed,
                            );
                            let wait = backoff_ms(
                                attempt,
                                BACKOFF_BASE_MS,
                                BACKOFF_CAP_MS,
                                &mut rng,
                            );
                            eprintln!(
                                "replica {id}: batch session rebuild \
                                 attempt {attempt} failed ({e2:#}); \
                                 retrying in {wait} ms"
                            );
                            std::thread::sleep(Duration::from_millis(
                                wait,
                            ));
                        }
                    }
                }
                match rebuilt {
                    Some(r) => {
                        runner = r;
                        ctl.set_health(
                            id,
                            ReplicaHealth::Up,
                            metrics,
                            &cfg.trace,
                        );
                    }
                    None => {
                        // rebuild budget exhausted: go Down but stay
                        // alive, draining the channel with typed
                        // retriable errors — no client ever hangs on a
                        // corpse and the gauges reconcile to zero
                        ctl.set_health(
                            id,
                            ReplicaHealth::Down,
                            metrics,
                            &cfg.trace,
                        );
                        metrics.record_failure(FailureKind::ReplicaDown);
                        eprintln!(
                            "replica {id}: batch session lost; draining"
                        );
                        return drain_down(
                            id, work, pending, metrics, ctl, &cfg.trace,
                        );
                    }
                }
            }
        }
        ctl.active.store(runner.occupancy(), Ordering::Relaxed);
    }
}

/// Down-state drain loop (DESIGN.md §13): the replica's device session
/// is gone for good, but the thread stays alive until shutdown so every
/// queued and still-arriving item gets a typed *retriable* error reply
/// — the router has already stopped selecting this replica, and racing
/// submits still in flight land here instead of hanging — and the
/// queued gauge reconciles to zero (the pre-§13 loop returned with the
/// channel open, leaking one `queued_hint` per in-flight submit).
fn drain_down(
    id: usize,
    work: &Receiver<WorkItem>,
    mut pending: VecDeque<WorkItem>,
    metrics: &Arc<MetricsRegistry>,
    ctl: &ReplicaCtl<'_>,
    trace: &Option<Arc<TraceWriter>>,
) {
    let reject = |item: WorkItem| {
        metrics.record_failure(FailureKind::ReplicaDown);
        metrics.record(failed_metrics(
            id,
            &item,
            item.submitted_at.elapsed().as_secs_f64(),
        ));
        trace_span(trace, item.request.id, id, Phase::Error, |te| {
            te.ok = Some(false);
        });
        let _ = item.reply.send(Response::retriable_error(
            item.request.id,
            &format!("replica {id} is down; retry another replica"),
        ));
        ctl.queued.fetch_sub(1, Ordering::Relaxed);
    };
    for item in pending.drain(..) {
        reject(item);
    }
    loop {
        if ctl.shutdown.load(Ordering::Relaxed) {
            return;
        }
        match work.recv_timeout(Duration::from_millis(50)) {
            Ok(item) => reject(item),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::{plan_admissions, ReplicaHealth};

    #[test]
    fn health_discriminants_round_trip() {
        for h in [
            ReplicaHealth::Up,
            ReplicaHealth::Draining,
            ReplicaHealth::Down,
        ] {
            assert_eq!(ReplicaHealth::from_u8(h as u8), h);
        }
        assert_eq!(ReplicaHealth::Up.as_str(), "up");
        assert_eq!(ReplicaHealth::Draining.as_str(), "draining");
        assert_eq!(ReplicaHealth::Down.as_str(), "down");
        // unknown discriminants degrade to Down, never to healthy
        assert_eq!(ReplicaHealth::from_u8(7), ReplicaHealth::Down);
    }

    #[test]
    fn empty_batch_admits_head_and_its_family() {
        let q = ["sps_batch", "ar_batch", "sps_batch", "sps_batch"];
        assert_eq!(plan_admissions(0, 4, None, &q), vec![0, 2, 3]);
    }

    #[test]
    fn running_family_filters_mismatches() {
        let q = ["ar_batch", "sps_batch", "ar_batch"];
        assert_eq!(plan_admissions(2, 4, Some("sps_batch"), &q), vec![1]);
    }

    #[test]
    fn never_admits_past_the_lane_budget() {
        let q = ["sps_batch"; 10];
        assert_eq!(plan_admissions(3, 4, Some("sps_batch"), &q), vec![0]);
        assert!(plan_admissions(4, 4, Some("sps_batch"), &q).is_empty());
        assert_eq!(plan_admissions(0, 2, None, &q), vec![0, 1]);
    }

    #[test]
    fn zero_queue_or_zero_slots_is_a_noop() {
        assert!(plan_admissions(0, 4, None, &[]).is_empty());
        assert!(
            plan_admissions(8, 8, Some("sps_batch"), &["sps_batch"])
                .is_empty()
        );
    }
}
