//! Engine replica: a dedicated OS thread owning one PJRT client.
//!
//! PJRT handles are not `Send`, so the `Runtime` is constructed *inside*
//! the thread and never crosses it. The replica runs a continuous-batching
//! loop: up to `slots` sequences are active at once and their rounds are
//! interleaved round-robin over the single device — the CPU-PJRT analog of
//! vLLM's iteration-level scheduling (cross-sequence GEMM batching is not
//! expressible through the single-tuple-output xla crate; DESIGN.md §9.5).
//!
//! The loop is packing-aware (DESIGN.md §9.6): one interleave turn is one
//! *device call*, which under round packing fuses up to `rounds_per_call`
//! draft-verify rounds — so a packed slot holds the device pack× longer
//! per turn. Admission therefore caps streaming slots at 1 (per-round
//! delta granularity) and the engine's adaptive controller runs every
//! sequence's first turn unpacked (TTFT p99) and shrinks the pack near
//! the generation budget.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{CacheConfig, SharedPrefixCache};
use crate::coordinator::metrics::{MetricsRegistry, RequestMetrics};
use crate::coordinator::request::{Response, StreamDelta, WorkItem};
use crate::engine::SeqRunner;
use crate::runtime::Runtime;

/// Handle to one engine-replica thread (see the module doc).
pub struct EngineReplica {
    /// Replica index (stable over the router's lifetime).
    pub id: usize,
    handle: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    /// Gauge of currently active (admitted, undone) sequences.
    pub active: Arc<AtomicUsize>,
    /// Submitted-but-not-admitted items: incremented by the router at
    /// submit, decremented by this replica's admission ack (after the
    /// item lands in an active slot or errors out), so `load()` counts
    /// queued backlog exactly instead of "best effort".
    pub queued_hint: Arc<AtomicUsize>,
}

/// Startup configuration for one replica.
pub struct ReplicaConfig {
    /// Directory holding the compiled HLO artifacts.
    pub artifact_dir: PathBuf,
    /// concurrent sequences interleaved on this replica
    pub slots: usize,
    /// Force the naive host-roundtrip runtime (§Perf baseline).
    pub hostloop: bool,
    /// Prefix-cache configuration: the store is built *inside* the
    /// replica thread and never leaves it, like the runtime it snapshots
    /// (DESIGN.md §8).
    pub cache: CacheConfig,
    /// Server-side round-packing default (`--pack`, DESIGN.md §9.6):
    /// requests whose wire object omitted `"rounds_per_call"` fuse up
    /// to this many rounds per device dispatch (an explicit
    /// `"rounds_per_call": 1` opts out instead of inheriting this). A
    /// packed step holds the device pack× longer per
    /// interleave turn, so the loop caps streaming slots at 1 (delta
    /// granularity) and the engine's controller caps the first turn of
    /// every sequence at 1 (TTFT p99).
    pub pack: usize,
}

impl EngineReplica {
    /// Spawn the replica thread. `ready` is signalled (with any startup
    /// error) once the runtime has compiled its executables.
    pub fn spawn(
        id: usize,
        cfg: ReplicaConfig,
        work: Receiver<WorkItem>,
        metrics: Arc<MetricsRegistry>,
        ready: std::sync::mpsc::Sender<Result<(), String>>,
    ) -> EngineReplica {
        let shutdown = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let queued_hint = Arc::new(AtomicUsize::new(0));
        let sd = shutdown.clone();
        let act = active.clone();
        let queued = queued_hint.clone();
        let handle = std::thread::Builder::new()
            .name(format!("mars-replica-{id}"))
            .spawn(move || {
                let rt = match Runtime::new(&cfg.artifact_dir) {
                    Ok(rt) => {
                        let _ = ready.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                let ctl = LoopCtl {
                    shutdown: &sd,
                    active: &act,
                    queued: &queued,
                };
                replica_loop(id, &rt, &cfg, &work, &metrics, &ctl);
            })
            .expect("spawn replica thread");
        EngineReplica {
            id,
            handle: Some(handle),
            shutdown,
            active,
            queued_hint,
        }
    }

    /// Current load (active sequences) — used by least-loaded routing.
    pub fn load(&self) -> usize {
        self.active.load(Ordering::Relaxed)
            + self.queued_hint.load(Ordering::Relaxed)
    }

    /// Signal shutdown and join the replica thread (drains active work).
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EngineReplica {
    fn drop(&mut self) {
        self.stop();
    }
}

struct Active<'rt> {
    runner: SeqRunner<'rt>,
    item: WorkItem,
    /// submit → admission wait (stamped from `WorkItem::submitted_at`, so
    /// the metric measures actual queue time, not prefill)
    queue_seconds: f64,
    /// submit → first committed token (stamped after the first round that
    /// commits); the honest serving TTFT, including queue + prefill
    ttft_seconds: Option<f64>,
}

/// Shutdown flag + load gauges shared with the [`EngineReplica`] handle.
struct LoopCtl<'a> {
    shutdown: &'a AtomicBool,
    active: &'a AtomicUsize,
    /// submitted-but-not-admitted items (see [`EngineReplica::queued_hint`])
    queued: &'a AtomicUsize,
}

fn replica_loop(
    id: usize,
    rt: &Runtime,
    cfg: &ReplicaConfig,
    work: &Receiver<WorkItem>,
    metrics: &MetricsRegistry,
    ctl: &LoopCtl<'_>,
) {
    let mut active: Vec<Active<'_>> = Vec::new();
    let slots = cfg.slots.max(1);
    // the prefix cache lives and dies on this thread, like the runtime
    let cache: Option<SharedPrefixCache> = cfg.cache.build();
    let publish_cache = |cache: &Option<SharedPrefixCache>| {
        if let Some(c) = cache {
            metrics.record_cache(id, c.borrow().stats());
        }
    };
    loop {
        if ctl.shutdown.load(Ordering::Relaxed) && active.is_empty() {
            return;
        }
        // ---- admission: fill free slots -------------------------------
        while active.len() < slots {
            let mut item = if active.is_empty() {
                match work.recv_timeout(Duration::from_millis(50)) {
                    Ok(i) => i,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        if active.is_empty() {
                            return;
                        }
                        break;
                    }
                }
            } else {
                match work.try_recv() {
                    Ok(i) => i,
                    Err(_) => break,
                }
            };
            let queue_seconds =
                Instant::now().duration_since(item.submitted_at).as_secs_f64();
            let toks = crate::tokenizer::encode(&item.request.prompt);
            let req_cache = if item.request.params.cache {
                cache.clone()
            } else {
                None
            };
            // packing-aware admission (DESIGN.md §9.6): the server
            // `--pack` default applies only to requests that did not
            // pin "rounds_per_call" themselves (an explicit 1 opts out
            // of packing on a packed server)
            if !item.request.pack_specified
                && item.request.params.rounds_per_call <= 1
            {
                item.request.params.rounds_per_call = cfg.pack.max(1);
            }
            let admitted = SeqRunner::new_with_cache(
                rt,
                &toks,
                &item.request.params,
                cfg.hostloop,
                req_cache,
            );
            match admitted {
                Ok(mut runner) => {
                    // streaming slots never pack: a fused call would
                    // collapse per-round deltas into one chunk and hold
                    // the device pack× longer before the next delta
                    if item.request.stream {
                        runner.set_pack_cap(1);
                    }
                    // the reply echoes the packing that actually runs —
                    // 1 (suppressed) for streaming-capped slots, host
                    // drafters and artifacts without *_multi programs
                    item.request.params.rounds_per_call =
                        runner.effective_rounds_per_call();
                    // thread the per-round commit callback: decode only
                    // the newly committed tail (the byte-level tokenizer
                    // decodes tokens independently, so tail decodes
                    // concatenate to the full text) and push the delta
                    // into the request's sink
                    if let Some(mut sink) = item.stream.take() {
                        let id = item.request.id;
                        let mut seen_tokens = 0usize;
                        runner.set_on_commit(Box::new(move |committed: &[u32]| {
                            if committed.len() <= seen_tokens {
                                return;
                            }
                            let delta = crate::tokenizer::decode(
                                &committed[seen_tokens..],
                            );
                            seen_tokens = committed.len();
                            // special ids decode to "" — nothing to send
                            if !delta.is_empty() {
                                sink(StreamDelta {
                                    id,
                                    delta,
                                    tokens: committed.len(),
                                });
                            }
                        }));
                    }
                    active.push(Active {
                        runner,
                        item,
                        queue_seconds,
                        ttft_seconds: None,
                    });
                    ctl.active.store(active.len(), Ordering::Relaxed);
                }
                Err(e) => {
                    let resp = Response::from_error(
                        item.request.id,
                        &format!("prefill failed: {e:#}"),
                    );
                    metrics.record(RequestMetrics {
                        ok: false,
                        tokens: 0,
                        decode_seconds: 0.0,
                        prefill_seconds: 0.0,
                        queue_seconds,
                        ttft_seconds: 0.0,
                        tau: 0.0,
                        relaxed_accepts: 0.0,
                        policy: item.request.params.policy.name(),
                        method: item.request.params.method.name(),
                    });
                    let _ = item.reply.send(resp);
                }
            }
            // admission ack: only now does the item stop counting as
            // queued — the active gauge (or the error reply) already
            // reflects it, so `load()` never dips mid-admission
            ctl.queued.fetch_sub(1, Ordering::Relaxed);
            publish_cache(&cache);
        }
        if active.is_empty() {
            continue;
        }
        // ---- one interleaved round per active sequence ----------------
        let mut i = 0;
        while i < active.len() {
            let a = &mut active[i];
            // cooperative cancel: finalize with the committed prefix
            // instead of stepping further
            let canceled =
                a.item.cancel.load(Ordering::Relaxed);
            let step_res = if canceled {
                a.runner.finish_early().map(Some)
            } else {
                a.runner.step()
            };
            if step_res.is_ok()
                && a.ttft_seconds.is_none()
                && a.runner.committed() > 0
            {
                a.ttft_seconds =
                    Some(a.item.submitted_at.elapsed().as_secs_f64());
            }
            let done = match step_res {
                Ok(Some(result)) => {
                    let params = &a.item.request.params;
                    let mut resp = Response::from_result(
                        a.item.request.id,
                        &result,
                        params,
                    );
                    resp.canceled = canceled;
                    metrics.record(RequestMetrics {
                        ok: true,
                        tokens: result.tokens.len(),
                        decode_seconds: result.decode_seconds,
                        prefill_seconds: result.prefill_seconds,
                        queue_seconds: a.queue_seconds,
                        ttft_seconds: a.ttft_seconds.unwrap_or(
                            a.queue_seconds + result.prefill_seconds,
                        ),
                        tau: result.tau(),
                        relaxed_accepts: result.snapshot.relaxed_accepts,
                        policy: params.policy.name(),
                        method: params.method.name(),
                    });
                    let _ = a.item.reply.send(resp);
                    true
                }
                Ok(None) => false,
                Err(e) => {
                    let _ = a.item.reply.send(Response::from_error(
                        a.item.request.id,
                        &format!("decode failed: {e:#}"),
                    ));
                    metrics.record(RequestMetrics {
                        ok: false,
                        tokens: 0,
                        decode_seconds: 0.0,
                        prefill_seconds: 0.0,
                        queue_seconds: a.queue_seconds,
                        ttft_seconds: 0.0,
                        tau: 0.0,
                        relaxed_accepts: 0.0,
                        policy: a.item.request.params.policy.name(),
                        method: a.item.request.params.method.name(),
                    });
                    true
                }
            };
            if done {
                active.swap_remove(i);
                ctl.active.store(active.len(), Ordering::Relaxed);
                // finalize exported a fresh context snapshot — publish
                // the new residency/hit gauges
                publish_cache(&cache);
            } else {
                i += 1;
            }
        }
    }
}
