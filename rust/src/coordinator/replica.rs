//! Engine replica: a dedicated OS thread owning one PJRT client.
//!
//! PJRT handles are not `Send`, so the `Runtime` is constructed *inside*
//! the thread and never crosses it. The replica runs a continuous-batching
//! loop: up to `slots` sequences are active at once and their rounds are
//! interleaved round-robin over the single device — the CPU-PJRT analog of
//! vLLM's iteration-level scheduling (cross-sequence GEMM batching is not
//! expressible through the single-tuple-output xla crate; DESIGN.md §9.5).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::{MetricsRegistry, RequestMetrics};
use crate::coordinator::request::{Response, WorkItem};
use crate::engine::SeqRunner;
use crate::runtime::Runtime;

pub struct EngineReplica {
    pub id: usize,
    handle: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    pub active: Arc<AtomicUsize>,
    pub queued_hint: Arc<AtomicUsize>,
}

pub struct ReplicaConfig {
    pub artifact_dir: PathBuf,
    /// concurrent sequences interleaved on this replica
    pub slots: usize,
    pub hostloop: bool,
}

impl EngineReplica {
    /// Spawn the replica thread. `ready` is signalled (with any startup
    /// error) once the runtime has compiled its executables.
    pub fn spawn(
        id: usize,
        cfg: ReplicaConfig,
        work: Receiver<WorkItem>,
        metrics: Arc<MetricsRegistry>,
        ready: std::sync::mpsc::Sender<Result<(), String>>,
    ) -> EngineReplica {
        let shutdown = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let queued_hint = Arc::new(AtomicUsize::new(0));
        let sd = shutdown.clone();
        let act = active.clone();
        let handle = std::thread::Builder::new()
            .name(format!("mars-replica-{id}"))
            .spawn(move || {
                let rt = match Runtime::new(&cfg.artifact_dir) {
                    Ok(rt) => {
                        let _ = ready.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                replica_loop(&rt, &cfg, &work, &metrics, &sd, &act);
            })
            .expect("spawn replica thread");
        EngineReplica {
            id,
            handle: Some(handle),
            shutdown,
            active,
            queued_hint,
        }
    }

    /// Current load (active sequences) — used by least-loaded routing.
    pub fn load(&self) -> usize {
        self.active.load(Ordering::Relaxed)
            + self.queued_hint.load(Ordering::Relaxed)
    }

    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EngineReplica {
    fn drop(&mut self) {
        self.stop();
    }
}

struct Active<'rt> {
    runner: SeqRunner<'rt>,
    item: WorkItem,
    /// submit → admission wait (stamped from `WorkItem::submitted_at`, so
    /// the metric measures actual queue time, not prefill)
    queue_seconds: f64,
}

fn replica_loop(
    rt: &Runtime,
    cfg: &ReplicaConfig,
    work: &Receiver<WorkItem>,
    metrics: &MetricsRegistry,
    shutdown: &AtomicBool,
    active_gauge: &AtomicUsize,
) {
    let mut active: Vec<Active<'_>> = Vec::new();
    let slots = cfg.slots.max(1);
    loop {
        if shutdown.load(Ordering::Relaxed) && active.is_empty() {
            return;
        }
        // ---- admission: fill free slots -------------------------------
        while active.len() < slots {
            let item = if active.is_empty() {
                match work.recv_timeout(Duration::from_millis(50)) {
                    Ok(i) => i,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        if active.is_empty() {
                            return;
                        }
                        break;
                    }
                }
            } else {
                match work.try_recv() {
                    Ok(i) => i,
                    Err(_) => break,
                }
            };
            let queue_seconds =
                Instant::now().duration_since(item.submitted_at).as_secs_f64();
            let toks = crate::tokenizer::encode(&item.request.prompt);
            match SeqRunner::new(rt, &toks, &item.request.params, cfg.hostloop)
            {
                Ok(runner) => {
                    active.push(Active { runner, item, queue_seconds });
                    active_gauge.store(active.len(), Ordering::Relaxed);
                }
                Err(e) => {
                    let resp = Response::from_error(
                        item.request.id,
                        &format!("prefill failed: {e:#}"),
                    );
                    metrics.record(RequestMetrics {
                        ok: false,
                        tokens: 0,
                        decode_seconds: 0.0,
                        prefill_seconds: 0.0,
                        queue_seconds,
                        tau: 0.0,
                        relaxed_accepts: 0.0,
                        policy: item.request.params.policy.name(),
                    });
                    let _ = item.reply.send(resp);
                }
            }
        }
        if active.is_empty() {
            continue;
        }
        // ---- one interleaved round per active sequence ----------------
        let mut i = 0;
        while i < active.len() {
            let done = match active[i].runner.step() {
                Ok(Some(result)) => {
                    let a = &active[i];
                    let policy = a.item.request.params.policy;
                    let resp = Response::from_result(
                        a.item.request.id,
                        &result,
                        policy,
                    );
                    metrics.record(RequestMetrics {
                        ok: true,
                        tokens: result.tokens.len(),
                        decode_seconds: result.decode_seconds,
                        prefill_seconds: result.prefill_seconds,
                        queue_seconds: a.queue_seconds,
                        tau: result.tau(),
                        relaxed_accepts: result.snapshot.relaxed_accepts,
                        policy: policy.name(),
                    });
                    let _ = a.item.reply.send(resp);
                    true
                }
                Ok(None) => false,
                Err(e) => {
                    let a = &active[i];
                    let _ = a.item.reply.send(Response::from_error(
                        a.item.request.id,
                        &format!("decode failed: {e:#}"),
                    ));
                    metrics.record(RequestMetrics {
                        ok: false,
                        tokens: 0,
                        decode_seconds: 0.0,
                        prefill_seconds: 0.0,
                        queue_seconds: a.queue_seconds,
                        tau: 0.0,
                        relaxed_accepts: 0.0,
                        policy: a.item.request.params.policy.name(),
                    });
                    true
                }
            };
            if done {
                active.swap_remove(i);
                active_gauge.store(active.len(), Ordering::Relaxed);
            } else {
                i += 1;
            }
        }
    }
}
