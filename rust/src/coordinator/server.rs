//! Line-JSON TCP server: JSON objects in, JSON objects out, one per line.
//! std-only (tokio is not in the offline registry; a thread-per-connection
//! accept loop over `std::net` is the honest equivalent for this CPU-bound
//! backend). Cross-linked from DESIGN.md §5.
//!
//! # Protocol
//!
//! Every line each way is one JSON object. Requests carry a client `"id"`
//! that is echoed on every reply line; connections are **pipelined** —
//! a client may send any number of requests without waiting, and replies
//! complete **out of order** (match them by id). A request without an
//! `"id"` gets a connection-local id assigned from a reserved high range
//! (≥ 2^52, echoed as usual), so it can never collide with a
//! client-assigned id on the same connection. Closing the connection
//! cancels that connection's in-flight requests.
//!
//! ## Generation
//!
//! ```text
//! -> {"id": 1, "prompt": "...", "method": {"eagle_tree": {"k": 7}},
//!     "policy": {"mars": {"theta": 0.9}},   // or "mars:0.9" CLI string
//!     "temperature": 1.0, "max_new": 128, "seed": 1}
//! <- {"id": 1, "ok": true, "text": "...", "tokens": 42, "tau": 6.1,
//!     "decode_seconds": ..., "prefill_seconds": ..., "relaxed_accepts": ...,
//!     "policy": "mars:0.9", "method": "eagle_tree:k=7,beam=2,branch=2"}
//! ```
//!
//! A reply additionally carries `"cached_tokens"` when the replica's
//! prefix cache (DESIGN.md §8) restored part of the prompt instead of
//! prefilling it; `"cache": false` opts a request out of reuse. A
//! failed request's terminal reply carries `"ok": false` and an
//! `"error"` string in place of the result fields.
//!
//! ## Failure semantics (DESIGN.md §13)
//!
//! A request may carry `"deadline_ms"` (positive integer): a wall
//! budget measured from submission, queue time included. When it runs
//! out the replica finalizes at the next round boundary and the
//! terminal reply carries the text committed so far plus
//! `"deadline_exceeded": true` — a deadline reply is `"ok": true` with
//! partial text, not an error. Requests without the field inherit the
//! server's `--deadline-ms` default, when set.
//!
//! Under overload (`--shed-above N`: queued backlog across replicas at
//! or past N) a new request is refused immediately with
//! `"busy": true`, `"retry_after_ms"` (a backoff hint that grows with
//! the backlog) and `"retriable": true` — nothing was executed and
//! resubmitting later is safe. Transient replica failures (a lane that
//! exhausted its requeue budget, a downed replica draining its queue,
//! no routable replica at submit) also reply `"ok": false` with
//! `"retriable": true`: the failure is the serving stack's, not the
//! request's, and the same request may succeed on retry. Permanent
//! errors (bad params, prefill failure) stay plain `"ok": false`.
//!
//! `"rounds_per_call"` (alias `"pack"`) opts a request into round
//! packing (DESIGN.md §9.6): up to N draft-verify rounds fused per
//! device dispatch. Absent means the server's `--pack` default applies;
//! an explicit `1` opts out of packing entirely. Streaming requests
//! always run unpacked (per-round deltas), as do host-drafted methods
//! and artifact sets without the fused programs — the reply echoes
//! `"rounds_per_call"` only when the request's *effective* packing
//! budget (after the server default, streaming cap, capability fallback
//! and `PACK_MAX` clamp) was > 1. Note the first call of every sequence
//! runs unpacked regardless (TTFT guard), so a generation that finishes
//! in one call issues no packed dispatch even when the echo is > 1.
//!
//! The `"method"` value selects the drafting descriptor (see
//! `crate::spec::SpecMethod::from_request`): a structured one-key
//! object, a CLI string (`"eagle_tree:k=7,beam=2"`), or a legacy bare
//! family name; the legacy flat `"k"` / `"beam"` / `"branch"` keys
//! still override the matching knobs for old clients. The `"policy"`
//! object selects the verification policy (see
//! `crate::verify::VerifyPolicy::from_request`); the legacy flat
//! `"mars"` / `"theta"` keys still parse for old clients. The echoed
//! `"policy"` / `"method"` labels are what actually ran
//! (device-normalized policy, full descriptor label).
//!
//! ## Streaming
//!
//! `"stream": true` requests additionally emit one delta line per verify
//! round that commits tokens, *before* the terminal reply:
//!
//! ```text
//! -> {"id": 2, "prompt": "...", "stream": true, "max_new": 64}
//! <- {"id": 2, "delta": "The", "tokens": 1, "done": false}
//! <- {"id": 2, "delta": " cat", "tokens": 2, "done": false}
//! <- {"id": 2, "ok": true, "text": "The cat", "done": true, ...}
//! ```
//!
//! Concatenating the deltas of a request reproduces the final `"text"`
//! exactly. The terminal line of a streaming request carries
//! `"done": true`.
//!
//! ## Commands
//!
//! ```text
//! -> {"cmd": "ping"}                  <- {"pong": true}
//! -> {"cmd": "metrics"}               <- {"requests_ok": ..., "ttft_ms_p50": ...}
//! -> {"cmd": "metrics", "reset": true} <- snapshot, then counters zeroed
//! -> {"cmd": "prom"}                  <- {"prom": "# TYPE mars_requests_ok counter\n..."}
//! -> {"cmd": "cancel", "id": 2}       <- {"cmd": "cancel", "id": 2, "ok": true}
//! -> {"cmd": "shutdown"}              <- {"ok": true}
//! ```
//!
//! `cancel` sets a cooperative flag on the in-flight request with that id
//! (on this connection); the replica stops between rounds and the
//! request's terminal reply arrives with `"canceled": true` and the text
//! committed so far. The ack's `"ok"` is `false` when the id is unknown
//! or already complete. `shutdown` stops the accept loop and drains:
//! in-flight requests on every connection run to completion and their
//! replies are flushed before the connection closes (`mars serve` polls
//! [`Router::active_total`] down to zero, bounded at 60 s, before
//! exiting).
//!
//! ## Telemetry (DESIGN.md §12)
//!
//! `{"cmd": "metrics", "reset": true}` replies with the snapshot *then*
//! zeroes every counter, histogram and the elapsed stamp — the bench
//! serve `--reset` scraper uses it between waves so scenarios don't
//! smear into each other. `{"cmd": "prom"}` replies with the Prometheus
//! text exposition (format 0.0.4) in a `"prom"` string field — the same
//! body `mars serve --prom-addr` serves over HTTP. A generation request
//! may carry `"probe": true` to opt into the margin telemetry: the
//! device probe ring is dumped at finalize and the decisive z2/z1
//! margins land in the registry's margin-by-outcome histograms
//! (solo/interleaved lanes only; batched lanes don't dump probes).
//! `mars serve --trace FILE` additionally logs every request's
//! queue → prefill → round → commit spans as JSONL
//! (`crate::obs::trace`; summarize with `mars trace summarize FILE`).

// Serving-layer lint wall (DESIGN.md §11): a panic here takes the whole
// connection or replica down, so unwrap/expect are denied outright in
// non-test code — recover or propagate instead.
#![deny(clippy::unwrap_used, clippy::expect_used)]
#![cfg_attr(test, allow(clippy::unwrap_used, clippy::expect_used))]

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::metrics::FailureKind;
use crate::coordinator::request::{
    parse_request_json, wire_id, Response, StreamSink, CLIENT_ID_MAX,
};
use crate::coordinator::router::{Router, SubmitOptions};
use crate::util::json::Value;

/// Handle to a running server (dropping it stops the accept loop).
pub struct ServerHandle {
    /// Bound address (useful with `--bind 127.0.0.1:0`).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Has a shutdown command been received?
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Stop accepting connections and join the accept thread. Open
    /// connections finish their in-flight requests (graceful drain).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // poke the accept loop so it notices the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve `router` on `bind` (e.g. "127.0.0.1:7071"). Returns immediately;
/// connections are handled on their own threads. The router reference must
/// outlive the server; use an `Arc<Router>`.
pub fn serve(router: Arc<Router>, bind: &str) -> Result<ServerHandle> {
    let listener = TcpListener::bind(bind)
        .with_context(|| format!("binding {bind}"))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let accept_thread = std::thread::Builder::new()
        .name("mars-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let router = router.clone();
                let stop3 = stop2.clone();
                let _ = std::thread::Builder::new()
                    .name("mars-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, &router, &stop3);
                    });
            }
        })?;
    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread) })
}

/// In-flight requests of one connection: id → cancel flag. Shared between
/// the reader (registers, cancels) and the per-request waiter threads
/// (deregister on completion).
type Inflight = Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>>;

/// Lock the in-flight map, recovering from poisoning: its invariants are
/// per-entry (id → cancel flag), so a holder that panicked between
/// operations cannot leave cross-entry state half-updated — continuing
/// with the map as-is is strictly better than taking the whole
/// connection down.
fn lock_inflight(
    map: &Mutex<HashMap<u64, Arc<AtomicBool>>>,
) -> std::sync::MutexGuard<'_, HashMap<u64, Arc<AtomicBool>>> {
    map.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Requests without a client `"id"` get connection-local ids from this
/// reserved base. Client ids are validated below [`CLIENT_ID_MAX`]
/// (`request::wire_id`), so the two namespaces cannot collide in the
/// `Inflight` map, and both stay within the f64-exact integer range the
/// wire encoding needs.
const CONN_ID_BASE: u64 = CLIENT_ID_MAX;

fn handle_conn(
    stream: TcpStream,
    router: &Router,
    stop: &AtomicBool,
) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    // Dedicated writer thread: serializes reply/delta lines from the many
    // in-flight requests of this connection onto the socket.
    let (wtx, wrx) = channel::<String>();
    let mut wstream = stream;
    let writer = std::thread::Builder::new()
        .name("mars-conn-write".into())
        .spawn(move || {
            for line in wrx {
                if writeln!(wstream, "{line}").is_err() {
                    break; // client gone; drain remaining sends cheaply
                }
            }
        })?;
    let inflight: Inflight = Arc::new(Mutex::new(HashMap::new()));
    let mut next_conn_id: u64 = 0;

    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        if stop.load(Ordering::Relaxed) {
            break; // server shutting down: stop reading, drain below
        }
        match Value::parse(&line) {
            Err(e) => {
                let _ = wtx
                    .send(err_json(0, &format!("bad json: {e}")).to_string_json());
            }
            Ok(v) => {
                if let Some(cmd) = v.get("cmd").and_then(|c| c.as_str()) {
                    let shutdown =
                        handle_cmd(cmd, &v, router, &inflight, stop, &wtx);
                    if shutdown {
                        break;
                    }
                } else {
                    next_conn_id += 1;
                    let fallback_id = CONN_ID_BASE + next_conn_id;
                    submit_request(
                        &v,
                        fallback_id,
                        router,
                        &inflight,
                        &wtx,
                    );
                }
            }
        }
    }
    // Client hung up (as opposed to a server shutdown, which drains):
    // cancel whatever is still in flight so replicas stop burning rounds
    // for a reader that no longer exists.
    if !stop.load(Ordering::Relaxed) {
        for flag in lock_inflight(inflight).values() {
            flag.store(true, Ordering::Relaxed);
        }
    }
    // Graceful drain: waiter threads hold wtx clones, so the writer stays
    // alive until every in-flight request has sent its terminal reply.
    drop(wtx);
    let _ = writer.join();
    Ok(())
}

/// Handle one `{"cmd": ...}` line. Returns `true` on shutdown.
fn handle_cmd(
    cmd: &str,
    v: &Value,
    router: &Router,
    inflight: &Inflight,
    stop: &AtomicBool,
    wtx: &Sender<String>,
) -> bool {
    let reply = match cmd {
        "metrics" => {
            let snap = router.metrics.snapshot_json();
            // snapshot-then-zero: the reply carries the pre-reset truth,
            // so a scraper loses nothing across the wave boundary
            if v.get("reset").and_then(|b| b.as_bool()) == Some(true) {
                router.metrics.reset();
            }
            snap
        }
        "prom" => {
            let mut o = Value::obj();
            o.set(
                "prom",
                Value::Str(router.metrics.render_prometheus()),
            );
            o
        }
        "ping" => {
            let mut o = Value::obj();
            o.set("pong", Value::Bool(true));
            o
        }
        "cancel" => {
            let id = wire_id(v);
            let found = match id {
                None => false,
                Some(id) => match lock_inflight(inflight).get(&id) {
                    Some(flag) => {
                        flag.store(true, Ordering::Relaxed);
                        true
                    }
                    None => false,
                },
            };
            let mut o = Value::obj();
            o.set("cmd", Value::Str("cancel".into()));
            o.set("id", Value::Num(id.unwrap_or(0) as f64));
            o.set("ok", Value::Bool(found));
            o
        }
        "shutdown" => {
            stop.store(true, Ordering::Relaxed);
            let mut o = Value::obj();
            o.set("ok", Value::Bool(true));
            let _ = wtx.send(o.to_string_json());
            return true;
        }
        other => err_json(0, &format!("unknown cmd '{other}'")),
    };
    let _ = wtx.send(reply.to_string_json());
    false
}

/// Parse and submit one generation request; replies (and deltas, when
/// streaming) flow back through the connection's writer channel without
/// blocking the read loop.
fn submit_request(
    v: &Value,
    fallback_id: u64,
    router: &Router,
    inflight: &Inflight,
    wtx: &Sender<String>,
) {
    let req = match parse_request_json(fallback_id, v) {
        Err(e) => {
            // echo the client's own id when it sent a valid one, even
            // though the rest of the request failed to parse — a
            // pipelining client correlates errors by id like any reply
            let id = wire_id(v).unwrap_or(fallback_id);
            let _ = wtx.send(err_json(id, &e).to_string_json());
            return;
        }
        Ok(req) => req,
    };
    let id = req.id;
    let streaming = req.stream;
    // a duplicate in-flight id would clobber the first request's cancel
    // flag in the map and make the two replies uncorrelatable — reject
    if lock_inflight(inflight).contains_key(&id) {
        let _ = wtx.send(
            err_json(id, "duplicate in-flight id").to_string_json(),
        );
        return;
    }
    // overload shedding (DESIGN.md §13): refuse before submitting so
    // the backlog never grows past the operator's bound — the reply is
    // a typed, retriable "busy" with a backoff hint
    if let Some(retry_after_ms) = router.should_shed() {
        router.metrics.record_failure(FailureKind::Shed);
        let _ = wtx.send(
            Response::busy(id, retry_after_ms).to_json().to_string_json(),
        );
        return;
    }
    let sink: Option<StreamSink> = if streaming {
        let dtx = wtx.clone();
        Some(Box::new(move |delta: crate::coordinator::request::StreamDelta| {
            let _ = dtx.send(delta.to_json().to_string_json());
        }))
    } else {
        None
    };
    let handle = router.submit_opts(
        &req.prompt,
        req.params,
        SubmitOptions {
            id: Some(id),
            stream: sink,
            pack_specified: req.pack_specified,
            deadline_ms: req.deadline_ms,
        },
    );
    lock_inflight(inflight).insert(id, handle.cancel.clone());
    // Per-request waiter: forwards the terminal reply once the replica is
    // done. Cheap (one blocked thread per in-flight request) and keeps
    // the read loop free to accept more pipelined requests.
    let wtx2 = wtx.clone();
    let inflight2 = inflight.clone();
    let cancel = handle.cancel.clone();
    let spawned = std::thread::Builder::new()
        .name("mars-conn-wait".into())
        .spawn(move || {
            let resp = handle.rx.recv().unwrap_or_else(|_| {
                crate::coordinator::request::Response::from_error(
                    id,
                    "replica dropped request",
                )
            });
            lock_inflight(&inflight2).remove(&id);
            let mut o = resp.to_json();
            if streaming {
                o.set("done", Value::Bool(true));
            }
            let _ = wtx2.send(o.to_string_json());
        });
    if spawned.is_err() {
        // no waiter means no one would ever forward the terminal reply:
        // cancel the already-submitted work, deregister, and tell the
        // client rather than leaving its id hanging forever
        cancel.store(true, Ordering::Relaxed);
        lock_inflight(inflight).remove(&id);
        let _ = wtx.send(
            err_json(id, "server busy: could not spawn reply waiter")
                .to_string_json(),
        );
    }
}

fn err_json(id: u64, msg: &str) -> Value {
    let mut o = Value::obj();
    o.set("id", Value::Num(id as f64));
    o.set("ok", Value::Bool(false));
    o.set("error", Value::Str(msg.to_string()));
    o
}

/// Minimal client for tests/examples: send one request line, read reply.
pub fn client_roundtrip(addr: &str, line: &str) -> Result<Value> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply)?;
    Value::parse(&reply).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
}

/// Streaming client helper: send one `"stream": true` request line and
/// collect every delta line until the terminal reply (`"done": true` or
/// an error line). Returns `(deltas, final_reply)` — the deltas in
/// arrival order, all observed strictly before the final reply.
pub fn client_stream(addr: &str, line: &str) -> Result<(Vec<Value>, Value)> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let reader = BufReader::new(stream);
    let mut deltas = Vec::new();
    for reply in reader.lines() {
        let reply = reply?;
        let v = Value::parse(&reply)
            .map_err(|e| anyhow::anyhow!("bad reply: {e}"))?;
        let done = v.get("done").and_then(|b| b.as_bool()).unwrap_or(false);
        if v.get("delta").is_some() && !done {
            deltas.push(v);
        } else {
            return Ok((deltas, v));
        }
    }
    anyhow::bail!("connection closed before the terminal reply")
}
