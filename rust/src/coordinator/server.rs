//! Line-JSON TCP server: one JSON request object per line in, one JSON
//! response per line out. std-only (tokio is not in the offline registry;
//! a thread-per-connection accept loop over `std::net` is the honest
//! equivalent for this CPU-bound backend).
//!
//! Protocol:
//! ```text
//! -> {"prompt": "...", "method": "eagle_tree", "mars": true, ...}
//! <- {"id": 1, "ok": true, "text": "...", "tau": 6.1, ...}
//! -> {"cmd": "metrics"}
//! <- {"requests_ok": 10, "throughput_tok_s": ...}
//! -> {"cmd": "shutdown"}
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::request::parse_request_json;
use crate::coordinator::router::Router;
use crate::util::json::Value;

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Has a shutdown command been received?
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // poke the accept loop so it notices the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve `router` on `bind` (e.g. "127.0.0.1:7071"). Returns immediately;
/// connections are handled on their own threads. The router reference must
/// outlive the server; use an `Arc<Router>`.
pub fn serve(router: Arc<Router>, bind: &str) -> Result<ServerHandle> {
    let listener = TcpListener::bind(bind)
        .with_context(|| format!("binding {bind}"))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let accept_thread = std::thread::Builder::new()
        .name("mars-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let router = router.clone();
                let stop3 = stop2.clone();
                let _ = std::thread::Builder::new()
                    .name("mars-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, &router, &stop3);
                    });
            }
        })?;
    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread) })
}

fn handle_conn(
    stream: TcpStream,
    router: &Router,
    stop: &AtomicBool,
) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Value::parse(&line) {
            Err(e) => err_json(0, &format!("bad json: {e}")),
            Ok(v) => {
                if let Some(cmd) = v.get("cmd").and_then(|c| c.as_str()) {
                    match cmd {
                        "metrics" => router.metrics.snapshot_json(),
                        "ping" => {
                            let mut o = Value::obj();
                            o.set("pong", Value::Bool(true));
                            o
                        }
                        "shutdown" => {
                            stop.store(true, Ordering::Relaxed);
                            let mut o = Value::obj();
                            o.set("ok", Value::Bool(true));
                            writeln!(writer, "{}", o.to_string_json())?;
                            break;
                        }
                        other => err_json(0, &format!("unknown cmd '{other}'")),
                    }
                } else {
                    match parse_request_json(0, &v) {
                        Err(e) => err_json(0, &e),
                        Ok(req) => {
                            let resp =
                                router.generate(&req.prompt, req.params);
                            resp.to_json()
                        }
                    }
                }
            }
        };
        writeln!(writer, "{}", reply.to_string_json())?;
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    let _ = peer;
    Ok(())
}

fn err_json(id: u64, msg: &str) -> Value {
    let mut o = Value::obj();
    o.set("id", Value::Num(id as f64));
    o.set("ok", Value::Bool(false));
    o.set("error", Value::Str(msg.to_string()));
    o
}

/// Minimal client for tests/examples: send one request line, read reply.
pub fn client_roundtrip(addr: &str, line: &str) -> Result<Value> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply)?;
    Value::parse(&reply).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
}
