//! Admission scheduler: a bounded FIFO queue with backpressure in front of
//! the router, plus a deadline-based workload driver used by the serving
//! benchmarks (open-loop Poisson-ish arrivals).
//!
//! The per-replica *iteration-level* scheduling (interleaving rounds of
//! active sequences) lives in `replica.rs`; this module decides *what gets
//! in* — the split mirrors vLLM's router/engine division.

use std::collections::VecDeque;
use std::sync::mpsc::Receiver;
use std::sync::{Condvar, Mutex};

use crate::coordinator::request::Response;
use crate::coordinator::router::Router;
use crate::engine::GenParams;
use crate::util::prng::Rng;

/// Bounded FIFO with blocking push (backpressure) over the router.
pub struct Scheduler<'r> {
    router: &'r Router,
    queue: Mutex<VecDeque<(String, GenParams)>>,
    capacity: usize,
    cv: Condvar,
}

impl<'r> Scheduler<'r> {
    pub fn new(router: &'r Router, capacity: usize) -> Self {
        Scheduler {
            router,
            queue: Mutex::new(VecDeque::new()),
            capacity: capacity.max(1),
            cv: Condvar::new(),
        }
    }

    /// Enqueue; blocks while the queue is at capacity (backpressure).
    pub fn enqueue(&self, prompt: String, params: GenParams) {
        let mut q = self.queue.lock().unwrap();
        while q.len() >= self.capacity {
            q = self.cv.wait(q).unwrap();
        }
        q.push_back((prompt, params));
        self.cv.notify_all();
    }

    /// Drain everything to the router, returning response receivers in
    /// submission order.
    pub fn dispatch_all(&self) -> Vec<Receiver<Response>> {
        let mut q = self.queue.lock().unwrap();
        let items: Vec<_> = q.drain(..).collect();
        self.cv.notify_all();
        drop(q);
        items
            .into_iter()
            .map(|(p, g)| self.router.submit(&p, g))
            .collect()
    }

    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

/// Open-loop workload driver: submits `n` requests with exponential
/// inter-arrival gaps at `rate` req/s, then waits for all responses.
/// Returns responses in completion order.
pub fn drive_open_loop(
    router: &Router,
    prompts: &[(String, GenParams)],
    rate_per_s: f64,
    seed: u64,
) -> Vec<Response> {
    let mut rng = Rng::new(seed);
    let mut pending = Vec::new();
    for (prompt, params) in prompts {
        pending.push(router.submit(prompt, params.clone()));
        if rate_per_s > 0.0 {
            // exponential inter-arrival
            let u = rng.f64().max(1e-12);
            let gap = -u.ln() / rate_per_s;
            std::thread::sleep(std::time::Duration::from_secs_f64(
                gap.min(1.0),
            ));
        }
    }
    pending
        .into_iter()
        .map(|rx| {
            rx.recv().unwrap_or_else(|_| {
                Response::from_error(0, "request dropped")
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Scheduler logic is tested without a live router via the queue half.
    struct Probe;

    #[test]
    fn queue_capacity_and_order() {
        // use a detached queue through the public API shape
        let q: Mutex<VecDeque<(String, GenParams)>> =
            Mutex::new(VecDeque::new());
        {
            let mut g = q.lock().unwrap();
            g.push_back(("a".into(), GenParams::default()));
            g.push_back(("b".into(), GenParams::default()));
        }
        let drained: Vec<_> =
            q.lock().unwrap().drain(..).map(|(p, _)| p).collect();
        assert_eq!(drained, vec!["a", "b"]);
        let _ = Probe;
    }
}
