//! Admission scheduler: a bounded FIFO queue with backpressure in front of
//! the router, plus a deadline-based workload driver used by the serving
//! benchmarks (open-loop Poisson-ish arrivals).
//!
//! The per-replica *iteration-level* scheduling (interleaving rounds of
//! active sequences) lives in `replica.rs`; this module decides *what gets
//! in* — the split mirrors vLLM's router/engine division.

// Serving-layer lint wall (DESIGN.md §11): a panic here takes the whole
// admission path down, so unwrap/expect are denied outright in non-test
// code — locks recover from poisoning instead (the queue's invariant is
// per-entry FIFO order, which a panicked holder cannot half-update).
#![deny(clippy::unwrap_used, clippy::expect_used)]
#![cfg_attr(test, allow(clippy::unwrap_used, clippy::expect_used))]

use std::collections::VecDeque;
use std::sync::mpsc::Receiver;
use std::sync::{Condvar, Mutex};

use crate::coordinator::request::Response;
use crate::coordinator::router::Router;
use crate::engine::GenParams;
use crate::util::prng::Rng;

/// Anything the scheduler can drain requests into. [`Router`] is the
/// production target; tests substitute a mock so queue semantics are
/// exercised without artifacts.
pub trait SubmitTarget {
    /// Submit one request; the terminal response arrives on the channel.
    fn submit_item(
        &self,
        prompt: &str,
        params: GenParams,
    ) -> Receiver<Response>;
}

impl SubmitTarget for Router {
    fn submit_item(
        &self,
        prompt: &str,
        params: GenParams,
    ) -> Receiver<Response> {
        self.submit(prompt, params)
    }
}

/// Bounded FIFO with blocking push (backpressure) over a submit target.
pub struct Scheduler<'r, T: SubmitTarget = Router> {
    target: &'r T,
    queue: Mutex<VecDeque<(String, GenParams)>>,
    capacity: usize,
    cv: Condvar,
}

impl<'r, T: SubmitTarget> Scheduler<'r, T> {
    /// Build a queue of `capacity` (≥ 1) in front of `target`.
    pub fn new(target: &'r T, capacity: usize) -> Self {
        Scheduler {
            target,
            queue: Mutex::new(VecDeque::new()),
            capacity: capacity.max(1),
            cv: Condvar::new(),
        }
    }

    /// Enqueue; blocks while the queue is at capacity (backpressure).
    pub fn enqueue(&self, prompt: String, params: GenParams) {
        let mut q = self
            .queue
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        while q.len() >= self.capacity {
            q = self
                .cv
                .wait(q)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
        }
        q.push_back((prompt, params));
        self.cv.notify_all();
    }

    /// Drain everything to the target, returning response receivers in
    /// submission order.
    pub fn dispatch_all(&self) -> Vec<Receiver<Response>> {
        let mut q = self
            .queue
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let items: Vec<_> = q.drain(..).collect();
        self.cv.notify_all();
        drop(q);
        items
            .into_iter()
            .map(|(p, g)| self.target.submit_item(&p, g))
            .collect()
    }

    /// Current queue depth (enqueued, not yet dispatched).
    pub fn depth(&self) -> usize {
        self.queue
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .len()
    }
}

/// One exponential inter-arrival gap (seconds) for an open-loop Poisson
/// process at `rate_per_s` requests/second, capped at 1 s so a low-rate
/// sweep still finishes. Shared by [`drive_open_loop`] and the
/// `mars bench serve` load generator.
pub fn exp_arrival_gap(rng: &mut Rng, rate_per_s: f64) -> f64 {
    if rate_per_s <= 0.0 {
        return 0.0;
    }
    let u = rng.f64().max(1e-12);
    (-u.ln() / rate_per_s).min(1.0)
}

/// Open-loop workload driver: submits `n` requests with exponential
/// inter-arrival gaps at `rate` req/s, then waits for all responses.
/// Returns responses in completion order.
pub fn drive_open_loop(
    router: &Router,
    prompts: &[(String, GenParams)],
    rate_per_s: f64,
    seed: u64,
) -> Vec<Response> {
    let mut rng = Rng::new(seed);
    let mut pending = Vec::new();
    for (prompt, params) in prompts {
        pending.push(router.submit(prompt, params.clone()));
        let gap = exp_arrival_gap(&mut rng, rate_per_s);
        if gap > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(gap));
        }
    }
    pending
        .into_iter()
        .map(|rx| {
            rx.recv().unwrap_or_else(|_| {
                Response::from_error(0, "request dropped")
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use std::time::Duration;

    /// Mock target: replies instantly, tagging each response with its
    /// submission sequence number so FIFO order is observable.
    #[derive(Default)]
    struct MockTarget {
        submitted: AtomicU64,
    }

    impl SubmitTarget for MockTarget {
        fn submit_item(
            &self,
            prompt: &str,
            _params: GenParams,
        ) -> Receiver<Response> {
            let seq = self.submitted.fetch_add(1, Ordering::SeqCst);
            let (tx, rx) = channel();
            let mut resp = Response::from_error(seq, "mock");
            resp.ok = true;
            resp.error = None;
            resp.text = prompt.to_string();
            let _ = tx.send(resp);
            rx
        }
    }

    #[test]
    fn dispatch_preserves_fifo_order() {
        let target = MockTarget::default();
        let sched = Scheduler::new(&target, 8);
        for i in 0..5 {
            sched.enqueue(format!("p{i}"), GenParams::default());
        }
        assert_eq!(sched.depth(), 5);
        let responses: Vec<Response> = sched
            .dispatch_all()
            .into_iter()
            .map(|rx| rx.recv().unwrap())
            .collect();
        assert_eq!(sched.depth(), 0);
        for (i, r) in responses.iter().enumerate() {
            // id carries the mock's submission sequence; text the prompt —
            // both must match the enqueue order
            assert_eq!(r.id, i as u64);
            assert_eq!(r.text, format!("p{i}"));
        }
    }

    #[test]
    fn enqueue_blocks_at_capacity_until_dispatch() {
        // the scheduler borrows its target; the spawned thread needs
        // 'static, so give the mock a static lifetime
        let target: &'static MockTarget =
            Box::leak(Box::new(MockTarget::default()));
        let sched = Arc::new(Scheduler::new(target, 2));

        sched.enqueue("a".into(), GenParams::default());
        sched.enqueue("b".into(), GenParams::default());
        assert_eq!(sched.depth(), 2);

        let s2 = sched.clone();
        let blocked = Arc::new(AtomicU64::new(0));
        let b2 = blocked.clone();
        let h = std::thread::spawn(move || {
            s2.enqueue("c".into(), GenParams::default());
            b2.store(1, Ordering::SeqCst);
        });
        // the third enqueue must be blocked by backpressure
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(blocked.load(Ordering::SeqCst), 0, "enqueue did not block");
        assert_eq!(sched.depth(), 2);

        // draining frees capacity and unblocks the waiter
        let first = sched.dispatch_all();
        assert_eq!(first.len(), 2);
        h.join().unwrap();
        assert_eq!(blocked.load(Ordering::SeqCst), 1);
        assert_eq!(sched.depth(), 1);
        let rest = sched.dispatch_all();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].recv().unwrap().text, "c");
    }
}
