//! Margin-aware observability subsystem (DESIGN.md §12).
//!
//! MARS's premise is that targets spend much of their time in low-margin
//! regimes where strict rejection buys nothing — this layer makes that
//! claim *visible* at runtime instead of only in offline figures. Four
//! pieces, each a peer of the other subsystems rather than a patch on
//! the serving layer:
//!
//! * [`round`] — per-device-turn [`round::RoundEvent`]s emitted by the
//!   engine's commit paths through a cheap [`round::RoundSink`] trait,
//!   plus a bounded per-sequence [`round::FlightRecorder`];
//! * [`hist`] — fixed-bucket, mergeable, log-spaced
//!   [`hist::StreamHistogram`]s: O(buckets) memory, bounded-error
//!   quantiles, exact means — what the metrics registry shards record
//!   into instead of unbounded sample vectors;
//! * [`trace`] — the `--trace FILE` JSONL span log (queue → prefill →
//!   rounds → commit) with a render ↔ parse round-trip and the
//!   `mars trace summarize` aggregation;
//! * [`prom`] — Prometheus text-exposition rendering and the
//!   `--prom-addr` HTTP scrape endpoint.
//!
//! The margin-by-outcome histograms themselves (strict-accept /
//! relaxed-accept / reject per policy × method) live in
//! [`crate::coordinator::MetricsRegistry`], built from these
//! primitives; they surface through the `{"cmd":"metrics"}` snapshot,
//! the `{"cmd":"prom"}` exposition, and the schema-2 bench records.

#![warn(missing_docs)]

pub mod hist;
pub mod prom;
pub mod round;
pub mod trace;

pub use hist::StreamHistogram;
pub use round::{FlightRecorder, RoundEvent, RoundSink};
pub use trace::{TraceEvent, TraceWriter};
