//! Fixed-bucket mergeable streaming histogram (DESIGN.md §12).
//!
//! HDR-style log-spaced buckets: [`SUB_BUCKETS`] sub-buckets per octave
//! over [`OCTAVES`] octaves starting at [`BUCKET_MIN`], so one histogram
//! is a flat `[u64; 640]` — O(buckets) memory no matter how many samples
//! it absorbs, which is the whole point: the metrics registry used to
//! keep every latency sample in an unbounded `Vec<f64>`.
//!
//! Properties the registry and the property tests rely on:
//!
//! * **bounded relative quantile error** — a bucket spans a factor of
//!   2^(1/16), and [`quantile`] answers the geometric midpoint of the
//!   nearest-rank bucket, so the error vs the exact nearest-rank sample
//!   is at most 2^(1/32) − 1 ≈ 2.2% (then clamped into the exact
//!   observed `[min, max]`, which makes single-valued streams exact);
//! * **exact mean** — `sum`/`count` are carried exactly, so means do not
//!   degrade with bucketing;
//! * **mergeable** — [`merge`] is element-wise bucket addition: bucket
//!   counts merge associatively and commutatively (the per-replica
//!   shards of the registry merge at snapshot time, not on the hot
//!   path).
//!
//! [`quantile`]: StreamHistogram::quantile
//! [`merge`]: StreamHistogram::merge

/// Sub-buckets per octave (per factor-of-two of value range).
pub const SUB_BUCKETS: usize = 16;
/// Octaves covered above [`BUCKET_MIN`].
pub const OCTAVES: usize = 40;
/// Total fixed bucket count.
pub const BUCKETS: usize = SUB_BUCKETS * OCTAVES;
/// Lower edge of bucket 0: values at or below it land in bucket 0.
/// 2^-20 ≈ 9.5e-7 — with milliseconds that is sub-nanosecond, with
/// margin ratios it is indistinguishable-from-zero; the top edge is
/// 2^20 ≈ 1.05e6 (≈ 17 minutes in ms).
pub const BUCKET_MIN: f64 = 1.0 / (1u64 << 20) as f64;

/// Streaming histogram with fixed log-spaced buckets, exact moments and
/// exact min/max.
#[derive(Debug, Clone)]
pub struct StreamHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for StreamHistogram {
    fn default() -> Self {
        StreamHistogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// Bucket index for a value (callers map NaN to 0.0 first; negatives
/// and the sub-resolution tail saturate into bucket 0).
fn bucket_index(v: f64) -> usize {
    if v <= BUCKET_MIN {
        return 0;
    }
    let idx = ((v / BUCKET_MIN).log2() * SUB_BUCKETS as f64) as usize;
    idx.min(BUCKETS - 1)
}

/// Geometric midpoint of a bucket — the value a quantile answers with.
fn bucket_mid(i: usize) -> f64 {
    BUCKET_MIN * ((i as f64 + 0.5) / SUB_BUCKETS as f64).exp2()
}

impl StreamHistogram {
    /// Fresh, empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample. NaN and negative values saturate into bucket 0
    /// (they still count — a margin of exactly 0.0 is a real outcome).
    pub fn record(&mut self, v: f64) {
        let v = if v.is_nan() { 0.0 } else { v };
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Element-wise merge of another histogram into this one.
    pub fn merge(&mut self, other: &StreamHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Whether any sample has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum observed (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum observed (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank quantile, `q` in [0, 1]: the geometric midpoint of
    /// the bucket holding the rank-⌈q·n⌉ sample, clamped into the exact
    /// observed `[min, max]`. Relative error ≤ 2^(1/32) − 1 ≈ 2.2%.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((self.count as f64 * q).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// p50 shorthand.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// p90 shorthand.
    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }

    /// p99 shorthand.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Approximate count of samples ≤ `x`: every bucket whose geometric
    /// midpoint is ≤ `x` counts. Monotone in `x` — what the Prometheus
    /// cumulative-`le` exposition needs.
    pub fn count_le(&self, x: f64) -> u64 {
        let mut n = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 && bucket_mid(i) <= x {
                n += c;
            }
        }
        n
    }

    /// Resident bytes of one histogram (the memory-bound regression test
    /// multiplies this out across the registry).
    pub fn approx_bytes() -> usize {
        BUCKETS * std::mem::size_of::<u64>()
            + std::mem::size_of::<StreamHistogram>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_answers_zeros() {
        let h = StreamHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn single_valued_stream_is_exact() {
        // min/max clamping makes a constant stream quantile-exact — the
        // registry tests rely on this for their pinned assertions
        let mut h = StreamHistogram::new();
        for _ in 0..10 {
            h.record(20.0);
        }
        assert_eq!(h.quantile(0.5), 20.0);
        assert_eq!(h.quantile(0.99), 20.0);
        assert_eq!(h.mean(), 20.0);
    }

    #[test]
    fn quantile_error_is_bounded() {
        let mut h = StreamHistogram::new();
        let mut vals: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.37).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.1, 0.5, 0.9, 0.99] {
            let rank = ((vals.len() as f64 * q).ceil() as usize).max(1);
            let exact = vals[rank - 1];
            let got = h.quantile(q);
            let rel = (got / exact - 1.0).abs();
            assert!(rel < 0.025, "q={q}: {got} vs exact {exact}");
        }
    }

    #[test]
    fn merge_matches_single_recording() {
        let mut a = StreamHistogram::new();
        let mut b = StreamHistogram::new();
        let mut all = StreamHistogram::new();
        for i in 0..500 {
            let v = (i as f64 + 1.0) * 1.7;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.quantile(0.5), all.quantile(0.5));
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        assert!((a.sum() - all.sum()).abs() < 1e-6);
    }

    #[test]
    fn saturating_edges_still_count() {
        let mut h = StreamHistogram::new();
        h.record(-3.0);
        h.record(0.0);
        h.record(f64::NAN);
        h.record(1e12);
        assert_eq!(h.count(), 4);
        assert_eq!(h.count_le(1e13), 4);
    }

    #[test]
    fn count_le_is_monotone() {
        let mut h = StreamHistogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        let mut prev = 0;
        for x in [0.5, 1.0, 10.0, 50.0, 200.0] {
            let n = h.count_le(x);
            assert!(n >= prev, "count_le not monotone at {x}");
            prev = n;
        }
        assert_eq!(h.count_le(1e6), 100);
    }
}
