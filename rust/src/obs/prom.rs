//! Prometheus text-exposition rendering + the `--prom-addr` scrape
//! endpoint (DESIGN.md §12).
//!
//! Hand-rolled text format 0.0.4 — no client library in the offline
//! registry, and the format is three line shapes:
//!
//! ```text
//! # TYPE mars_requests_ok counter
//! mars_requests_ok 42
//! mars_margin_bucket{policy="mars",outcome="relaxed",le="0.9"} 17
//! ```
//!
//! [`PromText`] accumulates families (one `# TYPE` header per metric
//! name, label escaping per the spec); [`serve_http`] binds a minimal
//! HTTP/1.1 listener that answers every `GET` with a freshly rendered
//! exposition — enough for a real Prometheus scraper or the CI smoke's
//! parser, with none of a web framework.

use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener};

use anyhow::{Context, Result};

use super::hist::StreamHistogram;

/// Accumulating text-exposition writer.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
    typed: BTreeSet<String>,
}

/// Escape a label value per the exposition format.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Format a sample value (Prometheus has no NaN-safe consumers here).
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "0".to_string()
    } else {
        format!("{v}")
    }
}

fn label_block(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

impl PromText {
    /// Fresh, empty exposition.
    pub fn new() -> Self {
        Self::default()
    }

    fn type_header(&mut self, name: &str, kind: &str) {
        if self.typed.insert(name.to_string()) {
            let _ = writeln!(self.out, "# TYPE {name} {kind}");
        }
    }

    /// One counter sample.
    pub fn counter(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        value: f64,
    ) {
        self.type_header(name, "counter");
        let _ = writeln!(
            self.out,
            "{name}{} {}",
            label_block(labels),
            fmt_value(value)
        );
    }

    /// One gauge sample.
    pub fn gauge(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.type_header(name, "gauge");
        let _ = writeln!(
            self.out,
            "{name}{} {}",
            label_block(labels),
            fmt_value(value)
        );
    }

    /// One histogram family member: cumulative `_bucket` lines at the
    /// given upper bounds (plus `+Inf`), then `_sum` and `_count`.
    pub fn histogram(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        h: &StreamHistogram,
        bounds: &[f64],
    ) {
        self.type_header(name, "histogram");
        let base = labels.to_vec();
        for &b in bounds {
            let le = format!("{b}");
            let mut ls = base.clone();
            ls.push(("le", &le));
            let _ = writeln!(
                self.out,
                "{name}_bucket{} {}",
                label_block(&ls),
                h.count_le(b)
            );
        }
        let mut ls = base.clone();
        ls.push(("le", "+Inf"));
        let _ = writeln!(
            self.out,
            "{name}_bucket{} {}",
            label_block(&ls),
            h.count()
        );
        let _ = writeln!(
            self.out,
            "{name}_sum{} {}",
            label_block(&base),
            fmt_value(h.sum())
        );
        let _ = writeln!(
            self.out,
            "{name}_count{} {}",
            label_block(&base),
            h.count()
        );
    }

    /// Finish and return the exposition body.
    pub fn finish(self) -> String {
        self.out
    }
}

/// Handle of a running scrape endpoint.
#[derive(Debug)]
pub struct PromServer {
    /// The bound address (`--prom-addr 127.0.0.1:0` picks a free port).
    pub addr: SocketAddr,
}

/// Bind `addr` and answer every HTTP request with `render()`'s output
/// as `text/plain; version=0.0.4`. The accept loop runs on a detached
/// thread for the life of the process — scrape endpoints have no
/// drain-on-shutdown obligations.
pub fn serve_http<F>(addr: &str, render: F) -> Result<PromServer>
where
    F: Fn() -> String + Send + 'static,
{
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding prom endpoint {addr}"))?;
    let bound = listener.local_addr()?;
    std::thread::Builder::new()
        .name("mars-prom".into())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                // consume the request head (line + headers) so the
                // client's write never sees a reset before our reply
                let mut r = BufReader::new(match stream.try_clone() {
                    Ok(s) => s,
                    Err(_) => continue,
                });
                let mut line = String::new();
                while r.read_line(&mut line).is_ok() {
                    if line == "\r\n" || line == "\n" || line.is_empty() {
                        break;
                    }
                    line.clear();
                }
                let body = render();
                let _ = write!(
                    stream,
                    "HTTP/1.1 200 OK\r\nContent-Type: text/plain; \
                     version=0.0.4\r\nContent-Length: {}\r\nConnection: \
                     close\r\n\r\n{body}",
                    body.len()
                );
            }
        })
        .context("spawning prom endpoint thread")?;
    Ok(PromServer { addr: bound })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_header_emitted_once_per_family() {
        let mut p = PromText::new();
        p.counter("mars_requests_ok", &[], 1.0);
        p.counter("mars_requests_ok", &[("policy", "mars")], 2.0);
        let s = p.finish();
        assert_eq!(s.matches("# TYPE mars_requests_ok counter").count(), 1);
        assert!(s.contains("mars_requests_ok{policy=\"mars\"} 2"));
    }

    #[test]
    fn label_values_are_escaped() {
        let mut p = PromText::new();
        p.gauge("g", &[("m", "a\"b\\c")], 1.0);
        assert!(p.finish().contains("g{m=\"a\\\"b\\\\c\"} 1"));
    }

    #[test]
    fn histogram_lines_are_cumulative_and_terminated() {
        let mut h = StreamHistogram::new();
        for v in [0.1, 0.5, 0.9, 0.95] {
            h.record(v);
        }
        let mut p = PromText::new();
        p.histogram("mars_margin", &[("outcome", "relaxed")], &h, &[0.5, 0.9]);
        let s = p.finish();
        assert!(s.contains("# TYPE mars_margin histogram"));
        assert!(s.contains("le=\"+Inf\"} 4"));
        assert!(s.contains("mars_margin_count{outcome=\"relaxed\"} 4"));
        // cumulative: the le=0.9 bucket holds at least the le=0.5 one
        let count_at = |needle: &str| -> u64 {
            s.lines()
                .find(|l| l.contains(needle))
                .and_then(|l| l.split(' ').next_back())
                .and_then(|n| n.parse().ok())
                .unwrap_or(u64::MAX)
        };
        assert!(count_at("le=\"0.5\"") <= count_at("le=\"0.9\""));
    }

    #[test]
    fn http_endpoint_serves_the_rendered_body() {
        let srv = serve_http("127.0.0.1:0", || "mars_up 1\n".to_string())
            .expect("bind");
        let mut s = std::net::TcpStream::connect(srv.addr).expect("connect");
        write!(s, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut buf = String::new();
        use std::io::Read as _;
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 200 OK"), "{buf}");
        assert!(buf.contains("mars_up 1"), "{buf}");
    }
}
