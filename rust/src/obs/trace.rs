//! Per-request JSONL span log (DESIGN.md §12): `mars serve --trace FILE`.
//!
//! One JSON object per line, one line per lifecycle phase of a request
//! as it moves through a replica:
//!
//! ```text
//! {"phase":"queue",...}    admission — wall_ms = router-submit → admit
//! {"phase":"prefill",...}  session built — wall_ms = prefill time
//! {"phase":"round",...}    one device turn — embeds the RoundEvent
//! {"phase":"commit",...}   terminal — tokens, tau, ok
//! {"phase":"error",...}    terminal failure path
//! {"phase":"fault",...}    a dispatch fault hit the replica (§13)
//! {"phase":"requeue",...}  innocent lane re-admitted after a fault
//! {"phase":"health",...}   replica health transition (detail = state)
//! {"phase":"deadline",...} request exceeded its deadline budget
//! {"phase":"shed",...}     request refused at admission (overload)
//! ```
//!
//! Every line carries `ts_ms` (milliseconds since the writer was
//! created), `id` (the wire request id) and `replica`. The render ↔
//! parse pair round-trips (property-tested), so `mars trace summarize
//! FILE` and any jq pipeline read the same truth the server wrote.

use std::fs::File;
use std::io::{BufRead, BufReader, Write as _};
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use super::hist::StreamHistogram;
use super::round::RoundEvent;
use crate::util::json::Value;

/// Request lifecycle phase of one trace line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Router submit → replica admission.
    Queue,
    /// Prompt prefill (or cache-restore + suffix prefill).
    Prefill,
    /// One device turn (embeds the [`RoundEvent`]).
    Round,
    /// Successful terminal commit.
    Commit,
    /// Terminal failure.
    Error,
    /// A dispatch fault poisoned the replica's state (DESIGN.md §13).
    Fault,
    /// An innocent batchmate was requeued after a fault.
    Requeue,
    /// Replica health transition (`detail` carries the new state).
    Health,
    /// The request ran out of its deadline budget (partial commit).
    Deadline,
    /// The request was refused at admission (overload shedding).
    Shed,
}

impl Phase {
    /// Wire name of the phase.
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Queue => "queue",
            Phase::Prefill => "prefill",
            Phase::Round => "round",
            Phase::Commit => "commit",
            Phase::Error => "error",
            Phase::Fault => "fault",
            Phase::Requeue => "requeue",
            Phase::Health => "health",
            Phase::Deadline => "deadline",
            Phase::Shed => "shed",
        }
    }

    /// Inverse of [`as_str`](Phase::as_str).
    pub fn parse(s: &str) -> Option<Phase> {
        Some(match s {
            "queue" => Phase::Queue,
            "prefill" => Phase::Prefill,
            "round" => Phase::Round,
            "commit" => Phase::Commit,
            "error" => Phase::Error,
            "fault" => Phase::Fault,
            "requeue" => Phase::Requeue,
            "health" => Phase::Health,
            "deadline" => Phase::Deadline,
            "shed" => Phase::Shed,
            _ => return None,
        })
    }
}

/// One trace line. Optional fields render only when present, so lines
/// stay short and phase-shaped.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Milliseconds since the trace writer was created.
    pub ts_ms: f64,
    /// Wire request id.
    pub id: u64,
    /// Replica that processed the phase.
    pub replica: usize,
    /// Lifecycle phase.
    pub phase: Phase,
    /// Phase duration, ms (queue wait, prefill time, decode time on the
    /// terminal line).
    pub wall_ms: Option<f64>,
    /// Committed tokens (terminal lines).
    pub tokens: Option<u64>,
    /// Prefix-cache tokens restored (prefill lines).
    pub cached_tokens: Option<u64>,
    /// Mean accepted tokens per round (terminal lines).
    pub tau: Option<f64>,
    /// Terminal outcome.
    pub ok: Option<bool>,
    /// Verification-policy family (terminal lines).
    pub policy: Option<String>,
    /// Speculative-method family (terminal lines).
    pub method: Option<String>,
    /// Free-form qualifier (health state on `health` lines, the fault
    /// message on `fault` lines, the retry count on `requeue` lines).
    pub detail: Option<String>,
    /// The per-turn counters (round lines).
    pub round: Option<RoundEvent>,
}

impl TraceEvent {
    /// Minimal event for a phase; callers fill the optional fields.
    pub fn new(ts_ms: f64, id: u64, replica: usize, phase: Phase) -> Self {
        TraceEvent {
            ts_ms,
            id,
            replica,
            phase,
            wall_ms: None,
            tokens: None,
            cached_tokens: None,
            tau: None,
            ok: None,
            policy: None,
            method: None,
            detail: None,
            round: None,
        }
    }

    /// Render one JSONL line (no trailing newline).
    pub fn render(&self) -> String {
        let mut o = Value::obj();
        o.set("ts_ms", Value::Num(self.ts_ms));
        o.set("id", Value::Num(self.id as f64));
        o.set("replica", Value::Num(self.replica as f64));
        o.set("phase", Value::Str(self.phase.as_str().to_string()));
        if let Some(w) = self.wall_ms {
            o.set("wall_ms", Value::Num(w));
        }
        if let Some(t) = self.tokens {
            o.set("tokens", Value::Num(t as f64));
        }
        if let Some(c) = self.cached_tokens {
            o.set("cached_tokens", Value::Num(c as f64));
        }
        if let Some(t) = self.tau {
            o.set("tau", Value::Num(t));
        }
        if let Some(k) = self.ok {
            o.set("ok", Value::Bool(k));
        }
        if let Some(p) = &self.policy {
            o.set("policy", Value::Str(p.clone()));
        }
        if let Some(m) = &self.method {
            o.set("method", Value::Str(m.clone()));
        }
        if let Some(d) = &self.detail {
            o.set("detail", Value::Str(d.clone()));
        }
        if let Some(r) = &self.round {
            o.set("round", r.to_json());
        }
        o.to_string_json()
    }

    /// Parse one JSONL line back into an event.
    pub fn parse_line(line: &str) -> Result<TraceEvent> {
        let v = Value::parse(line)
            .map_err(|e| anyhow::anyhow!("bad trace line: {e}"))?;
        let phase_str = v
            .get("phase")
            .and_then(|p| p.as_str())
            .context("trace line without \"phase\"")?;
        let phase = Phase::parse(phase_str)
            .with_context(|| format!("unknown trace phase '{phase_str}'"))?;
        let fnum = |k: &str| v.get(k).and_then(|x| x.as_f64());
        let mut ev = TraceEvent::new(
            fnum("ts_ms").context("trace line without \"ts_ms\"")?,
            fnum("id").context("trace line without \"id\"")? as u64,
            fnum("replica").unwrap_or(0.0) as usize,
            phase,
        );
        ev.wall_ms = fnum("wall_ms");
        ev.tokens = fnum("tokens").map(|t| t as u64);
        ev.cached_tokens = fnum("cached_tokens").map(|t| t as u64);
        ev.tau = fnum("tau");
        ev.ok = v.get("ok").and_then(|b| b.as_bool());
        ev.policy =
            v.get("policy").and_then(|p| p.as_str()).map(str::to_string);
        ev.method =
            v.get("method").and_then(|m| m.as_str()).map(str::to_string);
        ev.detail =
            v.get("detail").and_then(|d| d.as_str()).map(str::to_string);
        if let Some(r) = v.get("round") {
            let rnum = |k: &str| r.get(k).and_then(|x| x.as_f64());
            ev.round = Some(RoundEvent {
                turn: rnum("turn").unwrap_or(0.0) as u64,
                rounds: rnum("rounds").unwrap_or(0.0) as u64,
                drafted: rnum("drafted").unwrap_or(0.0) as u64,
                accepted: rnum("accepted").unwrap_or(0.0) as u64,
                exact: rnum("exact").unwrap_or(0.0) as u64,
                relaxed: rnum("relaxed").unwrap_or(0.0) as u64,
                rejects: rnum("rejects").unwrap_or(0.0) as u64,
                committed: rnum("committed").unwrap_or(0.0) as u64,
                last_accept: rnum("last_accept").unwrap_or(0.0) as u64,
                margin: rnum("margin"),
                wall_ms: rnum("wall_ms").unwrap_or(0.0),
                sim_units: rnum("sim_units"),
                pack: rnum("pack").unwrap_or(0.0) as u64,
                occupancy: rnum("occupancy").unwrap_or(0.0) as u64,
                finished: r.get("finished").and_then(|b| b.as_bool())
                    == Some(true),
            });
        }
        Ok(ev)
    }
}

/// Shared, append-only JSONL writer: one per server process, `Arc`-ed
/// into every replica. Writes are line-atomic under the mutex;
/// I/O errors are swallowed (tracing must never fail a request).
#[derive(Debug)]
pub struct TraceWriter {
    file: Mutex<File>,
    epoch: Instant,
}

impl TraceWriter {
    /// Create (truncate) the trace file.
    pub fn create(path: &Path) -> Result<TraceWriter> {
        let file = File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        Ok(TraceWriter { file: Mutex::new(file), epoch: Instant::now() })
    }

    /// Milliseconds since the writer was created — the `ts_ms` clock.
    pub fn now_ms(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e3
    }

    /// Append one event as one line. Best-effort: a poisoned lock or a
    /// full disk drops the line, never the request.
    pub fn log(&self, ev: &TraceEvent) {
        let line = ev.render();
        let mut g = match self.file.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let _ = writeln!(g, "{line}");
    }
}

/// Aggregates `mars trace summarize` prints.
#[derive(Debug, Default)]
pub struct TraceSummary {
    /// Distinct request ids seen.
    pub requests: usize,
    /// Terminal lines with `ok == true` / `ok == false`.
    pub ok: usize,
    /// Failed terminals (`commit` with ok=false, or `error` lines).
    pub err: usize,
    /// Round lines seen.
    pub round_events: u64,
    /// Lines that did not parse (corrupt tail, foreign lines).
    pub bad_lines: usize,
    /// Failure-semantics lines (fault / requeue / health / deadline /
    /// shed, DESIGN.md §13).
    pub fault_events: u64,
    /// Queue-phase wall, ms.
    pub queue_ms: StreamHistogram,
    /// Prefill-phase wall, ms.
    pub prefill_ms: StreamHistogram,
    /// Per-turn wall, ms.
    pub round_ms: StreamHistogram,
    /// Accepted tokens per turn.
    pub accepted: StreamHistogram,
    /// Turns where the relaxed rule fired.
    pub relaxed_rounds: u64,
    /// Committed tokens across ok terminals.
    pub tokens: u64,
}

/// Parse and aggregate a trace file.
pub fn summarize(path: &Path) -> Result<TraceSummary> {
    let f = File::open(path)
        .with_context(|| format!("opening trace {}", path.display()))?;
    let mut s = TraceSummary::default();
    let mut ids = std::collections::BTreeSet::new();
    for line in BufReader::new(f).lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let Ok(ev) = TraceEvent::parse_line(&line) else {
            s.bad_lines += 1;
            continue;
        };
        ids.insert(ev.id);
        match ev.phase {
            Phase::Queue => {
                if let Some(w) = ev.wall_ms {
                    s.queue_ms.record(w);
                }
            }
            Phase::Prefill => {
                if let Some(w) = ev.wall_ms {
                    s.prefill_ms.record(w);
                }
            }
            Phase::Round => {
                s.round_events += 1;
                if let Some(r) = &ev.round {
                    s.round_ms.record(r.wall_ms);
                    s.accepted.record(r.accepted as f64);
                    if r.relaxed > 0 {
                        s.relaxed_rounds += 1;
                    }
                }
            }
            Phase::Commit => {
                if ev.ok == Some(true) {
                    s.ok += 1;
                    s.tokens += ev.tokens.unwrap_or(0);
                } else {
                    s.err += 1;
                }
            }
            Phase::Error => s.err += 1,
            // non-terminal failure-semantics lines: counted, not latency
            Phase::Fault
            | Phase::Requeue
            | Phase::Health
            | Phase::Deadline
            | Phase::Shed => s.fault_events += 1,
        }
    }
    s.requests = ids.len();
    Ok(s)
}

/// Render the summary as the `mars trace summarize` table.
pub fn render_summary(s: &TraceSummary) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "## Trace summary — {} request(s), {} ok / {} err, {} round \
         event(s), {} committed token(s)",
        s.requests, s.ok, s.err, s.round_events, s.tokens
    );
    let _ = writeln!(out);
    let _ = writeln!(out, "| phase | events | p50 (ms) | p99 (ms) | mean (ms) |");
    let _ = writeln!(out, "|---|---|---|---|---|");
    for (name, h) in [
        ("queue", &s.queue_ms),
        ("prefill", &s.prefill_ms),
        ("round", &s.round_ms),
    ] {
        let _ = writeln!(
            out,
            "| {name} | {} | {:.2} | {:.2} | {:.2} |",
            h.count(),
            h.p50(),
            h.p99(),
            h.mean()
        );
    }
    if s.round_events > 0 {
        let _ = writeln!(
            out,
            "\naccepted/turn p50 {:.1} (mean {:.2}); relaxed rule fired in \
             {} of {} turns ({:.1}%)",
            s.accepted.p50(),
            s.accepted.mean(),
            s.relaxed_rounds,
            s.round_events,
            100.0 * s.relaxed_rounds as f64 / s.round_events as f64
        );
    }
    if s.fault_events > 0 {
        let _ = writeln!(
            out,
            "\n{} failure-semantics line(s) (fault/requeue/health/\
             deadline/shed)",
            s.fault_events
        );
    }
    if s.bad_lines > 0 {
        let _ = writeln!(out, "\n{} unparseable line(s) skipped", s.bad_lines);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_parse_round_trip() {
        let mut ev = TraceEvent::new(12.5, 42, 1, Phase::Round);
        ev.round = Some(RoundEvent {
            turn: 3,
            rounds: 1,
            drafted: 7,
            accepted: 5,
            exact: 4,
            relaxed: 1,
            rejects: 1,
            committed: 6,
            last_accept: 5,
            margin: Some(0.94),
            wall_ms: 1.5,
            sim_units: None,
            pack: 1,
            occupancy: 1,
            finished: false,
        });
        let back = TraceEvent::parse_line(&ev.render()).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn phase_names_round_trip() {
        for p in [
            Phase::Queue,
            Phase::Prefill,
            Phase::Round,
            Phase::Commit,
            Phase::Error,
            Phase::Fault,
            Phase::Requeue,
            Phase::Health,
            Phase::Deadline,
            Phase::Shed,
        ] {
            assert_eq!(Phase::parse(p.as_str()), Some(p));
        }
        assert_eq!(Phase::parse("warp"), None);
    }

    #[test]
    fn failure_phase_lines_round_trip_and_count() {
        let mut ev = TraceEvent::new(3.0, 9, 2, Phase::Health);
        ev.detail = Some("draining".to_string());
        let back = TraceEvent::parse_line(&ev.render()).unwrap();
        assert_eq!(back, ev);
        assert_eq!(back.detail.as_deref(), Some("draining"));
        let dir = std::env::temp_dir()
            .join(format!("mars-trace-fault-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f.jsonl");
        let w = TraceWriter::create(&path).unwrap();
        w.log(&ev);
        let mut rq = TraceEvent::new(4.0, 9, 2, Phase::Requeue);
        rq.detail = Some("retry 1".to_string());
        w.log(&rq);
        drop(w);
        let s = summarize(&path).unwrap();
        assert_eq!(s.fault_events, 2);
        assert!(render_summary(&s).contains("failure-semantics"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_and_summarize_end_to_end() {
        let dir = std::env::temp_dir()
            .join(format!("mars-trace-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let w = TraceWriter::create(&path).unwrap();
        let mut q = TraceEvent::new(w.now_ms(), 7, 0, Phase::Queue);
        q.wall_ms = Some(2.0);
        w.log(&q);
        let mut r = TraceEvent::new(w.now_ms(), 7, 0, Phase::Round);
        r.round = Some(RoundEvent {
            accepted: 4,
            relaxed: 1,
            wall_ms: 1.0,
            ..Default::default()
        });
        w.log(&r);
        let mut c = TraceEvent::new(w.now_ms(), 7, 0, Phase::Commit);
        c.ok = Some(true);
        c.tokens = Some(12);
        w.log(&c);
        drop(w);
        let s = summarize(&path).unwrap();
        assert_eq!(s.requests, 1);
        assert_eq!(s.ok, 1);
        assert_eq!(s.round_events, 1);
        assert_eq!(s.relaxed_rounds, 1);
        assert_eq!(s.tokens, 12);
        let table = render_summary(&s);
        assert!(table.contains("1 request(s)"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
