//! Per-round telemetry events (DESIGN.md §12).
//!
//! The engine's commit paths (`SeqRunner::step` and the per-lane half of
//! `BatchRunner::step`) emit one [`RoundEvent`] per device turn through
//! an installed [`RoundSink`]. The sink is deliberately cheap — a boxed
//! `FnMut` qualifies via the blanket impl — so the serving layer can
//! fan one event into the sharded metrics registry and the JSONL trace
//! writer without the engine knowing either exists.
//!
//! [`FlightRecorder`] is the bounded per-sequence buffer the coordinator
//! keeps when it wants the recent round history of a live sequence: a
//! ring of the last [`FlightRecorder::DEFAULT_CAP`] events, O(cap)
//! memory however long the sequence runs.

use crate::util::json::Value;

/// One device turn of one sequence, as seen at commit time.
///
/// A "turn" is one device dispatch (`pack` fused draft-verify rounds);
/// the counters are deltas of the engine snapshot across the dispatch,
/// so summing events over a sequence reproduces its end-of-request
/// aggregates.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RoundEvent {
    /// 0-based device-turn index within the sequence.
    pub turn: u64,
    /// Draft-verify rounds retired by this turn (= pack, except the
    /// final partial turn).
    pub rounds: u64,
    /// Draft tokens proposed this turn.
    pub drafted: u64,
    /// Draft tokens accepted this turn (exact + policy-relaxed).
    pub accepted: u64,
    /// Exact (strict-rule) acceptances this turn.
    pub exact: u64,
    /// Policy-relaxed acceptances this turn — whether the margin rule
    /// fired.
    pub relaxed: u64,
    /// Rejections this turn; the reject position within the last round
    /// is `last_accept` (tokens accepted before the first mismatch).
    pub rejects: u64,
    /// Tokens committed this turn (accepted + bonus/fallback tokens).
    pub committed: u64,
    /// Accepted prefix length of the turn's last round — the accept/
    /// reject position the paper's τ statistics are built from.
    pub last_accept: u64,
    /// Decisive-position target margin (z2/z1) when a probe surfaced
    /// it; `None` on the plain decode path (probes cost a device call).
    pub margin: Option<f64>,
    /// Wall-clock time of the dispatch, milliseconds.
    pub wall_ms: f64,
    /// Simclock cost of the dispatch in model units, when the caller
    /// runs under the simulated clock; `None` in real serving.
    pub sim_units: Option<f64>,
    /// Rounds fused per device call at dispatch time.
    pub pack: u64,
    /// Occupied lanes of the dispatch (1 on the solo/interleaved path).
    pub occupancy: u64,
    /// Whether the sequence finished at this turn.
    pub finished: bool,
}

impl RoundEvent {
    /// JSON object mirror (the trace writer embeds it per round line).
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("turn", Value::Num(self.turn as f64));
        o.set("rounds", Value::Num(self.rounds as f64));
        o.set("drafted", Value::Num(self.drafted as f64));
        o.set("accepted", Value::Num(self.accepted as f64));
        o.set("exact", Value::Num(self.exact as f64));
        o.set("relaxed", Value::Num(self.relaxed as f64));
        o.set("rejects", Value::Num(self.rejects as f64));
        o.set("committed", Value::Num(self.committed as f64));
        o.set("last_accept", Value::Num(self.last_accept as f64));
        if let Some(m) = self.margin {
            o.set("margin", Value::Num(m));
        }
        o.set("wall_ms", Value::Num(self.wall_ms));
        if let Some(u) = self.sim_units {
            o.set("sim_units", Value::Num(u));
        }
        o.set("pack", Value::Num(self.pack as f64));
        o.set("occupancy", Value::Num(self.occupancy as f64));
        o.set("finished", Value::Bool(self.finished));
        o
    }
}

/// Where round events go. Installed on a runner by the serving layer;
/// the engine calls it once per device turn, synchronously, on the
/// decode thread — implementations must be cheap (a histogram record, a
/// buffered write), never blocking on I/O flushes.
pub trait RoundSink: Send {
    /// Observe one committed device turn.
    fn on_round(&mut self, ev: &RoundEvent);
}

impl<F: FnMut(&RoundEvent) + Send> RoundSink for F {
    fn on_round(&mut self, ev: &RoundEvent) {
        self(ev)
    }
}

/// Bounded ring of the most recent round events of one sequence.
#[derive(Debug, Clone, Default)]
pub struct FlightRecorder {
    events: std::collections::VecDeque<RoundEvent>,
    cap: usize,
    dropped: u64,
}

impl FlightRecorder {
    /// Default ring capacity: enough for any max_new at pack 1 on the
    /// default artifact build, small enough to be per-sequence state.
    pub const DEFAULT_CAP: usize = 256;

    /// Recorder with the default capacity.
    pub fn new() -> Self {
        Self::with_capacity(Self::DEFAULT_CAP)
    }

    /// Recorder with an explicit ring capacity (min 1).
    pub fn with_capacity(cap: usize) -> Self {
        FlightRecorder {
            events: std::collections::VecDeque::new(),
            cap: cap.max(1),
            dropped: 0,
        }
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &RoundEvent> {
        self.events.iter()
    }

    /// Events evicted by the ring bound.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// JSON array of the retained events plus a drop marker.
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set(
            "events",
            Value::Arr(self.events.iter().map(|e| e.to_json()).collect()),
        );
        o.set("dropped", Value::Num(self.dropped as f64));
        o
    }
}

impl RoundSink for FlightRecorder {
    fn on_round(&mut self, ev: &RoundEvent) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(*ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(turn: u64) -> RoundEvent {
        RoundEvent { turn, rounds: 1, drafted: 7, ..Default::default() }
    }

    #[test]
    fn flight_recorder_is_bounded() {
        let mut fr = FlightRecorder::with_capacity(4);
        for t in 0..10 {
            fr.on_round(&ev(t));
        }
        let turns: Vec<u64> = fr.events().map(|e| e.turn).collect();
        assert_eq!(turns, vec![6, 7, 8, 9]);
        assert_eq!(fr.dropped(), 6);
    }

    #[test]
    fn closure_sink_via_blanket_impl() {
        let mut seen = 0u64;
        {
            let mut sink = |e: &RoundEvent| seen += e.drafted;
            sink.on_round(&ev(0));
            sink.on_round(&ev(1));
        }
        assert_eq!(seen, 14);
    }

    #[test]
    fn event_json_carries_optional_fields_conditionally() {
        let mut e = ev(3);
        let v = e.to_json();
        assert!(v.get("margin").is_none());
        assert!(v.get("sim_units").is_none());
        e.margin = Some(0.93);
        e.sim_units = Some(1.25);
        let v = e.to_json();
        assert_eq!(v.get("margin").unwrap().as_f64(), Some(0.93));
        assert_eq!(v.get("sim_units").unwrap().as_f64(), Some(1.25));
    }
}
