//! Decode engine: drives one request through prefill → rounds → extract.
//!
//! Method dispatch covers every row of the paper's Table 1:
//!
//! | method        | drafting                         | device program     |
//! |---------------|----------------------------------|--------------------|
//! | `Ar`          | — (1.00× baseline)               | `ar_step`          |
//! | `Sps`         | independent draft LM, chain      | `sps_round`        |
//! | `EagleChain`  | feature-conditioned head, chain  | `eagle_tree_round` (beam 1) |
//! | `EagleTree`   | feature-conditioned head, tree   | `eagle_tree_round` |
//! | `Medusa`      | multi-head static tree           | `medusa_round`     |
//! | `Pld`         | host n-gram prompt lookup        | `verify_ext_round` |
//! | `Lookahead`   | host n-gram pool (simplified)    | `verify_ext_round` |
//!
//! MARS is a *verification policy* ([`GenParams::policy`]), not a method:
//! it changes only the accept/reject rule inside the device-side
//! verification, exactly as in the paper. Every policy of the
//! [`crate::verify`] subsystem composes with every speculative method.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::state::{ProbeDump, Snapshot};
use crate::runtime::Runtime;
#[allow(unused_imports)]
use crate::runtime::Session;
use crate::spec::{HostDrafter, LookaheadDrafter, PldDrafter};
use crate::verify::VerifyPolicy;

/// Decoding method (the paper's baselines + MARS host).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Ar,
    Sps,
    EagleChain,
    EagleTree,
    Medusa,
    Pld,
    Lookahead,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "ar" | "baseline" | "vanilla" => Method::Ar,
            "sps" | "spd" => Method::Sps,
            "eagle" | "eagle_chain" | "eagle-chain" => Method::EagleChain,
            "eagle_tree" | "eagle-tree" | "eagle3" | "tree" => Method::EagleTree,
            "medusa" => Method::Medusa,
            "pld" => Method::Pld,
            "lookahead" | "la" => Method::Lookahead,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Ar => "ar",
            Method::Sps => "sps",
            Method::EagleChain => "eagle_chain",
            Method::EagleTree => "eagle_tree",
            Method::Medusa => "medusa",
            Method::Pld => "pld",
            Method::Lookahead => "lookahead",
        }
    }

    /// Does this method use draft-verify rounds (i.e. has a meaningful τ)?
    pub fn is_speculative(&self) -> bool {
        !matches!(self, Method::Ar)
    }

    pub fn all() -> &'static [Method] {
        &[
            Method::Ar,
            Method::Sps,
            Method::EagleChain,
            Method::EagleTree,
            Method::Medusa,
            Method::Pld,
            Method::Lookahead,
        ]
    }
}

/// Generation parameters for one request.
#[derive(Debug, Clone)]
pub struct GenParams {
    pub method: Method,
    /// verification policy applied on top of the method's drafting
    /// (`Strict` reproduces the lossless baseline rule; `Mars` is the
    /// paper's margin-aware relaxation)
    pub policy: VerifyPolicy,
    /// sampling temperature; 0 = greedy
    pub temperature: f32,
    /// chain draft length / tree depth K
    pub k: usize,
    /// tree beam width (EagleTree)
    pub beam: usize,
    /// children per node (EagleTree)
    pub branch: usize,
    pub max_new: usize,
    pub seed: u64,
    /// record (z1, z2, flag) probe entries for figures 1/4
    pub probe: bool,
    /// pull a snapshot every N rounds (1 = exact stats; >1 trades stat
    /// granularity for fewer device calls — §Perf lever)
    pub extract_every: usize,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            method: Method::EagleTree,
            policy: VerifyPolicy::default(),
            temperature: 1.0,
            k: 7,
            beam: 2,
            branch: 2,
            max_new: 160,
            seed: 0,
            probe: false,
            extract_every: 1,
        }
    }
}

/// Result of one generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub tokens: Vec<u32>,
    pub text: String,
    /// wall-clock decode time (prefill excluded), seconds
    pub decode_seconds: f64,
    pub prefill_seconds: f64,
    pub snapshot: Snapshot,
    pub probe: Option<ProbeDump>,
    pub device_calls: u64,
}

impl GenResult {
    pub fn tau(&self) -> f64 {
        self.snapshot.tau()
    }

    /// Tokens per second of decode.
    pub fn tok_per_sec(&self) -> f64 {
        if self.decode_seconds > 0.0 {
            self.tokens.len() as f64 / self.decode_seconds
        } else {
            0.0
        }
    }
}

/// An in-flight sequence: prefillled session + host drafter + progress.
///
/// Exposes incremental [`SeqRunner::step`] so the coordinator's replicas
/// can interleave many sequences over one device (continuous batching);
/// [`DecodeEngine::generate`] is the run-to-completion convenience loop.
pub struct SeqRunner<'a> {
    sess: crate::runtime::Session<'a>,
    params: GenParams,
    exec: &'static str,
    drafter: Option<Box<dyn HostDrafter + Send>>,
    prompt: Vec<u32>,
    history: Vec<u32>,
    spins: usize,
    round_cap: usize,
    /// Wall-clock prefill time, seconds (stamped in [`SeqRunner::new`]).
    pub prefill_seconds: f64,
    decode_started: Option<Instant>,
    decode_seconds: f64,
    /// Round-commit callback: invoked after every snapshot pull whose
    /// committed prefix grew, with the full committed token slice
    /// (clamped to `max_new`, exactly like the final result).
    on_commit: Option<OnCommit>,
    /// Tokens already reported through `on_commit`.
    reported: usize,
}

/// Round-commit callback type (see [`SeqRunner::set_on_commit`]). The
/// argument is the *entire* committed token prefix, not just the new
/// tail, so sinks can diff text without tracking token state.
pub type OnCommit = Box<dyn FnMut(&[u32]) + Send>;

impl<'a> SeqRunner<'a> {
    pub fn new(
        rt: &'a Runtime,
        prompt: &[u32],
        params: &GenParams,
        hostloop: bool,
    ) -> Result<Self> {
        let mut params = params.clone();
        if params.method == Method::EagleChain {
            // chain decoding is the beam-1 degenerate tree
            params.beam = 1;
            params.branch = 1;
        }
        let t0 = Instant::now();
        let mut sess = rt.session(prompt, &params)?;
        if hostloop {
            sess.set_hostloop(true)?;
        }
        let prefill_seconds = t0.elapsed().as_secs_f64();
        let exec = match params.method {
            Method::Ar => "ar_step",
            Method::Sps => "sps_round",
            Method::EagleChain | Method::EagleTree => "eagle_tree_round",
            Method::Medusa => "medusa_round",
            Method::Pld | Method::Lookahead => "verify_ext_round",
        };
        let drafter: Option<Box<dyn HostDrafter + Send>> = match params.method
        {
            Method::Pld => Some(Box::new(PldDrafter::default())),
            Method::Lookahead => Some(Box::new(LookaheadDrafter::default())),
            _ => None,
        };
        // generous hard cap: even tau=1 finishes within max_new rounds
        let round_cap = params.max_new * 2 + 8;
        Ok(SeqRunner {
            sess,
            params,
            exec,
            drafter,
            prompt: prompt.to_vec(),
            history: prompt.to_vec(),
            spins: 0,
            round_cap,
            prefill_seconds,
            decode_started: None,
            decode_seconds: 0.0,
            on_commit: None,
            reported: 0,
        })
    }

    /// Install the round-commit callback driving token streaming: after
    /// every [`SeqRunner::step`] that commits new tokens, `cb` receives
    /// the full committed prefix (clamped to `max_new`). Concatenating
    /// the text deltas a sink derives from successive calls reproduces
    /// the final [`GenResult::text`] exactly (the byte-level tokenizer
    /// decodes each token independently, so prefixes are stable).
    pub fn set_on_commit(&mut self, cb: OnCommit) {
        self.on_commit = Some(cb);
    }

    /// Tokens committed so far (clamped to `max_new`).
    pub fn committed(&self) -> usize {
        (self.history.len() - self.prompt.len()).min(self.params.max_new)
    }

    /// Run `extract_every` rounds + one snapshot pull. Returns the final
    /// result once the sequence has finished.
    pub fn step(&mut self) -> Result<Option<GenResult>> {
        let t = Instant::now();
        if self.decode_started.is_none() {
            self.decode_started = Some(t);
        }
        let every = self.params.extract_every.max(1);
        for _ in 0..every {
            match &mut self.drafter {
                Some(d) => {
                    d.observe(&self.history);
                    let drafts = d.draft(&self.history, self.params.k);
                    self.sess.round_ext(&drafts)?;
                }
                None => self.sess.round(self.exec)?,
            }
            self.spins += 1;
        }
        let snap = self.sess.extract()?;
        self.history = self.prompt.clone();
        self.history.extend(&snap.tokens);
        self.decode_seconds += t.elapsed().as_secs_f64();
        self.fire_on_commit(&snap);
        if snap.finished || self.spins >= self.round_cap {
            return Ok(Some(self.finalize(snap)?));
        }
        Ok(None)
    }

    /// Finalize mid-flight with whatever has committed (the cancel path:
    /// no further rounds run; the result mirrors a natural finish except
    /// the text may be a prefix).
    pub fn finish_early(&mut self) -> Result<GenResult> {
        let snap = self.sess.extract()?;
        self.history = self.prompt.clone();
        self.history.extend(&snap.tokens);
        self.fire_on_commit(&snap);
        self.finalize(snap)
    }

    fn fire_on_commit(&mut self, snap: &Snapshot) {
        let n = snap.tokens.len().min(self.params.max_new);
        if n > self.reported {
            if let Some(cb) = &mut self.on_commit {
                cb(&snap.tokens[..n]);
            }
            self.reported = n;
        }
    }

    fn finalize(&mut self, snap: Snapshot) -> Result<GenResult> {
        let probe = if self.params.probe {
            Some(self.sess.extract_probe()?)
        } else {
            None
        };
        // host-side truncation: rounds commit in chunks and may overshoot
        let mut tokens = snap.tokens.clone();
        tokens.truncate(self.params.max_new);
        let text = crate::tokenizer::decode(&tokens);
        Ok(GenResult {
            tokens,
            text,
            decode_seconds: self.decode_seconds,
            prefill_seconds: self.prefill_seconds,
            snapshot: snap,
            probe,
            device_calls: self.sess.device_calls,
        })
    }
}

/// The decode engine: a thin, single-threaded driver over a [`Runtime`].
pub struct DecodeEngine {
    pub rt: Runtime,
    /// force the naive host-roundtrip runtime (§Perf baseline)
    pub hostloop: bool,
}

impl DecodeEngine {
    pub fn new(rt: Runtime) -> Self {
        DecodeEngine { rt, hostloop: false }
    }

    /// Generate a completion for a prompt string.
    pub fn generate(&self, prompt: &str, params: &GenParams) -> Result<GenResult> {
        let toks = crate::tokenizer::encode(prompt);
        self.generate_tokens(&toks, params)
    }

    pub fn generate_tokens(
        &self,
        prompt: &[u32],
        params: &GenParams,
    ) -> Result<GenResult> {
        let mut runner =
            SeqRunner::new(&self.rt, prompt, params, self.hostloop)?;
        loop {
            if let Some(result) = runner.step()? {
                return Ok(result);
            }
        }
    }
}
