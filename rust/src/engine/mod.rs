//! Decode engine: drives one request through prefill → rounds → extract.
//!
//! Method dispatch covers every row of the paper's Table 1 through the
//! [`SpecMethod`] descriptor registry (`crate::spec::METHODS`,
//! DESIGN.md §7):
//!
//! | descriptor                         | drafting                         | device program     |
//! |------------------------------------|----------------------------------|--------------------|
//! | `ar`                               | — (1.00× baseline)               | `ar_step`          |
//! | `sps:k=7`                          | independent draft LM, chain      | `sps_round`        |
//! | `eagle_chain:k=7`                  | feature-conditioned head, chain  | `eagle_tree_round` (beam 1) |
//! | `eagle_tree:k=7,beam=2,branch=2`   | feature-conditioned head, tree   | `eagle_tree_round` |
//! | `medusa:k=4`                       | multi-head static tree           | `medusa_round`     |
//! | `pld:min=2,max=4,k=7`              | host n-gram prompt lookup        | `verify_ext_round` |
//! | `lookahead:n=3,g=8,cap=4096,k=7`   | host n-gram pool (simplified)    | `verify_ext_round` |
//!
//! Round packing ([`GenParams::rounds_per_call`] > 1, DESIGN.md §9.6)
//! swaps the device program for the method's fused `*_multi` variant
//! ([`SpecMethod::multi_exec_name`]) running up to N rounds per dispatch
//! with one `extract` per packed call — token-identical to the unpacked
//! path, minus the per-round dispatch tax. Host-drafted methods and
//! artifacts without the `*_multi` programs fall back to single rounds.
//!
//! Cross-sequence batching (DESIGN.md §9.5) is the other dispatch
//! amortization axis: a [`BatchRunner`] steps up to `batch_max` lanes
//! per `*_batch` dispatch, each lane a [`SeqRunner`]-equivalent view
//! (same prefill path, same commit callbacks, same [`effective_pack`]
//! budget per lane via `*_batch_multi`). Requests join and leave at
//! round boundaries — the replica's continuous-batching admission loop.
//!
//! MARS is a *verification policy* ([`GenParams::policy`]), not a method:
//! it changes only the accept/reject rule inside the device-side
//! verification, exactly as in the paper. Every policy of the
//! [`crate::verify`] subsystem composes with every [`SpecMethod`]; the
//! engine never matches on method variants — it asks the descriptor for a
//! [`DraftSource`] and the runtime lowers the descriptor's knobs to
//! config slots.

#![warn(missing_docs)]

use std::time::Instant;

use anyhow::Result;

use crate::cache::SharedPrefixCache;
use crate::obs::round::{RoundEvent, RoundSink};
use crate::runtime::state::{ProbeDump, Snapshot};
use crate::runtime::Runtime;
#[allow(unused_imports)]
use crate::runtime::Session;
use crate::spec::DraftSource;
pub use crate::spec::SpecMethod;
use crate::verify::VerifyPolicy;

/// Generation parameters for one request. Everything method-shaped lives
/// inside the [`SpecMethod`] descriptor; everything here is orthogonal to
/// the drafting method.
#[derive(Debug, Clone, PartialEq)]
pub struct GenParams {
    /// Speculative-decoding method descriptor (family + drafting knobs).
    pub method: SpecMethod,
    /// verification policy applied on top of the method's drafting
    /// (`Strict` reproduces the lossless baseline rule; `Mars` is the
    /// paper's margin-aware relaxation)
    pub policy: VerifyPolicy,
    /// sampling temperature; 0 = greedy
    pub temperature: f32,
    /// Generation budget (committed tokens are truncated to this).
    pub max_new: usize,
    /// Sampling seed (folded into the device RNG counter).
    pub seed: u64,
    /// record (z1, z2, flag) probe entries for figures 1/4
    pub probe: bool,
    /// pull a snapshot every N rounds (1 = exact stats; >1 trades stat
    /// granularity for fewer device calls — §Perf lever). Ignored while
    /// round packing is active ([`GenParams::rounds_per_call`] > 1 on a
    /// packable method): a packed call already amortizes the snapshot
    /// to one `extract` per fused pack.
    pub extract_every: usize,
    /// Rounds fused per device dispatch (round packing, DESIGN.md §9.6;
    /// CLI `--pack`, wire `"rounds_per_call"`). 1 = the classic
    /// one-dispatch-per-round path; > 1 drives the method's `*_multi`
    /// program with one `extract` per packed call, adaptively shrunk
    /// near the generation budget. Host-drafted methods and artifact
    /// sets without the `*_multi` programs fall back to 1.
    pub rounds_per_call: usize,
    /// opt this request into prefix-cache reuse when its replica carries
    /// a cache (wire field `"cache": false` opts out; see `crate::cache`)
    pub cache: bool,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            method: SpecMethod::default(),
            policy: VerifyPolicy::default(),
            temperature: 1.0,
            max_new: 160,
            seed: 0,
            probe: false,
            extract_every: 1,
            rounds_per_call: 1,
            cache: true,
        }
    }
}

/// The adaptive pack controller (pure, property-tested): how many rounds
/// the next packed call should fuse given the configured pack, an
/// external cap (the replica caps streaming slots at 1 to keep per-round
/// delta granularity), and generation progress. Packs aggressively
/// mid-sequence, but:
///
/// * **TTFT guard** — the first call after prefill always runs a single
///   round, so the time to the first committed token never stretches by
///   the pack factor;
/// * **budget shrink** — every round commits at least one token, so a
///   pack larger than the remaining `max_new` budget is guaranteed
///   overrun work; the pack shrinks to the remainder as the sequence
///   approaches its budget (the device additionally exits its fused loop
///   at the stop flag, so this bounds even the worst case twice).
pub fn effective_pack(
    configured: usize,
    cap: usize,
    committed: usize,
    max_new: usize,
) -> usize {
    let pack = configured.clamp(1, cap.max(1));
    if committed == 0 {
        return 1;
    }
    pack.min(max_new.saturating_sub(committed).max(1))
}

/// Result of one generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    /// Committed output tokens (truncated to `max_new`).
    pub tokens: Vec<u32>,
    /// Decoded completion text.
    pub text: String,
    /// wall-clock decode time (prefill excluded), seconds
    pub decode_seconds: f64,
    /// Wall-clock prefill time, seconds.
    pub prefill_seconds: f64,
    /// Prompt tokens restored from a prefix-cache snapshot instead of
    /// prefilled (0 on a cold prefill; the suffix past this count is all
    /// the prefill work this request actually did).
    pub prefill_cached_tokens: usize,
    /// Final device snapshot (acceptance stats, rounds, counters).
    pub snapshot: Snapshot,
    /// Probe-ring dump when [`GenParams::probe`] was set.
    pub probe: Option<ProbeDump>,
    /// Total device executions this request issued (under batching: the
    /// dispatches this request's stream participated in — a shared
    /// batched dispatch counts once per participating lane).
    pub device_calls: u64,
    /// This request's *amortized* dispatch count: each device dispatch
    /// contributes `1 / occupancy` to every lane it stepped, so a B=4
    /// batched round costs each lane a quarter dispatch. Equal to
    /// `device_calls` on the solo path (occupancy 1). The simulated-cost
    /// model charges its per-dispatch overhead against this, not
    /// `device_calls` (DESIGN.md §9.5; `bench::simclock`).
    pub dispatch_share: f64,
    /// The per-request deadline fired before the sequence finished
    /// naturally (DESIGN.md §13): `tokens`/`text` hold the partial
    /// committed prefix, and the serving layer echoes
    /// `"deadline_exceeded": true` on the wire.
    pub deadline_exceeded: bool,
}

impl GenResult {
    /// Mean accepted tokens per draft-verify cycle.
    pub fn tau(&self) -> f64 {
        self.snapshot.tau()
    }

    /// Tokens per second of decode.
    pub fn tok_per_sec(&self) -> f64 {
        if self.decode_seconds > 0.0 {
            self.tokens.len() as f64 / self.decode_seconds
        } else {
            0.0
        }
    }
}

/// An in-flight sequence: prefilled session + draft source + progress.
///
/// Exposes incremental [`SeqRunner::step`] so the coordinator's replicas
/// can interleave many sequences over one device (continuous batching);
/// [`DecodeEngine::generate`] is the run-to-completion convenience loop.
/// The per-request [`DraftSource`] is built from the [`SpecMethod`]
/// descriptor, so drafting knobs (`pld:min=3,max=5`, `lookahead:cap=64`)
/// configure the actual drafter instead of being ignored.
pub struct SeqRunner<'a> {
    sess: crate::runtime::Session<'a>,
    params: GenParams,
    source: Box<dyn DraftSource>,
    /// The method's fused multi-round program, resolved once at
    /// construction: `Some` only when the request packs
    /// (`rounds_per_call > 1`), the method is device-coupled, and the
    /// artifact set carries the `*_multi` executable (capability
    /// detection — old artifacts fall back to single rounds).
    multi_exec: Option<&'static str>,
    /// External pack cap ([`SeqRunner::set_pack_cap`]): the replica caps
    /// streaming slots at 1 so every round still emits its delta.
    pack_cap: usize,
    prompt: Vec<u32>,
    history: Vec<u32>,
    spins: usize,
    round_cap: usize,
    /// Wall-clock prefill time, seconds (stamped in [`SeqRunner::new`]).
    pub prefill_seconds: f64,
    /// Prompt tokens restored from the replica's prefix cache (stamped
    /// next to [`SeqRunner::prefill_seconds`]; 0 on a cold prefill).
    pub prefill_cached_tokens: usize,
    /// The replica's prefix cache, kept for the post-commit snapshot
    /// export in [`SeqRunner::finalize`] (`None` = no reuse).
    cache: Option<SharedPrefixCache>,
    decode_started: Option<Instant>,
    decode_seconds: f64,
    /// Round-commit callback: invoked after every snapshot pull whose
    /// committed prefix grew, with the full committed token slice
    /// (clamped to `max_new`, exactly like the final result).
    on_commit: Option<OnCommit>,
    /// Tokens already reported through `on_commit`.
    reported: usize,
    /// Per-turn telemetry sink (DESIGN.md §12): receives one
    /// [`RoundEvent`] after every snapshot pull.
    round_sink: Option<Box<dyn RoundSink>>,
    /// Previous-snapshot counters backing the sink's per-turn deltas.
    cursor: RoundCursor,
    /// Absolute per-request deadline ([`SeqRunner::set_deadline`],
    /// DESIGN.md §13), checked at every round boundary.
    deadline: Option<Instant>,
    /// Set once the deadline check fired; copied into the result.
    deadline_exceeded: bool,
}

/// Round-commit callback type (see [`SeqRunner::set_on_commit`]). The
/// argument is the *entire* committed token prefix, not just the new
/// tail, so sinks can diff text without tracking token state.
pub type OnCommit = Box<dyn FnMut(&[u32]) + Send>;

/// Snapshot counters at the previous commit: subtracting them from the
/// fresh snapshot yields one device turn's [`RoundEvent`] deltas
/// (DESIGN.md §12). The device counters are monotone f64 accumulators
/// holding small integers, so clamped rounded differences are exact.
#[derive(Debug, Clone, Copy, Default)]
struct RoundCursor {
    turn: u64,
    rounds: f64,
    draft_steps: f64,
    exact: f64,
    relaxed: f64,
    rejects: f64,
    committed: f64,
}

impl RoundCursor {
    /// Build this turn's event from the fresh snapshot and advance the
    /// cursor past it.
    fn advance(
        &mut self,
        snap: &Snapshot,
        wall_ms: f64,
        pack: u64,
        occupancy: u64,
    ) -> RoundEvent {
        let d = |now: f64, before: f64| (now - before).max(0.0) as u64;
        let exact = d(snap.exact_accepts, self.exact);
        let relaxed = d(snap.relaxed_accepts, self.relaxed);
        let ev = RoundEvent {
            turn: self.turn,
            rounds: d(snap.rounds, self.rounds),
            drafted: d(snap.draft_steps, self.draft_steps),
            accepted: exact + relaxed,
            exact,
            relaxed,
            rejects: d(snap.rejects, self.rejects),
            committed: d(snap.committed, self.committed),
            last_accept: snap.last_accept.max(0.0) as u64,
            margin: None,
            wall_ms,
            sim_units: None,
            pack,
            occupancy,
            finished: snap.finished,
        };
        self.turn += 1;
        self.rounds = snap.rounds;
        self.draft_steps = snap.draft_steps;
        self.exact = snap.exact_accepts;
        self.relaxed = snap.relaxed_accepts;
        self.rejects = snap.rejects;
        self.committed = snap.committed;
        ev
    }
}

/// Clamp the requested `rounds_per_call` to the artifact's `PACK_MAX`:
/// the device clamps its fused loop to the same bound, so the round
/// accounting (`spins`), the lowered cfg slot and the echoed value all
/// describe rounds the device can actually run. Artifact sets that
/// predate packing carry no `pack_max` const (and no `*_multi`
/// programs) — treat their bound as 1.
fn clamp_rounds_per_call(rt: &Runtime, params: &mut GenParams) {
    if params.rounds_per_call > 1 {
        let pack_max =
            rt.layout().consts.get("pack_max").copied().unwrap_or(1);
        params.rounds_per_call = params.rounds_per_call.min(pack_max.max(1));
    }
}

/// Prefill one request's solo session, consulting the replica's prefix
/// cache (DESIGN.md §8) — the path [`SeqRunner::new_with_cache`] always
/// ran, factored out so [`BatchRunner::admit`] prefills lanes through
/// the *identical* logic (a batched lane is a solo prefill spliced into
/// the stacked state via `batch_join`). Returns the session plus the
/// restored-prefix length (0 on a cold prefill); a failed restore falls
/// back to a cold prefill, and a freshly prefilled prompt is exported
/// back into the cache for future requests.
fn prefill_session<'a>(
    rt: &'a Runtime,
    prompt: &[u32],
    params: &GenParams,
    cache: &Option<SharedPrefixCache>,
) -> Result<(crate::runtime::Session<'a>, usize)> {
    let full_only = !rt.supports_suffix_prefill();
    let hit = cache.as_ref().and_then(|c| {
        let mut c = c.borrow_mut();
        let hit = c.lookup(prompt, full_only);
        if hit.is_none() {
            c.note_miss();
        }
        hit
    });
    let mut prefill_cached_tokens = 0;
    let mut sess = match hit {
        Some((l, state)) => {
            match rt.session_from_state(&state, l, prompt, params) {
                Ok(s) => {
                    prefill_cached_tokens = l;
                    s
                }
                Err(_) => {
                    // the fallback is a cold prefill: take the hit's
                    // accounting back so metrics only report reuse
                    // that actually happened
                    if let Some(c) = cache {
                        c.borrow_mut().rescind_hit(l);
                    }
                    rt.session(prompt, params)?
                }
            }
        }
        None => rt.session(prompt, params)?,
    };
    // snapshot the freshly prefilled prompt for future requests
    // (skipped when the whole prompt was already cached)
    if let Some(c) = cache {
        if prefill_cached_tokens < prompt.len() {
            if let Ok(state) = sess.export_state() {
                c.borrow_mut().insert(prompt, state);
            }
        }
    }
    Ok((sess, prefill_cached_tokens))
}

impl<'a> SeqRunner<'a> {
    /// Prefill `prompt` and set up the per-request draft source from the
    /// method descriptor.
    pub fn new(
        rt: &'a Runtime,
        prompt: &[u32],
        params: &GenParams,
        hostloop: bool,
    ) -> Result<Self> {
        SeqRunner::new_with_cache(rt, prompt, params, hostloop, None)
    }

    /// [`SeqRunner::new`] with the replica's prefix cache: the longest
    /// cached state prefix of `prompt` is restored instead of prefilled
    /// (partial hits additionally need the `prefill_ext` artifact —
    /// without it only exact full-prompt hits restore), and fresh
    /// snapshots are exported back after prefill and after the final
    /// commit so follow-up turns extending this context hit too. A failed
    /// restore falls back to a cold prefill: the cache accelerates
    /// requests, it never fails them.
    pub fn new_with_cache(
        rt: &'a Runtime,
        prompt: &[u32],
        params: &GenParams,
        hostloop: bool,
        cache: Option<SharedPrefixCache>,
    ) -> Result<Self> {
        let mut params = params.clone();
        clamp_rounds_per_call(rt, &mut params);
        let t0 = Instant::now();
        let (mut sess, prefill_cached_tokens) =
            prefill_session(rt, prompt, &params, &cache)?;
        if hostloop {
            sess.set_hostloop(true)?;
        }
        let prefill_seconds = t0.elapsed().as_secs_f64();
        let source = params.method.draft_source();
        let multi_exec = if params.rounds_per_call > 1 {
            params
                .method
                .multi_exec_name()
                .filter(|name| rt.supports_round_packing(name))
        } else {
            None
        };
        // generous hard cap: even tau=1 finishes within max_new rounds
        let round_cap = params.max_new * 2 + 8;
        Ok(SeqRunner {
            sess,
            params,
            source,
            multi_exec,
            pack_cap: usize::MAX,
            prompt: prompt.to_vec(),
            history: prompt.to_vec(),
            spins: 0,
            round_cap,
            prefill_seconds,
            prefill_cached_tokens,
            cache,
            decode_started: None,
            decode_seconds: 0.0,
            on_commit: None,
            reported: 0,
            round_sink: None,
            cursor: RoundCursor::default(),
            deadline: None,
            deadline_exceeded: false,
        })
    }

    /// Install an absolute per-request deadline (DESIGN.md §13): checked
    /// before every [`SeqRunner::step`] device turn, so a sequence past
    /// its deadline finalizes at the round boundary with its partial
    /// committed prefix and [`GenResult::deadline_exceeded`] set. `None`
    /// clears the deadline.
    pub fn set_deadline(&mut self, deadline: Option<Instant>) {
        self.deadline = deadline;
    }

    /// Install the round-commit callback driving token streaming: after
    /// every [`SeqRunner::step`] that commits new tokens, `cb` receives
    /// the full committed prefix (clamped to `max_new`). Concatenating
    /// the text deltas a sink derives from successive calls reproduces
    /// the final [`GenResult::text`] exactly (the byte-level tokenizer
    /// decodes each token independently, so prefixes are stable).
    pub fn set_on_commit(&mut self, cb: OnCommit) {
        self.on_commit = Some(cb);
    }

    /// Install the per-turn telemetry sink: after every
    /// [`SeqRunner::step`] snapshot pull, the sink receives one
    /// [`RoundEvent`] carrying that turn's counter deltas, wall time and
    /// pack (DESIGN.md §12). Orthogonal to [`SeqRunner::set_on_commit`]
    /// — streaming reports tokens, the sink reports accept behavior.
    pub fn set_round_sink(&mut self, sink: Box<dyn RoundSink>) {
        self.round_sink = Some(sink);
    }

    /// Tokens committed so far (clamped to `max_new`).
    pub fn committed(&self) -> usize {
        (self.history.len() - self.prompt.len()).min(self.params.max_new)
    }

    /// Cap the pack externally (packing-aware scheduling): the replica
    /// sets 1 on streaming slots so every verify round still emits its
    /// delta, and a packed step never holds the device R× longer than
    /// the slot's latency contract allows.
    pub fn set_pack_cap(&mut self, cap: usize) {
        self.pack_cap = cap.max(1);
    }

    /// The steady-state packing this sequence actually runs: the
    /// configured `rounds_per_call` bounded by the external cap, or 1
    /// when the method or artifact set cannot pack at all (host
    /// drafters, pre-`*_multi` artifacts). This — not the requested
    /// knob — is what the serving layer echoes as `"rounds_per_call"`.
    pub fn effective_rounds_per_call(&self) -> usize {
        if self.multi_exec.is_none() {
            1
        } else {
            self.params.rounds_per_call.clamp(1, self.pack_cap)
        }
    }

    /// The pack the next step will request (1 on the unpacked path).
    pub fn next_pack(&self) -> usize {
        if self.multi_exec.is_none() {
            return 1;
        }
        effective_pack(
            self.params.rounds_per_call,
            self.pack_cap,
            self.committed(),
            self.params.max_new,
        )
    }

    /// Run one device turn + one snapshot pull: `extract_every` rounds on
    /// the classic path, or one fused `*_multi` call of up to
    /// [`SeqRunner::next_pack`] rounds when the request packs — either
    /// way `extract` runs once per turn, not once per round. Returns the
    /// final result once the sequence has finished.
    pub fn step(&mut self) -> Result<Option<GenResult>> {
        let t = Instant::now();
        // deadline enforcement at the round boundary: no further device
        // turns; finalize with whatever has committed
        if let Some(dl) = self.deadline {
            if t >= dl {
                self.deadline_exceeded = true;
                return Ok(Some(self.finish_early()?));
            }
        }
        if self.decode_started.is_none() {
            self.decode_started = Some(t);
        }
        let pack;
        match self.multi_exec {
            Some(exec) => {
                let p = self.next_pack();
                if p > 1 {
                    self.sess.round_packed(exec, p)?;
                } else {
                    // a single round needs no pack argument — drive the
                    // plain program (also what the TTFT guard runs)
                    self.sess.round(self.source.exec_name())?;
                }
                self.spins += p;
                pack = p as u64;
            }
            None => {
                let every = self.params.extract_every.max(1);
                for _ in 0..every {
                    match self.source.next_drafts(&self.history) {
                        Some(drafts) => self.sess.round_ext(&drafts)?,
                        None => self.sess.round(self.source.exec_name())?,
                    }
                    self.spins += 1;
                }
                pack = 1;
            }
        }
        let snap = self.sess.extract()?;
        self.history = self.prompt.clone();
        self.history.extend(&snap.tokens);
        let dt = t.elapsed().as_secs_f64();
        self.decode_seconds += dt;
        self.fire_on_commit(&snap);
        if let Some(sink) = &mut self.round_sink {
            // solo decode: occupancy 1 by construction
            let ev = self.cursor.advance(&snap, dt * 1e3, pack, 1);
            sink.on_round(&ev);
        }
        if snap.finished || self.spins >= self.round_cap {
            return Ok(Some(self.finalize(snap)?));
        }
        Ok(None)
    }

    /// Finalize mid-flight with whatever has committed (the cancel path:
    /// no further rounds run; the result mirrors a natural finish except
    /// the text may be a prefix).
    pub fn finish_early(&mut self) -> Result<GenResult> {
        let snap = self.sess.extract()?;
        self.history = self.prompt.clone();
        self.history.extend(&snap.tokens);
        self.fire_on_commit(&snap);
        self.finalize(snap)
    }

    fn fire_on_commit(&mut self, snap: &Snapshot) {
        let n = snap.tokens.len().min(self.params.max_new);
        if n > self.reported {
            if let Some(cb) = &mut self.on_commit {
                cb(&snap.tokens[..n]);
            }
            self.reported = n;
        }
    }

    fn finalize(&mut self, snap: Snapshot) -> Result<GenResult> {
        let probe = if self.params.probe {
            Some(self.sess.extract_probe()?)
        } else {
            None
        };
        // snapshot the whole committed context for follow-up turns: a
        // multi-turn client's next prompt extends exactly these tokens.
        // The guards pin the key to the device's own row count (out-ring
        // overflow would desynchronize key and state) and to the
        // *client-visible* tokens: a chunked final round may overshoot
        // max_new, and a key carrying tokens the truncated reply never
        // showed could not prefix-match any follow-up prompt — skip the
        // export instead of caching a dead entry.
        if let Some(c) = &self.cache {
            if !snap.tokens.is_empty()
                && snap.tokens.len() <= self.params.max_new
                && snap.pos == self.prompt.len() + snap.tokens.len()
            {
                let mut key = self.prompt.clone();
                key.extend(&snap.tokens);
                if let Ok(state) = self.sess.export_state() {
                    c.borrow_mut().insert(&key, state);
                }
            }
        }
        // host-side truncation: rounds commit in chunks and may overshoot
        let mut tokens = snap.tokens.clone();
        tokens.truncate(self.params.max_new);
        let text = crate::tokenizer::decode(&tokens);
        Ok(GenResult {
            tokens,
            text,
            decode_seconds: self.decode_seconds,
            prefill_seconds: self.prefill_seconds,
            prefill_cached_tokens: self.prefill_cached_tokens,
            snapshot: snap,
            probe,
            device_calls: self.sess.device_calls,
            // solo decode: every dispatch served this one sequence
            dispatch_share: self.sess.device_calls as f64,
            deadline_exceeded: self.deadline_exceeded,
        })
    }
}

/// One lane of a [`BatchRunner`]: the per-sequence bookkeeping a
/// [`SeqRunner`] keeps, minus the session — the device state is one
/// slot of the shared stacked [`crate::runtime::BatchSession`].
struct Lane {
    params: GenParams,
    source: Box<dyn DraftSource>,
    /// This lane drives a per-lane `*_batch_multi` round budget
    /// (`rounds_per_call > 1` on a packable family).
    packs: bool,
    pack_cap: usize,
    prompt: Vec<u32>,
    history: Vec<u32>,
    spins: usize,
    round_cap: usize,
    prefill_seconds: f64,
    prefill_cached_tokens: usize,
    cache: Option<SharedPrefixCache>,
    decode_seconds: f64,
    on_commit: Option<OnCommit>,
    reported: usize,
    /// Per-turn telemetry sink (mirrors [`SeqRunner`]'s; DESIGN.md §12).
    round_sink: Option<Box<dyn RoundSink>>,
    /// Previous-snapshot counters backing the sink's per-turn deltas.
    cursor: RoundCursor,
    /// Dispatches this lane's stream participated in (prefill + join are
    /// dedicated; batched rounds count once per participating lane).
    device_calls: u64,
    /// Σ `1 / occupancy` over this lane's dispatches (the amortized
    /// dispatch count, see [`GenResult::dispatch_share`]).
    dispatch_share: f64,
    /// Finalize at the next round boundary without further rounds.
    cancel: bool,
    /// Absolute per-request deadline (DESIGN.md §13); a lane past it is
    /// canceled at the next round boundary with the flag below set.
    deadline: Option<Instant>,
    /// The deadline fired; copied into the lane's [`GenResult`].
    deadline_exceeded: bool,
}

impl Lane {
    fn committed(&self) -> usize {
        (self.history.len() - self.prompt.len()).min(self.params.max_new)
    }

    fn fire_on_commit(&mut self, snap: &Snapshot) {
        let n = snap.tokens.len().min(self.params.max_new);
        if n > self.reported {
            if let Some(cb) = &mut self.on_commit {
                cb(&snap.tokens[..n]);
            }
            self.reported = n;
        }
    }

    fn fire_round(
        &mut self,
        snap: &Snapshot,
        wall_ms: f64,
        pack: u64,
        occupancy: u64,
    ) {
        if let Some(sink) = &mut self.round_sink {
            let ev = self.cursor.advance(snap, wall_ms, pack, occupancy);
            sink.on_round(&ev);
        }
    }
}

/// Cross-sequence batched decoding (DESIGN.md §9.5): up to `batch_max`
/// sequences share one `*_batch` dispatch per round, each lane carrying
/// its own policy/method-knob/temperature/seed/`rounds_per_call` scalars
/// (mixed per-request configs batch together; only the method *family* —
/// the program identity — must match, see [`BatchRunner::can_admit`]).
///
/// The continuous-batching contract: sequences [`BatchRunner::admit`] and
/// leave only at round boundaries ([`BatchRunner::step`] returns the
/// finished lanes and frees their slots), exactly the vLLM-style
/// iteration-level scheduling the coordinator's replica loop drives.
/// [`SeqRunner`] semantics are preserved per lane: lanes prefill through
/// the same cache-aware path, per-slot commit callbacks fire after every
/// batched extract, and each lane packs by its own
/// [`effective_pack`] budget (TTFT guard and budget shrink included) via
/// the `*_batch_multi` per-lane `pack` vector.
pub struct BatchRunner<'a> {
    rt: &'a Runtime,
    sess: crate::runtime::BatchSession<'a>,
    /// The batched program every live lane shares (`None` while empty —
    /// the first admission of an empty batch picks the family).
    batch_exec: Option<&'static str>,
    /// The family's fused per-lane-budget variant, when the artifact set
    /// carries it and the family packs.
    batch_multi_exec: Option<&'static str>,
    lanes: Vec<Option<Lane>>,
}

impl<'a> BatchRunner<'a> {
    /// Start an empty batch over the artifact's `batch_max` lanes.
    /// Fails when the artifact set predates the `*_batch` programs
    /// (callers gate on [`Runtime::supports_batching`]).
    pub fn new(rt: &'a Runtime) -> Result<Self> {
        let sess = rt.batch_session()?;
        let n = sess.batch_max;
        Ok(BatchRunner {
            rt,
            sess,
            batch_exec: None,
            batch_multi_exec: None,
            lanes: (0..n).map(|_| None).collect(),
        })
    }

    /// Lane capacity (the layout's `batch_max` constant).
    pub fn batch_max(&self) -> usize {
        self.lanes.len()
    }

    /// Live (admitted, not yet retired) lane count — the occupancy each
    /// batched dispatch amortizes over.
    pub fn occupancy(&self) -> usize {
        self.lanes.iter().flatten().count()
    }

    /// No live lanes.
    pub fn is_empty(&self) -> bool {
        self.occupancy() == 0
    }

    fn free_slot(&self) -> Option<usize> {
        self.lanes.iter().position(|l| l.is_none())
    }

    /// At least one slot is free for admission.
    pub fn has_free_slot(&self) -> bool {
        self.free_slot().is_some()
    }

    /// Can a request of `method` join now? One dispatch runs one
    /// program, so every lane must share the method's *batched program*
    /// identity ([`SpecMethod::batch_exec_name`]); knobs, policies,
    /// temperatures and budgets are per-lane state and always mix. An
    /// empty batch admits any family.
    pub fn can_admit(&self, method: &SpecMethod) -> bool {
        self.has_free_slot()
            && match self.batch_exec {
                None => true,
                Some(exec) => exec == method.batch_exec_name(),
            }
    }

    /// The batched program the live lanes share (`None` while empty) —
    /// the admission "family" key the coordinator's planner matches
    /// queued requests against.
    pub fn family(&self) -> Option<&'static str> {
        self.batch_exec
    }

    /// Admit one request: prefill it solo (cache-aware, exactly the
    /// [`SeqRunner`] path) and splice the prefilled state into a free
    /// slot on device. Returns the slot index. The prefill + join
    /// dispatches are dedicated to this lane; everything after is
    /// shared and amortized.
    pub fn admit(
        &mut self,
        prompt: &[u32],
        params: &GenParams,
        cache: Option<SharedPrefixCache>,
    ) -> Result<usize> {
        if !self.can_admit(&params.method) {
            anyhow::bail!(
                "batch cannot admit method '{}' now",
                params.method.name()
            );
        }
        let slot = self.free_slot().expect("can_admit checked a free slot");
        let mut params = params.clone();
        clamp_rounds_per_call(self.rt, &mut params);
        let t0 = Instant::now();
        let (mut solo, prefill_cached_tokens) =
            prefill_session(self.rt, prompt, &params, &cache)?;
        let solo_calls = solo.device_calls;
        self.sess.join(&mut solo, slot)?;
        let prefill_seconds = t0.elapsed().as_secs_f64();
        self.batch_exec = Some(params.method.batch_exec_name());
        self.batch_multi_exec = params
            .method
            .batch_multi_exec_name()
            .filter(|name| self.rt.supports_round_packing(name));
        let source = params.method.draft_source();
        // generous hard cap: even tau=1 finishes within max_new rounds
        let round_cap = params.max_new * 2 + 8;
        let dedicated = solo_calls + 2; // prefill traffic + join splice
        self.lanes[slot] = Some(Lane {
            packs: params.rounds_per_call > 1
                && self.batch_multi_exec.is_some(),
            pack_cap: usize::MAX,
            source,
            prompt: prompt.to_vec(),
            history: prompt.to_vec(),
            spins: 0,
            round_cap,
            prefill_seconds,
            prefill_cached_tokens,
            cache,
            decode_seconds: 0.0,
            on_commit: None,
            reported: 0,
            round_sink: None,
            cursor: RoundCursor::default(),
            device_calls: dedicated,
            dispatch_share: dedicated as f64,
            cancel: false,
            deadline: None,
            deadline_exceeded: false,
            params,
        });
        Ok(slot)
    }

    /// Install `slot`'s absolute deadline (mirrors
    /// [`SeqRunner::set_deadline`]): a lane past it is retired at the
    /// next round boundary with its partial prefix and
    /// [`GenResult::deadline_exceeded`] set.
    pub fn set_deadline(&mut self, slot: usize, deadline: Option<Instant>) {
        if let Some(l) = self.lanes.get_mut(slot).and_then(|l| l.as_mut()) {
            l.deadline = deadline;
        }
    }

    /// Install `slot`'s round-commit callback (streaming deltas; same
    /// contract as [`SeqRunner::set_on_commit`]).
    pub fn set_on_commit(&mut self, slot: usize, cb: OnCommit) {
        if let Some(l) = self.lanes.get_mut(slot).and_then(|l| l.as_mut()) {
            l.on_commit = Some(cb);
        }
    }

    /// Install `slot`'s per-turn telemetry sink (same contract as
    /// [`SeqRunner::set_round_sink`]; events carry the batch occupancy
    /// of each dispatch).
    pub fn set_round_sink(&mut self, slot: usize, sink: Box<dyn RoundSink>) {
        if let Some(l) = self.lanes.get_mut(slot).and_then(|l| l.as_mut()) {
            l.round_sink = Some(sink);
        }
    }

    /// Cap `slot`'s pack externally (streaming slots cap at 1, exactly
    /// as [`SeqRunner::set_pack_cap`]).
    pub fn set_pack_cap(&mut self, slot: usize, cap: usize) {
        if let Some(l) = self.lanes.get_mut(slot).and_then(|l| l.as_mut()) {
            l.pack_cap = cap.max(1);
        }
    }

    /// The steady-state packing `slot` actually runs (the echoed
    /// `"rounds_per_call"`; mirrors
    /// [`SeqRunner::effective_rounds_per_call`]).
    pub fn effective_rounds_per_call(&self, slot: usize) -> usize {
        match self.lanes.get(slot).and_then(|l| l.as_ref()) {
            Some(l) if l.packs => {
                l.params.rounds_per_call.clamp(1, l.pack_cap)
            }
            _ => 1,
        }
    }

    /// `slot`'s prefill accounting: (wall seconds, cache-restored
    /// tokens). `None` for an empty slot. The serving layer logs this as
    /// the prefill span of the request's trace (DESIGN.md §12).
    pub fn prefill_stats(&self, slot: usize) -> Option<(f64, usize)> {
        self.lanes
            .get(slot)
            .and_then(|l| l.as_ref())
            .map(|l| (l.prefill_seconds, l.prefill_cached_tokens))
    }

    /// Tokens `slot` has committed so far (clamped to its `max_new`).
    pub fn committed(&self, slot: usize) -> usize {
        self.lanes
            .get(slot)
            .and_then(|l| l.as_ref())
            .map(|l| l.committed())
            .unwrap_or(0)
    }

    /// One batched device turn: a single `*_batch` (or `*_batch_multi`)
    /// dispatch stepping every live lane, then one `extract_batch`
    /// snapshot pull. Returns the finished lanes' `(slot, result)`
    /// pairs; their slots are free for re-admission on return — this is
    /// the round boundary where continuous batching joins and leaves.
    pub fn step(&mut self) -> Result<Vec<(usize, GenResult)>> {
        let occ = self.occupancy();
        if occ == 0 {
            return Ok(Vec::new());
        }
        let t = Instant::now();
        // deadline enforcement at the round boundary: a lane past its
        // deadline runs no further budget and retires after this turn
        for lane in self.lanes.iter_mut().flatten() {
            if let Some(dl) = lane.deadline {
                if t >= dl {
                    lane.cancel = true;
                    lane.deadline_exceeded = true;
                }
            }
        }
        let calls_before = self.sess.device_calls;
        let exec = self.batch_exec.expect("live lanes imply a family");
        let turn_packs: Vec<usize> = if exec == "verify_ext_batch" {
            // host-drafted lanes: fresh per-lane draft blocks each round
            let drafts: Vec<Vec<u32>> = self
                .lanes
                .iter_mut()
                .map(|l| match l {
                    Some(l) if !l.cancel => {
                        l.spins += 1;
                        l.source.next_drafts(&l.history).unwrap_or_default()
                    }
                    _ => Vec::new(),
                })
                .collect();
            self.sess.round_ext(&drafts)?;
            vec![1; self.lanes.len()]
        } else {
            let packs: Vec<usize> = self
                .lanes
                .iter_mut()
                .map(|l| match l {
                    Some(l) if !l.cancel => {
                        let pack = if l.packs {
                            effective_pack(
                                l.params.rounds_per_call,
                                l.pack_cap,
                                l.committed(),
                                l.params.max_new,
                            )
                        } else {
                            1
                        };
                        l.spins += pack;
                        pack
                    }
                    _ => 1,
                })
                .collect();
            match self.batch_multi_exec {
                Some(multi) if packs.iter().any(|&p| p > 1) => {
                    self.sess.round_packed(multi, &packs)?
                }
                _ => self.sess.round(exec)?,
            }
            packs
        };
        let snaps = self.sess.extract_all()?;
        let dt = t.elapsed().as_secs_f64();
        let turn_calls = self.sess.device_calls - calls_before;
        // the §9.5 amortization: this turn's dispatches served `occ`
        // lanes at once, so each lane's share is 1/occ of each
        let share = turn_calls as f64 / occ as f64;
        let mut done = Vec::new();
        for slot in 0..self.lanes.len() {
            let Some(lane) = self.lanes[slot].as_mut() else { continue };
            let snap = &snaps[slot];
            lane.decode_seconds += dt;
            lane.device_calls += turn_calls;
            lane.dispatch_share += share;
            lane.history = lane.prompt.clone();
            lane.history.extend(&snap.tokens);
            lane.fire_on_commit(snap);
            lane.fire_round(
                snap,
                dt * 1e3,
                turn_packs[slot] as u64,
                occ as u64,
            );
            if snap.finished || lane.cancel || lane.spins >= lane.round_cap
            {
                done.push(slot);
            }
        }
        let mut out = Vec::new();
        for slot in done {
            let result = self.retire(slot, snaps[slot].clone())?;
            out.push((slot, result));
        }
        Ok(out)
    }

    /// Finalize `slot` mid-flight with whatever has committed (the
    /// cancel path — mirrors [`SeqRunner::finish_early`]): one batched
    /// extract, no further rounds for this lane, slot freed on return.
    pub fn finish_early(&mut self, slot: usize) -> Result<GenResult> {
        if self.lanes.get(slot).and_then(|l| l.as_ref()).is_none() {
            anyhow::bail!("no live lane in slot {slot}");
        }
        let snaps = self.sess.extract_all()?;
        {
            let lane = self.lanes[slot].as_mut().expect("checked above");
            lane.device_calls += 1;
            lane.dispatch_share += 1.0; // dedicated extract
            lane.history = lane.prompt.clone();
            lane.history.extend(&snaps[slot].tokens);
            lane.fire_on_commit(&snaps[slot]);
        }
        self.retire(slot, snaps[slot].clone())
    }

    /// Retire one lane: export its cache snapshot, re-mask the slot if
    /// the device never set its `finished` flag, and build the result.
    fn retire(&mut self, slot: usize, snap: Snapshot) -> Result<GenResult> {
        let lane = self.lanes[slot].take().expect("live lane");
        // cache export under the same guards as the solo finalize: key
        // pinned to the device's own row count and the client-visible
        // (max_new-truncated) tokens
        if let Some(c) = &lane.cache {
            if !snap.tokens.is_empty()
                && snap.tokens.len() <= lane.params.max_new
                && snap.pos == lane.prompt.len() + snap.tokens.len()
            {
                let mut key = lane.prompt.clone();
                key.extend(&snap.tokens);
                if let Ok(state) = self.sess.export_slot(slot) {
                    c.borrow_mut().insert(&key, state);
                }
            }
        }
        // a lane retired before its device flag set (cancel / round-cap
        // overrun) would keep decoding in place; splice a zeroed
        // finished lane over it so the slot is truly masked again
        if !snap.finished {
            let lay = self.rt.layout();
            let mut dead = vec![0f32; lay.state_len];
            dead[lay.scalar("finished")] = 1.0;
            self.sess.join_host(&dead, slot)?;
        }
        if self.is_empty() {
            // empty batch: the next admission may bring any family
            self.batch_exec = None;
            self.batch_multi_exec = None;
        }
        let mut tokens = snap.tokens.clone();
        tokens.truncate(lane.params.max_new);
        let text = crate::tokenizer::decode(&tokens);
        Ok(GenResult {
            tokens,
            text,
            decode_seconds: lane.decode_seconds,
            prefill_seconds: lane.prefill_seconds,
            prefill_cached_tokens: lane.prefill_cached_tokens,
            snapshot: snap,
            // the probe ring is pulled by a solo-state program; batched
            // lanes don't dump probes (GenParams::probe is a bench knob)
            probe: None,
            device_calls: lane.device_calls,
            dispatch_share: lane.dispatch_share,
            deadline_exceeded: lane.deadline_exceeded,
        })
    }
}

/// The decode engine: a thin, single-threaded driver over a [`Runtime`].
pub struct DecodeEngine {
    /// The runtime this engine drives (owned; one engine per device).
    pub rt: Runtime,
    /// force the naive host-roundtrip runtime (§Perf baseline)
    pub hostloop: bool,
}

impl DecodeEngine {
    /// Wrap a runtime in the run-to-completion driver.
    pub fn new(rt: Runtime) -> Self {
        DecodeEngine { rt, hostloop: false }
    }

    /// Generate a completion for a prompt string.
    pub fn generate(&self, prompt: &str, params: &GenParams) -> Result<GenResult> {
        let toks = crate::tokenizer::encode(prompt);
        self.generate_tokens(&toks, params)
    }

    /// Generate a completion for pre-tokenized input.
    pub fn generate_tokens(
        &self,
        prompt: &[u32],
        params: &GenParams,
    ) -> Result<GenResult> {
        let mut runner =
            SeqRunner::new(&self.rt, prompt, params, self.hostloop)?;
        loop {
            if let Some(result) = runner.step()? {
                return Ok(result);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_pack_guards_ttft() {
        // the first call after prefill is always a single round
        assert_eq!(effective_pack(8, usize::MAX, 0, 64), 1);
        assert_eq!(effective_pack(1, usize::MAX, 0, 64), 1);
        // once committed, the configured pack applies
        assert_eq!(effective_pack(8, usize::MAX, 1, 64), 8);
    }

    #[test]
    fn effective_pack_shrinks_at_the_budget_boundary() {
        // remaining budget bounds the pack: every round commits >= 1
        // token, so packs past the remainder are guaranteed overrun
        assert_eq!(effective_pack(8, usize::MAX, 60, 64), 4);
        assert_eq!(effective_pack(8, usize::MAX, 63, 64), 1);
        // at/past the budget the caller finalizes; never return 0
        assert_eq!(effective_pack(8, usize::MAX, 64, 64), 1);
        assert_eq!(effective_pack(8, usize::MAX, 80, 64), 1);
    }

    #[test]
    fn round_cursor_emits_snapshot_deltas() {
        let mut c = RoundCursor::default();
        let mut snap = Snapshot {
            pos: 10,
            out_len: 3,
            finished: false,
            rounds: 2.0,
            committed: 3.0,
            target_calls: 2.0,
            draft_steps: 8.0,
            exact_accepts: 2.0,
            relaxed_accepts: 1.0,
            rejects: 1.0,
            bonus: 1.0,
            last_accept: 2.0,
            tokens: vec![1, 2, 3],
        };
        let ev = c.advance(&snap, 1.5, 2, 1);
        assert_eq!(ev.turn, 0);
        assert_eq!(ev.rounds, 2);
        assert_eq!(ev.drafted, 8);
        assert_eq!((ev.exact, ev.relaxed, ev.accepted), (2, 1, 3));
        assert_eq!(ev.committed, 3);
        assert_eq!(ev.pack, 2);
        // second turn reports deltas, not running totals
        snap.rounds = 3.0;
        snap.draft_steps = 12.0;
        snap.exact_accepts = 5.0;
        snap.committed = 7.0;
        snap.finished = true;
        let ev = c.advance(&snap, 0.5, 1, 4);
        assert_eq!(ev.turn, 1);
        assert_eq!(ev.rounds, 1);
        assert_eq!(ev.drafted, 4);
        assert_eq!((ev.exact, ev.relaxed, ev.accepted), (3, 0, 3));
        assert_eq!(ev.committed, 4);
        assert_eq!(ev.occupancy, 4);
        assert!(ev.finished);
    }

    #[test]
    fn effective_pack_respects_external_cap() {
        // the replica's streaming cap wins over the configured pack
        assert_eq!(effective_pack(8, 1, 10, 64), 1);
        assert_eq!(effective_pack(8, 4, 10, 64), 4);
        // degenerate inputs clamp instead of panicking
        assert_eq!(effective_pack(0, 0, 10, 64), 1);
    }
}
