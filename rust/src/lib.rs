//! MARS — Margin-Aware Speculative Verification: a rust/JAX/Pallas serving
//! stack reproducing Song et al., ACL 2026.
//!
//! Layer map (see DESIGN.md):
//! * [`runtime`] — PJRT bridge: loads `artifacts/*.hlo.txt`, uploads model
//!   weights once, threads the flat f32 decode state buffer-to-buffer.
//! * [`engine`] — per-sequence decode sessions: prefill → rounds → extract,
//!   with every decode method of the paper's evaluation (AR, SpS, EAGLE
//!   chain/tree, Medusa, PLD, Lookahead) and the MARS verification rule as
//!   a runtime flag.
//! * [`coordinator`] — the serving layer: scheduler, engine workers,
//!   line-JSON TCP server, router, metrics.
//! * [`datasets`] / [`eval`] / [`bench`] — the paper's benchmark suite:
//!   synthetic task analogs, quality metrics, and one harness per table
//!   and figure of the evaluation section.

pub mod bench;
pub mod coordinator;
pub mod datasets;
pub mod engine;
pub mod eval;
pub mod runtime;
pub mod spec;
pub mod tokenizer;
pub mod util;

pub use engine::{DecodeEngine, GenParams, GenResult, Method};
pub use runtime::{Artifacts, Runtime};
