//! MARS — Margin-Aware Speculative Verification: a rust/JAX/Pallas serving
//! stack reproducing Song et al., ACL 2026.
//!
//! Layer map (see DESIGN.md):
//! * [`verify`] — the verification-policy subsystem: every accept rule
//!   ([`verify::VerifyPolicy`]: `Strict`, `Mars`, `TopK`, `Entropy`) with
//!   one canonical representation across CLI strings, request JSON, the
//!   device `(policy_id, p0, p1)` config-slot triple, and a host-side
//!   reference verifier used by the property tests.
//! * [`spec`] — the drafting subsystem, mirror image of [`verify`]: every
//!   decode method of the paper's evaluation (AR, SpS, EAGLE chain/tree,
//!   Medusa, PLD, Lookahead) is a [`spec::SpecMethod`] descriptor carrying
//!   its drafting knobs, registered once in [`spec::METHODS`], with one
//!   codec per surface (CLI string, request JSON, device config slots)
//!   and a [`spec::DraftSource`] unifying device-coupled and host
//!   drafters.
//! * [`cache`] — the prefix-reuse subsystem: per-replica
//!   [`cache::PrefixCache`] of flat-state snapshots keyed by a token
//!   chain hash with token-equality confirmation, LRU-evicted under a
//!   byte budget, so multi-turn chat over a shared prefix prefills only
//!   the suffix (restored full-prompt hits skip prefill entirely).
//! * [`runtime`] — PJRT bridge: loads `artifacts/*.hlo.txt`, uploads model
//!   weights once, threads the flat f32 decode state buffer-to-buffer;
//!   `session_from_state` resumes a cached snapshot and `prefill_ext`
//!   extends it with the uncached token suffix.
//! * [`engine`] — per-sequence decode sessions: prefill → rounds →
//!   extract, driving whatever [`spec::DraftSource`] the request's
//!   descriptor builds; the verification policy is a [`GenParams`] field,
//!   orthogonal to the method.
//! * [`coordinator`] — the serving layer: scheduler, engine workers,
//!   router, per-policy metrics (TTFT/TPOT percentiles), and a
//!   streaming, pipelined line-JSON TCP server (client ids, per-round
//!   deltas, cancel, graceful drain — see `coordinator::server`).
//! * [`datasets`] / [`eval`] / [`bench`] — the paper's benchmark suite:
//!   synthetic task analogs, quality metrics, one harness per table and
//!   figure of the evaluation section, a policy-sweep axis, and the
//!   `bench serve` open-loop serving-latency harness (BENCHMARKS.md).
//! * [`obs`] — the observability subsystem (DESIGN.md §12): per-round
//!   [`obs::RoundEvent`]s from the engine's commit paths, mergeable
//!   fixed-bucket [`obs::StreamHistogram`]s backing the sharded metrics
//!   registry, the `--trace` JSONL span log, and the Prometheus
//!   text-exposition surface (`{"cmd":"prom"}` / `--prom-addr`).
//! * [`check`] — the cross-layer contract checker (`mars check
//!   contracts`, DESIGN.md §11): diffs the python-exported contract
//!   manifest (`contracts.json`) against the rust mirrors — state
//!   scalars, cfg slots, policy ids, layout consts, exec names, wire
//!   fields, bench thresholds — and names every drift.
//! * [`fault`] — deterministic fault injection (DESIGN.md §13): a
//!   seed-driven [`fault::FaultPlan`] installed on the runtime injects
//!   dispatch errors, hung-dispatch latency, and session-rebuild
//!   failures, driving the replica supervisor, router failover,
//!   per-request deadlines, and overload shedding under test.

#![forbid(unsafe_code)]

pub mod bench;
pub mod cache;
pub mod check;
pub mod coordinator;
pub mod datasets;
pub mod engine;
pub mod fault;
pub mod eval;
pub mod obs;
pub mod runtime;
pub mod spec;
pub mod tokenizer;
pub mod util;
pub mod verify;

pub use cache::{CacheConfig, PrefixCache};
pub use engine::{DecodeEngine, GenParams, GenResult};
pub use runtime::{Artifacts, Runtime};
pub use spec::{DraftSource, SpecMethod, METHODS};
pub use verify::{AcceptFlag, VerifyPolicy};
