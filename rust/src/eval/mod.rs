//! Quality metrics for the paper's evaluation tables.
//!
//! * [`rouge`]  — ROUGE-L F1 (Table 3, CNN/DM analog)
//! * [`bleu`]   — corpus BLEU-4 with brevity penalty (Table 4/5, WMT analog)
//! * [`chrf`]   — chrF(β=2) character n-gram F-score (Table 4)
//! * [`accuracy`] — exact-match / avg@k task accuracy (Tables 1/2/5/6)
//! * [`judge`]  — heuristic MT-Bench judge (Table 7; GPT-5 is substituted
//!   by keyword coverage + fluency heuristics, DESIGN.md §9.3)

pub mod accuracy;
pub mod bleu;
pub mod chrf;
pub mod judge;
pub mod rouge;

pub use accuracy::{task_accuracy, task_correct};
pub use bleu::corpus_bleu;
pub use chrf::chrf;
pub use judge::judge_score;
pub use rouge::rouge_l;
