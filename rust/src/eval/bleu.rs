//! Corpus BLEU-4 (Papineni et al., 2002): modified n-gram precision with
//! brevity penalty, +1 smoothing on higher orders (standard sacrebleu-like
//! "exp" smoothing simplification for short corpora).

use std::collections::HashMap;

fn ngram_counts<'a>(toks: &'a [&'a str], n: usize) -> HashMap<&'a [&'a str], usize> {
    let mut m: HashMap<&[&str], usize> = HashMap::new();
    if toks.len() >= n {
        for i in 0..=toks.len() - n {
            *m.entry(&toks[i..i + n]).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus BLEU over (candidate, reference) pairs, scaled to [0, 100].
pub fn corpus_bleu(pairs: &[(String, String)]) -> f64 {
    let max_n = 4;
    let mut match_n = [0usize; 4];
    let mut total_n = [0usize; 4];
    let mut cand_len = 0usize;
    let mut ref_len = 0usize;
    for (c, r) in pairs {
        let ct: Vec<&str> = c.split_whitespace().collect();
        let rt: Vec<&str> = r.split_whitespace().collect();
        cand_len += ct.len();
        ref_len += rt.len();
        for n in 1..=max_n {
            let cc = ngram_counts(&ct, n);
            let rc = ngram_counts(&rt, n);
            for (g, &cnt) in &cc {
                let m = rc.get(g).copied().unwrap_or(0);
                match_n[n - 1] += cnt.min(m);
            }
            total_n[n - 1] += ct.len().saturating_sub(n - 1);
        }
    }
    if cand_len == 0 {
        return 0.0;
    }
    let mut log_sum = 0.0;
    for n in 0..max_n {
        // +1 smoothing beyond unigrams to keep short corpora finite
        let (m, t) = if n == 0 {
            (match_n[0] as f64, total_n[0] as f64)
        } else {
            (match_n[n] as f64 + 1.0, total_n[n] as f64 + 1.0)
        };
        if m == 0.0 || t == 0.0 {
            return 0.0;
        }
        log_sum += (m / t).ln();
    }
    let precision = (log_sum / max_n as f64).exp();
    let bp = if cand_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / cand_len as f64).exp()
    };
    100.0 * precision * bp
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(c: &str, r: &str) -> Vec<(String, String)> {
        vec![(c.to_string(), r.to_string())]
    }

    #[test]
    fn perfect_match_near_100() {
        let b = corpus_bleu(&p(
            "the river runs past the mill tonight",
            "the river runs past the mill tonight",
        ));
        assert!(b > 90.0, "{b}");
    }

    #[test]
    fn disjoint_is_zero() {
        assert_eq!(corpus_bleu(&p("aa bb cc dd", "xx yy zz ww")), 0.0);
    }

    #[test]
    fn partial_overlap_in_between() {
        let b = corpus_bleu(&p(
            "the cat sat on the mat today ok",
            "the cat sat on a mat today ok",
        ));
        assert!(b > 20.0 && b < 95.0, "{b}");
    }

    #[test]
    fn brevity_penalty_applies() {
        let long_ref = "a b c d e f g h i j";
        let short = corpus_bleu(&p("a b c", long_ref));
        let full = corpus_bleu(&p(long_ref, long_ref));
        assert!(short < full);
    }

    #[test]
    fn empty_candidate_zero() {
        assert_eq!(corpus_bleu(&p("", "a b")), 0.0);
        assert_eq!(corpus_bleu(&[]), 0.0);
    }
}
