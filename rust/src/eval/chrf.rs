//! chrF (Popović, 2015): character n-gram F-β score, β = 2 as in the
//! paper's Table 4, n-gram orders 1..6, uniform averaging.

use std::collections::HashMap;

fn char_ngrams(s: &str, n: usize) -> HashMap<String, usize> {
    let chars: Vec<char> = s.chars().filter(|c| !c.is_whitespace()).collect();
    let mut m = HashMap::new();
    if chars.len() >= n {
        for i in 0..=chars.len() - n {
            let g: String = chars[i..i + n].iter().collect();
            *m.entry(g).or_insert(0) += 1;
        }
    }
    m
}

/// chrF(β) between candidate and reference, scaled to [0, 100].
pub fn chrf_beta(candidate: &str, reference: &str, beta: f64) -> f64 {
    let max_n = 6;
    let mut f_sum = 0.0;
    let mut orders = 0usize;
    for n in 1..=max_n {
        let cc = char_ngrams(candidate, n);
        let rc = char_ngrams(reference, n);
        let c_total: usize = cc.values().sum();
        let r_total: usize = rc.values().sum();
        if c_total == 0 && r_total == 0 {
            continue;
        }
        orders += 1;
        if c_total == 0 || r_total == 0 {
            continue; // F = 0 for this order
        }
        let mut overlap = 0usize;
        for (g, &cnt) in &cc {
            overlap += cnt.min(rc.get(g).copied().unwrap_or(0));
        }
        if overlap == 0 {
            continue;
        }
        let p = overlap as f64 / c_total as f64;
        let r = overlap as f64 / r_total as f64;
        let b2 = beta * beta;
        f_sum += (1.0 + b2) * p * r / (b2 * p + r);
    }
    if orders == 0 {
        return 0.0;
    }
    100.0 * f_sum / orders as f64
}

/// chrF with the paper's β = 2.
pub fn chrf(candidate: &str, reference: &str) -> f64 {
    chrf_beta(candidate, reference, 2.0)
}

/// Corpus chrF: average of segment scores (macro-average).
pub fn corpus_chrf(pairs: &[(String, String)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    pairs.iter().map(|(c, r)| chrf(c, r)).sum::<f64>() / pairs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_100() {
        assert!((chrf("abcdef", "abcdef") - 100.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_is_zero() {
        assert_eq!(chrf("aaaaaa", "zzzzzz"), 0.0);
    }

    #[test]
    fn recall_weighted() {
        // beta=2 weights recall: missing content hurts more than extra
        let missing = chrf("the cat", "the cat sat on the mat");
        let extra = chrf("the cat sat on the mat", "the cat");
        assert!(extra > missing);
    }

    #[test]
    fn whitespace_ignored() {
        assert!((chrf("ab cd", "abcd") - 100.0).abs() < 1e-9);
    }

    #[test]
    fn corpus_averages() {
        let pairs = vec![
            ("abc".to_string(), "abc".to_string()),
            ("zzz".to_string(), "abc".to_string()),
        ];
        let c = corpus_chrf(&pairs);
        assert!(c > 0.0 && c < 100.0);
    }
}
