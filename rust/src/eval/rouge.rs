//! ROUGE-L: longest-common-subsequence F1 over whitespace tokens
//! (Lin, 2004 — the variant reported for CNN/DailyMail in the paper's
//! Table 3).

fn lcs_len(a: &[&str], b: &[&str]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for x in a {
        for (j, y) in b.iter().enumerate() {
            cur[j + 1] = if x == y {
                prev[j] + 1
            } else {
                cur[j].max(prev[j + 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// ROUGE-L F1 between a candidate and a reference.
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let c: Vec<&str> = candidate.split_whitespace().collect();
    let r: Vec<&str> = reference.split_whitespace().collect();
    if c.is_empty() || r.is_empty() {
        return 0.0;
    }
    let l = lcs_len(&c, &r) as f64;
    if l == 0.0 {
        return 0.0;
    }
    let p = l / c.len() as f64;
    let rec = l / r.len() as f64;
    2.0 * p * rec / (p + rec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_one() {
        assert!((rouge_l("the cat sat", "the cat sat") - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_is_zero() {
        assert_eq!(rouge_l("aa bb", "cc dd"), 0.0);
    }

    #[test]
    fn subsequence_scores() {
        // lcs("the cat sat on mat", "the dog sat on a mat") = 4 words
        let f = rouge_l("the cat sat on mat", "the dog sat on a mat");
        let p = 4.0 / 5.0;
        let r = 4.0 / 6.0;
        let expect = 2.0 * p * r / (p + r);
        assert!((f - expect).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(rouge_l("", "x"), 0.0);
        assert_eq!(rouge_l("x", ""), 0.0);
    }

    #[test]
    fn order_matters() {
        let a = rouge_l("a b c d", "a b c d");
        let b = rouge_l("d c b a", "a b c d");
        assert!(a > b);
    }
}
