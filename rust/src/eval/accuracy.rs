//! Task accuracy: exact-match for arith (GSM8K convention — final answer),
//! exact output match for code (avg@k), keyword containment for chat.

use crate::datasets::{arith_answer, Example, Task};

/// Is a single generation correct for its task?
pub fn task_correct(ex: &Example, generated: &str) -> bool {
    match ex.task {
        Task::Arith => {
            let gold = ex.answer.as_deref().unwrap_or("");
            !gold.is_empty() && arith_answer(generated) == gold
        }
        Task::Code => {
            let gold = ex.answer.as_deref().unwrap_or("");
            generated.lines().next().map(str::trim).unwrap_or("") == gold
        }
        Task::Chat => {
            // all gold keywords present
            !ex.keywords.is_empty()
                && ex.keywords.iter().all(|k| generated.contains(k.as_str()))
        }
        // sum / mt report continuous quality metrics, not accuracy; a
        // "correct" notion is still useful for sanity checks:
        Task::Sum | Task::Mt => {
            generated.trim().starts_with(ex.reference.trim())
        }
    }
}

/// Mean accuracy over (example, generations) pairs. Multiple generations
/// per example are averaged (HumanEval's avg@k).
pub fn task_accuracy(results: &[(&Example, Vec<String>)]) -> f64 {
    let mut total = 0.0;
    let mut n = 0usize;
    for (ex, gens) in results {
        if gens.is_empty() {
            continue;
        }
        let ok = gens.iter().filter(|g| task_correct(ex, g)).count() as f64;
        total += ok / gens.len() as f64;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        total / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::dataset;

    #[test]
    fn arith_correct_on_reference() {
        for ex in dataset(Task::Arith, 20, 5) {
            assert!(task_correct(&ex, &ex.reference), "{}", ex.reference);
            assert!(!task_correct(&ex, "A: 99999\n"));
        }
    }

    #[test]
    fn code_requires_exact_line() {
        for ex in dataset(Task::Code, 20, 6) {
            assert!(task_correct(&ex, &ex.reference));
            assert!(!task_correct(&ex, "'wrong'\n"));
        }
    }

    #[test]
    fn chat_checks_keywords() {
        for ex in dataset(Task::Chat, 20, 7) {
            assert!(task_correct(&ex, &ex.reference));
        }
    }

    #[test]
    fn avg_at_k_averages() {
        let exs = dataset(Task::Arith, 1, 8);
        let gold = exs[0].reference.clone();
        let results = vec![(
            &exs[0],
            vec![gold.clone(), "nope".to_string(), gold.clone(), "x".into()],
        )];
        assert!((task_accuracy(&results) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_results_zero() {
        assert_eq!(task_accuracy(&[]), 0.0);
    }
}
