//! Heuristic MT-Bench judge — the stand-in for the paper's GPT-5 judge
//! (DESIGN.md §9.3). Scores a response 0..10 from task-ground-truth
//! keyword coverage plus simple fluency heuristics. The judge's role in
//! Table 7 is to be a *stable scalar quality probe* across decoding
//! variants, which these deterministic heuristics provide.

use crate::datasets::Example;

/// Score one chat response on the 0..10 MT-Bench scale.
pub fn judge_score(ex: &Example, generated: &str) -> f64 {
    let text = generated.trim();
    if text.is_empty() {
        return 0.0;
    }
    // --- content: keyword coverage (0..6) ---
    let content = if ex.keywords.is_empty() {
        3.0
    } else {
        let hits = ex
            .keywords
            .iter()
            .filter(|k| text.contains(k.as_str()))
            .count() as f64;
        6.0 * hits / ex.keywords.len() as f64
    };
    // --- fluency heuristics (0..4) ---
    let mut fluency: f64 = 0.0;
    // terminates with sentence punctuation
    if text.ends_with('.') || text.ends_with('!') || text.ends_with('?') {
        fluency += 1.0;
    }
    // reasonable length (not truncated, not rambling)
    let words = text.split_whitespace().count();
    if (3..=40).contains(&words) {
        fluency += 1.0;
    }
    // no immediate word repetition (degenerate sampling artifact)
    let toks: Vec<&str> = text.split_whitespace().collect();
    let repeats = toks.windows(2).filter(|w| w[0] == w[1]).count();
    if repeats == 0 {
        fluency += 1.0;
    }
    // character diversity (collapse detection)
    let uniq = {
        let mut cs: Vec<char> = text.chars().collect();
        cs.sort_unstable();
        cs.dedup();
        cs.len()
    };
    if uniq >= 8 {
        fluency += 1.0;
    }
    (content + fluency).min(10.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{dataset, Task};

    #[test]
    fn reference_scores_high() {
        for ex in dataset(Task::Chat, 20, 11) {
            let s = judge_score(&ex, &ex.reference);
            assert!(s >= 8.0, "ref scored {s}: {}", ex.reference);
        }
    }

    #[test]
    fn empty_scores_zero() {
        let ex = &dataset(Task::Chat, 1, 12)[0];
        assert_eq!(judge_score(ex, ""), 0.0);
    }

    #[test]
    fn degenerate_text_scores_low() {
        let ex = &dataset(Task::Chat, 1, 13)[0];
        let bad = "aaa aaa aaa aaa aaa aaa aaa aaa aaa aaa aaa aaa";
        assert!(judge_score(ex, bad) < 4.0);
    }

    #[test]
    fn wrong_but_fluent_scores_mid() {
        let ex = &dataset(Task::Chat, 1, 14)[0];
        let s = judge_score(ex, "The weather is quite pleasant today.");
        assert!(s > 2.0 && s < 8.0, "{s}");
    }
}
