//! Byte-level tokenizer — the rust mirror of `python/compile/tokenizer.py`.
//!
//! The vocab layout is fixed by specification (and double-checked against
//! `artifacts/vocab.json` at engine start):
//!
//! ```text
//! 0 PAD   1 BOS   2 EOS   3 SEP
//! 4..98   printable ASCII 0x20..0x7E (id = byte - 0x20 + 4)
//! 99      '\n'
//! 100..127 unused padding up to VOCAB = 128
//! ```

use crate::util::json::Value;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;
pub const NL_ID: u32 = 99;
pub const VOCAB: usize = 128;

const ASCII_LO: u32 = 0x20;
const ASCII_HI: u32 = 0x7E;
const OFFSET: u32 = 4;

/// Encode text to token ids; unknown characters map to space.
pub fn encode(text: &str) -> Vec<u32> {
    text.chars()
        .map(|c| {
            let b = c as u32;
            if c == '\n' {
                NL_ID
            } else if (ASCII_LO..=ASCII_HI).contains(&b) {
                b - ASCII_LO + OFFSET
            } else {
                OFFSET // space fallback
            }
        })
        .collect()
}

/// Decode ids to text; special/padding ids are dropped.
pub fn decode(ids: &[u32]) -> String {
    let mut out = String::with_capacity(ids.len());
    for &t in ids {
        if t == NL_ID {
            out.push('\n');
        } else if (OFFSET..OFFSET + (ASCII_HI - ASCII_LO + 1)).contains(&t) {
            out.push(char::from_u32(t - OFFSET + ASCII_LO).unwrap());
        }
    }
    out
}

/// Validate this implementation against the vocab.json emitted by aot.py.
pub fn check_vocab_spec(spec: &Value) -> Result<(), String> {
    let want = [
        ("vocab_size", VOCAB as i64),
        ("pad", PAD as i64),
        ("bos", BOS as i64),
        ("eos", EOS as i64),
        ("nl", NL_ID as i64),
        ("ascii_lo", ASCII_LO as i64),
        ("ascii_hi", ASCII_HI as i64),
        ("ascii_offset", OFFSET as i64),
    ];
    for (k, v) in want {
        let got = spec
            .get(k)
            .and_then(|x| x.as_i64())
            .ok_or_else(|| format!("vocab.json missing {k}"))?;
        if got != v {
            return Err(format!("vocab.json {k}: artifact {got} != rust {v}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "Q: 12+34=?\nA: 46\n";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn newline_id() {
        assert_eq!(encode("\n"), vec![NL_ID]);
    }

    #[test]
    fn unknown_maps_to_space() {
        assert_eq!(decode(&encode("héllo")), "h llo");
    }

    #[test]
    fn specials_dropped_on_decode() {
        // id 40 = byte 0x20 + (40 - 4) = 'D'
        assert_eq!(decode(&[BOS, 40, EOS, PAD]), "D");
    }

    #[test]
    fn ids_in_vocab() {
        for id in encode("The quick ~ brown fox! 0123") {
            assert!((id as usize) < VOCAB);
        }
    }

    #[test]
    fn matches_python_examples() {
        // spot values pinned against the python implementation
        assert_eq!(encode(" ")[0], 4);
        assert_eq!(encode("~")[0], 0x7E - 0x20 + 4);
        assert_eq!(encode("Q")[0], ('Q' as u32) - 0x20 + 4);
    }
}
