//! Verification policies — the one layer MARS actually changes.
//!
//! The paper's framing is that speculative decoding frameworks differ in
//! *drafting* while the accept/reject rule is a small, swappable policy.
//! This module makes that literal: every accept rule the stack supports is
//! a [`VerifyPolicy`] variant with one canonical representation across
//!
//! * the CLI (`--policy mars:0.9`, see [`VerifyPolicy::parse`]),
//! * the line-JSON protocol (`"policy": {"mars": {"theta": 0.9}}` plus the
//!   legacy flat `"mars"/"theta"` keys, see [`VerifyPolicy::from_request`]),
//! * the device config-slot triple `(policy_id, p0, p1)` consumed by the
//!   lowered round programs (see [`VerifyPolicy::encode_slots`] and
//!   `python/compile/state_spec.py`), and
//! * a host-side reference verifier ([`VerifyPolicy::accept`],
//!   [`VerifyPolicy::scan`]) that mirrors the Pallas kernel and anchors the
//!   property tests.
//!
//! Policy semantics (relaxation always targets the target's top-2 token;
//! an exact match with the target's own pick `t*` is always accepted):
//!
//! | id | variant              | relaxed accept of `draft == top2` when |
//! |----|----------------------|-----------------------------------------|
//! | 0  | `Strict`             | never (bit-identical to pre-policy `mars=false`) |
//! | 1  | `Mars { theta }`     | `z1>0 && z2>0 && z2/z1 > theta`          |
//! | 2  | `TopK { k, eps }`    | draft in target top-k and `zk>0 && zk/z1 > 1-eps` (device clamps k to 2 — the round programs materialize top-2 only) |
//! | 3  | `Entropy { h_max }`  | `z1 - z2 < h_max` — the top-2 entropy `H(σ(z1-z2))` is strictly decreasing in the logit gap, so an entropy floor is a gap ceiling in nats |
//!
//! `TopK { 2, eps }` is definitionally `Mars { 1 - eps }`; the property
//! suite pins that equivalence.

#![warn(missing_docs)]

use crate::util::json::Value;

// Device-slot policy ids (mirrored by `python/compile/state_spec.py`).

/// Device-slot id of [`VerifyPolicy::Strict`].
pub const POLICY_ID_STRICT: f32 = 0.0;
/// Device-slot id of [`VerifyPolicy::Mars`].
pub const POLICY_ID_MARS: f32 = 1.0;
/// Device-slot id of [`VerifyPolicy::TopK`].
pub const POLICY_ID_TOPK: f32 = 2.0;
/// Device-slot id of [`VerifyPolicy::Entropy`].
pub const POLICY_ID_ENTROPY: f32 = 3.0;

/// A pluggable speculative-verification accept rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum VerifyPolicy {
    /// Exact verification only — the lossless baseline rule.
    Strict,
    /// Margin-aware relaxation (the paper): accept the target's top-2
    /// token when the top-2/top-1 logit ratio exceeds `theta` on the
    /// positive domain.
    Mars { theta: f32 },
    /// Top-k relaxation: accept any of the target's top-k tokens whose
    /// logit is within a relative `eps` of top-1 (positive domain).
    TopK { k: usize, eps: f32 },
    /// Entropy relaxation: accept the target's top-2 token while the
    /// top-2 logit gap (nats) stays under `h_max`.
    Entropy { h_max: f32 },
}

impl Default for VerifyPolicy {
    /// The paper's headline setting.
    fn default() -> Self {
        VerifyPolicy::Mars { theta: 0.9 }
    }
}

/// Outcome of verifying one drafted token (the accept-flag taxonomy that
/// flows through probe rings, snapshots and metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum AcceptFlag {
    /// Draft token rejected; the chain scan stops here.
    Reject = 0,
    /// Draft token matched the target's own pick exactly.
    Exact = 1,
    /// Accepted by the policy's relaxation, not by exact match.
    Relaxed = 2,
}

impl AcceptFlag {
    /// Decode the device-side f32 flag (0/1/2; anything else rejects).
    pub fn from_f32(x: f32) -> AcceptFlag {
        match x as u8 {
            1 => AcceptFlag::Exact,
            2 => AcceptFlag::Relaxed,
            _ => AcceptFlag::Reject,
        }
    }

    /// Was the token accepted (exactly or via relaxation)?
    pub fn accepted(&self) -> bool {
        !matches!(self, AcceptFlag::Reject)
    }
}

impl VerifyPolicy {
    /// Parse the CLI string form: `strict`, `mars[:theta]`, `topk[:k[:eps]]`,
    /// `entropy[:h_max]`.
    pub fn parse(s: &str) -> Option<VerifyPolicy> {
        let s = s.trim().to_ascii_lowercase();
        let mut parts = s.split(':');
        let head = parts.next()?;
        let p0 = parts.next();
        let p1 = parts.next();
        if parts.next().is_some() {
            return None;
        }
        let f = |x: Option<&str>, d: f32| -> Option<f32> {
            match x {
                None => Some(d),
                Some(t) => t.parse::<f32>().ok().filter(|v| v.is_finite()),
            }
        };
        Some(match head {
            "strict" | "exact" | "off" => {
                if p0.is_some() {
                    return None;
                }
                VerifyPolicy::Strict
            }
            "mars" | "margin" => {
                if p1.is_some() {
                    return None;
                }
                VerifyPolicy::Mars { theta: f(p0, 0.9)? }
            }
            "topk" | "top-k" => {
                let k = match p0 {
                    None => 2,
                    Some(t) => t.parse::<usize>().ok().filter(|&k| k >= 1)?,
                };
                VerifyPolicy::TopK { k, eps: f(p1, 0.1)? }
            }
            "entropy" | "ent" => {
                if p1.is_some() {
                    return None;
                }
                VerifyPolicy::Entropy { h_max: f(p0, 1.5)? }
            }
            _ => return None,
        })
    }

    /// Parse a comma-separated sweep list, e.g.
    /// `strict,mars:0.9,topk:2,entropy:1.5`.
    pub fn parse_list(s: &str) -> Option<Vec<VerifyPolicy>> {
        s.split(',')
            .filter(|p| !p.trim().is_empty())
            .map(VerifyPolicy::parse)
            .collect::<Option<Vec<_>>>()
            .filter(|v| !v.is_empty())
    }

    /// Family name (metrics label; stable across parameter values).
    pub fn name(&self) -> &'static str {
        match self {
            VerifyPolicy::Strict => "strict",
            VerifyPolicy::Mars { .. } => "mars",
            VerifyPolicy::TopK { .. } => "topk",
            VerifyPolicy::Entropy { .. } => "entropy",
        }
    }

    /// Full CLI label; `parse(label())` round-trips the policy.
    pub fn label(&self) -> String {
        match self {
            VerifyPolicy::Strict => "strict".to_string(),
            VerifyPolicy::Mars { theta } => format!("mars:{theta}"),
            VerifyPolicy::TopK { k, eps } => format!("topk:{k}:{eps}"),
            VerifyPolicy::Entropy { h_max } => format!("entropy:{h_max}"),
        }
    }

    /// Does this policy ever accept beyond exact matches?
    pub fn is_relaxed(&self) -> bool {
        !matches!(self, VerifyPolicy::Strict)
    }

    /// Normalize to what the device pipeline can actually execute: the
    /// round programs materialize top-2 only, so `TopK { k > 2 }` clamps
    /// to `k = 2`. Applied at the request/CLI boundary so the label a
    /// response echoes (and metrics attribute) is the policy that ran;
    /// the full top-k rule remains available host-side via
    /// [`VerifyPolicy::accept`].
    pub fn normalize_for_device(&self) -> VerifyPolicy {
        match *self {
            VerifyPolicy::TopK { k, eps } if k > 2 => {
                VerifyPolicy::TopK { k: 2, eps }
            }
            p => p,
        }
    }

    // ----------------------------------------------------- JSON codec ----

    /// Wire form: `"strict"` | `{"mars": {"theta": θ}}` |
    /// `{"topk": {"k": k, "eps": ε}}` | `{"entropy": {"h_max": h}}`.
    pub fn to_json(&self) -> Value {
        match self {
            VerifyPolicy::Strict => Value::Str("strict".into()),
            VerifyPolicy::Mars { theta } => {
                let mut inner = Value::obj();
                inner.set("theta", Value::Num(*theta as f64));
                let mut o = Value::obj();
                o.set("mars", inner);
                o
            }
            VerifyPolicy::TopK { k, eps } => {
                let mut inner = Value::obj();
                inner.set("k", Value::Num(*k as f64));
                inner.set("eps", Value::Num(*eps as f64));
                let mut o = Value::obj();
                o.set("topk", inner);
                o
            }
            VerifyPolicy::Entropy { h_max } => {
                let mut inner = Value::obj();
                inner.set("h_max", Value::Num(*h_max as f64));
                let mut o = Value::obj();
                o.set("entropy", inner);
                o
            }
        }
    }

    /// Parse the wire form produced by [`VerifyPolicy::to_json`]; a JSON
    /// string is treated as the CLI form (so `"mars:0.9"` also works).
    pub fn from_json(v: &Value) -> Result<VerifyPolicy, String> {
        if let Some(s) = v.as_str() {
            return VerifyPolicy::parse(s)
                .ok_or_else(|| format!("unknown policy '{s}'"));
        }
        let obj = v
            .as_obj()
            .ok_or("policy must be a string or a one-key object")?;
        if obj.len() != 1 {
            return Err("policy object must have exactly one key".into());
        }
        let (key, body) = obj.iter().next().unwrap();
        let num = |name: &str, d: f32| -> Result<f32, String> {
            match body.get(name) {
                None => Ok(d),
                Some(x) => x
                    .as_f64()
                    .map(|f| f as f32)
                    .filter(|f| f.is_finite())
                    .ok_or_else(|| format!("policy.{key}.{name} not a number")),
            }
        };
        match key.as_str() {
            "strict" => Ok(VerifyPolicy::Strict),
            "mars" => Ok(VerifyPolicy::Mars { theta: num("theta", 0.9)? }),
            "topk" => {
                let k = match body.get("k") {
                    None => 2,
                    Some(x) => x
                        .as_usize()
                        .filter(|&k| k >= 1)
                        .ok_or("policy.topk.k must be a positive integer")?,
                };
                Ok(VerifyPolicy::TopK { k, eps: num("eps", 0.1)? })
            }
            "entropy" => {
                Ok(VerifyPolicy::Entropy { h_max: num("h_max", 1.5)? })
            }
            other => Err(format!("unknown policy '{other}'")),
        }
    }

    /// Resolve the policy of one request object: the `"policy"` key wins;
    /// otherwise the legacy flat `"mars"` / `"theta"` keys are honored
    /// (`mars=false` → `Strict`, `mars=true` or bare `theta` → `Mars`).
    pub fn from_request(v: &Value) -> Result<VerifyPolicy, String> {
        if let Some(p) = v.get("policy") {
            return VerifyPolicy::from_json(p);
        }
        let theta = match v.get("theta") {
            None => None,
            Some(x) => Some(
                x.as_f64()
                    .map(|f| f as f32)
                    .filter(|f| f.is_finite())
                    .ok_or("'theta' not a number")?,
            ),
        };
        match v.get("mars").and_then(|b| b.as_bool()) {
            Some(false) => Ok(VerifyPolicy::Strict),
            Some(true) => {
                Ok(VerifyPolicy::Mars { theta: theta.unwrap_or(0.9) })
            }
            None => match theta {
                Some(theta) => Ok(VerifyPolicy::Mars { theta }),
                None => Ok(VerifyPolicy::default()),
            },
        }
    }

    // ------------------------------------------------ device encoding ----

    /// Encode into the `(policy_id, p0, p1)` device config-slot triple
    /// consumed by the round programs (one HLO artifact covers every
    /// policy; see `python/compile/state_spec.py`).
    pub fn encode_slots(&self) -> [f32; 3] {
        match self {
            VerifyPolicy::Strict => [POLICY_ID_STRICT, 0.0, 0.0],
            VerifyPolicy::Mars { theta } => [POLICY_ID_MARS, *theta, 0.0],
            VerifyPolicy::TopK { k, eps } => {
                [POLICY_ID_TOPK, *k as f32, *eps]
            }
            VerifyPolicy::Entropy { h_max } => {
                [POLICY_ID_ENTROPY, *h_max, 0.0]
            }
        }
    }

    /// Invert [`VerifyPolicy::encode_slots`].
    pub fn decode_slots(slots: [f32; 3]) -> Result<VerifyPolicy, String> {
        let [id, p0, p1] = slots;
        match id as i64 {
            0 => Ok(VerifyPolicy::Strict),
            1 => Ok(VerifyPolicy::Mars { theta: p0 }),
            2 => Ok(VerifyPolicy::TopK { k: p0 as usize, eps: p1 }),
            3 => Ok(VerifyPolicy::Entropy { h_max: p0 }),
            other => Err(format!("unknown policy_id {other}")),
        }
    }

    // ------------------------------------------- reference verification --

    /// Host-side reference accept rule for one position — mirrors the
    /// device kernel (`python/compile/kernels/mars_verify.py`) and is the
    /// ground truth for the property tests.
    ///
    /// `top` is the target's top logits at this position as
    /// `(token, logit)` pairs, best first (at least top-2 for relaxed
    /// policies; the device pipeline materializes exactly 2). `tstar` is
    /// the target's own chosen token (argmax when greedy, else a sample).
    pub fn accept(
        &self,
        draft: u32,
        tstar: u32,
        top: &[(u32, f32)],
    ) -> AcceptFlag {
        if draft == tstar {
            return AcceptFlag::Exact;
        }
        let Some(&(_, z1)) = top.first() else {
            return AcceptFlag::Reject;
        };
        let top2 = top.get(1);
        let relaxed = match *self {
            VerifyPolicy::Strict => false,
            VerifyPolicy::Mars { theta } => top2.is_some_and(|&(i2, z2)| {
                draft == i2 && z1 > 0.0 && z2 > 0.0 && z2 / z1 > theta
            }),
            VerifyPolicy::TopK { k, eps } => top
                .iter()
                .take(k)
                .skip(1)
                .any(|&(ij, zj)| {
                    draft == ij && z1 > 0.0 && zj > 0.0 && zj / z1 > 1.0 - eps
                }),
            VerifyPolicy::Entropy { h_max } => {
                top2.is_some_and(|&(i2, z2)| draft == i2 && z1 - z2 < h_max)
            }
        };
        if relaxed {
            AcceptFlag::Relaxed
        } else {
            AcceptFlag::Reject
        }
    }

    /// Reference chain scan: verify drafted positions in order, stopping
    /// at the first reject (paper Algorithm 1 shape). Each row of `rows`
    /// is `(tstar, top)` for the matching draft position. Returns the
    /// per-position flags and the accepted prefix length `m`.
    pub fn scan(
        &self,
        drafts: &[u32],
        rows: &[(u32, Vec<(u32, f32)>)],
    ) -> (Vec<AcceptFlag>, usize) {
        let n = drafts.len().min(rows.len());
        let mut flags = vec![AcceptFlag::Reject; n];
        let mut m = 0;
        for i in 0..n {
            let (tstar, top) = &rows[i];
            let f = self.accept(drafts[i], *tstar, top);
            if !f.accepted() {
                break;
            }
            flags[i] = f;
            m += 1;
        }
        (flags, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_every_family() {
        assert_eq!(VerifyPolicy::parse("strict"), Some(VerifyPolicy::Strict));
        assert_eq!(
            VerifyPolicy::parse("mars:0.92"),
            Some(VerifyPolicy::Mars { theta: 0.92 })
        );
        assert_eq!(
            VerifyPolicy::parse("mars"),
            Some(VerifyPolicy::Mars { theta: 0.9 })
        );
        assert_eq!(
            VerifyPolicy::parse("topk:3:0.2"),
            Some(VerifyPolicy::TopK { k: 3, eps: 0.2 })
        );
        assert_eq!(
            VerifyPolicy::parse("topk:2"),
            Some(VerifyPolicy::TopK { k: 2, eps: 0.1 })
        );
        assert_eq!(
            VerifyPolicy::parse("entropy:1.5"),
            Some(VerifyPolicy::Entropy { h_max: 1.5 })
        );
        assert_eq!(VerifyPolicy::parse("warp"), None);
        assert_eq!(VerifyPolicy::parse("strict:0.5"), None);
        assert_eq!(VerifyPolicy::parse("topk:0"), None);
    }

    #[test]
    fn label_round_trips() {
        for p in [
            VerifyPolicy::Strict,
            VerifyPolicy::Mars { theta: 0.875 },
            VerifyPolicy::TopK { k: 4, eps: 0.25 },
            VerifyPolicy::Entropy { h_max: 0.75 },
        ] {
            assert_eq!(VerifyPolicy::parse(&p.label()), Some(p), "{p:?}");
        }
    }

    #[test]
    fn json_round_trips() {
        for p in [
            VerifyPolicy::Strict,
            VerifyPolicy::Mars { theta: 0.9 },
            VerifyPolicy::TopK { k: 2, eps: 0.5 },
            VerifyPolicy::Entropy { h_max: 1.5 },
        ] {
            let v = p.to_json();
            let text = v.to_string_json();
            let back = Value::parse(&text).unwrap();
            assert_eq!(VerifyPolicy::from_json(&back), Ok(p), "{text}");
        }
    }

    #[test]
    fn device_normalization_clamps_topk() {
        assert_eq!(
            VerifyPolicy::TopK { k: 5, eps: 0.3 }.normalize_for_device(),
            VerifyPolicy::TopK { k: 2, eps: 0.3 }
        );
        for p in [
            VerifyPolicy::Strict,
            VerifyPolicy::Mars { theta: 0.9 },
            VerifyPolicy::TopK { k: 2, eps: 0.1 },
            VerifyPolicy::Entropy { h_max: 1.5 },
        ] {
            assert_eq!(p.normalize_for_device(), p);
        }
    }

    #[test]
    fn slots_round_trip() {
        for p in [
            VerifyPolicy::Strict,
            VerifyPolicy::Mars { theta: 0.5 },
            VerifyPolicy::TopK { k: 3, eps: 0.125 },
            VerifyPolicy::Entropy { h_max: 2.0 },
        ] {
            assert_eq!(VerifyPolicy::decode_slots(p.encode_slots()), Ok(p));
        }
        assert!(VerifyPolicy::decode_slots([9.0, 0.0, 0.0]).is_err());
    }

    #[test]
    fn legacy_request_keys_map_to_policies() {
        let strict = Value::parse(r#"{"mars": false, "theta": 0.7}"#).unwrap();
        assert_eq!(
            VerifyPolicy::from_request(&strict),
            Ok(VerifyPolicy::Strict)
        );
        let mars = Value::parse(r#"{"mars": true, "theta": 0.7}"#).unwrap();
        assert_eq!(
            VerifyPolicy::from_request(&mars),
            Ok(VerifyPolicy::Mars { theta: 0.7 })
        );
        let bare_theta = Value::parse(r#"{"theta": 0.85}"#).unwrap();
        assert_eq!(
            VerifyPolicy::from_request(&bare_theta),
            Ok(VerifyPolicy::Mars { theta: 0.85 })
        );
        let none = Value::parse(r#"{}"#).unwrap();
        assert_eq!(
            VerifyPolicy::from_request(&none),
            Ok(VerifyPolicy::default())
        );
        // the structured key wins over legacy keys
        let both = Value::parse(
            r#"{"policy": {"entropy": {"h_max": 1.0}}, "mars": true}"#,
        )
        .unwrap();
        assert_eq!(
            VerifyPolicy::from_request(&both),
            Ok(VerifyPolicy::Entropy { h_max: 1.0 })
        );
    }

    #[test]
    fn strict_accepts_only_exact() {
        let p = VerifyPolicy::Strict;
        let top = vec![(7, 3.0), (9, 2.9)];
        assert_eq!(p.accept(7, 7, &top), AcceptFlag::Exact);
        assert_eq!(p.accept(9, 7, &top), AcceptFlag::Reject);
    }

    #[test]
    fn mars_relaxes_above_theta_on_positive_domain() {
        let p = VerifyPolicy::Mars { theta: 0.9 };
        assert_eq!(
            p.accept(9, 7, &[(7, 3.0), (9, 2.9)]),
            AcceptFlag::Relaxed
        );
        assert_eq!(
            p.accept(9, 7, &[(7, 3.0), (9, 2.0)]),
            AcceptFlag::Reject
        );
        // negative logits never relax
        assert_eq!(
            p.accept(9, 7, &[(7, -1.0), (9, -1.01)]),
            AcceptFlag::Reject
        );
    }

    #[test]
    fn topk2_equals_mars_complement() {
        let topk = VerifyPolicy::TopK { k: 2, eps: 0.1 };
        let mars = VerifyPolicy::Mars { theta: 0.9 };
        for (z1, z2) in [(3.0, 2.95), (3.0, 2.0), (1.0, 0.95), (-1.0, -2.0)]
        {
            let top = vec![(7u32, z1), (9u32, z2)];
            for draft in [7u32, 9, 11] {
                assert_eq!(
                    topk.accept(draft, 7, &top),
                    mars.accept(draft, 7, &top),
                    "draft={draft} z1={z1} z2={z2}"
                );
            }
        }
    }

    #[test]
    fn topk_reaches_beyond_top2() {
        let p = VerifyPolicy::TopK { k: 3, eps: 0.5 };
        let top = vec![(7, 3.0), (9, 2.9), (11, 2.8)];
        assert_eq!(p.accept(11, 7, &top), AcceptFlag::Relaxed);
        let p2 = VerifyPolicy::TopK { k: 2, eps: 0.5 };
        assert_eq!(p2.accept(11, 7, &top), AcceptFlag::Reject);
    }

    #[test]
    fn entropy_gate_is_a_gap_ceiling() {
        let p = VerifyPolicy::Entropy { h_max: 0.5 };
        assert_eq!(
            p.accept(9, 7, &[(7, 3.0), (9, 2.6)]),
            AcceptFlag::Relaxed
        );
        assert_eq!(
            p.accept(9, 7, &[(7, 3.0), (9, 2.4)]),
            AcceptFlag::Reject
        );
    }

    #[test]
    fn scan_stops_at_first_reject() {
        let p = VerifyPolicy::Mars { theta: 0.9 };
        let rows: Vec<(u32, Vec<(u32, f32)>)> = vec![
            (5, vec![(5, 3.0), (6, 1.0)]),
            (5, vec![(5, 3.0), (8, 2.95)]),
            (5, vec![(5, 3.0), (6, 1.0)]),
            (5, vec![(5, 3.0), (6, 1.0)]),
        ];
        let (flags, m) = p.scan(&[5, 8, 9, 5], &rows);
        assert_eq!(m, 2);
        assert_eq!(
            flags,
            vec![
                AcceptFlag::Exact,
                AcceptFlag::Relaxed,
                AcceptFlag::Reject,
                AcceptFlag::Reject
            ]
        );
    }
}
