//! Deterministic fault injection for the serving stack (DESIGN.md §13).
//!
//! A [`FaultSpec`] is parsed once from the `--fault-plan` CLI string and
//! handed to every replica; each replica that the spec applies to builds
//! its own [`FaultPlan`] with a seed forked from `(spec seed, replica
//! id)`, so a run is reproducible end-to-end without any wall-clock
//! entropy (`Date::now` is deliberately never consulted — the only
//! randomness is [`crate::util::prng::Rng`]).
//!
//! Injection points (all inside [`crate::runtime::Runtime`]):
//! * **dispatch** — `Runtime::run` fails with an `injected:`-prefixed
//!   error at the configured rate, modeling a transient device-dispatch
//!   fault (the error every batchmate of a faulted lane sees);
//! * **latency** — `Runtime::run` sleeps a fixed number of milliseconds
//!   at the configured rate, modeling a hung dispatch (what per-request
//!   deadlines exist to bound);
//! * **rebuild** — `Runtime::batch_session` fails at the configured
//!   rate, modeling an unrecoverable device session (what drives a
//!   replica to `Down` and the router to fail over).
//!
//! Spec grammar (comma-separated `key=value`, all keys optional):
//!
//! ```text
//! dispatch=0.2,latency=0.05:250,rebuild=0.5,seed=7,only=0
//! ```
//!
//! `dispatch`/`rebuild` are probabilities in `[0, 1]`; `latency` is
//! `rate:millis`; `seed` is the base PRNG seed (default 0); `only`
//! restricts injection to a single replica id (the chaos suite uses it
//! to kill one replica while its peers stay healthy).
//!
//! The capped-exponential [`backoff_ms`] helper used by the replica
//! supervisor lives here too so the property tests can drive it as a
//! pure function.

#![deny(clippy::unwrap_used)]
#![deny(clippy::expect_used)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::prng::Rng;

/// Marker prefix on every injected error message, so tests (and humans
/// reading traces) can tell an injected fault from a real one.
pub const INJECTED_PREFIX: &str = "injected:";

/// Parsed `--fault-plan` spec. Plain data: cloneable, comparable,
/// carried on the replica config; [`FaultSpec::build`] turns it into a
/// live per-replica [`FaultPlan`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultSpec {
    /// Probability a `Runtime::run` dispatch fails.
    pub dispatch_rate: f64,
    /// Probability a `Runtime::run` dispatch sleeps `latency_ms`.
    pub latency_rate: f64,
    /// Artificial dispatch latency, milliseconds.
    pub latency_ms: u64,
    /// Probability a `Runtime::batch_session` rebuild fails.
    pub rebuild_rate: f64,
    /// Base PRNG seed; each replica forks `seed ^ mix(replica)`.
    pub seed: u64,
    /// Restrict injection to this replica id (None = all replicas).
    pub only: Option<usize>,
}

fn parse_rate(key: &str, v: &str) -> Result<f64, String> {
    let r: f64 = v
        .parse()
        .map_err(|_| format!("fault-plan: {key} wants a number, got {v:?}"))?;
    if !(0.0..=1.0).contains(&r) {
        return Err(format!("fault-plan: {key} rate {r} outside [0, 1]"));
    }
    Ok(r)
}

impl FaultSpec {
    /// Parse the CLI spec string. Empty string is an error (pass no
    /// `--fault-plan` at all for a fault-free run).
    pub fn parse(spec: &str) -> Result<FaultSpec, String> {
        let mut out = FaultSpec::default();
        if spec.trim().is_empty() {
            return Err("fault-plan: empty spec".into());
        }
        for part in spec.split(',') {
            let part = part.trim();
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("fault-plan: {part:?} is not key=value"))?;
            match key {
                "dispatch" => out.dispatch_rate = parse_rate(key, val)?,
                "rebuild" => out.rebuild_rate = parse_rate(key, val)?,
                "latency" => {
                    let (rate, ms) = val.split_once(':').ok_or_else(|| {
                        format!("fault-plan: latency wants rate:millis, got {val:?}")
                    })?;
                    out.latency_rate = parse_rate("latency", rate)?;
                    out.latency_ms = ms.parse().map_err(|_| {
                        format!("fault-plan: latency millis {ms:?} is not an integer")
                    })?;
                }
                "seed" => {
                    out.seed = val
                        .parse()
                        .map_err(|_| format!("fault-plan: seed {val:?} is not an integer"))?;
                }
                "only" => {
                    out.only = Some(val.parse().map_err(|_| {
                        format!("fault-plan: only wants a replica id, got {val:?}")
                    })?);
                }
                other => return Err(format!("fault-plan: unknown key {other:?}")),
            }
        }
        Ok(out)
    }

    /// Canonical spec string (parse round-trips through it).
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.dispatch_rate > 0.0 {
            parts.push(format!("dispatch={}", self.dispatch_rate));
        }
        if self.latency_rate > 0.0 {
            parts.push(format!("latency={}:{}", self.latency_rate, self.latency_ms));
        }
        if self.rebuild_rate > 0.0 {
            parts.push(format!("rebuild={}", self.rebuild_rate));
        }
        parts.push(format!("seed={}", self.seed));
        if let Some(id) = self.only {
            parts.push(format!("only={id}"));
        }
        parts.join(",")
    }

    /// Does this spec inject anything on the given replica?
    pub fn applies_to(&self, replica: usize) -> bool {
        self.only.map_or(true, |id| id == replica)
    }

    /// Build the live per-replica plan. Returns `None` when the spec is
    /// filtered away from this replica (`only=` mismatch), so callers
    /// skip installing a plan entirely.
    pub fn build(&self, replica: usize) -> Option<FaultPlan> {
        if !self.applies_to(replica) {
            return None;
        }
        // fork the seed per replica so peers draw independent streams
        // but the whole fleet stays reproducible from one spec
        let mut base = Rng::new(self.seed);
        let mut forked = base.fork();
        for _ in 0..replica {
            forked = base.fork();
        }
        Some(FaultPlan {
            spec: self.clone(),
            rng: Mutex::new(forked),
            dispatch_injected: AtomicU64::new(0),
            latency_injected: AtomicU64::new(0),
            rebuild_injected: AtomicU64::new(0),
        })
    }
}

/// Injection counters, snapshot via [`FaultPlan::counts`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultCounts {
    pub dispatch: u64,
    pub latency: u64,
    pub rebuild: u64,
}

/// Live, thread-safe fault injector. `Runtime::run` takes `&self`, so
/// the PRNG sits behind a poison-recovering mutex; the draw itself is
/// a few dozen nanoseconds and only taken when a plan is installed.
#[derive(Debug)]
pub struct FaultPlan {
    spec: FaultSpec,
    rng: Mutex<Rng>,
    dispatch_injected: AtomicU64,
    latency_injected: AtomicU64,
    rebuild_injected: AtomicU64,
}

impl FaultPlan {
    fn draw(&self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        let mut rng = self
            .rng
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        rng.bool(p)
    }

    /// Should this dispatch fail? Increments the dispatch counter when
    /// it fires.
    pub fn dispatch_fault(&self) -> bool {
        let hit = self.draw(self.spec.dispatch_rate);
        if hit {
            self.dispatch_injected.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Artificial latency to apply to this dispatch, if any.
    pub fn latency(&self) -> Option<u64> {
        if self.draw(self.spec.latency_rate) {
            self.latency_injected.fetch_add(1, Ordering::Relaxed);
            Some(self.spec.latency_ms)
        } else {
            None
        }
    }

    /// Should this batch-session rebuild fail? Increments the rebuild
    /// counter when it fires.
    pub fn rebuild_fault(&self) -> bool {
        let hit = self.draw(self.spec.rebuild_rate);
        if hit {
            self.rebuild_injected.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Snapshot the injection counters.
    pub fn counts(&self) -> FaultCounts {
        FaultCounts {
            dispatch: self.dispatch_injected.load(Ordering::Relaxed),
            latency: self.latency_injected.load(Ordering::Relaxed),
            rebuild: self.rebuild_injected.load(Ordering::Relaxed),
        }
    }

    /// The spec this plan was built from.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }
}

/// Pre-jitter backoff bound for rebuild `attempt` (0-based): capped
/// exponential, `base_ms * 2^attempt` clamped to `cap_ms`. Pure and
/// monotone non-decreasing in `attempt` — the property tests pin both.
pub fn backoff_bound_ms(attempt: u32, base_ms: u64, cap_ms: u64) -> u64 {
    let base = base_ms.max(1);
    let shifted = if attempt >= 63 {
        u64::MAX
    } else {
        base.saturating_mul(1u64 << attempt.min(62))
    };
    shifted.min(cap_ms.max(1))
}

/// Jittered backoff for rebuild `attempt`: uniform in
/// `[bound/2, bound]` where `bound = backoff_bound_ms(...)` — "equal
/// jitter", so consecutive attempts never collapse to zero sleep and
/// the cap is a hard ceiling.
pub fn backoff_ms(attempt: u32, base_ms: u64, cap_ms: u64, rng: &mut Rng) -> u64 {
    let bound = backoff_bound_ms(attempt, base_ms, cap_ms);
    let lo = bound / 2;
    lo + rng.below(bound - lo + 1)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec_round_trips_through_label() {
        let spec = FaultSpec::parse("dispatch=0.2,latency=0.05:250,rebuild=0.5,seed=7,only=0")
            .unwrap();
        assert_eq!(spec.dispatch_rate, 0.2);
        assert_eq!(spec.latency_rate, 0.05);
        assert_eq!(spec.latency_ms, 250);
        assert_eq!(spec.rebuild_rate, 0.5);
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.only, Some(0));
        let reparsed = FaultSpec::parse(&spec.label()).unwrap();
        assert_eq!(spec, reparsed);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in [
            "",
            "dispatch",
            "dispatch=2.0",
            "dispatch=-0.1",
            "latency=0.5",
            "latency=0.5:abc",
            "seed=x",
            "only=x",
            "bogus=1",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn only_filter_gates_plan_construction() {
        let spec = FaultSpec::parse("dispatch=1.0,only=1,seed=3").unwrap();
        assert!(spec.build(0).is_none());
        assert!(spec.build(2).is_none());
        let plan = spec.build(1).unwrap();
        assert!(plan.dispatch_fault());
        assert_eq!(plan.counts().dispatch, 1);
    }

    #[test]
    fn draws_are_deterministic_per_seed_and_replica() {
        let spec = FaultSpec::parse("dispatch=0.5,seed=42").unwrap();
        let a = spec.build(0).unwrap();
        let b = spec.build(0).unwrap();
        let seq_a: Vec<bool> = (0..64).map(|_| a.dispatch_fault()).collect();
        let seq_b: Vec<bool> = (0..64).map(|_| b.dispatch_fault()).collect();
        assert_eq!(seq_a, seq_b, "same seed+replica must draw identically");
        let c = spec.build(1).unwrap();
        let seq_c: Vec<bool> = (0..64).map(|_| c.dispatch_fault()).collect();
        assert_ne!(seq_a, seq_c, "replicas must fork distinct streams");
    }

    #[test]
    fn zero_rates_never_fire() {
        let spec = FaultSpec::parse("seed=1").unwrap();
        let plan = spec.build(0).unwrap();
        for _ in 0..128 {
            assert!(!plan.dispatch_fault());
            assert!(plan.latency().is_none());
            assert!(!plan.rebuild_fault());
        }
        assert_eq!(plan.counts(), FaultCounts::default());
    }

    #[test]
    fn latency_fires_with_configured_millis() {
        let spec = FaultSpec::parse("latency=1.0:250,seed=9").unwrap();
        let plan = spec.build(0).unwrap();
        assert_eq!(plan.latency(), Some(250));
        assert_eq!(plan.counts().latency, 1);
    }

    #[test]
    fn backoff_bound_is_capped_and_monotone() {
        let mut prev = 0u64;
        for attempt in 0..80 {
            let b = backoff_bound_ms(attempt, 50, 5_000);
            assert!(b <= 5_000, "attempt {attempt}: bound {b} above cap");
            assert!(b >= prev, "attempt {attempt}: bound {b} shrank from {prev}");
            prev = b;
        }
        assert_eq!(backoff_bound_ms(0, 50, 5_000), 50);
        assert_eq!(backoff_bound_ms(63, 50, 5_000), 5_000);
    }

    #[test]
    fn backoff_jitter_stays_in_the_equal_jitter_band() {
        let mut rng = Rng::new(11);
        for attempt in 0..20 {
            let bound = backoff_bound_ms(attempt, 50, 5_000);
            for _ in 0..32 {
                let ms = backoff_ms(attempt, 50, 5_000, &mut rng);
                assert!(ms >= bound / 2 && ms <= bound, "{ms} outside [{}, {bound}]", bound / 2);
            }
        }
    }
}
