//! Simulated clock: translate round/step counters into the paper's
//! memory-bound H100 cost regime.
//!
//! On an H100 serving an 8B model, decoding is memory-bandwidth-bound:
//! one target forward costs ~1 unit whether it processes 1 token or a
//! K+1-token verify block (the whole point of speculative decoding).
//! Draft costs are scaled by parameter ratio — EAGLE-style heads are ~5%
//! of the target per step (one transformer layer + head), an independent
//! half-size drafter ~12% (Vicuna-68M vs 13B is ~0.5%, but small models
//! have worse utilization; we follow the EAGLE-3 paper's measured ~8-15%
//! per-chain overhead), Medusa heads ~2% (a single matmul).
//!
//! `simulated_units` returns cost units per generated token, so
//! `base_units / method_units` is the simulated speedup. The *shape*
//! claims of Table 1 (ordering, rough factors) are made under this model;
//! wall-clock numbers are reported alongside.
//!
//! The model also charges a per-dispatch overhead ([`DISPATCH_OVERHEAD`]
//! units per device call): launch/dispatch cost is real on every
//! substrate (CUDA launch + scheduling on a GPU, ~0.5 ms `execute_b`
//! here — DESIGN.md §1.1) and is exactly what round packing
//! (DESIGN.md §9.6) amortizes, so a cost model without it would report
//! identical "speedups" for a packed and an unpacked run and hide the
//! stack's largest remaining wall-clock lever. The overhead is per
//! *device* dispatch: cross-sequence batching (DESIGN.md §9.5) shares
//! one dispatch across every occupied lane, so the model charges each
//! request its amortized [`GenResult::dispatch_share`] (Σ 1/occupancy),
//! not its raw `device_calls`.
//!
//! The model is pure — it emits nothing itself. Bench targets fold its
//! outputs (`tau`, `*_sim_units`, `speedup_sim`) into their schema-2
//! trajectory records ([`super::record`], DESIGN.md §10), where the
//! sim-unit metrics gate PR-to-PR via `mars bench diff` and τ stays
//! informational.

use crate::engine::{GenResult, SpecMethod};

/// Cost of one target forward (any block width ≤ K+1): the unit.
pub const TARGET_FORWARD: f64 = 1.0;

/// Per-device-dispatch overhead in target-forward units: each
/// `execute_b` call (round, packed round, extract, upload) pays this on
/// top of its compute. 0.05 ≈ a launch tax of 5% of a memory-bound
/// decode forward — conservative for the H100 regime the model targets
/// and far below the ~30% this CPU-PJRT substrate actually pays.
pub const DISPATCH_OVERHEAD: f64 = 0.05;

/// Tokens one prefill target forward chews through in the memory-bound
/// regime — the same K+1 block width the decode model assumes (K = 7).
pub const PREFILL_BLOCK_TOKENS: f64 = 8.0;

/// Simulated prefill cost for `uncached_tokens` of prompt: chunked target
/// forwards over the tokens that actually need prefilling, i.e. the
/// prompt minus whatever the prefix cache restored (DESIGN.md §8). This
/// is the simclock quantity the `chat` serve scenario compares cache-on
/// vs cache-off by — wall-clock prefill on this substrate is dominated
/// by per-call PJRT overhead, so the cost model is the honest lens for
/// the paper-regime saving.
pub fn prefill_units(uncached_tokens: usize) -> f64 {
    (uncached_tokens as f64 / PREFILL_BLOCK_TOKENS).ceil() * TARGET_FORWARD
}

/// Per-draft-step cost as a fraction of a target forward (keyed by the
/// descriptor's family; knob values don't change the per-step ratio).
pub fn draft_step_cost(method: SpecMethod) -> f64 {
    match method {
        SpecMethod::Sps { .. } => 0.12,
        SpecMethod::EagleChain { .. } | SpecMethod::EagleTree { .. } => 0.05,
        SpecMethod::Medusa { .. } => 0.02,
        // host-side drafting is free on the accelerator
        SpecMethod::Pld { .. } | SpecMethod::Lookahead { .. } => 0.0,
        SpecMethod::Ar => 0.0,
    }
}

/// Simulated cost units per generated token for one finished request.
/// Compute (target forwards + scaled draft steps) plus the per-dispatch
/// tax: [`DISPATCH_OVERHEAD`] × the request's *amortized* dispatch count
/// ([`GenResult::dispatch_share`]). The overhead is paid once per
/// *device* dispatch, not once per sequence-dispatch: under
/// cross-sequence batching (DESIGN.md §9.5) a dispatch steps every
/// occupied lane, so each lane is charged `1 / occupancy` of it —
/// charging full `device_calls` per lane would bill a B=4 batch four
/// launch taxes for one launch. On the solo path `dispatch_share ==
/// device_calls` and nothing changes; packed runs (fewer dispatches for
/// the same rounds) earn their call-count savings the same way.
pub fn simulated_units(method: SpecMethod, r: &GenResult) -> f64 {
    let tokens = r.tokens.len().max(1) as f64;
    let compute = match method {
        // AR: one target forward per token
        SpecMethod::Ar => tokens * TARGET_FORWARD,
        _ => {
            // one verify forward per round (the commit step is fused into
            // the next round's block in production systems)
            let verify = r.snapshot.rounds * TARGET_FORWARD;
            let draft = r.snapshot.draft_steps * draft_step_cost(method);
            verify + draft
        }
    };
    (compute + r.dispatch_share * DISPATCH_OVERHEAD) / tokens
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::GenResult;
    use crate::runtime::state::Snapshot;

    fn result(tokens: usize, rounds: f64, draft_steps: f64) -> GenResult {
        GenResult {
            tokens: vec![5; tokens],
            text: String::new(),
            decode_seconds: 1.0,
            prefill_seconds: 0.0,
            prefill_cached_tokens: 0,
            snapshot: Snapshot {
                rounds,
                draft_steps,
                committed: tokens as f64,
                ..Default::default()
            },
            probe: None,
            device_calls: 0,
            dispatch_share: 0.0,
            deadline_exceeded: false,
        }
    }

    /// Stamp a solo run's dispatch counters (occupancy 1: share == calls).
    fn with_calls(mut r: GenResult, calls: u64) -> GenResult {
        r.device_calls = calls;
        r.dispatch_share = calls as f64;
        r
    }

    #[test]
    fn ar_is_one_unit_per_token() {
        // zero dispatches recorded -> pure compute: exactly 1 unit/token
        let r = result(50, 50.0, 0.0);
        assert!((simulated_units(SpecMethod::Ar, &r) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dispatch_overhead_pins_ar_at_pack_1_as_baseline() {
        // the regression pin for the per-dispatch term: unpacked AR
        // issues 2 dispatches per token (one round + one extract), so
        // the baseline costs exactly 1 + 2 * DISPATCH_OVERHEAD per token
        let r = with_calls(result(50, 50.0, 0.0), 2 * 50);
        let want = 1.0 + 2.0 * DISPATCH_OVERHEAD;
        let got = simulated_units(SpecMethod::Ar, &r);
        assert!((got - want).abs() < 1e-12, "got {got}, want {want}");
    }

    #[test]
    fn batching_amortizes_dispatch_overhead_across_occupied_slots() {
        // the §9.5 regression pin, next to the ar-at-pack-1 baseline
        // above: same per-lane dispatch participation (2 per token), but
        // at B=4 each dispatch served 4 lanes, so the lane's amortized
        // share is a quarter — 1 + 2 * DISPATCH_OVERHEAD / 4 per token.
        // The old model charged DISPATCH_OVERHEAD per sequence-dispatch
        // (device_calls), billing four launch taxes for one launch.
        let mut r = result(50, 50.0, 0.0);
        r.device_calls = 2 * 50; // lane participated in 100 dispatches
        r.dispatch_share = 2.0 * 50.0 / 4.0; // each shared 4 ways
        let want = 1.0 + 2.0 * DISPATCH_OVERHEAD / 4.0;
        let got = simulated_units(SpecMethod::Ar, &r);
        assert!((got - want).abs() < 1e-12, "got {got}, want {want}");
        // and the B=4 lane is strictly cheaper than the solo baseline
        let solo = with_calls(result(50, 50.0, 0.0), 2 * 50);
        assert!(got < simulated_units(SpecMethod::Ar, &solo));
    }

    #[test]
    fn packing_earns_its_call_savings_in_simulated_units() {
        // same rounds and tokens, 8 rounds fused per dispatch: only the
        // dispatch term shrinks, by the call-count ratio
        let unpacked = with_calls(result(48, 48.0, 0.0), 2 * 48);
        // one call + extract per 8 rounds
        let packed = with_calls(result(48, 48.0, 0.0), 2 * 48 / 8);
        let a = simulated_units(SpecMethod::Ar, &unpacked);
        let b = simulated_units(SpecMethod::Ar, &packed);
        assert!(b < a, "packed {b} not cheaper than unpacked {a}");
        let diff = a - b;
        let want = (2.0 - 0.25) * DISPATCH_OVERHEAD;
        assert!((diff - want).abs() < 1e-12, "diff {diff}, want {want}");
    }

    #[test]
    fn speculative_beats_ar_when_tau_high() {
        // 50 tokens in 10 rounds (tau 5), 7 eagle draft steps per round
        let r = result(50, 10.0, 70.0);
        let u = simulated_units(SpecMethod::default(), &r);
        assert!(u < 0.5, "units {u}"); // > 2x speedup
    }

    #[test]
    fn tau_one_is_slower_than_ar() {
        // one committed token per round: SD degenerates
        let r = result(10, 10.0, 70.0);
        let u = simulated_units(SpecMethod::Sps { k: 7 }, &r);
        assert!(u > 1.0, "units {u}");
    }

    #[test]
    fn prefill_units_scale_with_uncached_suffix() {
        assert_eq!(prefill_units(0), 0.0);
        assert_eq!(prefill_units(1), 1.0);
        assert_eq!(prefill_units(8), 1.0);
        assert_eq!(prefill_units(9), 2.0);
        // a 120-token prompt with a 96-token cached prefix costs only
        // the 24-token suffix: 3 blocks instead of 15
        assert_eq!(prefill_units(120 - 96), 3.0);
        assert_eq!(prefill_units(120), 15.0);
    }

    #[test]
    fn host_drafters_cost_only_verify() {
        let r = result(40, 10.0, 0.0);
        let u = simulated_units(
            SpecMethod::Pld { min_ngram: 2, max_ngram: 4, k: 7 },
            &r,
        );
        assert!((u - 0.25).abs() < 1e-12);
    }
}
