//! `mars bench diff OLD.json NEW.json` — the trajectory comparator and
//! regression gate (DESIGN.md §10).
//!
//! Two schema-2 documents ([`super::record`]) are paired record-by-record
//! on [`super::record::Record::key_id`]; each pair gets a ratio and a
//! verdict from the per-metric direction/threshold table
//! ([`metric_rule`]):
//!
//! * throughput-like metrics may not **drop** more than their threshold;
//! * latency-like metrics may not **rise** more than theirs (p99 gets a
//!   wider band than p50 — tails are noisy at bench sample counts);
//! * informational metrics (τ, error counts, unknown names) are reported
//!   but never gate.
//!
//! The gate respects sample counts and provenance: a pair whose smaller
//! side has fewer than [`DiffCfg::min_samples`] samples gets its
//! tolerance widened by [`DiffCfg::wide_factor`], and when either
//! document is `provenance: "estimated"` every would-be failure is
//! downgraded to a warning (CI's soft gate while baselines remain
//! hand-estimated — committing a measured baseline upgrades the gate to
//! hard automatically). Unmatched keys are always reported as
//! added/removed, never silently dropped. Schema invalidity is a hard
//! error before any comparison happens.

use std::path::Path;

use anyhow::{anyhow, Result};

use super::record::{Provenance, Record, RecordDoc};

/// Which way a metric is allowed to move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Bigger is better (throughput, accuracy): gate on drops.
    Higher,
    /// Smaller is better (latency, dispatch tax): gate on rises.
    Lower,
    /// Reported, never gated (τ, counters, unknown metrics).
    Info,
}

/// How one pattern of the threshold table matches a metric name.
#[derive(Debug, Clone, Copy)]
pub enum MetricPattern {
    /// The metric name equals the string.
    Exact(&'static str),
    /// The metric name starts with the string (rendered `name*`).
    Prefix(&'static str),
    /// The metric name ends with the string (rendered `*name`).
    Suffix(&'static str),
    /// The metric name contains the string (rendered `*name*`).
    Contains(&'static str),
}

impl MetricPattern {
    fn matches(&self, metric: &str) -> bool {
        match *self {
            MetricPattern::Exact(s) => metric == s,
            MetricPattern::Prefix(s) => metric.starts_with(s),
            MetricPattern::Suffix(s) => metric.ends_with(s),
            MetricPattern::Contains(s) => metric.contains(s),
        }
    }

    fn label(&self) -> String {
        match *self {
            MetricPattern::Exact(s) => format!("`{s}`"),
            MetricPattern::Prefix(s) => format!("`{s}*`"),
            MetricPattern::Suffix(s) => format!("`*{s}`"),
            MetricPattern::Contains(s) => format!("`*{s}*`"),
        }
    }
}

/// One row of the threshold table: any matching pattern applies the
/// row's direction + allowed fractional regression.
#[derive(Debug, Clone, Copy)]
pub struct MetricRule {
    /// Patterns sharing this rule (one rendered table row).
    pub patterns: &'static [MetricPattern],
    /// Which way the metric may move.
    pub direction: Direction,
    /// Allowed fractional regression (0.15 = 15%).
    pub threshold: f64,
    /// Rendered parenthetical, e.g. why a band is wider.
    pub note: &'static str,
}

/// The threshold table itself. First matching row wins; metrics matching
/// no row (τ, error counters, unknown names) are informational. This
/// table is the single source: [`metric_rule`] evaluates it and
/// [`thresholds_markdown`] renders it (`mars bench diff
/// --print-thresholds`) — BENCHMARKS.md embeds that rendering verbatim,
/// which `mars check contracts` verifies.
pub const RULES: &[MetricRule] = &[
    MetricRule {
        patterns: &[
            MetricPattern::Prefix("tok_per_s"),
            MetricPattern::Exact("req_per_s"),
            MetricPattern::Prefix("speedup"),
        ],
        direction: Direction::Higher,
        threshold: 0.15,
        note: "",
    },
    MetricRule {
        patterns: &[
            MetricPattern::Exact("accuracy"),
            MetricPattern::Exact("rouge_l"),
            MetricPattern::Exact("bleu"),
            MetricPattern::Exact("chrf"),
            MetricPattern::Exact("judge"),
            MetricPattern::Exact("hit_rate"),
            MetricPattern::Exact("follow_cached_tok"),
        ],
        direction: Direction::Higher,
        threshold: 0.15,
        note: "",
    },
    MetricRule {
        patterns: &[
            MetricPattern::Exact("device_calls_per_token"),
            MetricPattern::Exact("dispatches_per_token"),
        ],
        direction: Direction::Lower,
        threshold: 0.15,
        note: "",
    },
    MetricRule {
        patterns: &[MetricPattern::Suffix("_ms_p99")],
        direction: Direction::Lower,
        threshold: 0.50,
        note: "tails are noisy",
    },
    MetricRule {
        patterns: &[
            MetricPattern::Suffix("_ms_p50"),
            MetricPattern::Suffix("_ms"),
        ],
        direction: Direction::Lower,
        threshold: 0.25,
        note: "",
    },
    MetricRule {
        patterns: &[
            MetricPattern::Suffix("_units"),
            MetricPattern::Contains("sim_units"),
        ],
        direction: Direction::Lower,
        threshold: 0.15,
        note: "",
    },
];

/// Direction + allowed fractional regression for a metric name: the
/// first matching [`RULES`] row, else informational. τ never gates — it
/// is a property of the method × workload, not a perf budget: policy
/// changes move it on purpose.
pub fn metric_rule(metric: &str) -> (Direction, f64) {
    for rule in RULES {
        if rule.patterns.iter().any(|p| p.matches(metric)) {
            return (rule.direction, rule.threshold);
        }
    }
    (Direction::Info, 0.0)
}

/// Canonical markdown rendering of [`RULES`] — what `mars bench diff
/// --print-thresholds` emits and BENCHMARKS.md must contain verbatim
/// (checked by `mars check contracts`).
pub fn thresholds_markdown() -> String {
    let mut out = String::from("| metric | direction | gate |\n|---|---|---|\n");
    for rule in RULES {
        let pats = rule
            .patterns
            .iter()
            .map(|p| p.label())
            .collect::<Vec<_>>()
            .join(", ");
        let (dir, verb) = match rule.direction {
            Direction::Higher => ("higher is better", "drop"),
            Direction::Lower => ("lower is better", "rise"),
            Direction::Info => ("informational", "move"),
        };
        let pct = (rule.threshold * 100.0).round() as usize;
        let mut gate = format!("may not {verb} > {pct}%");
        if !rule.note.is_empty() {
            gate.push_str(&format!(" ({})", rule.note));
        }
        out.push_str(&format!("| {pats} | {dir} | {gate} |\n"));
    }
    out.push_str(
        "| `tau`, `err`, anything unrecognized | informational | \
         reported, never gates |\n",
    );
    out
}

/// Knobs of the gate.
#[derive(Debug, Clone, Copy)]
pub struct DiffCfg {
    /// Below this sample count (on either side) the pair's tolerance is
    /// widened by [`DiffCfg::wide_factor`].
    pub min_samples: usize,
    /// Tolerance multiplier for tiny-sample pairs.
    pub wide_factor: f64,
}

impl Default for DiffCfg {
    fn default() -> Self {
        DiffCfg { min_samples: 8, wide_factor: 2.0 }
    }
}

/// Outcome of one paired record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Within tolerance (or improved).
    Pass,
    /// Outside tolerance, but either side is `estimated` — reported, not
    /// gating.
    Warn,
    /// Outside tolerance on measured data: the gate fails.
    Fail,
    /// Informational metric (or no usable ratio): never gates.
    Info,
}

impl Verdict {
    fn tag(self) -> &'static str {
        match self {
            Verdict::Pass => "ok",
            Verdict::Warn => "WARN",
            Verdict::Fail => "FAIL",
            Verdict::Info => "info",
        }
    }
}

/// One paired row of the diff table.
#[derive(Debug, Clone)]
pub struct DiffRow {
    /// Pairing identity ([`Record::key_id`]).
    pub key: String,
    /// Metric name (also part of the key; split out for the table).
    pub metric: String,
    /// Old/new values.
    pub old: f64,
    /// New value.
    pub new: f64,
    /// `new / old` (1.0 when both are zero).
    pub ratio: f64,
    /// Effective allowed fractional regression after sample widening
    /// (0.0 for informational rows).
    pub limit: f64,
    /// Direction the rule applied.
    pub direction: Direction,
    /// The verdict.
    pub verdict: Verdict,
}

/// Full diff outcome: paired rows plus the unmatched keys on each side.
#[derive(Debug, Clone)]
pub struct DiffReport {
    /// Paired rows, in key order.
    pub rows: Vec<DiffRow>,
    /// Keys present only in the new document.
    pub added: Vec<String>,
    /// Keys present only in the old document.
    pub removed: Vec<String>,
    /// True when either side was `estimated` (failures downgraded).
    pub soft: bool,
}

impl DiffReport {
    /// Rows that hard-fail the gate.
    pub fn failures(&self) -> Vec<&DiffRow> {
        self.rows
            .iter()
            .filter(|r| r.verdict == Verdict::Fail)
            .collect()
    }

    /// Rows that would fail but were softened by estimated provenance.
    pub fn warnings(&self) -> Vec<&DiffRow> {
        self.rows
            .iter()
            .filter(|r| r.verdict == Verdict::Warn)
            .collect()
    }

    /// Does the gate fail (nonzero exit)?
    pub fn regressed(&self) -> bool {
        !self.failures().is_empty()
    }

    /// Readable table, worst rows first, unmatched keys always listed.
    pub fn render(&self, old_name: &str, new_name: &str) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "## bench diff — {old_name} -> {new_name}\n");
        if self.soft {
            let _ = writeln!(
                out,
                "soft gate: a side is `estimated` — regressions WARN \
                 instead of FAIL until a measured baseline is committed.\n"
            );
        }
        let _ =
            writeln!(out, "| verdict | key | old | new | ratio | allowed |");
        let _ = writeln!(out, "|---|---|---|---|---|---|");
        let sev = |v: Verdict| match v {
            Verdict::Fail => 0,
            Verdict::Warn => 1,
            Verdict::Pass => 2,
            Verdict::Info => 3,
        };
        let mut rows: Vec<&DiffRow> = self.rows.iter().collect();
        rows.sort_by(|a, b| {
            sev(a.verdict).cmp(&sev(b.verdict)).then(a.key.cmp(&b.key))
        });
        for r in rows {
            let allowed = match r.direction {
                Direction::Info => "-".to_string(),
                Direction::Higher => format!(">= {:.2}x", 1.0 - r.limit),
                Direction::Lower => format!("<= {:.2}x", 1.0 + r.limit),
            };
            let _ = writeln!(
                out,
                "| {} | {} | {} | {} | {:.3}x | {} |",
                r.verdict.tag(),
                r.key,
                fmt_num(r.old),
                fmt_num(r.new),
                r.ratio,
                allowed
            );
        }
        for key in &self.removed {
            let _ = writeln!(out, "| removed | {key} | - | - | - | - |");
        }
        for key in &self.added {
            let _ = writeln!(out, "| added | {key} | - | - | - | - |");
        }
        let fails = self.failures();
        let warns = self.warnings();
        let _ = writeln!(
            out,
            "\n{} compared, {} FAIL, {} WARN, {} added, {} removed",
            self.rows.len(),
            fails.len(),
            warns.len(),
            self.added.len(),
            self.removed.len()
        );
        for r in fails {
            let _ = writeln!(out, "FAIL: {}", r.key);
        }
        out
    }
}

fn fmt_num(v: f64) -> String {
    crate::util::json::Value::Num(v).to_string_json()
}

/// Pair two documents by record key and apply the threshold table.
pub fn diff_docs(old: &RecordDoc, new: &RecordDoc, cfg: &DiffCfg) -> DiffReport {
    let soft = old.env.provenance == Provenance::Estimated
        || new.env.provenance == Provenance::Estimated;
    let old_by = old.by_key();
    let new_by = new.by_key();
    let mut rows = Vec::new();
    let mut added = Vec::new();
    let mut removed = Vec::new();
    for (key, o) in &old_by {
        match new_by.get(key) {
            None => removed.push(key.clone()),
            Some(n) => rows.push(pair_row(key, o, n, soft, cfg)),
        }
    }
    for key in new_by.keys() {
        if !old_by.contains_key(key) {
            added.push(key.clone());
        }
    }
    DiffReport { rows, added, removed, soft }
}

/// Verdict for one (old, new) pair. Monotone by construction: for a
/// fixed old value, direction and tolerance, a strictly worse new value
/// can only keep or raise the severity (the property tests pin this).
fn pair_row(
    key: &str,
    old: &Record,
    new: &Record,
    soft: bool,
    cfg: &DiffCfg,
) -> DiffRow {
    let (direction, base) = metric_rule(&old.metric);
    let n_min = old.n.min(new.n);
    let mut limit = base;
    if n_min < cfg.min_samples {
        limit *= cfg.wide_factor;
    }
    let ratio = if old.value != 0.0 {
        new.value / old.value
    } else if new.value == 0.0 {
        1.0
    } else {
        f64::INFINITY
    };
    let verdict = if direction == Direction::Info {
        Verdict::Info
    } else if n_min == 0 || old.value <= 0.0 {
        // no samples, or no positive baseline magnitude to scale the
        // tolerance band by: report, don't gate
        Verdict::Info
    } else {
        let bad = match direction {
            Direction::Higher => new.value < old.value * (1.0 - limit),
            Direction::Lower => new.value > old.value * (1.0 + limit),
            Direction::Info => false,
        };
        match (bad, soft) {
            (false, _) => Verdict::Pass,
            (true, true) => Verdict::Warn,
            (true, false) => Verdict::Fail,
        }
    };
    DiffRow {
        key: key.to_string(),
        metric: old.metric.clone(),
        old: old.value,
        new: new.value,
        ratio,
        limit: if direction == Direction::Info { 0.0 } else { limit },
        direction,
        verdict,
    }
}

/// Load, validate and diff two snapshot files. Schema invalidity on
/// either side is a hard error (the CI gate fails before any value
/// comparison); on success returns the report plus its rendering.
pub fn run_diff(
    old_path: &Path,
    new_path: &Path,
    cfg: &DiffCfg,
) -> Result<(DiffReport, String)> {
    let load = |path: &Path| -> Result<RecordDoc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        RecordDoc::parse(&text)
            .map_err(|e| anyhow!("{}: invalid schema: {e}", path.display()))
    };
    let old = load(old_path)?;
    let new = load(new_path)?;
    let report = diff_docs(&old, &new, cfg);
    let mut rendered = report.render(
        &old_path.display().to_string(),
        &new_path.display().to_string(),
    );
    if old.env.host != new.env.host {
        rendered.push_str(&format!(
            "\nnote: hosts differ ({} vs {}) — wall-clock rows are not \
             comparable across machines.\n",
            old.env.host, new.env.host
        ));
    }
    Ok((report, rendered))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::record::Env;

    fn doc(provenance: Provenance, tok_per_s: f64, ttft: f64) -> RecordDoc {
        let mut d = RecordDoc::new(
            "packing",
            Env {
                provenance,
                host: "h".into(),
                artifact_hash: "x".into(),
                created_by: "test".into(),
                note: None,
            },
        );
        let keys = [("method", "sps:k=7".to_string()), ("pack", "4".into())];
        d.push("tok_per_s", tok_per_s, "tok/s", 16, 7, &keys);
        d.push("ttft_ms_p50", ttft, "ms", 16, 7, &keys);
        d.push("tau", 2.8, "tok/cycle", 16, 7, &keys);
        d
    }

    #[test]
    fn self_diff_passes_with_unit_ratios() {
        let d = doc(Provenance::Measured, 650.0, 9.0);
        let r = diff_docs(&d, &d, &DiffCfg::default());
        assert!(!r.regressed());
        assert!(r.added.is_empty() && r.removed.is_empty());
        for row in &r.rows {
            assert_eq!(row.ratio, 1.0, "{}", row.key);
            assert_ne!(row.verdict, Verdict::Fail);
        }
    }

    #[test]
    fn throughput_drop_fails_and_names_the_key() {
        let old = doc(Provenance::Measured, 650.0, 9.0);
        let new = doc(Provenance::Measured, 400.0, 9.0);
        let r = diff_docs(&old, &new, &DiffCfg::default());
        assert!(r.regressed());
        let rendered = r.render("old", "new");
        assert!(
            rendered.contains("FAIL: packing/tok_per_s"),
            "{rendered}"
        );
        // the latency row stayed fine
        assert_eq!(r.failures().len(), 1);
    }

    #[test]
    fn latency_rise_fails_but_tau_never_gates() {
        let old = doc(Provenance::Measured, 650.0, 9.0);
        let mut new = doc(Provenance::Measured, 650.0, 12.0);
        new.records[2].value = 99.0; // tau explodes — informational
        let r = diff_docs(&old, &new, &DiffCfg::default());
        assert_eq!(r.failures().len(), 1);
        assert!(r.failures()[0].key.contains("ttft_ms_p50"));
    }

    #[test]
    fn estimated_provenance_softens_failures_to_warnings() {
        let old = doc(Provenance::Estimated, 650.0, 9.0);
        let new = doc(Provenance::Measured, 300.0, 30.0);
        let r = diff_docs(&old, &new, &DiffCfg::default());
        assert!(r.soft);
        assert!(!r.regressed());
        assert_eq!(r.warnings().len(), 2);
        let rendered = r.render("old", "new");
        assert!(rendered.contains("WARN"), "{rendered}");
        assert!(rendered.contains("soft gate"), "{rendered}");
    }

    #[test]
    fn tiny_samples_widen_the_tolerance() {
        let mut old = doc(Provenance::Measured, 650.0, 9.0);
        let mut new = doc(Provenance::Measured, 520.0, 9.0);
        // 20% drop: fails at the 15% base threshold with full samples...
        let r = diff_docs(&old, &new, &DiffCfg::default());
        assert!(r.regressed());
        // ...passes the widened 30% band when samples are tiny
        for d in [&mut old, &mut new] {
            for rec in &mut d.records {
                rec.n = 2;
            }
        }
        let r = diff_docs(&old, &new, &DiffCfg::default());
        assert!(!r.regressed());
    }

    #[test]
    fn unmatched_keys_are_reported_as_added_and_removed() {
        let old = doc(Provenance::Measured, 650.0, 9.0);
        let mut new = doc(Provenance::Measured, 650.0, 9.0);
        new.records.remove(1); // drop the latency row
        let keys = [("method", "sps:k=7".to_string()), ("pack", "8".into())];
        new.push("tok_per_s", 800.0, "tok/s", 16, 7, &keys);
        let r = diff_docs(&old, &new, &DiffCfg::default());
        assert_eq!(r.removed.len(), 1);
        assert_eq!(r.added.len(), 1);
        assert!(r.removed[0].contains("ttft_ms_p50"));
        assert!(r.added[0].contains("pack=8"));
        let rendered = r.render("old", "new");
        assert!(rendered.contains("| removed |"), "{rendered}");
        assert!(rendered.contains("| added |"), "{rendered}");
    }

    #[test]
    fn metric_rules_keep_their_table_semantics() {
        // first-match-wins ordering: p99 before the generic *_ms rows
        assert_eq!(metric_rule("tok_per_s"), (Direction::Higher, 0.15));
        assert_eq!(metric_rule("tok_per_s_mean"), (Direction::Higher, 0.15));
        assert_eq!(metric_rule("speedup_vs_ar"), (Direction::Higher, 0.15));
        assert_eq!(metric_rule("ttft_ms_p99"), (Direction::Lower, 0.50));
        assert_eq!(metric_rule("ttft_ms_p50"), (Direction::Lower, 0.25));
        assert_eq!(metric_rule("decode_ms"), (Direction::Lower, 0.25));
        assert_eq!(metric_rule("sim_units"), (Direction::Lower, 0.15));
        assert_eq!(
            metric_rule("device_calls_per_token"),
            (Direction::Lower, 0.15)
        );
        assert_eq!(metric_rule("tau"), (Direction::Info, 0.0));
        assert_eq!(metric_rule("err"), (Direction::Info, 0.0));
        assert_eq!(metric_rule("brand_new_metric"), (Direction::Info, 0.0));
    }

    #[test]
    fn thresholds_markdown_renders_every_rule() {
        let md = thresholds_markdown();
        assert!(md.starts_with("| metric | direction | gate |\n|---|---|---|\n"));
        // header (2 lines) + one row per rule + the informational row
        assert_eq!(md.lines().count(), 2 + RULES.len() + 1);
        assert!(md.contains("`tok_per_s*`"), "{md}");
        assert!(md.contains("`*_ms_p99`"), "{md}");
        assert!(md.contains("`*sim_units*`"), "{md}");
        assert!(md.contains("may not rise > 50% (tails are noisy)"), "{md}");
        assert!(md.contains("reported, never gates"), "{md}");
    }

    #[test]
    fn improvements_never_fail() {
        let old = doc(Provenance::Measured, 650.0, 9.0);
        let new = doc(Provenance::Measured, 2000.0, 2.0);
        let r = diff_docs(&old, &new, &DiffCfg::default());
        assert!(!r.regressed());
        assert!(r.warnings().is_empty());
    }
}
