//! Benchmark trajectory records — the one machine-readable result schema
//! every `mars bench` target emits (DESIGN.md §10).
//!
//! A **record** is one measured (or estimated) scalar: target name,
//! metric name, value, unit, sample count, seed, and the method/policy/
//! config keys that identify the wave it came from. A **document**
//! (`BENCH_<target>.json`) is a set of records plus an env/provenance
//! block (`measured` vs `estimated`, artifact hash, host) and the sweep
//! config. Records are paired across documents by
//! [`Record::key_id`] — target + metric + sorted keys — which is what
//! [`super::diff`] compares two snapshots by.
//!
//! The rendered form is canonical: sorted object keys, one record per
//! line, integers without a fractional part. Encode → parse → encode is
//! byte-identical (pinned by a property test), so committed snapshots
//! never churn under rewrites.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Value;

/// Current schema version of `BENCH_<target>.json` documents. Version 1
/// was the ad-hoc per-target shape (a bare row array + freeform `note`);
/// version 2 is the record format this module owns.
pub const SCHEMA: u64 = 2;

/// Where a document's numbers came from — the field the regression gate
/// keys its hard/soft behavior on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provenance {
    /// A real run of the emitting bench target on this host.
    Measured,
    /// Hand-derived from a cost model (e.g. a baseline authored on a box
    /// without the toolchain). Diffs against estimated numbers report
    /// regressions as warnings, never failures.
    Estimated,
}

impl Provenance {
    /// Canonical wire name (`"measured"` / `"estimated"`).
    pub fn name(self) -> &'static str {
        match self {
            Provenance::Measured => "measured",
            Provenance::Estimated => "estimated",
        }
    }

    /// Parse the wire name back.
    pub fn parse(s: &str) -> Option<Provenance> {
        match s {
            "measured" => Some(Provenance::Measured),
            "estimated" => Some(Provenance::Estimated),
            _ => None,
        }
    }
}

/// Document-level env/provenance block: every record in the document
/// shares it (one bench invocation = one host + one artifact build).
#[derive(Debug, Clone, PartialEq)]
pub struct Env {
    /// Measured run vs hand-estimated baseline.
    pub provenance: Provenance,
    /// Hostname the numbers were produced on (wall-clock metrics are not
    /// comparable across hosts; the diff table surfaces this).
    pub host: String,
    /// State-layout hash of the artifact build (`layout.hash`), or
    /// `"unknown"` for documents authored without artifacts.
    pub artifact_hash: String,
    /// The command that produced (or would refresh) the document.
    pub created_by: String,
    /// Optional freeform context (refresh instructions, caveats).
    pub note: Option<String>,
}

impl Env {
    /// Env block for a real emitter run on this host: provenance is
    /// stamped `measured`, overwriting whatever a committed estimated
    /// baseline carried once the file is refreshed.
    pub fn measured(artifact_hash: &str, created_by: &str) -> Env {
        Env {
            provenance: Provenance::Measured,
            host: host_label(),
            artifact_hash: artifact_hash.to_string(),
            created_by: created_by.to_string(),
            note: None,
        }
    }

    fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("provenance", Value::Str(self.provenance.name().into()));
        o.set("host", Value::Str(self.host.clone()));
        o.set("artifact_hash", Value::Str(self.artifact_hash.clone()));
        o.set("created_by", Value::Str(self.created_by.clone()));
        if let Some(n) = &self.note {
            o.set("note", Value::Str(n.clone()));
        }
        o
    }
}

/// One benchmark scalar, identified by target + metric + keys.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    /// Bench target that emitted it (`packing`, `batch`, `policies`,
    /// `serve`).
    pub target: String,
    /// Metric name (`tok_per_s`, `ttft_ms_p50`, ...) — drives the diff
    /// direction/threshold table ([`super::diff::metric_rule`]).
    pub metric: String,
    /// The scalar itself. Must be finite.
    pub value: f64,
    /// Unit label (`tok/s`, `ms`, `calls/tok`, ...) — documentation, not
    /// identity.
    pub unit: String,
    /// Samples behind the value (requests that finished ok in the wave).
    /// The diff gate widens its tolerance when this is small.
    pub n: usize,
    /// Workload seed the wave ran under.
    pub seed: u64,
    /// Wave identity: method/policy/config keys (`method`, `policy`,
    /// `pack`, `batch`, `task`, `scenario`, ...), all values strings.
    pub keys: BTreeMap<String, String>,
}

impl Record {
    /// Canonical pairing identity: `target/metric{k1=v1,k2=v2}` with the
    /// keys in sorted order (the map is a `BTreeMap`, so iteration is
    /// already sorted).
    pub fn key_id(&self) -> String {
        let keys = self
            .keys
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",");
        format!("{}/{}{{{}}}", self.target, self.metric, keys)
    }

    fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("target", Value::Str(self.target.clone()));
        o.set("metric", Value::Str(self.metric.clone()));
        o.set("value", Value::Num(self.value));
        o.set("unit", Value::Str(self.unit.clone()));
        o.set("n", Value::Num(self.n as f64));
        o.set("seed", Value::Num(self.seed as f64));
        let mut keys = Value::obj();
        for (k, v) in &self.keys {
            keys.set(k, Value::Str(v.clone()));
        }
        o.set("keys", keys);
        o
    }
}

/// One `BENCH_<target>.json` document: schema + env + config + records.
#[derive(Debug, Clone, PartialEq)]
pub struct RecordDoc {
    /// Bench target the document snapshots.
    pub target: String,
    /// Shared env/provenance block.
    pub env: Env,
    /// Sweep configuration (`n`, `seed`, `max_new`, `task`, ...): shared
    /// context for a human reading the file, not part of record identity.
    pub config: BTreeMap<String, Value>,
    /// The records.
    pub records: Vec<Record>,
}

impl RecordDoc {
    /// Empty document for `target` under `env`.
    pub fn new(target: &str, env: Env) -> RecordDoc {
        RecordDoc {
            target: target.to_string(),
            env,
            config: BTreeMap::new(),
            records: Vec::new(),
        }
    }

    /// Add a config entry (numbers and strings only, by convention).
    pub fn config_num(&mut self, key: &str, v: f64) {
        self.config.insert(key.to_string(), Value::Num(v));
    }

    /// Add a string config entry.
    pub fn config_str(&mut self, key: &str, v: &str) {
        self.config.insert(key.to_string(), Value::Str(v.to_string()));
    }

    /// Append one record; `keys` is the wave identity as label pairs.
    pub fn push(
        &mut self,
        metric: &str,
        value: f64,
        unit: &str,
        n: usize,
        seed: u64,
        keys: &[(&str, String)],
    ) {
        self.records.push(Record {
            target: self.target.clone(),
            metric: metric.to_string(),
            value,
            unit: unit.to_string(),
            n,
            seed,
            keys: keys
                .iter()
                .map(|(k, v)| (k.to_string(), v.clone()))
                .collect(),
        });
    }

    /// Records indexed by [`Record::key_id`] — the diff pairing map.
    /// Duplicate ids keep the last record (emitters never produce
    /// duplicates; the validator rejects them).
    pub fn by_key(&self) -> BTreeMap<String, &Record> {
        self.records.iter().map(|r| (r.key_id(), r)).collect()
    }

    /// Canonical rendering: deterministic field order, one record per
    /// line, sorted object keys. Re-rendering a parsed document
    /// reproduces the input byte-for-byte.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": {SCHEMA},\n"));
        out.push_str(&format!(
            "  \"target\": {},\n",
            Value::Str(self.target.clone()).to_string_json()
        ));
        out.push_str(&format!(
            "  \"env\": {},\n",
            self.env.to_json().to_string_json()
        ));
        if !self.config.is_empty() {
            let cfg = Value::Obj(self.config.clone());
            out.push_str(&format!(
                "  \"config\": {},\n",
                cfg.to_string_json()
            ));
        }
        out.push_str("  \"records\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str("    ");
            out.push_str(&r.to_json().to_string_json());
            if i + 1 < self.records.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse + validate a schema-2 document (the shared validator: CI,
    /// `bench diff` and the test suites all go through here).
    pub fn parse(text: &str) -> Result<RecordDoc, String> {
        let v = Value::parse(text).map_err(|e| e.to_string())?;
        validate(&v)
    }
}

/// The shared schema validator: checks a parsed JSON value against the
/// schema-2 shape and returns the typed document, or a readable error
/// naming the offending field.
pub fn validate(v: &Value) -> Result<RecordDoc, String> {
    if v.as_obj().is_none() {
        return Err("document is not a JSON object".into());
    }
    let schema = v
        .get("schema")
        .and_then(|s| s.as_f64())
        .ok_or("missing numeric 'schema'")?;
    if schema != SCHEMA as f64 {
        return Err(format!(
            "schema {schema} is not the supported schema {SCHEMA} \
             (schema-1 files predate the record format — re-run the \
             emitting bench target to refresh)"
        ));
    }
    let target = non_empty_str(v, "target")?;
    let env_v = v.get("env").ok_or("missing 'env' block")?;
    if env_v.as_obj().is_none() {
        return Err("'env' is not an object".into());
    }
    let prov_s = non_empty_str(env_v, "env.provenance")?;
    let provenance = Provenance::parse(&prov_s).ok_or_else(|| {
        format!(
            "env.provenance {prov_s:?} is not \"measured\" or \"estimated\""
        )
    })?;
    let env = Env {
        provenance,
        host: non_empty_str(env_v, "env.host")?,
        artifact_hash: non_empty_str(env_v, "env.artifact_hash")?,
        created_by: env_v
            .get("created_by")
            .and_then(|s| s.as_str())
            .unwrap_or("")
            .to_string(),
        note: env_v
            .get("note")
            .and_then(|s| s.as_str())
            .map(|s| s.to_string()),
    };
    let config = match v.get("config") {
        None => BTreeMap::new(),
        Some(c) => c
            .as_obj()
            .cloned()
            .ok_or("'config' is not an object")?,
    };
    let arr = v
        .get("records")
        .and_then(|r| r.as_arr())
        .ok_or("missing 'records' array")?;
    if arr.is_empty() {
        return Err("'records' is empty — an emitter produced no rows".into());
    }
    let mut records = Vec::with_capacity(arr.len());
    let mut seen = std::collections::BTreeSet::new();
    for (i, rv) in arr.iter().enumerate() {
        let r = validate_record(rv)
            .map_err(|e| format!("records[{i}]: {e}"))?;
        if r.target != target {
            return Err(format!(
                "records[{i}]: target {:?} != document target {target:?}",
                r.target
            ));
        }
        if !seen.insert(r.key_id()) {
            return Err(format!(
                "records[{i}]: duplicate key {}",
                r.key_id()
            ));
        }
        records.push(r);
    }
    // extra top-level fields are ignored so old readers survive
    // additive schema evolution
    Ok(RecordDoc { target, env, config, records })
}

fn validate_record(v: &Value) -> Result<Record, String> {
    if v.as_obj().is_none() {
        return Err("record is not an object".into());
    }
    let value = v
        .get("value")
        .and_then(|x| x.as_f64())
        .ok_or("missing numeric 'value'")?;
    if !value.is_finite() {
        return Err(format!("'value' {value} is not finite"));
    }
    let n = v
        .get("n")
        .and_then(|x| x.as_f64())
        .ok_or("missing numeric 'n' (sample count)")?;
    if n < 0.0 || n.fract() != 0.0 {
        return Err(format!("'n' {n} is not a non-negative integer"));
    }
    let seed = v
        .get("seed")
        .and_then(|x| x.as_f64())
        .ok_or("missing numeric 'seed'")?;
    let mut keys = BTreeMap::new();
    if let Some(kv) = v.get("keys") {
        let m = kv.as_obj().ok_or("'keys' is not an object")?;
        for (k, val) in m {
            // numbers tolerated on input, normalized to the string form
            // the emitters write
            let s = match val {
                Value::Str(s) => s.clone(),
                Value::Num(_) => val.to_string_json(),
                _ => {
                    return Err(format!(
                        "keys.{k} is neither a string nor a number"
                    ))
                }
            };
            keys.insert(k.clone(), s);
        }
    } else {
        return Err("missing 'keys' object".into());
    }
    Ok(Record {
        target: non_empty_str(v, "target")?,
        metric: non_empty_str(v, "metric")?,
        value,
        unit: v
            .get("unit")
            .and_then(|s| s.as_str())
            .ok_or("missing string 'unit'")?
            .to_string(),
        n: n as usize,
        seed: seed as u64,
        keys,
    })
}

fn non_empty_str(v: &Value, field: &str) -> Result<String, String> {
    // nested field names ("env.provenance") index the leaf only — the
    // caller already holds the right object
    let leaf = field.rsplit('.').next().unwrap_or(field);
    let s = v
        .get(leaf)
        .and_then(|s| s.as_str())
        .ok_or_else(|| format!("missing string '{field}'"))?;
    if s.is_empty() {
        return Err(format!("'{field}' is empty"));
    }
    Ok(s.to_string())
}

/// Write a document to `path` in the canonical rendering, creating any
/// missing parent directories (the `results/`-style dirs are not assumed
/// to exist).
pub fn write_doc(path: &Path, doc: &RecordDoc) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent).with_context(|| {
                format!("creating {}", parent.display())
            })?;
        }
    }
    fs::write(path, doc.render())
        .with_context(|| format!("writing {}", path.display()))
}

/// Best-effort hostname for the env block (`$HOSTNAME`, then the kernel
/// gauge, then `"unknown"`). Wall-clock metrics are host-bound; the diff
/// report prints both hosts so cross-host comparisons are visibly so.
pub fn host_label() -> String {
    if let Ok(h) = std::env::var("HOSTNAME") {
        if !h.is_empty() {
            return h;
        }
    }
    if let Ok(h) = fs::read_to_string("/proc/sys/kernel/hostname") {
        let h = h.trim();
        if !h.is_empty() {
            return h.to_string();
        }
    }
    "unknown".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_doc() -> RecordDoc {
        let mut doc = RecordDoc::new(
            "packing",
            Env {
                provenance: Provenance::Measured,
                host: "testhost".into(),
                artifact_hash: "abc123".into(),
                created_by: "mars bench packing --n 2".into(),
                note: Some("unit fixture".into()),
            },
        );
        doc.config_str("task", "sum");
        doc.config_num("n", 2.0);
        let keys = [
            ("method", "sps:k=7".to_string()),
            ("policy", "mars:0.9".to_string()),
            ("pack", "4".to_string()),
        ];
        doc.push("tok_per_s", 690.5, "tok/s", 2, 7, &keys);
        doc.push("ttft_ms_p50", 9.0, "ms", 2, 7, &keys);
        doc
    }

    #[test]
    fn render_parse_round_trip_is_byte_identical() {
        let doc = sample_doc();
        let text = doc.render();
        let back = RecordDoc::parse(&text).expect("parses");
        assert_eq!(back, doc);
        assert_eq!(back.render(), text);
    }

    #[test]
    fn key_id_is_sorted_and_total() {
        let doc = sample_doc();
        let id = doc.records[0].key_id();
        assert_eq!(
            id,
            "packing/tok_per_s{method=sps:k=7,pack=4,policy=mars:0.9}"
        );
        assert_eq!(doc.by_key().len(), doc.records.len());
    }

    #[test]
    fn validator_names_the_offending_field() {
        let doc = sample_doc();
        let mut v = Value::parse(&doc.render()).unwrap();
        v.set("schema", Value::Num(1.0));
        let err = validate(&v).unwrap_err();
        assert!(err.contains("schema"), "{err}");

        let mut v = Value::parse(&doc.render()).unwrap();
        if let Value::Obj(m) = &mut v {
            m.remove("env");
        }
        let err = validate(&v).unwrap_err();
        assert!(err.contains("env"), "{err}");

        let mut v = Value::parse(&doc.render()).unwrap();
        if let Some(Value::Arr(a)) = match &mut v {
            Value::Obj(m) => m.get_mut("records"),
            _ => None,
        } {
            a[1].set("value", Value::Str("fast".into()));
        }
        let err = validate(&v).unwrap_err();
        assert!(err.contains("records[1]"), "{err}");
    }

    #[test]
    fn validator_rejects_duplicate_keys_and_empty_records() {
        let mut doc = sample_doc();
        let dup = doc.records[0].clone();
        doc.records.push(dup);
        let err = RecordDoc::parse(&doc.render()).unwrap_err();
        assert!(err.contains("duplicate"), "{err}");

        let mut doc = sample_doc();
        doc.records.clear();
        let err = RecordDoc::parse(&doc.render()).unwrap_err();
        assert!(err.contains("empty"), "{err}");
    }

    #[test]
    fn numeric_keys_normalize_to_strings() {
        let doc = sample_doc();
        let mut v = Value::parse(&doc.render()).unwrap();
        if let Some(Value::Arr(a)) = match &mut v {
            Value::Obj(m) => m.get_mut("records"),
            _ => None,
        } {
            if let Some(keys) = match &mut a[0] {
                Value::Obj(m) => m.get_mut("keys"),
                _ => None,
            } {
                keys.set("pack", Value::Num(4.0));
            }
        }
        let back = validate(&v).expect("validates");
        assert_eq!(back.records[0].keys["pack"], "4");
    }

    #[test]
    fn write_doc_creates_missing_directories() {
        let dir = std::env::temp_dir().join(format!(
            "mars-record-test-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let path = dir.join("deep/nested/BENCH_packing.json");
        assert!(!dir.exists());
        let doc = sample_doc();
        write_doc(&path, &doc).expect("writes into missing dir");
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(RecordDoc::parse(&text).unwrap(), doc);
        let _ = fs::remove_dir_all(&dir);
    }
}
