//! `mars bench serve` — open-loop serving benchmark (DESIGN.md §3).
//!
//! Starts a router + TCP server in-process, then drives a Poisson
//! arrival process over N real client connections (streaming requests,
//! pipelined per connection) and reports the serving percentiles the
//! speculative-decoding surveys compare methods by:
//!
//! * **TTFT** — send → first delta line (queue + prefill + first round);
//! * **TPOT** — (last event − first delta) / (tokens − 1);
//! * **throughput** — committed tokens / wall-clock, requests / second.
//!
//! The sweep axes are the drafting method (`--methods`, descriptor
//! grammar) and the verification policy (`--policies`): each method ×
//! policy combination gets its own wave of `n` requests at the same
//! arrival rate, so the table isolates what the drafter and the accept
//! rule each do to tail latency under load.
//! Client-side measurements can be cross-checked against the server's
//! own `{"cmd": "metrics"}` snapshot (TTFT there is measured
//! submit → first commit, without the socket hop).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::router::{Router, RouterPolicy};
use crate::coordinator::scheduler::exp_arrival_gap;
use crate::coordinator::server;
use crate::datasets::{dataset, Task};
use crate::engine::SpecMethod;
use crate::util::json::Value;
use crate::util::prng::Rng;
use crate::util::stats::Summary;
use crate::verify::VerifyPolicy;

/// Configuration for one `mars bench serve` run.
pub struct ServeBenchCfg {
    /// Compiled-artifact directory (same as `mars serve --artifacts`).
    pub artifact_dir: PathBuf,
    /// Engine replicas behind the router.
    pub replicas: usize,
    /// Concurrent sequences interleaved per replica.
    pub slots: usize,
    /// Client TCP connections the load is spread over (round-robin).
    pub connections: usize,
    /// Requests per wave.
    pub n_requests: usize,
    /// Open-loop arrival rate, requests/second (Poisson).
    pub rate_per_s: f64,
    /// `max_new` per request.
    pub max_new: usize,
    /// Workload seed (prompts + arrival gaps).
    pub seed: u64,
    /// Drafting-method descriptors swept (one wave per method × policy).
    pub methods: Vec<SpecMethod>,
    /// Verification policies swept (one wave per method × policy).
    pub policies: Vec<VerifyPolicy>,
    /// Where the rendered table lands (`results/serve.md`).
    pub out_dir: PathBuf,
}

/// Client-side record of one request's lifecycle.
#[derive(Debug, Clone)]
struct ReqProbe {
    sent_at: Instant,
    first_delta: Option<Instant>,
    last_event: Option<Instant>,
    tokens: usize,
    done: bool,
    ok: bool,
}

type ProbeMap = Arc<Mutex<HashMap<u64, ReqProbe>>>;

/// One benchmark client connection: a writer plus a reader thread that
/// demultiplexes delta/reply lines by id into the shared probe map.
struct BenchConn {
    writer: TcpStream,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl BenchConn {
    fn connect(addr: &str, probes: ProbeMap) -> Result<BenchConn> {
        let writer = TcpStream::connect(addr)
            .with_context(|| format!("connecting {addr}"))?;
        let read_half = writer.try_clone()?;
        let reader = std::thread::Builder::new()
            .name("mars-bench-read".into())
            .spawn(move || {
                let buf = BufReader::new(read_half);
                for line in buf.lines() {
                    let Ok(line) = line else { break };
                    let Ok(v) = Value::parse(&line) else { continue };
                    let Some(id) =
                        v.get("id").and_then(|x| x.as_f64()).map(|f| f as u64)
                    else {
                        continue;
                    };
                    let now = Instant::now();
                    let mut g = probes.lock().unwrap();
                    let Some(p) = g.get_mut(&id) else { continue };
                    if v.get("delta").is_some()
                        && v.get("done").and_then(|b| b.as_bool())
                            == Some(false)
                    {
                        if p.first_delta.is_none() {
                            p.first_delta = Some(now);
                        }
                        p.last_event = Some(now);
                        if let Some(t) =
                            v.get("tokens").and_then(|t| t.as_usize())
                        {
                            p.tokens = t;
                        }
                    } else if v.get("ok").is_some() {
                        p.done = true;
                        p.ok = v.get("ok").and_then(|b| b.as_bool())
                            == Some(true);
                        p.last_event = Some(now);
                        if let Some(t) =
                            v.get("tokens").and_then(|t| t.as_usize())
                        {
                            p.tokens = t;
                        }
                    }
                }
            })?;
        Ok(BenchConn { writer, reader: Some(reader) })
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.writer, "{line}")?;
        Ok(())
    }
}

impl Drop for BenchConn {
    fn drop(&mut self) {
        let _ = self.writer.shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Per-wave (method × policy) outcome row.
struct PolicyRow {
    label: String,
    ok: usize,
    err: usize,
    ttft_ms: Summary,
    tpot_ms: Summary,
    tok_per_s: f64,
    req_per_s: f64,
}

/// Run the full serving benchmark: one open-loop wave per method ×
/// policy combination against a live in-process server, rendered into
/// the standard bench table machinery (`results/serve.md`).
pub fn run(cfg: &ServeBenchCfg) -> Result<()> {
    if cfg.connections == 0 || cfg.n_requests == 0 {
        bail!("bench serve needs --connections >= 1 and --n >= 1");
    }
    if cfg.methods.is_empty() || cfg.policies.is_empty() {
        bail!("bench serve needs at least one --methods / --policies entry");
    }
    println!(
        "starting {} replica(s) x {} slot(s) for bench serve...",
        cfg.replicas.max(1),
        cfg.slots
    );
    let router = Arc::new(Router::start(
        &cfg.artifact_dir,
        cfg.replicas,
        cfg.slots,
        false,
        RouterPolicy::LeastLoaded,
    )?);
    let handle = server::serve(router.clone(), "127.0.0.1:0")?;
    let addr = handle.addr.to_string();

    let mut rows = Vec::new();
    let waves: Vec<(SpecMethod, VerifyPolicy)> = cfg
        .methods
        .iter()
        .flat_map(|&m| cfg.policies.iter().map(move |&p| (m, p)))
        .collect();
    for (wi, &(method, policy)) in waves.iter().enumerate() {
        let row = drive_wave(cfg, &addr, wi, method, policy)?;
        println!(
            "  {}: {} ok / {} err, ttft p50 {:.0} ms, tpot p50 {:.2} ms, \
             {:.1} tok/s",
            row.label,
            row.ok,
            row.err,
            row.ttft_ms.p50(),
            row.tpot_ms.p50(),
            row.tok_per_s
        );
        rows.push(row);
    }

    let table = render_table(cfg, &rows);
    println!("{table}");
    let _ = std::fs::create_dir_all(&cfg.out_dir);
    let path = cfg.out_dir.join("serve.md");
    std::fs::write(&path, &table)
        .with_context(|| format!("writing {}", path.display()))?;
    eprintln!("[written {}]", path.display());
    eprintln!(
        "server metrics: {}",
        router.metrics.snapshot_json().to_string_json()
    );
    Ok(())
}

/// Drive one method × policy open-loop wave over `cfg.connections`
/// connections.
fn drive_wave(
    cfg: &ServeBenchCfg,
    addr: &str,
    wave_idx: usize,
    method: SpecMethod,
    policy: VerifyPolicy,
) -> Result<PolicyRow> {
    let probes: ProbeMap = Arc::new(Mutex::new(HashMap::new()));
    let mut conns = Vec::new();
    for _ in 0..cfg.connections {
        conns.push(BenchConn::connect(addr, probes.clone())?);
    }
    let mut rng = Rng::new(cfg.seed.wrapping_add(wave_idx as u64 * 7919));
    let tasks = Task::all();
    let wave_started = Instant::now();
    let mut ids = Vec::new();
    for i in 0..cfg.n_requests {
        let id = (wave_idx as u64 + 1) * 100_000 + i as u64 + 1;
        let task = tasks[i % tasks.len()];
        let ex = &dataset(task, 1, cfg.seed.wrapping_add(i as u64))[0];
        let mut o = Value::obj();
        o.set("id", Value::Num(id as f64));
        o.set("prompt", Value::Str(ex.prompt.clone()));
        o.set("stream", Value::Bool(true));
        o.set("method", Value::Str(method.label()));
        o.set("policy", Value::Str(policy.label()));
        o.set("max_new", Value::Num(cfg.max_new as f64));
        o.set("seed", Value::Num(i as f64));
        probes.lock().unwrap().insert(
            id,
            ReqProbe {
                sent_at: Instant::now(),
                first_delta: None,
                last_event: None,
                tokens: 0,
                done: false,
                ok: false,
            },
        );
        conns[i % conns.len()].send_line(&o.to_string_json())?;
        ids.push(id);
        let gap = exp_arrival_gap(&mut rng, cfg.rate_per_s);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap));
        }
    }

    // wait for every request of the wave (bounded: the workload is small
    // and the replicas drain monotonically)
    let deadline = Instant::now() + Duration::from_secs(600);
    loop {
        {
            let g = probes.lock().unwrap();
            if ids.iter().all(|id| g.get(id).is_some_and(|p| p.done)) {
                break;
            }
        }
        if Instant::now() > deadline {
            bail!("bench serve wave timed out after 600 s");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let wall = wave_started.elapsed().as_secs_f64().max(1e-9);

    let g = probes.lock().unwrap();
    let mut row = PolicyRow {
        label: format!("{} / {}", method.label(), policy.label()),
        ok: 0,
        err: 0,
        ttft_ms: Summary::new(),
        tpot_ms: Summary::new(),
        tok_per_s: 0.0,
        req_per_s: 0.0,
    };
    let mut tokens_total = 0usize;
    for id in &ids {
        let p = &g[id];
        if !p.ok {
            row.err += 1;
            continue;
        }
        row.ok += 1;
        tokens_total += p.tokens;
        if let Some(first) = p.first_delta {
            row.ttft_ms
                .push(first.duration_since(p.sent_at).as_secs_f64() * 1e3);
            if p.tokens > 1 {
                if let Some(last) = p.last_event {
                    let span = last.duration_since(first).as_secs_f64();
                    row.tpot_ms
                        .push(span * 1e3 / (p.tokens - 1) as f64);
                }
            }
        }
    }
    row.tok_per_s = tokens_total as f64 / wall;
    row.req_per_s = row.ok as f64 / wall;
    Ok(row)
}

fn render_table(cfg: &ServeBenchCfg, rows: &[PolicyRow]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "## Serve — open-loop load, {} conns, {:.1} req/s Poisson, \
         n={} per wave, max_new={}\n",
        cfg.connections, cfg.rate_per_s, cfg.n_requests, cfg.max_new
    );
    let _ = writeln!(
        out,
        "| Method / Policy | ok/err | TTFT p50 (ms) | TTFT p99 (ms) | \
         TPOT p50 (ms) | TPOT p99 (ms) | tok/s | req/s |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {}/{} | {:.0} | {:.0} | {:.2} | {:.2} | {:.1} | {:.2} |",
            r.label,
            r.ok,
            r.err,
            r.ttft_ms.p50(),
            r.ttft_ms.p99(),
            r.tpot_ms.p50(),
            r.tpot_ms.p99(),
            r.tok_per_s,
            r.req_per_s
        );
    }
    let _ = writeln!(
        out,
        "\nTTFT = send -> first streamed delta (client-side, includes the \
         socket hop); TPOT = (last event - first delta)/(tokens-1). \
         Wall-clock on this substrate — compare shapes across rows \
         (method vs method, policy vs policy), not absolute numbers \
         against the paper (see BENCHMARKS.md)."
    );
    out
}
