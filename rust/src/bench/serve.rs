//! `mars bench serve` — open-loop serving benchmark (DESIGN.md §3).
//!
//! Starts a router + TCP server in-process, then drives a Poisson
//! arrival process over N real client connections (streaming requests,
//! pipelined per connection) and reports the serving percentiles the
//! speculative-decoding surveys compare methods by:
//!
//! * **TTFT** — send → first delta line (queue + prefill + first round);
//! * **TPOT** — (last event − first delta) / (tokens − 1);
//! * **throughput** — committed tokens / wall-clock, requests / second.
//!
//! Two scenarios share the harness (`--scenario`):
//!
//! * **sweep** (default) — the drafting method (`--methods`, descriptor
//!   grammar) × verification policy (`--policies`) grid: each
//!   combination gets its own wave of `n` requests at the same arrival
//!   rate, so the table isolates what the drafter and the accept rule
//!   each do to tail latency under load.
//! * **chat** — `n` multi-turn conversations over shared system prompts
//!   ([`crate::datasets::chat_conversations`]): conversations arrive
//!   open-loop, each turn's prompt extends the previous turn + answer
//!   byte-for-byte, and the same workload runs twice — prefix cache on
//!   vs off (DESIGN.md §8) — reporting TTFT/TPOT plus the prefill cost
//!   of follow-up turns in wall-clock *and* simclock units
//!   ([`super::simclock::prefill_units`]).
//!
//! Client-side measurements can be cross-checked against the server's
//! own `{"cmd": "metrics"}` snapshot (TTFT there is measured
//! submit → first commit, without the socket hop; the `"cache"` object
//! carries the server-side hit-rate/tokens-saved/bytes-resident gauges).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::record;
use crate::cache::CacheConfig;
use crate::coordinator::router::{Router, RouterConfig, RouterPolicy};
use crate::coordinator::scheduler::exp_arrival_gap;
use crate::coordinator::server;
use crate::datasets::{chat_conversations, dataset, Task};
use crate::engine::SpecMethod;
use crate::util::json::Value;
use crate::util::prng::Rng;
use crate::util::stats::Summary;
use crate::verify::VerifyPolicy;

/// Cap on `--max-new` in the `chat` scenario: answers must stay short
/// enough that a whole multi-turn conversation fits the `P_MAX` prompt
/// budget of the default artifact build (see
/// `datasets::chat_conversations`).
pub const CHAT_MAX_NEW_CAP: usize = 12;

/// Hard client-side wall deadline per request: a request that has not
/// reached its terminal reply this long after being sent is abandoned
/// with the named *client wall deadline* error instead of hanging the
/// wave (and CI) forever — the failure mode a chaos wave that downs
/// every replica would otherwise hit.
pub const CLIENT_WALL_DEADLINE: Duration = Duration::from_secs(120);

/// Which workload shape `mars bench serve` drives (`--scenario`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeScenario {
    /// Method × policy grid of independent single-turn requests.
    Sweep,
    /// Multi-turn conversations over shared system prompts, run once
    /// with the prefix cache on and once off.
    Chat {
        /// User turns per conversation.
        turns: usize,
    },
}

/// Configuration for one `mars bench serve` run.
pub struct ServeBenchCfg {
    /// Compiled-artifact directory (same as `mars serve --artifacts`).
    pub artifact_dir: PathBuf,
    /// Engine replicas behind the router.
    pub replicas: usize,
    /// Concurrent sequences interleaved per replica.
    pub slots: usize,
    /// Cross-sequence batch width per replica (`--batch`, DESIGN.md
    /// §9.5): > 1 engages the replicas' batched loop when the artifacts
    /// carry the `*_batch` programs; 1 keeps the interleaved loop.
    pub batch: usize,
    /// Client TCP connections the sweep scenario spreads its load over
    /// (round-robin). The `chat` scenario ignores it: each turn opens a
    /// fresh connection, like a real chat client's request cycle.
    pub connections: usize,
    /// Requests per wave (`chat`: conversations per wave).
    pub n_requests: usize,
    /// Open-loop arrival rate, requests/second (Poisson).
    pub rate_per_s: f64,
    /// `max_new` per request.
    pub max_new: usize,
    /// Workload seed (prompts + arrival gaps).
    pub seed: u64,
    /// Drafting-method descriptors swept (one wave per method × policy).
    pub methods: Vec<SpecMethod>,
    /// Verification policies swept (one wave per method × policy).
    pub policies: Vec<VerifyPolicy>,
    /// Workload shape (`sweep` grid vs multi-turn `chat`).
    pub scenario: ServeScenario,
    /// Zero the server's metrics between waves (`--reset`) via
    /// `{"cmd": "metrics", "reset": true}` (DESIGN.md §12): each wave's
    /// scraped margin/round records then cover exactly that wave instead
    /// of everything since the server came up. Off by default so the
    /// end-of-run `server metrics` line still shows run totals.
    pub reset: bool,
    /// Deterministic fault-injection plan (`--fault-plan`, DESIGN.md
    /// §13) installed on every replica — chaos benchmarking: measures
    /// the serving percentiles *under* injected dispatch faults,
    /// latency, and rebuild failures.
    pub fault: Option<crate::fault::FaultSpec>,
    /// Server-side default per-request wall budget (`--deadline-ms`);
    /// also echoed on each benchmark request as `"deadline_ms"` so the
    /// wire path is exercised, not just the server default.
    pub deadline_ms: Option<u64>,
    /// Queue-depth shedding threshold (`--shed-above`): past it new
    /// requests get `{"busy": true}` replies, which the wave counts as
    /// errors.
    pub shed_above: Option<usize>,
    /// Per-replica prefix-cache budget (`--cache-mb`) for the `chat`
    /// scenario's cache-on wave. The sweep scenario always runs cache-off
    /// so every wave's prefills are uniformly cold and rows compare.
    pub cache_mb: usize,
    /// Where the rendered table lands (`results/serve.md`).
    pub out_dir: PathBuf,
    /// Where the machine-readable `BENCH_serve.json` trajectory lands
    /// (schema-2 records, [`super::record`]).
    pub bench_dir: PathBuf,
}

/// Provenance block for a measured serve run: the artifact's layout hash
/// plus the scenario's refresh command.
fn serve_env(cfg: &ServeBenchCfg, created_by: &str) -> Result<record::Env> {
    let arts = crate::runtime::Artifacts::load(&cfg.artifact_dir)?;
    Ok(record::Env::measured(&arts.layout.hash, created_by))
}

/// Write the serve record doc to `bench_dir/BENCH_serve.json`.
fn emit_serve_records(
    cfg: &ServeBenchCfg,
    doc: &record::RecordDoc,
) -> Result<()> {
    let path = cfg.bench_dir.join(format!("BENCH_{}.json", doc.target));
    record::write_doc(&path, doc)?;
    eprintln!("[written {}]", path.display());
    Ok(())
}

/// Client-side record of one request's lifecycle.
#[derive(Debug, Clone)]
struct ReqProbe {
    sent_at: Instant,
    first_delta: Option<Instant>,
    last_event: Option<Instant>,
    tokens: usize,
    done: bool,
    ok: bool,
    /// Abandoned at [`CLIENT_WALL_DEADLINE`] without a terminal reply.
    timed_out: bool,
}

type ProbeMap = Arc<Mutex<HashMap<u64, ReqProbe>>>;

/// One benchmark client connection: a writer plus a reader thread that
/// demultiplexes delta/reply lines by id into the shared probe map.
struct BenchConn {
    writer: TcpStream,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl BenchConn {
    fn connect(addr: &str, probes: ProbeMap) -> Result<BenchConn> {
        let writer = TcpStream::connect(addr)
            .with_context(|| format!("connecting {addr}"))?;
        let read_half = writer.try_clone()?;
        let reader = std::thread::Builder::new()
            .name("mars-bench-read".into())
            .spawn(move || {
                let buf = BufReader::new(read_half);
                for line in buf.lines() {
                    let Ok(line) = line else { break };
                    let Ok(v) = Value::parse(&line) else { continue };
                    let Some(id) =
                        v.get("id").and_then(|x| x.as_f64()).map(|f| f as u64)
                    else {
                        continue;
                    };
                    let now = Instant::now();
                    let mut g = probes.lock().unwrap();
                    let Some(p) = g.get_mut(&id) else { continue };
                    if v.get("delta").is_some()
                        && v.get("done").and_then(|b| b.as_bool())
                            == Some(false)
                    {
                        if p.first_delta.is_none() {
                            p.first_delta = Some(now);
                        }
                        p.last_event = Some(now);
                        if let Some(t) =
                            v.get("tokens").and_then(|t| t.as_usize())
                        {
                            p.tokens = t;
                        }
                    } else if v.get("ok").is_some() {
                        p.done = true;
                        p.ok = v.get("ok").and_then(|b| b.as_bool())
                            == Some(true);
                        p.last_event = Some(now);
                        if let Some(t) =
                            v.get("tokens").and_then(|t| t.as_usize())
                        {
                            p.tokens = t;
                        }
                    }
                }
            })?;
        Ok(BenchConn { writer, reader: Some(reader) })
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.writer, "{line}")?;
        Ok(())
    }
}

impl Drop for BenchConn {
    fn drop(&mut self) {
        let _ = self.writer.shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Per-wave (method × policy) outcome row.
struct PolicyRow {
    label: String,
    method: SpecMethod,
    policy: VerifyPolicy,
    ok: usize,
    err: usize,
    /// Of `err`: requests abandoned at [`CLIENT_WALL_DEADLINE`] with no
    /// terminal reply (named separately so a wedged server is
    /// distinguishable from server-reported failures).
    client_timeouts: usize,
    ttft_ms: Summary,
    tpot_ms: Summary,
    tok_per_s: f64,
    req_per_s: f64,
    /// Server-side aggregates scraped post-wave over the `metrics` RPC
    /// (DESIGN.md §12); `None` when the wave produced no such samples.
    scrape: WaveScrape,
}

/// Margin/round aggregates lifted from one wave's `{"cmd": "metrics"}`
/// snapshot.
#[derive(Debug, Default, Clone, Copy)]
struct WaveScrape {
    /// p50 of the z2/z1 margin ratio over *relaxed* acceptances for the
    /// wave's policy × method (the MARS decisive-margin headline).
    margin_relaxed_p50: Option<f64>,
    /// Relaxed acceptances / all verify decisions for the wave's
    /// policy × method.
    relaxed_share: Option<f64>,
    /// p50 of the per-round decode wall time across traced rounds.
    round_wall_ms_p50: Option<f64>,
}

/// Scrape the server's post-wave snapshot over the same wire RPC a real
/// scraper would use, optionally zeroing the counters for the next wave
/// (`--reset`), and lift the wave's margin/round aggregates out of it.
fn scrape_wave(
    addr: &str,
    method: SpecMethod,
    policy: VerifyPolicy,
    reset: bool,
) -> Result<WaveScrape> {
    let req = if reset {
        r#"{"cmd": "metrics", "reset": true}"#
    } else {
        r#"{"cmd": "metrics"}"#
    };
    let snap = server::client_roundtrip(addr, req)?;
    let margin = |outcome: &str, field: &str| -> Option<f64> {
        snap.path(&["margin", policy.name(), method.name(), outcome, field])
            .and_then(|v| v.as_f64())
    };
    let counts = (
        margin("exact", "count"),
        margin("relaxed", "count"),
        margin("reject", "count"),
    );
    let relaxed_share = match counts {
        (Some(e), Some(r), Some(j)) if e + r + j > 0.0 => {
            Some(r / (e + r + j))
        }
        _ => None,
    };
    Ok(WaveScrape {
        margin_relaxed_p50: margin("relaxed", "p50").filter(|_| {
            // an empty relaxed histogram answers 0.0 — don't record a
            // fake margin when the policy never fired a relaxation
            margin("relaxed", "count").unwrap_or(0.0) > 0.0
        }),
        relaxed_share,
        round_wall_ms_p50: snap
            .path(&["rounds", "wall_ms_p50"])
            .and_then(|v| v.as_f64()),
    })
}

/// Run the serving benchmark for the configured scenario, rendered into
/// the standard bench table machinery (`results/serve.md`).
pub fn run(cfg: &ServeBenchCfg) -> Result<()> {
    if cfg.connections == 0 || cfg.n_requests == 0 {
        bail!("bench serve needs --connections >= 1 and --n >= 1");
    }
    match cfg.scenario {
        ServeScenario::Sweep => run_sweep(cfg),
        ServeScenario::Chat { turns } => run_chat(cfg, turns),
    }
}

/// The method × policy grid: one open-loop wave per combination against
/// a live in-process server.
fn run_sweep(cfg: &ServeBenchCfg) -> Result<()> {
    if cfg.methods.is_empty() || cfg.policies.is_empty() {
        bail!("bench serve needs at least one --methods / --policies entry");
    }
    println!(
        "starting {} replica(s) x {} slot(s){} for bench serve...",
        cfg.replicas.max(1),
        cfg.slots,
        if cfg.batch > 1 {
            format!(", batch={}", cfg.batch)
        } else {
            String::new()
        }
    );
    // prefix cache OFF: every wave replays the same seeded prompts, so a
    // shared warm cache would hand later waves full-prompt hits and skew
    // the cross-wave TTFT comparison the sweep table exists for
    let mut rcfg = RouterConfig::new(&cfg.artifact_dir);
    rcfg.replicas = cfg.replicas;
    rcfg.slots = cfg.slots;
    rcfg.policy = RouterPolicy::LeastLoaded;
    rcfg.cache = CacheConfig::disabled();
    rcfg.batch = cfg.batch.max(1);
    rcfg.fault = cfg.fault.clone();
    rcfg.deadline_ms = cfg.deadline_ms;
    rcfg.shed_above = cfg.shed_above;
    let router = Arc::new(Router::start(rcfg)?);
    let handle = server::serve(router.clone(), "127.0.0.1:0")?;
    let addr = handle.addr.to_string();

    let mut rows = Vec::new();
    let waves: Vec<(SpecMethod, VerifyPolicy)> = cfg
        .methods
        .iter()
        .flat_map(|&m| cfg.policies.iter().map(move |&p| (m, p)))
        .collect();
    for (wi, &(method, policy)) in waves.iter().enumerate() {
        let mut row = drive_wave(cfg, &addr, wi, method, policy)?;
        row.scrape = scrape_wave(&addr, method, policy, cfg.reset)?;
        println!(
            "  {}: {} ok / {} err, ttft p50 {:.0} ms, tpot p50 {:.2} ms, \
             {:.1} tok/s",
            row.label,
            row.ok,
            row.err,
            row.ttft_ms.p50(),
            row.tpot_ms.p50(),
            row.tok_per_s
        );
        if row.client_timeouts > 0 {
            eprintln!(
                "  warning: {} request(s) hit the {} s client wall \
                 deadline without a terminal reply",
                row.client_timeouts,
                CLIENT_WALL_DEADLINE.as_secs()
            );
        }
        rows.push(row);
    }

    let table = render_table(cfg, &rows);
    println!("{table}");
    super::emit_md(&cfg.out_dir, "serve", &table)?;
    eprintln!(
        "server metrics: {}",
        router.metrics.snapshot_json().to_string_json()
    );

    // machine-readable trajectory for PR-to-PR diffing (`bench diff`)
    let mut doc = record::RecordDoc::new(
        "serve",
        serve_env(cfg, "mars bench serve --scenario sweep")?,
    );
    doc.config_num("n", cfg.n_requests as f64);
    doc.config_num("seed", cfg.seed as f64);
    doc.config_num("max_new", cfg.max_new as f64);
    doc.config_num("rate_per_s", cfg.rate_per_s);
    doc.config_num("connections", cfg.connections as f64);
    for r in &rows {
        let keys = [
            ("scenario", "sweep".to_string()),
            ("method", r.method.label()),
            ("policy", r.policy.label()),
        ];
        let mut push = |metric: &str, value: f64, unit: &str| {
            doc.push(metric, value, unit, r.ok, cfg.seed, &keys);
        };
        push("ttft_ms_p50", r.ttft_ms.p50(), "ms");
        push("ttft_ms_p99", r.ttft_ms.p99(), "ms");
        push("tpot_ms_p50", r.tpot_ms.p50(), "ms");
        push("tpot_ms_p99", r.tpot_ms.p99(), "ms");
        push("tok_per_s", r.tok_per_s, "tok/s");
        push("req_per_s", r.req_per_s, "req/s");
        push("err", r.err as f64, "count");
        if r.client_timeouts > 0 {
            push("client_timeouts", r.client_timeouts as f64, "count");
        }
        // server-side margin/round aggregates (DESIGN.md §12) — present
        // only when the wave produced the underlying samples, so the
        // record set stays stable under `bench diff` self-pairing
        if let Some(v) = r.scrape.margin_relaxed_p50 {
            push("margin_relaxed_p50", v, "ratio");
        }
        if let Some(v) = r.scrape.relaxed_share {
            push("relaxed_share", v, "frac");
        }
        if let Some(v) = r.scrape.round_wall_ms_p50 {
            push("round_wall_ms_p50", v, "ms");
        }
    }
    emit_serve_records(cfg, &doc)?;
    Ok(())
}

/// Drive one method × policy open-loop wave over `cfg.connections`
/// connections.
fn drive_wave(
    cfg: &ServeBenchCfg,
    addr: &str,
    wave_idx: usize,
    method: SpecMethod,
    policy: VerifyPolicy,
) -> Result<PolicyRow> {
    let probes: ProbeMap = Arc::new(Mutex::new(HashMap::new()));
    let mut conns = Vec::new();
    for _ in 0..cfg.connections {
        conns.push(BenchConn::connect(addr, probes.clone())?);
    }
    let mut rng = Rng::new(cfg.seed.wrapping_add(wave_idx as u64 * 7919));
    let tasks = Task::all();
    let wave_started = Instant::now();
    let mut ids = Vec::new();
    for i in 0..cfg.n_requests {
        let id = (wave_idx as u64 + 1) * 100_000 + i as u64 + 1;
        let task = tasks[i % tasks.len()];
        let ex = &dataset(task, 1, cfg.seed.wrapping_add(i as u64))[0];
        let mut o = Value::obj();
        o.set("id", Value::Num(id as f64));
        o.set("prompt", Value::Str(ex.prompt.clone()));
        o.set("stream", Value::Bool(true));
        o.set("method", Value::Str(method.label()));
        o.set("policy", Value::Str(policy.label()));
        o.set("max_new", Value::Num(cfg.max_new as f64));
        o.set("seed", Value::Num(i as f64));
        if let Some(ms) = cfg.deadline_ms {
            // exercise the wire field, not just the server-side default
            o.set("deadline_ms", Value::Num(ms as f64));
        }
        // probe rings feed the server's margin-by-outcome histograms
        // (DESIGN.md §12) that the wave scrape below turns into records
        o.set("probe", Value::Bool(true));
        probes.lock().unwrap().insert(
            id,
            ReqProbe {
                sent_at: Instant::now(),
                first_delta: None,
                last_event: None,
                tokens: 0,
                done: false,
                ok: false,
                timed_out: false,
            },
        );
        conns[i % conns.len()].send_line(&o.to_string_json())?;
        ids.push(id);
        let gap = exp_arrival_gap(&mut rng, cfg.rate_per_s);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap));
        }
    }

    // wait for every request of the wave; a request that outlives
    // CLIENT_WALL_DEADLINE is abandoned in place with the named *client
    // wall deadline* error, so a downed or wedged server bounds the
    // wave at send-time + deadline instead of hanging CI
    loop {
        let now = Instant::now();
        let mut all_done = true;
        {
            let mut g = probes.lock().unwrap();
            for id in &ids {
                let Some(p) = g.get_mut(id) else { continue };
                if p.done {
                    continue;
                }
                if now.duration_since(p.sent_at) > CLIENT_WALL_DEADLINE {
                    p.done = true;
                    p.ok = false;
                    p.timed_out = true;
                } else {
                    all_done = false;
                }
            }
        }
        if all_done {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let wall = wave_started.elapsed().as_secs_f64().max(1e-9);

    let g = probes.lock().unwrap();
    let mut row = PolicyRow {
        label: format!("{} / {}", method.label(), policy.label()),
        method,
        policy,
        ok: 0,
        err: 0,
        client_timeouts: 0,
        ttft_ms: Summary::new(),
        tpot_ms: Summary::new(),
        tok_per_s: 0.0,
        req_per_s: 0.0,
        scrape: WaveScrape::default(),
    };
    let mut tokens_total = 0usize;
    for id in &ids {
        let p = &g[id];
        if !p.ok {
            row.err += 1;
            if p.timed_out {
                row.client_timeouts += 1;
            }
            continue;
        }
        row.ok += 1;
        tokens_total += p.tokens;
        if let Some(first) = p.first_delta {
            row.ttft_ms
                .push(first.duration_since(p.sent_at).as_secs_f64() * 1e3);
            if p.tokens > 1 {
                if let Some(last) = p.last_event {
                    let span = last.duration_since(first).as_secs_f64();
                    row.tpot_ms
                        .push(span * 1e3 / (p.tokens - 1) as f64);
                }
            }
        }
    }
    row.tok_per_s = tokens_total as f64 / wall;
    row.req_per_s = row.ok as f64 / wall;
    Ok(row)
}

// ------------------------------------------------------- chat scenario ----

/// Client-side record of one conversation turn.
struct TurnProbe {
    ok: bool,
    /// send → first streamed delta, ms
    ttft_ms: Option<f64>,
    /// (last event − first delta) / (tokens − 1), ms
    tpot_ms: Option<f64>,
    tokens: usize,
    prompt_tokens: usize,
    /// `"cached_tokens"` echoed by the server (prefix-cache reuse)
    cached_tokens: usize,
    /// server-side wall prefill, seconds (echoed on the reply)
    prefill_seconds: f64,
    /// final text — the next turn's prompt extends it verbatim
    text: String,
}

/// Send one streaming turn on a fresh connection and time its lifecycle.
fn drive_turn(
    addr: &str,
    id: u64,
    prompt: &str,
    max_new: usize,
    method: SpecMethod,
    policy: VerifyPolicy,
) -> Result<TurnProbe> {
    let mut o = Value::obj();
    o.set("id", Value::Num(id as f64));
    o.set("prompt", Value::Str(prompt.to_string()));
    o.set("method", Value::Str(method.label()));
    o.set("policy", Value::Str(policy.label()));
    o.set("stream", Value::Bool(true));
    o.set("max_new", Value::Num(max_new as f64));
    o.set("temperature", Value::Num(0.0)); // turns must be reproducible
    o.set("seed", Value::Num((id % 1000) as f64));
    let mut probe = TurnProbe {
        ok: false,
        ttft_ms: None,
        tpot_ms: None,
        tokens: 0,
        prompt_tokens: crate::tokenizer::encode(prompt).len(),
        cached_tokens: 0,
        prefill_seconds: 0.0,
        text: String::new(),
    };
    let sent = Instant::now();
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting {addr}"))?;
    // the chat path reads the socket directly, so the client wall
    // deadline lands as a read timeout: a wedged server errors the turn
    // (the worker abandons the conversation) instead of hanging it
    stream.set_read_timeout(Some(CLIENT_WALL_DEADLINE))?;
    writeln!(stream, "{}", o.to_string_json())?;
    let reader = BufReader::new(stream);
    let mut first_delta: Option<Instant> = None;
    let mut last_event: Option<Instant> = None;
    for line in reader.lines() {
        let line = line?;
        let v = Value::parse(&line)
            .map_err(|e| anyhow::anyhow!("bad reply: {e}"))?;
        let now = Instant::now();
        let done = v.get("done").and_then(|b| b.as_bool()).unwrap_or(false);
        if v.get("delta").is_some() && !done {
            if first_delta.is_none() {
                first_delta = Some(now);
                probe.ttft_ms =
                    Some(now.duration_since(sent).as_secs_f64() * 1e3);
            }
            last_event = Some(now);
            continue;
        }
        // terminal reply
        probe.ok = v.get("ok").and_then(|b| b.as_bool()) == Some(true);
        probe.tokens =
            v.get("tokens").and_then(|t| t.as_usize()).unwrap_or(0);
        probe.cached_tokens = v
            .get("cached_tokens")
            .and_then(|t| t.as_usize())
            .unwrap_or(0);
        probe.prefill_seconds = v
            .get("prefill_seconds")
            .and_then(|t| t.as_f64())
            .unwrap_or(0.0);
        probe.text = v
            .get("text")
            .and_then(|t| t.as_str())
            .unwrap_or("")
            .to_string();
        if let (Some(first), Some(last)) =
            (first_delta, last_event.or(Some(now)))
        {
            if probe.tokens > 1 {
                probe.tpot_ms = Some(
                    last.duration_since(first).as_secs_f64() * 1e3
                        / (probe.tokens - 1) as f64,
                );
            }
        }
        return Ok(probe);
    }
    bail!("connection closed before the terminal reply")
}

/// Per-wave (cache on/off) chat outcome.
struct ChatRow {
    label: String,
    ok: usize,
    err: usize,
    ttft_ms: Summary,
    tpot_ms: Summary,
    /// follow-up turns only (turn >= 1): where prefix reuse can land
    follow_prefill_ms: Summary,
    follow_cached_tok: Summary,
    follow_sim_units: Summary,
    first_sim_units: Summary,
    tok_per_s: f64,
}

/// The multi-turn chat scenario: the same conversation workload twice —
/// prefix cache on, then off — under `prefix_affinity` routing, so the
/// two rows isolate exactly what prefix reuse does to follow-up turns.
fn run_chat(cfg: &ServeBenchCfg, turns: usize) -> Result<()> {
    // the chat scenario isolates reuse, not the method x policy grid: it
    // drives ONE method and ONE policy (the first of each sweep list) so
    // the cache-on and cache-off rows differ in exactly one thing
    let method = *cfg.methods.first().unwrap_or(&SpecMethod::default());
    let policy = *cfg.policies.first().unwrap_or(&VerifyPolicy::Strict);
    if cfg.methods.len() > 1 || cfg.policies.len() > 1 {
        println!(
            "note: --scenario chat runs a single method x policy \
             combination; using {} / {}",
            method.label(),
            policy.label()
        );
    }
    let on_mb = if cfg.cache_mb == 0 {
        // the scenario's whole point is the on-vs-off comparison, so the
        // on wave needs a budget — say so instead of silently overriding
        // the flag's documented "0 disables" meaning
        println!(
            "note: --scenario chat always runs a cache-on wave; \
             --cache-mb 0 replaced by the {} MB default",
            crate::cache::DEFAULT_CACHE_MB
        );
        crate::cache::DEFAULT_CACHE_MB
    } else {
        cfg.cache_mb
    };
    // one clamp, shared by the workers and the rendered header: answers
    // must stay short enough that a whole conversation fits P_MAX
    let max_new = cfg.max_new.min(CHAT_MAX_NEW_CAP);
    let waves = [
        ("cache on", CacheConfig::with_mb(on_mb)),
        ("cache off", CacheConfig::disabled()),
    ];
    let mut rows = Vec::new();
    for (label, cache) in waves {
        println!(
            "starting {} replica(s) x {} slot(s) for chat wave '{label}' \
             ({})...",
            cfg.replicas.max(1),
            cfg.slots,
            cache.label()
        );
        let mut rcfg = RouterConfig::new(&cfg.artifact_dir);
        rcfg.replicas = cfg.replicas;
        rcfg.slots = cfg.slots;
        rcfg.policy = RouterPolicy::PrefixAffinity;
        rcfg.cache = cache;
        rcfg.batch = cfg.batch.max(1);
        rcfg.fault = cfg.fault.clone();
        rcfg.deadline_ms = cfg.deadline_ms;
        rcfg.shed_above = cfg.shed_above;
        let router = Arc::new(Router::start(rcfg)?);
        let handle = server::serve(router.clone(), "127.0.0.1:0")?;
        let addr = handle.addr.to_string();
        let row =
            drive_chat_wave(cfg, &addr, label, turns, max_new, method, policy)?;
        println!(
            "  {label}: {} ok / {} err turns, ttft p50 {:.0} ms, \
             follow-up prefill {:.1} ms / {:.2} sim units, \
             cached {:.1} tok/turn",
            row.ok,
            row.err,
            row.ttft_ms.p50(),
            row.follow_prefill_ms.mean(),
            row.follow_sim_units.mean(),
            row.follow_cached_tok.mean(),
        );
        eprintln!(
            "  server metrics ({label}): {}",
            router.metrics.snapshot_json().to_string_json()
        );
        rows.push(row);
    }

    let table = render_chat_table(cfg, turns, max_new, method, policy, &rows);
    println!("{table}");
    super::emit_md(&cfg.out_dir, "serve", &table)?;

    // machine-readable trajectory for PR-to-PR diffing (`bench diff`)
    let mut doc = record::RecordDoc::new(
        "serve",
        serve_env(cfg, "mars bench serve --scenario chat")?,
    );
    doc.config_num("n", cfg.n_requests as f64);
    doc.config_num("seed", cfg.seed as f64);
    doc.config_num("max_new", max_new as f64);
    doc.config_num("turns", turns as f64);
    doc.config_num("rate_per_s", cfg.rate_per_s);
    for r in &rows {
        let cache = if r.label.ends_with("on") { "on" } else { "off" };
        let keys = [
            ("scenario", "chat".to_string()),
            ("cache", cache.to_string()),
            ("method", method.label()),
            ("policy", policy.label()),
        ];
        let mut push = |metric: &str, value: f64, unit: &str| {
            doc.push(metric, value, unit, r.ok, cfg.seed, &keys);
        };
        push("ttft_ms_p50", r.ttft_ms.p50(), "ms");
        push("ttft_ms_p99", r.ttft_ms.p99(), "ms");
        push("tpot_ms_p50", r.tpot_ms.p50(), "ms");
        push("first_sim_units", r.first_sim_units.mean(), "units");
        push("follow_prefill_ms", r.follow_prefill_ms.mean(), "ms");
        push("follow_cached_tok", r.follow_cached_tok.mean(), "tok");
        push("follow_sim_units", r.follow_sim_units.mean(), "units");
        push("tok_per_s", r.tok_per_s, "tok/s");
        push("err", r.err as f64, "count");
    }
    emit_serve_records(cfg, &doc)?;
    Ok(())
}

/// Drive one chat wave: conversations arrive open-loop (Poisson); inside
/// a conversation the turns are closed-loop — turn t+1's prompt extends
/// turn t's prompt + answer verbatim, like a real chat client.
fn drive_chat_wave(
    cfg: &ServeBenchCfg,
    addr: &str,
    label: &str,
    turns: usize,
    max_new: usize,
    method: SpecMethod,
    policy: VerifyPolicy,
) -> Result<ChatRow> {
    let convs = chat_conversations(cfg.n_requests, turns, cfg.seed);
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let wave_started = Instant::now();
    let mut workers = Vec::new();
    let mut start_delay = 0.0f64;
    for (ci, conv) in convs.into_iter().enumerate() {
        start_delay += exp_arrival_gap(&mut rng, cfg.rate_per_s);
        let addr = addr.to_string();
        let worker = std::thread::Builder::new()
            .name(format!("mars-chat-{ci}"))
            .spawn(move || -> Vec<Option<TurnProbe>> {
                std::thread::sleep(Duration::from_secs_f64(start_delay));
                let mut answers: Vec<String> = Vec::new();
                let mut probes = Vec::new();
                for t in 0..conv.turns.len() {
                    let prompt = conv.prompt(t, &answers);
                    let id = (ci as u64 + 1) * 1000 + t as u64;
                    match drive_turn(&addr, id, &prompt, max_new, method, policy)
                    {
                        Ok(p) if p.ok => {
                            answers.push(p.text.clone());
                            probes.push(Some(p));
                        }
                        Ok(p) => {
                            probes.push(Some(p));
                            break; // lost turn: abandon the conversation
                        }
                        Err(_) => {
                            probes.push(None);
                            break;
                        }
                    }
                }
                probes
            })?;
        workers.push(worker);
    }

    let mut row = ChatRow {
        label: label.to_string(),
        ok: 0,
        err: 0,
        ttft_ms: Summary::new(),
        tpot_ms: Summary::new(),
        follow_prefill_ms: Summary::new(),
        follow_cached_tok: Summary::new(),
        follow_sim_units: Summary::new(),
        first_sim_units: Summary::new(),
        tok_per_s: 0.0,
    };
    let mut tokens_total = 0usize;
    for w in workers {
        let probes = w.join().unwrap_or_default();
        for (t, p) in probes.into_iter().enumerate() {
            let Some(p) = p else {
                row.err += 1;
                continue;
            };
            if !p.ok {
                row.err += 1;
                continue;
            }
            row.ok += 1;
            tokens_total += p.tokens;
            if let Some(ttft) = p.ttft_ms {
                row.ttft_ms.push(ttft);
            }
            if let Some(tpot) = p.tpot_ms {
                row.tpot_ms.push(tpot);
            }
            let uncached = p.prompt_tokens.saturating_sub(p.cached_tokens);
            let sim = super::simclock::prefill_units(uncached);
            if t == 0 {
                row.first_sim_units.push(sim);
            } else {
                row.follow_prefill_ms.push(p.prefill_seconds * 1e3);
                row.follow_cached_tok.push(p.cached_tokens as f64);
                row.follow_sim_units.push(sim);
            }
        }
    }
    let wall = wave_started.elapsed().as_secs_f64().max(1e-9);
    row.tok_per_s = tokens_total as f64 / wall;
    Ok(row)
}

fn render_chat_table(
    cfg: &ServeBenchCfg,
    turns: usize,
    max_new: usize,
    method: SpecMethod,
    policy: VerifyPolicy,
    rows: &[ChatRow],
) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "## Serve — multi-turn chat scenario: {} conversations x {turns} \
         turns, {:.1} conv/s Poisson, max_new={max_new}, {} / {}, \
         prefix_affinity routing\n",
        cfg.n_requests,
        cfg.rate_per_s,
        method.label(),
        policy.label()
    );
    let _ = writeln!(
        out,
        "| Cache | turns ok/err | TTFT p50 (ms) | TTFT p99 (ms) | \
         TPOT p50 (ms) | first-turn prefill sim units | follow-up \
         prefill ms | follow-up cached tok/turn | follow-up prefill sim \
         units | tok/s |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {}/{} | {:.0} | {:.0} | {:.2} | {:.2} | {:.1} | \
             {:.1} | {:.2} | {:.1} |",
            r.label,
            r.ok,
            r.err,
            r.ttft_ms.p50(),
            r.ttft_ms.p99(),
            r.tpot_ms.p50(),
            r.first_sim_units.mean(),
            r.follow_prefill_ms.mean(),
            r.follow_cached_tok.mean(),
            r.follow_sim_units.mean(),
            r.tok_per_s
        );
    }
    let _ = writeln!(
        out,
        "\nEach turn's prompt extends the previous turn + answer \
         byte-for-byte, so with the cache on, follow-up turns restore \
         the shared prefix from the replica's snapshot store and prefill \
         only the new turn (`cached tok/turn` > 0 and `prefill sim \
         units` — simclock blocks of {} tokens per target forward — \
         drop vs the cache-off row). First turns start cold unless an \
         identical first-turn prompt already ran (the system/question \
         pools are small on purpose). Wall-clock prefill ms on this \
         substrate also carries the snapshot upload (~MB state vector), \
         so the sim column is the paper-regime number; see \
         BENCHMARKS.md.",
        super::simclock::PREFILL_BLOCK_TOKENS
    );
    out
}

fn render_table(cfg: &ServeBenchCfg, rows: &[PolicyRow]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "## Serve — open-loop load, {} conns, {:.1} req/s Poisson, \
         n={} per wave, max_new={}\n",
        cfg.connections, cfg.rate_per_s, cfg.n_requests, cfg.max_new
    );
    let _ = writeln!(
        out,
        "| Method / Policy | ok/err | TTFT p50 (ms) | TTFT p99 (ms) | \
         TPOT p50 (ms) | TPOT p99 (ms) | tok/s | req/s |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {}/{} | {:.0} | {:.0} | {:.2} | {:.2} | {:.1} | {:.2} |",
            r.label,
            r.ok,
            r.err,
            r.ttft_ms.p50(),
            r.ttft_ms.p99(),
            r.tpot_ms.p50(),
            r.tpot_ms.p99(),
            r.tok_per_s,
            r.req_per_s
        );
    }
    let _ = writeln!(
        out,
        "\nTTFT = send -> first streamed delta (client-side, includes the \
         socket hop); TPOT = (last event - first delta)/(tokens-1). \
         Wall-clock on this substrate — compare shapes across rows \
         (method vs method, policy vs policy), not absolute numbers \
         against the paper (see BENCHMARKS.md)."
    );
    out
}
